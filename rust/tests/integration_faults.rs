//! Fault-injection, elastic membership and bounded-staleness quorum.
//!
//! The robustness layer extends the determinism contract: a fixed seed
//! PLUS a fixed [`FaultPlan`] must give bit-identical training runs for
//! every thread count and pipeline mode, because skew/jitter/quorum are
//! pure functions of (plan, uid, step) and membership events fire
//! strictly between steps. These tests pin that contract, the
//! residual-conservation guarantee of elastic re-sharding, the
//! bounded-staleness telemetry, and the merge-capacity re-sizing fix.

use lags::cluster::faults::{FaultPlan, MembershipAction, MembershipEvent};
use lags::cluster::Cluster;
use lags::collectives::PipelineMode;
use lags::config::TrainConfig;
use lags::runtime::Runtime;
use lags::trainer::{Algorithm, MessageStats, Trainer};
use std::sync::Arc;

fn cfg(model: &str, alg: Algorithm, steps: usize, workers: usize, threads: usize) -> TrainConfig {
    let mut c = TrainConfig::default_for(model);
    c.algorithm = alg;
    c.steps = steps;
    c.workers = workers;
    c.threads = threads;
    c.lr = 0.1;
    c.compression = 20.0;
    c.eval_every = 0;
    c
}

fn ev(step: usize, action: MembershipAction, worker: usize) -> MembershipEvent {
    MembershipEvent { step, action, worker }
}

/// Run the full loop step-by-step, returning (per-step losses, final
/// params, message stats).
fn run_traced(rt: &Arc<Runtime>, cfg: TrainConfig) -> (Vec<f64>, Vec<f32>, MessageStats) {
    let steps = cfg.steps;
    let mut t = Trainer::with_runtime(rt, cfg).expect("build trainer");
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        losses.push(t.step().expect("step"));
    }
    (losses, t.params().to_vec(), t.msg_stats().clone())
}

/// Skew + link jitter + a drop and a re-join mid-run.
fn chaotic_plan() -> FaultPlan {
    FaultPlan {
        seed: 11,
        compute_skew: vec![1.0, 2.0, 1.0, 1.0],
        alpha_jitter: 0.15,
        bandwidth_jitter: 0.15,
        events: vec![ev(3, MembershipAction::Drop, 1), ev(5, MembershipAction::Join, 4)],
    }
}

#[test]
fn fault_plan_bit_identical_across_threads_and_modes() {
    // same seed + same plan ⇒ bit-identical losses, params and message
    // stats, with skew, jitter, a drop, a join AND the quorum active —
    // for both algorithms, both pipeline modes, several thread counts,
    // and across repeated runs (the plan's jitter streams are seeded,
    // never wall-clock)
    let rt = Arc::new(Runtime::native(42));
    for (alg, quorum) in [(Algorithm::Lags, 3usize), (Algorithm::Slgs, 0)] {
        let make = |mode: PipelineMode, threads: usize| {
            let mut c = cfg("mlp", alg, 7, 4, threads);
            c.faults = chaotic_plan();
            c.quorum = quorum;
            c.staleness_bound = if quorum > 0 { 2 } else { 0 };
            c.pipeline = mode;
            c
        };
        let (l0, p0, s0) = run_traced(&rt, make(PipelineMode::Barrier, 1));
        let (l1, p1, s1) = run_traced(&rt, make(PipelineMode::Barrier, 1));
        assert_eq!(l0, l1, "{}: rerun with the same plan diverged", alg.name());
        assert_eq!(p0, p1, "{}: rerun params diverged", alg.name());
        assert_eq!(s0, s1, "{}: rerun msg stats diverged", alg.name());
        for threads in [1usize, 3] {
            for mode in [PipelineMode::Barrier, PipelineMode::Overlap] {
                let (l, p, s) = run_traced(&rt, make(mode, threads));
                let tag = format!("{} {} threads={threads}", alg.name(), mode.name());
                assert_eq!(l0, l, "losses diverged under faults: {tag}");
                assert_eq!(p0, p, "params diverged under faults: {tag}");
                assert_eq!(s0, s, "msg stats diverged under faults: {tag}");
            }
        }
    }
}

#[test]
fn drop_resharding_conserves_residual_coordinate_sums() {
    // the elastic-membership invariant at the cluster level: dropping a
    // worker moves its residual mass wholesale onto survivors, so every
    // coordinate's cluster-wide sum is preserved (up to one f32 add),
    // and a join (fresh zero residual) changes nothing
    let d = 101usize;
    let mut c = Cluster::new(3, d, 16);
    for w in &mut c.workers {
        for i in 0..d {
            w.ef.add_residual_at(i, (w.id + 1) as f32 * 0.01 * (i as f32 - 50.0));
        }
    }
    let before = c.residual_coordinate_sums();
    c.drop_worker(1).unwrap();
    assert_eq!(c.size(), 2);
    let after = c.residual_coordinate_sums();
    for (i, (a, b)) in before.iter().zip(after.iter()).enumerate() {
        assert!((a - b).abs() < 1e-5, "coordinate {i} lost mass: {a} -> {b}");
    }
    c.join_worker(7, d, 16, &[50, 51]).unwrap();
    assert_eq!(c.size(), 3);
    assert_eq!(after, c.residual_coordinate_sums(), "a joiner must not shift residual mass");
    // dropping the last worker or an absent uid is refused
    assert!(c.drop_worker(99).is_err());
}

#[test]
fn trainer_drop_and_rejoin_completes_with_membership_log() {
    // end-to-end elastic run: a worker leaves at step 2 and a new one
    // joins at step 5; the run completes, the membership log records both
    // events with the post-event cluster sizes, per-worker membership
    // durations are tracked, and the residual state stays finite
    let rt = Arc::new(Runtime::native(101));
    let mut c = cfg("mlp", Algorithm::Lags, 8, 3, 2);
    c.faults.events = vec![ev(2, MembershipAction::Drop, 2), ev(5, MembershipAction::Join, 3)];
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    let mut losses = Vec::new();
    for _ in 0..8 {
        losses.push(t.step().unwrap());
    }
    assert!(losses.iter().all(|l| l.is_finite()), "losses stayed finite: {losses:?}");
    assert_eq!(t.cluster_size(), 3, "back to 3 workers after drop + join");
    let rb = t.robustness_stats();
    assert_eq!(rb.membership_log.len(), 2);
    assert_eq!(rb.membership_log[0].step, 2);
    assert_eq!(rb.membership_log[0].action, "drop");
    assert_eq!(rb.membership_log[0].worker, 2);
    assert_eq!(rb.membership_log[0].workers_after, 2);
    assert_eq!(rb.membership_log[1].step, 5);
    assert_eq!(rb.membership_log[1].action, "join");
    assert_eq!(rb.membership_log[1].worker, 3);
    assert_eq!(rb.membership_log[1].workers_after, 3);
    // membership durations: uid 0 full run, uid 2 until the drop, uid 3
    // from the join; skew defaults to nominal
    let active = |uid: usize| {
        let w = rb.worker_skew.iter().find(|w| w.worker == uid).expect("worker in telemetry");
        assert_eq!(w.skew, 1.0);
        w.steps_active
    };
    assert_eq!(active(0), 8);
    assert_eq!(active(2), 2);
    assert_eq!(active(3), 3);
    assert!(t.residual_coordinate_sums().iter().all(|s| s.is_finite()));
}

#[test]
fn quorum_with_permanent_drop_trains_to_healthy_loss() {
    // the acceptance scenario: LAGS with --quorum P-1 survives a
    // permanent mid-run drop. Late messages fold back into the excluded
    // worker's residual (no mass lost), so the final loss lands within a
    // generous band of the no-fault run and still decreases end to end.
    let rt = Arc::new(Runtime::native(103));
    let (clean_losses, _, _) = run_traced(&rt, cfg("mlp", Algorithm::Lags, 40, 4, 2));
    let clean_final = *clean_losses.last().unwrap();

    let mut c = cfg("mlp", Algorithm::Lags, 40, 4, 2);
    c.quorum = 3;
    c.staleness_bound = 4;
    c.faults.events = vec![ev(10, MembershipAction::Drop, 1)];
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    let mut losses = Vec::new();
    for _ in 0..40 {
        losses.push(t.step().unwrap());
    }
    let (first, last) = (losses[0], *losses.last().unwrap());
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(last < first, "faulted run still converges ({first} -> {last})");
    assert!(
        last < clean_final * 2.0 + 0.1,
        "faulted final loss {last} too far from clean {clean_final}"
    );
    assert_eq!(t.cluster_size(), 3, "the drop is permanent");
    let rb = t.robustness_stats();
    assert_eq!(rb.quorum, 3);
    assert_eq!(rb.membership_log.len(), 1);
    assert!(rb.total_quorum_misses() > 0, "P=4 at quorum 3 must exclude someone");
    assert!(t.residual_coordinate_sums().iter().all(|s| s.is_finite()));
}

#[test]
fn quorum_telemetry_counts_misses_and_bounded_staleness() {
    // P=3 at quorum 2 with an 8× straggler and no jitter: the straggler
    // is excluded every step until the staleness bound (3) forces it back
    // in, displacing a nominal worker that step. Over 8 steps the pure
    // selection function yields exactly 8 (step × worker) exclusions and
    // two forced re-inclusions at staleness 3 — pinned here so the
    // telemetry (and the selection semantics behind it) cannot drift.
    let rt = Arc::new(Runtime::native(107));
    let mut c = cfg("mlp", Algorithm::Lags, 8, 3, 2);
    c.faults.compute_skew = vec![1.0, 8.0, 1.0];
    c.quorum = 2;
    c.staleness_bound = 3;
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    for _ in 0..8 {
        t.step().unwrap();
    }
    let rb = t.robustness_stats();
    assert_eq!(rb.quorum, 2);
    assert_eq!(rb.staleness_bound, 3);
    let nl = t.model_manifest().layers.len();
    assert_eq!(rb.quorum_miss_per_layer.len(), nl);
    assert!(
        rb.quorum_miss_per_layer.iter().all(|&m| m == 8),
        "every layer misses each excluded worker once per step: {:?}",
        rb.quorum_miss_per_layer
    );
    assert_eq!(rb.total_quorum_misses(), 8 * nl as u64);
    assert_eq!(rb.max_staleness(), 3, "bound 3 caps the backlog");
    assert_eq!(rb.staleness_hist[3], 2, "two forced re-inclusions over 8 steps");
    let straggler = rb.worker_skew.iter().find(|w| w.worker == 1).unwrap();
    assert_eq!(straggler.skew, 8.0);
    assert_eq!(straggler.steps_active, 8);
}

#[test]
fn membership_change_recomputes_merge_capacity() {
    // the §5 merge-buffer capacity is merge_bytes × CURRENT P; it used to
    // stay frozen at the startup worker count, silently over-grouping
    // after a drop. Two drops must shrink it twice.
    let rt = Arc::new(Runtime::native(109));
    let mut c = cfg("mlp_deep", Algorithm::Lags, 3, 4, 2);
    c.merge_bytes = 4096;
    c.faults.events = vec![ev(1, MembershipAction::Drop, 3), ev(2, MembershipAction::Drop, 2)];
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    assert_eq!(t.merge_capacity_bytes(), 4096 * 4);
    t.step().unwrap(); // step 0: no event
    assert_eq!(t.merge_capacity_bytes(), 4096 * 4);
    t.step().unwrap(); // step 1: drop → P=3
    assert_eq!(t.merge_capacity_bytes(), 4096 * 3, "capacity tracks the live membership");
    t.step().unwrap(); // step 2: drop → P=2
    assert_eq!(t.merge_capacity_bytes(), 4096 * 2);
    assert_eq!(t.cluster_size(), 2);
}
