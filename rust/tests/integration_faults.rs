//! Fault-injection, elastic membership and bounded-staleness quorum.
//!
//! The robustness layer extends the determinism contract: a fixed seed
//! PLUS a fixed [`FaultPlan`] must give bit-identical training runs for
//! every thread count and pipeline mode, because skew/jitter/quorum are
//! pure functions of (plan, uid, step) and membership events fire
//! strictly between steps. These tests pin that contract, the
//! residual-conservation guarantee of elastic re-sharding, the
//! bounded-staleness telemetry, and the merge-capacity re-sizing fix.

use lags::cluster::faults::{CrashPoint, FaultPlan, MembershipAction, MembershipEvent};
use lags::cluster::Cluster;
use lags::collectives::PipelineMode;
use lags::config::TrainConfig;
use lags::runtime::Runtime;
use lags::sparsify::CompressorKind;
use lags::trainer::{Algorithm, Checkpoint, MessageStats, Trainer};
use std::sync::Arc;

fn cfg(model: &str, alg: Algorithm, steps: usize, workers: usize, threads: usize) -> TrainConfig {
    let mut c = TrainConfig::default_for(model);
    c.algorithm = alg;
    c.steps = steps;
    c.workers = workers;
    c.threads = threads;
    c.lr = 0.1;
    c.compression = 20.0;
    c.eval_every = 0;
    c
}

fn ev(step: usize, action: MembershipAction, worker: usize) -> MembershipEvent {
    MembershipEvent { step, action, worker }
}

/// Run the full loop step-by-step, returning (per-step losses, final
/// params, message stats).
fn run_traced(rt: &Arc<Runtime>, cfg: TrainConfig) -> (Vec<f64>, Vec<f32>, MessageStats) {
    let steps = cfg.steps;
    let mut t = Trainer::with_runtime(rt, cfg).expect("build trainer");
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        losses.push(t.step().expect("step"));
    }
    (losses, t.params().to_vec(), t.msg_stats().clone())
}

/// Skew + link jitter + a drop and a re-join mid-run.
fn chaotic_plan() -> FaultPlan {
    FaultPlan {
        seed: 11,
        compute_skew: vec![1.0, 2.0, 1.0, 1.0],
        alpha_jitter: 0.15,
        bandwidth_jitter: 0.15,
        events: vec![ev(3, MembershipAction::Drop, 1), ev(5, MembershipAction::Join, 4)],
        ..FaultPlan::none()
    }
}

#[test]
fn fault_plan_bit_identical_across_threads_and_modes() {
    // same seed + same plan ⇒ bit-identical losses, params and message
    // stats, with skew, jitter, a drop, a join AND the quorum active —
    // for both algorithms, both pipeline modes, several thread counts,
    // and across repeated runs (the plan's jitter streams are seeded,
    // never wall-clock)
    let rt = Arc::new(Runtime::native(42));
    for (alg, quorum) in [(Algorithm::Lags, 3usize), (Algorithm::Slgs, 0)] {
        let make = |mode: PipelineMode, threads: usize| {
            let mut c = cfg("mlp", alg, 7, 4, threads);
            c.faults = chaotic_plan();
            c.quorum = quorum;
            c.staleness_bound = if quorum > 0 { 2 } else { 0 };
            c.pipeline = mode;
            c
        };
        let (l0, p0, s0) = run_traced(&rt, make(PipelineMode::Barrier, 1));
        let (l1, p1, s1) = run_traced(&rt, make(PipelineMode::Barrier, 1));
        assert_eq!(l0, l1, "{}: rerun with the same plan diverged", alg.name());
        assert_eq!(p0, p1, "{}: rerun params diverged", alg.name());
        assert_eq!(s0, s1, "{}: rerun msg stats diverged", alg.name());
        for threads in [1usize, 3] {
            for mode in [PipelineMode::Barrier, PipelineMode::Overlap] {
                let (l, p, s) = run_traced(&rt, make(mode, threads));
                let tag = format!("{} {} threads={threads}", alg.name(), mode.name());
                assert_eq!(l0, l, "losses diverged under faults: {tag}");
                assert_eq!(p0, p, "params diverged under faults: {tag}");
                assert_eq!(s0, s, "msg stats diverged under faults: {tag}");
            }
        }
    }
}

#[test]
fn drop_resharding_conserves_residual_coordinate_sums() {
    // the elastic-membership invariant at the cluster level: dropping a
    // worker moves its residual mass wholesale onto survivors, so every
    // coordinate's cluster-wide sum is preserved (up to one f32 add),
    // and a join (fresh zero residual) changes nothing
    let d = 101usize;
    let mut c = Cluster::new(3, d, 16, CompressorKind::HostExact);
    for w in &mut c.workers {
        for i in 0..d {
            w.ef.add_residual_at(i, (w.id + 1) as f32 * 0.01 * (i as f32 - 50.0));
        }
    }
    let before = c.residual_coordinate_sums();
    c.drop_worker(1).unwrap();
    assert_eq!(c.size(), 2);
    let after = c.residual_coordinate_sums();
    for (i, (a, b)) in before.iter().zip(after.iter()).enumerate() {
        assert!((a - b).abs() < 1e-5, "coordinate {i} lost mass: {a} -> {b}");
    }
    c.join_worker(7, d, 16, CompressorKind::HostExact, &[50, 51]).unwrap();
    assert_eq!(c.size(), 3);
    assert_eq!(after, c.residual_coordinate_sums(), "a joiner must not shift residual mass");
    // dropping the last worker or an absent uid is refused
    assert!(c.drop_worker(99).is_err());
}

#[test]
fn trainer_drop_and_rejoin_completes_with_membership_log() {
    // end-to-end elastic run: a worker leaves at step 2 and a new one
    // joins at step 5; the run completes, the membership log records both
    // events with the post-event cluster sizes, per-worker membership
    // durations are tracked, and the residual state stays finite
    let rt = Arc::new(Runtime::native(101));
    let mut c = cfg("mlp", Algorithm::Lags, 8, 3, 2);
    c.faults.events = vec![ev(2, MembershipAction::Drop, 2), ev(5, MembershipAction::Join, 3)];
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    let mut losses = Vec::new();
    for _ in 0..8 {
        losses.push(t.step().unwrap());
    }
    assert!(losses.iter().all(|l| l.is_finite()), "losses stayed finite: {losses:?}");
    assert_eq!(t.cluster_size(), 3, "back to 3 workers after drop + join");
    let rb = t.robustness_stats();
    assert_eq!(rb.membership_log.len(), 2);
    assert_eq!(rb.membership_log[0].step, 2);
    assert_eq!(rb.membership_log[0].action, "drop");
    assert_eq!(rb.membership_log[0].worker, 2);
    assert_eq!(rb.membership_log[0].workers_after, 2);
    assert_eq!(rb.membership_log[1].step, 5);
    assert_eq!(rb.membership_log[1].action, "join");
    assert_eq!(rb.membership_log[1].worker, 3);
    assert_eq!(rb.membership_log[1].workers_after, 3);
    // membership durations: uid 0 full run, uid 2 until the drop, uid 3
    // from the join; skew defaults to nominal
    let active = |uid: usize| {
        let w = rb.worker_skew.iter().find(|w| w.worker == uid).expect("worker in telemetry");
        assert_eq!(w.skew, 1.0);
        w.steps_active
    };
    assert_eq!(active(0), 8);
    assert_eq!(active(2), 2);
    assert_eq!(active(3), 3);
    assert!(t.residual_coordinate_sums().iter().all(|s| s.is_finite()));
}

#[test]
fn quorum_with_permanent_drop_trains_to_healthy_loss() {
    // the acceptance scenario: LAGS with --quorum P-1 survives a
    // permanent mid-run drop. Late messages fold back into the excluded
    // worker's residual (no mass lost), so the final loss lands within a
    // generous band of the no-fault run and still decreases end to end.
    let rt = Arc::new(Runtime::native(103));
    let (clean_losses, _, _) = run_traced(&rt, cfg("mlp", Algorithm::Lags, 40, 4, 2));
    let clean_final = *clean_losses.last().unwrap();

    let mut c = cfg("mlp", Algorithm::Lags, 40, 4, 2);
    c.quorum = 3;
    c.staleness_bound = 4;
    c.faults.events = vec![ev(10, MembershipAction::Drop, 1)];
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    let mut losses = Vec::new();
    for _ in 0..40 {
        losses.push(t.step().unwrap());
    }
    let (first, last) = (losses[0], *losses.last().unwrap());
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(last < first, "faulted run still converges ({first} -> {last})");
    assert!(
        last < clean_final * 2.0 + 0.1,
        "faulted final loss {last} too far from clean {clean_final}"
    );
    assert_eq!(t.cluster_size(), 3, "the drop is permanent");
    let rb = t.robustness_stats();
    assert_eq!(rb.quorum, 3);
    assert_eq!(rb.membership_log.len(), 1);
    assert!(rb.total_quorum_misses() > 0, "P=4 at quorum 3 must exclude someone");
    assert!(t.residual_coordinate_sums().iter().all(|s| s.is_finite()));
}

#[test]
fn quorum_telemetry_counts_misses_and_bounded_staleness() {
    // P=3 at quorum 2 with an 8× straggler and no jitter: the straggler
    // is excluded every step until the staleness bound (3) forces it back
    // in, displacing a nominal worker that step. Over 8 steps the pure
    // selection function yields exactly 8 (step × worker) exclusions and
    // two forced re-inclusions at staleness 3 — pinned here so the
    // telemetry (and the selection semantics behind it) cannot drift.
    let rt = Arc::new(Runtime::native(107));
    let mut c = cfg("mlp", Algorithm::Lags, 8, 3, 2);
    c.faults.compute_skew = vec![1.0, 8.0, 1.0];
    c.quorum = 2;
    c.staleness_bound = 3;
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    for _ in 0..8 {
        t.step().unwrap();
    }
    let rb = t.robustness_stats();
    assert_eq!(rb.quorum, 2);
    assert_eq!(rb.staleness_bound, 3);
    let nl = t.model_manifest().layers.len();
    assert_eq!(rb.quorum_miss_per_layer.len(), nl);
    assert!(
        rb.quorum_miss_per_layer.iter().all(|&m| m == 8),
        "every layer misses each excluded worker once per step: {:?}",
        rb.quorum_miss_per_layer
    );
    assert_eq!(rb.total_quorum_misses(), 8 * nl as u64);
    assert_eq!(rb.max_staleness(), 3, "bound 3 caps the backlog");
    assert_eq!(rb.staleness_hist[3], 2, "two forced re-inclusions over 8 steps");
    let straggler = rb.worker_skew.iter().find(|w| w.worker == 1).unwrap();
    assert_eq!(straggler.skew, 8.0);
    assert_eq!(straggler.steps_active, 8);
}

#[test]
fn membership_change_recomputes_merge_capacity() {
    // the §5 merge-buffer capacity is merge_bytes × CURRENT P; it used to
    // stay frozen at the startup worker count, silently over-grouping
    // after a drop. Two drops must shrink it twice.
    let rt = Arc::new(Runtime::native(109));
    let mut c = cfg("mlp_deep", Algorithm::Lags, 3, 4, 2);
    c.merge_bytes = 4096;
    c.faults.events = vec![ev(1, MembershipAction::Drop, 3), ev(2, MembershipAction::Drop, 2)];
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    assert_eq!(t.merge_capacity_bytes(), 4096 * 4);
    t.step().unwrap(); // step 0: no event
    assert_eq!(t.merge_capacity_bytes(), 4096 * 4);
    t.step().unwrap(); // step 1: drop → P=3
    assert_eq!(t.merge_capacity_bytes(), 4096 * 3, "capacity tracks the live membership");
    t.step().unwrap(); // step 2: drop → P=2
    assert_eq!(t.merge_capacity_bytes(), 4096 * 2);
    assert_eq!(t.cluster_size(), 2);
}

/// Fresh scratch dir for checkpoint files, unique per test and process.
fn ckdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("lags-ckpt-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn kill_and_resume_is_bit_identical_to_uninterrupted() {
    // the checkpoint acceptance matrix: a crash@5 with --checkpoint-every 2
    // followed by a resume must replay to EXACTLY the uninterrupted run —
    // bit-identical per-step losses, final params and message stats — for
    // a dense, a conv and a recurrent model, both pipeline modes and two
    // thread counts. The crash fires before any step-5 mutation, so the
    // step-4 checkpoint replays steps 4..; its tombstone disarms the
    // crash event on the resumed run.
    let rt = Arc::new(Runtime::native(42));
    let steps = 8usize;
    let crash = 5usize;
    for model in ["mlp", "convnet", "rnn"] {
        for mode in [PipelineMode::Barrier, PipelineMode::Overlap] {
            for threads in [1usize, 3] {
                let tag = format!("{model}-{}-t{threads}", mode.name());
                let mut clean = cfg(model, Algorithm::Lags, steps, 3, threads);
                clean.pipeline = mode;
                let (ref_losses, ref_params, ref_stats) = run_traced(&rt, clean.clone());

                let dir = ckdir(&tag);
                let mut c = clean;
                c.checkpoint_every = 2;
                c.checkpoint_dir = dir.to_string_lossy().into_owned();
                c.faults.crashes = vec![crash];
                let mut t = Trainer::with_runtime(&rt, c).unwrap();
                let mut losses = Vec::new();
                let err = loop {
                    match t.step() {
                        Ok(l) => losses.push(l),
                        Err(e) => break e,
                    }
                };
                let cp = err.downcast_ref::<CrashPoint>().expect("a CrashPoint error");
                assert_eq!(cp.0, crash, "{tag}: crash fired at the scheduled step");
                assert_eq!(losses.len(), crash, "{tag}: steps completed before the crash");
                assert!(
                    Trainer::checkpoint_path(&dir.to_string_lossy()).is_file(),
                    "{tag}: a checkpoint exists at the crash"
                );
                drop(t); // the "killed" process

                let mut r = Trainer::resume_with_runtime(&rt, &dir.to_string_lossy()).unwrap();
                assert_eq!(r.step_index(), 4, "{tag}: resumed from the last boundary");
                while r.step_index() < steps {
                    let s = r.step_index();
                    let l = r.step().unwrap_or_else(|e| panic!("{tag}: resumed step {s}: {e:#}"));
                    if s < losses.len() {
                        assert_eq!(losses[s], l, "{tag}: replayed step {s} diverged");
                    } else {
                        losses.push(l);
                    }
                }
                assert_eq!(ref_losses, losses, "{tag}: losses diverged after resume");
                assert_eq!(ref_params, r.params().to_vec(), "{tag}: final params diverged");
                assert_eq!(ref_stats, *r.msg_stats(), "{tag}: message stats diverged");
                std::fs::remove_dir_all(&dir).ok();
            }
        }
    }
}

#[test]
fn prop_checkpoint_round_trip_is_bit_identical() {
    // arbitrary zoo model × compressor × P × threads × algorithm: warm up
    // `size` steps, save, resume into a second trainer, and step both —
    // the next step must be bit-identical (loss, params, message stats,
    // δ series), i.e. the checkpoint captures the COMPLETE deterministic
    // state
    let rt = Arc::new(Runtime::native(7));
    let models = ["mlp", "mlp_deep", "convnet", "rnn"];
    let mut case_no = 0usize;
    lags::util::prop::check(
        "checkpoint-round-trip",
        lags::util::prop::Config { cases: 10, seed: 0x5EED_CDE7 },
        1,
        4,
        |case| {
            case_no += 1;
            let model = models[case.rng.below(models.len())];
            let alg =
                if case.rng.below(4) == 0 { Algorithm::Slgs } else { Algorithm::Lags };
            let workers = 2 + case.rng.below(3);
            let threads = 1 + case.rng.below(2);
            let warm = case.size;
            let mut c = cfg(model, alg, warm + 1, workers, threads);
            c.compressor = if case.rng.below(2) == 0 {
                CompressorKind::HostExact
            } else {
                CompressorKind::HostSampled
            };
            if case.rng.below(2) == 0 {
                c.pipeline = PipelineMode::Barrier;
            }
            if alg == Algorithm::Lags && case.rng.below(2) == 0 {
                c.delta_every = 1; // exercise the δ monitor's RNG stream
            }
            let dir = ckdir(&format!("prop{case_no}"));
            c.checkpoint_dir = dir.to_string_lossy().into_owned();
            let mut a = Trainer::with_runtime(&rt, c).map_err(|e| format!("build: {e:#}"))?;
            for s in 0..warm {
                a.step().map_err(|e| format!("warm step {s}: {e:#}"))?;
            }
            a.save_checkpoint().map_err(|e| format!("save: {e:#}"))?;
            let mut b = Trainer::resume_with_runtime(&rt, &dir.to_string_lossy())
                .map_err(|e| format!("resume: {e:#}"))?;
            let la = a.step().map_err(|e| format!("original step: {e:#}"))?;
            let lb = b.step().map_err(|e| format!("resumed step: {e:#}"))?;
            std::fs::remove_dir_all(&dir).ok();
            if la.to_bits() != lb.to_bits() {
                return Err(format!("loss diverged: {la} vs {lb}"));
            }
            if a.params() != b.params() {
                return Err("params diverged".into());
            }
            if a.msg_stats() != b.msg_stats() {
                return Err("message stats diverged".into());
            }
            if a.delta_series() != b.delta_series() {
                return Err("δ series diverged".into());
            }
            Ok(())
        },
    );
}

#[test]
fn checkpoint_preserves_online_profile_and_delta_rng() {
    // the online EWMA profile, the selection history and the δ monitor's
    // RandK stream position are deterministic state too: a resumed
    // trainer must carry the exact snapshot (asserted by re-capturing
    // both sides), not re-measure from scratch
    let rt = Arc::new(Runtime::native(42));
    let mut c = cfg("mlp", Algorithm::Lags, 10, 3, 2);
    c.adaptive = true;
    c.reselect_every = 50; // arm online measurement; no reselect in-window
    c.delta_every = 2;
    let dir = ckdir("online");
    c.checkpoint_dir = dir.to_string_lossy().into_owned();
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    for _ in 0..4 {
        t.step().unwrap();
    }
    t.save_checkpoint().unwrap();
    let r = Trainer::resume_with_runtime(&rt, &dir.to_string_lossy()).unwrap();
    let a = Checkpoint::capture(&t);
    let b = Checkpoint::capture(&r);
    assert_eq!(a.step, b.step);
    assert!(a.online.is_some(), "adaptive + reselect_every arms the EWMA profile");
    assert_eq!(a.online, b.online, "measured-profile EWMAs survive the round trip");
    assert!(a.delta.is_some(), "delta_every arms the δ monitor");
    assert_eq!(a.delta, b.delta, "δ series + RNG stream position survive");
    assert_eq!(a.selections, b.selections, "selection history survives");
    assert_eq!(a.ratios, b.ratios);
    assert_eq!(a.ks, b.ks);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupted_or_truncated_checkpoint_fails_with_checksum_error() {
    // resume must refuse a damaged checkpoint loudly: a single flipped
    // byte or a truncated file both surface as a checksum error, and
    // restoring the original bytes makes the same directory resumable
    // again
    let rt = Arc::new(Runtime::native(42));
    let dir = ckdir("corrupt");
    let mut c = cfg("mlp", Algorithm::Lags, 4, 2, 1);
    c.checkpoint_dir = dir.to_string_lossy().into_owned();
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    t.step().unwrap();
    t.save_checkpoint().unwrap();
    let path = Trainer::checkpoint_path(&dir.to_string_lossy());
    let good = std::fs::read(&path).unwrap();

    let mut bad = good.clone();
    let mid = bad.len() / 2;
    bad[mid] ^= 0x40;
    std::fs::write(&path, &bad).unwrap();
    let err = match Trainer::resume_with_runtime(&rt, &dir.to_string_lossy()) {
        Ok(_) => panic!("a flipped byte must be refused"),
        Err(e) => e,
    };
    assert!(format!("{err:#}").contains("checksum"), "flipped byte: {err:#}");

    std::fs::write(&path, &good[..16]).unwrap();
    let err = match Trainer::resume_with_runtime(&rt, &dir.to_string_lossy()) {
        Ok(_) => panic!("a truncated file must be refused"),
        Err(e) => e,
    };
    assert!(format!("{err:#}").contains("checksum"), "truncated file: {err:#}");

    std::fs::write(&path, &good).unwrap();
    Trainer::resume_with_runtime(&rt, &dir.to_string_lossy())
        .expect("pristine bytes resume cleanly");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn recorded_trace_replays_as_a_fault_schedule() {
    // --record-trace → FaultPlan::from_trace → trace replay: a skewed
    // run's recorded per-step profile loads back as a valid fault
    // schedule, and a trace-driven run is bit-identical across repeats,
    // thread counts and pipeline modes (the trace is data, not wall
    // clock)
    let rt = Arc::new(Runtime::native(42));
    let path = std::env::temp_dir()
        .join(format!("lags-trace-rec-{}.json", std::process::id()));
    let mut c = cfg("mlp", Algorithm::Lags, 6, 3, 2);
    c.faults.compute_skew = vec![1.0, 3.0, 1.0];
    c.record_trace = path.to_string_lossy().into_owned();
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    for _ in 0..6 {
        t.step().unwrap();
    }
    t.write_trace().unwrap();

    let plan = FaultPlan::from_trace(&path.to_string_lossy()).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(plan.trace.len(), 6, "one trace row per recorded step");
    assert!(plan.trace.iter().all(|row| row.len() == 3), "one column per worker");
    assert!(plan.perturbs_time(), "a non-empty trace perturbs step timing");
    plan.validate(3).unwrap();
    assert!(
        plan.trace.iter().flatten().all(|m| m.is_finite() && *m > 0.0),
        "normalized multipliers are positive and finite"
    );

    let mut c2 = cfg("mlp", Algorithm::Lags, 5, 3, 2);
    c2.faults.trace = plan.trace.clone();
    let (l0, p0, s0) = run_traced(&rt, c2.clone());
    let (l1, p1, s1) = run_traced(&rt, c2.clone());
    assert_eq!(l0, l1, "trace replay reruns identically");
    assert_eq!(p0, p1);
    assert_eq!(s0, s1);
    let mut c3 = c2;
    c3.pipeline = PipelineMode::Barrier;
    c3.threads = 1;
    let (l2, p2, s2) = run_traced(&rt, c3);
    assert_eq!(l0, l2, "trace replay is mode- and thread-invariant");
    assert_eq!(p0, p2);
    assert_eq!(s0, s2);
}
