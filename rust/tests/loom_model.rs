//! Real-loom model of the overlap pipeline's cross-thread protocol.
//!
//! Compiled out unless `RUSTFLAGS="--cfg loom"` — the vendored build image
//! has no network, so `loom` cannot ship as a default dev-dependency; the
//! scheduled CI deep tier runs `cargo add loom --dev` and then executes
//! this harness (see .github/workflows/ci.yml, job `loom`). The plain
//! `cargo test` twin — same invariants, schedule enumeration instead of
//! loom's C11-model exploration — is `concurrency_model.rs`.
//!
//! What loom adds over the mini-loom sweep: it explores atomics/fence
//! reorderings and lock acquisition orders of the REAL synchronization
//! primitives, not just message-arrival permutations — so a missing
//! happens-before edge between a worker's publish and the aggregator's
//! slot read would surface here even though every arrival order looks
//! fine to the schedule enumerator.
#![cfg(loom)]

use loom::sync::{Arc, Mutex};
use loom::thread;

use lags::collectives::pipeline::{LayerMsg, StreamAggregator};
use lags::collectives::sparse_agg;
use lags::pipeline::merge::MergeBuffer;
use lags::sparsify::sparse::SparseVec;
use lags::util::clock;
use lags::util::rng::Rng;

const LAYER_N: usize = 8;

fn msg(rank: usize, layer: usize) -> SparseVec {
    let mut rng = Rng::new(0x10c0 + (rank * 17 + layer) as u64);
    let mut dense = vec![0.0f32; LAYER_N];
    for i in rng.sample_distinct(LAYER_N, 3) {
        dense[i] = rng.normal_f32();
    }
    SparseVec::from_dense(&dense)
}

fn reference(layers: usize, ranks: &[usize]) -> Vec<u32> {
    let mut out = vec![0.0f32; layers * LAYER_N];
    for li in 0..layers {
        let msgs: Vec<SparseVec> = ranks.iter().map(|&r| msg(r, li)).collect();
        sparse_agg::sparse_add_rank_ordered(
            msgs.iter(),
            &mut out[li * LAYER_N..(li + 1) * LAYER_N],
        );
    }
    out.iter().map(|x| x.to_bits()).collect()
}

/// Two racing publishers + the shared aggregator behind a lock: every
/// loom execution must fire layers in backprop order and reduce to the
/// same bits.
#[test]
fn loom_stream_aggregator_publish_fire_order() {
    let layers = 2usize;
    let p = 2usize;
    let want = reference(layers, &[0, 1]);
    loom::model(move || {
        let agg = Arc::new(Mutex::new(StreamAggregator::new(layers, p)));
        let fired = Arc::new(Mutex::new(Vec::<usize>::new()));
        let mut handles = Vec::new();
        for rank in 0..p {
            let agg = Arc::clone(&agg);
            let fired = Arc::clone(&fired);
            handles.push(thread::spawn(move || {
                for li in (0..layers).rev() {
                    let m = LayerMsg { rank, layer: li, msg: msg(rank, li), sent: clock::now() };
                    let mut a = agg.lock().unwrap();
                    let mut f = fired.lock().unwrap();
                    a.push(m, |l, _| f.push(l));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let a = agg.lock().unwrap();
        let f = fired.lock().unwrap();
        assert_eq!(*f, vec![1, 0], "backprop fire order on every loom execution");
        assert!(a.finished());
        let mut out = vec![0.0f32; layers * LAYER_N];
        for li in 0..layers {
            let msgs: Vec<&SparseVec> =
                a.layer_slots(li).iter().map(|s| s.as_ref().unwrap()).collect();
            sparse_agg::sparse_add_rank_ordered(
                msgs.into_iter(),
                &mut out[li * LAYER_N..(li + 1) * LAYER_N],
            );
        }
        let got: Vec<u32> = out.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, want, "bit-identical reduction on every loom execution");
    });
}

/// arm_participants vs a quorum-excluded straggler's late publishes: the
/// mask is armed before any push (the trainer's contract), the straggler
/// races the participants, and no execution lets it gate or refire.
#[test]
fn loom_quorum_mask_vs_straggler() {
    let layers = 2usize;
    let p = 3usize;
    let want = reference(layers, &[0, 2]);
    loom::model(move || {
        let agg = Arc::new(Mutex::new(StreamAggregator::new(layers, p)));
        agg.lock().unwrap().arm_participants(&[true, false, true]);
        let fired = Arc::new(Mutex::new(Vec::<usize>::new()));
        let mut handles = Vec::new();
        for rank in 0..p {
            let agg = Arc::clone(&agg);
            let fired = Arc::clone(&fired);
            handles.push(thread::spawn(move || {
                for li in (0..layers).rev() {
                    let m = LayerMsg { rank, layer: li, msg: msg(rank, li), sent: clock::now() };
                    let mut a = agg.lock().unwrap();
                    let mut f = fired.lock().unwrap();
                    a.push(m, |l, _| f.push(l));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let a = agg.lock().unwrap();
        assert_eq!(*fired.lock().unwrap(), vec![1, 0]);
        assert!(a.finished());
        let mut out = vec![0.0f32; layers * LAYER_N];
        for li in 0..layers {
            let msgs: Vec<&SparseVec> = a
                .layer_slots(li)
                .iter()
                .zip(a.required())
                .filter(|(_, &req)| req)
                .map(|(s, _)| s.as_ref().unwrap())
                .collect();
            sparse_agg::sparse_add_rank_ordered(
                msgs.into_iter(),
                &mut out[li * LAYER_N..(li + 1) * LAYER_N],
            );
        }
        let got: Vec<u32> = out.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, want);
    });
}

/// MergeBuffer capacity-resize racing a staging sequence: layers are
/// conserved (each in exactly one group, backprop order) and nothing is
/// left staged after the final flush, on every execution.
#[test]
fn loom_merge_capacity_resize() {
    let layers = 3usize;
    loom::model(move || {
        let merge = Arc::new(Mutex::new(MergeBuffer::<usize>::new(1000)));
        let groups = Arc::new(Mutex::new(Vec::<Vec<usize>>::new()));
        let pusher = {
            let merge = Arc::clone(&merge);
            let groups = Arc::clone(&groups);
            thread::spawn(move || {
                for li in (0..layers).rev() {
                    let mut m = merge.lock().unwrap();
                    m.push_with(li, 40, li);
                    for g in m.take_groups() {
                        groups.lock().unwrap().push(g.layer_indices);
                    }
                }
            })
        };
        let resizer = {
            let merge = Arc::clone(&merge);
            thread::spawn(move || {
                merge.lock().unwrap().set_capacity(50);
            })
        };
        pusher.join().unwrap();
        resizer.join().unwrap();
        let mut m = merge.lock().unwrap();
        m.flush();
        for g in m.take_groups() {
            groups.lock().unwrap().push(g.layer_indices);
        }
        assert_eq!(m.pending_bytes(), 0);
        let flat: Vec<usize> = groups.lock().unwrap().iter().flatten().copied().collect();
        assert_eq!(flat, vec![2, 1, 0], "conservation + backprop order");
    });
}
