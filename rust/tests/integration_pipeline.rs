//! Pipeline-level integration: DES reproductions of the paper's headline
//! timing claims + merge buffer numerics + adaptive ratios end-to-end +
//! the streaming/merge bit-identity contract on the heterogeneous zoo.

use lags::adaptive::{perf_model, ratio, RatioConfig};
use lags::collectives::{NetworkModel, PipelineMode};
use lags::config::TrainConfig;
use lags::models::zoo;
use lags::pipeline::desim::{simulate, Schedule, SimParams};
use lags::pipeline::merge::MergeBuffer;
use lags::runtime::Runtime;
use lags::sparsify::sparse::SparseVec;
use lags::trainer::{Algorithm, Trainer};
use lags::util::rng::Rng;
use std::sync::Arc;

fn net16() -> NetworkModel {
    NetworkModel::gige_16()
}

/// Paper headline: LAGS-SGD speedup over Dense-SGD between 2.86x and 8.52x
/// on the tested models (Table 2, S1 column).
#[test]
fn table2_s1_speedups_in_paper_band() {
    for m in zoo::table2_models() {
        let c = if m.name == "lstm_ptb" { 250.0 } else { 1000.0 };
        let sp = SimParams::uniform(&m, c);
        let dense = simulate(&m, &net16(), Schedule::DensePipelined, &SimParams::dense(&m));
        let lags = simulate(&m, &net16(), Schedule::Lags, &sp);
        let s1 = dense.iter_time / lags.iter_time;
        assert!(
            (1.8..12.0).contains(&s1),
            "{}: S1 = {s1} outside the plausible band",
            m.name
        );
    }
}

/// Paper headline: LAGS achieves a meaningful fraction of S_max, and the
/// LSTM (unbalanced layers) achieves the LOWEST fraction of the three.
#[test]
fn table2_smax_fraction_ordering() {
    let mut fractions = std::collections::BTreeMap::new();
    for m in zoo::table2_models() {
        let c = if m.name == "lstm_ptb" { 250.0 } else { 1000.0 };
        let sp = SimParams::uniform(&m, c);
        let slgs = simulate(&m, &net16(), Schedule::Slgs, &sp);
        let lags = simulate(&m, &net16(), Schedule::Lags, &sp);
        let s2 = slgs.iter_time / lags.iter_time;
        let smax = perf_model::smax(m.t_f, m.t_b(), slgs.t_comm);
        let frac = (s2 - 1.0) / (smax - 1.0);
        assert!(frac > 0.2, "{}: fraction {frac} too low", m.name);
        fractions.insert(m.name.clone(), frac);
    }
    let lstm = fractions["lstm_ptb"];
    assert!(
        lstm <= fractions["inception_v4"],
        "lstm fraction {lstm} should be the lowest (paper: 39.3% vs 96.5%)"
    );
}

/// SLGS calibration anchors (how the zoo profiles were fit): simulated
/// SLGS times must reproduce the paper's measured SLGS column.
#[test]
fn table2_slgs_calibration_anchors() {
    let paper = [("resnet50", 0.67), ("inception_v4", 1.60), ("lstm_ptb", 1.02)];
    for (name, expect) in paper {
        let m = zoo::by_name(name).unwrap();
        let c = if name == "lstm_ptb" { 250.0 } else { 1000.0 };
        let b = simulate(&m, &net16(), Schedule::Slgs, &SimParams::uniform(&m, c));
        let rel = (b.iter_time - expect).abs() / expect;
        assert!(rel < 0.10, "{name}: SLGS {:.3}s vs paper {expect}s", b.iter_time);
    }
}

/// Eq. 18 + DES composition: adaptive per-layer ratios must hide at least
/// as much communication as the paper's flat c_u on the conv profiles.
#[test]
fn adaptive_ratios_hide_more_than_uniform() {
    for name in ["resnet50", "inception_v4"] {
        let m = zoo::by_name(name).unwrap();
        let cfg = RatioConfig::default();
        let rs = ratio::select_ratios(&m, &net16(), &cfg);
        let mut p_adaptive = SimParams::uniform(&m, 1000.0);
        p_adaptive.ratios = rs;
        let uni = simulate(&m, &net16(), Schedule::Lags, &SimParams::uniform(&m, 1000.0));
        let ada = simulate(&m, &net16(), Schedule::Lags, &p_adaptive);
        // adaptive sends MORE data (lower c where it fits)...
        let uni_bytes: f64 = uni.events.iter().map(|e| e.wire_bytes).sum();
        let ada_bytes: f64 = ada.events.iter().map(|e| e.wire_bytes).sum();
        assert!(ada_bytes >= uni_bytes, "{name}: adaptive sent less than uniform");
        // ...while keeping the iteration within 10% of the uniform-c one
        assert!(
            ada.iter_time <= uni.iter_time * 1.10 + 1e-9,
            "{name}: adaptive iter {} vs uniform {}",
            ada.iter_time,
            uni.iter_time
        );
    }
}

/// Numeric merge buffer: grouped payloads must decode to exactly the same
/// aggregate as ungrouped, regardless of capacity.
#[test]
fn merge_buffer_numerics_invariant_under_capacity() {
    let mut rng = Rng::new(5);
    let n_layers = 12;
    let payloads: Vec<SparseVec> = (0..n_layers)
        .map(|_| {
            let mut d = vec![0.0f32; 400];
            for i in rng.sample_distinct(400, 25) {
                d[i] = rng.normal_f32();
            }
            SparseVec::from_dense(&d)
        })
        .collect();

    let collect = |capacity: usize| -> (usize, Vec<f32>) {
        let mut buf = MergeBuffer::new(capacity);
        for (i, p) in payloads.iter().enumerate() {
            buf.push(i, p.clone());
        }
        buf.flush();
        let groups = buf.take_groups();
        let n_groups = groups.len();
        // order-preserving decode
        let mut seen = Vec::new();
        for g in &groups {
            for (li, p) in g.layer_indices.iter().zip(g.payloads.iter()) {
                seen.push((*li, p.clone()));
            }
        }
        let mut agg = vec![0.0f32; 400 * n_layers];
        for (li, p) in seen {
            p.add_into(&mut agg[li * 400..(li + 1) * 400]);
        }
        (n_groups, agg)
    };

    let (g0, a0) = collect(0); // no merging
    let (g1, a1) = collect(600); // some merging
    let (g2, a2) = collect(usize::MAX); // single flush
    assert_eq!(g0, n_layers);
    assert!(g1 < g0);
    assert_eq!(g2, 1);
    assert_eq!(a0, a1);
    assert_eq!(a0, a2);
}

/// Eq. 19 sweep: S_max peaks at r = 1 and the peak equals 1 + t_b/(t_f+t_b).
#[test]
fn smax_sweep_shape() {
    let (t_f, t_b) = (0.18, 0.353); // resnet50 calibration
    let peak = 1.0 + t_b / (t_f + t_b);
    let mut max_seen: f64 = 0.0;
    for i in 0..=40 {
        let r = 0.05 * (i as f64 + 1.0);
        let s = perf_model::smax(t_f, t_b, r * t_b);
        assert!(s <= peak + 1e-9);
        max_seen = max_seen.max(s);
    }
    assert!((max_seen - peak).abs() < 1e-6, "peak {max_seen} vs bound {peak}");
}

/// Fig 1 qualitative shapes: LAGS starts communicating before backprop
/// ends; SLGS strictly after.
#[test]
fn fig1_comm_start_ordering() {
    let m = zoo::resnet50();
    let p = SimParams::uniform(&m, 1000.0);
    let comp_end = m.t_comp();
    let lags = simulate(&m, &net16(), Schedule::Lags, &p);
    let slgs = simulate(&m, &net16(), Schedule::Slgs, &p);
    assert!(lags.events.first().unwrap().start < comp_end, "LAGS did not overlap");
    assert!(slgs.events.first().unwrap().start >= comp_end - 1e-12);
    // dense pipelined also overlaps
    let dense = simulate(&m, &net16(), Schedule::DensePipelined, &SimParams::dense(&m));
    assert!(dense.events.first().unwrap().start < comp_end);
}

/// The streaming overlap + §5 merge buffer work UNCHANGED on the conv
/// and recurrent zoo models: overlap ≡ barrier, merge on ≡ merge off
/// (losses/params), threads a pure perf knob — for every algorithm.
#[test]
fn heterogeneous_zoo_pipeline_and_merge_bit_identity() {
    let rt = Arc::new(Runtime::native(97));
    for model in ["convnet", "rnn"] {
        for alg in [Algorithm::Dense, Algorithm::Slgs, Algorithm::Lags] {
            let run = |mode: PipelineMode, threads: usize, merge_bytes: usize| {
                let mut c = TrainConfig::default_for(model);
                c.algorithm = alg;
                c.workers = 3;
                c.threads = threads;
                c.steps = 3;
                c.lr = 0.05;
                c.compression = 10.0;
                c.eval_every = 0;
                c.pipeline = mode;
                c.merge_bytes = merge_bytes;
                let mut t = Trainer::with_runtime(&rt, c).expect("trainer");
                let mut losses = Vec::new();
                for _ in 0..3 {
                    losses.push(t.step().expect("step"));
                }
                (losses, t.params().to_vec(), t.msg_stats().clone())
            };
            let (l0, p0, s0) = run(PipelineMode::Barrier, 1, 0);
            for (mode, threads) in [
                (PipelineMode::Overlap, 1usize),
                (PipelineMode::Overlap, 4),
                (PipelineMode::Barrier, 4),
            ] {
                let (l, p, s) = run(mode, threads, 0);
                let tag = format!("{model} {} {} threads={threads}", alg.name(), mode.name());
                assert_eq!(l0, l, "losses diverged: {tag}");
                assert_eq!(p0, p, "params diverged: {tag}");
                assert_eq!(s0, s, "msg stats diverged: {tag}");
            }
            // a merge buffer big enough to group a whole step changes
            // message granularity only — numerics stay bit-identical
            let (lm, pm, sm) = run(PipelineMode::Overlap, 2, 1 << 20);
            assert_eq!(l0, lm, "{model} {}: merge changed losses", alg.name());
            assert_eq!(p0, pm, "{model} {}: merge changed params", alg.name());
            assert_eq!(s0.total_bytes, sm.total_bytes, "{model} {}: merge changed bytes", alg.name());
        }
    }
}

/// The bound 1 + t_b/(t_f+t_b) from the paper's §Bound discussion caps all
/// achievable S2 values in the DES.
#[test]
fn s2_never_exceeds_upper_bound() {
    for m in zoo::table2_models() {
        let bound = 1.0 + m.t_b() / (m.t_f + m.t_b());
        for c in [100.0, 250.0, 1000.0] {
            let sp = SimParams::uniform(&m, c);
            let slgs = simulate(&m, &net16(), Schedule::Slgs, &sp);
            let lags = simulate(&m, &net16(), Schedule::Lags, &sp);
            let s2 = slgs.iter_time / lags.iter_time;
            assert!(
                s2 <= bound + 0.35,
                "{} c={c}: S2 {s2} way above bound {bound}",
                m.name
            );
        }
    }
}
