//! Gradient-check conformance suite for the native layer zoo.
//!
//! Central finite differences against the analytic backward pass, run for
//! every layer kind in isolation (micro-specs built via the public
//! [`NativeNet::from_spec`] API) and for every built-in zoo model — so
//! any future layer work is conformance-tested by construction.
//!
//! ## Tolerances (documented, f32 forward path)
//!
//! The forward pass is f32, so the finite-difference quotient carries
//! ~`loss_ulp / (2·eps)` ≈ 1e-4 of rounding noise at `eps = 2e-3`, plus
//! `O(eps²)` truncation; analytic-vs-fd agreement is therefore asserted
//! to `2e-3 + 2.5e-2·max(|analytic|, |fd|)` — a relative 2.5% with an
//! absolute floor, not the 1e-4 a f64 shadow path would allow. ReLU and
//! MaxPool are piecewise-linear: a probe whose perturbation crosses a
//! kink (pre-activation or argmax flip within ±eps) legitimately
//! disagrees, so a bounded number of probes may exceed the tolerance —
//! at most HALF of any single layer's probes (so a systematically wrong
//! layer gradient, which fails all of its own probes, always trips the
//! assert no matter how many layers the model has) and at most
//! probes/8 (min 2) model-wide. The directional-derivative check (one
//! fd along a random direction vs `g·v`) averages the per-coordinate
//! noise and must always pass.

use lags::runtime::native::{
    native_manifest, spec_manifest, GradScratch, InputKind, LayerSpec, ModelSpec, NativeNet,
};
use lags::runtime::{BatchData, DType, Metric, ModelManifest};
use lags::util::rng::Rng;

const EPS: f64 = 2e-3;

fn batch_for(mm: &ModelManifest, seed: u64) -> (BatchData, BatchData) {
    let mut rng = Rng::new(seed);
    let x = match mm.x.dtype {
        DType::F32 => {
            let mut xs = vec![0.0f32; mm.x.elements()];
            rng.fill_normal(&mut xs, 1.0);
            BatchData::F32(xs)
        }
        DType::I32 => {
            BatchData::I32((0..mm.x.elements()).map(|_| rng.below(mm.classes) as i32).collect())
        }
    };
    let y =
        BatchData::I32((0..mm.y.elements()).map(|_| rng.below(mm.classes) as i32).collect());
    (x, y)
}

fn loss_at(net: &NativeNet, params: &[f32], x: &BatchData, y: &BatchData) -> f64 {
    let mut g = Vec::new();
    let mut s = GradScratch::default();
    net.train_step_into(params, x, y, &mut g, &mut s).expect("step") as f64
}

/// Run the conformance check for one (net, manifest) pair: probe every
/// manifest layer at its strongest-gradient coordinate plus 3 random
/// coordinates, and one random direction over the whole vector.
fn gradcheck(tag: &str, net: &NativeNet, mm: &ModelManifest, seed: u64) {
    let params = net.init_params(seed);
    let (x, y) = batch_for(mm, seed ^ 0x51ab);
    let mut grad = Vec::new();
    let mut gs = GradScratch::default();
    let loss = net.train_step_into(&params, &x, &y, &mut grad, &mut gs).expect("step");
    assert!(loss.is_finite() && loss > 0.0, "{tag}: loss {loss}");
    assert_eq!(grad.len(), mm.d, "{tag}: grad dim");
    assert!(grad.iter().all(|g| g.is_finite()), "{tag}: non-finite grad");

    // directional derivative: fd along one random direction vs g·v —
    // aggregates every coordinate, so per-coordinate kink noise washes out
    let mut rng = Rng::new(seed ^ 0xd1c7);
    let mut v = vec![0.0f32; mm.d];
    rng.fill_normal(&mut v, 1.0);
    let gv: f64 = grad.iter().zip(v.iter()).map(|(&g, &vi)| g as f64 * vi as f64).sum();
    let deps = 3e-4f64;
    let mut pp: Vec<f32> = params
        .iter()
        .zip(v.iter())
        .map(|(&p, &vi)| p + (deps as f32) * vi)
        .collect();
    let lp = loss_at(net, &pp, &x, &y);
    for ((q, &p), &vi) in pp.iter_mut().zip(params.iter()).zip(v.iter()) {
        *q = p - (deps as f32) * vi;
    }
    let lm = loss_at(net, &pp, &x, &y);
    let fd = (lp - lm) / (2.0 * deps);
    assert!(
        (fd - gv).abs() <= 2e-3 + 3e-2 * gv.abs().max(fd.abs()),
        "{tag}: directional derivative {fd} vs g·v {gv}"
    );

    // per-coordinate probes: each layer's max-|g| coordinate (covers
    // every tensor kind) + 3 random coordinates per layer. The kink
    // allowance is PER LAYER (at most half a layer's probes), so a
    // systematically wrong layer gradient — which fails all of its own
    // probes — always trips the assert regardless of how many other
    // layers the model has.
    let mut failures: Vec<String> = Vec::new();
    let mut probes = 0usize;
    for l in &mm.layers {
        let span = l.offset..l.offset + l.size;
        let strongest = span
            .clone()
            .max_by(|&a, &b| grad[a].abs().partial_cmp(&grad[b].abs()).unwrap())
            .unwrap();
        let mut coords = vec![strongest];
        for _ in 0..3 {
            coords.push(l.offset + rng.below(l.size));
        }
        let layer_probes = coords.len();
        let mut layer_failures = 0usize;
        for i in coords {
            probes += 1;
            let mut pp = params.clone();
            pp[i] += EPS as f32;
            let lp = loss_at(net, &pp, &x, &y);
            pp[i] = params[i] - EPS as f32;
            let lm = loss_at(net, &pp, &x, &y);
            let fd = (lp - lm) / (2.0 * EPS);
            let an = grad[i] as f64;
            let tol = 2e-3 + 2.5e-2 * an.abs().max(fd.abs());
            if (fd - an).abs() > tol {
                layer_failures += 1;
                failures.push(format!(
                    "{tag} layer {} coord {i}: analytic {an} vs fd {fd} (tol {tol})",
                    l.name
                ));
            }
        }
        assert!(
            layer_failures <= layer_probes / 2,
            "{tag} layer {}: {layer_failures}/{layer_probes} probes failed — \
             systematically wrong gradient, not kink noise:\n{}",
            l.name,
            failures.join("\n")
        );
    }
    let allowed = (probes / 8).max(2); // global kink allowance, see module doc
    assert!(
        failures.len() <= allowed,
        "{tag}: {}/{probes} probes failed (allowed {allowed}):\n{}",
        failures.len(),
        failures.join("\n")
    );
}

fn check_spec(spec: &ModelSpec, seed: u64) {
    let mm = spec_manifest(spec).expect("micro spec is valid");
    mm.validate().expect("spec manifest validates");
    let net = NativeNet::from_spec(spec).expect("spec resolves");
    gradcheck(&spec.name, &net, &mm, seed);
}

// ---- per-layer-kind micro specs -------------------------------------------

#[test]
fn gradcheck_conv_pool_flatten() {
    check_spec(
        &ModelSpec {
            name: "micro_conv".into(),
            batch: 3,
            input: InputKind::Image { h: 6, w: 6, c: 2 },
            classes: 3,
            metric: Metric::Accuracy,
            layers: vec![
                LayerSpec::Conv { out_ch: 4, k: 3, stride: 1, pad: 1 },
                LayerSpec::MaxPool { k: 2 },
                LayerSpec::Flatten,
                LayerSpec::Dense { out: 3 },
            ],
        },
        11,
    );
}

#[test]
fn gradcheck_conv_strided_no_pad() {
    check_spec(
        &ModelSpec {
            name: "micro_conv_s2".into(),
            batch: 2,
            input: InputKind::Image { h: 7, w: 7, c: 1 },
            classes: 4,
            metric: Metric::Accuracy,
            layers: vec![
                LayerSpec::Conv { out_ch: 3, k: 3, stride: 2, pad: 0 },
                LayerSpec::Dense { out: 4 },
            ],
        },
        13,
    );
}

#[test]
fn gradcheck_conv_stack_rectangular() {
    check_spec(
        &ModelSpec {
            name: "micro_conv_stack".into(),
            batch: 2,
            input: InputKind::Image { h: 8, w: 6, c: 3 },
            classes: 5,
            metric: Metric::Accuracy,
            layers: vec![
                LayerSpec::Conv { out_ch: 4, k: 3, stride: 1, pad: 1 },
                LayerSpec::Conv { out_ch: 6, k: 3, stride: 2, pad: 1 },
                LayerSpec::Flatten,
                LayerSpec::Dense { out: 8 },
                LayerSpec::Dense { out: 5 },
            ],
        },
        17,
    );
}

#[test]
fn gradcheck_embed_elman_bptt() {
    check_spec(
        &ModelSpec {
            name: "micro_rnn".into(),
            batch: 2,
            input: InputKind::Tokens { t: 5 },
            classes: 8,
            metric: Metric::PplLoss,
            layers: vec![
                LayerSpec::Embed { dim: 6 },
                LayerSpec::Elman { hidden: 7 },
                LayerSpec::Dense { out: 8 },
            ],
        },
        19,
    );
}

#[test]
fn gradcheck_stacked_recurrent() {
    // two recurrent layers: the BPTT carry must chain through both
    check_spec(
        &ModelSpec {
            name: "micro_rnn2".into(),
            batch: 2,
            input: InputKind::Tokens { t: 4 },
            classes: 6,
            metric: Metric::PplLoss,
            layers: vec![
                LayerSpec::Embed { dim: 5 },
                LayerSpec::Elman { hidden: 6 },
                LayerSpec::Elman { hidden: 5 },
                LayerSpec::Dense { out: 6 },
            ],
        },
        23,
    );
}

// ---- every zoo model -------------------------------------------------------

#[test]
fn gradcheck_all_zoo_models() {
    let man = native_manifest(42);
    for (name, mm) in &man.models {
        let net = NativeNet::from_manifest(mm).expect("zoo model builds");
        gradcheck(name, &net, mm, 0xbeef ^ mm.d as u64);
    }
}
