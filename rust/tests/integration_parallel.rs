//! Parallel-vs-sequential equivalence over the native runtime.
//!
//! The trainer's `--threads` fan-out must be a pure performance knob:
//! same seed → bit-identical params, per-step losses and message stats,
//! for every algorithm, compressor and thread count. These tests run
//! unconditionally (the native backend needs no artifacts), so the
//! determinism contract is enforced on every `cargo test`.

use lags::adaptive::{self, RatioConfig};
use lags::collectives::{NetworkModel, PipelineMode};
use lags::config::{NetConfig, TrainConfig};
use lags::runtime::Runtime;
use lags::sparsify::CompressorKind;
use lags::trainer::{Algorithm, MessageStats, Trainer};
use std::sync::Arc;

fn cfg(model: &str, alg: Algorithm, steps: usize, workers: usize, threads: usize) -> TrainConfig {
    let mut c = TrainConfig::default_for(model);
    c.algorithm = alg;
    c.steps = steps;
    c.workers = workers;
    c.threads = threads;
    c.lr = 0.1;
    c.compression = 20.0;
    c.eval_every = 0;
    c
}

/// Run the full loop step-by-step, returning (per-step losses, final
/// params, message stats).
fn run_traced(rt: &Arc<Runtime>, cfg: TrainConfig) -> (Vec<f64>, Vec<f32>, MessageStats) {
    let steps = cfg.steps;
    let mut t = Trainer::with_runtime(rt, cfg).expect("build trainer");
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        losses.push(t.step().expect("step"));
    }
    (losses, t.params().to_vec(), t.msg_stats().clone())
}

#[test]
fn parallel_bit_identical_all_algorithms() {
    let rt = Arc::new(Runtime::native(42));
    for alg in [Algorithm::Dense, Algorithm::Slgs, Algorithm::Lags] {
        let (l1, p1, s1) = run_traced(&rt, cfg("mlp", alg, 6, 8, 1));
        for threads in [2usize, 3, 8] {
            let (l2, p2, s2) = run_traced(&rt, cfg("mlp", alg, 6, 8, threads));
            assert_eq!(l1, l2, "{} losses diverged at threads={threads}", alg.name());
            assert_eq!(p1, p2, "{} params diverged at threads={threads}", alg.name());
            assert_eq!(s1, s2, "{} msg stats diverged at threads={threads}", alg.name());
        }
    }
}

#[test]
fn parallel_bit_identical_deep_model_uneven_chunks() {
    // 6 workers over 4 threads: uneven chunk sizes must not matter
    let rt = Arc::new(Runtime::native(7));
    let (l1, p1, s1) = run_traced(&rt, cfg("mlp_deep", Algorithm::Lags, 4, 6, 1));
    let (l2, p2, s2) = run_traced(&rt, cfg("mlp_deep", Algorithm::Lags, 4, 6, 4));
    assert_eq!(l1, l2);
    assert_eq!(p1, p2);
    assert_eq!(s1, s2);
}

#[test]
fn parallel_bit_identical_with_training_tricks() {
    // sampled threshold + warm-up + momentum correction, the stateful path
    let rt = Arc::new(Runtime::native(9));
    let make = |threads| {
        let mut c = cfg("mlp", Algorithm::Lags, 8, 4, threads);
        c.compressor = CompressorKind::HostSampled;
        c.warmup_steps = 5;
        c.local_momentum = 0.5;
        c
    };
    let (l1, p1, s1) = run_traced(&rt, make(1));
    let (l2, p2, s2) = run_traced(&rt, make(4));
    assert_eq!(l1, l2);
    assert_eq!(p1, p2);
    assert_eq!(s1, s2);
}

#[test]
fn parallel_bit_identical_xla_emulated_compressor() {
    // the Xla* compressor path compresses sequentially but grads still fan
    // out; the whole run must stay bit-identical
    let rt = Arc::new(Runtime::native(11));
    let make = |threads| {
        let mut c = cfg("mlp", Algorithm::Lags, 4, 4, threads);
        c.compressor = CompressorKind::XlaExact;
        c
    };
    let (l1, p1, s1) = run_traced(&rt, make(1));
    let (l2, p2, s2) = run_traced(&rt, make(8));
    assert_eq!(l1, l2);
    assert_eq!(p1, p2);
    assert_eq!(s1, s2);
}

#[test]
fn parallel_bit_identical_delta_monitor_series() {
    let rt = Arc::new(Runtime::native(13));
    let run = |threads: usize| {
        let mut c = cfg("mlp", Algorithm::Lags, 6, 4, threads);
        c.delta_every = 2;
        let mut t = Trainer::with_runtime(&rt, c).unwrap();
        for _ in 0..6 {
            t.step().unwrap();
        }
        let series = t.delta_series().unwrap().to_vec();
        (series, t.params().to_vec())
    };
    let (d1, p1) = run(1);
    let (d2, p2) = run(4);
    assert_eq!(d1, d2, "delta series diverged");
    assert_eq!(p1, p2);
}

#[test]
fn threads_zero_resolves_to_cores_and_stays_identical() {
    let rt = Arc::new(Runtime::native(17));
    let mut c0 = cfg("mlp", Algorithm::Lags, 3, 4, 0);
    c0.eval_every = 0;
    let t = Trainer::with_runtime(&rt, c0.clone()).unwrap();
    assert!(t.threads() >= 1);
    let (l1, p1, _) = run_traced(&rt, cfg("mlp", Algorithm::Lags, 3, 4, 1));
    let (l2, p2, _) = run_traced(&rt, c0);
    assert_eq!(l1, l2);
    assert_eq!(p1, p2);
}

#[test]
fn overlap_bit_identical_to_barrier_all_algorithms_and_compressors() {
    // `--pipeline` must be a pure performance knob: overlap ≡ barrier
    // bitwise (params, per-step losses, message stats) for every
    // algorithm × compressor × thread count. The barrier sequential run
    // is the reference every combination must match.
    let rt = Arc::new(Runtime::native(42));
    for alg in [Algorithm::Dense, Algorithm::Slgs, Algorithm::Lags] {
        let compressors: &[CompressorKind] = if alg == Algorithm::Dense {
            &[CompressorKind::HostExact] // dense ignores the compressor
        } else {
            &[
                CompressorKind::HostExact,
                CompressorKind::HostSampled,
                CompressorKind::XlaExact,
                CompressorKind::XlaSampled,
            ]
        };
        for &comp in compressors {
            let make = |mode: PipelineMode, threads: usize| {
                let mut c = cfg("mlp", alg, 5, 5, threads);
                c.compressor = comp;
                c.pipeline = mode;
                c
            };
            let (l0, p0, s0) = run_traced(&rt, make(PipelineMode::Barrier, 1));
            for threads in [1usize, 3, 8] {
                for mode in [PipelineMode::Barrier, PipelineMode::Overlap] {
                    let (l, p, s) = run_traced(&rt, make(mode, threads));
                    let tag = format!(
                        "{} {:?} {} threads={threads}",
                        alg.name(),
                        comp,
                        mode.name()
                    );
                    assert_eq!(l0, l, "losses diverged: {tag}");
                    assert_eq!(p0, p, "params diverged: {tag}");
                    assert_eq!(s0, s, "msg stats diverged: {tag}");
                }
            }
        }
    }
}

#[test]
fn overlap_bit_identical_deep_model_with_tricks() {
    // the stateful path (warm-up ramp + momentum correction + sampled
    // threshold) on the deep model, barrier vs overlap across threads
    let rt = Arc::new(Runtime::native(29));
    let make = |mode: PipelineMode, threads: usize| {
        let mut c = cfg("mlp_deep", Algorithm::Lags, 6, 6, threads);
        c.compressor = CompressorKind::HostSampled;
        c.warmup_steps = 4;
        c.local_momentum = 0.4;
        c.pipeline = mode;
        c
    };
    let (l0, p0, s0) = run_traced(&rt, make(PipelineMode::Barrier, 1));
    for threads in [2usize, 4] {
        let (l, p, s) = run_traced(&rt, make(PipelineMode::Overlap, threads));
        assert_eq!(l0, l, "threads={threads}");
        assert_eq!(p0, p, "threads={threads}");
        assert_eq!(s0, s, "threads={threads}");
    }
}

#[test]
fn overlap_bit_identical_delta_series_and_global_momentum() {
    // δ-monitor sampling + global momentum exercise the order-sensitive
    // instrumentation and the streamed per-layer apply
    let rt = Arc::new(Runtime::native(31));
    let run = |mode: PipelineMode, threads: usize| {
        let mut c = cfg("mlp", Algorithm::Lags, 6, 4, threads);
        c.delta_every = 2;
        c.momentum = 0.9;
        c.lr = 0.02;
        c.pipeline = mode;
        let mut t = Trainer::with_runtime(&rt, c).unwrap();
        for _ in 0..6 {
            t.step().unwrap();
        }
        let series = t.delta_series().unwrap().to_vec();
        (series, t.params().to_vec())
    };
    let (d0, p0) = run(PipelineMode::Barrier, 1);
    for threads in [1usize, 4] {
        let (d, p) = run(PipelineMode::Overlap, threads);
        assert_eq!(d0, d, "delta series diverged at threads={threads}");
        assert_eq!(p0, p, "params diverged at threads={threads}");
    }
}

#[test]
fn overlap_measures_hidden_time_only_when_streaming() {
    let rt = Arc::new(Runtime::native(37));
    // barrier mode never touches the stream table → zero overlap stats
    let mut c = cfg("mlp_deep", Algorithm::Lags, 3, 4, 2);
    c.pipeline = PipelineMode::Barrier;
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    for _ in 0..3 {
        t.step().unwrap();
    }
    assert_eq!(t.overlap_stats().busy_seconds, 0.0);
    assert_eq!(t.overlap_stats().efficiency(), 0.0);
    // overlap mode accumulates busy time and reports it in the run report
    let mut c = cfg("mlp_deep", Algorithm::Lags, 3, 4, 2);
    c.pipeline = PipelineMode::Overlap;
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    let report = t.run().unwrap();
    assert!(t.overlap_stats().busy_seconds > 0.0);
    assert!(t.overlap_stats().hidden_seconds <= t.overlap_stats().busy_seconds);
    assert_eq!(report.pipeline, "overlap");
    assert!(report.measured_comm_seconds > 0.0);
    assert!((0.0..=1.0).contains(&report.overlap_efficiency));
    assert!((0.0..=1.0).contains(&report.sim_overlap_efficiency));
}

#[test]
fn native_lags_training_reduces_loss_end_to_end() {
    // full trainer loop over the native backend — the convergence sanity
    // check that previously needed `make artifacts`
    let rt = Arc::new(Runtime::native(42));
    for alg in [Algorithm::Dense, Algorithm::Slgs, Algorithm::Lags] {
        let mut c = cfg("mlp", alg, 40, 2, 2);
        c.eval_every = 40;
        c.eval_batches = 2;
        let mut t = Trainer::with_runtime(&rt, c).unwrap();
        let first = t.step().unwrap();
        let r = t.run().unwrap();
        assert!(
            r.final_loss < first,
            "{}: loss did not drop ({first} -> {})",
            alg.name(),
            r.final_loss
        );
        assert!(r.final_metric.is_finite());
    }
}

#[test]
fn lags_message_volume_matches_compression_native() {
    // the sparse aggregation really ships ~P·(d/c) coordinates per iter
    let rt = Arc::new(Runtime::native(42));
    let mut c = cfg("mlp_deep", Algorithm::Lags, 5, 2, 2);
    c.compression = 100.0;
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    for _ in 0..5 {
        t.step().unwrap();
    }
    let d = t.model_manifest().d as f64;
    let expect = 2.0 * (d / 100.0) * 8.0;
    let got = t.msg_stats().bytes_per_iter();
    assert!(
        got > 0.5 * expect && got < 3.0 * expect,
        "bytes/iter {got} vs expected ~{expect}"
    );
}

#[test]
fn merge_buffer_groups_messages_and_preserves_bytes() {
    // §5 merge buffer in the REAL trainer: grouping changes only message
    // granularity — wire bytes, losses and params are bit-identical for
    // every capacity, and merge_bytes = 0 reproduces per-layer flushing
    // (P messages per layer per iteration) exactly
    let rt = Arc::new(Runtime::native(51));
    let workers = 4usize;
    let make = |merge_bytes: usize, mode: PipelineMode| {
        let mut c = cfg("mlp_deep", Algorithm::Lags, 4, workers, 2);
        c.merge_bytes = merge_bytes;
        c.pipeline = mode;
        c
    };
    let nl = Trainer::with_runtime(&rt, make(0, PipelineMode::Overlap))
        .unwrap()
        .model_manifest()
        .layers
        .len();
    let (l0, p0, s0) = run_traced(&rt, make(0, PipelineMode::Overlap));
    assert_eq!(s0.messages_per_iter(), (workers * nl) as f64, "per-layer flush at capacity 0");
    // capacity bigger than all traffic: one merged group per iteration
    let (l1, p1, s1) = run_traced(&rt, make(usize::MAX / 8, PipelineMode::Overlap));
    assert_eq!(s1.messages_per_iter(), workers as f64, "single group per iter");
    assert_eq!(l0, l1, "losses must not depend on merge grouping");
    assert_eq!(p0, p1, "params must not depend on merge grouping");
    // merged-group wire bytes equal the per-layer sum
    assert_eq!(s0.total_bytes, s1.total_bytes);
    // intermediate capacity: strictly between the two extremes, and
    // barrier groups exactly like overlap (same schedule → same stats)
    let (lb, pb, sb) = run_traced(&rt, make(2048, PipelineMode::Barrier));
    let (lo, po, so) = run_traced(&rt, make(2048, PipelineMode::Overlap));
    assert_eq!(lb, lo);
    assert_eq!(pb, po);
    assert_eq!(sb, so, "merge grouping diverged between pipeline modes");
    assert_eq!(sb.total_bytes, s0.total_bytes);
    assert!(
        sb.total_messages <= s0.total_messages && sb.total_messages >= s1.total_messages,
        "grouping between the extremes: {} vs [{}, {}]",
        sb.total_messages,
        s1.total_messages,
        s0.total_messages
    );
}

#[test]
fn overlap_bit_identical_to_barrier_with_merge_enabled() {
    // the full bit-identity contract with the merge buffer active at a
    // capacity that actually groups: every thread count, both modes
    let rt = Arc::new(Runtime::native(53));
    let make = |mode: PipelineMode, threads: usize| {
        let mut c = cfg("mlp_deep", Algorithm::Lags, 5, 5, threads);
        c.merge_bytes = 4096;
        c.pipeline = mode;
        c
    };
    let (l0, p0, s0) = run_traced(&rt, make(PipelineMode::Barrier, 1));
    for threads in [1usize, 3, 8] {
        for mode in [PipelineMode::Barrier, PipelineMode::Overlap] {
            let (l, p, s) = run_traced(&rt, make(mode, threads));
            let tag = format!("{} threads={threads}", mode.name());
            assert_eq!(l0, l, "losses diverged: {tag}");
            assert_eq!(p0, p, "params diverged: {tag}");
            assert_eq!(s0, s, "msg stats diverged: {tag}");
        }
    }
}

#[test]
fn dense_message_stats_follow_cost_model() {
    // aggregate_dense used to record d·4·2 bytes and 1 message regardless
    // of P; the convention is now cost::allreduce_dense's transfer
    // (2·bytes·(P−1)/P per rank, summed over ranks) with per-worker
    // message counting
    let rt = Arc::new(Runtime::native(61));
    for p in [1usize, 2, 5] {
        let mut t = Trainer::with_runtime(&rt, cfg("mlp", Algorithm::Dense, 3, p, 1)).unwrap();
        for _ in 0..3 {
            t.step().unwrap();
        }
        let d = t.model_manifest().d;
        let s = t.msg_stats();
        assert_eq!(s.bytes_per_iter(), (8 * d * (p - 1)) as f64, "P={p}");
        assert_eq!(s.messages_per_iter(), p as f64, "P={p}");
        // consistent with the α–β model: recorded bytes over the P NICs
        // equal the cost model's transfer seconds × bandwidth
        let net = NetworkModel { alpha: 0.0, bandwidth: 1e9, workers: p };
        let transfer_secs = net.allreduce_dense((d * 4) as f64);
        let implied = s.bytes_per_iter() / (p as f64 * 1e9);
        assert!((transfer_secs - implied).abs() < 1e-12, "P={p}: {transfer_secs} vs {implied}");
    }
}

#[test]
fn online_reselection_updates_ratios_from_measured_timings() {
    // --adaptive --reselect-every N: the trainer re-runs Eq. 18 from the
    // measured EWMA profile at step boundaries and records the history
    let rt = Arc::new(Runtime::native(71));
    let mut c = cfg("mlp_deep", Algorithm::Lags, 9, 4, 2);
    c.adaptive = true;
    c.c_max = 400.0;
    c.reselect_every = 3;
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    let initial = t.ratios().to_vec();
    let r = t.run().unwrap();
    // history: startup + re-selections at steps 3, 6, 9
    assert_eq!(t.selections().len(), 4, "selection history: {:?}", t.selections());
    assert_eq!(t.selections()[0].step, 0);
    assert_eq!(t.selections()[0].ratios, initial);
    assert_eq!(t.selections()[1].step, 3);
    for sel in t.selections() {
        assert!(
            sel.ratios.iter().all(|&c| (1.0..=400.0).contains(&c)),
            "ratios out of bounds: {:?}",
            sel.ratios
        );
        let cmax = sel.ratios.iter().cloned().fold(1.0, f64::max);
        assert_eq!(sel.effective_cmax, cmax);
    }
    // ks stay consistent with the ratios in effect
    for ((k, &ratio), l) in
        t.layer_ks().iter().zip(t.ratios().iter()).zip(t.model_manifest().layers.iter())
    {
        assert_eq!(*k, ((l.size as f64 / ratio).ceil() as usize).clamp(1, l.size));
    }
    // the report carries the history and the net config
    assert_eq!(r.selections.len(), t.selections().len());
    assert_eq!(r.net_alpha, NetConfig::gige16().alpha);
    assert_eq!(r.net_bandwidth, NetConfig::gige16().bandwidth);
}

#[test]
fn trainer_initial_selection_matches_select_ratios_manifest() {
    // `lags ratios` (live-model mode) calls select_ratios_manifest with
    // the trainer's own inputs — assert they agree, per the acceptance
    // criterion that the CLI prints the trainer's initial selection
    let rt = Arc::new(Runtime::native(81));
    let mut c = cfg("mlp_deep", Algorithm::Lags, 1, 6, 1);
    c.adaptive = true;
    c.c_max = 777.0;
    c.net = NetConfig { alpha: 2e-4, bandwidth: 5e8 };
    let net = c.net.model(c.workers);
    let t = Trainer::with_runtime(&rt, c).unwrap();
    let rc = RatioConfig { c_max: 777.0, ..RatioConfig::default() };
    let expect =
        adaptive::select_ratios_manifest(t.model_manifest(), rt.device_flops(), &net, &rc);
    assert_eq!(t.ratios(), &expect[..]);
    assert_eq!(t.selections().len(), 1, "startup selection recorded");
    // P = 1 adaptively selects all-dense (c = 1), not a phantom 2-worker
    // cluster
    let mut c1 = cfg("mlp_deep", Algorithm::Lags, 1, 1, 1);
    c1.adaptive = true;
    let t1 = Trainer::with_runtime(&rt, c1).unwrap();
    assert!(t1.ratios().iter().all(|&c| c == 1.0), "{:?}", t1.ratios());
    let d = t1.model_manifest().d;
    let k_total: usize = t1.layer_ks().iter().sum();
    assert_eq!(k_total, d, "all-dense keeps every coordinate");
}

#[test]
fn parallel_bit_identical_heterogeneous_zoo() {
    // the conv and recurrent zoo models ride the SAME determinism
    // contract as the MLPs: barrier/1-thread is the reference; every
    // thread count × pipeline mode × compressor must match it bitwise
    let rt = Arc::new(Runtime::native(91));
    for (model, workers) in [("convnet", 3usize), ("rnn", 4)] {
        for comp in [CompressorKind::HostExact, CompressorKind::HostSampled] {
            let make = |mode: PipelineMode, threads: usize| {
                let mut c = cfg(model, Algorithm::Lags, 3, workers, threads);
                c.lr = 0.05;
                c.compression = 10.0;
                c.compressor = comp;
                c.pipeline = mode;
                c
            };
            let (l0, p0, s0) = run_traced(&rt, make(PipelineMode::Barrier, 1));
            for threads in [2usize, 4] {
                for mode in [PipelineMode::Barrier, PipelineMode::Overlap] {
                    let (l, p, s) = run_traced(&rt, make(mode, threads));
                    let tag = format!("{model} {comp:?} {} threads={threads}", mode.name());
                    assert_eq!(l0, l, "losses diverged: {tag}");
                    assert_eq!(p0, p, "params diverged: {tag}");
                    assert_eq!(s0, s, "msg stats diverged: {tag}");
                }
            }
        }
    }
}

#[test]
fn heterogeneous_models_converge_end_to_end() {
    let rt = Arc::new(Runtime::native(93));
    // convnet: every algorithm drops the loss on the image-template task
    for alg in [Algorithm::Dense, Algorithm::Slgs, Algorithm::Lags] {
        let mut c = cfg("convnet", alg, 25, 2, 2);
        c.lr = 0.05;
        c.compression = 10.0;
        c.eval_every = 25;
        c.eval_batches = 2;
        let mut t = Trainer::with_runtime(&rt, c).unwrap();
        let first = t.step().unwrap();
        let r = t.run().unwrap();
        assert!(
            r.final_loss < first,
            "convnet {}: loss did not drop ({first} -> {})",
            alg.name(),
            r.final_loss
        );
        assert_eq!(r.metric_name, "accuracy");
        assert!(r.final_metric.is_finite());
    }
    // rnn: next-token loss falls from ~ln(vocab) toward the chain's
    // entropy floor; the report carries the LM metric convention
    let mut c = cfg("rnn", Algorithm::Lags, 60, 2, 2);
    c.lr = 0.1;
    c.compression = 10.0;
    c.eval_every = 60;
    c.eval_batches = 2;
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    let first = t.step().unwrap();
    let r = t.run().unwrap();
    assert!(r.final_loss < first, "rnn: ppl loss did not drop ({first} -> {})", r.final_loss);
    assert_eq!(r.metric_name, "ppl_loss");
    assert!((r.final_metric - r.final_eval_loss).abs() < 1e-6, "LM metric == eval loss");
}

#[test]
fn adaptive_selection_and_online_reselection_on_convnet() {
    // startup Eq. 18 over the heterogeneous table must be non-uniform at
    // the default network, and the measured-profile reselection path must
    // run cleanly over fused conv/dense tensors
    let rt = Arc::new(Runtime::native(95));
    let mut c = cfg("convnet", Algorithm::Lags, 4, 4, 2);
    c.lr = 0.05;
    c.adaptive = true;
    c.reselect_every = 2;
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    let initial = t.ratios().to_vec();
    let (lo, hi) =
        initial.iter().fold((f64::INFINITY, 0.0f64), |(lo, hi), &r| (lo.min(r), hi.max(r)));
    assert!(hi > 2.0 * lo, "convnet startup selection should be non-uniform: {initial:?}");
    for _ in 0..4 {
        t.step().unwrap();
    }
    assert!(t.selections().len() >= 2, "online reselection ran: {:?}", t.selections().len());
    for ((k, &ratio), l) in
        t.layer_ks().iter().zip(t.ratios().iter()).zip(t.model_manifest().layers.iter())
    {
        assert_eq!(*k, ((l.size as f64 / ratio).ceil() as usize).clamp(1, l.size));
    }
}

#[test]
fn adaptive_ratios_run_parallel_identical() {
    let rt = Arc::new(Runtime::native(23));
    let make = |threads| {
        let mut c = cfg("mlp_deep", Algorithm::Lags, 3, 4, threads);
        c.adaptive = true;
        c.c_max = 500.0;
        c
    };
    let (l1, p1, _) = run_traced(&rt, make(1));
    let (l2, p2, _) = run_traced(&rt, make(3));
    assert_eq!(l1, l2);
    assert_eq!(p1, p2);
}
