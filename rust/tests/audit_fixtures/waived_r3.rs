// R3 fixture: waiver on the comment line directly above the accumulation.

fn largest(xs: &[f64]) -> f64 {
    // lags-audit: allow(R3) reason="fixture: max-fold is order-insensitive"
    xs.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b))
}
