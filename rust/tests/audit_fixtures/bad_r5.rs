// R5 fixture: randomness source other than util::rng::Rng. The single
// line below matches two R5 patterns ("rand::" and "thread_rng") — the
// audit reports both, one finding per matched pattern.

fn noise() -> f64 {
    let mut r = rand::thread_rng();
    r.gen()
}
