// R1 fixture: order-unstable collection in a deterministic-core module.
// MUST flag when audited under a core rel path (e.g. "trainer/fixture.rs").
use std::collections::HashMap;

fn residual_index() -> HashMap<usize, f32> {
    HashMap::new()
}
