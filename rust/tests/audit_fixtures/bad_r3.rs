// R3 fixture: float accumulation in core, outside the fixed-order sites
// runtime/kernels.rs and collectives/sparse_agg.rs. MUST flag under a core
// rel path; MUST NOT flag under those two whitelisted paths.

fn norm(xs: &[f32]) -> f32 {
    xs.iter().map(|x| x * x).sum::<f32>()
}
