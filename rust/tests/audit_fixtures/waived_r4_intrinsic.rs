// R4 fixture: the waivered twin of bad_r4_intrinsic.rs. Two waivers, one
// per unsafe token. NOTE the placement of the first one: attribute lines
// count as code to the scanner's next-code-line targeting, so the waiver
// must sit BETWEEN #[target_feature] and the `unsafe fn` line (legal Rust
// — comments may separate an attribute from its item).

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
// lags-audit: allow(R4) reason="fixture: target_feature intrinsic impl, lanes are independent chains"
unsafe fn mask_avx2_impl(x: &[f32], out: &mut [f32]) {
    use std::arch::x86_64::*;
    let v = _mm256_loadu_ps(x.as_ptr());
    _mm256_storeu_ps(out.as_mut_ptr(), v);
}

#[cfg(target_arch = "x86_64")]
fn mask_avx2(x: &[f32], out: &mut [f32]) {
    assert!(x.len() >= 8 && out.len() >= 8);
    // lags-audit: allow(R4) reason="fixture: intrinsic entry, bounds asserted above, ISA checked by dispatch"
    unsafe { mask_avx2_impl(x, out) }
}
