// W0 fixture: a waiver with no reason suppresses NOTHING — the original
// finding stays unwaived AND the waiver itself becomes a W0 finding, so
// the audit reports two problems for this file.

fn stamp() -> std::time::Instant {
    std::time::Instant::now() // lags-audit: allow(R2)
}
