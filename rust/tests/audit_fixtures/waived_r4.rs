// R4 fixture: waivered unsafe. The scanner suppresses it (and reports the
// waiver); the compiler-level #![forbid(unsafe_code)] backstop would still
// reject it, which is exactly the defense-in-depth the contract wants.

fn peek(v: &[u8]) -> u8 {
    // lags-audit: allow(R4) reason="fixture: demonstrates waiver plumbing only"
    unsafe { *v.get_unchecked(0) }
}
