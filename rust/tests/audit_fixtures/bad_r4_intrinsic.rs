// R4 fixture: the SIMD-tier shape — a #[target_feature] impl fn plus the
// checked-dispatch wrapper that calls it. Both carry a bare `unsafe`
// token, so the scanner must flag TWICE, under every rel path.

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn mask_avx2_impl(x: &[f32], out: &mut [f32]) {
    use std::arch::x86_64::*;
    let v = _mm256_loadu_ps(x.as_ptr());
    _mm256_storeu_ps(out.as_mut_ptr(), v);
}

#[cfg(target_arch = "x86_64")]
fn mask_avx2(x: &[f32], out: &mut [f32]) {
    assert!(x.len() >= 8 && out.len() >= 8);
    unsafe { mask_avx2_impl(x, out) }
}
