// R1 fixture: same violation, suppressed by a reasoned waiver on the
// comment line directly above. MUST suppress (report clean) but still
// surface in the waiver list.

// lags-audit: allow(R1) reason="fixture: membership-only set, never iterated"
use std::collections::HashSet as Seen;

fn fresh(seen: &Seen<usize>, i: usize) -> bool {
    !seen.contains(&i)
}
