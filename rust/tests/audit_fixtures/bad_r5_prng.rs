// R5 fixture: a hand-rolled xorshift64* generator. All randomness must
// flow through util::rng's forked streams; the multiplier constant below
// is the fingerprint the audit keys on, so this file MUST flag exactly
// one unwaived R5 finding (outside util/rng.rs).

fn xorshift_star(mut s: u64) -> u64 {
    s ^= s >> 12;
    s ^= s << 25;
    s ^= s >> 27;
    s.wrapping_mul(0x2545F4914F6CDD1D)
}
