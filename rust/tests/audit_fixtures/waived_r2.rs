// R2 fixture: same-line waiver form (comment trails the violating code).

fn stamp() -> std::time::Instant {
    std::time::Instant::now() // lags-audit: allow(R2) reason="fixture: boundary probe, value never reaches deterministic state"
}
