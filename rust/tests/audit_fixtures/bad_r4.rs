// R4 fixture: unsafe is forbidden crate-wide, so this flags under EVERY
// rel path, core or not.

fn transmute_len(v: &[u8]) -> usize {
    unsafe { v.get_unchecked(0); }
    v.len()
}
