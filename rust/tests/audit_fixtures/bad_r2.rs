// R2 fixture: wall-clock read outside the util::clock funnel. MUST flag
// under any rel path except "util/clock.rs".

fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}
