// R5 fixture: a waiver suppresses every matching pattern on its target
// line — both the "rand::" and "thread_rng" hits below end up waived.

fn noise() -> u64 {
    // lags-audit: allow(R5) reason="fixture: exercising multi-pattern waiver"
    let mut r = rand::thread_rng();
    r.next_u64()
}
