// R5 fixture: the splitmix64 gamma constant outside util/rng.rs, with a
// waiver — e.g. a golden test pinning the stream constant. The finding
// must be suppressed but still reported into audit.json.

fn gamma() -> u64 {
    // lags-audit: allow(R5) reason="fixture: pinned stream constant, not a generator"
    0x9e3779b97f4a7c15
}
