//! Property suite for the [`lags::sparsify::Compressor`] trait contract
//! (DESIGN.md §Compressor zoo and validation):
//!
//! 1. `densify(msg) + resid == acc` bit-exact, for every zoo member, on
//!    every shape (including degenerate all-zero layers);
//! 2. the kept count respects the scheme's budget (`<= k` for the
//!    budgeted schemes; adaptive-stoch floats BELOW `k`);
//! 3. identical `(seed, uid, step, layer)` ⇒ bit-identical output across
//!    fresh instances, OS threads, pipeline modes and whole reruns;
//! 4. the QSGD quantizer's round-trip error is bounded by the level
//!    spacing `Δ <= 2·max|acc|/128`;
//! 5. bytes-on-wire accounting follows the compressor's [`WireFormat`]
//!    end-to-end (index+level is cheaper than index+value at equal k).

use lags::collectives::PipelineMode;
use lags::config::TrainConfig;
use lags::runtime::Runtime;
use lags::sparsify::{Compressor, CompressorKind, LayerCtx, SparseVec};
use lags::trainer::{Algorithm, Trainer};
use lags::util::rng::Rng;
use std::sync::Arc;

/// Every kind the factory can build (the `xla*` kinds build their host
/// TopK twins — same selection semantics, same contract).
const ALL_KINDS: [CompressorKind; 8] = [
    CompressorKind::HostExact,
    CompressorKind::HostSampled,
    CompressorKind::XlaExact,
    CompressorKind::XlaSampled,
    CompressorKind::AdaptiveStoch,
    CompressorKind::GlobalTopk,
    CompressorKind::QsgdTopk,
    CompressorKind::BottomK,
];

/// The kinds whose split consumes the ctx RNG stream.
const STOCHASTIC: [CompressorKind; 2] =
    [CompressorKind::AdaptiveStoch, CompressorKind::QsgdTopk];

fn ctx(seed: u64, uid: u64, step: u64, layer: u64) -> LayerCtx {
    LayerCtx { seed, uid, step, layer }
}

fn randvec(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| r.normal_f32()).collect()
}

fn densify(msg: &SparseVec) -> Vec<f32> {
    let mut out = vec![0.0f32; msg.len];
    for (&i, &v) in msg.idx.iter().zip(msg.val.iter()) {
        out[i as usize] = v;
    }
    out
}

/// Build a fresh compressor of `kind`, arm it, and split one layer.
fn split_with(
    kind: CompressorKind,
    c: &LayerCtx,
    acc: &[f32],
    k: usize,
) -> (SparseVec, Vec<f32>, usize) {
    let n = acc.len();
    let mut comp = kind.build(8);
    // single-layer model: the layer IS the flat vector, k_total = k
    let zero_resid = vec![0.0f32; n];
    comp.begin_step(&zero_resid, acc, 1.0, k);
    let mut msg = SparseVec::new(n);
    let mut resid = vec![0.0f32; n];
    let stats = comp.split(c, acc, k, &mut msg, &mut resid);
    (msg, resid, stats.kept)
}

#[test]
fn mass_conservation_is_bit_exact_for_every_kind_and_shape() {
    for kind in ALL_KINDS {
        for (si, n) in [8usize, 33, 257, 1024].into_iter().enumerate() {
            let acc = randvec(n, 100 + si as u64);
            let k = (n / 8).max(1);
            let c = ctx(42, 1, 3, si as u64);
            let (msg, resid, kept) = split_with(kind, &c, &acc, k);
            let dense = densify(&msg);
            for i in 0..n {
                assert_eq!(
                    (dense[i] + resid[i]).to_bits(),
                    acc[i].to_bits(),
                    "{} n={n} i={i}: {} + {} != {}",
                    kind.name(),
                    dense[i],
                    resid[i],
                    acc[i]
                );
            }
            assert!(kept <= n, "{} kept {} > n {}", kind.name(), kept, n);
        }
    }
}

#[test]
fn degenerate_all_zero_layer_conserves_and_sends_nothing_stochastic() {
    // all-zero accumulator: no mass to move; the contract still holds
    // and nothing panics (QSGD's pow2 guard falls back to plain TopK)
    let n = 64;
    let acc = vec![0.0f32; n];
    for kind in ALL_KINDS {
        let (msg, resid, _) = split_with(kind, &ctx(1, 2, 3, 4), &acc, 8);
        let dense = densify(&msg);
        for i in 0..n {
            assert_eq!((dense[i] + resid[i]).to_bits(), acc[i].to_bits(), "{}", kind.name());
        }
        // whatever is transmitted carries zero mass (threshold-based
        // kinds keep |v| >= 0 here, but only exact zeros)
        assert!(msg.val.iter().all(|&v| v == 0.0), "{} sent nonzero mass", kind.name());
    }
    // adaptive-stoch's degenerate guard sends nothing at all
    let (msg, _, kept) = split_with(CompressorKind::AdaptiveStoch, &ctx(1, 2, 3, 4), &acc, 8);
    assert_eq!(msg.nnz(), 0);
    assert_eq!(kept, 0);
}

#[test]
fn budgeted_kinds_never_exceed_k() {
    // exact selection kinds keep exactly-k-or-fewer; adaptive-stoch is
    // hard-capped below k; only the sampled-threshold estimate may
    // legitimately overshoot (that's its documented trade)
    let n = 2048;
    let acc = randvec(n, 7);
    for kind in [
        CompressorKind::HostExact,
        CompressorKind::XlaExact,
        CompressorKind::AdaptiveStoch,
        CompressorKind::QsgdTopk,
        CompressorKind::BottomK,
    ] {
        for k in [1usize, 16, 256] {
            let (_, _, kept) = split_with(kind, &ctx(9, 0, 1, 0), &acc, k);
            assert!(kept <= k, "{} kept {} > budget {}", kind.name(), kept, k);
        }
    }
}

#[test]
fn same_ctx_is_bit_identical_across_fresh_instances_and_threads() {
    let n = 1024;
    let acc = randvec(n, 21);
    let k = 96;
    for kind in ALL_KINDS {
        let c = ctx(42, 5, 17, 2);
        let (m_ref, r_ref, _) = split_with(kind, &c, &acc, k);
        // fresh instance, same ctx → identical
        let (m2, r2, _) = split_with(kind, &c, &acc, k);
        assert_eq!(m_ref.idx, m2.idx, "{}", kind.name());
        assert_eq!(m_ref.val, m2.val, "{}", kind.name());
        assert_eq!(r_ref, r2, "{}", kind.name());
        // four OS threads, each with its own instance, same ctx →
        // identical to the reference (no ambient/shared RNG state)
        let acc_arc = Arc::new(acc.clone());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let acc = Arc::clone(&acc_arc);
                std::thread::spawn(move || split_with(kind, &c, &acc, k))
            })
            .collect();
        for h in handles {
            let (m, r, _) = h.join().expect("thread");
            assert_eq!(m_ref.idx, m.idx, "{} diverged across threads", kind.name());
            assert_eq!(m_ref.val, m.val, "{} diverged across threads", kind.name());
            assert_eq!(r_ref, r, "{} residual diverged across threads", kind.name());
        }
    }
}

#[test]
fn stochastic_streams_fork_on_every_ctx_coordinate() {
    // perturbing any one of (seed, uid, step, layer) must change a
    // stochastic compressor's kept set — the four forks are all live
    let n = 4096;
    let acc = randvec(n, 31);
    let k = 128;
    let base = ctx(42, 1, 3, 0);
    for kind in STOCHASTIC {
        let (m0, _, _) = split_with(kind, &base, &acc, k);
        for (label, c) in [
            ("seed", ctx(43, 1, 3, 0)),
            ("uid", ctx(42, 2, 3, 0)),
            ("step", ctx(42, 1, 4, 0)),
            ("layer", ctx(42, 1, 3, 1)),
        ] {
            let (m, _, _) = split_with(kind, &c, &acc, k);
            assert!(
                m.idx != m0.idx || m.val != m0.val,
                "{}: {label} fork did not change the message",
                kind.name()
            );
        }
    }
}

#[test]
fn qsgd_round_trip_error_is_bounded_by_level_spacing() {
    // |a - q| <= Δ for every transmitted coordinate, with
    // Δ = pow2_at_least(max|a|)/128 <= 2·max|a|/128; residuals of kept
    // coordinates obey the same bound (they ARE a - q, exactly)
    for trial in 0..8u64 {
        let n = 2048;
        let acc = randvec(n, 200 + trial);
        let norm = acc.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let delta_max = 2.0 * norm / 128.0;
        let (msg, resid, _) = split_with(CompressorKind::QsgdTopk, &ctx(5, 1, trial, 0), &acc, 256);
        assert!(msg.nnz() > 0, "trial {trial}: quantizer sent nothing");
        for (&i, &q) in msg.idx.iter().zip(msg.val.iter()) {
            let a = acc[i as usize];
            assert!(
                (a - q).abs() <= delta_max,
                "trial {trial} i={i}: |{a} - {q}| > {delta_max}"
            );
            assert_eq!(resid[i as usize], a - q, "residual must be the exact rounding error");
            assert_eq!(a.signum(), q.signum(), "quantization must preserve sign");
        }
    }
}

fn train_cfg(
    kind: CompressorKind,
    alg: Algorithm,
    mode: PipelineMode,
    threads: usize,
) -> TrainConfig {
    let mut c = TrainConfig::default_for("mlp");
    c.algorithm = alg;
    c.compressor = kind;
    c.pipeline = mode;
    c.threads = threads;
    c.workers = 3;
    c.steps = 6;
    c.lr = 0.1;
    c.compression = 10.0;
    c.eval_every = 0;
    c
}

type RunFingerprint = (Vec<f64>, Vec<f32>, lags::trainer::MessageStats);

fn run_losses(rt: &Arc<Runtime>, cfg: TrainConfig) -> RunFingerprint {
    let mut t = Trainer::with_runtime(rt, cfg).expect("trainer");
    let mut losses = Vec::new();
    for _ in 0..t.cfg.steps {
        losses.push(t.step().expect("step"));
    }
    (losses, t.params().to_vec(), t.msg_stats().clone())
}

#[test]
fn training_is_bit_identical_across_pipeline_modes_and_threads() {
    // the end-to-end determinism contract for every NEW zoo member: the
    // barrier single-thread run is the reference; overlap + multi-thread
    // must reproduce losses, params and message accounting bit-for-bit
    // (TopK kinds already have this matrix in integration_parallel.rs)
    let rt = Arc::new(Runtime::native(77));
    for kind in [
        CompressorKind::AdaptiveStoch,
        CompressorKind::GlobalTopk,
        CompressorKind::QsgdTopk,
        CompressorKind::BottomK,
    ] {
        let (l0, p0, s0) =
            run_losses(&rt, train_cfg(kind, Algorithm::Lags, PipelineMode::Barrier, 1));
        assert!(l0.iter().all(|l| l.is_finite()), "{}: non-finite loss", kind.name());
        for (mode, threads) in
            [(PipelineMode::Barrier, 3), (PipelineMode::Overlap, 1), (PipelineMode::Overlap, 3)]
        {
            let (l, p, s) = run_losses(&rt, train_cfg(kind, Algorithm::Lags, mode, threads));
            let tag = format!("{} {} threads={threads}", kind.name(), mode.name());
            assert_eq!(l0, l, "losses diverged: {tag}");
            assert_eq!(p0, p, "params diverged: {tag}");
            assert_eq!(s0, s, "message stats diverged: {tag}");
        }
    }
    // the whole-model SLGS path drives the same trait machinery
    let qsgd = CompressorKind::QsgdTopk;
    let (l0, p0, s0) = run_losses(&rt, train_cfg(qsgd, Algorithm::Slgs, PipelineMode::Barrier, 1));
    let (l1, p1, s1) = run_losses(&rt, train_cfg(qsgd, Algorithm::Slgs, PipelineMode::Overlap, 2));
    assert_eq!(l0, l1, "slgs qsgd-topk diverged across modes");
    assert_eq!(p0, p1);
    assert_eq!(s0, s1);
}

#[test]
fn wire_format_prices_the_narrow_encoding_cheaper() {
    // same model, same budget: qsgd-topk's index+level encoding must put
    // fewer bytes on the wire than host TopK's index+value (5k + 4 < 8k
    // per layer message at any k >= 2)
    let rt = Arc::new(Runtime::native(78));
    let cfg = |kind| train_cfg(kind, Algorithm::Lags, PipelineMode::Barrier, 1);
    let (_, _, host) = run_losses(&rt, cfg(CompressorKind::HostExact));
    let (_, _, qsgd) = run_losses(&rt, cfg(CompressorKind::QsgdTopk));
    assert!(host.total_bytes > 0 && qsgd.total_bytes > 0);
    assert!(
        qsgd.total_bytes < host.total_bytes,
        "index+level ({}) must beat index+value ({})",
        qsgd.total_bytes,
        host.total_bytes
    );
}
