//! Property-based tests over the coordinator invariants, using the
//! in-crate harness (`lags::util::prop`) — randomized cases with seeded
//! shrinking, proptest-style.
//!
//! Invariant groups:
//!   1. Top-k semantics (Eq. 4)
//!   2. Error-feedback mass conservation (Alg. 1 l.7-8)
//!   3. Sparse codec round trips + merge associativity
//!   4. Ring allreduce == naive mean (collective correctness)
//!   5. Lemma 1 on gaussian ensembles (the convergence keystone)
//!   6. DES sanity: monotonicity + bounds
//!   7. Eq. 18/19 model coherence
//!   8. Native layer kinds: im2col ≡ direct convolution, BPTT ≡ unrolled
//!   9. Blocked GEMM kernels ≡ fixed-order reference (bit-identical)

use lags::adaptive::{perf_model, ratio, RatioConfig};
use lags::collectives::{dense, sparse_agg, NetworkModel};
use lags::config::TrainConfig;
use lags::models::{zoo, LayerProfile, ModelProfile};
use lags::pipeline::desim::{simulate, Schedule, SimParams};
use lags::runtime::kernels;
use lags::runtime::native::{
    conv2d_backward, conv2d_forward, elman_backward, elman_forward, ConvDims, ConvGrads,
    ConvScratch, ElmanDims, ElmanGrads, ElmanScratch, ElmanWeights,
};
use lags::runtime::Runtime;
use lags::sparsify::{randk, sparse::SparseVec, topk, ErrorFeedback};
use lags::trainer::{Algorithm, Trainer};
use lags::util::prop::{check, quick, Case, Config};
use lags::util::rng::Rng;
use lags::util::ParallelExecutor;
use std::sync::Arc;

fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32()).collect()
}

// ---------------------------------------------------------------------------
// 1. Top-k semantics
// ---------------------------------------------------------------------------
#[test]
fn prop_topk_keeps_largest_magnitudes() {
    quick("topk-largest", 2, 2048, |c: &mut Case| {
        let x = randvec(&mut c.rng, c.size);
        let k = 1 + c.rng.below(c.size);
        let (m, thr) = topk::topk_mask(&x, k);
        let kept: Vec<f32> = m.iter().filter(|&&v| v != 0.0).map(|v| v.abs()).collect();
        if kept.len() < k {
            return Err(format!("kept {} < k {}", kept.len(), k));
        }
        let min_kept = kept.iter().cloned().fold(f32::INFINITY, f32::min);
        for (i, &v) in x.iter().enumerate() {
            if m[i] == 0.0 && v.abs() > min_kept {
                return Err(format!("dropped |{v}| > min kept {min_kept}"));
            }
            if m[i] != 0.0 && v.abs() < thr {
                return Err("kept below threshold".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_topk_error_beats_randk_expectation() {
    // single-vector Assumption-1 precursor: TopK error <= E[RandK error]
    quick("topk-vs-randk", 8, 1024, |c: &mut Case| {
        let x = randvec(&mut c.rng, c.size);
        let k = 1 + c.rng.below(c.size);
        let (m, _) = topk::topk_mask(&x, k);
        let err: f64 = x.iter().zip(m.iter()).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
        let rand_err = randk::randk_expected_error_sq(&x, k);
        if err <= rand_err + 1e-9 {
            Ok(())
        } else {
            Err(format!("topk err {err} > randk {rand_err}"))
        }
    });
}

// ---------------------------------------------------------------------------
// 2. Error feedback
// ---------------------------------------------------------------------------
#[test]
fn prop_error_feedback_mass_conservation() {
    quick("ef-conservation", 4, 512, |c: &mut Case| {
        let n = c.size;
        let mut ef = ErrorFeedback::new(n, 1 + c.rng.below(16));
        let lr = c.rng.range_f64(1e-3, 1.0) as f32;
        let mut kept = vec![0.0f32; n];
        for _ in 0..5 {
            let g = randvec(&mut c.rng, n);
            let k = 1 + c.rng.below(n);
            let exact = c.rng.below(2) == 0;
            let before = ef.peek_acc(0, &g, lr);
            ef.compress_layer(0, &g, lr, k, exact, &mut kept);
            for i in 0..n {
                let total = kept[i] + ef.residual()[i];
                if (total - before[i]).abs() > 1e-5 {
                    return Err(format!("mass leak at {i}: {} vs {}", total, before[i]));
                }
                if kept[i] != 0.0 && ef.residual()[i] != 0.0 {
                    return Err(format!("element {i} in both kept and residual"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_compress_matches_dense_compress() {
    // compress_layer_sparse (the parallel trainer's wire path) must be
    // bit-equivalent to the dense-masked compress_layer it replaced
    quick("ef-sparse-equiv", 4, 512, |c: &mut Case| {
        let n = c.size;
        let stride = 1 + c.rng.below(16);
        let mut dense_ef = ErrorFeedback::new(n, stride);
        let mut sparse_ef = ErrorFeedback::new(n, stride);
        let lr = c.rng.range_f64(1e-3, 1.0) as f32;
        let mut kept = vec![0.0f32; n];
        let mut msg = SparseVec::new(n);
        for _ in 0..4 {
            let g = randvec(&mut c.rng, n);
            let k = 1 + c.rng.below(n);
            let exact = c.rng.below(2) == 0;
            let sd = dense_ef.compress_layer(0, &g, lr, k, exact, &mut kept);
            let ss = sparse_ef.compress_layer_sparse(0, &g, lr, k, exact, &mut msg);
            if sd.threshold != ss.threshold || sd.kept != ss.kept {
                return Err(format!(
                    "stats diverged: thr {} vs {}, kept {} vs {}",
                    sd.threshold, ss.threshold, sd.kept, ss.kept
                ));
            }
            if msg.to_dense() != kept {
                return Err("kept values diverged".into());
            }
            if dense_ef.residual() != sparse_ef.residual() {
                return Err("residuals diverged".into());
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 3. Sparse codec
// ---------------------------------------------------------------------------
#[test]
fn prop_sparse_round_trip() {
    quick("sparse-round-trip", 1, 2048, |c: &mut Case| {
        let n = c.size;
        let mut dense = vec![0.0f32; n];
        let nnz = c.rng.below(n + 1);
        for i in c.rng.sample_distinct(n, nnz) {
            dense[i] = c.rng.normal_f32();
        }
        let s = SparseVec::from_dense(&dense);
        if s.to_dense() != dense {
            return Err("dense round trip".into());
        }
        let s2 = SparseVec::from_bytes(&s.to_bytes()).map_err(|e| e.to_string())?;
        if s2 != s {
            return Err("bytes round trip".into());
        }
        Ok(())
    });
}

#[test]
fn prop_merge_is_associative_sum() {
    quick("merge-assoc", 4, 512, |c: &mut Case| {
        let n = c.size;
        let mk = |c: &mut Case| {
            let mut d = vec![0.0f32; n];
            let nnz = c.rng.below(n / 2 + 1);
            for i in c.rng.sample_distinct(n, nnz) {
                d[i] = c.rng.normal_f32();
            }
            SparseVec::from_dense(&d)
        };
        let (a, b, z) = (mk(c), mk(c), mk(c));
        let left = a.merge(&b).merge(&z).to_dense();
        let right = a.merge(&b.merge(&z)).to_dense();
        for i in 0..n {
            if (left[i] - right[i]).abs() > 1e-4 {
                return Err(format!("assoc mismatch at {i}"));
            }
        }
        // and equals the flat allgather sum
        let mut flat = vec![0.0f32; n];
        sparse_agg::sparse_allgather_sum(&[a, b, z], &mut flat);
        for i in 0..n {
            if (left[i] - flat[i]).abs() > 1e-4 {
                return Err(format!("flat mismatch at {i}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_allgather_invariant_to_producer_thread() {
    // the parallel trainer's contract: it does not matter WHICH thread
    // produced each rank's message — the reduction consumes rank-indexed
    // slots in rank order, so any executor fan-out yields bitwise the
    // same messages and the same aggregate as sequential production
    quick("allgather-thread-invariant", 4, 512, |c: &mut Case| {
        let n = c.size;
        let p = 2 + c.rng.below(15); // 2..=16 ranks
        let threads = 1 + c.rng.below(8);
        let dense_in: Vec<Vec<f32>> = (0..p).map(|_| randvec(&mut c.rng, n)).collect();
        let ks: Vec<usize> = (0..p).map(|_| 1 + c.rng.below(n)).collect();
        let encode = |rank: usize| {
            let thr = topk::kth_largest_abs(&dense_in[rank], ks[rank]);
            SparseVec::from_dense_threshold(&dense_in[rank], thr)
        };

        let seq: Vec<SparseVec> = (0..p).map(&encode).collect();
        let mut par: Vec<SparseVec> = vec![SparseVec::default(); p];
        ParallelExecutor::new(threads)
            .run(&mut par, |rank, slot| {
                *slot = encode(rank);
                Ok(())
            })
            .map_err(|e| e.to_string())?;
        if par != seq {
            return Err(format!("messages diverged under {threads} threads"));
        }

        let mut a = vec![0.0f32; n];
        let mut b = vec![0.0f32; n];
        sparse_agg::sparse_allgather_sum(&seq, &mut a);
        sparse_agg::sparse_allgather_sum(&par, &mut b);
        if a != b {
            return Err("aggregates diverged bitwise".into());
        }
        // the non-zeroing hot-path variant agrees when `out` starts zeroed
        let mut c2 = vec![0.0f32; n];
        sparse_agg::sparse_add_rank_ordered(par.iter(), &mut c2);
        if a != c2 {
            return Err("sparse_add_rank_ordered diverged from allgather".into());
        }
        Ok(())
    });
}

#[test]
fn prop_stream_aggregator_arrival_order_invariant() {
    // the streaming pipeline's contract: per-layer completion order —
    // any interleaving of worker publishes — cannot change the reduced
    // aggregate, because messages land in rank-indexed slots and each
    // layer is reduced rank-ordered once complete. Reference: the
    // layer-major rank-ordered barrier reduction.
    use lags::collectives::pipeline::{LayerMsg, StreamAggregator};
    use lags::util::clock;
    quick("stream-arrival-invariant", 4, 256, |c: &mut Case| {
        let layers = 1 + c.rng.below(6);
        let p = 1 + c.rng.below(8);
        // random layer spans laid out back to back
        let sizes: Vec<usize> = (0..layers).map(|_| 1 + c.rng.below(c.size)).collect();
        let mut spans = Vec::with_capacity(layers);
        let mut off = 0;
        for &n in &sizes {
            spans.push((off, n));
            off += n;
        }
        let d = off;

        // per (rank, layer) sparse messages + the barrier reference
        let mut msgs_table: Vec<Vec<SparseVec>> = Vec::with_capacity(p);
        for _ in 0..p {
            let row: Vec<SparseVec> = sizes
                .iter()
                .map(|&n| {
                    let dense = randvec(&mut c.rng, n);
                    let k = 1 + c.rng.below(n);
                    let thr = topk::kth_largest_abs(&dense, k);
                    SparseVec::from_dense_threshold(&dense, thr)
                })
                .collect();
            msgs_table.push(row);
        }
        let mut reference = vec![0.0f32; d];
        for li in (0..layers).rev() {
            let (o, n) = spans[li];
            sparse_agg::sparse_add_rank_ordered(
                msgs_table.iter().map(|row| &row[li]),
                &mut reference[o..o + n],
            );
        }

        // shuffled arrival (Fisher-Yates over all (rank, layer) pairs)
        let mut order: Vec<(usize, usize)> =
            (0..p).flat_map(|r| (0..layers).map(move |l| (r, l))).collect();
        for i in (1..order.len()).rev() {
            let j = c.rng.below(i + 1);
            order.swap(i, j);
        }
        let mut agg = StreamAggregator::new(layers, p);
        let mut out = vec![0.0f32; d];
        let mut fired = Vec::new();
        for (rank, layer) in order {
            let msg = LayerMsg {
                rank,
                layer,
                msg: msgs_table[rank][layer].clone(),
                sent: clock::now(),
            };
            agg.push(msg, |li, slots| {
                let (o, n) = spans[li];
                sparse_agg::sparse_add_rank_ordered(
                    slots.iter().map(|s| s.as_ref().unwrap()),
                    &mut out[o..o + n],
                );
                fired.push(li);
            });
        }
        if !agg.finished() {
            return Err("aggregator did not finish".into());
        }
        // strict backprop firing order
        let expect_order: Vec<usize> = (0..layers).rev().collect();
        if fired != expect_order {
            return Err(format!("fired {fired:?} != backprop order"));
        }
        if out != reference {
            return Err("streamed aggregate diverged bitwise from barrier".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 4. Ring allreduce
// ---------------------------------------------------------------------------
#[test]
fn prop_ring_allreduce_matches_naive() {
    quick("ring-allreduce", 1, 300, |c: &mut Case| {
        let p = 1 + c.rng.below(9);
        let n = c.size;
        let mut bufs: Vec<Vec<f32>> = (0..p).map(|_| randvec(&mut c.rng, n)).collect();
        let expect = dense::naive_mean(&bufs);
        dense::ring_allreduce_mean(&mut bufs);
        for r in 0..p {
            if bufs[r] != bufs[0] {
                return Err(format!("rank {r} diverged"));
            }
            for i in 0..n {
                if (bufs[r][i] - expect[i]).abs() > 1e-4 {
                    return Err(format!("p={p} rank {r} i {i}"));
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 5. Lemma 1
// ---------------------------------------------------------------------------
#[test]
fn prop_lemma1_gaussian_ensembles() {
    // layer-wise TopK aggregation error <= (1 - 1/c_max) ||sum x||^2
    // on gaussian ensembles (the regime Fig. 2 verifies empirically)
    quick("lemma1", 32, 512, |c: &mut Case| {
        let p = 2 + c.rng.below(7);
        // random layer partition of the flat dim
        let n_layers = 1 + c.rng.below(4);
        let sizes: Vec<usize> = (0..n_layers).map(|_| 16 + c.rng.below(c.size)).collect();
        let d: usize = sizes.iter().sum();
        let ks: Vec<usize> = sizes.iter().map(|&s| 1 + c.rng.below(s / 2 + 1)).collect();
        let xs: Vec<Vec<f32>> = (0..p).map(|_| randvec(&mut c.rng, d)).collect();

        let mut agg = vec![0.0f32; d];
        let mut agg_topk = vec![0.0f32; d];
        for x in &xs {
            for i in 0..d {
                agg[i] += x[i];
            }
            let mut off = 0;
            for (li, &sz) in sizes.iter().enumerate() {
                let (m, _) = topk::topk_mask(&x[off..off + sz], ks[li]);
                for i in 0..sz {
                    agg_topk[off + i] += m[i];
                }
                off += sz;
            }
        }
        let lhs: f64 =
            agg.iter().zip(agg_topk.iter()).map(|(&a, &s)| ((a - s) as f64).powi(2)).sum();
        let cmax = sizes
            .iter()
            .zip(ks.iter())
            .map(|(&s, &k)| s as f64 / k as f64)
            .fold(1.0f64, f64::max);
        let norm: f64 = agg.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let rhs = (1.0 - 1.0 / cmax) * norm;
        if lhs <= rhs + 1e-6 {
            Ok(())
        } else {
            Err(format!("Lemma1 violated: lhs={lhs} rhs={rhs} cmax={cmax}"))
        }
    });
}

// ---------------------------------------------------------------------------
// 6. DES sanity
// ---------------------------------------------------------------------------
fn random_profile(c: &mut Case) -> ModelProfile {
    let l = 2 + c.rng.below(12);
    let layers = (0..l)
        .map(|i| LayerProfile {
            name: format!("l{i}"),
            params: 1000 + c.rng.below(1_000_000),
            t_b: c.rng.range_f64(1e-4, 0.05),
        })
        .collect();
    ModelProfile { name: "rand".into(), t_f: c.rng.range_f64(1e-3, 0.1), layers }
}

#[test]
fn prop_des_lags_never_slower_than_slgs() {
    quick("des-lags-le-slgs", 1, 100, |c: &mut Case| {
        let m = random_profile(c);
        let net = NetworkModel {
            alpha: c.rng.range_f64(1e-5, 2e-3),
            bandwidth: c.rng.range_f64(1e7, 1e10),
            workers: 2 + c.rng.below(31),
        };
        let cr = c.rng.range_f64(1.0, 2000.0);
        let p = SimParams::uniform(&m, cr);
        let lags = simulate(&m, &net, Schedule::Lags, &p);
        let slgs = simulate(&m, &net, Schedule::Slgs, &p);
        // LAGS launches one sparsification per layer where SLGS launches
        // one total, so per-layer FIXED costs (spar_fixed, and per-group
        // alpha latencies beyond the first) are LAGS overhead that overlap
        // may or may not recover — the §5 small-message trade-off. The
        // invariant is: LAGS never loses by more than those fixed costs.
        let l = m.layers.len() as f64;
        let groups = lags.events.len() as f64;
        let p_minus_1 = (net.workers.max(1) - 1) as f64;
        let slack = (l - 1.0) * p.spar_fixed + (groups - 1.0) * p_minus_1 * net.alpha;
        if lags.iter_time <= slgs.iter_time + slack + 1e-9 {
            Ok(())
        } else {
            Err(format!(
                "lags {} > slgs {} + slack {}",
                lags.iter_time, slgs.iter_time, slack
            ))
        }
    });
}

#[test]
fn prop_des_iter_bounds() {
    quick("des-bounds", 1, 100, |c: &mut Case| {
        let m = random_profile(c);
        let net = NetworkModel {
            alpha: c.rng.range_f64(1e-5, 2e-3),
            bandwidth: c.rng.range_f64(1e7, 1e10),
            workers: 1 + c.rng.below(32),
        };
        for sched in [
            Schedule::DensePipelined,
            Schedule::DenseSingle,
            Schedule::Slgs,
            Schedule::Lags,
        ] {
            let params = match sched {
                Schedule::DensePipelined | Schedule::DenseSingle => SimParams::dense(&m),
                _ => SimParams::uniform(&m, c.rng.range_f64(1.0, 1000.0)),
            };
            let b = simulate(&m, &net, sched, &params);
            let comp = b.t_f + b.t_b;
            if b.iter_time < comp - 1e-9 {
                return Err(format!("{sched:?} iter below compute"));
            }
            if b.iter_time < b.t_comm - 1e-9 {
                return Err(format!("{sched:?} iter below comm"));
            }
            if b.iter_time > comp + b.t_comm + 1e-6 {
                return Err(format!("{sched:?} iter above serial sum"));
            }
            if b.hidden < -1e-12 || b.hidden > b.t_comm + 1e-9 {
                return Err(format!("{sched:?} hidden out of range"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_des_monotone_in_bandwidth() {
    quick("des-bandwidth-monotone", 1, 50, |c: &mut Case| {
        let m = random_profile(c);
        let base = NetworkModel {
            alpha: 5e-4,
            bandwidth: c.rng.range_f64(1e7, 1e9),
            workers: 2 + c.rng.below(15),
        };
        let fast = NetworkModel { bandwidth: base.bandwidth * 4.0, ..base };
        let p = SimParams::uniform(&m, 100.0);
        let slow_t = simulate(&m, &base, Schedule::Lags, &p).iter_time;
        let fast_t = simulate(&m, &fast, Schedule::Lags, &p).iter_time;
        if fast_t <= slow_t + 1e-9 {
            Ok(())
        } else {
            Err(format!("faster net slower: {fast_t} > {slow_t}"))
        }
    });
}

// ---------------------------------------------------------------------------
// 7. Eq. 18 / Eq. 19 coherence
// ---------------------------------------------------------------------------
#[test]
fn prop_smax_equals_direct_form() {
    quick("smax-direct", 1, 100, |c: &mut Case| {
        let t_f = c.rng.range_f64(0.0, 1.0);
        let t_b = c.rng.range_f64(1e-3, 1.0);
        let t_c = c.rng.range_f64(1e-6, 2.0);
        let a = perf_model::smax(t_f, t_b, t_c);
        let total = t_f + t_b + t_c;
        let direct = total / (total - t_b.min(t_c));
        if (a - direct).abs() < 1e-9 {
            Ok(())
        } else {
            Err(format!("smax {a} != direct {direct}"))
        }
    });
}

#[test]
fn prop_ratio_selection_fits_or_caps() {
    quick("eq18-fits", 1, 30, |c: &mut Case| {
        let m = random_profile(c);
        let net = NetworkModel {
            alpha: c.rng.range_f64(1e-6, 1e-3),
            bandwidth: c.rng.range_f64(1e7, 1e10),
            workers: 2 + c.rng.below(31),
        };
        let cfg = RatioConfig::default();
        let rs = ratio::select_ratios(&m, &net, &cfg);
        for (i, &cr) in rs.iter().enumerate() {
            if !(cfg.c_min..=cfg.c_max).contains(&cr) {
                return Err(format!("c out of bounds: {cr}"));
            }
            // interior solutions must satisfy the Eq. 18 constraint
            if i + 1 < m.layers.len() && cr < cfg.c_max - 1e-6 && cr > cfg.c_min + 1e-6 {
                let d = m.layers[i].params;
                let spar = cfg.spar_fixed + cfg.spar_per_elem * d as f64;
                let t = net.layer_comm_time(d, cr) + spar;
                if t > m.layers[i + 1].t_b + 1e-9 {
                    return Err(format!("layer {i} does not fit: {t}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_warmup_k_monotone_lands_on_ks() {
    // the Lin et al. warm-up ramp: for every layer, k_at is monotone
    // NON-INCREASING over the warm-up window and lands exactly on ks[li]
    // at t + 1 == warmup_steps — for uniform AND adaptive ratio vectors
    let rt = Arc::new(Runtime::native(5));
    let cases = Config { cases: 24, ..Config::default() };
    check("warmup-k-monotone", cases, 2, 40, |c: &mut Case| {
        let warmup = 1 + c.rng.below(c.size);
        let mut cfg = TrainConfig::default_for("mlp_deep");
        cfg.algorithm = Algorithm::Lags;
        cfg.workers = 2 + c.rng.below(4);
        cfg.warmup_steps = warmup;
        cfg.compression = 1.0 + c.rng.range_f64(0.0, 400.0);
        cfg.adaptive = c.rng.below(2) == 1;
        cfg.c_max = 1.0 + c.rng.range_f64(0.0, 900.0);
        cfg.eval_every = 0;
        let t = Trainer::with_runtime(&rt, cfg)
            .map_err(|e| format!("trainer build failed: {e:#}"))?;
        for li in 0..t.layer_ks().len() {
            let mut last = usize::MAX;
            for step in 0..warmup + 2 {
                let k = t.k_at(li, step);
                if k == 0 {
                    return Err(format!("layer {li} step {step}: k = 0"));
                }
                if k > last {
                    return Err(format!(
                        "layer {li} step {step}: k grew {last} -> {k} (warmup {warmup})"
                    ));
                }
                last = k;
            }
            // t + 1 == warmup_steps: exactly the post-warm-up k
            let k_land = t.k_at(li, warmup - 1);
            if k_land != t.layer_ks()[li] {
                return Err(format!(
                    "layer {li}: k_at landed on {k_land}, ks[li] = {} (warmup {warmup})",
                    t.layer_ks()[li]
                ));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 8. Native layer kinds: im2col conv ≡ direct convolution, BPTT ≡ unrolled
// ---------------------------------------------------------------------------

/// Draw a random valid conv geometry (small enough that the O(everything)
/// naive reference stays cheap).
fn rand_conv_dims(rng: &mut Rng) -> ConvDims {
    loop {
        let d = ConvDims {
            h: 3 + rng.below(4),
            w: 3 + rng.below(4),
            cin: 1 + rng.below(3),
            cout: 1 + rng.below(4),
            k: 1 + rng.below(3),
            stride: 1 + rng.below(2),
            pad: rng.below(3),
        };
        if d.validate().is_ok() {
            return d;
        }
    }
}

#[test]
fn prop_im2col_conv_forward_matches_naive() {
    // the im2col GEMM must equal a direct 7-loop convolution on random
    // shapes, strides and paddings (f64 reference, f32-rounding tolerance)
    let cases = Config { cases: 48, ..Config::default() };
    check("im2col-forward", cases, 1, 2, |c: &mut Case| {
        let d = rand_conv_dims(&mut c.rng);
        let batch = c.size;
        let x = randvec(&mut c.rng, batch * d.in_len());
        let w = randvec(&mut c.rng, d.weight_len());
        let bias = randvec(&mut c.rng, d.cout);
        let mut col = Vec::new();
        let mut out = vec![0.0f32; batch * d.out_len()];
        conv2d_forward(&d, &w, &bias, &x, batch, &mut col, &mut out);
        let (ho, wo) = (d.out_h(), d.out_w());
        for n in 0..batch {
            let xn = &x[n * d.in_len()..(n + 1) * d.in_len()];
            for oy in 0..ho {
                for ox in 0..wo {
                    for co in 0..d.cout {
                        let mut acc = bias[co] as f64;
                        for ky in 0..d.k {
                            for kx in 0..d.k {
                                let iy = (oy * d.stride + ky) as isize - d.pad as isize;
                                let ix = (ox * d.stride + kx) as isize - d.pad as isize;
                                if iy < 0
                                    || ix < 0
                                    || iy as usize >= d.h
                                    || ix as usize >= d.w
                                {
                                    continue;
                                }
                                for ci in 0..d.cin {
                                    let xv =
                                        xn[((iy as usize) * d.w + ix as usize) * d.cin + ci];
                                    let wv = w[((ky * d.k + kx) * d.cin + ci) * d.cout + co];
                                    acc += xv as f64 * wv as f64;
                                }
                            }
                        }
                        let got = out[((n * ho + oy) * wo + ox) * d.cout + co] as f64;
                        if (got - acc).abs() > 1e-4 * (1.0 + acc.abs()) {
                            return Err(format!(
                                "{d:?} n={n} ({oy},{ox},{co}): im2col {got} vs naive {acc}"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_conv_backward_matches_naive() {
    // dW, db AND dX from the im2col backward must match the direct
    // convolution-gradient loops on random geometry
    let cases = Config { cases: 32, ..Config::default() };
    check("im2col-backward", cases, 1, 2, |c: &mut Case| {
        let d = rand_conv_dims(&mut c.rng);
        let batch = c.size;
        let (ho, wo) = (d.out_h(), d.out_w());
        let x = randvec(&mut c.rng, batch * d.in_len());
        let w = randvec(&mut c.rng, d.weight_len());
        let delta = randvec(&mut c.rng, batch * d.out_len());
        let (mut col, mut dcol, mut wt) = (Vec::new(), Vec::new(), Vec::new());
        let mut dw = vec![0.0f32; d.weight_len()];
        let mut db = vec![0.0f32; d.cout];
        let mut dx = vec![0.0f32; batch * d.in_len()];
        let mut scr = ConvScratch { col: &mut col, dcol: &mut dcol, wt: &mut wt };
        let mut g = ConvGrads { dw: &mut dw, db: &mut db, dx: Some(&mut dx[..]) };
        conv2d_backward(&d, &w, &x, batch, &delta, &mut scr, &mut g);
        // f64 references
        let mut rdw = vec![0.0f64; d.weight_len()];
        let mut rdb = vec![0.0f64; d.cout];
        let mut rdx = vec![0.0f64; batch * d.in_len()];
        for n in 0..batch {
            let xn = &x[n * d.in_len()..(n + 1) * d.in_len()];
            for oy in 0..ho {
                for ox in 0..wo {
                    for co in 0..d.cout {
                        let dv = delta[((n * ho + oy) * wo + ox) * d.cout + co] as f64;
                        rdb[co] += dv;
                        for ky in 0..d.k {
                            for kx in 0..d.k {
                                let iy = (oy * d.stride + ky) as isize - d.pad as isize;
                                let ix = (ox * d.stride + kx) as isize - d.pad as isize;
                                if iy < 0
                                    || ix < 0
                                    || iy as usize >= d.h
                                    || ix as usize >= d.w
                                {
                                    continue;
                                }
                                for ci in 0..d.cin {
                                    let xi = ((iy as usize) * d.w + ix as usize) * d.cin + ci;
                                    let q = (ky * d.k + kx) * d.cin + ci;
                                    rdw[q * d.cout + co] += xn[xi] as f64 * dv;
                                    rdx[n * d.in_len() + xi] +=
                                        w[q * d.cout + co] as f64 * dv;
                                }
                            }
                        }
                    }
                }
            }
        }
        let close = |a: f32, b: f64| (a as f64 - b).abs() <= 1e-4 + 1e-3 * b.abs();
        for (i, (&a, &b)) in dw.iter().zip(rdw.iter()).enumerate() {
            if !close(a, b) {
                return Err(format!("{d:?} dW[{i}]: {a} vs {b}"));
            }
        }
        for (i, (&a, &b)) in db.iter().zip(rdb.iter()).enumerate() {
            if !close(a, b) {
                return Err(format!("{d:?} db[{i}]: {a} vs {b}"));
            }
        }
        for (i, (&a, &b)) in dx.iter().zip(rdx.iter()).enumerate() {
            if !close(a, b) {
                return Err(format!("{d:?} dX[{i}]: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_elman_bptt_matches_unrolled_reference() {
    // the linear-time carry BPTT must equal the O(t²) fully-unrolled
    // graph: for every output timestep, walk the chain back explicitly
    // (f64 dense reference, no carry, no sparsity skips)
    let cases = Config { cases: 32, ..Config::default() };
    check("elman-bptt-unrolled", cases, 1, 2, |c: &mut Case| {
        let batch = c.size;
        let t = 2 + c.rng.below(4);
        let in_dim = 1 + c.rng.below(4);
        let hidden = 1 + c.rng.below(5);
        let wx = randvec(&mut c.rng, in_dim * hidden);
        let wh: Vec<f32> =
            randvec(&mut c.rng, hidden * hidden).iter().map(|v| 0.5 * v).collect();
        let bias = randvec(&mut c.rng, hidden);
        let x = randvec(&mut c.rng, batch * t * in_dim);
        let mut hs = vec![0.0f32; batch * t * hidden];
        let e = ElmanDims { batch, t, in_dim, hidden };
        let weights = ElmanWeights { wx: &wx, wh: &wh };
        elman_forward(&e, &weights, &bias, &x, &mut hs);
        let delta = randvec(&mut c.rng, batch * t * hidden);

        let (mut dh, mut carry, mut wt) = (Vec::new(), Vec::new(), Vec::new());
        let mut dwx = vec![0.0f32; in_dim * hidden];
        let mut dwh = vec![0.0f32; hidden * hidden];
        let mut db = vec![0.0f32; hidden];
        let mut dx = vec![0.0f32; batch * t * in_dim];
        let mut scr = ElmanScratch { dh: &mut dh, carry: &mut carry, wt: &mut wt };
        let mut g =
            ElmanGrads { dwx: &mut dwx, dwh: &mut dwh, db: &mut db, dx: Some(&mut dx[..]) };
        elman_backward(&e, &weights, &x, &hs, &delta, &mut scr, &mut g);

        // unrolled reference: contributions of each output timestep s_out
        // to every earlier timestep's parameters, chained explicitly
        let mut rwx = vec![0.0f64; in_dim * hidden];
        let mut rwh = vec![0.0f64; hidden * hidden];
        let mut rb = vec![0.0f64; hidden];
        let mut rdx = vec![0.0f64; batch * t * in_dim];
        for n in 0..batch {
            for s_out in 0..t {
                let mut g: Vec<f64> = (0..hidden)
                    .map(|j| delta[(n * t + s_out) * hidden + j] as f64)
                    .collect();
                for s in (0..=s_out).rev() {
                    let hrow = &hs[(n * t + s) * hidden..(n * t + s + 1) * hidden];
                    let d: Vec<f64> = (0..hidden)
                        .map(|j| g[j] * (1.0 - (hrow[j] as f64) * (hrow[j] as f64)))
                        .collect();
                    let xrow = &x[(n * t + s) * in_dim..(n * t + s + 1) * in_dim];
                    for i in 0..in_dim {
                        for j in 0..hidden {
                            rwx[i * hidden + j] += xrow[i] as f64 * d[j];
                        }
                    }
                    if s > 0 {
                        let hprev = &hs[(n * t + s - 1) * hidden..(n * t + s) * hidden];
                        for j0 in 0..hidden {
                            for j in 0..hidden {
                                rwh[j0 * hidden + j] += hprev[j0] as f64 * d[j];
                            }
                        }
                    }
                    for j in 0..hidden {
                        rb[j] += d[j];
                    }
                    for i in 0..in_dim {
                        let mut acc = 0.0f64;
                        for j in 0..hidden {
                            acc += wx[i * hidden + j] as f64 * d[j];
                        }
                        rdx[(n * t + s) * in_dim + i] += acc;
                    }
                    if s > 0 {
                        let mut gnext = vec![0.0f64; hidden];
                        for (j0, gn) in gnext.iter_mut().enumerate() {
                            for j in 0..hidden {
                                *gn += wh[j0 * hidden + j] as f64 * d[j];
                            }
                        }
                        g = gnext;
                    }
                }
            }
        }
        let close = |a: f32, b: f64| (a as f64 - b).abs() <= 1e-4 + 2e-3 * b.abs();
        for (i, (&a, &b)) in dwx.iter().zip(rwx.iter()).enumerate() {
            if !close(a, b) {
                return Err(format!("t={t} i={in_dim} h={hidden} dWx[{i}]: {a} vs {b}"));
            }
        }
        for (i, (&a, &b)) in dwh.iter().zip(rwh.iter()).enumerate() {
            if !close(a, b) {
                return Err(format!("t={t} dWh[{i}]: {a} vs {b}"));
            }
        }
        for (i, (&a, &b)) in db.iter().zip(rb.iter()).enumerate() {
            if !close(a, b) {
                return Err(format!("t={t} db[{i}]: {a} vs {b}"));
            }
        }
        for (i, (&a, &b)) in dx.iter().zip(rdx.iter()).enumerate() {
            if !close(a, b) {
                return Err(format!("t={t} dX[{i}]: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 9. Blocked GEMM kernels: bit-identical to the fixed-order reference
// ---------------------------------------------------------------------------

#[test]
fn prop_blocked_gemm_bit_identical_to_reference() {
    // random M/K/N — including sizes that leave MR/NR remainder tiles and
    // cross the KC reduction block — over every transpose variant, on a
    // random (non-zero) initial C. The blocked kernels must reproduce the
    // naive fixed-order triple loop BIT for bit: that chain equality is
    // what makes blocking/tiling invisible to the trainer's determinism
    // contracts (DESIGN.md §Kernels-and-calibration).
    let cases = Config { cases: 96, ..Config::default() };
    check("blocked-gemm-bitwise", cases, 1, 2, |c: &mut Case| {
        let m = 1 + c.rng.below(13);
        let n = 1 + c.rng.below(21);
        // bias toward small k, but cross the KC=256 boundary sometimes
        let k = if c.rng.below(8) == 0 { 250 + c.rng.below(20) } else { 1 + c.rng.below(40) };
        let a = randvec(&mut c.rng, m * k);
        let b = randvec(&mut c.rng, k * n);
        let c0 = randvec(&mut c.rng, m * n);
        let mut at = Vec::new();
        kernels::pack_transpose(&a, m, k, &mut at);
        let mut bt = Vec::new();
        kernels::pack_transpose(&b, k, n, &mut bt);

        let mut want = c0.clone();
        kernels::gemm_ref(&mut want, &a, false, &b, false, m, k, n);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();

        let mut got = c0.clone();
        kernels::gemm_nn(&mut got, &a, &b, m, k, n);
        if bits(&got) != bits(&want) {
            return Err(format!("gemm_nn {m}x{k}x{n} diverged from gemm_ref"));
        }
        let mut got = c0.clone();
        kernels::gemm_tn(&mut got, &at, &b, m, k, n);
        if bits(&got) != bits(&want) {
            return Err(format!("gemm_tn {m}x{k}x{n} diverged from gemm_ref"));
        }
        let mut got = c0.clone();
        let mut scratch = kernels::GemmScratch::default();
        kernels::gemm_nt(&mut got, &a, &bt, m, k, n, &mut scratch);
        if bits(&got) != bits(&want) {
            return Err(format!("gemm_nt {m}x{k}x{n} diverged from gemm_ref"));
        }
        // the reference's own transposed-storage flags agree too
        let mut want_t = c0.clone();
        kernels::gemm_ref(&mut want_t, &at, true, &bt, true, m, k, n);
        if bits(&want_t) != bits(&want) {
            return Err(format!("gemm_ref transpose flags {m}x{k}x{n} inconsistent"));
        }
        Ok(())
    });
}

// sanity anchor: the published zoo profiles obey the same invariants
#[test]
fn zoo_profiles_pass_des_invariants() {
    let net = NetworkModel::gige_16();
    for m in zoo::table2_models() {
        let p = SimParams::uniform(&m, 1000.0);
        let b = simulate(&m, &net, Schedule::Lags, &p);
        assert!(b.iter_time >= m.t_comp() - 1e-9);
        assert!(b.hidden <= b.t_comm);
    }
}
