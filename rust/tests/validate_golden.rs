//! Golden δ-series snapshots + end-to-end `lags validate` coverage.
//!
//! The δ^(l) series (Eq. 20, actual-compressor numerator over the
//! expected-RandK denominator) is a pure function of
//! `(model, compressor, seed, steps, workers)` under the determinism
//! contract. These tests pin it three ways:
//!
//! 1. **Golden snapshot** (bless-on-absence): a seeded 30-step mlp run
//!    per zoo compressor renders every sample's exact f64 bit pattern
//!    into `rust/tests/golden/`. First run writes the file; every later
//!    run must match byte-for-byte. Delete a file to re-bless after an
//!    intentional numeric change.
//! 2. **Invariance**: reruns and pipeline modes (barrier vs overlap)
//!    must reproduce the series bit-identically.
//! 3. **Harness**: `analysis::validate::run` passes the shipped zoo on a
//!    reduced matrix and FAILS it when the bottom-k violation is
//!    injected — the negative test CI relies on.

use lags::analysis::validate::{self, ValidateSpec, DELTA_TOL, ZOO};
use lags::collectives::PipelineMode;
use lags::config::TrainConfig;
use lags::runtime::Runtime;
use lags::sparsify::CompressorKind;
use lags::trainer::{Algorithm, Trainer};
use std::path::PathBuf;
use std::sync::Arc;

const SEED: u64 = 4242;
const STEPS: usize = 30;
const DELTA_EVERY: usize = 5;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden")
}

fn delta_cfg(kind: CompressorKind, mode: PipelineMode, expectation: bool) -> TrainConfig {
    let mut c = TrainConfig::default_for("mlp");
    c.algorithm = Algorithm::Lags;
    c.compressor = kind;
    c.pipeline = mode;
    c.threads = 1;
    c.workers = 3;
    c.steps = STEPS;
    c.seed = SEED;
    c.delta_every = DELTA_EVERY;
    c.delta_expectation = expectation;
    c.eval_every = 0;
    c.verbose = false;
    c
}

fn run_series(rt: &Arc<Runtime>, cfg: TrainConfig) -> Vec<Vec<(usize, f64)>> {
    let mut t = Trainer::with_runtime(rt, cfg).expect("trainer");
    t.run().expect("train");
    t.delta_series().expect("delta monitor armed").to_vec()
}

/// Render a δ-series with exact bit patterns (the golden file format).
fn render(series: &[Vec<(usize, f64)>]) -> String {
    let mut out = String::new();
    out.push_str("# lags golden delta series v1: layer step bits(hex) value\n");
    for (li, layer) in series.iter().enumerate() {
        for &(step, d) in layer {
            out.push_str(&format!("{li} {step} {:016x} {d:.17e}\n", d.to_bits()));
        }
    }
    out
}

#[test]
fn golden_delta_series_pins_the_zoo_on_mlp() {
    let rt = Arc::new(Runtime::native(SEED));
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).expect("mkdir golden");
    for kind in ZOO {
        let series = run_series(&rt, delta_cfg(kind, PipelineMode::Barrier, true));
        // teeth independent of the snapshot: every sample is finite and
        // inside the Assumption-1 band for every shipped zoo member
        assert!(!series.is_empty() && series.iter().any(|l| !l.is_empty()), "{}", kind.name());
        for (li, layer) in series.iter().enumerate() {
            for &(step, d) in layer {
                assert!(
                    d.is_finite() && d <= 1.0 + DELTA_TOL,
                    "{} layer {li} step {step}: delta {d} outside band",
                    kind.name()
                );
            }
        }
        let got = render(&series);
        let path = dir.join(format!("delta_mlp_{}.golden", kind.name()));
        match std::fs::read_to_string(&path) {
            Ok(want) => assert_eq!(
                want,
                got,
                "{}: delta series drifted from {} — if the numeric change \
                 is intentional, delete the golden file to re-bless",
                kind.name(),
                path.display()
            ),
            Err(_) => {
                std::fs::write(&path, &got).expect("bless golden");
                eprintln!("blessed {}", path.display());
            }
        }
    }
}

#[test]
fn delta_series_is_invariant_across_reruns_and_pipeline_modes() {
    let rt = Arc::new(Runtime::native(SEED));
    let stochastic =
        [CompressorKind::AdaptiveStoch, CompressorKind::GlobalTopk, CompressorKind::QsgdTopk];
    for kind in stochastic {
        let a = run_series(&rt, delta_cfg(kind, PipelineMode::Barrier, true));
        let b = run_series(&rt, delta_cfg(kind, PipelineMode::Barrier, true));
        let c = run_series(&rt, delta_cfg(kind, PipelineMode::Overlap, true));
        let bits = |s: &[Vec<(usize, f64)>]| -> Vec<Vec<(usize, u64)>> {
            s.iter().map(|l| l.iter().map(|&(st, d)| (st, d.to_bits())).collect()).collect()
        };
        assert_eq!(bits(&a), bits(&b), "{}: rerun drift", kind.name());
        assert_eq!(bits(&a), bits(&c), "{}: pipeline-mode drift", kind.name());
    }
}

#[test]
fn expectation_denominator_agrees_with_single_draw_statistically() {
    // delta_expectation=true swaps one RandK draw's error for the
    // closed-form E‖·‖². The two series share sample points and must
    // agree in aggregate (the draw concentrates around its mean) even
    // though individual samples differ.
    let rt = Arc::new(Runtime::native(SEED));
    let exp = run_series(&rt, delta_cfg(CompressorKind::HostExact, PipelineMode::Barrier, true));
    let draw = run_series(&rt, delta_cfg(CompressorKind::HostExact, PipelineMode::Barrier, false));
    assert_eq!(exp.len(), draw.len());
    let mut ratios = Vec::new();
    for (le, ld) in exp.iter().zip(draw.iter()) {
        assert_eq!(le.len(), ld.len(), "sample cadence must not depend on the mode");
        for (&(se, de), &(sd, dd)) in le.iter().zip(ld.iter()) {
            assert_eq!(se, sd);
            assert!(de.is_finite() && dd.is_finite() && de > 0.0 && dd > 0.0);
            ratios.push(de / dd);
        }
    }
    assert!(!ratios.is_empty());
    let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
    assert!(
        (0.25..=4.0).contains(&mean),
        "expectation vs single-draw denominators disagree wildly: mean ratio {mean}"
    );
}

fn tiny_spec(inject: bool) -> ValidateSpec {
    let mut spec = ValidateSpec::quick(77);
    spec.models = vec!["mlp".into()];
    spec.compressors =
        vec![CompressorKind::HostExact, CompressorKind::AdaptiveStoch, CompressorKind::QsgdTopk];
    spec.steps = 15;
    spec.workers = 2;
    spec.mode = "test".into();
    spec.inject_violation = inject;
    spec
}

#[test]
fn validate_run_passes_the_zoo_on_a_reduced_matrix() {
    let spec = tiny_spec(false);
    let report = validate::run("native", &spec).expect("validate");
    assert_eq!(report.results.len(), 3);
    assert!(report.pass, "shipped zoo must clear the delta gate");
    for leg in &report.results {
        assert!(leg.pass, "{} failed: {}", leg.compressor, leg.summary_line());
        assert!(leg.final_loss.is_finite() && leg.dense_final_loss.is_finite());
        assert!(!leg.layers.is_empty());
    }
    // the report is valid JSON with the pinned schema tag
    let text = report.to_json().to_string_pretty();
    assert!(text.contains("\"schema_version\": 1"));
}

#[test]
fn validate_run_fails_when_the_violation_is_injected() {
    let spec = tiny_spec(true);
    let report = validate::run("native", &spec).expect("validate");
    assert_eq!(report.results.len(), 4, "the bottom-k control leg must be appended");
    assert!(!report.pass, "the gate must have teeth");
    let control = report
        .results
        .iter()
        .find(|l| l.compressor == "bottom-k")
        .expect("bottom-k leg present");
    assert!(!control.pass);
    let max = control.layers.iter().map(|l| l.max_delta).fold(0.0f64, f64::max);
    assert!(max > 1.0 + spec.tolerance, "bottom-k max delta {max} should breach the band");
    // only the injected control fails — the genuine zoo legs still pass
    assert!(report.results.iter().filter(|l| l.compressor != "bottom-k").all(|l| l.pass));
}
