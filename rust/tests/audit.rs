//! Fixture and self-audit tests for `lags-audit` (the determinism-contract
//! scanner, `lags::analysis::audit`).
//!
//! Two layers:
//! 1. **Fixtures** (`rust/tests/audit_fixtures/*.rs` — data files, never
//!    compiled): for every rule R1–R5, a known-bad file that MUST flag and
//!    a waivered twin that MUST suppress-but-report; plus the reasonless
//!    waiver, which suppresses nothing and is itself a W0.
//! 2. **Self-audit**: the shipped `rust/src` tree must audit clean, with
//!    exactly the justified waivers the contract documents (four legacy
//!    exceptions plus the per-intrinsic R4 waivers in `runtime/simd.rs`).

use lags::analysis::audit::{audit_source, audit_tree, Finding, Rule};
use std::path::Path;

fn fixture(name: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/audit_fixtures")
        .join(name);
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading fixture {}: {e}", path.display()))
}

/// Audit a fixture under a caller-chosen root-relative path (the rel path
/// selects which rules apply — fixtures simulate core or non-core files).
fn audit_fixture(name: &str, rel: &str) -> Vec<Finding> {
    audit_source(rel, &fixture(name))
}

fn unwaived_of(findings: &[Finding], rule: Rule) -> usize {
    findings.iter().filter(|f| f.rule == rule && !f.is_waived()).count()
}

fn waived_of(findings: &[Finding], rule: Rule) -> usize {
    findings.iter().filter(|f| f.rule == rule && f.is_waived()).count()
}

// --- R1: order-unstable collections in core ------------------------------

#[test]
fn bad_r1_flags_in_core_and_not_outside() {
    let fs = audit_fixture("bad_r1.rs", "trainer/fixture.rs");
    assert!(unwaived_of(&fs, Rule::R1) >= 1, "known-bad R1 must flag: {fs:?}");
    assert!(fs.iter().all(|f| f.rule == Rule::R1 && !f.is_waived()));
    // findings carry file:line into the report
    assert!(fs.iter().all(|f| f.file == "trainer/fixture.rs" && f.line >= 1));
    // R1 is scoped to the deterministic core
    assert!(audit_fixture("bad_r1.rs", "metrics/fixture.rs").is_empty());
}

#[test]
fn waived_r1_suppresses_but_reports() {
    let fs = audit_fixture("waived_r1.rs", "trainer/fixture.rs");
    assert_eq!(unwaived_of(&fs, Rule::R1), 0, "waiver must suppress: {fs:?}");
    assert_eq!(waived_of(&fs, Rule::R1), 1, "waived finding must still be reported");
    assert!(fs[0].waiver.as_deref().unwrap().contains("membership-only"));
}

// --- R2: wall-clock / env outside the clock funnel -----------------------

#[test]
fn bad_r2_flags_everywhere_but_clock() {
    let fs = audit_fixture("bad_r2.rs", "metrics/fixture.rs");
    assert_eq!(unwaived_of(&fs, Rule::R2), 1, "{fs:?}");
    // the funnel itself is structurally whitelisted
    assert!(audit_fixture("bad_r2.rs", "util/clock.rs").is_empty());
}

#[test]
fn waived_r2_same_line_form() {
    let fs = audit_fixture("waived_r2.rs", "trainer/fixture.rs");
    assert_eq!(unwaived_of(&fs, Rule::R2), 0, "{fs:?}");
    assert_eq!(waived_of(&fs, Rule::R2), 1);
}

// --- R3: float accumulation outside fixed-order sites --------------------

#[test]
fn bad_r3_flags_in_core_but_not_fixed_order_sites() {
    let fs = audit_fixture("bad_r3.rs", "sparsify/fixture.rs");
    assert_eq!(unwaived_of(&fs, Rule::R3), 1, "{fs:?}");
    assert!(audit_fixture("bad_r3.rs", "runtime/kernels.rs").is_empty());
    assert!(audit_fixture("bad_r3.rs", "collectives/sparse_agg.rs").is_empty());
    assert!(audit_fixture("bad_r3.rs", "util/json.rs").is_empty(), "R3 is core-scoped");
}

#[test]
fn waived_r3_comment_above_form() {
    let fs = audit_fixture("waived_r3.rs", "adaptive/fixture.rs");
    assert_eq!(unwaived_of(&fs, Rule::R3), 0, "{fs:?}");
    assert_eq!(waived_of(&fs, Rule::R3), 1);
}

// --- R4: unsafe, crate-wide ----------------------------------------------

#[test]
fn bad_r4_flags_core_and_non_core_alike() {
    for rel in ["trainer/fixture.rs", "metrics/fixture.rs", "util/fixture.rs"] {
        let fs = audit_fixture("bad_r4.rs", rel);
        assert_eq!(unwaived_of(&fs, Rule::R4), 1, "R4 must fire under {rel}: {fs:?}");
    }
}

#[test]
fn waived_r4_suppresses_but_reports() {
    let fs = audit_fixture("waived_r4.rs", "metrics/fixture.rs");
    assert_eq!(unwaived_of(&fs, Rule::R4), 0, "{fs:?}");
    assert_eq!(waived_of(&fs, Rule::R4), 1);
}

#[test]
fn bad_r4_intrinsic_flags_target_feature_fn_and_caller() {
    // SIMD-tier shape: a #[target_feature] unsafe fn plus the unsafe call
    // into it — two bare `unsafe` tokens, two findings, core or not
    for rel in ["runtime/fixture.rs", "metrics/fixture.rs"] {
        let fs = audit_fixture("bad_r4_intrinsic.rs", rel);
        assert_eq!(unwaived_of(&fs, Rule::R4), 2, "R4 must fire twice under {rel}: {fs:?}");
    }
}

#[test]
fn waived_r4_intrinsic_twin_suppresses_both_sites() {
    // the waiver for the fn line sits BETWEEN the #[target_feature]
    // attribute and the `unsafe fn` (attributes count as code, so a
    // comment above the attribute would miss its target)
    let fs = audit_fixture("waived_r4_intrinsic.rs", "runtime/fixture.rs");
    assert_eq!(unwaived_of(&fs, Rule::R4), 0, "{fs:?}");
    assert_eq!(waived_of(&fs, Rule::R4), 2, "fn line + call line both waived: {fs:?}");
    assert!(fs.iter().all(|f| f.waiver.as_deref().unwrap().contains("intrinsic")));
}

// --- R5: foreign randomness ----------------------------------------------

#[test]
fn bad_r5_flags_each_matched_pattern() {
    let fs = audit_fixture("bad_r5.rs", "util/fixture.rs");
    // one line matches both "rand::" and "thread_rng"
    assert_eq!(unwaived_of(&fs, Rule::R5), 2, "{fs:?}");
}

#[test]
fn waived_r5_covers_all_patterns_on_target_line() {
    let fs = audit_fixture("waived_r5.rs", "util/fixture.rs");
    assert_eq!(unwaived_of(&fs, Rule::R5), 0, "{fs:?}");
    assert_eq!(waived_of(&fs, Rule::R5), 2, "one waiver, both patterns reported waived");
}

#[test]
fn bad_r5_prng_constant_flags_outside_the_rng_funnel() {
    // a hand-rolled xorshift64* — its multiplier constant is the R5
    // fingerprint; stochastic compressors must fork util::rng streams
    let fs = audit_fixture("bad_r5_prng.rs", "sparsify/fixture.rs");
    assert_eq!(unwaived_of(&fs, Rule::R5), 1, "{fs:?}");
    // the one sanctioned generator is structurally exempt
    assert!(audit_fixture("bad_r5_prng.rs", "util/rng.rs").is_empty());
}

#[test]
fn waived_r5_prng_constant_suppresses_but_reports() {
    let fs = audit_fixture("waived_r5_prng.rs", "metrics/fixture.rs");
    assert_eq!(unwaived_of(&fs, Rule::R5), 0, "{fs:?}");
    assert_eq!(waived_of(&fs, Rule::R5), 1);
    assert!(fs[0].waiver.as_deref().unwrap().contains("pinned stream constant"));
}

// --- W0: waiver protocol --------------------------------------------------

#[test]
fn reasonless_waiver_suppresses_nothing_and_is_w0() {
    let fs = audit_fixture("reasonless.rs", "trainer/fixture.rs");
    assert_eq!(unwaived_of(&fs, Rule::R2), 1, "original finding stays live: {fs:?}");
    assert_eq!(unwaived_of(&fs, Rule::W0), 1, "reasonless waiver is itself a finding");
    assert!(fs.iter().all(|f| !f.is_waived()), "W0 is not waivable");
}

// --- self-audit: the shipped tree ----------------------------------------

#[test]
fn shipped_tree_audits_clean_with_documented_waivers() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let report = audit_tree(&root).expect("audit rust/src");
    assert!(report.files_scanned >= 30, "walk looks truncated: {}", report.files_scanned);

    let unwaived = report.unwaived();
    assert!(
        unwaived.is_empty(),
        "shipped tree must audit clean; unwaived findings:\n{}",
        unwaived
            .iter()
            .map(|f| format!("  {} {}:{} [{}] {}", f.rule.id(), f.file, f.line, f.what, f.excerpt))
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(report.clean());

    // exactly the justified exceptions the contract documents — the four
    // legacy waivers plus the SIMD tier's per-unsafe-token R4 waivers and
    // its one LAGS_ISA env read. Adding a waiver anywhere in rust/src must
    // update this list (and the DESIGN.md table) to stay green.
    let mut got: Vec<(String, &'static str)> =
        report.waivers().iter().map(|f| (f.file.clone(), f.rule.id())).collect();
    got.sort();
    let mut want: Vec<(String, &'static str)> = vec![
        ("adaptive/ratio.rs".to_string(), "R3"),
        ("runtime/native.rs".to_string(), "R3"),
        ("runtime/simd.rs".to_string(), "R2"), // the LAGS_ISA override read
        ("util/cli.rs".to_string(), "R2"),
        ("util/rng.rs".to_string(), "R1"),
    ];
    // 20 unsafe tokens in the SIMD tier: 7 x86 entry/impl fn pairs + 3
    // NEON pairs, 2 tokens each (the `unsafe fn` line and the wrapper's
    // `unsafe { .. }` call line), every one individually waived
    want.extend(std::iter::repeat(("runtime/simd.rs".to_string(), "R4")).take(20));
    want.sort();
    assert_eq!(got, want, "shipped waiver set drifted");
    // every effective waiver carries a non-empty reason (audit.json shape)
    assert!(report
        .waivers()
        .iter()
        .all(|f| !f.waiver.as_deref().unwrap_or("").trim().is_empty()));

    // audit.json reflects the same state machine-readably
    let j = report.to_json();
    assert!(j.get("clean").unwrap().as_bool().unwrap());
    assert_eq!(j.get("findings").unwrap().as_arr().unwrap().len(), 0);
    assert_eq!(j.get("waivers").unwrap().as_arr().unwrap().len(), 25);
}
