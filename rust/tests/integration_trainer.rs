//! End-to-end trainer integration: the three algorithms over the live
//! artifacts, convergence/equivalence/determinism properties.

use lags::config::TrainConfig;
use lags::runtime::Runtime;
use lags::sparsify::CompressorKind;
use lags::trainer::{Algorithm, Trainer};
use std::sync::Arc;

fn runtime() -> Option<Arc<Runtime>> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    match Runtime::load("artifacts") {
        Ok(rt) => Some(Arc::new(rt)),
        // e.g. a non-pjrt build with artifacts present — skip, don't panic
        Err(e) => {
            eprintln!("skipping: {e:#}");
            None
        }
    }
}

fn cfg(model: &str, alg: Algorithm, steps: usize) -> TrainConfig {
    let mut c = TrainConfig::default_for(model);
    c.algorithm = alg;
    c.steps = steps;
    c.workers = 2;
    c.lr = 0.1;
    c.compression = 20.0;
    c.eval_every = steps;
    c.eval_batches = 2;
    c
}

#[test]
fn all_algorithms_reduce_loss_mlp() {
    let Some(rt) = runtime() else { return };
    for alg in [Algorithm::Dense, Algorithm::Slgs, Algorithm::Lags] {
        let mut t = Trainer::with_runtime(&rt, cfg("mlp", alg, 40)).unwrap();
        let first = t.step().unwrap();
        let r = t.run().unwrap();
        assert!(
            r.final_loss < first,
            "{}: {first} -> {}",
            alg.name(),
            r.final_loss
        );
        assert!(r.final_metric.is_finite());
    }
}

#[test]
fn lags_trains_language_model() {
    let Some(rt) = runtime() else { return };
    let mut c = cfg("grulm", Algorithm::Lags, 30);
    c.lr = 0.5;
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    let first = t.step().unwrap();
    let r = t.run().unwrap();
    assert!(r.final_loss < first, "{first} -> {}", r.final_loss);
    // perplexity = exp(loss) sane for vocab 64
    assert!(r.headline_metric() < 64.0);
}

#[test]
fn deterministic_given_seed() {
    let Some(rt) = runtime() else { return };
    let run = || {
        let mut t = Trainer::with_runtime(&rt, cfg("mlp", Algorithm::Lags, 10)).unwrap();
        let r = t.run().unwrap();
        (r.final_loss, t.params().to_vec())
    };
    let (l1, p1) = run();
    let (l2, p2) = run();
    assert_eq!(l1, l2);
    assert_eq!(p1, p2);
}

#[test]
fn lags_equals_slgs_for_single_layer_budget() {
    // With compression c, SLGS uses k_total = sum of per-layer ks; when the
    // model has ONE layer-wise partition (k vector collapses), dynamics
    // must still differ only through layer boundaries. Here we check the
    // weaker but exact invariant: same total kept budget.
    let Some(rt) = runtime() else { return };
    let t_lags = Trainer::with_runtime(&rt, cfg("mlp", Algorithm::Lags, 1)).unwrap();
    let t_slgs = Trainer::with_runtime(&rt, cfg("mlp", Algorithm::Slgs, 1)).unwrap();
    let k_lags: usize = t_lags.layer_ks().iter().sum();
    let k_slgs: usize = t_slgs.layer_ks().iter().sum();
    assert_eq!(k_lags, k_slgs);
}

#[test]
fn dense_is_exact_data_parallel_sgd() {
    // P=1 dense == plain SGD on the artifact; final params must match a
    // manual loop within f32 tolerance
    let Some(rt) = runtime() else { return };
    let mut c = cfg("mlp", Algorithm::Dense, 5);
    c.workers = 1;
    c.eval_every = 0;
    let mut t = Trainer::with_runtime(&rt, c).unwrap();

    // manual replica
    let mr = rt.model_runtime("mlp").unwrap();
    let data = lags::data::Synthetic::for_model(&mr.mm, 42).unwrap();
    let mut params = mr.init_params.clone();
    for step in 0..5 {
        let b = data.batch(0, step);
        let (_, grad) = mr.train_step(&params, &b.x, &b.y).unwrap();
        for (p, g) in params.iter_mut().zip(grad.iter()) {
            *p -= 0.1 * g;
        }
        t.step().unwrap();
    }
    let max_diff = t
        .params()
        .iter()
        .zip(params.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "max_diff={max_diff}");
}

#[test]
fn error_feedback_recovers_heavy_compression() {
    // extremely aggressive compression still converges on mlp thanks to
    // error feedback (Corollary 1), just slower
    let Some(rt) = runtime() else { return };
    let mut c = cfg("mlp", Algorithm::Lags, 60);
    c.compression = 200.0;
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    let first = t.step().unwrap();
    let r = t.run().unwrap();
    assert!(r.final_loss < first, "{first} -> {}", r.final_loss);
}

#[test]
fn xla_compressor_path_matches_host_path() {
    // the full trainer with CompressorKind::XlaExact must produce the SAME
    // parameters as HostExact (the artifacts are bit-compatible)
    let Some(rt) = runtime() else { return };
    let run = |kind: CompressorKind| {
        let mut c = cfg("cnn", Algorithm::Lags, 4);
        c.compressor = kind;
        let mut t = Trainer::with_runtime(&rt, c).unwrap();
        t.run().unwrap();
        t.params().to_vec()
    };
    let host = run(CompressorKind::HostExact);
    let xla = run(CompressorKind::XlaExact);
    let max_diff = host
        .iter()
        .zip(xla.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "host vs xla max_diff = {max_diff}");
}

#[test]
fn delta_monitor_fig2_property() {
    // Assumption 1 (Fig. 2): delta^(l) <= 1 for the overwhelming majority
    // of samples during real LAGS training
    let Some(rt) = runtime() else { return };
    let mut c = cfg("mlp", Algorithm::Lags, 20);
    c.workers = 4;
    c.delta_every = 2;
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    let r = t.run().unwrap();
    let frac = r.delta_fraction_holding.unwrap();
    assert!(frac > 0.9, "delta holds only {frac}");
}

#[test]
fn momentum_changes_but_still_converges() {
    let Some(rt) = runtime() else { return };
    let mut c = cfg("mlp", Algorithm::Lags, 40);
    c.momentum = 0.9;
    c.lr = 0.03;
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    let first = t.step().unwrap();
    let r = t.run().unwrap();
    assert!(r.final_loss < first);
}

#[test]
fn momentum_correction_improves_lm_convergence() {
    // the paper (§Comparison of Convergence Rates) says warm-up + momentum
    // correction (Lin et al. 2018) close the sparsification gap — verify
    // the tricks help on the LM task at aggressive compression
    let Some(rt) = runtime() else { return };
    let mut base = cfg("grulm", Algorithm::Lags, 60);
    base.lr = 0.5;
    base.compression = 100.0;
    let mut plain = Trainer::with_runtime(&rt, base.clone()).unwrap();
    let r_plain = plain.run().unwrap();
    let mut tricks_cfg = base;
    tricks_cfg.local_momentum = 0.5;
    tricks_cfg.warmup_steps = 20;
    let mut tricks = Trainer::with_runtime(&rt, tricks_cfg).unwrap();
    let r_tricks = tricks.run().unwrap();
    assert!(
        r_tricks.final_loss < r_plain.final_loss,
        "tricks {} !< plain {}",
        r_tricks.final_loss,
        r_plain.final_loss
    );
}

#[test]
fn warmup_ramps_message_sizes() {
    let Some(rt) = runtime() else { return };
    let mut c = cfg("mlp", Algorithm::Lags, 10);
    c.compression = 100.0;
    c.warmup_steps = 10;
    let mut t = Trainer::with_runtime(&rt, c.clone()).unwrap();
    let r_warm = t.run().unwrap();
    c.warmup_steps = 0;
    let mut t2 = Trainer::with_runtime(&rt, c).unwrap();
    let r_cold = t2.run().unwrap();
    // during warm-up more coordinates are shipped per iteration
    assert!(r_warm.msg_stats.bytes_per_iter() > 2.0 * r_cold.msg_stats.bytes_per_iter());
}

#[test]
fn momentum_exclusivity_validated() {
    let mut c = cfg("mlp", Algorithm::Lags, 1);
    c.momentum = 0.9;
    c.local_momentum = 0.9;
    assert!(c.validate().is_err());
}

#[test]
fn adaptive_ratio_selection_runs() {
    let Some(rt) = runtime() else { return };
    let mut c = cfg("mlp", Algorithm::Lags, 5);
    c.adaptive = true;
    c.c_max = 500.0;
    let t = Trainer::with_runtime(&rt, c).unwrap();
    // per-layer ratios differ (big fc layers compressed harder than biases)
    let rs = t.ratios();
    assert!(rs.iter().any(|&a| a != rs[0]) || rs.iter().all(|&a| a == 500.0));
    assert!(rs.iter().all(|&c| (1.0..=500.0).contains(&c)));
}

#[test]
fn message_accounting_matches_compression() {
    let Some(rt) = runtime() else { return };
    let steps = 5;
    let mut c = cfg("mlp", Algorithm::Lags, steps);
    c.compression = 100.0;
    c.workers = 2;
    let mut t = Trainer::with_runtime(&rt, c).unwrap();
    let r = t.run().unwrap();
    let d = 165514.0f64;
    // expected ~ workers * (d/c) * 8 bytes per iter (ties can add a few)
    let expect = 2.0 * (d / 100.0) * 8.0;
    let got = r.msg_stats.bytes_per_iter();
    assert!(
        got > 0.5 * expect && got < 2.0 * expect,
        "bytes/iter {got} vs expected ~{expect}"
    );
    // dense for comparison moves the full model
    let mut cd = cfg("mlp", Algorithm::Dense, steps);
    cd.workers = 2;
    let mut td = Trainer::with_runtime(&rt, cd).unwrap();
    let rd = td.run().unwrap();
    // dense moves ~c/2 = 50x more (2x for the allreduce round trip vs
    // allgather, over the c=100 compression) — check a safe 30x margin
    assert!(rd.msg_stats.bytes_per_iter() > 30.0 * got);
}
