//! Exhaustive-interleaving model of the overlap pipeline's shared state
//! (`lags::analysis::interleave` — the in-repo mini-loom that runs under
//! plain `cargo test`; the real-loom twin lives in `loom_model.rs` behind
//! `--cfg loom`).
//!
//! The overlap path's concurrency contract: P worker threads publish
//! per-layer messages into an mpsc channel in backprop order, racing each
//! other; the aggregator thread lands them in the `StreamAggregator`'s
//! rank-indexed slots and fires layers strictly in backprop order, staging
//! completions in the `MergeBuffer`. Determinism demands that NOTHING
//! observable — fired order, per-layer reductions, merge grouping —
//! depends on the cross-thread interleaving. These tests replay every
//! schedule of the per-thread publish sequences and assert bit-identical
//! outcomes, which is exactly the property `cargo test` cannot establish
//! by running threads (one execution = one schedule).

use lags::analysis::interleave::{count, for_each_schedule};
use lags::collectives::pipeline::{LayerMsg, StreamAggregator};
use lags::collectives::sparse_agg;
use lags::pipeline::merge::MergeBuffer;
use lags::sparsify::sparse::SparseVec;
use lags::util::clock;
use lags::util::rng::Rng;

const LAYER_N: usize = 16;

/// Deterministic per-(rank, layer) sparse message — same values every
/// replay, distinct across (rank, layer).
fn msg(rank: usize, layer: usize) -> SparseVec {
    let mut rng = Rng::new(0x5EED + (rank * 31 + layer) as u64);
    let mut dense = vec![0.0f32; LAYER_N];
    for i in rng.sample_distinct(LAYER_N, 5) {
        dense[i] = rng.normal_f32();
    }
    SparseVec::from_dense(&dense)
}

fn layer_msg(rank: usize, layer: usize) -> LayerMsg {
    LayerMsg { rank, layer, msg: msg(rank, layer), sent: clock::now() }
}

/// The barrier reference: rank-ordered reduction of `ranks`' messages for
/// each layer, laid out back to back.
fn reference(layers: usize, ranks: &[usize]) -> Vec<f32> {
    let mut out = vec![0.0f32; layers * LAYER_N];
    for li in 0..layers {
        let msgs: Vec<SparseVec> = ranks.iter().map(|&r| msg(r, li)).collect();
        sparse_agg::sparse_add_rank_ordered(
            msgs.iter(),
            &mut out[li * LAYER_N..(li + 1) * LAYER_N],
        );
    }
    out
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Replay one publish schedule through a fresh aggregator: thread `t`'s
/// j-th op publishes layer `layers-1-j` from rank `t` (backprop order, as
/// the worker loop does). Returns (fired order, reduced flat aggregate).
fn replay(agg: &mut StreamAggregator, layers: usize, schedule: &[usize]) -> (Vec<usize>, Vec<f32>) {
    let mut next_op = vec![0usize; agg.workers()];
    let mut fired = Vec::new();
    let mut out = vec![0.0f32; layers * LAYER_N];
    for &t in schedule {
        let li = layers - 1 - next_op[t];
        next_op[t] += 1;
        agg.push(layer_msg(t, li), |l, _slots| fired.push(l));
    }
    // reduce in fired order (the callback order IS the reduction order in
    // drain_stream; doing it after the replay is equivalent because slots
    // are never overwritten once landed)
    for &li in &fired {
        let required = agg.required().to_vec();
        let msgs: Vec<&SparseVec> = agg
            .layer_slots(li)
            .iter()
            .zip(required.iter())
            .filter(|(_, &req)| req)
            .map(|(s, _)| s.as_ref().expect("required slot"))
            .collect();
        sparse_agg::sparse_add_rank_ordered(
            msgs.into_iter(),
            &mut out[li * LAYER_N..(li + 1) * LAYER_N],
        );
    }
    (fired, out)
}

#[test]
fn stream_aggregator_invariant_under_all_interleavings_p3_l3() {
    let (layers, p) = (3usize, 3usize);
    let want_fired: Vec<usize> = (0..layers).rev().collect();
    let want = bits(&reference(layers, &[0, 1, 2]));
    let lens = vec![layers; p];
    assert_eq!(count(&lens), 1680, "multinomial (9)!/(3!)^3");
    let mut agg = StreamAggregator::new(layers, p);
    let n = for_each_schedule(&lens, |schedule| {
        agg.reset();
        let (fired, out) = replay(&mut agg, layers, schedule);
        assert_eq!(fired, want_fired, "backprop fire order, schedule {schedule:?}");
        assert!(agg.finished());
        assert_eq!(bits(&out), want, "bit-identical reduction, schedule {schedule:?}");
    });
    assert_eq!(n, 1680);
}

#[test]
fn quorum_mask_excludes_straggler_under_all_interleavings() {
    // rank 1 is quorum-excluded: its publishes land in slots (for the
    // residual-reclaim path) but must neither gate nor enter the
    // reduction, under EVERY interleaving of the three publishers.
    let (layers, p) = (3usize, 3usize);
    let want_fired: Vec<usize> = (0..layers).rev().collect();
    let want = bits(&reference(layers, &[0, 2]));
    let mask = [true, false, true];
    let lens = vec![layers; p];
    let mut agg = StreamAggregator::new(layers, p);
    let n = for_each_schedule(&lens, |schedule| {
        agg.reset();
        agg.arm_participants(&mask);
        assert_eq!(agg.required_count(), 2);
        let (fired, out) = replay(&mut agg, layers, schedule);
        assert_eq!(fired, want_fired, "schedule {schedule:?}");
        assert!(agg.finished(), "all layers fire on the 2-rank quorum");
        assert_eq!(bits(&out), want, "excluded rank never reduced, schedule {schedule:?}");
        // the straggler's buffers stayed reclaimable
        for li in 0..layers {
            assert!(agg.layer_slots(li)[1].is_some(), "excluded slot retained");
        }
    });
    assert_eq!(n, 1680);
}

#[test]
fn late_quorum_straggler_never_refires_a_layer() {
    // straggler's ops all land AFTER every required publish: each of its
    // messages hits an already-fired layer and must not re-fire it
    let (layers, p) = (2usize, 3usize);
    let mut agg = StreamAggregator::new(layers, p);
    agg.arm_participants(&[true, false, true]);
    let mut fired = Vec::new();
    for li in (0..layers).rev() {
        for rank in [0usize, 2] {
            agg.push(layer_msg(rank, li), |l, _| fired.push(l));
        }
    }
    assert_eq!(fired, vec![1, 0]);
    assert!(agg.finished());
    for li in (0..layers).rev() {
        agg.push(layer_msg(1, li), |l, _| fired.push(l));
    }
    assert_eq!(fired, vec![1, 0], "late arrivals fire nothing");
}

#[test]
fn merge_grouping_is_schedule_invariant() {
    // the merge buffer's grouping consumes completions, which arrive in
    // backprop order regardless of the publish interleaving — so the §5
    // group partition (and with it MessageStats) must be identical across
    // every schedule. Capacity chosen so the partition is non-trivial:
    // push_with stages then checks, so layers 2+1 fill the first group
    // and layer 0 rides the end-of-backprop flush.
    let (layers, p) = (3usize, 2usize);
    let bytes: Vec<usize> = (0..layers).map(|li| msg(0, li).wire_bytes() * p).collect();
    let capacity = bytes[2] + bytes[1]; // first group fills on the second staging
    let lens = vec![layers; p];
    let mut expected: Option<Vec<Vec<usize>>> = None;
    let mut agg = StreamAggregator::new(layers, p);
    let n = for_each_schedule(&lens, |schedule| {
        agg.reset();
        let mut merge: MergeBuffer<usize> = MergeBuffer::new(capacity);
        let mut groups: Vec<Vec<usize>> = Vec::new();
        let mut next_op = vec![0usize; p];
        for &t in schedule {
            let li = layers - 1 - next_op[t];
            next_op[t] += 1;
            let mut completed = Vec::new();
            agg.push(layer_msg(t, li), |l, _| completed.push(l));
            for l in completed {
                merge.push_with(l, bytes[l], l);
            }
            for g in merge.take_groups() {
                groups.push(g.layer_indices);
            }
        }
        merge.flush();
        for g in merge.take_groups() {
            groups.push(g.layer_indices);
        }
        // every layer staged exactly once, in backprop order overall
        let flat: Vec<usize> = groups.iter().flatten().copied().collect();
        assert_eq!(flat, vec![2, 1, 0], "schedule {schedule:?}");
        match &expected {
            None => expected = Some(groups),
            Some(e) => assert_eq!(e, &groups, "grouping differs for schedule {schedule:?}"),
        }
    });
    assert_eq!(n, count(&lens));
    let e = expected.unwrap();
    assert_eq!(e.len(), 2, "partition is non-trivial: [2, 1], [0]");
    assert_eq!(e[0], vec![2, 1]);
}

#[test]
fn merge_capacity_resize_interleaved_with_pushes_conserves_layers() {
    // elastic membership resizes the live merge capacity between layer
    // completions; model a shrink racing the push sequence. Whatever the
    // interleaving: each layer lands in exactly one group, groups preserve
    // backprop order, and the final flush leaves nothing staged.
    let layers = 3usize;
    let bytes = [40usize, 40, 40];
    // thread 0: stage layers 2, 1, 0; thread 1: one capacity shrink
    let lens = vec![layers, 1];
    let n = for_each_schedule(&lens, |schedule| {
        let mut merge: MergeBuffer<usize> = MergeBuffer::new(1000);
        let mut groups: Vec<Vec<usize>> = Vec::new();
        let mut staged = 0usize;
        for &t in schedule {
            if t == 0 {
                let li = layers - 1 - staged;
                staged += 1;
                merge.push_with(li, bytes[li], li);
            } else {
                merge.set_capacity(50); // shrink below one staged layer's bytes
            }
            for g in merge.take_groups() {
                groups.push(g.layer_indices);
            }
        }
        merge.flush();
        for g in merge.take_groups() {
            groups.push(g.layer_indices);
        }
        assert_eq!(merge.pending_bytes(), 0, "schedule {schedule:?}");
        let flat: Vec<usize> = groups.iter().flatten().copied().collect();
        assert_eq!(flat, vec![2, 1, 0], "conservation + order, schedule {schedule:?}");
    });
    assert_eq!(n, 4, "C(4,1) placements of the resize among 3 pushes");
}

#[test]
fn resize_between_steps_replays_cleanly() {
    // elastic membership: the live aggregator is resized between steps;
    // the post-resize step must satisfy the same all-interleavings
    // invariant as a freshly constructed one.
    let mut agg = StreamAggregator::new(2, 2);
    let (fired, _) = replay(&mut agg, 2, &[0, 1, 0, 1]);
    assert_eq!(fired, vec![1, 0]);
    agg.resize(3, 2);
    assert!(!agg.finished());
    let layers = 3;
    let want = bits(&reference(layers, &[0, 1]));
    let lens = vec![layers; 2];
    let n = for_each_schedule(&lens, |schedule| {
        agg.reset();
        let (fired, out) = replay(&mut agg, layers, schedule);
        assert_eq!(fired, vec![2, 1, 0]);
        assert_eq!(bits(&out), want, "schedule {schedule:?}");
    });
    assert_eq!(n, 20, "multinomial (6)!/(3!)^2");
}
