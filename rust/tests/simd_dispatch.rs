//! Per-ISA bit-identity of the SIMD kernel tier (`runtime::simd`).
//!
//! The module contract says every dispatched kernel — GEMM tile, TopK
//! select, sparse reduction — is bit-identical to its scalar reference.
//! This suite proves it at three levels:
//!
//! 1. the GEMM drivers against `gemm_ref` under every available FORCED
//!    ISA, over fixed shapes (full tiles, lane tails, row/column
//!    remainders, KC boundary) plus a randomized shape sweep;
//! 2. the select / sparse-add kernels through `KernelSet::for_isa`
//!    directly (no global state needed);
//! 3. a short end-to-end training run: the final loss bits under every
//!    available ISA must equal the scalar run's.
//!
//! `set_active` re-points the process-global dispatch, so every test that
//! forces an ISA serializes on one mutex and restores the detected ISA
//! before releasing it.

use lags::config::TrainConfig;
use lags::runtime::kernels::{self, GemmScratch};
use lags::runtime::simd::{self, Isa, KernelSet};
use lags::trainer::{Algorithm, Trainer};
use lags::util::rng::Rng;
use std::sync::Mutex;

static ISA_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with the process dispatch forced to `isa`, restoring the
/// detected ISA before releasing the lock.
fn with_isa<T>(isa: Isa, f: impl FnOnce() -> T) -> T {
    let _g = ISA_LOCK.lock().unwrap();
    simd::set_active(isa).unwrap();
    let out = f();
    simd::set_active(Isa::detect()).unwrap();
    out
}

fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32()).collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// All three GEMM drivers at one shape must match the fixed-order
/// reference bitwise under the CURRENTLY dispatched ISA.
fn check_gemm_shape(m: usize, k: usize, n: usize, seed: u64, label: &str) {
    let mut rng = Rng::new(seed);
    let a = randvec(&mut rng, m * k);
    let b = randvec(&mut rng, k * n);
    let c0 = randvec(&mut rng, m * n);
    let (mut at, mut bt) = (Vec::new(), Vec::new());
    kernels::pack_transpose(&a, m, k, &mut at);
    kernels::pack_transpose(&b, k, n, &mut bt);

    let mut want = c0.clone();
    kernels::gemm_ref(&mut want, &a, false, &b, false, m, k, n);

    let mut got = c0.clone();
    kernels::gemm_nn(&mut got, &a, &b, m, k, n);
    assert_eq!(bits(&got), bits(&want), "{label} nn {m}x{k}x{n}");

    let mut got = c0.clone();
    kernels::gemm_tn(&mut got, &at, &b, m, k, n);
    assert_eq!(bits(&got), bits(&want), "{label} tn {m}x{k}x{n}");

    let mut got = c0.clone();
    let mut scratch = GemmScratch::default();
    kernels::gemm_nt(&mut got, &a, &bt, m, k, n, &mut scratch);
    assert_eq!(bits(&got), bits(&want), "{label} nt {m}x{k}x{n}");
}

/// Fixed shapes: exactly one scalar tile, one AVX-512-width tile, lane
/// tails either side of nr ∈ {8, 16}, row remainders, GEMV rows, a K
/// crossing the KC=256 block boundary — under every available ISA.
#[test]
fn gemm_matches_ref_bitwise_under_every_forced_isa() {
    let shapes = [
        (1usize, 1usize, 1usize),
        (4, 8, 8),     // one full scalar/AVX2/NEON tile
        (4, 8, 16),    // one full AVX-512 tile (two 8-wide)
        (5, 9, 11),    // remainders everywhere
        (7, 13, 17),   // one 16-wide or two 8-wide tiles + 1-column tail
        (6, 10, 33),   // crosses both 8- and 16-wide tile counts
        (1, 64, 64),   // the Elman GEMV shape
        (3, 7, 1),     // single output column
        (16, 300, 20), // K crosses the KC=256 block boundary
    ];
    for isa in Isa::available() {
        with_isa(isa, || {
            for (si, &(m, k, n)) in shapes.iter().enumerate() {
                check_gemm_shape(m, k, n, 0x51d0 ^ ((si as u64) << 8), isa.name());
            }
        });
    }
}

/// Randomized M/K/N sweep per ISA — the property form of the fixed-shape
/// test, biased toward small dims so tails and remainders dominate.
#[test]
fn gemm_matches_ref_bitwise_random_shapes() {
    for isa in Isa::available() {
        with_isa(isa, || {
            let mut shape_rng = Rng::new(0xbead ^ isa as u64);
            for case in 0..40u64 {
                let m = 1 + (shape_rng.next_u64() % 9) as usize;
                let k = 1 + (shape_rng.next_u64() % 300) as usize;
                let n = 1 + (shape_rng.next_u64() % 40) as usize;
                check_gemm_shape(m, k, n, 0xca5e ^ (case << 16), isa.name());
            }
        });
    }
}

/// The select / sparse-add families through `KernelSet::for_isa` — same
/// coverage grid as the module's unit test but from the integration
/// surface, including the dispatched `topk` entry points.
#[test]
fn select_and_sparse_add_match_scalar_for_every_isa() {
    let scalar = KernelSet::for_isa(Isa::Scalar);
    for isa in Isa::available() {
        let ks = KernelSet::for_isa(isa);
        for n in [0usize, 1, 5, 8, 15, 16, 17, 31, 32, 33, 127, 250] {
            let mut rng = Rng::new(0xf00d + n as u64);
            let mut x = randvec(&mut rng, n);
            if n >= 4 {
                x[0] = f32::NAN;
                x[1] = f32::NEG_INFINITY;
                x[2] = -0.0;
                x[3] = 0.0;
            }
            for thr in [0.0f32, 0.7, f32::INFINITY, f32::NAN] {
                let (mut m0, mut m1) = (vec![7.0f32; n], vec![7.0f32; n]);
                scalar.mask_with_threshold(&x, thr, &mut m0);
                ks.mask_with_threshold(&x, thr, &mut m1);
                assert_eq!(bits(&m0), bits(&m1), "{} mask n={n}", isa.name());
                let (mut k0, mut r0) = (vec![7.0f32; n], vec![7.0f32; n]);
                let (mut k1, mut r1) = (vec![7.0f32; n], vec![7.0f32; n]);
                scalar.split_with_threshold(&x, thr, &mut k0, &mut r0);
                ks.split_with_threshold(&x, thr, &mut k1, &mut r1);
                assert_eq!(bits(&k0), bits(&k1), "{} kept n={n}", isa.name());
                assert_eq!(bits(&r0), bits(&r1), "{} resid n={n}", isa.name());
            }
            // strictly-increasing sparse indices with irregular gaps
            let mut idx = Vec::new();
            let mut at = 0u32;
            for _ in 0..n {
                at += 1 + (rng.next_u64() % 7) as u32;
                idx.push(at);
            }
            let dense = at as usize + 3;
            let val = randvec(&mut rng, n);
            let mut o0 = randvec(&mut rng, dense);
            let mut o1 = o0.clone();
            scalar.sparse_add(&idx, &val, &mut o0);
            ks.sparse_add(&idx, &val, &mut o1);
            assert_eq!(bits(&o0), bits(&o1), "{} sparse_add n={n}", isa.name());
        }
    }
}

/// End-to-end ISA invariance: a short LAGS run on the native mlp must
/// produce the same final-loss bits under every available ISA as under
/// the forced scalar reference — the whole-trainer form of the kernel
/// contract (and what the forced-ISA CI matrix re-proves at scale).
#[test]
fn training_is_isa_invariant_end_to_end() {
    let run_under = |isa: Isa| -> u64 {
        with_isa(isa, || {
            let mut cfg = TrainConfig::default_for("mlp");
            cfg.steps = 6;
            cfg.workers = 2;
            cfg.algorithm = Algorithm::Lags;
            let mut t = Trainer::from_artifacts("native", cfg).unwrap();
            t.run().unwrap().final_loss.to_bits()
        })
    };
    let scalar_bits = run_under(Isa::Scalar);
    for isa in Isa::available() {
        assert_eq!(
            run_under(isa),
            scalar_bits,
            "final loss bits diverged under {}",
            isa.name()
        );
    }
}
