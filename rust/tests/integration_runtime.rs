//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These close the three-layer loop: the Pallas kernels were verified
//! against ref.py in pytest; here the SAME artifacts are executed from
//! rust and checked against the rust host implementations, proving the
//! host/XLA compressor paths are interchangeable and the train/eval/apply
//! artifacts have the contracted signatures.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use lags::runtime::native::CompressScratch;
use lags::runtime::{BatchData, Runtime};
use lags::sparsify::{topk, ErrorFeedback};
use lags::util::rng::Rng;
use std::sync::Arc;

fn runtime() -> Option<Arc<Runtime>> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    match Runtime::load("artifacts") {
        Ok(rt) => Some(Arc::new(rt)),
        // e.g. a non-pjrt build with artifacts present — skip, don't panic
        Err(e) => {
            eprintln!("skipping: {e:#}");
            None
        }
    }
}

fn randvec(n: usize, seed: u64, scale: f32) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal_f32() * scale).collect()
}

#[test]
fn manifest_models_all_load() {
    let Some(rt) = runtime() else { return };
    assert!(rt.manifest.models.contains_key("mlp"));
    assert!(rt.manifest.models.contains_key("translm_e2e"));
    for m in rt.manifest.models.values() {
        m.validate().unwrap();
        assert_eq!(rt.manifest.load_init_params(m).unwrap().len(), m.d);
    }
}

#[test]
fn train_step_runs_and_grad_is_finite() {
    let Some(rt) = runtime() else { return };
    let mr = rt.model_runtime("mlp").unwrap();
    let mm = &mr.mm;
    let x = BatchData::F32(randvec(mm.x.elements(), 1, 1.0));
    let y = BatchData::I32(vec![0; mm.y.elements()]);
    let (loss, grad) = mr.train_step(&mr.init_params, &x, &y).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert_eq!(grad.len(), mm.d);
    assert!(grad.iter().all(|g| g.is_finite()));
    // gradient must be nonzero somewhere
    assert!(grad.iter().any(|&g| g != 0.0));
}

#[test]
fn train_step_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let mr = rt.model_runtime("cnn").unwrap();
    let mm = &mr.mm;
    let x = BatchData::F32(randvec(mm.x.elements(), 2, 1.0));
    let y = BatchData::I32(vec![1; mm.y.elements()]);
    let (l1, g1) = mr.train_step(&mr.init_params, &x, &y).unwrap();
    let (l2, g2) = mr.train_step(&mr.init_params, &x, &y).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(g1, g2);
}

#[test]
fn eval_step_metric_contract() {
    let Some(rt) = runtime() else { return };
    // classifier: metric is accuracy in [0,1]
    let mr = rt.model_runtime("mlp").unwrap();
    let x = BatchData::F32(randvec(mr.mm.x.elements(), 3, 1.0));
    let y = BatchData::I32(vec![2; mr.mm.y.elements()]);
    let (loss, acc) = mr.eval_step(&mr.init_params, &x, &y).unwrap();
    assert!(loss.is_finite());
    assert!((0.0..=1.0).contains(&acc));
    // LM: metric == loss
    let lm = rt.model_runtime("grulm").unwrap();
    let x = BatchData::I32(vec![1; lm.mm.x.elements()]);
    let y = BatchData::I32(vec![2; lm.mm.y.elements()]);
    let (loss, metric) = lm.eval_step(&lm.init_params, &x, &y).unwrap();
    assert!((loss - metric).abs() < 1e-5);
}

#[test]
fn xla_compress_matches_host_exact() {
    let Some(rt) = runtime() else { return };
    let mr = rt.model_runtime("mlp").unwrap();
    let lr = 0.07f32;
    for layer in &mr.mm.layers {
        let n = layer.size;
        let k = (n / 50).max(1);
        let grad = randvec(n, 10 + layer.offset as u64, 1.0);
        let resid = randvec(n, 11 + layer.offset as u64, 0.2);

        // host reference
        let mut ef = ErrorFeedback::new(n, 64);
        ef.write_residual(0, &resid);
        let mut kept = vec![0.0f32; n];
        ef.compress_layer(0, &grad, lr, k, true, &mut kept);

        // XLA Pallas artifact
        let (sparse, new_resid, thr) =
            mr.compress_layer_xla(layer, &grad, &resid, lr, k, false, &mut CompressScratch::default())
                .unwrap();

        assert!(thr.is_finite());
        for i in 0..n {
            assert!(
                (sparse[i] - kept[i]).abs() < 1e-5,
                "layer {} i {}: xla {} host {}",
                layer.name,
                i,
                sparse[i],
                kept[i]
            );
            assert!((new_resid[i] - ef.residual()[i]).abs() < 1e-5);
        }
    }
}

#[test]
fn xla_compress_error_feedback_conserves_mass() {
    let Some(rt) = runtime() else { return };
    let mr = rt.model_runtime("cnn").unwrap();
    let layer = mr.mm.layers.iter().max_by_key(|l| l.size).unwrap();
    let n = layer.size;
    let grad = randvec(n, 20, 1.0);
    let resid = randvec(n, 21, 0.3);
    let lr = 0.1f32;
    let (sparse, new_resid, _) =
        mr.compress_layer_xla(layer, &grad, &resid, lr, n / 100 + 1, false, &mut CompressScratch::default())
            .unwrap();
    for i in 0..n {
        let acc = resid[i] + lr * grad[i];
        assert!((sparse[i] + new_resid[i] - acc).abs() < 1e-5, "i={i}");
    }
}

#[test]
fn xla_compress_sampled_keeps_roughly_k() {
    let Some(rt) = runtime() else { return };
    let mr = rt.model_runtime("mlp").unwrap();
    let layer = mr.mm.layers.iter().max_by_key(|l| l.size).unwrap();
    let n = layer.size;
    let k = n / 100;
    let grad = randvec(n, 30, 1.0);
    let resid = vec![0.0f32; n];
    let (sparse, _, _) = mr.compress_layer_xla(layer, &grad, &resid, 1.0, k, true, &mut CompressScratch::default()).unwrap();
    let nnz = sparse.iter().filter(|&&v| v != 0.0).count();
    assert!(nnz >= k / 4 && nnz <= k * 4, "nnz={nnz} k={k}");
}

#[test]
fn xla_apply_matches_host() {
    let Some(rt) = runtime() else { return };
    let mr = rt.model_runtime("cnn").unwrap();
    let dp = mr.mm.d_padded;
    let params = randvec(dp, 40, 1.0);
    let mom = randvec(dp, 41, 0.05);
    let agg = randvec(dp, 42, 0.01);
    let mu = 0.9f32;
    let (p2, m2) = mr.apply_update(&params, &mom, &agg, mu).unwrap();
    for i in 0..dp {
        let m_expect = mu * mom[i] + agg[i];
        assert!((m2[i] - m_expect).abs() < 1e-5, "mom i={i}");
        assert!((p2[i] - (params[i] - m_expect)).abs() < 1e-5, "param i={i}");
    }
}

#[test]
fn sgd_on_artifact_reduces_loss() {
    // pure-runtime sanity: repeated (train_step; apply) must overfit a
    // fixed batch through the AOT artifacts alone (no trainer involved)
    let Some(rt) = runtime() else { return };
    let mr = rt.model_runtime("mlp").unwrap();
    let mm = mr.mm.clone();
    let x = BatchData::F32(randvec(mm.x.elements(), 50, 1.0));
    let mut yv = vec![0i32; mm.y.elements()];
    let mut rng = Rng::new(51);
    for v in yv.iter_mut() {
        *v = rng.below(mm.classes) as i32;
    }
    let y = BatchData::I32(yv);

    let mut params = mr.init_params.clone();
    let mut mom = vec![0.0f32; mm.d_padded];
    let (loss0, _) = mr.train_step(&params, &x, &y).unwrap();
    let mut last = loss0;
    for _ in 0..25 {
        let (loss, grad) = mr.train_step(&params, &x, &y).unwrap();
        last = loss;
        // agg = lr * grad, padded; apply via the Pallas artifact
        let mut agg = vec![0.0f32; mm.d_padded];
        for (a, g) in agg.iter_mut().zip(grad.iter()) {
            *a = 0.3 * g;
        }
        let mut ppad = vec![0.0f32; mm.d_padded];
        ppad[..mm.d].copy_from_slice(&params);
        let (p2, m2) = mr.apply_update(&ppad, &mom, &agg, 0.0).unwrap();
        params.copy_from_slice(&p2[..mm.d]);
        mom = m2;
    }
    assert!(last < 0.6 * loss0, "loss {loss0} -> {last}");
}

#[test]
fn topk_threshold_stability_across_layers() {
    // host threshold on padded bucket == threshold on raw layer (zeros pad)
    let Some(rt) = runtime() else { return };
    let mr = rt.model_runtime("grulm").unwrap();
    for layer in &mr.mm.layers {
        let n = layer.size;
        let k = (n / 20).max(1);
        let x = randvec(n, 60 + layer.offset as u64, 1.0);
        let mut padded = vec![0.0f32; layer.bucket];
        padded[..n].copy_from_slice(&x);
        let t1 = topk::kth_largest_abs(&x, k);
        let t2 = topk::kth_largest_abs(&padded, k);
        assert_eq!(t1, t2, "layer {}", layer.name);
    }
}
