//! EQ19 bench: S_max sweep over the communication-to-computation ratio
//! r = t_c / t_b (the paper's analysis after Eq. 19), for each calibrated
//! model profile — shows the r=1 peak and the 1 + t_b/(t_f+t_b) ceiling.
//!
//!     cargo bench --bench smax_eq19

use lags::adaptive::perf_model;
use lags::models::zoo;
use lags::util::bench;

fn main() {
    for m in zoo::table2_models() {
        let (t_f, t_b) = (m.t_f, m.t_b());
        let ceiling = 1.0 + t_b / (t_f + t_b);
        println!(
            "\n# {}: t_f={t_f:.3}s t_b={t_b:.3}s, S_max ceiling = {ceiling:.3}",
            m.name
        );
        bench::table_header(&["r", "S_max"]);
        for i in 0..=16 {
            let r = 0.1 * (10f64).powf(i as f64 / 8.0); // 0.1 .. 10 log grid
            bench::table_row(&[
                format!("{r:.2}"),
                format!("{:.4}", perf_model::smax(t_f, t_b, r * t_b)),
            ]);
        }
    }
    // the formula itself is branch-light; verify it's effectively free
    let m = zoo::resnet50();
    bench::run_val("smax_eval", || perf_model::smax(m.t_f, m.t_b(), 0.3));
}
