//! FAULTS bench: straggler degradation and what wins it back.
//!
//! Four scenarios over the native `mlp_deep` at P = 4, c = 20:
//!
//! * `baseline`   — healthy cluster, fixed uniform ratios
//! * `skew4`      — worker 1 runs 4× slow, fixed ratios (`--reselect-every 0`)
//! * `skew4_resel`— same skew, Eq. 18 re-selection against the MEASURED
//!                  straggler-inflated profile (`--adaptive --reselect-every 4`)
//! * `skew4_q3`   — same skew, bounded-staleness quorum 3 of 4
//!
//! Each `BENCH_faults.json` row carries the measured step median plus
//! `des_iter_s` (the DES prediction on the configured α–β network under
//! the SAME fault plan), `final_loss_30` (a fresh fixed 30-step run, so
//! losses are comparable across rows), `gate` (the q-th-fastest skew that
//! paces the synchronous step) and `effective_cmax` when adaptive.
//!
//! Read the DES and measured columns together: the in-process trainer
//! shares one machine, so the quorum cannot reclaim the straggler's REAL
//! wall clock (its sleep still runs on a local thread) — the DES is where
//! the wall-clock recovery shows (gate 4 → 1), while the measured rows
//! validate numerics and the re-selection's lower effective c_max.
//!
//!     cargo bench --bench faults

use lags::cluster::faults::FaultPlan;
use lags::config::TrainConfig;
use lags::runtime::Runtime;
use lags::trainer::{Algorithm, Trainer};
use lags::util::bench;
use std::sync::Arc;

struct Scenario {
    name: &'static str,
    skew: bool,
    quorum: usize,
    reselect: bool,
}

fn skew4() -> FaultPlan {
    FaultPlan { compute_skew: vec![1.0, 4.0, 1.0, 1.0], ..FaultPlan::none() }
}

fn cfg(s: &Scenario) -> TrainConfig {
    let mut c = TrainConfig::default_for("mlp_deep");
    c.algorithm = Algorithm::Lags;
    c.workers = 4;
    c.threads = 2;
    c.lr = 0.1;
    c.compression = 20.0;
    c.eval_every = 0;
    if s.skew {
        c.faults = skew4();
    }
    c.quorum = s.quorum;
    c.staleness_bound = if s.quorum > 0 { 4 } else { 0 };
    if s.reselect {
        c.adaptive = true;
        c.reselect_every = 4;
    }
    c
}

fn main() {
    let scenarios = [
        Scenario { name: "baseline", skew: false, quorum: 0, reselect: false },
        Scenario { name: "skew4", skew: true, quorum: 0, reselect: false },
        Scenario { name: "skew4_resel", skew: true, quorum: 0, reselect: true },
        Scenario { name: "skew4_q3", skew: true, quorum: 3, reselect: false },
    ];
    let rt = Arc::new(Runtime::native(42));

    println!("# robustness: straggler (4x on worker 1) vs re-selection vs quorum, P=4");
    bench::table_header(&["scenario", "step_ms", "des_iter_s", "loss@30", "gate", "eff_cmax"]);
    for s in &scenarios {
        let name = format!("faults_P4_{}", s.name);

        // measured step wall-clock — includes the straggler sleeps, the
        // re-selection bookkeeping and (for quorum) the late-message folds
        let mut t = Trainer::with_runtime(&rt, cfg(s)).unwrap();
        let stats = bench::run(&name, || {
            t.step().unwrap();
        });

        // the DES twin: same plan, same live ratios, α–β-priced network.
        // This is where the quorum's wall-clock recovery is visible — the
        // compute gate falls from the slowest skew to the q-th fastest.
        let sim = t.simulated_iteration();
        bench::annotate(&name, "des_iter_s", sim.iter_time);
        let rb = t.robustness_stats();
        let gate = if !s.skew {
            1.0
        } else if s.quorum > 0 {
            1.0 // 3rd-fastest of [1, 4, 1, 1]
        } else {
            4.0
        };
        bench::annotate(&name, "gate", gate);
        bench::annotate(&name, "quorum_misses", rb.total_quorum_misses() as f64);

        // fixed-length convergence twin: a FRESH 30-step run so the loss
        // column is comparable across scenarios (the bench loop above
        // runs a machine-dependent number of steps)
        let mut t30 = Trainer::with_runtime(&rt, cfg(s)).unwrap();
        let mut loss30 = f64::NAN;
        for _ in 0..30 {
            loss30 = t30.step().unwrap();
        }
        bench::annotate(&name, "final_loss_30", loss30);

        // re-selection against the gate-inflated profile trades
        // compression for overlap budget: effective c_max drops
        let eff_cmax = t.selections().last().map(|sel| sel.effective_cmax);
        if let Some(cm) = eff_cmax {
            bench::annotate(&name, "effective_cmax", cm);
        }
        bench::table_row(&[
            s.name.to_string(),
            format!("{:.3}", stats.median * 1e3),
            format!("{:.4}", sim.iter_time),
            format!("{loss30:.4}"),
            format!("{gate:.1}"),
            eff_cmax.map_or("-".to_string(), |cm| format!("{cm:.0}")),
        ]);
    }

    bench::write_json("BENCH_faults.json").expect("write BENCH_faults.json");
}
