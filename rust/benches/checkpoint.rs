//! CHECKPOINT bench: durable-state cost and the steady-state overhead of
//! periodic checkpointing.
//!
//! For each of `mlp_deep`, `convnet_deep` and `rnn` at P = 4, c = 20:
//!
//! * `ckpt_write_<model>`   — latency of one atomic checkpoint write
//!   (capture + encode + fsync + rename), annotated with `bytes` (the
//!   on-disk size) and `write_mb_s` (encode+fsync throughput)
//! * `ckpt_restore_<model>` — latency of a full restore: read + checksum
//!   verify + decode + rebuild a trainer from the snapshot
//! * `step_<model>_every{0,1,10,100}` — training-step wall clock with
//!   checkpointing off vs `--checkpoint-every {1,10,100}`; the every-N
//!   rows carry `overhead_pct` relative to the every-0 baseline, i.e. the
//!   amortized price of durability at each cadence
//!
//! Emits `BENCH_checkpoint.json` (atomic write) for the perf trajectory.
//!
//!     cargo bench --bench checkpoint

use lags::config::TrainConfig;
use lags::runtime::Runtime;
use lags::trainer::{Algorithm, Trainer};
use lags::util::bench;
use std::sync::Arc;

fn cfg(model: &str, dir: &str, every: usize) -> TrainConfig {
    let mut c = TrainConfig::default_for(model);
    c.algorithm = Algorithm::Lags;
    c.workers = 4;
    c.threads = 2;
    c.lr = 0.1;
    c.compression = 20.0;
    c.eval_every = 0;
    c.checkpoint_dir = dir.to_string();
    c.checkpoint_every = every;
    c
}

fn main() {
    let rt = Arc::new(Runtime::native(42));
    let scratch = std::env::temp_dir().join(format!("lags-bench-ckpt-{}", std::process::id()));

    println!("# checkpoint: write/restore latency and per-step overhead, P=4, c=20");
    bench::table_header(&["model", "write_ms", "size_kb", "restore_ms", "ovh@1", "ovh@10", "ovh@100"]);
    for model in ["mlp_deep", "convnet_deep", "rnn"] {
        let dir = scratch.join(model);
        let dir_s = dir.to_string_lossy().into_owned();

        // warm a trainer a few steps so the snapshot carries realistic
        // residual/momentum state, then measure one durable write
        let mut t = Trainer::with_runtime(&rt, cfg(model, &dir_s, 0)).unwrap();
        for _ in 0..3 {
            t.step().unwrap();
        }
        let write_name = format!("ckpt_write_{model}");
        let ws = bench::run(&write_name, || {
            t.save_checkpoint().unwrap();
        });
        let path = Trainer::checkpoint_path(&dir_s);
        let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        bench::annotate(&write_name, "bytes", bytes as f64);
        bench::annotate(&write_name, "write_mb_s", bytes as f64 / 1e6 / ws.median.max(1e-12));

        // full restore: read + checksum + decode + rebuild the trainer
        let restore_name = format!("ckpt_restore_{model}");
        let rs = bench::run_val(&restore_name, || {
            Trainer::resume_with_runtime(&rt, &dir_s).unwrap()
        });

        // steady-state step cost at each checkpoint cadence; every=0 is
        // the no-durability baseline the overheads are measured against
        let mut medians = Vec::new();
        for every in [0usize, 1, 10, 100] {
            let mut tt = Trainer::with_runtime(&rt, cfg(model, &dir_s, every)).unwrap();
            let name = format!("step_{model}_every{every}");
            let s = bench::run(&name, || {
                tt.step().unwrap();
            });
            medians.push((name, every, s.median));
        }
        let base = medians[0].2.max(1e-12);
        let mut ovh = Vec::new();
        for (name, every, med) in &medians[1..] {
            let pct = (med - base) / base * 100.0;
            bench::annotate(name, "overhead_pct", pct);
            bench::annotate(name, "checkpoint_every", *every as f64);
            ovh.push(pct);
        }
        bench::table_row(&[
            model.to_string(),
            format!("{:.3}", ws.median * 1e3),
            format!("{:.1}", bytes as f64 / 1e3),
            format!("{:.3}", rs.median * 1e3),
            format!("{:.1}%", ovh[0]),
            format!("{:.1}%", ovh[1]),
            format!("{:.1}%", ovh[2]),
        ]);
    }

    std::fs::remove_dir_all(&scratch).ok();
    bench::write_json("BENCH_checkpoint.json").expect("write BENCH_checkpoint.json");
}
