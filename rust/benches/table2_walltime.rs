//! TAB2 bench: end-to-end DES iteration simulation per model/schedule —
//! regenerates the Table 2 rows and times the simulator itself.
//!
//!     cargo bench --bench table2_walltime

use lags::adaptive::perf_model;
use lags::collectives::NetworkModel;
use lags::models::zoo;
use lags::pipeline::desim::{simulate, Schedule, SimParams};
use lags::util::bench;

fn main() {
    let net = NetworkModel::gige_16();
    println!("# Table 2 rows (simulated, paper values in EXPERIMENTS.md)");
    bench::table_header(&["model", "dense_s", "slgs_s", "lags_s", "S1", "S2", "Smax"]);
    for m in zoo::table2_models() {
        let c = if m.name == "lstm_ptb" { 250.0 } else { 1000.0 };
        let sp = SimParams::uniform(&m, c);
        let dense = simulate(&m, &net, Schedule::DensePipelined, &SimParams::dense(&m));
        let slgs = simulate(&m, &net, Schedule::Slgs, &sp);
        let lags = simulate(&m, &net, Schedule::Lags, &sp);
        let smax = perf_model::smax(m.t_f, m.t_b(), slgs.t_comm);
        bench::table_row(&[
            m.name.clone(),
            format!("{:.3}", dense.iter_time),
            format!("{:.3}", slgs.iter_time),
            format!("{:.3}", lags.iter_time),
            format!("{:.2}", dense.iter_time / lags.iter_time),
            format!("{:.2}", slgs.iter_time / lags.iter_time),
            format!("{:.2}", smax),
        ]);
    }

    println!("\n# simulator micro-benchmarks");
    for m in zoo::table2_models() {
        let sp = SimParams::uniform(&m, 1000.0);
        let name = m.name.clone();
        bench::run_val(&format!("des_lags_{name}"), || {
            simulate(&m, &net, Schedule::Lags, &sp).iter_time
        });
    }
    // worker-count sweep: DES cost is O(L) regardless of P
    let m = zoo::resnet50();
    for p in [4usize, 16, 64, 256] {
        let net_p = NetworkModel::gige_16().with_workers(p);
        let sp = SimParams::uniform(&m, 1000.0);
        bench::run_val(&format!("des_lags_resnet50_P{p}"), || {
            simulate(&m, &net_p, Schedule::Lags, &sp).iter_time
        });
    }

    bench::write_json("BENCH_table2.json").expect("write BENCH_table2.json");
}
