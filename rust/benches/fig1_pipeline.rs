//! FIG1 bench: pipeline overlap quality per schedule — regenerates the
//! Fig. 1 comparison quantitatively (how much communication each schedule
//! hides) and sweeps the merge-buffer ablation from DESIGN.md.
//!
//!     cargo bench --bench fig1_pipeline

use lags::collectives::NetworkModel;
use lags::models::zoo;
use lags::pipeline::desim::{simulate, Schedule, SimParams};
use lags::util::bench;

fn main() {
    let net = NetworkModel::gige_16();
    println!("# Fig 1: communication hidden under computation, per schedule");
    bench::table_header(&["model", "schedule", "iter_s", "t_comm_s", "hidden_s", "hidden_%"]);
    for m in zoo::table2_models() {
        let c = if m.name == "lstm_ptb" { 250.0 } else { 1000.0 };
        for (sched, label) in [
            (Schedule::DenseSingle, "dense-single"),
            (Schedule::DensePipelined, "dense-pipelined"),
            (Schedule::Slgs, "slgs"),
            (Schedule::Lags, "lags"),
        ] {
            let p = match sched {
                Schedule::DenseSingle | Schedule::DensePipelined => SimParams::dense(&m),
                _ => SimParams::uniform(&m, c),
            };
            let b = simulate(&m, &net, sched, &p);
            bench::table_row(&[
                m.name.clone(),
                label.to_string(),
                format!("{:.3}", b.iter_time),
                format!("{:.3}", b.t_comm),
                format!("{:.3}", b.hidden),
                format!("{:.1}", 100.0 * b.hidden / b.t_comm.max(1e-12)),
            ]);
        }
    }

    println!("\n# ablation: merge-buffer capacity (LAGS, resnet50, c=1000)");
    bench::table_header(&["merge_bytes", "messages", "iter_s", "hidden_s"]);
    let m = zoo::resnet50();
    for cap in [0.0, 4096.0, 16384.0, 32768.0, 131072.0, 1048576.0, 1e12] {
        let mut p = SimParams::uniform(&m, 1000.0);
        p.merge_bytes = cap;
        let b = simulate(&m, &net, Schedule::Lags, &p);
        bench::table_row(&[
            format!("{cap:.0}"),
            format!("{}", b.events.len()),
            format!("{:.4}", b.iter_time),
            format!("{:.4}", b.hidden),
        ]);
    }
}
