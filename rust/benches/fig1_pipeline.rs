//! FIG1 bench: pipeline overlap quality per schedule — regenerates the
//! Fig. 1 comparison quantitatively (how much communication each schedule
//! hides), sweeps the merge-buffer ablation from DESIGN.md, and measures
//! the REAL trainer's barrier-vs-overlap wall clock at P ∈ {4, 8, 16}
//! over the native `mlp_deep` model (predicted vs. measured hidden time).
//!
//! Results land in `BENCH_fig1.json`: each `trainer_iter_*` row carries
//! `ns_per_iter` plus `overlap_efficiency` (measured hidden_comm /
//! total_comm on this machine) and `sim_overlap_efficiency` (the DES
//! prediction on the paper's 1GbE testbed), so the perf trajectory can
//! track both the speedup and how much of the reduction stayed hidden.
//!
//!     cargo bench --bench fig1_pipeline

use lags::collectives::{NetworkModel, PipelineMode};
use lags::config::TrainConfig;
use lags::models::zoo;
use lags::pipeline::desim::{simulate, Schedule, SimParams};
use lags::runtime::Runtime;
use lags::trainer::{Algorithm, Trainer};
use lags::util::bench;
use std::sync::Arc;

fn main() {
    let net = NetworkModel::gige_16();
    println!("# Fig 1: communication hidden under computation, per schedule");
    bench::table_header(&["model", "schedule", "iter_s", "t_comm_s", "hidden_s", "hidden_%"]);
    for m in zoo::table2_models() {
        let c = if m.name == "lstm_ptb" { 250.0 } else { 1000.0 };
        for (sched, label) in [
            (Schedule::DenseSingle, "dense-single"),
            (Schedule::DensePipelined, "dense-pipelined"),
            (Schedule::Slgs, "slgs"),
            (Schedule::Lags, "lags"),
        ] {
            let p = match sched {
                Schedule::DenseSingle | Schedule::DensePipelined => SimParams::dense(&m),
                _ => SimParams::uniform(&m, c),
            };
            let b = simulate(&m, &net, sched, &p);
            bench::table_row(&[
                m.name.clone(),
                label.to_string(),
                format!("{:.3}", b.iter_time),
                format!("{:.3}", b.t_comm),
                format!("{:.3}", b.hidden),
                format!("{:.1}", 100.0 * b.hidden / b.t_comm.max(1e-12)),
            ]);
        }
    }

    println!("\n# ablation: merge-buffer capacity (LAGS, resnet50, c=1000)");
    bench::table_header(&["merge_bytes", "messages", "iter_s", "hidden_s"]);
    let m = zoo::resnet50();
    for cap in [0.0, 4096.0, 16384.0, 32768.0, 131072.0, 1048576.0, 1e12] {
        let mut p = SimParams::uniform(&m, 1000.0);
        p.merge_bytes = cap;
        let b = simulate(&m, &net, Schedule::Lags, &p);
        bench::table_row(&[
            format!("{cap:.0}"),
            format!("{}", b.events.len()),
            format!("{:.4}", b.iter_time),
            format!("{:.4}", b.hidden),
        ]);
    }

    // --- real trainer: barrier vs overlap (native runtime, always runs).
    // One worker thread + the main-thread aggregator, so the streamed
    // reduction has a core to hide on even on small CI machines; c=4
    // keeps the per-layer messages heavy enough that the reduction is
    // worth hiding. The PR's perf trajectory reads these rows expecting
    // overlap strictly faster than its barrier twin with
    // overlap_efficiency > 0; nothing is asserted here — judge from
    // BENCH_fig1.json.
    println!("\n# real trainer: barrier vs overlap (mlp_deep, c=4, threads=1+aggregator)");
    let nrt = Arc::new(Runtime::native(42));
    for p in [4usize, 8, 16] {
        let mut barrier_median = f64::NAN;
        for (mode, label) in
            [(PipelineMode::Barrier, "barrier"), (PipelineMode::Overlap, "overlap")]
        {
            let mut cfg = TrainConfig::default_for("mlp_deep");
            cfg.algorithm = Algorithm::Lags;
            cfg.workers = p;
            cfg.threads = 1;
            cfg.pipeline = mode;
            cfg.steps = 1;
            cfg.compression = 4.0;
            cfg.eval_every = 0;
            let mut t = Trainer::with_runtime(&nrt, cfg).unwrap();
            let name = format!("trainer_iter_lags_P{p}_{label}");
            let s = bench::run(&name, || {
                t.step().unwrap();
            });
            let sim = t.simulated_iteration();
            bench::annotate(&name, "overlap_efficiency", t.overlap_stats().efficiency());
            bench::annotate(&name, "sim_overlap_efficiency", sim.overlap_efficiency());
            match mode {
                PipelineMode::Barrier => barrier_median = s.median,
                PipelineMode::Overlap => {
                    println!(
                        "  P={p}: overlap {:.2}% faster, measured overlap_efficiency {:.2} \
                         (DES predicts {:.2} on 1GbE)",
                        100.0 * (barrier_median / s.median - 1.0),
                        t.overlap_stats().efficiency(),
                        sim.overlap_efficiency()
                    );
                }
            }
        }
    }
    // --- real-trainer §5 merge ablation: the same trade-off the DES
    // sweep above predicts, now measured in the actual hot loop — bigger
    // groups mean fewer messages but defer reduction past the last
    // publish (overlap_efficiency sinks toward 0 as capacity grows)
    println!("\n# real trainer: merge-buffer ablation (mlp_deep, c=4, P=8)");
    bench::table_header(&["merge_bytes", "msgs/iter", "bytes/iter", "overlap_eff"]);
    for cap in [0usize, 4096, 32 * 1024, 1 << 20] {
        let mut cfg = TrainConfig::default_for("mlp_deep");
        cfg.algorithm = Algorithm::Lags;
        cfg.workers = 8;
        cfg.threads = 1;
        cfg.pipeline = PipelineMode::Overlap;
        cfg.steps = 1;
        cfg.compression = 4.0;
        cfg.eval_every = 0;
        cfg.merge_bytes = cap;
        let mut t = Trainer::with_runtime(&nrt, cfg).unwrap();
        let name = format!("trainer_iter_lags_P8_merge{cap}");
        bench::run(&name, || {
            t.step().unwrap();
        });
        bench::annotate(&name, "overlap_efficiency", t.overlap_stats().efficiency());
        bench::annotate(&name, "messages_per_iter", t.msg_stats().messages_per_iter());
        bench::table_row(&[
            format!("{cap}"),
            format!("{:.0}", t.msg_stats().messages_per_iter()),
            format!("{:.0}", t.msg_stats().bytes_per_iter()),
            format!("{:.3}", t.overlap_stats().efficiency()),
        ]);
    }

    // SLGS counterpoint: single-shot sparsification has nothing to hide
    // behind, so its measured overlap_efficiency stays ≈ 0 (Fig. 1b)
    for (alg, label) in [(Algorithm::Slgs, "slgs"), (Algorithm::Lags, "lags")] {
        let mut cfg = TrainConfig::default_for("mlp_deep");
        cfg.algorithm = alg;
        cfg.workers = 8;
        cfg.threads = 1;
        cfg.pipeline = PipelineMode::Overlap;
        cfg.steps = 1;
        cfg.compression = 4.0;
        cfg.eval_every = 0;
        let mut t = Trainer::with_runtime(&nrt, cfg).unwrap();
        let name = format!("trainer_iter_{label}_P8_overlap_vs_fig1b");
        bench::run(&name, || {
            t.step().unwrap();
        });
        bench::annotate(&name, "overlap_efficiency", t.overlap_stats().efficiency());
    }

    bench::write_json("BENCH_fig1.json").expect("write BENCH_fig1.json");
}
