//! Hot-path ablations (DESIGN.md §Design-choices + EXPERIMENTS.md §Perf):
//!
//!   * naive (pre-kernel branchy loop) vs blocked GEMM at the zoo's
//!     actual Dense/Conv shapes — the `gemm_{naive,blocked}` family,
//!     snapshotted to `BENCH_gemm.json` with measured GFLOP/s
//!   * exact O(n) select vs double-sampling threshold (§5 heuristic 2)
//!   * host compress vs XLA/Pallas compress artifact (ablation_compress_path)
//!   * sparse codec encode/decode/merge throughput
//!   * ring allreduce throughput
//!   * sequential-vs-parallel trainer iteration over the native runtime
//!     at P ∈ {4, 8, 16} (the `--threads` worker fan-out speedup)
//!   * full LAGS trainer iteration over artifacts (when present)
//!
//! Results are also written to `BENCH_hotpath.json` (name, ns/iter,
//! throughput) so the perf trajectory is trackable across PRs.
//!
//!     cargo bench --bench ablation_hotpath

use lags::collectives::dense::ring_allreduce_mean;
use lags::config::TrainConfig;
use lags::runtime::simd::{self, Isa};
use lags::runtime::{kernels, native::NativeNet, Runtime};
use lags::sparsify::{sparse::SparseVec, threshold, topk, ErrorFeedback};
use lags::trainer::{Algorithm, Trainer};
use lags::util::bench::{self, bb};
use lags::util::rng::Rng;
use std::sync::Arc;

fn randvec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal_f32()).collect()
}

/// The pre-branchless `mask_with_threshold` (scalar branch per element) —
/// kept here as the before/after baseline for the `kernels` case.
fn mask_with_threshold_branchy(x: &[f32], thr: f32, out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(x.iter()) {
        *o = if v.abs() >= thr { v } else { 0.0 };
    }
}

/// The pre-branchless `split_with_threshold`.
fn split_with_threshold_branchy(x: &[f32], thr: f32, kept: &mut [f32], resid: &mut [f32]) {
    for i in 0..x.len() {
        let v = x[i];
        if v.abs() >= thr {
            kept[i] = v;
            resid[i] = 0.0;
        } else {
            kept[i] = 0.0;
            resid[i] = v;
        }
    }
}

/// The pre-kernel mat-mul hot loop, verbatim: row-major axpy walk with a
/// scalar zero-skip branch per reduction element — the honest "before"
/// baseline for the `gemm_{naive,blocked}` family. Same per-element
/// accumulation order as the blocked kernel's contract.
fn gemm_naive_branchy(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                let brow = &b[kk * n..(kk + 1) * n];
                for (o, &bv) in crow.iter_mut().zip(brow.iter()) {
                    *o += av * bv;
                }
            }
        }
    }
}

fn main() {
    // optional positional family filter: `cargo bench --bench
    // ablation_hotpath -- gemm` runs ONLY the GEMM/SIMD family and its
    // BENCH_gemm.json snapshot (the CI perf-trend step's fast path)
    let gemm_only = matches!(std::env::args().nth(1).as_deref(), Some("gemm"));

    // --- naive vs blocked GEMM at the zoo's actual hot-loop shapes.
    // Runs FIRST so the BENCH_gemm.json snapshot below contains exactly
    // this family; the acceptance bar is >= 3x blocked-vs-naive on the
    // largest Dense and Conv shapes. Each row is annotated with its
    // measured GFLOP/s (2·m·k·n per iteration). Baseline rows are pinned
    // to the SCALAR kernel set so their meaning is stable across CI
    // hardware; the dispatched SIMD tiers get their own per-ISA rows.
    println!("# gemm kernels: naive (branchy axpy) vs blocked/register-tiled (scalar)");
    simd::set_active(Isa::Scalar).expect("scalar is always available");
    let man = lags::runtime::native::native_manifest(42);
    let mut gemm_shapes: Vec<(String, usize, usize, usize)> = Vec::new();
    for name in ["mlp_deep", "convnet", "convnet_deep", "rnn"] {
        let net = NativeNet::from_manifest(&man.models[name]).unwrap();
        for s in net.gemm_shapes() {
            let tagged = format!("{name}/{}", s.label);
            if !gemm_shapes.iter().any(|(_, sm, sk, sn)| (*sm, *sk, *sn) == (s.m, s.k, s.n)) {
                gemm_shapes.push((tagged, s.m, s.k, s.n));
            }
        }
    }
    for (label, m, k, n) in &gemm_shapes {
        let (m, k, n) = (*m, *k, *n);
        let mut rng = Rng::new(7);
        let a = randvec(m * k, 11);
        let b = randvec(k * n, 12);
        let mut c = vec![0.0f32; m * n];
        rng.fill_normal(&mut c, 1.0);
        let gflops_per_iter = 2.0 * m as f64 * k as f64 * n as f64;
        let s = bench::run_items(&format!("gemm_naive_{label}"), m * k * n, || {
            gemm_naive_branchy(bb(&mut c), bb(&a), bb(&b), m, k, n);
        });
        bench::annotate(&format!("gemm_naive_{label}"), "gflops", gflops_per_iter / s.median / 1e9);
        let mut c = vec![0.0f32; m * n];
        rng.fill_normal(&mut c, 1.0);
        let s2 = bench::run_items(&format!("gemm_blocked_{label}"), m * k * n, || {
            kernels::gemm_nn(bb(&mut c), bb(&a), bb(&b), m, k, n);
        });
        bench::annotate(
            &format!("gemm_blocked_{label}"),
            "gflops",
            gflops_per_iter / s2.median / 1e9,
        );
        println!("  speedup {label} ({m}x{k}x{n}): {:.2}x", s.median / s2.median);
    }

    // --- the SIMD tier: re-run the blocked kernel under every available
    // dispatched ISA (rows `gemm_blocked_{label}_{isa}`), so the snapshot
    // carries the scalar-vs-SIMD trajectory; the acceptance bar is
    // >= 1.5x over blocked-scalar on the largest shapes wherever a vector
    // ISA is available. Results are bit-identical by the simd contract —
    // only the wall clock may move.
    println!("\n# gemm kernels: dispatched SIMD tiers vs blocked-scalar");
    for isa in Isa::available() {
        if isa == Isa::Scalar {
            continue; // already measured as the gemm_blocked_{label} rows
        }
        simd::set_active(isa).expect("listed as available");
        for (label, m, k, n) in &gemm_shapes {
            let (m, k, n) = (*m, *k, *n);
            let a = randvec(m * k, 11);
            let b = randvec(k * n, 12);
            let mut c = vec![0.0f32; m * n];
            Rng::new(7).fill_normal(&mut c, 1.0);
            let gflops_per_iter = 2.0 * m as f64 * k as f64 * n as f64;
            let name = format!("gemm_blocked_{label}_{}", isa.name());
            let s = bench::run_items(&name, m * k * n, || {
                kernels::gemm_nn(bb(&mut c), bb(&a), bb(&b), m, k, n);
            });
            bench::annotate(&name, "gflops", gflops_per_iter / s.median / 1e9);
            println!("  {} {label} ({m}x{k}x{n}): {:.2} GFLOP/s", isa.name(), gflops_per_iter / s.median / 1e9);
        }
    }

    // --- select + sparse reduction per ISA (rows `kernels_mask_{isa}_*`,
    // `kernels_split_{isa}_*`, `sparse_agg_add_{isa}`): the other two
    // kernel families of the SIMD tier, in the same snapshot.
    println!("\n# select + sparse reduction per dispatched ISA");
    {
        let n = 1 << 20;
        let x = randvec(n, 7);
        let thr = topk::kth_largest_abs(&x, n / 100);
        let sv = {
            let mut v = vec![0.0f32; n];
            let mut rng = Rng::new(3);
            for i in rng.sample_distinct(n, n / 100) {
                v[i] = rng.normal_f32();
            }
            SparseVec::from_dense(&v)
        };
        for isa in Isa::available() {
            simd::set_active(isa).expect("listed as available");
            let mut out = vec![0.0f32; n];
            bench::run_items(&format!("kernels_mask_{}_n{n}", isa.name()), n, || {
                topk::mask_with_threshold(bb(&x), thr, &mut out);
            });
            let mut kept = vec![0.0f32; n];
            let mut resid = vec![0.0f32; n];
            bench::run_items(&format!("kernels_split_{}_n{n}", isa.name()), n, || {
                topk::split_with_threshold(bb(&x), thr, &mut kept, &mut resid);
            });
            let mut dense = vec![0.0f32; n];
            bench::run_items(&format!("sparse_agg_add_{}", isa.name()), sv.nnz(), || {
                sv.add_into(bb(&mut dense));
            });
        }
    }
    simd::set_active(Isa::detect()).expect("detected ISA is available");

    bench::write_json("BENCH_gemm.json").expect("write BENCH_gemm.json");
    if gemm_only {
        return;
    }

    println!("\n# threshold selection: exact O(n) vs double-sampling (stride 64)");
    for n in [65_536usize, 1 << 20, 1 << 22] {
        let x = randvec(n, 1);
        let k = n / 1000;
        bench::run_val(&format!("topk_exact_n{n}"), || topk::kth_largest_abs(&x, k));
        let mut st = threshold::SampledThreshold::new(64);
        bench::run_val(&format!("topk_sampled_n{n}"), || st.estimate(&x, k));
    }

    println!("\n# error-feedback compress (accumulate + select + split)");
    for n in [131_072usize, 1 << 20] {
        let g = randvec(n, 2);
        let mut ef = ErrorFeedback::new(n, 64);
        let mut kept = vec![0.0f32; n];
        bench::run(&format!("ef_compress_exact_n{n}"), || {
            bb(ef.compress_layer(0, &g, 0.05, n / 1000, true, &mut kept));
        });
        let mut ef2 = ErrorFeedback::new(n, 64);
        bench::run(&format!("ef_compress_sampled_n{n}"), || {
            bb(ef2.compress_layer(0, &g, 0.05, n / 1000, false, &mut kept));
        });
    }

    println!("\n# kernels: branchy vs branchless threshold mask/split");
    for n in [131_072usize, 1 << 20] {
        let x = randvec(n, 7);
        let thr = topk::kth_largest_abs(&x, n / 100);
        let mut out = vec![0.0f32; n];
        bench::run_items(&format!("kernels_mask_branchy_n{n}"), n, || {
            mask_with_threshold_branchy(bb(&x), thr, &mut out);
        });
        bench::run_items(&format!("kernels_mask_branchless_n{n}"), n, || {
            topk::mask_with_threshold(bb(&x), thr, &mut out);
        });
        let mut kept = vec![0.0f32; n];
        let mut resid = vec![0.0f32; n];
        bench::run_items(&format!("kernels_split_branchy_n{n}"), n, || {
            split_with_threshold_branchy(bb(&x), thr, &mut kept, &mut resid);
        });
        bench::run_items(&format!("kernels_split_branchless_n{n}"), n, || {
            topk::split_with_threshold(bb(&x), thr, &mut kept, &mut resid);
        });
    }

    println!("\n# sparse codec");
    let n = 1 << 20;
    let x = {
        let mut v = vec![0.0f32; n];
        let mut rng = Rng::new(3);
        for i in rng.sample_distinct(n, n / 100) {
            v[i] = rng.normal_f32();
        }
        v
    };
    let sv = SparseVec::from_dense(&x);
    let thr = topk::kth_largest_abs(&x, n / 100);
    bench::run_items("sparse_encode_1M_1pct", n, || {
        bb(SparseVec::from_dense_threshold(&x, thr));
    });
    let mut out = vec![0.0f32; n];
    bench::run_items(&format!("sparse_decode_add_nnz{}", sv.nnz()), sv.nnz(), || {
        sv.add_into(bb(&mut out))
    });
    let sv2 = SparseVec::from_dense_threshold(&randvec(n, 4), thr);
    bench::run_val("sparse_merge", || sv.merge(&sv2));

    println!("\n# ring allreduce (P=8)");
    for n in [65_536usize, 1 << 20] {
        let base: Vec<Vec<f32>> = (0..8).map(|p| randvec(n, 100 + p as u64)).collect();
        let mut bufs = base.clone();
        bench::run_items(&format!("ring_allreduce_P8_n{n}"), 8 * n, || {
            bufs.clone_from(&base);
            ring_allreduce_mean(bb(&mut bufs));
        });
    }

    // --- sequential vs parallel worker hot loop (native runtime, always
    // runs). The acceptance bar: >= 2x on trainer_iter_lags at P=8 with
    // threads >= 4 on a multi-core machine.
    println!("\n# parallel worker hot loop (native runtime, mlp_deep, c=100)");
    let nrt = Arc::new(Runtime::native(42));
    for p in [4usize, 8, 16] {
        let mut seq_median = f64::NAN;
        for threads in [1usize, 4, 8] {
            let mut cfg = TrainConfig::default_for("mlp_deep");
            cfg.algorithm = Algorithm::Lags;
            cfg.workers = p;
            cfg.threads = threads;
            // barrier isolates the worker fan-out speedup; the
            // barrier-vs-overlap comparison lives in fig1_pipeline
            cfg.pipeline = lags::collectives::PipelineMode::Barrier;
            cfg.steps = 1;
            cfg.compression = 100.0;
            cfg.eval_every = 0;
            let mut t = Trainer::with_runtime(&nrt, cfg).unwrap();
            let s = bench::run(&format!("trainer_iter_lags_P{p}_threads{threads}"), || {
                t.step().unwrap();
            });
            if threads == 1 {
                seq_median = s.median;
            } else {
                println!(
                    "  speedup trainer_iter_lags P={p} threads={threads}: {:.2}x",
                    seq_median / s.median
                );
            }
        }
    }
    // algorithm comparison at P=8, sequential vs 8 threads
    for alg in [Algorithm::Dense, Algorithm::Slgs, Algorithm::Lags] {
        for threads in [1usize, 8] {
            let mut cfg = TrainConfig::default_for("mlp_deep");
            cfg.algorithm = alg;
            cfg.workers = 8;
            cfg.threads = threads;
            cfg.pipeline = lags::collectives::PipelineMode::Barrier;
            cfg.steps = 1;
            cfg.compression = 100.0;
            cfg.eval_every = 0;
            let mut t = Trainer::with_runtime(&nrt, cfg).unwrap();
            bench::run(&format!("trainer_iter_{}_P8_threads{threads}", alg.name()), || {
                t.step().unwrap();
            });
        }
    }

    // end-to-end trainer iterations over artifacts (PJRT builds only)
    let artifacts_rt = if std::path::Path::new("artifacts/manifest.json").exists() {
        Runtime::load("artifacts").map(Arc::new).map_err(|e| e.to_string())
    } else {
        Err("run `make artifacts` first".to_string())
    };
    match artifacts_rt {
        Ok(rt) => {
            println!("\n# full trainer iteration (mlp, P=4, c=100) — host vs xla compress");
            for (label, comp) in [
                ("host", lags::sparsify::CompressorKind::HostExact),
                ("host-sampled", lags::sparsify::CompressorKind::HostSampled),
                ("xla", lags::sparsify::CompressorKind::XlaExact),
                ("xla-sampled", lags::sparsify::CompressorKind::XlaSampled),
            ] {
                let mut cfg = TrainConfig::default_for("mlp");
                cfg.algorithm = Algorithm::Lags;
                cfg.workers = 4;
                cfg.steps = 1;
                cfg.compression = 100.0;
                cfg.compressor = comp;
                cfg.eval_every = 0;
                let mut t = Trainer::with_runtime(&rt, cfg).unwrap();
                bench::run(&format!("trainer_iter_lags_{label}"), || {
                    t.step().unwrap();
                });
            }
            // algorithm comparison at the same settings
            for alg in [Algorithm::Dense, Algorithm::Slgs, Algorithm::Lags] {
                let mut cfg = TrainConfig::default_for("mlp");
                cfg.algorithm = alg;
                cfg.workers = 4;
                cfg.steps = 1;
                cfg.compression = 100.0;
                cfg.eval_every = 0;
                let mut t = Trainer::with_runtime(&rt, cfg).unwrap();
                bench::run(&format!("trainer_iter_{}", alg.name()), || {
                    t.step().unwrap();
                });
            }
        }
        Err(e) => println!("\n(skipping artifact trainer benches: {e})"),
    }

    bench::write_json("BENCH_hotpath.json").expect("write BENCH_hotpath.json");
}
