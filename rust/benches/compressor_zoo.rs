//! COMPRESSOR ZOO bench: split() throughput per zoo member.
//!
//! One synthetic 1 M-element layer (seeded N(0,1) accumulator), c = 100
//! (k = 10 000), measured per zoo member through the exact trait path the
//! trainer's hot loop drives: `begin_step` once per iteration, then
//! `split` into reused scratch. Rows report median split time, elements/s
//! and the realized kept count + bytes-on-wire under the member's
//! [`WireFormat`] — the table that shows what qsgd-topk's narrower
//! encoding costs in CPU and buys in bytes.
//!
//!     cargo bench --bench compressor_zoo

use lags::sparsify::{Compressor, CompressorKind, LayerCtx, SparseVec};
use lags::util::bench;
use lags::util::rng::Rng;

const N: usize = 1 << 20;
const K: usize = N / 100;

fn main() {
    let kinds = [
        CompressorKind::HostExact,
        CompressorKind::HostSampled,
        CompressorKind::AdaptiveStoch,
        CompressorKind::GlobalTopk,
        CompressorKind::QsgdTopk,
        CompressorKind::BottomK,
    ];

    let mut rng = Rng::new(7);
    let acc: Vec<f32> = (0..N).map(|_| rng.normal_f32()).collect();
    let zeros = vec![0.0f32; N];

    println!("# compressor zoo: split() on one {N}-element layer, k={K}");
    bench::table_header(&["compressor", "split_ms", "Melem_s", "kept", "wire_bytes"]);
    for kind in kinds {
        let name = format!("zoo_{}", kind.name());
        let mut comp = kind.build(8);
        let mut msg = SparseVec::new(N);
        let mut resid = vec![0.0f32; N];
        let mut step = 0u64;
        let mut kept = 0usize;
        let stats = bench::run_items(&name, N, || {
            comp.begin_step(&zeros, &acc, 1.0, K);
            let ctx = LayerCtx { seed: 42, uid: 0, step, layer: 0 };
            kept = comp.split(&ctx, &acc, K, &mut msg, &mut resid).kept;
            step += 1;
        });
        let wf = kind.wire();
        let wire_bytes = wf.message_bytes(kept);
        bench::annotate(&name, "kept", kept as f64);
        bench::annotate(&name, "wire_bytes", wire_bytes as f64);
        bench::table_row(&[
            kind.name().to_string(),
            format!("{:.3}", stats.median * 1e3),
            format!("{:.1}", N as f64 / stats.median / 1e6),
            format!("{kept}"),
            format!("{wire_bytes}"),
        ]);
    }

    bench::write_json("BENCH_compressor_zoo.json").expect("write BENCH_compressor_zoo.json");
}
