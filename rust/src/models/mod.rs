//! Model descriptions for the timing experiments.
//!
//! Two sources of layer tables:
//!
//! * live models from `artifacts/manifest.json` (used by the numeric
//!   trainers) — converted via [`ModelProfile::from_manifest`];
//! * the published layer profiles of the paper's evaluation models
//!   (ResNet-50, Inception-v4, VGG-16, LSTM-PTB) in [`zoo`] — used by the
//!   discrete-event simulator to regenerate Table 2 / Fig 1, since those
//!   networks are too large to train numerically on this testbed.

pub mod zoo;

use crate::runtime::ModelManifest;

/// UNCALIBRATED-FALLBACK device speed (flops/s) for the native backend,
/// used to turn a live manifest's flop counts into the startup timing
/// profile when no measured calibration exists — shared by the trainer's
/// `--adaptive` selection, its DES pricing, and `lags ratios`, so all
/// three agree on the same inputs until measured timings take over.
/// Device speed is a property of the BACKEND
/// ([`crate::runtime::Runtime::device_flops`] dispatches), and since the
/// blocked-GEMM kernel core landed it is a MEASURED property: `lags
/// calibrate` (or `train --calibrate`) benchmarks the kernels at the
/// zoo's actual shapes and persists the sustained figure
/// (`crate::runtime::calibrate`), which then replaces this constant
/// everywhere `device_flops()` is consulted. The constant remains only
/// as the documented fallback for uncalibrated runs (and as the fixture
/// the deterministic adaptive-selection tests pin their regimes to).
///
/// The order of magnitude is an honest ballpark for scalar-ish f32 rust
/// (~1e9), not the 1e12 of an accelerator: at an accelerator-class
/// figure every layer's backward would price in microseconds, the Eq. 18
/// budget check would degenerate (latency alone exceeds every budget),
/// and the "adaptive" selection would be uniformly capped. Around 1e9
/// the conv/rnn zoo layers' real comm-to-compute asymmetry is visible to
/// the selection, which is the paper's whole point; the MLP family's
/// layers are still too small to hide anything, so its selection is
/// uniform either way.
pub const DEVICE_FLOPS: f64 = 1e9;

/// Fallback device speed (flops/s) used to price manifests served by the
/// PJRT backend. A host GEMM calibration says nothing about an
/// accelerator, so PJRT runs always use this accelerator-class constant
/// — the figure the repo used for every backend before device speed
/// became backend-dispatched (and, later, measurable).
pub const PJRT_DEVICE_FLOPS: f64 = 1e12;

/// A layer as the timing model sees it: parameter count + backprop compute
/// time share. Order follows the BACKPROP schedule: index 0 is the OUTPUT
/// layer (gradient ready first), last index is the input layer (Fig. 1).
#[derive(Debug, Clone)]
pub struct LayerProfile {
    pub name: String,
    /// number of learnable elements d^(l)
    pub params: usize,
    /// backward computation time for this layer (s)
    pub t_b: f64,
}

/// Whole-model timing profile.
#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub name: String,
    /// forward pass time (s)
    pub t_f: f64,
    /// layers in backprop order (output-first)
    pub layers: Vec<LayerProfile>,
}

impl ModelProfile {
    pub fn d(&self) -> usize {
        self.layers.iter().map(|l| l.params).sum()
    }

    /// total backward time t_b
    pub fn t_b(&self) -> f64 {
        self.layers.iter().map(|l| l.t_b).sum()
    }

    /// total compute time per iteration
    pub fn t_comp(&self) -> f64 {
        self.t_f + self.t_b()
    }

    /// Build a profile from a live manifest + device speed (flops/s).
    /// Backward flops ~ 2x forward; layer order reversed (backprop starts
    /// at the last layer of the table).
    pub fn from_manifest(mm: &ModelManifest, device_flops: f64) -> ModelProfile {
        let t_f = mm.total_fwd_flops() / device_flops;
        let layers = mm
            .layers
            .iter()
            .rev()
            .map(|l| LayerProfile {
                name: l.name.clone(),
                params: l.size,
                t_b: 2.0 * l.fwd_flops / device_flops,
            })
            .collect();
        ModelProfile { name: mm.name.clone(), t_f, layers }
    }

    /// Scale all compute times (calibration knob).
    pub fn scale_compute(mut self, s: f64) -> Self {
        self.t_f *= s;
        for l in &mut self.layers {
            l.t_b *= s;
        }
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_sums() {
        let p = ModelProfile {
            name: "t".into(),
            t_f: 0.1,
            layers: vec![
                LayerProfile { name: "a".into(), params: 10, t_b: 0.2 },
                LayerProfile { name: "b".into(), params: 20, t_b: 0.3 },
            ],
        };
        assert_eq!(p.d(), 30);
        assert!((p.t_b() - 0.5).abs() < 1e-12);
        assert!((p.t_comp() - 0.6).abs() < 1e-12);
        let p2 = p.scale_compute(2.0);
        assert!((p2.t_comp() - 1.2).abs() < 1e-12);
    }
}
