//! Published layer profiles of the paper's evaluation models, for the
//! discrete-event timing simulator (Table 2 / Fig 1).
//!
//! Parameter counts follow the published architectures; per-layer backward
//! times are distributed proportionally to layer FLOPs and the total
//! compute time is CALIBRATED to the paper's testbed (Nvidia P102-100,
//! batch 32/worker) by inverting Table 2: `t_comp ≈ t_SLGS - t_c^sparse`,
//! since SLGS-SGD does not overlap anything. Calibration targets are
//! recorded in EXPERIMENTS.md §Table2.
//!
//! Layer order is BACKPROP order (output layer first), matching Fig. 1.

use super::{LayerProfile, ModelProfile};

/// Distribute a calibrated (t_f, t_b) over layers proportional to flops.
fn build(name: &str, t_f: f64, t_b: f64, layers: Vec<(String, usize, f64)>) -> ModelProfile {
    let total_flops: f64 = layers.iter().map(|(_, _, f)| *f).sum();
    let layers = layers
        .into_iter()
        .map(|(lname, params, flops)| LayerProfile {
            name: lname,
            params,
            t_b: t_b * flops / total_flops,
        })
        .collect();
    ModelProfile { name: name.to_string(), t_f, layers }
}

/// ResNet-50 (He et al. 2016): 53 convs + fc, ~25.5M params.
/// Bottleneck stages [3, 4, 6, 3] at 224x224. Conv-dominated: both params
/// and flops concentrate in convs, so LAGS overlap is near-ideal (paper
/// achieves 59.6% of S_max).
pub fn resnet50() -> ModelProfile {
    let mut layers: Vec<(String, usize, f64)> = Vec::new();
    let mut push = |n: String, cin: usize, cout: usize, k: usize, hw: usize| {
        let params = k * k * cin * cout;
        let flops = (params * hw * hw) as f64 * 2.0 * 32.0; // batch 32
        layers.push((n, params, flops));
    };
    // stem
    push("conv1".into(), 3, 64, 7, 112);
    // bottleneck stages: (blocks, cin_first, mid, out, hw)
    let stages = [(3usize, 64usize, 64usize, 256usize, 56usize),
                  (4, 256, 128, 512, 28),
                  (6, 512, 256, 1024, 14),
                  (3, 1024, 512, 2048, 7)];
    for (si, &(blocks, cin_first, mid, out, hw)) in stages.iter().enumerate() {
        for b in 0..blocks {
            let cin = if b == 0 { cin_first } else { out };
            push(format!("s{si}b{b}.c1"), cin, mid, 1, hw);
            push(format!("s{si}b{b}.c2"), mid, mid, 3, hw);
            push(format!("s{si}b{b}.c3"), mid, out, 1, hw);
            if b == 0 {
                push(format!("s{si}b{b}.proj"), cin, out, 1, hw);
            }
        }
    }
    // classifier
    layers.push(("fc".into(), 2048 * 1000 + 1000, 2.0 * 32.0 * 2048.0 * 1000.0));
    layers.reverse(); // backprop order: fc first
    // calibration: t_comp = t_SLGS(0.67) - t_spar(25.5M -> 0.102) -
    // t_comm^sparse(k=25.5k -> 0.035) = 0.533s; fwd:bwd ~= 1:2
    build("resnet50", 0.18, 0.353, layers)
}

/// Inception-v4 (Szegedy et al. 2017): ~42.7M params over ~150 convs.
/// Modeled as its stem + 4xA + 7xB + 3xC cells with representative widths.
pub fn inception_v4() -> ModelProfile {
    let mut layers: Vec<(String, usize, f64)> = Vec::new();
    let mut push = |n: String, params: usize, hw: usize| {
        layers.push((n, params, (params * hw * hw) as f64 * 2.0 * 32.0));
    };
    // stem (~1M params)
    for (i, p) in [9 * 3 * 32, 9 * 32 * 32, 9 * 32 * 64, 9 * 64 * 96, 64 * 96 + 9 * 96 * 96]
        .iter()
        .enumerate()
    {
        push(format!("stem{i}"), *p, 73);
    }
    // 4 x Inception-A (384 ch, 35x35): ~0.4M each over 4 branches
    for a in 0..4 {
        for (bi, p) in [384 * 96, 384 * 64 + 9 * 64 * 96, 384 * 64 + 2 * 9 * 96 * 96, 384 * 96]
            .iter()
            .enumerate()
        {
            push(format!("incA{a}.br{bi}"), *p, 35);
        }
    }
    // 7 x Inception-B (1024 ch, 17x17): ~2M each
    for b in 0..7 {
        for (bi, p) in [
            1024 * 384,
            1024 * 192 + 7 * 192 * 224 + 7 * 224 * 256,
            1024 * 192 + 2 * 7 * 192 * 224 + 2 * 7 * 224 * 256,
            1024 * 128,
        ]
        .iter()
        .enumerate()
        {
            push(format!("incB{b}.br{bi}"), *p, 17);
        }
    }
    // 3 x Inception-C (1536 ch, 8x8): ~3.5M each
    for c in 0..3 {
        for (bi, p) in [
            1536 * 256,
            1536 * 384 + 2 * 3 * 384 * 256,
            1536 * 384 + 3 * 384 * 448 + 3 * 448 * 512 + 2 * 3 * 512 * 256,
            1536 * 256,
        ]
        .iter()
        .enumerate()
        {
            push(format!("incC{c}.br{bi}"), *p, 8);
        }
    }
    layers.push(("fc".into(), 1536 * 1000 + 1000, 2.0 * 32.0 * 1536.0 * 1000.0));
    layers.reverse();
    // calibration: t_comp = t_SLGS(1.60) - t_spar(42.7M -> 0.171) -
    // t_comm^sparse(k=42.7k -> 0.054) = 1.375s
    build("inception_v4", 0.46, 0.915, layers)
}

/// VGG-16 (Simonyan & Zisserman 2014): 13 convs + 3 fc, ~138M params.
/// fc-dominated parameters (fc6 alone is 103M) with conv-dominated compute
/// — the classic pathological case for dense allreduce.
pub fn vgg16() -> ModelProfile {
    let cfg: &[(usize, usize, usize)] = &[
        (3, 64, 224), (64, 64, 224),
        (64, 128, 112), (128, 128, 112),
        (128, 256, 56), (256, 256, 56), (256, 256, 56),
        (256, 512, 28), (512, 512, 28), (512, 512, 28),
        (512, 512, 14), (512, 512, 14), (512, 512, 14),
    ];
    let mut layers: Vec<(String, usize, f64)> = cfg
        .iter()
        .enumerate()
        .map(|(i, &(cin, cout, hw))| {
            let params = 9 * cin * cout;
            (format!("conv{i}"), params, (params * hw * hw) as f64 * 2.0 * 32.0)
        })
        .collect();
    layers.push(("fc6".into(), 25088 * 4096, 2.0 * 32.0 * 25088.0 * 4096.0));
    layers.push(("fc7".into(), 4096 * 4096, 2.0 * 32.0 * 4096.0 * 4096.0));
    layers.push(("fc8".into(), 4096 * 1000, 2.0 * 32.0 * 4096.0 * 1000.0));
    layers.reverse();
    build("vgg16", 0.18, 0.37, layers)
}

/// LSTM-PTB: 2-layer LSTM, 1500 hidden, vocab 10k (Lin et al. 2018 setup),
/// ~66M params in only 6 fat tensors — embedding-dominated, the case where
/// LAGS overlap is hardest (paper reaches only 39.3% of S_max).
pub fn lstm_ptb() -> ModelProfile {
    let h = 1500usize;
    let v = 10000usize;
    let seq = 35.0 * 20.0; // seq len x batch tokens per step
    let layers: Vec<(String, usize, f64)> = vec![
        // backprop order: softmax/fc first, embedding last
        ("fc".into(), h * v + v, 2.0 * seq * (h * v) as f64),
        ("lstm2".into(), 4 * (2 * h * h + h), 2.0 * seq * (4 * 2 * h * h) as f64),
        ("lstm1".into(), 4 * (2 * h * h + h), 2.0 * seq * (4 * 2 * h * h) as f64),
        ("embed".into(), v * h, seq * h as f64),
    ];
    // calibration: t_comp = t_SLGS(1.02) - t_spar(66M -> 0.264) -
    // t_comm^sparse(k=264k at c=250 -> 0.293) = 0.463s
    build("lstm_ptb", 0.155, 0.308, layers)
}

/// All Table-2 profiles.
pub fn table2_models() -> Vec<ModelProfile> {
    vec![resnet50(), inception_v4(), lstm_ptb()]
}

pub fn by_name(name: &str) -> Option<ModelProfile> {
    match name {
        "resnet50" => Some(resnet50()),
        "inception_v4" => Some(inception_v4()),
        "vgg16" => Some(vgg16()),
        "lstm_ptb" => Some(lstm_ptb()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet50_param_count() {
        let p = resnet50();
        let d = p.d();
        assert!((23_000_000..28_000_000).contains(&d), "resnet50 d={d}");
        assert!(p.layers.len() > 50);
        assert_eq!(p.layers[0].name, "fc"); // backprop order
    }

    #[test]
    fn inception_param_count() {
        let d = inception_v4().d();
        assert!((35_000_000..50_000_000).contains(&d), "inception d={d}");
    }

    #[test]
    fn vgg16_param_count() {
        let d = vgg16().d();
        assert!((130_000_000..145_000_000).contains(&d), "vgg16 d={d}");
    }

    #[test]
    fn lstm_param_count() {
        let d = lstm_ptb().d();
        assert!((60_000_000..70_000_000).contains(&d), "lstm d={d}");
    }

    #[test]
    fn calibrated_compute_times() {
        // must match the t_SLGS - t_c^sparse inversions (EXPERIMENTS.md)
        assert!((resnet50().t_comp() - 0.533).abs() < 0.02);
        assert!((inception_v4().t_comp() - 1.375).abs() < 0.02);
        assert!((lstm_ptb().t_comp() - 0.463).abs() < 0.02);
    }

    #[test]
    fn layer_times_positive_and_sum() {
        for m in table2_models() {
            assert!(m.layers.iter().all(|l| l.t_b > 0.0));
            let sum: f64 = m.layers.iter().map(|l| l.t_b).sum();
            assert!((sum - m.t_b()).abs() < 1e-9);
        }
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("resnet50").is_some());
        assert!(by_name("vgg16").is_some());
        assert!(by_name("nope").is_none());
    }
}
