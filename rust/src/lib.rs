//! # LAGS-SGD — Layer-wise Adaptive Gradient Sparsification
//!
//! Reproduction of *"Layer-wise Adaptive Gradient Sparsification for
//! Distributed Deep Learning with Convergence Guarantees"* (Shi et al.,
//! AAAI 2020) as a three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the distributed-training coordinator:
//!   worker pool, collectives with an α–β network model, the wait-free
//!   layer-wise pipeline scheduler, error-feedback state, adaptive
//!   compression-ratio selection (Eq. 18), a discrete-event cluster
//!   simulator for wall-clock reproduction (Table 2 / Fig 1), and the
//!   three trainers the paper compares: Dense-SGD, SLGS-SGD, LAGS-SGD.
//! * **Layer 2** — JAX models AOT-lowered to HLO text (`python/compile/`),
//!   executed here through the PJRT CPU client ([`runtime`]).
//! * **Layer 1** — Pallas kernels (compress / apply) lowered into the same
//!   artifacts; [`sparsify`] contains the bit-equivalent host fallbacks.
//!
//! Python never runs on the training path: `make artifacts` is the only
//! compile step, after which the `lags` binary is self-contained. A
//! pure-rust [`runtime::native`] backend (artifacts dir `"native"`) serves
//! a built-in model zoo when no artifacts/PJRT are available, and the
//! per-worker hot loop fans out over OS threads (`--threads`, DESIGN.md)
//! with bit-identical results.
//!
//! ## Quick start
//!
//! ```no_run
//! use lags::config::TrainConfig;
//! use lags::trainer::{Algorithm, Trainer};
//!
//! let mut cfg = TrainConfig::default_for("mlp");
//! cfg.steps = 100;
//! cfg.workers = 4;
//! cfg.threads = 4; // parallel hot loop, bit-identical to threads = 1
//! cfg.algorithm = Algorithm::Lags;
//! let mut t = Trainer::from_artifacts("native", cfg).unwrap();
//! let report = t.run().unwrap();
//! println!("final loss {:.4}", report.final_loss);
//! ```
//!
//! The crate ships its own determinism auditor ([`analysis`], `lags
//! audit`): rules R1–R5 (DESIGN.md §Determinism contract and enforcement)
//! are statically enforced over this source tree, `unsafe` is denied
//! crate-wide and allowed only inside [`runtime::simd`] (the explicit
//! SIMD kernel tier, where every intrinsic call carries an audited R4
//! waiver), and every wall-clock read funnels through
//! [`util::clock::now`].

#![deny(unsafe_code)]

pub mod adaptive;
pub mod analysis;
pub mod cluster;
pub mod collectives;
pub mod config;
pub mod data;
pub mod metrics;
pub mod models;
pub mod pipeline;
pub mod runtime;
pub mod sparsify;
pub mod trainer;
pub mod util;

pub use anyhow::{bail, Context, Result};
