//! Configuration system: typed training/experiment configs with defaults,
//! JSON config-file loading, CLI overrides, and validation.
//!
//! Precedence (lowest to highest): built-in defaults → `--config file.json`
//! → individual `--key value` CLI flags.

use crate::collectives::PipelineMode;
use crate::sparsify::CompressorKind;
use crate::trainer::Algorithm;
use crate::util::cli::Args;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Full configuration of a numeric training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub model: String,
    pub algorithm: Algorithm,
    /// logical data-parallel workers P
    pub workers: usize,
    /// OS threads for the per-worker hot loop (gradient compute + error
    /// feedback compression). 1 = sequential baseline; 0 = one per core.
    /// Results are bit-identical for every value — the reduction stays
    /// rank-ordered outside the parallel region (DESIGN.md §Threading).
    pub threads: usize,
    pub steps: usize,
    pub lr: f64,
    /// momentum on the aggregated update (0 = plain Algorithm 1)
    pub momentum: f64,
    /// momentum CORRECTION (Lin et al. 2018): per-worker local momentum
    /// accumulated BEFORE sparsification — the training trick the paper
    /// cites for closing the sparsification accuracy gap (§Comparison of
    /// Convergence Rates). 0 = off.
    pub local_momentum: f64,
    /// warm-up schedule (Lin et al. 2018): ramp the compression ratio
    /// exponentially from ~1 to `compression` over this many steps. 0 = off.
    pub warmup_steps: usize,
    /// uniform compression ratio c (LAGS per-layer k = ceil(d_l / c));
    /// ignored by Dense
    pub compression: f64,
    /// use Eq. 18 adaptive per-layer ratios instead of the uniform c
    pub adaptive: bool,
    /// cap c_u for adaptive selection
    pub c_max: f64,
    pub compressor: CompressorKind,
    /// hot-loop schedule: `overlap` streams each layer's rank-ordered
    /// reduction (and its slice of the apply) concurrently with workers
    /// still compressing earlier layers; `barrier` is the fork-join
    /// baseline. Bit-identical either way (DESIGN.md §Streaming-overlap);
    /// XLA compressors force barrier aggregation (PJRT is not Sync).
    pub pipeline: PipelineMode,
    /// sampled-threshold stride for host/xla sampled compressors
    pub sample_stride: usize,
    /// eval every N steps (0 = never)
    pub eval_every: usize,
    pub eval_batches: usize,
    /// record delta^(l) every N steps (0 = never)
    pub delta_every: usize,
    /// merge-buffer capacity in bytes for LAGS aggregation granularity
    pub merge_bytes: usize,
    pub seed: u64,
    /// print progress lines
    pub verbose: bool,
}

impl TrainConfig {
    pub fn default_for(model: &str) -> TrainConfig {
        TrainConfig {
            model: model.to_string(),
            algorithm: Algorithm::Lags,
            workers: 4,
            threads: 1,
            steps: 200,
            lr: 0.05,
            momentum: 0.0,
            local_momentum: 0.0,
            warmup_steps: 0,
            compression: 100.0,
            adaptive: false,
            c_max: 1000.0,
            compressor: CompressorKind::HostExact,
            pipeline: PipelineMode::Overlap,
            sample_stride: 64,
            eval_every: 50,
            eval_batches: 4,
            delta_every: 0,
            merge_bytes: 128 * 1024,
            seed: 42,
            verbose: false,
        }
    }

    /// Apply a JSON config object (unknown keys rejected).
    pub fn apply_json(&mut self, v: &Json) -> Result<()> {
        for (k, val) in v.as_obj()? {
            match k.as_str() {
                "model" => self.model = val.as_str()?.to_string(),
                "algorithm" => self.algorithm = Algorithm::parse(val.as_str()?)?,
                "workers" => self.workers = val.as_usize()?,
                "threads" => self.threads = val.as_usize()?,
                "steps" => self.steps = val.as_usize()?,
                "lr" => self.lr = val.as_f64()?,
                "momentum" => self.momentum = val.as_f64()?,
                "local_momentum" => self.local_momentum = val.as_f64()?,
                "warmup_steps" => self.warmup_steps = val.as_usize()?,
                "compression" => self.compression = val.as_f64()?,
                "adaptive" => self.adaptive = val.as_bool()?,
                "c_max" => self.c_max = val.as_f64()?,
                "compressor" => self.compressor = CompressorKind::parse(val.as_str()?)?,
                "pipeline" => self.pipeline = PipelineMode::parse(val.as_str()?)?,
                "sample_stride" => self.sample_stride = val.as_usize()?,
                "eval_every" => self.eval_every = val.as_usize()?,
                "eval_batches" => self.eval_batches = val.as_usize()?,
                "delta_every" => self.delta_every = val.as_usize()?,
                "merge_bytes" => self.merge_bytes = val.as_usize()?,
                "seed" => self.seed = val.as_usize()? as u64,
                "verbose" => self.verbose = val.as_bool()?,
                other => bail!("unknown config key {other:?}"),
            }
        }
        Ok(())
    }

    /// Apply CLI flags (the train subcommand's surface).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(path) = args.get("config") {
            let text =
                std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            self.apply_json(&Json::parse(&text)?)?;
        }
        if let Some(m) = args.get("model") {
            self.model = m.to_string();
        }
        if let Some(a) = args.get("algorithm") {
            self.algorithm = Algorithm::parse(a)?;
        }
        self.workers = args.usize_or("workers", self.workers)?;
        self.threads = args.usize_or("threads", self.threads)?;
        self.steps = args.usize_or("steps", self.steps)?;
        self.lr = args.f64_or("lr", self.lr)?;
        self.momentum = args.f64_or("momentum", self.momentum)?;
        self.local_momentum = args.f64_or("local-momentum", self.local_momentum)?;
        self.warmup_steps = args.usize_or("warmup-steps", self.warmup_steps)?;
        self.compression = args.f64_or("compression", self.compression)?;
        if args.bool("adaptive") {
            self.adaptive = true;
        }
        self.c_max = args.f64_or("c-max", self.c_max)?;
        if let Some(c) = args.get("compressor") {
            self.compressor = CompressorKind::parse(c)?;
        }
        if let Some(p) = args.get("pipeline") {
            self.pipeline = PipelineMode::parse(p)?;
        }
        self.sample_stride = args.usize_or("sample-stride", self.sample_stride)?;
        self.eval_every = args.usize_or("eval-every", self.eval_every)?;
        self.eval_batches = args.usize_or("eval-batches", self.eval_batches)?;
        self.delta_every = args.usize_or("delta-every", self.delta_every)?;
        self.merge_bytes = args.usize_or("merge-bytes", self.merge_bytes)?;
        self.seed = args.usize_or("seed", self.seed as usize)? as u64;
        if args.bool("verbose") {
            self.verbose = true;
        }
        self.validate()
    }

    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            bail!("workers must be >= 1");
        }
        if self.steps == 0 {
            bail!("steps must be >= 1");
        }
        if !(self.lr > 0.0 && self.lr.is_finite()) {
            bail!("lr must be positive");
        }
        if !(0.0..1.0).contains(&self.momentum) {
            bail!("momentum must be in [0, 1)");
        }
        if !(0.0..1.0).contains(&self.local_momentum) {
            bail!("local_momentum must be in [0, 1)");
        }
        if self.momentum > 0.0 && self.local_momentum > 0.0 {
            bail!("use either global momentum or momentum correction, not both");
        }
        if self.compression < 1.0 {
            bail!("compression ratio must be >= 1");
        }
        if self.c_max < 1.0 {
            bail!("c_max must be >= 1");
        }
        if self.sample_stride == 0 {
            bail!("sample_stride must be >= 1");
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("algorithm", Json::Str(self.algorithm.name().into())),
            ("workers", Json::Num(self.workers as f64)),
            ("threads", Json::Num(self.threads as f64)),
            ("steps", Json::Num(self.steps as f64)),
            ("lr", Json::Num(self.lr)),
            ("momentum", Json::Num(self.momentum)),
            ("compression", Json::Num(self.compression)),
            ("adaptive", Json::Bool(self.adaptive)),
            ("pipeline", Json::Str(self.pipeline.name().into())),
            ("c_max", Json::Num(self.c_max)),
            ("seed", Json::Num(self.seed as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        TrainConfig::default_for("mlp").validate().unwrap();
    }

    #[test]
    fn json_round_trip_and_overrides() {
        let mut cfg = TrainConfig::default_for("mlp");
        let j = Json::parse(
            r#"{"model": "cnn", "workers": 8, "lr": 0.1, "algorithm": "slgs", "compression": 250}"#,
        )
        .unwrap();
        cfg.apply_json(&j).unwrap();
        assert_eq!(cfg.model, "cnn");
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.algorithm, Algorithm::Slgs);
        assert_eq!(cfg.compression, 250.0);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut cfg = TrainConfig::default_for("mlp");
        let j = Json::parse(r#"{"modle": "cnn"}"#).unwrap();
        assert!(cfg.apply_json(&j).is_err());
    }

    #[test]
    fn cli_overrides() {
        let mut cfg = TrainConfig::default_for("mlp");
        let args = Args::parse(
            "train --workers 2 --steps 7 --threads 8 --algorithm dense --pipeline barrier --verbose"
                .split_whitespace()
                .map(String::from),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.workers, 2);
        assert_eq!(cfg.steps, 7);
        assert_eq!(cfg.threads, 8);
        assert_eq!(cfg.algorithm, Algorithm::Dense);
        assert_eq!(cfg.pipeline, PipelineMode::Barrier);
        assert!(cfg.verbose);
    }

    #[test]
    fn pipeline_mode_json_and_default() {
        let mut cfg = TrainConfig::default_for("mlp");
        assert_eq!(cfg.pipeline, PipelineMode::Overlap);
        cfg.apply_json(&Json::parse(r#"{"pipeline": "barrier"}"#).unwrap()).unwrap();
        assert_eq!(cfg.pipeline, PipelineMode::Barrier);
        assert!(cfg.apply_json(&Json::parse(r#"{"pipeline": "wat"}"#).unwrap()).is_err());
        assert_eq!(cfg.to_json().get("pipeline").unwrap().as_str().unwrap(), "barrier");
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut cfg = TrainConfig::default_for("mlp");
        cfg.workers = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default_for("mlp");
        cfg.momentum = 1.5;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default_for("mlp");
        cfg.compression = 0.5;
        assert!(cfg.validate().is_err());
    }
}
