//! Configuration system: typed training/experiment configs with defaults,
//! JSON config-file loading, CLI overrides, and validation.
//!
//! Precedence (lowest to highest): built-in defaults → `--config file.json`
//! → individual `--key value` CLI flags.

use crate::cluster::faults::FaultPlan;
use crate::collectives::{NetworkModel, PipelineMode};
use crate::sparsify::CompressorKind;
use crate::trainer::Algorithm;
use crate::util::cli::Args;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// The simulated interconnect the run prices communication with: the α–β
/// parameters Eq. 18 ratio selection and the DES consume. The worker count
/// comes from [`TrainConfig::workers`]; `--net gige16|tengige|infiniband`
/// picks a preset, `--net-alpha`/`--net-bandwidth` override it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetConfig {
    /// per-message latency (s) — wire latency + software launch overhead
    pub alpha: f64,
    /// bandwidth (bytes/s)
    pub bandwidth: f64,
}

impl NetConfig {
    fn of(m: NetworkModel) -> NetConfig {
        NetConfig { alpha: m.alpha, bandwidth: m.bandwidth }
    }

    /// The paper's testbed: 1 Gbps Ethernet (the default).
    pub fn gige16() -> NetConfig {
        NetConfig::of(NetworkModel::gige_16())
    }

    /// 10 Gbps Ethernet.
    pub fn tengige() -> NetConfig {
        NetConfig::of(NetworkModel::tengige_16())
    }

    /// 100 Gbps-class InfiniBand/RDMA.
    pub fn infiniband() -> NetConfig {
        NetConfig::of(NetworkModel::infiniband_16())
    }

    pub fn preset(name: &str) -> Result<NetConfig> {
        Ok(match name {
            "gige16" => NetConfig::gige16(),
            "tengige" => NetConfig::tengige(),
            "infiniband" => NetConfig::infiniband(),
            _ => bail!("unknown network preset {name:?} (gige16|tengige|infiniband)"),
        })
    }

    /// The full α–β model at a concrete worker count.
    pub fn model(&self, workers: usize) -> NetworkModel {
        NetworkModel { alpha: self.alpha, bandwidth: self.bandwidth, workers }
    }
}

/// Full configuration of a numeric training run.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    pub model: String,
    pub algorithm: Algorithm,
    /// logical data-parallel workers P
    pub workers: usize,
    /// OS threads for the per-worker hot loop (gradient compute + error
    /// feedback compression). 1 = sequential baseline; 0 = one per core.
    /// Results are bit-identical for every value — the reduction stays
    /// rank-ordered outside the parallel region (DESIGN.md §Threading).
    pub threads: usize,
    pub steps: usize,
    pub lr: f64,
    /// momentum on the aggregated update (0 = plain Algorithm 1)
    pub momentum: f64,
    /// momentum CORRECTION (Lin et al. 2018): per-worker local momentum
    /// accumulated BEFORE sparsification — the training trick the paper
    /// cites for closing the sparsification accuracy gap (§Comparison of
    /// Convergence Rates). 0 = off.
    pub local_momentum: f64,
    /// warm-up schedule (Lin et al. 2018): ramp the compression ratio
    /// exponentially from ~1 to `compression` over this many steps. 0 = off.
    pub warmup_steps: usize,
    /// uniform compression ratio c (LAGS per-layer k = ceil(d_l / c));
    /// ignored by Dense
    pub compression: f64,
    /// use Eq. 18 adaptive per-layer ratios instead of the uniform c.
    /// P = 1 explicitly selects all-dense (c = 1 everywhere): a single
    /// worker has no communication to hide, so no phantom cluster is
    /// substituted.
    pub adaptive: bool,
    /// cap c_u for adaptive selection
    pub c_max: f64,
    /// online adaptive re-selection period: every N steps the trainer
    /// re-runs Eq. 18 over the MEASURED (EWMA) per-layer timing profile
    /// and swaps in the new ratios at the step boundary. 0 = select once
    /// at startup (the fixed-schedule baseline). Requires `adaptive` and
    /// the LAGS algorithm; re-selection starts after `warmup_steps`.
    pub reselect_every: usize,
    /// the α–β interconnect Eq. 18 and the DES price communication with
    pub net: NetConfig,
    /// run the startup device-flops calibration: measure sustained GEMM
    /// flops at the zoo's hot-loop shapes and PERSIST the result next to
    /// the artifacts, so this and every later run prices Eq. 18 with the
    /// measured number. Off by default: plain runs only LOAD an existing
    /// calibration file (`runtime::calibrate` explains why measuring
    /// implicitly on every startup would hurt reproducibility).
    pub calibrate: bool,
    pub compressor: CompressorKind,
    /// hot-loop schedule: `overlap` streams each layer's rank-ordered
    /// reduction (and its slice of the apply) concurrently with workers
    /// still compressing earlier layers; `barrier` is the fork-join
    /// baseline. Bit-identical either way (DESIGN.md §Streaming-overlap);
    /// XLA compressors force barrier aggregation (PJRT is not Sync).
    pub pipeline: PipelineMode,
    /// sampled-threshold stride for host/xla sampled compressors
    pub sample_stride: usize,
    /// eval every N steps (0 = never)
    pub eval_every: usize,
    pub eval_batches: usize,
    /// record delta^(l) every N steps (0 = never)
    pub delta_every: usize,
    /// δ denominator mode: true = closed-form E‖RandK error‖² (Eq. 20's
    /// expectation — what `lags validate` gates on), false = a single
    /// RandK draw per sample (the cheap per-run spot check)
    pub delta_expectation: bool,
    /// §5 merge-buffer capacity in wire bytes per rank: consecutive layer
    /// messages are grouped up to this size before reduction (real
    /// trainer AND the DES prediction). 0 (the default) = per-layer
    /// flushing — on the small built-in models a large buffer would
    /// swallow a whole step's traffic and defer every reduction past the
    /// last publish, erasing the streaming overlap, so merging is the
    /// opt-in ablation knob, not the default.
    pub merge_bytes: usize,
    /// deterministic fault/heterogeneity schedule (`cluster::faults`):
    /// per-worker compute skew, per-(worker, step) link jitter, drop/join
    /// membership events. `--faults plan.json` on the CLI; the JSON config
    /// key `faults` takes either an inline plan object or a path string.
    pub faults: FaultPlan,
    /// bounded-staleness quorum (LAGS only): each step, only the q
    /// virtually-fastest alive workers participate in the reduction; the
    /// excluded ranks' messages fold back into their own error-feedback
    /// residuals and re-enter next step. 0 = off (full synchronous P).
    pub quorum: usize,
    /// with `quorum`: a worker excluded this many CONSECUTIVE steps is
    /// force-included on the next one (bounds gradient staleness). 0 = no
    /// forcing.
    pub staleness_bound: usize,
    /// write a durable checkpoint every N steps (0 = off). Requires
    /// `checkpoint_dir`. Each write is atomic (temp + fsync + rename), so
    /// a crash mid-write keeps the previous checkpoint intact.
    pub checkpoint_every: usize,
    /// directory holding `checkpoint.bin` and crash tombstones ("" =
    /// unset); `lags resume <dir>` and `train --resume` read it back
    pub checkpoint_dir: String,
    /// write the per-step per-worker measured timing trace to this JSON
    /// file at the end of the run ("" = off). Replay the recorded profile
    /// as a fault schedule with `--faults-trace FILE`.
    pub record_trace: String,
    pub seed: u64,
    /// print progress lines
    pub verbose: bool,
}

impl TrainConfig {
    /// Built-in defaults, lightly specialised per zoo model: the conv and
    /// recurrent native-zoo models cost ~10× more compute per step than
    /// the MLPs, so their default runs are shorter, and LM-metric zoo
    /// models (the markov task) converge faster with a slightly larger
    /// step size. Specialisation keys off the native zoo spec registry
    /// (`runtime::native::zoo_spec`) rather than a second hardcoded name
    /// list; unknown names keep the generic defaults. Caveat: the config
    /// layer has no backend in scope, so an ARTIFACT model that shares a
    /// zoo spec name (`convnet`/`convnet_deep`/`rnn`) inherits these
    /// defaults too — defaults only; explicit flags always win.
    pub fn default_for(model: &str) -> TrainConfig {
        let spec = crate::runtime::native::zoo_spec(model);
        let steps = if spec.is_some() { 120 } else { 200 };
        let lr = match &spec {
            Some(s) if s.metric == crate::runtime::Metric::PplLoss => 0.1,
            _ => 0.05,
        };
        TrainConfig {
            model: model.to_string(),
            algorithm: Algorithm::Lags,
            workers: 4,
            threads: 1,
            steps,
            lr,
            momentum: 0.0,
            local_momentum: 0.0,
            warmup_steps: 0,
            compression: 100.0,
            adaptive: false,
            c_max: 1000.0,
            reselect_every: 0,
            net: NetConfig::gige16(),
            calibrate: false,
            compressor: CompressorKind::HostExact,
            pipeline: PipelineMode::Overlap,
            sample_stride: 64,
            eval_every: 50,
            eval_batches: 4,
            delta_every: 0,
            delta_expectation: false,
            merge_bytes: 0,
            faults: FaultPlan::none(),
            quorum: 0,
            staleness_bound: 0,
            checkpoint_every: 0,
            checkpoint_dir: String::new(),
            record_trace: String::new(),
            seed: 42,
            verbose: false,
        }
    }

    /// Apply a JSON config object (unknown keys rejected).
    pub fn apply_json(&mut self, v: &Json) -> Result<()> {
        // "faults" sorts before "workers" in the BTreeMap walk, so resolve
        // the start-worker count up front: a path-form plan validates at
        // load time against the worker count the SAME object configures.
        let start_workers = match v.opt("workers") {
            Some(w) => w.as_usize().context("workers")?,
            None => self.workers,
        };
        for (k, val) in v.as_obj()? {
            match k.as_str() {
                "model" => self.model = val.as_str()?.to_string(),
                "algorithm" => self.algorithm = Algorithm::parse(val.as_str()?)?,
                "workers" => self.workers = val.as_usize()?,
                "threads" => self.threads = val.as_usize()?,
                "steps" => self.steps = val.as_usize()?,
                "lr" => self.lr = val.as_f64()?,
                "momentum" => self.momentum = val.as_f64()?,
                "local_momentum" => self.local_momentum = val.as_f64()?,
                "warmup_steps" => self.warmup_steps = val.as_usize()?,
                "compression" => self.compression = val.as_f64()?,
                "adaptive" => self.adaptive = val.as_bool()?,
                "c_max" => self.c_max = val.as_f64()?,
                // BTreeMap iterates keys alphabetically, so a "net" preset
                // is applied before "net_alpha"/"net_bandwidth" overrides
                "net" => self.net = NetConfig::preset(val.as_str()?)?,
                "net_alpha" => self.net.alpha = val.as_f64()?,
                "net_bandwidth" => self.net.bandwidth = val.as_f64()?,
                "reselect_every" => self.reselect_every = val.as_usize()?,
                "calibrate" => self.calibrate = val.as_bool()?,
                "compressor" => self.compressor = CompressorKind::parse(val.as_str()?)?,
                "pipeline" => self.pipeline = PipelineMode::parse(val.as_str()?)?,
                "sample_stride" => self.sample_stride = val.as_usize()?,
                "eval_every" => self.eval_every = val.as_usize()?,
                "eval_batches" => self.eval_batches = val.as_usize()?,
                "delta_every" => self.delta_every = val.as_usize()?,
                "delta_expectation" => self.delta_expectation = val.as_bool()?,
                "merge_bytes" => self.merge_bytes = val.as_usize()?,
                "checkpoint_every" => self.checkpoint_every = val.as_usize()?,
                "checkpoint_dir" => self.checkpoint_dir = val.as_str()?.to_string(),
                "record_trace" => self.record_trace = val.as_str()?.to_string(),
                // either an inline plan object or a path to a plan file
                "faults" => {
                    self.faults = match val {
                        Json::Str(path) => FaultPlan::load(path, start_workers)?,
                        obj => FaultPlan::from_json(obj)?,
                    }
                }
                "quorum" => self.quorum = val.as_usize()?,
                "staleness_bound" => self.staleness_bound = val.as_usize()?,
                "seed" => self.seed = val.as_usize()? as u64,
                "verbose" => self.verbose = val.as_bool()?,
                other => bail!("unknown config key {other:?}"),
            }
        }
        Ok(())
    }

    /// Apply CLI flags (the train subcommand's surface).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(path) = args.get("config") {
            let text =
                std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            self.apply_json(&Json::parse(&text)?)?;
        }
        if let Some(m) = args.get("model") {
            self.model = m.to_string();
        }
        if let Some(a) = args.get("algorithm") {
            self.algorithm = Algorithm::parse(a)?;
        }
        self.workers = args.usize_or("workers", self.workers)?;
        self.threads = args.usize_or("threads", self.threads)?;
        self.steps = args.usize_or("steps", self.steps)?;
        self.lr = args.f64_or("lr", self.lr)?;
        self.momentum = args.f64_or("momentum", self.momentum)?;
        self.local_momentum = args.f64_or("local-momentum", self.local_momentum)?;
        self.warmup_steps = args.usize_or("warmup-steps", self.warmup_steps)?;
        self.compression = args.f64_or("compression", self.compression)?;
        if args.bool("adaptive") {
            self.adaptive = true;
        }
        self.c_max = args.f64_or("c-max", self.c_max)?;
        self.reselect_every = args.usize_or("reselect-every", self.reselect_every)?;
        if let Some(p) = args.get("net") {
            self.net = NetConfig::preset(p)?;
        }
        self.net.alpha = args.f64_or("net-alpha", self.net.alpha)?;
        self.net.bandwidth = args.f64_or("net-bandwidth", self.net.bandwidth)?;
        if args.bool("calibrate") {
            self.calibrate = true;
        }
        if let Some(c) = args.get("compressor") {
            self.compressor = CompressorKind::parse(c)?;
        }
        if let Some(p) = args.get("pipeline") {
            self.pipeline = PipelineMode::parse(p)?;
        }
        self.sample_stride = args.usize_or("sample-stride", self.sample_stride)?;
        self.eval_every = args.usize_or("eval-every", self.eval_every)?;
        self.eval_batches = args.usize_or("eval-batches", self.eval_batches)?;
        self.delta_every = args.usize_or("delta-every", self.delta_every)?;
        if args.bool("delta-expectation") {
            self.delta_expectation = true;
        }
        self.merge_bytes = args.usize_or("merge-bytes", self.merge_bytes)?;
        if let Some(path) = args.get("faults") {
            // --workers is resolved above, so the load-time validation
            // sees the final start-worker count
            self.faults = FaultPlan::load(path, self.workers)?;
        }
        if let Some(path) = args.get("faults-trace") {
            // replay a --record-trace file as a compute-skew schedule; the
            // trace composes with (overrides the skew rows of) --faults
            self.faults.trace = FaultPlan::from_trace(path)?.trace;
        }
        self.quorum = args.usize_or("quorum", self.quorum)?;
        self.staleness_bound = args.usize_or("staleness-bound", self.staleness_bound)?;
        self.checkpoint_every = args.usize_or("checkpoint-every", self.checkpoint_every)?;
        if let Some(d) = args.get("checkpoint-dir") {
            self.checkpoint_dir = d.to_string();
        }
        if let Some(p) = args.get("record-trace") {
            self.record_trace = p.to_string();
        }
        self.seed = args.usize_or("seed", self.seed as usize)? as u64;
        if args.bool("verbose") {
            self.verbose = true;
        }
        self.validate()
    }

    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            bail!("workers must be >= 1");
        }
        if self.steps == 0 {
            bail!("steps must be >= 1");
        }
        if !(self.lr > 0.0 && self.lr.is_finite()) {
            bail!("lr must be positive");
        }
        if !(0.0..1.0).contains(&self.momentum) {
            bail!("momentum must be in [0, 1)");
        }
        if !(0.0..1.0).contains(&self.local_momentum) {
            bail!("local_momentum must be in [0, 1)");
        }
        if self.momentum > 0.0 && self.local_momentum > 0.0 {
            bail!("use either global momentum or momentum correction, not both");
        }
        if self.compression < 1.0 {
            bail!("compression ratio must be >= 1");
        }
        if self.c_max < 1.0 {
            bail!("c_max must be >= 1");
        }
        if self.sample_stride == 0 {
            bail!("sample_stride must be >= 1");
        }
        if self.reselect_every > 0 && (!self.adaptive || self.algorithm != Algorithm::Lags) {
            bail!("reselect_every requires --adaptive and the lags algorithm");
        }
        if !(self.net.alpha >= 0.0 && self.net.alpha.is_finite()) {
            bail!("net alpha must be finite and >= 0");
        }
        if !(self.net.bandwidth > 0.0 && self.net.bandwidth.is_finite()) {
            bail!("net bandwidth must be positive");
        }
        self.faults.validate(self.workers)?;
        if self.quorum > self.workers {
            bail!("quorum ({}) cannot exceed the starting worker count ({})", self.quorum, self.workers);
        }
        if self.quorum > 0 && self.algorithm != Algorithm::Lags {
            bail!("--quorum requires the lags algorithm (per-layer reduction with error feedback)");
        }
        if self.staleness_bound > 0 && self.quorum == 0 {
            bail!("--staleness-bound requires --quorum");
        }
        if self.checkpoint_every > 0 && self.checkpoint_dir.is_empty() {
            bail!("--checkpoint-every requires --checkpoint-dir");
        }
        if !self.faults.crashes.is_empty() && self.checkpoint_every == 0 {
            bail!(
                "a crash@step schedule requires --checkpoint-every > 0 \
                 (and --checkpoint-dir): without a durable checkpoint the \
                 crashed run could never resume"
            );
        }
        Ok(())
    }

    /// Serialize EVERY config field, so a saved report config round-trips
    /// through [`Self::apply_json`] (asserted by `to_json_round_trips`).
    /// The net config is emitted as its `net_alpha`/`net_bandwidth` values
    /// (a preset is just shorthand for those two numbers).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("algorithm", Json::Str(self.algorithm.name().into())),
            ("workers", Json::Num(self.workers as f64)),
            ("threads", Json::Num(self.threads as f64)),
            ("steps", Json::Num(self.steps as f64)),
            ("lr", Json::Num(self.lr)),
            ("momentum", Json::Num(self.momentum)),
            ("local_momentum", Json::Num(self.local_momentum)),
            ("warmup_steps", Json::Num(self.warmup_steps as f64)),
            ("compression", Json::Num(self.compression)),
            ("adaptive", Json::Bool(self.adaptive)),
            ("c_max", Json::Num(self.c_max)),
            ("reselect_every", Json::Num(self.reselect_every as f64)),
            ("net_alpha", Json::Num(self.net.alpha)),
            ("net_bandwidth", Json::Num(self.net.bandwidth)),
            ("calibrate", Json::Bool(self.calibrate)),
            ("compressor", Json::Str(self.compressor.name().into())),
            ("pipeline", Json::Str(self.pipeline.name().into())),
            ("sample_stride", Json::Num(self.sample_stride as f64)),
            ("eval_every", Json::Num(self.eval_every as f64)),
            ("eval_batches", Json::Num(self.eval_batches as f64)),
            ("delta_every", Json::Num(self.delta_every as f64)),
            ("delta_expectation", Json::Bool(self.delta_expectation)),
            ("merge_bytes", Json::Num(self.merge_bytes as f64)),
            ("faults", self.faults.to_json()),
            ("quorum", Json::Num(self.quorum as f64)),
            ("staleness_bound", Json::Num(self.staleness_bound as f64)),
            ("checkpoint_every", Json::Num(self.checkpoint_every as f64)),
            ("checkpoint_dir", Json::Str(self.checkpoint_dir.clone())),
            ("record_trace", Json::Str(self.record_trace.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("verbose", Json::Bool(self.verbose)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        TrainConfig::default_for("mlp").validate().unwrap();
    }

    #[test]
    fn json_round_trip_and_overrides() {
        let mut cfg = TrainConfig::default_for("mlp");
        let j = Json::parse(
            r#"{"model": "cnn", "workers": 8, "lr": 0.1, "algorithm": "slgs", "compression": 250}"#,
        )
        .unwrap();
        cfg.apply_json(&j).unwrap();
        assert_eq!(cfg.model, "cnn");
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.algorithm, Algorithm::Slgs);
        assert_eq!(cfg.compression, 250.0);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut cfg = TrainConfig::default_for("mlp");
        let j = Json::parse(r#"{"modle": "cnn"}"#).unwrap();
        assert!(cfg.apply_json(&j).is_err());
    }

    #[test]
    fn cli_overrides() {
        let mut cfg = TrainConfig::default_for("mlp");
        let args = Args::parse(
            "train --workers 2 --steps 7 --threads 8 --algorithm dense --pipeline barrier --verbose"
                .split_whitespace()
                .map(String::from),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.workers, 2);
        assert_eq!(cfg.steps, 7);
        assert_eq!(cfg.threads, 8);
        assert_eq!(cfg.algorithm, Algorithm::Dense);
        assert_eq!(cfg.pipeline, PipelineMode::Barrier);
        assert!(cfg.verbose);
    }

    #[test]
    fn pipeline_mode_json_and_default() {
        let mut cfg = TrainConfig::default_for("mlp");
        assert_eq!(cfg.pipeline, PipelineMode::Overlap);
        cfg.apply_json(&Json::parse(r#"{"pipeline": "barrier"}"#).unwrap()).unwrap();
        assert_eq!(cfg.pipeline, PipelineMode::Barrier);
        assert!(cfg.apply_json(&Json::parse(r#"{"pipeline": "wat"}"#).unwrap()).is_err());
        assert_eq!(cfg.to_json().get("pipeline").unwrap().as_str().unwrap(), "barrier");
    }

    #[test]
    fn to_json_round_trips_every_field() {
        // non-default value in every field; to_json → apply_json must
        // reproduce the config exactly (the bug: to_json used to drop
        // local_momentum, warmup_steps, compressor, sample_stride,
        // eval_every, eval_batches, delta_every, merge_bytes, threads and
        // verbose)
        let mut cfg = TrainConfig::default_for("cnn");
        cfg.algorithm = Algorithm::Slgs;
        cfg.workers = 7;
        cfg.threads = 3;
        cfg.steps = 11;
        cfg.lr = 0.125;
        cfg.momentum = 0.0;
        cfg.local_momentum = 0.25;
        cfg.warmup_steps = 9;
        cfg.compression = 50.0;
        cfg.adaptive = true;
        cfg.c_max = 321.0;
        cfg.reselect_every = 25;
        cfg.net = NetConfig { alpha: 1e-4, bandwidth: 2e9 };
        cfg.calibrate = true;
        cfg.compressor = CompressorKind::HostSampled;
        cfg.pipeline = PipelineMode::Barrier;
        cfg.sample_stride = 17;
        cfg.eval_every = 13;
        cfg.eval_batches = 3;
        cfg.delta_every = 4;
        cfg.delta_expectation = true;
        cfg.merge_bytes = 4096;
        cfg.faults = FaultPlan {
            seed: 13,
            compute_skew: vec![1.0, 3.5],
            alpha_jitter: 0.125,
            bandwidth_jitter: 0.25,
            events: vec![crate::cluster::faults::MembershipEvent {
                step: 5,
                action: crate::cluster::faults::MembershipAction::Drop,
                worker: 2,
            }],
            crashes: vec![12],
            trace: vec![vec![1.0, 2.0], vec![0.5, 1.5]],
        };
        cfg.quorum = 5;
        cfg.staleness_bound = 2;
        cfg.checkpoint_every = 6;
        cfg.checkpoint_dir = "ckpt-dir".into();
        cfg.record_trace = "trace.json".into();
        cfg.seed = 7;
        cfg.verbose = true;
        let mut back = TrainConfig::default_for("other");
        back.apply_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        // and the serialized text form parses back to the same object
        let reparsed = Json::parse(&cfg.to_json().to_string_compact()).unwrap();
        let mut back2 = TrainConfig::default_for("other");
        back2.apply_json(&reparsed).unwrap();
        assert_eq!(cfg.model, back2.model);
        assert_eq!(cfg.compressor, back2.compressor);
        assert_eq!(cfg.merge_bytes, back2.merge_bytes);
    }

    #[test]
    fn net_presets_and_overrides() {
        let mut cfg = TrainConfig::default_for("mlp");
        assert_eq!(cfg.net, NetConfig::gige16());
        let args = Args::parse(
            "train --net infiniband --net-alpha 1e-5"
                .split_whitespace()
                .map(String::from),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.net.alpha, 1e-5); // override wins over the preset
        assert_eq!(cfg.net.bandwidth, NetConfig::infiniband().bandwidth);
        // JSON spelling: preset then field overrides (alphabetical keys)
        let mut cfg = TrainConfig::default_for("mlp");
        cfg.apply_json(&Json::parse(r#"{"net": "tengige", "net_bandwidth": 5e8}"#).unwrap())
            .unwrap();
        assert_eq!(cfg.net.alpha, NetConfig::tengige().alpha);
        assert_eq!(cfg.net.bandwidth, 5e8);
        assert!(NetConfig::preset("wat").is_err());
        // presets get faster left to right
        assert!(NetConfig::gige16().bandwidth < NetConfig::tengige().bandwidth);
        assert!(NetConfig::tengige().bandwidth < NetConfig::infiniband().bandwidth);
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut cfg = TrainConfig::default_for("mlp");
        cfg.workers = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default_for("mlp");
        cfg.momentum = 1.5;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default_for("mlp");
        cfg.compression = 0.5;
        assert!(cfg.validate().is_err());
        // --reselect-every without --adaptive (or off the LAGS path)
        // would be a silent no-op otherwise
        let mut cfg = TrainConfig::default_for("mlp");
        cfg.reselect_every = 50;
        assert!(cfg.validate().is_err());
        cfg.adaptive = true;
        cfg.validate().unwrap();
        cfg.algorithm = Algorithm::Slgs;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn robustness_flags_validate() {
        // quorum must fit the cluster and needs the lags algorithm
        let mut cfg = TrainConfig::default_for("mlp");
        cfg.quorum = 3;
        cfg.validate().unwrap();
        cfg.quorum = 5; // > workers (4)
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default_for("mlp");
        cfg.quorum = 3;
        cfg.algorithm = Algorithm::Dense;
        assert!(cfg.validate().is_err());
        // staleness bound is meaningless without a quorum
        let mut cfg = TrainConfig::default_for("mlp");
        cfg.staleness_bound = 2;
        assert!(cfg.validate().is_err());
        cfg.quorum = 3;
        cfg.validate().unwrap();
        // an inconsistent fault schedule is rejected through the config
        let mut cfg = TrainConfig::default_for("mlp");
        cfg.faults.events.push(crate::cluster::faults::MembershipEvent {
            step: 0,
            action: crate::cluster::faults::MembershipAction::Drop,
            worker: 9,
        });
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn checkpoint_flags_validate() {
        // a checkpoint period without a destination has nowhere to write
        let mut cfg = TrainConfig::default_for("mlp");
        cfg.checkpoint_every = 10;
        assert!(cfg.validate().is_err());
        cfg.checkpoint_dir = "ckpts".into();
        cfg.validate().unwrap();
        // a crash schedule without durable checkpoints could never resume
        let mut cfg = TrainConfig::default_for("mlp");
        cfg.faults.crashes.push(5);
        assert!(cfg.validate().is_err());
        cfg.checkpoint_every = 1;
        cfg.checkpoint_dir = "ckpts".into();
        cfg.validate().unwrap();
        // CLI spelling
        let mut cfg = TrainConfig::default_for("mlp");
        let args = Args::parse(
            "train --checkpoint-every 3 --checkpoint-dir out/ck --record-trace t.json"
                .split_whitespace()
                .map(String::from),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.checkpoint_every, 3);
        assert_eq!(cfg.checkpoint_dir, "out/ck");
        assert_eq!(cfg.record_trace, "t.json");
    }

    #[test]
    fn faults_json_inline_and_cli_flags() {
        let mut cfg = TrainConfig::default_for("mlp");
        cfg.apply_json(
            &Json::parse(
                r#"{"faults": {"seed": 3, "compute_skew": [1.0, 2.0]}, "quorum": 3, "staleness_bound": 4}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.faults.seed, 3);
        assert_eq!(cfg.faults.compute_skew, vec![1.0, 2.0]);
        assert_eq!((cfg.quorum, cfg.staleness_bound), (3, 4));
        let args = Args::parse(
            "train --quorum 2 --staleness-bound 1".split_whitespace().map(String::from),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!((cfg.quorum, cfg.staleness_bound), (2, 1));
    }
}
