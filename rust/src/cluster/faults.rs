//! Deterministic fault & heterogeneity injection (the "flaky cluster"
//! the paper never tests).
//!
//! A [`FaultPlan`] describes everything that can go wrong with the P
//! logical workers, in a form that is a **pure function of (plan, worker
//! uid, step)**:
//!
//! - **compute skew** — a per-worker multiplicative slowdown (`2.0` = the
//!   worker takes twice the nominal step compute). Constant over the run,
//!   indexed by the worker's stable uid.
//! - **link jitter** — per-(worker, step) multiplicative noise on the
//!   worker's effective α (latency) and bandwidth terms, drawn from the
//!   plan's own seeded [`Rng`] stream. Never sampled from wall-clock or
//!   arrival order, so two runs with the same plan draw identical jitter.
//! - **membership events** — a drop/join schedule keyed by step. Events
//!   fire strictly *between* optimizer steps (at the top of `step()` for
//!   their step index), which is what makes elastic membership compatible
//!   with the bit-identity contract: the parameter state at every step
//!   boundary is a deterministic function of the seed and the plan.
//!
//! The same plan is threaded through the real trainer (quorum selection,
//! straggler sleeps, membership) and the DES (`pipeline::desim`, compute
//! gating + conservative link pricing), so predicted and measured
//! degradation are directly comparable.
//!
//! **Quorum determinism.** The bounded-staleness quorum mode does NOT use
//! reduce timeouts on real clocks — that would make participation depend
//! on scheduler noise. Instead each step's participants are the `q`
//! virtually-fastest alive workers under [`FaultPlan::virtual_step_time`]
//! (skew × jittered link multiplier, ties broken by rank), with workers
//! that have been excluded for `staleness_bound` consecutive steps forced
//! back in. The *wall-clock* effect of straggling is modelled separately
//! (sleeps in the trainer, compute gating in the DES); the *numeric*
//! effect is this pure selection function.

use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};

/// What a membership event does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MembershipAction {
    /// Worker leaves; its error-feedback residual is re-sharded across
    /// the survivors (no gradient mass is lost).
    Drop,
    /// Worker joins with fresh (zero) residual and its own uid-keyed data
    /// shard stream.
    Join,
}

impl MembershipAction {
    pub fn name(&self) -> &'static str {
        match self {
            MembershipAction::Drop => "drop",
            MembershipAction::Join => "join",
        }
    }

    pub fn parse(s: &str) -> Result<MembershipAction> {
        match s {
            "drop" => Ok(MembershipAction::Drop),
            "join" => Ok(MembershipAction::Join),
            other => bail!("unknown membership action {other:?} (want drop|join)"),
        }
    }
}

/// One scheduled membership change. `worker` is the stable uid (the data
/// shard key), not the current rank.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MembershipEvent {
    /// step index at whose start the event fires (before the step's
    /// gradients are computed)
    pub step: usize,
    pub action: MembershipAction,
    pub worker: usize,
}

/// The full deterministic fault schedule for a run. See the module docs
/// for semantics; [`FaultPlan::none`] is the default healthy cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// seed for the jitter streams (independent of the training seed)
    pub seed: u64,
    /// per-worker-uid multiplicative compute skew; missing entries mean
    /// 1.0 (nominal). Values < 1 model faster-than-nominal workers.
    pub compute_skew: Vec<f64>,
    /// relative α (latency) jitter amplitude in [0, 1): each (worker,
    /// step) draws a multiplier in [1-j, 1+j]
    pub alpha_jitter: f64,
    /// relative bandwidth jitter amplitude in [0, 1), same convention
    pub bandwidth_jitter: f64,
    /// drop/join schedule, applied in listed order within a step
    pub events: Vec<MembershipEvent>,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan::none()
    }
}

impl FaultPlan {
    /// The healthy cluster: no skew, no jitter, no membership changes.
    pub fn none() -> FaultPlan {
        FaultPlan {
            seed: 0,
            compute_skew: Vec::new(),
            alpha_jitter: 0.0,
            bandwidth_jitter: 0.0,
            events: Vec::new(),
        }
    }

    /// True when the plan injects nothing (the default-config fast path).
    pub fn is_none(&self) -> bool {
        self.events.is_empty() && !self.perturbs_time()
    }

    /// True when the plan perturbs per-worker step time (skew or jitter)
    /// — the trainer then measures compute wall-clock every step so the
    /// straggler sleeps have a base to scale.
    pub fn perturbs_time(&self) -> bool {
        self.alpha_jitter > 0.0
            || self.bandwidth_jitter > 0.0
            || self.compute_skew.iter().any(|&s| s != 1.0)
    }

    /// Compute skew for a worker uid (1.0 when unlisted).
    pub fn skew_of(&self, uid: usize) -> f64 {
        self.compute_skew.get(uid).copied().unwrap_or(1.0)
    }

    /// Per-(worker, step) link multipliers `(alpha_mult, bandwidth_mult)`,
    /// each in `[1-j, 1+j]` clamped to ≥ 0.05. Pure function of the plan
    /// seed — never of wall-clock.
    pub fn link_jitter(&self, uid: usize, step: usize) -> (f64, f64) {
        if self.alpha_jitter == 0.0 && self.bandwidth_jitter == 0.0 {
            return (1.0, 1.0);
        }
        let stream = (uid as u64) << 32 | (step as u64 & 0xffff_ffff);
        let mut r = Rng::new(self.seed).fork(stream);
        let a = (1.0 + self.alpha_jitter * (2.0 * r.uniform() - 1.0)).max(0.05);
        let b = (1.0 + self.bandwidth_jitter * (2.0 * r.uniform() - 1.0)).max(0.05);
        (a, b)
    }

    /// Relative virtual duration of worker `uid`'s step `step`: compute
    /// skew × jittered link slowdown (a slow link delays the worker's
    /// messages just like slow compute does). This is the quantity the
    /// quorum ranks workers by.
    pub fn virtual_step_time(&self, uid: usize, step: usize) -> f64 {
        let (a, b) = self.link_jitter(uid, step);
        // α grows link time multiplicatively; bandwidth shrinks it
        self.skew_of(uid) * a / b
    }

    /// Events scheduled for `step`, in listed order.
    pub fn events_at(&self, step: usize) -> impl Iterator<Item = &MembershipEvent> {
        self.events.iter().filter(move |e| e.step == step)
    }

    /// Check internal consistency against a starting worker count:
    /// replays the schedule and rejects drops of absent workers, joins of
    /// present workers, and schedules that empty the cluster.
    pub fn validate(&self, start_workers: usize) -> Result<()> {
        if !(0.0..1.0).contains(&self.alpha_jitter) {
            bail!("alpha_jitter must be in [0, 1), got {}", self.alpha_jitter);
        }
        if !(0.0..1.0).contains(&self.bandwidth_jitter) {
            bail!("bandwidth_jitter must be in [0, 1), got {}", self.bandwidth_jitter);
        }
        if let Some(s) = self.compute_skew.iter().find(|s| !s.is_finite() || **s <= 0.0) {
            bail!("compute_skew entries must be finite and > 0, got {s}");
        }
        let mut alive: Vec<usize> = (0..start_workers).collect();
        let mut sorted = self.events.clone();
        // replay in (step, listed) order — stable sort keeps the intra-step
        // order the trainer will apply
        sorted.sort_by_key(|e| e.step);
        for ev in &sorted {
            match ev.action {
                MembershipAction::Drop => {
                    let Some(pos) = alive.iter().position(|&u| u == ev.worker) else {
                        bail!("step {}: drop of absent worker {}", ev.step, ev.worker);
                    };
                    if alive.len() == 1 {
                        bail!("step {}: schedule would drop the last worker", ev.step);
                    }
                    alive.remove(pos);
                }
                MembershipAction::Join => {
                    if alive.contains(&ev.worker) {
                        bail!("step {}: join of already-present worker {}", ev.step, ev.worker);
                    }
                    alive.push(ev.worker);
                }
            }
        }
        Ok(())
    }

    // ---- JSON ------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::Num(self.seed as f64)),
            ("compute_skew", Json::arr_f64(&self.compute_skew)),
            ("alpha_jitter", Json::Num(self.alpha_jitter)),
            ("bandwidth_jitter", Json::Num(self.bandwidth_jitter)),
            (
                "events",
                Json::Arr(
                    self.events
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("step", Json::Num(e.step as f64)),
                                ("action", Json::Str(e.action.name().into())),
                                ("worker", Json::Num(e.worker as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse a plan object. Missing keys default to the healthy values so
    /// a plan file only needs the faults it injects; unknown keys are
    /// rejected (same contract as `TrainConfig::apply_json`).
    pub fn from_json(v: &Json) -> Result<FaultPlan> {
        let obj = v.as_obj().context("fault plan must be a JSON object")?;
        let mut plan = FaultPlan::none();
        for (key, val) in obj {
            match key.as_str() {
                "seed" => plan.seed = val.as_usize()? as u64,
                "compute_skew" => {
                    plan.compute_skew =
                        val.as_arr()?.iter().map(Json::as_f64).collect::<Result<_>>()?;
                }
                "alpha_jitter" => plan.alpha_jitter = val.as_f64()?,
                "bandwidth_jitter" => plan.bandwidth_jitter = val.as_f64()?,
                "events" => {
                    plan.events = val
                        .as_arr()?
                        .iter()
                        .map(|e| {
                            Ok(MembershipEvent {
                                step: e.get("step")?.as_usize()?,
                                action: MembershipAction::parse(e.get("action")?.as_str()?)?,
                                worker: e.get("worker")?.as_usize()?,
                            })
                        })
                        .collect::<Result<_>>()?;
                }
                other => bail!("unknown fault plan key {other:?}"),
            }
        }
        Ok(plan)
    }

    /// Load a plan from a JSON file (the `--faults FILE` path).
    pub fn load(path: &str) -> Result<FaultPlan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading fault plan {path:?}"))?;
        FaultPlan::from_json(&Json::parse(&text)?)
            .with_context(|| format!("parsing fault plan {path:?}"))
    }
}

/// Deterministic bounded-staleness quorum selection for one step.
///
/// `uids` are the alive workers' stable uids in rank order; `stale[r]` is
/// rank r's count of consecutive past exclusions. Returns the rank-aligned
/// participation mask: with `quorum == 0` (off) or `quorum >= P` everyone
/// participates; otherwise ranks stale for ≥ `staleness_bound` steps
/// (bound > 0) are force-included first, then the virtually-fastest
/// remaining ranks fill the quorum. Stable sort + rank tiebreak make the
/// mask a pure function of `(plan, uids, stale, step)` — the determinism
/// contract's replacement for a wall-clock reduce timeout.
pub fn quorum_participants(
    plan: &FaultPlan,
    uids: &[usize],
    stale: &[usize],
    step: usize,
    quorum: usize,
    staleness_bound: usize,
) -> Vec<bool> {
    let p = uids.len();
    if quorum == 0 || quorum >= p {
        return vec![true; p];
    }
    let mut mask = vec![false; p];
    let mut slots = quorum;
    if staleness_bound > 0 {
        for (r, &s) in stale.iter().enumerate() {
            if s >= staleness_bound {
                mask[r] = true;
                slots = slots.saturating_sub(1);
            }
        }
    }
    let mut order: Vec<usize> = (0..p).filter(|&r| !mask[r]).collect();
    order.sort_by(|&a, &b| {
        plan.virtual_step_time(uids[a], step)
            .partial_cmp(&plan.virtual_step_time(uids[b], step))
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    for &r in order.iter().take(slots) {
        mask[r] = true;
    }
    mask
}

/// The compute-pacing multiplier of a synchronous step: the q-th fastest
/// participant's skew gates the step (everyone waits for it). With
/// `quorum == 0` the slowest alive worker gates. Link jitter is excluded
/// here on purpose — the gate feeds the EWMA profile behind Eq. 18
/// reselection and the DES, where a stable per-run value is wanted.
pub fn compute_gate(plan: &FaultPlan, alive_uids: &[usize], quorum: usize) -> f64 {
    if alive_uids.is_empty() {
        return 1.0;
    }
    let mut skews: Vec<f64> = alive_uids.iter().map(|&u| plan.skew_of(u)).collect();
    skews.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let q = if quorum == 0 { skews.len() } else { quorum.min(skews.len()) };
    skews[q - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn skewed_plan() -> FaultPlan {
        FaultPlan {
            seed: 9,
            compute_skew: vec![1.0, 4.0, 1.0],
            alpha_jitter: 0.3,
            bandwidth_jitter: 0.2,
            events: vec![
                MembershipEvent { step: 3, action: MembershipAction::Drop, worker: 1 },
                MembershipEvent { step: 5, action: MembershipAction::Join, worker: 3 },
            ],
        }
    }

    #[test]
    fn json_round_trip_exact() {
        let p = skewed_plan();
        let back = FaultPlan::from_json(&Json::parse(&p.to_json().to_string_pretty()).unwrap())
            .unwrap();
        assert_eq!(p, back);
        // sparse plan files parse with defaults filled in
        let min = FaultPlan::from_json(&Json::parse("{\"seed\": 5}").unwrap()).unwrap();
        assert_eq!(min.seed, 5);
        assert!(min.events.is_empty() && min.compute_skew.is_empty());
        assert!(FaultPlan::from_json(&Json::parse("{\"bogus\": 1}").unwrap()).is_err());
    }

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        let p = skewed_plan();
        for uid in 0..4 {
            for step in 0..20 {
                let (a1, b1) = p.link_jitter(uid, step);
                let (a2, b2) = p.link_jitter(uid, step);
                assert_eq!((a1, b1), (a2, b2), "same (uid, step) must redraw identically");
                assert!((0.7..=1.3).contains(&a1), "alpha mult {a1}");
                assert!((0.8..=1.2).contains(&b1), "bw mult {b1}");
            }
        }
        // distinct (uid, step) pairs draw independent streams
        assert_ne!(p.link_jitter(0, 1), p.link_jitter(1, 0));
        // the healthy plan never perturbs
        assert_eq!(FaultPlan::none().link_jitter(2, 7), (1.0, 1.0));
        assert!(!FaultPlan::none().perturbs_time());
        assert!(p.perturbs_time());
    }

    #[test]
    fn validate_replays_schedule() {
        assert!(skewed_plan().validate(3).is_ok());
        // dropping an absent worker
        let mut p = FaultPlan::none();
        p.events.push(MembershipEvent { step: 0, action: MembershipAction::Drop, worker: 7 });
        assert!(p.validate(3).is_err());
        // emptying the cluster
        let mut p = FaultPlan::none();
        p.events.push(MembershipEvent { step: 0, action: MembershipAction::Drop, worker: 0 });
        assert!(p.validate(1).is_err());
        // double join
        let mut p = FaultPlan::none();
        p.events.push(MembershipEvent { step: 1, action: MembershipAction::Join, worker: 0 });
        assert!(p.validate(2).is_err());
        // jitter range
        let mut p = FaultPlan::none();
        p.alpha_jitter = 1.5;
        assert!(p.validate(2).is_err());
    }

    #[test]
    fn quorum_excludes_the_straggler_and_staleness_forces_it_back() {
        let mut plan = FaultPlan::none();
        plan.compute_skew = vec![1.0, 8.0, 1.0];
        let uids = [0, 1, 2];
        // no jitter: worker 1 is always slowest, always excluded at q=2
        let m = quorum_participants(&plan, &uids, &[0, 0, 0], 0, 2, 0);
        assert_eq!(m, vec![true, false, true]);
        // after 3 consecutive misses with bound 3, it is force-included
        let m = quorum_participants(&plan, &uids, &[0, 3, 0], 7, 2, 3);
        assert!(m[1], "stale worker must be forced back in");
        assert_eq!(m.iter().filter(|&&b| b).count(), 2);
        // quorum off or >= P: everyone participates
        assert_eq!(quorum_participants(&plan, &uids, &[0, 0, 0], 0, 0, 0), vec![true; 3]);
        assert_eq!(quorum_participants(&plan, &uids, &[0, 0, 0], 0, 3, 0), vec![true; 3]);
    }

    #[test]
    fn quorum_tie_breaks_by_rank_deterministically() {
        let plan = FaultPlan::none(); // all virtual times equal
        let m = quorum_participants(&plan, &[0, 1, 2, 3], &[0; 4], 5, 2, 0);
        assert_eq!(m, vec![true, true, false, false]);
    }

    #[test]
    fn compute_gate_is_qth_fastest_skew() {
        let mut plan = FaultPlan::none();
        plan.compute_skew = vec![1.0, 4.0, 2.0];
        let uids = [0, 1, 2];
        assert_eq!(compute_gate(&plan, &uids, 0), 4.0); // full sync: slowest gates
        assert_eq!(compute_gate(&plan, &uids, 2), 2.0); // quorum 2: 2nd fastest
        assert_eq!(compute_gate(&plan, &uids, 1), 1.0);
        assert_eq!(compute_gate(&FaultPlan::none(), &uids, 0), 1.0);
        assert_eq!(compute_gate(&plan, &[], 0), 1.0);
    }
}
