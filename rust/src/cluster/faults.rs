//! Deterministic fault & heterogeneity injection (the "flaky cluster"
//! the paper never tests).
//!
//! A [`FaultPlan`] describes everything that can go wrong with the P
//! logical workers, in a form that is a **pure function of (plan, worker
//! uid, step)**:
//!
//! - **compute skew** — a per-worker multiplicative slowdown (`2.0` = the
//!   worker takes twice the nominal step compute). Constant over the run,
//!   indexed by the worker's stable uid.
//! - **link jitter** — per-(worker, step) multiplicative noise on the
//!   worker's effective α (latency) and bandwidth terms, drawn from the
//!   plan's own seeded [`Rng`] stream. Never sampled from wall-clock or
//!   arrival order, so two runs with the same plan draw identical jitter.
//! - **membership events** — a drop/join schedule keyed by step. Events
//!   fire strictly *between* optimizer steps (at the top of `step()` for
//!   their step index), which is what makes elastic membership compatible
//!   with the bit-identity contract: the parameter state at every step
//!   boundary is a deterministic function of the seed and the plan.
//!
//! The same plan is threaded through the real trainer (quorum selection,
//! straggler sleeps, membership) and the DES (`pipeline::desim`, compute
//! gating + conservative link pricing), so predicted and measured
//! degradation are directly comparable.
//!
//! **Quorum determinism.** The bounded-staleness quorum mode does NOT use
//! reduce timeouts on real clocks — that would make participation depend
//! on scheduler noise. Instead each step's participants are the `q`
//! virtually-fastest alive workers under [`FaultPlan::virtual_step_time`]
//! (skew × jittered link multiplier, ties broken by rank), with workers
//! that have been excluded for `staleness_bound` consecutive steps forced
//! back in. The *wall-clock* effect of straggling is modelled separately
//! (sleeps in the trainer, compute gating in the DES); the *numeric*
//! effect is this pure selection function.

use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};

/// What a membership event does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MembershipAction {
    /// Worker leaves; its error-feedback residual is re-sharded across
    /// the survivors (no gradient mass is lost).
    Drop,
    /// Worker joins with fresh (zero) residual and its own uid-keyed data
    /// shard stream.
    Join,
}

impl MembershipAction {
    pub fn name(&self) -> &'static str {
        match self {
            MembershipAction::Drop => "drop",
            MembershipAction::Join => "join",
        }
    }

    pub fn parse(s: &str) -> Result<MembershipAction> {
        match s {
            "drop" => Ok(MembershipAction::Drop),
            "join" => Ok(MembershipAction::Join),
            other => bail!("unknown membership action {other:?} (want drop|join)"),
        }
    }
}

/// One scheduled membership change. `worker` is the stable uid (the data
/// shard key), not the current rank.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MembershipEvent {
    /// step index at whose start the event fires (before the step's
    /// gradients are computed)
    pub step: usize,
    pub action: MembershipAction,
    pub worker: usize,
}

/// The typed error a `crash@step` event raises: the trainer refuses to
/// run the scheduled step and unwinds, modelling a process death the
/// chaos harness can catch (tests) or turn into a non-zero exit (CLI).
/// Downcast with `err.downcast_ref::<CrashPoint>()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CrashPoint(pub usize);

impl std::fmt::Display for CrashPoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "injected crash at step {}", self.0)
    }
}

impl std::error::Error for CrashPoint {}

/// One step of a recorded execution trace: per-worker-uid measured
/// compute seconds plus the link multipliers that step actually applied.
/// Workers absent that step carry 0.0 compute (replay treats it as
/// nominal). Written by `--record-trace`, consumed by
/// [`FaultPlan::from_trace`].
#[derive(Debug, Clone, PartialEq)]
pub struct TraceStepRecord {
    pub step: usize,
    /// per-uid measured per-worker wall-clock seconds (0.0 = absent)
    pub comp_secs: Vec<f64>,
    /// per-uid α multiplier applied this step (1.0 = no jitter)
    pub alpha_mult: Vec<f64>,
    /// per-uid bandwidth multiplier applied this step
    pub bw_mult: Vec<f64>,
}

/// The full deterministic fault schedule for a run. See the module docs
/// for semantics; [`FaultPlan::none`] is the default healthy cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// seed for the jitter streams (independent of the training seed)
    pub seed: u64,
    /// per-worker-uid multiplicative compute skew; missing entries mean
    /// 1.0 (nominal). Values < 1 model faster-than-nominal workers.
    pub compute_skew: Vec<f64>,
    /// relative α (latency) jitter amplitude in [0, 1): each (worker,
    /// step) draws a multiplier in [1-j, 1+j]
    pub alpha_jitter: f64,
    /// relative bandwidth jitter amplitude in [0, 1), same convention
    pub bandwidth_jitter: f64,
    /// drop/join schedule, applied in listed order within a step
    pub events: Vec<MembershipEvent>,
    /// steps at whose START the process crashes (`crash@step`): the
    /// trainer raises [`CrashPoint`] before computing any gradient, so
    /// the last durable checkpoint is the complete state. A fired crash
    /// is disarmed on resume via a tombstone in the checkpoint dir —
    /// pure schedule data here, no mutable cursor.
    pub crashes: Vec<usize>,
    /// recorded-profile replay: per-step rows of per-uid compute-time
    /// multipliers (median-normalized by [`FaultPlan::from_trace`]).
    /// Row `step % len` paces step `step`, so a short trace cycles over
    /// a longer run. Empty = no trace replay.
    pub trace: Vec<Vec<f64>>,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan::none()
    }
}

impl FaultPlan {
    /// The healthy cluster: no skew, no jitter, no membership changes.
    pub fn none() -> FaultPlan {
        FaultPlan {
            seed: 0,
            compute_skew: Vec::new(),
            alpha_jitter: 0.0,
            bandwidth_jitter: 0.0,
            events: Vec::new(),
            crashes: Vec::new(),
            trace: Vec::new(),
        }
    }

    /// True when the plan injects nothing (the default-config fast path).
    pub fn is_none(&self) -> bool {
        self.events.is_empty() && self.crashes.is_empty() && !self.perturbs_time()
    }

    /// True when the plan perturbs per-worker step time (skew, jitter or
    /// a replayed trace) — the trainer then measures compute wall-clock
    /// every step so the straggler sleeps have a base to scale.
    pub fn perturbs_time(&self) -> bool {
        self.alpha_jitter > 0.0
            || self.bandwidth_jitter > 0.0
            || self.compute_skew.iter().any(|&s| s != 1.0)
            || !self.trace.is_empty()
    }

    /// True when a crash is scheduled at the start of `step`.
    pub fn crash_at(&self, step: usize) -> bool {
        self.crashes.contains(&step)
    }

    /// The configured (synthetic) skew for a worker uid, trace excluded.
    fn base_skew(&self, uid: usize) -> f64 {
        self.compute_skew.get(uid).copied().unwrap_or(1.0)
    }

    /// The replayed trace multiplier for `(uid, step)` — row `step % T`
    /// of the schedule, 1.0 with no trace or for uids beyond the row.
    pub fn trace_multiplier(&self, uid: usize, step: usize) -> f64 {
        if self.trace.is_empty() {
            return 1.0;
        }
        self.trace[step % self.trace.len()].get(uid).copied().unwrap_or(1.0)
    }

    /// Mean trace multiplier for a uid across the schedule (1.0 with no
    /// trace) — the run-level pacing factor of a replayed profile.
    fn trace_mean(&self, uid: usize) -> f64 {
        if self.trace.is_empty() {
            return 1.0;
        }
        let sum: f64 = self.trace.iter().map(|row| row.get(uid).copied().unwrap_or(1.0)).sum();
        sum / self.trace.len() as f64
    }

    /// Run-level compute skew for a worker uid: the configured synthetic
    /// skew × the mean replayed trace multiplier (each 1.0 when absent).
    /// This is the single scalar the compute gate, the DES `skews` and
    /// the telemetry consume, so a replayed trace flows into all three
    /// without any caller changes.
    pub fn skew_of(&self, uid: usize) -> f64 {
        self.base_skew(uid) * self.trace_mean(uid)
    }

    /// Per-(worker, step) link multipliers `(alpha_mult, bandwidth_mult)`,
    /// each in `[1-j, 1+j]` clamped to ≥ 0.05. Pure function of the plan
    /// seed — never of wall-clock.
    pub fn link_jitter(&self, uid: usize, step: usize) -> (f64, f64) {
        if self.alpha_jitter == 0.0 && self.bandwidth_jitter == 0.0 {
            return (1.0, 1.0);
        }
        let stream = (uid as u64) << 32 | (step as u64 & 0xffff_ffff);
        let mut r = Rng::new(self.seed).fork(stream);
        let a = (1.0 + self.alpha_jitter * (2.0 * r.uniform() - 1.0)).max(0.05);
        let b = (1.0 + self.bandwidth_jitter * (2.0 * r.uniform() - 1.0)).max(0.05);
        (a, b)
    }

    /// Relative virtual duration of worker `uid`'s step `step`: compute
    /// skew × this step's replayed trace multiplier × jittered link
    /// slowdown (a slow link delays the worker's messages just like slow
    /// compute does). This is the quantity the quorum ranks workers by.
    pub fn virtual_step_time(&self, uid: usize, step: usize) -> f64 {
        let (a, b) = self.link_jitter(uid, step);
        // α grows link time multiplicatively; bandwidth shrinks it
        self.base_skew(uid) * self.trace_multiplier(uid, step) * a / b
    }

    /// Events scheduled for `step`, in listed order.
    pub fn events_at(&self, step: usize) -> impl Iterator<Item = &MembershipEvent> {
        self.events.iter().filter(move |e| e.step == step)
    }

    /// Check internal consistency against a starting worker count:
    /// replays the schedule and rejects drops of absent workers, joins of
    /// present workers, and schedules that empty the cluster.
    pub fn validate(&self, start_workers: usize) -> Result<()> {
        if !(0.0..1.0).contains(&self.alpha_jitter) {
            bail!("alpha_jitter must be in [0, 1), got {}", self.alpha_jitter);
        }
        if !(0.0..1.0).contains(&self.bandwidth_jitter) {
            bail!("bandwidth_jitter must be in [0, 1), got {}", self.bandwidth_jitter);
        }
        if let Some(s) = self.compute_skew.iter().find(|s| !s.is_finite() || **s <= 0.0) {
            bail!("compute_skew entries must be finite and > 0, got {s}");
        }
        for (i, row) in self.trace.iter().enumerate() {
            if let Some(m) = row.iter().find(|m| !m.is_finite() || **m <= 0.0) {
                bail!("trace row {i}: multipliers must be finite and > 0, got {m}");
            }
        }
        let mut alive: Vec<usize> = (0..start_workers).collect();
        let mut sorted = self.events.clone();
        // replay in (step, listed) order — stable sort keeps the intra-step
        // order the trainer will apply
        sorted.sort_by_key(|e| e.step);
        for ev in &sorted {
            match ev.action {
                MembershipAction::Drop => {
                    let Some(pos) = alive.iter().position(|&u| u == ev.worker) else {
                        bail!("step {}: drop of absent worker {}", ev.step, ev.worker);
                    };
                    if alive.len() == 1 {
                        bail!("step {}: schedule would drop the last worker", ev.step);
                    }
                    alive.remove(pos);
                }
                MembershipAction::Join => {
                    if alive.contains(&ev.worker) {
                        bail!("step {}: join of already-present worker {}", ev.step, ev.worker);
                    }
                    alive.push(ev.worker);
                }
            }
        }
        Ok(())
    }

    // ---- JSON ------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::Num(self.seed as f64)),
            ("compute_skew", Json::arr_f64(&self.compute_skew)),
            ("alpha_jitter", Json::Num(self.alpha_jitter)),
            ("bandwidth_jitter", Json::Num(self.bandwidth_jitter)),
            (
                "events",
                Json::Arr(
                    self.events
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("step", Json::Num(e.step as f64)),
                                ("action", Json::Str(e.action.name().into())),
                                ("worker", Json::Num(e.worker as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "crashes",
                Json::Arr(self.crashes.iter().map(|&s| Json::Num(s as f64)).collect()),
            ),
            ("trace", Json::Arr(self.trace.iter().map(|row| Json::arr_f64(row)).collect())),
        ])
    }

    /// Parse a plan object. Missing keys default to the healthy values so
    /// a plan file only needs the faults it injects; unknown keys are
    /// rejected (same contract as `TrainConfig::apply_json`).
    pub fn from_json(v: &Json) -> Result<FaultPlan> {
        let obj = v.as_obj().context("fault plan must be a JSON object")?;
        let mut plan = FaultPlan::none();
        for (key, val) in obj {
            match key.as_str() {
                "seed" => plan.seed = val.as_usize()? as u64,
                "compute_skew" => {
                    plan.compute_skew =
                        val.as_arr()?.iter().map(Json::as_f64).collect::<Result<_>>()?;
                }
                "alpha_jitter" => plan.alpha_jitter = val.as_f64()?,
                "bandwidth_jitter" => plan.bandwidth_jitter = val.as_f64()?,
                "events" => {
                    plan.events = val
                        .as_arr()?
                        .iter()
                        .map(|e| {
                            Ok(MembershipEvent {
                                step: e.get("step")?.as_usize()?,
                                action: MembershipAction::parse(e.get("action")?.as_str()?)?,
                                worker: e.get("worker")?.as_usize()?,
                            })
                        })
                        .collect::<Result<_>>()?;
                }
                "crashes" => {
                    plan.crashes =
                        val.as_arr()?.iter().map(Json::as_usize).collect::<Result<_>>()?;
                }
                "trace" => {
                    plan.trace = val
                        .as_arr()?
                        .iter()
                        .map(|row| row.as_arr()?.iter().map(Json::as_f64).collect::<Result<_>>())
                        .collect::<Result<_>>()?;
                }
                other => bail!("unknown fault plan key {other:?}"),
            }
        }
        Ok(plan)
    }

    /// Load a plan from a JSON file (the `--faults FILE` path) and
    /// validate it against the configured starting worker count
    /// immediately — a malformed schedule fails HERE with its file and
    /// the offending event's step, not at first use mid-run.
    pub fn load(path: &str, start_workers: usize) -> Result<FaultPlan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading fault plan {path:?}"))?;
        let plan = FaultPlan::from_json(&Json::parse(&text)?)
            .with_context(|| format!("parsing fault plan {path:?}"))?;
        plan.validate(start_workers).with_context(|| {
            format!("invalid fault plan {path:?} (at {start_workers} starting workers)")
        })?;
        Ok(plan)
    }

    /// Build a replay plan from a `--record-trace` file: each recorded
    /// step's per-uid compute seconds become multipliers normalized by
    /// the row's median positive entry (the median worker replays at
    /// 1.0, stragglers replay their measured relative slowdown). Entries
    /// ≤ 0 mark workers absent that step and replay as nominal.
    pub fn from_trace(path: &str) -> Result<FaultPlan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {path:?}"))?;
        let v = Json::parse(&text).with_context(|| format!("parsing trace {path:?}"))?;
        let kind = v.get("kind")?.as_str()?;
        if kind != TRACE_KIND {
            bail!("{path:?} is not a recorded trace (kind {kind:?}, want {TRACE_KIND:?})");
        }
        let rows: Vec<Vec<f64>> = v
            .get("steps")?
            .as_arr()?
            .iter()
            .map(|s| s.get("comp_secs")?.as_arr()?.iter().map(Json::as_f64).collect())
            .collect::<Result<_>>()?;
        FaultPlan::from_trace_rows(&rows).with_context(|| format!("normalizing trace {path:?}"))
    }

    /// [`FaultPlan::from_trace`] over in-memory rows of per-uid seconds.
    pub fn from_trace_rows(rows: &[Vec<f64>]) -> Result<FaultPlan> {
        if rows.is_empty() {
            bail!("trace has no recorded steps");
        }
        let trace = rows
            .iter()
            .map(|row| {
                let mut pos: Vec<f64> = row.iter().copied().filter(|&s| s > 0.0).collect();
                if pos.is_empty() {
                    return vec![1.0; row.len()];
                }
                pos.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
                let median = pos[pos.len() / 2];
                row.iter()
                    .map(|&s| if s > 0.0 { (s / median).max(0.05) } else { 1.0 })
                    .collect()
            })
            .collect();
        Ok(FaultPlan { trace, ..FaultPlan::none() })
    }
}

/// Schema tag of a `--record-trace` file.
pub const TRACE_KIND: &str = "lags-trace";

/// Serialize a recorded execution trace (the `--record-trace` artifact):
///
/// ```json
/// {"kind": "lags-trace", "version": 1, "model": "...", "workers": P,
///  "steps": [{"step": 0, "comp_secs": [...], "alpha_mult": [...],
///             "bw_mult": [...]}, ...]}
/// ```
///
/// Arrays are indexed by stable worker uid; absent workers carry 0.0
/// compute. [`FaultPlan::from_trace`] consumes `comp_secs`; the link
/// multipliers document what the recorded run's plan applied.
pub fn trace_to_json(model: &str, workers: usize, rows: &[TraceStepRecord]) -> Json {
    Json::obj(vec![
        ("kind", Json::Str(TRACE_KIND.into())),
        ("version", Json::Num(1.0)),
        ("model", Json::Str(model.into())),
        ("workers", Json::Num(workers as f64)),
        (
            "steps",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("step", Json::Num(r.step as f64)),
                            ("comp_secs", Json::arr_f64(&r.comp_secs)),
                            ("alpha_mult", Json::arr_f64(&r.alpha_mult)),
                            ("bw_mult", Json::arr_f64(&r.bw_mult)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Deterministic bounded-staleness quorum selection for one step.
///
/// `uids` are the alive workers' stable uids in rank order; `stale[r]` is
/// rank r's count of consecutive past exclusions. Returns the rank-aligned
/// participation mask: with `quorum == 0` (off) or `quorum >= P` everyone
/// participates; otherwise ranks stale for ≥ `staleness_bound` steps
/// (bound > 0) are force-included first, then the virtually-fastest
/// remaining ranks fill the quorum. Stable sort + rank tiebreak make the
/// mask a pure function of `(plan, uids, stale, step)` — the determinism
/// contract's replacement for a wall-clock reduce timeout.
pub fn quorum_participants(
    plan: &FaultPlan,
    uids: &[usize],
    stale: &[usize],
    step: usize,
    quorum: usize,
    staleness_bound: usize,
) -> Vec<bool> {
    let p = uids.len();
    if quorum == 0 || quorum >= p {
        return vec![true; p];
    }
    let mut mask = vec![false; p];
    let mut slots = quorum;
    if staleness_bound > 0 {
        for (r, &s) in stale.iter().enumerate() {
            if s >= staleness_bound {
                mask[r] = true;
                slots = slots.saturating_sub(1);
            }
        }
    }
    let mut order: Vec<usize> = (0..p).filter(|&r| !mask[r]).collect();
    order.sort_by(|&a, &b| {
        plan.virtual_step_time(uids[a], step)
            .partial_cmp(&plan.virtual_step_time(uids[b], step))
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    for &r in order.iter().take(slots) {
        mask[r] = true;
    }
    mask
}

/// The compute-pacing multiplier of a synchronous step: the q-th fastest
/// participant's skew gates the step (everyone waits for it). With
/// `quorum == 0` the slowest alive worker gates. Link jitter is excluded
/// here on purpose — the gate feeds the EWMA profile behind Eq. 18
/// reselection and the DES, where a stable per-run value is wanted.
pub fn compute_gate(plan: &FaultPlan, alive_uids: &[usize], quorum: usize) -> f64 {
    if alive_uids.is_empty() {
        return 1.0;
    }
    let mut skews: Vec<f64> = alive_uids.iter().map(|&u| plan.skew_of(u)).collect();
    skews.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let q = if quorum == 0 { skews.len() } else { quorum.min(skews.len()) };
    skews[q - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn skewed_plan() -> FaultPlan {
        FaultPlan {
            seed: 9,
            compute_skew: vec![1.0, 4.0, 1.0],
            alpha_jitter: 0.3,
            bandwidth_jitter: 0.2,
            events: vec![
                MembershipEvent { step: 3, action: MembershipAction::Drop, worker: 1 },
                MembershipEvent { step: 5, action: MembershipAction::Join, worker: 3 },
            ],
            crashes: vec![4],
            trace: vec![vec![1.0, 1.5, 0.5], vec![2.0, 1.0, 1.0]],
        }
    }

    #[test]
    fn json_round_trip_exact() {
        let p = skewed_plan();
        let back = FaultPlan::from_json(&Json::parse(&p.to_json().to_string_pretty()).unwrap())
            .unwrap();
        assert_eq!(p, back);
        // sparse plan files parse with defaults filled in
        let min = FaultPlan::from_json(&Json::parse("{\"seed\": 5}").unwrap()).unwrap();
        assert_eq!(min.seed, 5);
        assert!(min.events.is_empty() && min.compute_skew.is_empty());
        assert!(FaultPlan::from_json(&Json::parse("{\"bogus\": 1}").unwrap()).is_err());
    }

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        let p = skewed_plan();
        for uid in 0..4 {
            for step in 0..20 {
                let (a1, b1) = p.link_jitter(uid, step);
                let (a2, b2) = p.link_jitter(uid, step);
                assert_eq!((a1, b1), (a2, b2), "same (uid, step) must redraw identically");
                assert!((0.7..=1.3).contains(&a1), "alpha mult {a1}");
                assert!((0.8..=1.2).contains(&b1), "bw mult {b1}");
            }
        }
        // distinct (uid, step) pairs draw independent streams
        assert_ne!(p.link_jitter(0, 1), p.link_jitter(1, 0));
        // the healthy plan never perturbs
        assert_eq!(FaultPlan::none().link_jitter(2, 7), (1.0, 1.0));
        assert!(!FaultPlan::none().perturbs_time());
        assert!(p.perturbs_time());
    }

    #[test]
    fn validate_replays_schedule() {
        assert!(skewed_plan().validate(3).is_ok());
        // dropping an absent worker
        let mut p = FaultPlan::none();
        p.events.push(MembershipEvent { step: 0, action: MembershipAction::Drop, worker: 7 });
        assert!(p.validate(3).is_err());
        // emptying the cluster
        let mut p = FaultPlan::none();
        p.events.push(MembershipEvent { step: 0, action: MembershipAction::Drop, worker: 0 });
        assert!(p.validate(1).is_err());
        // double join
        let mut p = FaultPlan::none();
        p.events.push(MembershipEvent { step: 1, action: MembershipAction::Join, worker: 0 });
        assert!(p.validate(2).is_err());
        // jitter range
        let mut p = FaultPlan::none();
        p.alpha_jitter = 1.5;
        assert!(p.validate(2).is_err());
    }

    #[test]
    fn crash_schedule_and_trace_fields() {
        let p = skewed_plan();
        assert!(p.crash_at(4));
        assert!(!p.crash_at(3));
        assert!(!FaultPlan::none().crash_at(4));
        // a crashes-only plan is not "none" and must round-trip
        let mut c = FaultPlan::none();
        c.crashes = vec![7];
        assert!(!c.is_none());
        let back =
            FaultPlan::from_json(&Json::parse(&c.to_json().to_string_compact()).unwrap()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn trace_multipliers_pace_virtual_time_and_cycle() {
        let p = skewed_plan(); // trace rows: [1.0, 1.5, 0.5], [2.0, 1.0, 1.0]
        assert_eq!(p.trace_multiplier(1, 0), 1.5);
        assert_eq!(p.trace_multiplier(0, 1), 2.0);
        // a short trace cycles: step 2 re-reads row 0
        assert_eq!(p.trace_multiplier(1, 2), 1.5);
        // uids beyond the row fall back to nominal
        assert_eq!(p.trace_multiplier(9, 0), 1.0);
        assert_eq!(FaultPlan::none().trace_multiplier(0, 0), 1.0);
        // virtual step time scales by base skew × the step's multiplier
        let mut t = FaultPlan::none();
        t.compute_skew = vec![2.0];
        t.trace = vec![vec![3.0], vec![1.0]];
        let base = FaultPlan::none().virtual_step_time(0, 0);
        assert_eq!(t.virtual_step_time(0, 0), 6.0 * base);
        assert_eq!(t.virtual_step_time(0, 1), 2.0 * base);
        // skew_of folds the trace mean, so the DES and telemetry see the
        // recorded profile's average pace
        assert_eq!(t.skew_of(0), 2.0 * 2.0);
        // a trace alone perturbs time (gates the Instant::now probes)
        let mut only = FaultPlan::none();
        only.trace = vec![vec![1.0]];
        assert!(only.perturbs_time());
    }

    #[test]
    fn from_trace_rows_normalizes_by_median() {
        // rows of measured seconds → multipliers around a median of 1
        let p = FaultPlan::from_trace_rows(&[
            vec![0.010, 0.020, 0.040],
            vec![0.010, 0.010, 0.0], // 0.0 = absent worker → nominal
        ])
        .unwrap();
        assert_eq!(p.trace.len(), 2);
        assert_eq!(p.trace[0], vec![0.5, 1.0, 2.0]);
        assert_eq!(p.trace[1][2], 1.0, "absent workers replay at nominal pace");
        // multipliers are floored so one tiny sample cannot zero a worker
        let p = FaultPlan::from_trace_rows(&[vec![1e-9, 1.0]]).unwrap();
        assert!(p.trace[0][0] >= 0.05);
        assert!(FaultPlan::from_trace_rows(&[]).is_err());
        // the result passes its own validation
        FaultPlan::from_trace_rows(&[vec![0.01, 0.02]]).unwrap().validate(2).unwrap();
    }

    #[test]
    fn load_validates_against_start_workers_and_names_the_event() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("lags-bad-plan-{}.json", std::process::id()));
        // structurally valid JSON, but the schedule drops an absent worker
        std::fs::write(
            &path,
            r#"{"seed": 1, "events": [{"step": 6, "action": "drop", "worker": 9}]}"#,
        )
        .unwrap();
        let err = FaultPlan::load(path.to_str().unwrap(), 3).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("invalid fault plan"), "missing load context: {msg}");
        assert!(msg.contains("3 starting workers"), "missing worker count: {msg}");
        assert!(
            msg.contains("step 6") && msg.contains('9'),
            "must name the offending event and its step: {msg}"
        );
        // a healthy plan at a sufficient worker count loads fine
        std::fs::write(&path, r#"{"seed": 1, "compute_skew": [1.0, 2.0]}"#).unwrap();
        let ok = FaultPlan::load(path.to_str().unwrap(), 2).unwrap();
        assert_eq!(ok.compute_skew, vec![1.0, 2.0]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn trace_file_round_trips_through_from_trace() {
        let rows = vec![
            TraceStepRecord {
                step: 0,
                comp_secs: vec![0.010, 0.020],
                alpha_mult: vec![1.0, 1.1],
                bw_mult: vec![1.0, 0.9],
            },
            TraceStepRecord {
                step: 1,
                comp_secs: vec![0.010, 0.010],
                alpha_mult: vec![1.0, 1.0],
                bw_mult: vec![1.0, 1.0],
            },
        ];
        let doc = trace_to_json("mlp", 2, &rows);
        assert_eq!(doc.get("kind").unwrap().as_str().unwrap(), TRACE_KIND);
        let dir = std::env::temp_dir();
        let path = dir.join(format!("lags-trace-{}.json", std::process::id()));
        std::fs::write(&path, doc.to_string_pretty()).unwrap();
        let p = FaultPlan::from_trace(path.to_str().unwrap()).unwrap();
        assert_eq!(p.trace.len(), 2);
        // even-length row: the upper-middle sample (0.020) is the median
        assert_eq!(p.trace[0], vec![0.5, 1.0]);
        // a non-trace JSON file is refused with the kind named
        std::fs::write(&path, r#"{"kind": "other", "steps": []}"#).unwrap();
        let err = format!("{:#}", FaultPlan::from_trace(path.to_str().unwrap()).unwrap_err());
        assert!(err.contains(TRACE_KIND), "error must name the expected kind: {err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn quorum_excludes_the_straggler_and_staleness_forces_it_back() {
        let mut plan = FaultPlan::none();
        plan.compute_skew = vec![1.0, 8.0, 1.0];
        let uids = [0, 1, 2];
        // no jitter: worker 1 is always slowest, always excluded at q=2
        let m = quorum_participants(&plan, &uids, &[0, 0, 0], 0, 2, 0);
        assert_eq!(m, vec![true, false, true]);
        // after 3 consecutive misses with bound 3, it is force-included
        let m = quorum_participants(&plan, &uids, &[0, 3, 0], 7, 2, 3);
        assert!(m[1], "stale worker must be forced back in");
        assert_eq!(m.iter().filter(|&&b| b).count(), 2);
        // quorum off or >= P: everyone participates
        assert_eq!(quorum_participants(&plan, &uids, &[0, 0, 0], 0, 0, 0), vec![true; 3]);
        assert_eq!(quorum_participants(&plan, &uids, &[0, 0, 0], 0, 3, 0), vec![true; 3]);
    }

    #[test]
    fn quorum_tie_breaks_by_rank_deterministically() {
        let plan = FaultPlan::none(); // all virtual times equal
        let m = quorum_participants(&plan, &[0, 1, 2, 3], &[0; 4], 5, 2, 0);
        assert_eq!(m, vec![true, true, false, false]);
    }

    #[test]
    fn compute_gate_is_qth_fastest_skew() {
        let mut plan = FaultPlan::none();
        plan.compute_skew = vec![1.0, 4.0, 2.0];
        let uids = [0, 1, 2];
        assert_eq!(compute_gate(&plan, &uids, 0), 4.0); // full sync: slowest gates
        assert_eq!(compute_gate(&plan, &uids, 2), 2.0); // quorum 2: 2nd fastest
        assert_eq!(compute_gate(&plan, &uids, 1), 1.0);
        assert_eq!(compute_gate(&FaultPlan::none(), &uids, 0), 1.0);
        assert_eq!(compute_gate(&plan, &[], 0), 1.0);
    }
}
