//! Logical worker pool.
//!
//! The paper's testbed is 16 physical GPU nodes; here the P data-parallel
//! workers are *logical* replicas sharing one PJRT CPU device. Each worker
//! owns exactly the state a physical worker would: its data shard (a PRNG
//! stream), its error-feedback residuals, and its scratch buffers. The
//! arithmetic each worker performs is therefore identical to a physical
//! deployment; only the wall-clock comes from the DES instead of a real
//! NIC (DESIGN.md §Hardware-Adaptation).

use crate::collectives::pipeline::LayerMsg;
use crate::runtime::native::{CompressScratch, GradScratch};
use crate::sparsify::{ErrorFeedback, SparseVec};
use std::sync::mpsc::Sender;
use std::time::Instant;

/// Per-replica state.
///
/// The worker is the unit of parallelism in the trainer hot loop: gradient
/// compute, momentum correction and error-feedback compression all operate
/// on state owned here, so the P workers can run on separate threads with
/// no shared mutable aggregation inside the parallel region (the
/// rank-ordered reduction over `msgs` happens afterwards, sequentially).
pub struct Worker {
    pub id: usize,
    /// error-feedback residuals over the flat parameter vector
    pub ef: ErrorFeedback,
    /// scratch: last computed gradient (flat)
    pub grad: Vec<f32>,
    /// scratch: per-layer outgoing sparse messages (LAGS wire format,
    /// indices local to the layer slice); buffers reused across steps
    pub msgs: Vec<SparseVec>,
    /// scratch: whole-flat-vector sparse message (SLGS wire format)
    pub msg_flat: SparseVec,
    /// local momentum u_t for momentum correction (Lin et al. 2018);
    /// allocated lazily on first use
    pub local_mom: Vec<f32>,
    /// last training loss this worker observed
    pub last_loss: f32,
    /// scratch for the native backward pass (activations, δ buffers, the
    /// per-layer Wᵀ cache, im2col col/dcol matrices and BPTT carry rows)
    /// — reused across steps, one set per worker so conv/recurrent
    /// models fan out with no shared mutable state
    pub grad_scratch: GradScratch,
    /// scratch for the bucket-padded compress path (`CompressorKind::Xla*`
    /// host emulation): accumulator + selection buffers
    pub compress_scratch: CompressScratch,
    /// scratch: this step's per-layer compression wall-clock (s), written
    /// only when the trainer's online adaptive measurement is active
    /// (`adaptive::online`); manifest order, sized with the message
    /// scratch
    pub compress_secs: Vec<f64>,
}

impl Worker {
    /// Momentum correction (Lin et al. 2018): u ← mu·u + grad, then the
    /// corrected gradient u replaces grad as the sparsification input.
    pub fn fold_local_momentum(&mut self, mu: f32) {
        if self.local_mom.is_empty() {
            self.local_mom = vec![0.0; self.grad.len()];
        }
        for (u, g) in self.local_mom.iter_mut().zip(self.grad.iter_mut()) {
            *u = mu * *u + *g;
            *g = *u;
        }
    }
}

impl Worker {
    pub fn new(id: usize, d: usize, sample_stride: usize) -> Worker {
        Worker {
            id,
            ef: ErrorFeedback::new(d, sample_stride),
            grad: vec![0.0; d],
            msgs: Vec::new(),
            msg_flat: SparseVec::new(d),
            local_mom: Vec::new(),
            last_loss: f32::NAN,
            grad_scratch: GradScratch::default(),
            compress_scratch: CompressScratch::default(),
            compress_secs: Vec::new(),
        }
    }

    /// Publish layer `li`'s freshly compressed message into the streaming
    /// sink, stamping production time (the overlap accounting's notion of
    /// "compute was still running here"). The buffer is moved out and
    /// cycles back via the trainer's post-phase reclaim, so steady-state
    /// capacity is preserved and the hot loop stays allocation-free.
    pub fn publish_layer(&mut self, li: usize, sink: &Sender<LayerMsg>) {
        let msg = std::mem::take(&mut self.msgs[li]);
        // send can only fail if the aggregator died, in which case the
        // executor surfaces that error; dropping the message here is fine
        let _ = sink.send(LayerMsg { rank: self.id, layer: li, msg, sent: Instant::now() });
    }

    /// SLGS variant: publish the whole-flat-vector message as layer 0 of a
    /// single-layer stream.
    pub fn publish_flat(&mut self, sink: &Sender<LayerMsg>) {
        let msg = std::mem::take(&mut self.msg_flat);
        let _ = sink.send(LayerMsg { rank: self.id, layer: 0, msg, sent: Instant::now() });
    }

    /// Size the per-layer message scratch for a model's layer table. Called
    /// once by the trainer; after the first step the message buffers reach
    /// their steady-state capacity and the hot loop stops allocating.
    pub fn ensure_message_scratch(&mut self, layer_sizes: &[usize]) {
        self.msgs = layer_sizes.iter().map(|&n| SparseVec::new(n)).collect();
        self.compress_secs = vec![0.0; layer_sizes.len()];
    }
}

/// The worker pool.
pub struct Cluster {
    pub workers: Vec<Worker>,
}

impl Cluster {
    pub fn new(p: usize, d: usize, sample_stride: usize) -> Cluster {
        Cluster { workers: (0..p).map(|i| Worker::new(i, d, sample_stride)).collect() }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Mean of the workers' last losses (the logged training loss).
    pub fn mean_loss(&self) -> f64 {
        let s: f64 = self.workers.iter().map(|w| w.last_loss as f64).sum();
        s / self.workers.len() as f64
    }

    /// Total residual mass across workers (diagnostic).
    pub fn total_residual_norm_sq(&self) -> f64 {
        self.workers.iter().map(|w| w.ef.residual_norm_sq()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction() {
        let c = Cluster::new(4, 100, 16);
        assert_eq!(c.size(), 4);
        assert_eq!(c.workers[3].id, 3);
        assert_eq!(c.workers[0].ef.dim(), 100);
        assert_eq!(c.workers[0].msg_flat.len, 100);
        assert_eq!(c.total_residual_norm_sq(), 0.0);
    }

    #[test]
    fn message_scratch_sized_per_layer() {
        let mut c = Cluster::new(2, 100, 16);
        for w in &mut c.workers {
            w.ensure_message_scratch(&[40, 60]);
        }
        assert_eq!(c.workers[1].msgs.len(), 2);
        assert_eq!(c.workers[1].msgs[0].len, 40);
        assert_eq!(c.workers[1].msgs[1].len, 60);
        assert_eq!(c.workers[1].msgs[1].nnz(), 0);
    }

    #[test]
    fn publish_moves_message_and_stamps_rank() {
        use std::sync::mpsc;
        let mut c = Cluster::new(2, 10, 1);
        for w in &mut c.workers {
            w.ensure_message_scratch(&[4, 6]);
        }
        let (tx, rx) = mpsc::channel();
        c.workers[1].msgs[0].len = 4;
        c.workers[1].msgs[0].idx.push(2);
        c.workers[1].msgs[0].val.push(1.5);
        c.workers[1].publish_layer(0, &tx);
        c.workers[0].publish_flat(&tx);
        drop(tx);
        let m1 = rx.recv().unwrap();
        assert_eq!((m1.rank, m1.layer, m1.msg.nnz()), (1, 0, 1));
        let m2 = rx.recv().unwrap();
        assert_eq!((m2.rank, m2.layer, m2.msg.len), (0, 0, 10));
        // the buffer was moved out (capacity cycles back via reclaim)
        assert_eq!(c.workers[1].msgs[0].len, 0);
    }

    #[test]
    fn mean_loss() {
        let mut c = Cluster::new(2, 10, 1);
        c.workers[0].last_loss = 1.0;
        c.workers[1].last_loss = 3.0;
        assert_eq!(c.mean_loss(), 2.0);
    }
}
