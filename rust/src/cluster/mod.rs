//! Logical worker pool.
//!
//! The paper's testbed is 16 physical GPU nodes; here the P data-parallel
//! workers are *logical* replicas sharing one PJRT CPU device. Each worker
//! owns exactly the state a physical worker would: its data shard (a PRNG
//! stream), its error-feedback residuals, and its scratch buffers. The
//! arithmetic each worker performs is therefore identical to a physical
//! deployment; only the wall-clock comes from the DES instead of a real
//! NIC (DESIGN.md §Hardware-Adaptation).

pub mod faults;

use crate::collectives::pipeline::LayerMsg;
use crate::runtime::native::{CompressScratch, GradScratch};
use crate::sparsify::{Compressor, CompressorKind, ErrorFeedback, SparseVec};
use crate::util::clock;
use anyhow::{ensure, Result};
use std::sync::mpsc::Sender;

/// Per-replica state.
///
/// The worker is the unit of parallelism in the trainer hot loop: gradient
/// compute, momentum correction and error-feedback compression all operate
/// on state owned here, so the P workers can run on separate threads with
/// no shared mutable aggregation inside the parallel region (the
/// rank-ordered reduction over `msgs` happens afterwards, sequentially).
pub struct Worker {
    /// stable uid — the data-shard PRNG key. Under elastic membership a
    /// worker's uid never changes even as its rank (index in
    /// `Cluster::workers`) shifts, so its shard stream stays deterministic
    /// across drops/joins of *other* workers.
    pub id: usize,
    /// error-feedback residuals over the flat parameter vector
    pub ef: ErrorFeedback,
    /// this worker's sparsification scheme (DESIGN.md §Compressor zoo);
    /// owns its scratch, draws randomness only from per-call
    /// `(seed, uid, step, layer)` streams, so it needs no checkpoint state
    pub comp: Box<dyn Compressor>,
    /// scratch: last computed gradient (flat)
    pub grad: Vec<f32>,
    /// scratch: per-layer outgoing sparse messages (LAGS wire format,
    /// indices local to the layer slice); buffers reused across steps
    pub msgs: Vec<SparseVec>,
    /// scratch: whole-flat-vector sparse message (SLGS wire format)
    pub msg_flat: SparseVec,
    /// local momentum u_t for momentum correction (Lin et al. 2018);
    /// allocated lazily on first use
    pub local_mom: Vec<f32>,
    /// last training loss this worker observed
    pub last_loss: f32,
    /// scratch for the native backward pass (activations, δ buffers, the
    /// per-layer Wᵀ cache, im2col col/dcol matrices and BPTT carry rows)
    /// — reused across steps, one set per worker so conv/recurrent
    /// models fan out with no shared mutable state
    pub grad_scratch: GradScratch,
    /// scratch for the bucket-padded compress path (`CompressorKind::Xla*`
    /// host emulation): accumulator + selection buffers
    pub compress_scratch: CompressScratch,
    /// scratch: this step's per-layer compression wall-clock (s), written
    /// only when the trainer's online adaptive measurement is active
    /// (`adaptive::online`); manifest order, sized with the message
    /// scratch
    pub compress_secs: Vec<f64>,
    /// consecutive steps this worker was excluded by the bounded-staleness
    /// quorum (`cluster::faults::quorum_participants`); travels with the
    /// worker through membership changes because it lives here, not in a
    /// rank-indexed array
    pub quorum_stale: usize,
    /// scratch: this step's measured whole-phase wall-clock (s) for this
    /// worker — compression plus any injected straggler sleep — written
    /// only while `--record-trace` is capturing an execution trace
    pub step_secs: f64,
}

impl Worker {
    /// Momentum correction (Lin et al. 2018): u ← mu·u + grad, then the
    /// corrected gradient u replaces grad as the sparsification input.
    pub fn fold_local_momentum(&mut self, mu: f32) {
        if self.local_mom.is_empty() {
            self.local_mom = vec![0.0; self.grad.len()];
        }
        for (u, g) in self.local_mom.iter_mut().zip(self.grad.iter_mut()) {
            *u = mu * *u + *g;
            *g = *u;
        }
    }
}

impl Worker {
    pub fn new(id: usize, d: usize, sample_stride: usize, kind: CompressorKind) -> Worker {
        Worker {
            id,
            ef: ErrorFeedback::new(d, sample_stride),
            comp: kind.build(sample_stride),
            grad: vec![0.0; d],
            msgs: Vec::new(),
            msg_flat: SparseVec::new(d),
            local_mom: Vec::new(),
            last_loss: f32::NAN,
            grad_scratch: GradScratch::default(),
            compress_scratch: CompressScratch::default(),
            compress_secs: Vec::new(),
            quorum_stale: 0,
            step_secs: 0.0,
        }
    }

    /// Publish layer `li`'s freshly compressed message into the streaming
    /// sink, stamping production time (the overlap accounting's notion of
    /// "compute was still running here"). The buffer is moved out and
    /// cycles back via the trainer's post-phase reclaim, so steady-state
    /// capacity is preserved and the hot loop stays allocation-free.
    /// `rank` is the worker's current POSITION in the pool (the executor's
    /// item index), which under elastic membership can differ from `id` —
    /// the aggregator's slots are positional.
    pub fn publish_layer(&mut self, rank: usize, li: usize, sink: &Sender<LayerMsg>) {
        let msg = std::mem::take(&mut self.msgs[li]);
        // send can only fail if the aggregator died, in which case the
        // executor surfaces that error; dropping the message here is fine
        let _ = sink.send(LayerMsg { rank, layer: li, msg, sent: clock::now() });
    }

    /// SLGS variant: publish the whole-flat-vector message as layer 0 of a
    /// single-layer stream.
    pub fn publish_flat(&mut self, rank: usize, sink: &Sender<LayerMsg>) {
        let msg = std::mem::take(&mut self.msg_flat);
        let _ = sink.send(LayerMsg { rank, layer: 0, msg, sent: clock::now() });
    }

    /// Size the per-layer message scratch for a model's layer table. Called
    /// once by the trainer; after the first step the message buffers reach
    /// their steady-state capacity and the hot loop stops allocating.
    pub fn ensure_message_scratch(&mut self, layer_sizes: &[usize]) {
        self.msgs = layer_sizes.iter().map(|&n| SparseVec::new(n)).collect();
        self.compress_secs = vec![0.0; layer_sizes.len()];
    }
}

/// The worker pool.
pub struct Cluster {
    pub workers: Vec<Worker>,
}

impl Cluster {
    pub fn new(p: usize, d: usize, sample_stride: usize, kind: CompressorKind) -> Cluster {
        Cluster { workers: (0..p).map(|i| Worker::new(i, d, sample_stride, kind)).collect() }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Mean of the workers' last losses (the logged training loss).
    pub fn mean_loss(&self) -> f64 {
        let s: f64 = self.workers.iter().map(|w| w.last_loss as f64).sum();
        s / self.workers.len() as f64
    }

    /// Total residual mass across workers (diagnostic).
    pub fn total_residual_norm_sq(&self) -> f64 {
        self.workers.iter().map(|w| w.ef.residual_norm_sq()).sum()
    }

    /// Per-coordinate sum of every worker's error-feedback residual, in
    /// f64 — the quantity elastic re-sharding conserves (the deferred
    /// gradient mass that the EF convergence argument, arxiv 1809.10505,
    /// requires to eventually reach the parameters).
    pub fn residual_coordinate_sums(&self) -> Vec<f64> {
        let d = self.workers.first().map(|w| w.ef.dim()).unwrap_or(0);
        let mut sums = vec![0.0f64; d];
        for w in &self.workers {
            for (s, &r) in sums.iter_mut().zip(w.ef.residual()) {
                *s += r as f64;
            }
        }
        sums
    }

    /// Remove the worker with stable uid `uid`, re-sharding its
    /// error-feedback residual across the survivors: coordinate `i`'s mass
    /// moves **wholesale** to survivor `i % P_new` (coordinate-interleaved
    /// for balance). Values are added, never scaled by 1/P, so each
    /// coordinate's cluster-wide residual sum changes by at most one f32
    /// rounding — no gradient mass is dropped when a worker departs.
    pub fn drop_worker(&mut self, uid: usize) -> Result<()> {
        let pos = self
            .workers
            .iter()
            .position(|w| w.id == uid)
            .ok_or_else(|| anyhow::anyhow!("drop of absent worker {uid}"))?;
        ensure!(self.workers.len() > 1, "cannot drop the last worker");
        let departing = self.workers.remove(pos);
        let p_new = self.workers.len();
        for (i, &v) in departing.ef.residual().iter().enumerate() {
            // skip exact zeros: faster, and avoids -0.0 + 0.0 sign flips
            if v != 0.0 {
                self.workers[i % p_new].ef.add_residual_at(i, v);
            }
        }
        Ok(())
    }

    /// Add a fresh worker with stable uid `uid` (zero residual, sized
    /// message scratch). Its data shard starts at `(uid, current_step)` —
    /// uid-keyed streams mean no other worker's shard shifts.
    pub fn join_worker(
        &mut self,
        uid: usize,
        d: usize,
        sample_stride: usize,
        kind: CompressorKind,
        layer_sizes: &[usize],
    ) -> Result<()> {
        ensure!(self.workers.iter().all(|w| w.id != uid), "join of already-present worker {uid}");
        let mut w = Worker::new(uid, d, sample_stride, kind);
        w.ensure_message_scratch(layer_sizes);
        self.workers.push(w);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    const KIND: CompressorKind = CompressorKind::HostExact;

    #[test]
    fn construction() {
        let c = Cluster::new(4, 100, 16, KIND);
        assert_eq!(c.size(), 4);
        assert_eq!(c.workers[3].id, 3);
        assert_eq!(c.workers[0].ef.dim(), 100);
        assert_eq!(c.workers[0].msg_flat.len, 100);
        assert_eq!(c.total_residual_norm_sq(), 0.0);
    }

    #[test]
    fn message_scratch_sized_per_layer() {
        let mut c = Cluster::new(2, 100, 16, KIND);
        for w in &mut c.workers {
            w.ensure_message_scratch(&[40, 60]);
        }
        assert_eq!(c.workers[1].msgs.len(), 2);
        assert_eq!(c.workers[1].msgs[0].len, 40);
        assert_eq!(c.workers[1].msgs[1].len, 60);
        assert_eq!(c.workers[1].msgs[1].nnz(), 0);
    }

    #[test]
    fn publish_moves_message_and_stamps_rank() {
        use std::sync::mpsc;
        let mut c = Cluster::new(2, 10, 1, KIND);
        for w in &mut c.workers {
            w.ensure_message_scratch(&[4, 6]);
        }
        let (tx, rx) = mpsc::channel();
        c.workers[1].msgs[0].len = 4;
        c.workers[1].msgs[0].idx.push(2);
        c.workers[1].msgs[0].val.push(1.5);
        c.workers[1].publish_layer(1, 0, &tx);
        c.workers[0].publish_flat(0, &tx);
        drop(tx);
        let m1 = rx.recv().unwrap();
        assert_eq!((m1.rank, m1.layer, m1.msg.nnz()), (1, 0, 1));
        let m2 = rx.recv().unwrap();
        assert_eq!((m2.rank, m2.layer, m2.msg.len), (0, 0, 10));
        // the buffer was moved out (capacity cycles back via reclaim)
        assert_eq!(c.workers[1].msgs[0].len, 0);
    }

    #[test]
    fn drop_worker_conserves_residual_mass_and_interleaves() {
        let d = 10;
        let mut c = Cluster::new(3, d, 1, KIND);
        // seed distinct residuals on every worker
        for (w, worker) in c.workers.iter_mut().enumerate() {
            let r: Vec<f32> = (0..d).map(|i| (w * 100 + i) as f32 * 0.25 + 0.5).collect();
            worker.ef.write_residual(0, &r);
        }
        let before = c.residual_coordinate_sums();
        c.drop_worker(1).unwrap();
        assert_eq!(c.size(), 2);
        assert_eq!(c.workers.iter().map(|w| w.id).collect::<Vec<_>>(), vec![0, 2]);
        let after = c.residual_coordinate_sums();
        for (i, (&b, &a)) in before.iter().zip(after.iter()).enumerate() {
            assert!((b - a).abs() < 1e-4 * b.abs().max(1.0), "coord {i}: {b} vs {a}");
        }
        // coordinate-interleaved: departing resid[i] landed on survivor i%2
        // (coordinate 0 → new rank 0 = uid 0, coordinate 1 → new rank 1);
        // quarters stay exact in f32, so the sums are exact
        assert_eq!(c.workers[0].ef.residual()[0], 0.5 + (100f32 * 0.25 + 0.5));
        assert_eq!(c.workers[1].ef.residual()[1], (201f32 * 0.25 + 0.5) + (101f32 * 0.25 + 0.5));
        // dropping the last worker or an absent uid is rejected
        assert!(c.drop_worker(7).is_err());
        c.drop_worker(0).unwrap();
        assert!(c.drop_worker(2).is_err());
    }

    #[test]
    fn join_worker_gets_fresh_state_and_unique_uid() {
        let mut c = Cluster::new(2, 8, 1, KIND);
        c.join_worker(5, 8, 1, KIND, &[3, 5]).unwrap();
        assert_eq!(c.size(), 3);
        let w = &c.workers[2];
        assert_eq!((w.id, w.ef.dim(), w.msgs.len()), (5, 8, 2));
        assert_eq!(w.ef.residual_norm_sq(), 0.0);
        assert!(c.join_worker(0, 8, 1, KIND, &[3, 5]).is_err(), "uid collision must fail");
    }

    #[test]
    fn mean_loss() {
        let mut c = Cluster::new(2, 10, 1, KIND);
        c.workers[0].last_loss = 1.0;
        c.workers[1].last_loss = 3.0;
        assert_eq!(c.mean_loss(), 2.0);
    }
}
