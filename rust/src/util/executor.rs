//! Deterministic fork/join execution over per-worker state.
//!
//! The LAGS/SLGS hot loop is "embarrassingly parallel per worker, then a
//! rank-ordered reduction": every logical worker owns its residuals,
//! momentum and message scratch, so gradient compute and error-feedback
//! compression can fan out across OS threads with **no shared mutable
//! state inside the parallel region**. Determinism therefore does not
//! depend on scheduling: each worker's math is a pure function of its own
//! state, and everything order-sensitive (the f32 reduction, instrument
//! RNGs, the parameter update) stays outside, in rank order 0..P-1
//! (DESIGN.md §Threading-model).
//!
//! `std::thread::scope` is used instead of a persistent pool: scoped
//! threads borrow the worker slice directly (no Arc/channel plumbing), and
//! spawn cost (~10µs/thread) is negligible against a trainer iteration.

use anyhow::{anyhow, Result};

/// Fans work over the `Worker` pool. `threads == 1` degenerates to the
/// sequential loop (the baseline every parallel run must bit-match).
#[derive(Debug, Clone)]
pub struct ParallelExecutor {
    threads: usize,
}

impl ParallelExecutor {
    /// `threads == 0` selects the machine's available parallelism.
    pub fn new(threads: usize) -> ParallelExecutor {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        ParallelExecutor { threads }
    }

    pub fn sequential() -> ParallelExecutor {
        ParallelExecutor { threads: 1 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(index, &mut items[index])` for every item, fanning contiguous
    /// chunks over up to `threads` scoped threads. Each invocation gets
    /// exclusive access to its item; `f` must not rely on cross-item
    /// ordering. Errors are reported in rank order (the failure a
    /// sequential run would hit first), so error behaviour is also
    /// deterministic.
    pub fn run<W, F>(&self, items: &mut [W], f: F) -> Result<()>
    where
        W: Send,
        F: Fn(usize, &mut W) -> Result<()> + Sync,
    {
        let n = items.len();
        let t = self.threads.min(n);
        if t <= 1 {
            for (i, w) in items.iter_mut().enumerate() {
                f(i, w)?;
            }
            return Ok(());
        }
        let chunk = n.div_ceil(t);
        let results: Vec<Result<()>> = std::thread::scope(|s| {
            let f = &f;
            let handles: Vec<_> = items
                .chunks_mut(chunk)
                .enumerate()
                .map(|(ci, part)| {
                    s.spawn(move || {
                        for (j, w) in part.iter_mut().enumerate() {
                            f(ci * chunk + j, w)?;
                        }
                        Ok(())
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| Err(anyhow!("worker thread panicked")))
                })
                .collect()
        });
        for r in results {
            r?;
        }
        Ok(())
    }

    /// Streaming variant of [`Self::run`]: fan `f(index, &mut items[index],
    /// &mut sink)` over worker threads while the **calling thread** runs
    /// `drain` concurrently. Each spawned thread gets its own clone of
    /// `sink` (typically an `mpsc::Sender`); the original is dropped after
    /// spawning, so once every worker thread finishes, a channel-backed
    /// drain sees disconnection and terminates.
    ///
    /// Unlike `run`, `threads == 1` still spawns one worker thread — the
    /// point of the streaming shape is that the caller's drain (the
    /// rank-ordered reduction) overlaps item processing, which needs the
    /// calling thread free. Items are processed in rank order within each
    /// chunk, and errors are reported in rank order, exactly as in `run`.
    pub fn run_with_sink<W, S, F, D, R>(
        &self,
        items: &mut [W],
        sink: S,
        f: F,
        drain: D,
    ) -> Result<R>
    where
        W: Send,
        S: Clone + Send,
        F: Fn(usize, &mut W, &mut S) -> Result<()> + Sync,
        D: FnOnce() -> R,
    {
        let n = items.len();
        if n == 0 {
            drop(sink);
            return Ok(drain());
        }
        let t = self.threads.min(n).max(1);
        let chunk = n.div_ceil(t);
        let (results, out) = std::thread::scope(|s| {
            let f = &f;
            let handles: Vec<_> = items
                .chunks_mut(chunk)
                .enumerate()
                .map(|(ci, part)| {
                    let mut sink = sink.clone();
                    s.spawn(move || {
                        for (j, w) in part.iter_mut().enumerate() {
                            f(ci * chunk + j, w, &mut sink)?;
                        }
                        Ok(())
                    })
                })
                .collect();
            // the worker threads now hold the only sink clones
            drop(sink);
            let out = drain();
            let results: Vec<Result<()>> = handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| Err(anyhow!("worker thread panicked")))
                })
                .collect();
            (results, out)
        });
        for r in results {
            r?;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn visits_every_index_exactly_once() {
        for threads in [1usize, 2, 3, 4, 7, 16] {
            let exec = ParallelExecutor::new(threads);
            let mut items = vec![0usize; 13];
            exec.run(&mut items, |i, v| {
                *v += i + 1;
                Ok(())
            })
            .unwrap();
            let expect: Vec<usize> = (1..=13).collect();
            assert_eq!(items, expect, "threads={threads}");
        }
    }

    #[test]
    fn parallel_matches_sequential_writes() {
        let mut seq = vec![0.0f64; 100];
        ParallelExecutor::sequential()
            .run(&mut seq, |i, v| {
                *v = (i as f64).sqrt();
                Ok(())
            })
            .unwrap();
        let mut par = vec![0.0f64; 100];
        ParallelExecutor::new(8)
            .run(&mut par, |i, v| {
                *v = (i as f64).sqrt();
                Ok(())
            })
            .unwrap();
        assert_eq!(seq, par);
    }

    #[test]
    fn counts_calls_once_each() {
        let calls = AtomicUsize::new(0);
        let mut items = vec![(); 37];
        ParallelExecutor::new(5)
            .run(&mut items, |_, _| {
                calls.fetch_add(1, Ordering::SeqCst);
                Ok(())
            })
            .unwrap();
        assert_eq!(calls.load(Ordering::SeqCst), 37);
    }

    #[test]
    fn error_propagates_lowest_rank_first() {
        let mut items = vec![0usize; 10];
        let err = ParallelExecutor::new(4)
            .run(&mut items, |i, _| {
                if i == 3 || i == 7 {
                    anyhow::bail!("rank {i} failed")
                }
                Ok(())
            })
            .unwrap_err();
        assert_eq!(err.to_string(), "rank 3 failed");
    }

    #[test]
    fn auto_threads_is_at_least_one() {
        assert!(ParallelExecutor::new(0).threads() >= 1);
    }

    #[test]
    fn sink_streams_every_item_and_drain_overlaps() {
        use std::sync::mpsc;
        for threads in [1usize, 2, 3, 8] {
            let exec = ParallelExecutor::new(threads);
            let mut items: Vec<usize> = (0..17).collect();
            let (tx, rx) = mpsc::channel();
            let total = exec
                .run_with_sink(
                    &mut items,
                    tx,
                    |i, v, tx| {
                        *v *= 2;
                        tx.send(i).unwrap();
                        Ok(())
                    },
                    move || {
                        let mut seen: Vec<usize> = rx.iter().collect();
                        seen.sort_unstable();
                        seen
                    },
                )
                .unwrap();
            assert_eq!(total, (0..17).collect::<Vec<_>>(), "threads={threads}");
            assert_eq!(items, (0..17).map(|i| i * 2).collect::<Vec<_>>());
        }
    }

    #[test]
    fn sink_error_propagates_and_drain_terminates() {
        use std::sync::mpsc;
        let exec = ParallelExecutor::new(4);
        let mut items = vec![0usize; 12];
        let (tx, rx) = mpsc::channel::<usize>();
        let err = exec
            .run_with_sink(
                &mut items,
                tx,
                |i, _, tx| {
                    if i == 5 {
                        anyhow::bail!("rank {i} failed");
                    }
                    tx.send(i).unwrap();
                    Ok(())
                },
                move || rx.iter().count(),
            )
            .unwrap_err();
        assert_eq!(err.to_string(), "rank 5 failed");
    }

    #[test]
    fn sink_empty_items_still_drains() {
        use std::sync::mpsc;
        let exec = ParallelExecutor::new(4);
        let mut none: Vec<usize> = vec![];
        let (tx, rx) = mpsc::channel::<usize>();
        let n = exec
            .run_with_sink(&mut none, tx, |_, _, _| Ok(()), move || rx.iter().count())
            .unwrap();
        assert_eq!(n, 0);
    }

    #[test]
    fn empty_and_undersized_pools() {
        let exec = ParallelExecutor::new(8);
        let mut none: Vec<usize> = vec![];
        exec.run(&mut none, |_, _| Ok(())).unwrap();
        let mut one = vec![5usize];
        exec.run(&mut one, |i, v| {
            *v += i;
            Ok(())
        })
        .unwrap();
        assert_eq!(one, vec![5]);
    }
}
