//! Micro-benchmark harness (criterion is not in the vendored crate set).
//!
//! Measures wall-clock over adaptive iteration counts, reports median /
//! mean / p95 with outlier-robust statistics, and prints rows in a stable
//! machine-grepable format:
//!
//! ```text
//! bench <name> median=1.234ms mean=1.301ms p95=1.9ms iters=4096
//! ```
//!
//! The `cargo bench` targets (`rust/benches/*.rs`, harness = false) use
//! this to regenerate each paper table/figure.

use crate::util::clock;
use crate::util::json::Json;
use std::hint::black_box;
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

pub use std::hint::black_box as bb;

#[derive(Debug, Clone)]
pub struct Stats {
    pub median: f64,
    pub mean: f64,
    pub p95: f64,
    pub min: f64,
    pub iters: usize,
}

impl Stats {
    pub fn line(&self, name: &str) -> String {
        format!(
            "bench {name} median={} mean={} p95={} min={} iters={}",
            super::fmt_secs(self.median),
            super::fmt_secs(self.mean),
            super::fmt_secs(self.p95),
            super::fmt_secs(self.min),
            self.iters
        )
    }
}

/// Benchmark `f`, auto-scaling iteration count to fill ~`budget`.
pub fn bench_with_budget<F: FnMut()>(budget: Duration, mut f: F) -> Stats {
    // warm-up + calibration
    let t0 = clock::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let per_sample = (once * 1.2).max(1e-6);
    let samples = ((budget.as_secs_f64() / per_sample) as usize).clamp(5, 2000);

    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = clock::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let p95 = times[(times.len() * 95 / 100).min(times.len() - 1)];
    Stats { median, mean, p95, min: times[0], iters: samples }
}

/// Benchmark with the default 1-second budget, print the stats line, and
/// record the result into the process-wide registry for [`write_json`].
pub fn run<F: FnMut()>(name: &str, f: F) -> Stats {
    let s = bench_with_budget(Duration::from_secs(1), f);
    println!("{}", s.line(name));
    record(name, &s, None);
    s
}

/// Benchmark a function returning a value (kept alive via black_box).
pub fn run_val<T, F: FnMut() -> T>(name: &str, mut f: F) -> Stats {
    run(name, move || {
        black_box(f());
    })
}

/// Like [`run`], but tags the result with a work size (elements processed
/// per call) so [`write_json`] can report throughput (items/s).
pub fn run_items<F: FnMut()>(name: &str, items_per_iter: usize, f: F) -> Stats {
    let s = bench_with_budget(Duration::from_secs(1), f);
    println!("{}", s.line(name));
    record(name, &s, Some(items_per_iter as f64));
    s
}

struct Recorded {
    name: String,
    stats: Stats,
    items_per_iter: Option<f64>,
    /// extra numeric fields attached via [`annotate`] (e.g. a bench's
    /// measured overlap_efficiency), serialized alongside the timing row
    extra: Vec<(String, f64)>,
}

fn registry() -> &'static Mutex<Vec<Recorded>> {
    static REG: OnceLock<Mutex<Vec<Recorded>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Vec::new()))
}

fn record(name: &str, stats: &Stats, items_per_iter: Option<f64>) {
    registry()
        .lock()
        .unwrap()
        .push(Recorded { name: name.to_string(), stats: stats.clone(), items_per_iter, extra: Vec::new() });
}

/// Attach an extra numeric field to an already-recorded bench row (most
/// recent row with that name), e.g. `overlap_efficiency` on a trainer
/// iteration bench. No-op if the name was never recorded.
pub fn annotate(name: &str, key: &str, value: f64) {
    let mut reg = registry().lock().unwrap();
    if let Some(r) = reg.iter_mut().rev().find(|r| r.name == name) {
        r.extra.push((key.to_string(), value));
    }
}

/// Snapshot every result recorded so far as a JSON document:
///
/// ```json
/// {"benches": [{"name": ..., "ns_per_iter": ..., "throughput_items_per_sec": ...}]}
/// ```
pub fn results_json() -> Json {
    let reg = registry().lock().unwrap();
    let rows = reg
        .iter()
        .map(|r| {
            let mut fields = vec![
                ("name", Json::Str(r.name.clone())),
                ("ns_per_iter", Json::Num(r.stats.median * 1e9)),
                ("mean_ns", Json::Num(r.stats.mean * 1e9)),
                ("p95_ns", Json::Num(r.stats.p95 * 1e9)),
                ("min_ns", Json::Num(r.stats.min * 1e9)),
                ("iters", Json::Num(r.stats.iters as f64)),
            ];
            if let Some(items) = r.items_per_iter {
                fields.push((
                    "throughput_items_per_sec",
                    Json::Num(items / r.stats.median.max(1e-12)),
                ));
            }
            for (k, v) in &r.extra {
                fields.push((k.as_str(), Json::Num(*v)));
            }
            Json::obj(fields)
        })
        .collect();
    Json::obj(vec![("benches", Json::Arr(rows))])
}

/// Write the recorded results as machine-readable JSON (e.g.
/// `BENCH_hotpath.json`) so the perf trajectory is trackable across PRs.
/// Atomic (temp file + rename): an interrupted bench run keeps the
/// previous snapshot instead of truncating it.
pub fn write_json(path: &str) -> std::io::Result<()> {
    let doc = results_json().to_string_pretty();
    crate::util::json::write_atomic(std::path::Path::new(path), doc.as_bytes())
        .map_err(|e| std::io::Error::other(format!("{e:#}")))?;
    println!("wrote {path} ({} benches)", registry().lock().unwrap().len());
    Ok(())
}

/// Print a markdown-style table row (used by the table benches to emit the
/// same rows the paper reports).
pub fn table_row(cols: &[String]) {
    println!("| {} |", cols.join(" | "));
}

pub fn table_header(cols: &[&str]) {
    println!("| {} |", cols.join(" | "));
    println!("|{}|", cols.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_records_and_serializes() {
        let s = bench_with_budget(Duration::from_millis(10), || {
            bb((0..100).sum::<u64>());
        });
        record("unit_test_bench", &s, Some(100.0));
        annotate("unit_test_bench", "overlap_efficiency", 0.5);
        annotate("no_such_bench", "ignored", 1.0); // silently dropped
        let j = results_json();
        let rows = j.get("benches").unwrap().as_arr().unwrap();
        let row = rows
            .iter()
            .find(|r| r.get("name").unwrap().as_str().unwrap() == "unit_test_bench")
            .expect("recorded bench present");
        assert!(row.get("ns_per_iter").unwrap().as_f64().unwrap() > 0.0);
        assert!(row.get("throughput_items_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(row.get("overlap_efficiency").unwrap().as_f64().unwrap(), 0.5);
    }

    #[test]
    fn stats_sane() {
        let s = bench_with_budget(Duration::from_millis(50), || {
            bb((0..1000).sum::<u64>());
        });
        assert!(s.iters >= 5);
        assert!(s.min <= s.median && s.median <= s.p95);
        assert!(s.mean > 0.0);
    }
}
