//! Micro-benchmark harness (criterion is not in the vendored crate set).
//!
//! Measures wall-clock over adaptive iteration counts, reports median /
//! mean / p95 with outlier-robust statistics, and prints rows in a stable
//! machine-grepable format:
//!
//! ```text
//! bench <name> median=1.234ms mean=1.301ms p95=1.9ms iters=4096
//! ```
//!
//! The `cargo bench` targets (`rust/benches/*.rs`, harness = false) use
//! this to regenerate each paper table/figure.

use std::hint::black_box;
use std::time::{Duration, Instant};

pub use std::hint::black_box as bb;

#[derive(Debug, Clone)]
pub struct Stats {
    pub median: f64,
    pub mean: f64,
    pub p95: f64,
    pub min: f64,
    pub iters: usize,
}

impl Stats {
    pub fn line(&self, name: &str) -> String {
        format!(
            "bench {name} median={} mean={} p95={} min={} iters={}",
            super::fmt_secs(self.median),
            super::fmt_secs(self.mean),
            super::fmt_secs(self.p95),
            super::fmt_secs(self.min),
            self.iters
        )
    }
}

/// Benchmark `f`, auto-scaling iteration count to fill ~`budget`.
pub fn bench_with_budget<F: FnMut()>(budget: Duration, mut f: F) -> Stats {
    // warm-up + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let per_sample = (once * 1.2).max(1e-6);
    let samples = ((budget.as_secs_f64() / per_sample) as usize).clamp(5, 2000);

    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let p95 = times[(times.len() * 95 / 100).min(times.len() - 1)];
    Stats { median, mean, p95, min: times[0], iters: samples }
}

/// Benchmark with the default 1-second budget and print the stats line.
pub fn run<F: FnMut()>(name: &str, f: F) -> Stats {
    let s = bench_with_budget(Duration::from_secs(1), f);
    println!("{}", s.line(name));
    s
}

/// Benchmark a function returning a value (kept alive via black_box).
pub fn run_val<T, F: FnMut() -> T>(name: &str, mut f: F) -> Stats {
    run(name, move || {
        black_box(f());
    })
}

/// Print a markdown-style table row (used by the table benches to emit the
/// same rows the paper reports).
pub fn table_row(cols: &[String]) {
    println!("| {} |", cols.join(" | "));
}

pub fn table_header(cols: &[&str]) {
    println!("| {} |", cols.join(" | "));
    println!("|{}|", cols.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_sane() {
        let s = bench_with_budget(Duration::from_millis(50), || {
            bb((0..1000).sum::<u64>());
        });
        assert!(s.iters >= 5);
        assert!(s.min <= s.median && s.median <= s.p95);
        assert!(s.mean > 0.0);
    }
}
