//! Centralized monotonic-clock access — the determinism contract's single
//! sanctioned wall-clock read site.
//!
//! Rule R2 of the determinism audit (DESIGN.md §Determinism contract and
//! enforcement) forbids `Instant::now` / `SystemTime` / `std::env` reads
//! anywhere in `rust/src/**`: wall-clock values must never feed control
//! flow, selection, or arithmetic that the bit-identity contract covers.
//! Timing *telemetry* (OverlapTimer intervals, `lags calibrate`, the bench
//! harness) is legitimate, so every such consumer calls [`now`] instead of
//! `Instant::now()` directly. That leaves exactly one clock read in the
//! tree — this function — which `lags audit` whitelists structurally; any
//! new direct read anywhere else is an R2 finding and fails CI.
//!
//! Keeping the funnel this narrow is what makes the rule reviewable: a
//! timing value can only enter the program here, so "does wall clock leak
//! into the deterministic state?" reduces to auditing the callers of one
//! function instead of grepping the whole tree.

use std::time::Instant;

/// Read the monotonic clock. The only wall-clock read in the crate; use
/// this (never `Instant::now()`) for every timing measurement so the R2
/// audit and the clippy `disallowed-methods` gate stay clean.
#[allow(clippy::disallowed_methods)] // the single sanctioned clock read
pub fn now() -> Instant {
    Instant::now()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_monotonic() {
        let a = now();
        let b = now();
        assert!(b >= a);
    }
}
