//! Tiny CLI argument parser (clap is not in the vendored crate set).
//!
//! Grammar: `lags <subcommand> [--flag] [--key value]...` — exactly what the
//! coordinator binary and the examples need. Unknown keys are collected so
//! callers can reject them with a helpful message.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]). `--key value` and
    /// `--key=value` both work; a `--key` followed by another `--` token or
    /// end-of-args is treated as boolean `true`.
    pub fn parse_env() -> Args {
        // lags-audit: allow(R2) reason="argv read at process start; configuration enters exactly once, before any deterministic state exists"
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut out = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    let is_val = iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false);
                    if is_val {
                        out.flags.insert(stripped.to_string(), iter.next().unwrap());
                    } else {
                        out.flags.insert(stripped.to_string(), "true".to_string());
                    }
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Error out if any flag is not in `known` (catches typos).
    pub fn reject_unknown(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k}; known: {}", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn basic() {
        let a = parse("train --model mlp --steps 100 --verbose --lr=0.05 extra");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("model"), Some("mlp"));
        assert_eq!(a.usize_or("steps", 1).unwrap(), 100);
        assert!(a.bool("verbose"));
        assert_eq!(a.f64_or("lr", 0.1).unwrap(), 0.05);
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.usize_or("steps", 7).unwrap(), 7);
        assert_eq!(a.str_or("model", "mlp"), "mlp");
        assert!(!a.bool("verbose"));
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("x --steps abc");
        assert!(a.usize_or("steps", 1).is_err());
    }

    #[test]
    fn unknown_flags_rejected() {
        let a = parse("x --good 1 --bad 2");
        assert!(a.reject_unknown(&["good"]).is_err());
        assert!(a.reject_unknown(&["good", "bad"]).is_ok());
    }
}
