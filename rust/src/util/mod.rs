//! Self-contained utilities (the image vendors only `xla` + `anyhow`, so
//! JSON, PRNG, CLI parsing, the bench harness and the property-test harness
//! live here instead of third-party crates).

pub mod bench;
pub mod cli;
pub mod clock;
pub mod executor;
pub mod json;
pub mod prop;
pub mod rng;

pub use executor::ParallelExecutor;

/// Round `n` up to the next power of two (compress bucket sizing; must
/// mirror `python/compile/aot.py::next_pow2`).
pub fn next_pow2(n: usize) -> usize {
    let mut p = 1;
    while p < n {
        p *= 2;
    }
    p
}

/// Round `n` up to a multiple of `align` (apply-artifact padding; must
/// mirror `python/compile/aot.py::pad_to`).
pub fn pad_to(n: usize, align: usize) -> usize {
    n.div_ceil(align) * align
}

/// Human-readable byte count.
pub fn fmt_bytes(b: f64) -> String {
    if b >= 1e9 {
        format!("{:.2} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2} MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.2} KB", b / 1e3)
    } else {
        format!("{b:.0} B")
    }
}

/// Human-readable seconds.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pow2() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(1000), 1024);
        assert_eq!(next_pow2(1024), 1024);
        assert_eq!(next_pow2(1025), 2048);
    }

    #[test]
    fn padding() {
        assert_eq!(pad_to(1, 4096), 4096);
        assert_eq!(pad_to(4096, 4096), 4096);
        assert_eq!(pad_to(4097, 4096), 8192);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert_eq!(fmt_bytes(2.5e6), "2.50 MB");
        assert_eq!(fmt_secs(0.0015), "1.500 ms");
        assert_eq!(fmt_secs(2.0), "2.000 s");
    }
}
