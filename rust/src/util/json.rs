//! Minimal JSON parser / serializer (no serde in the vendored crate set).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json` and
//! the experiment config/result files: objects, arrays, strings with
//! standard escapes, numbers, booleans, null. Numbers are kept as f64
//! (the manifest's sizes fit exactly below 2^53).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at offset {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors --------------------------------------------------
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).with_context(|| format!("missing key {key:?}")),
            _ => bail!("not an object (wanted key {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("not a non-negative integer: {f}");
        }
        Ok(f as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    // ---- construction helpers ---------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn arr_str(xs: &[String]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Str(x.clone())).collect())
    }

    // ---- serialization ----------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

/// Crash-safe file write: the bytes land in a temp file in the SAME
/// directory (rename across filesystems isn't atomic), are fsynced, and
/// only then renamed over `path`. A crash at any point leaves either the
/// old file or nothing — never a truncated artifact. Every JSON artifact
/// (report.json, BENCH_*.json, calibration files) and the binary
/// checkpoints go through this path.
pub fn write_atomic(path: &std::path::Path, bytes: &[u8]) -> Result<()> {
    use std::io::Write as _;
    let dir = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
        _ => std::path::PathBuf::from("."),
    };
    // the pid keeps concurrent writers (e.g. two bench runs) from
    // clobbering each other's temp file; the final rename still wins-last
    let name = path.file_name().context("write_atomic needs a file name")?;
    let tmp = dir.join(format!(".{}.tmp.{}", name.to_string_lossy(), std::process::id()));
    let result = (|| -> Result<()> {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating temp file {tmp:?}"))?;
        f.write_all(bytes).with_context(|| format!("writing {tmp:?}"))?;
        f.sync_all().with_context(|| format!("fsyncing {tmp:?}"))?;
        drop(f);
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {tmp:?} over {path:?}"))?;
        // fsync the directory so the rename itself survives a crash;
        // best-effort — some filesystems refuse to sync a directory handle
        if let Ok(d) = std::fs::File::open(&dir) {
            let _ = d.sync_all();
        }
        Ok(())
    })();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().context("unexpected end of JSON")
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at offset {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at offset {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at offset {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs are not needed by our files;
                            // map unpaired surrogates to the replacement char
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{} at offset {}", e as char, self.i),
                    }
                }
                c if c < 0x20 => bail!("raw control char in string at offset {}", self.i),
                c => {
                    // collect the full UTF-8 sequence
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        bail!("truncated UTF-8 sequence");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        let n: f64 = s.parse().with_context(|| format!("bad number {s:?}"))?;
        Ok(Json::Num(n))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_manifest_like() {
        let src = r#"{"models": {"mlp": {"d": 165514, "layers": [{"name": "fc0.w", "size": 131072}], "metric": "accuracy"}}, "buckets": [1024, 2048], "ok": true, "none": null, "f": -1.5e-3}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(
            v.get("models").unwrap().get("mlp").unwrap().get("d").unwrap().as_usize().unwrap(),
            165514
        );
        assert_eq!(v.get("ok").unwrap().as_bool().unwrap(), true);
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""a\"b\\c\nA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\c\nA");
        let s = Json::Str("x\"y\nz\\".into()).to_string_compact();
        assert_eq!(Json::parse(&s).unwrap().as_str().unwrap(), "x\"y\nz\\");
    }

    #[test]
    fn unicode_pass_through() {
        let v = Json::parse(r#""δ(l) ⊔ α–β""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "δ(l) ⊔ α–β");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn write_atomic_replaces_and_leaves_no_temp() {
        let dir = std::env::temp_dir().join(format!("lags_json_atomic_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("artifact.json");
        write_atomic(&path, b"{\"v\": 1}").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "{\"v\": 1}");
        // overwrite: the new contents fully replace the old
        write_atomic(&path, b"{\"v\": 2}").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "{\"v\": 2}");
        // no temp droppings left behind
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "temp files left: {leftovers:?}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn nested_arrays() {
        let v = Json::parse("[[1,2],[3,[4]]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[1].as_arr().unwrap()[1].as_arr().unwrap()[0].as_f64().unwrap(), 4.0);
    }
}
