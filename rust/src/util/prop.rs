//! Minimal property-testing harness (proptest is not in the vendored crate
//! set). Runs a property over many seeded random cases; on failure it
//! re-runs a simple shrink loop (halving sizes) and reports the smallest
//! failing seed/size it found.
//!
//! Used by `rust/tests/proptest_invariants.rs` for the coordinator
//! invariants: Top-k semantics, error-feedback conservation, sparse codec
//! round-trips, Lemma 1, DES monotonicity.

use super::rng::Rng;

pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 128, seed: 0xC0FFEE }
    }
}

/// A generated case: seeded RNG plus a size hint in [min_size, max_size].
pub struct Case {
    pub rng: Rng,
    pub size: usize,
}

/// Run `prop` over `cfg.cases` random cases. `prop` returns Err(msg) to fail.
/// Panics with diagnostics on the first failure (after shrinking the size).
pub fn check<F>(name: &str, cfg: Config, min_size: usize, max_size: usize, mut prop: F)
where
    F: FnMut(&mut Case) -> Result<(), String>,
{
    let mut meta = Rng::new(cfg.seed);
    for case_idx in 0..cfg.cases {
        let case_seed = meta.next_u64();
        let size = min_size + Rng::new(case_seed ^ 0x517E).below(max_size - min_size + 1);
        let mut case = Case { rng: Rng::new(case_seed), size };
        if let Err(msg) = prop(&mut case) {
            // shrink: halve the size until it passes, report smallest failure
            let mut failing_size = size;
            let mut s = size / 2;
            while s >= min_size.max(1) {
                let mut c = Case { rng: Rng::new(case_seed), size: s };
                if prop(&mut c).is_err() {
                    failing_size = s;
                }
                if s == min_size { break; }
                s = (s / 2).max(min_size);
                if s == min_size && failing_size == min_size { break; }
            }
            panic!(
                "property `{name}` failed: case #{case_idx} seed={case_seed:#x} \
                 size={size} (smallest failing size {failing_size}): {msg}",
            );
        }
    }
}

/// Convenience: check with default config.
pub fn quick<F>(name: &str, min_size: usize, max_size: usize, prop: F)
where
    F: FnMut(&mut Case) -> Result<(), String>,
{
    check(name, Config::default(), min_size, max_size, prop)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        quick("sum-commutes", 1, 64, |c| {
            count += 1;
            let a = c.rng.uniform();
            let b = c.rng.uniform();
            if (a + b - (b + a)).abs() < 1e-15 { Ok(()) } else { Err("no".into()) }
        });
        assert_eq!(count, Config::default().cases);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics() {
        quick("always-fails", 8, 64, |c| {
            if c.size < 8 { Ok(()) } else { Err(format!("size {} >= 8", c.size)) }
        });
    }
}
