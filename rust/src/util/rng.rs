//! Deterministic PRNG (SplitMix64 core) + distributions.
//!
//! Every stochastic component of the coordinator (data synthesis, shard
//! sampling, RandK draws, property tests) goes through this module so runs
//! are exactly reproducible from a single seed, independent of platform.

/// SplitMix64: tiny, fast, passes BigCrush as a 64-bit mixer; ideal for
/// seeding and for the modest statistical demands of workload synthesis.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// cached second Box-Muller sample
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9e3779b97f4a7c15), spare_normal: None }
    }

    /// Derive an independent stream (worker p, layer l, ...) from this one.
    pub fn fork(&self, stream: u64) -> Self {
        let mut mix = Rng::new(self.state ^ stream.wrapping_mul(0xff51afd7ed558ccd));
        mix.next_u64();
        mix
    }

    /// Snapshot the stream position for checkpointing: (raw state word,
    /// cached Box-Muller spare). Together with [`Self::restore`] this
    /// round-trips the generator bit-exactly mid-stream.
    pub fn snapshot(&self) -> (u64, Option<f64>) {
        (self.state, self.spare_normal)
    }

    /// Rebuild a generator at an exact stream position captured by
    /// [`Self::snapshot`]. NOT `new` — the state word is installed raw,
    /// without the seed scramble.
    pub fn restore(state: u64, spare_normal: Option<f64>) -> Self {
        Rng { state, spare_normal }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias is negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = (self.uniform().max(1e-300), self.uniform());
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with N(0, sigma^2) f32 samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for x in out.iter_mut() {
            *x = self.normal_f32() * sigma;
        }
    }

    /// Sample k distinct indices from [0, n) (Floyd's algorithm, O(k)).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // membership-probe set only: never iterated, so the seed-randomized
        // bucket order can't leak into any output (`out` is built in Floyd
        // visit order, which depends only on this Rng's stream)
        #[allow(clippy::disallowed_types)]
        // lags-audit: allow(R1) reason="membership-only HashSet, never iterated; output order comes from the deterministic Rng stream"
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

    /// Sample from a categorical distribution given cumulative weights.
    pub fn categorical(&mut self, cdf: &[f64]) -> usize {
        let u = self.uniform() * cdf.last().copied().unwrap_or(1.0);
        match cdf.binary_search_by(|w| w.partial_cmp(&u).unwrap()) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
        .min(cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_differ() {
        let base = Rng::new(7);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn snapshot_restore_round_trips_mid_stream() {
        // advance past a normal() draw so the Box-Muller spare is live,
        // snapshot, and check the restored stream is bit-identical —
        // including the cached spare — for every distribution kind
        let mut a = Rng::new(99);
        for _ in 0..7 {
            a.next_u64();
        }
        a.normal(); // leaves spare_normal = Some(..)
        let (state, spare) = a.snapshot();
        assert!(spare.is_some(), "odd normal draw must cache a spare");
        let mut b = Rng::restore(state, spare);
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
            assert_eq!(a.normal(), b.normal());
            assert_eq!(a.uniform(), b.uniform());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    #[allow(clippy::disallowed_types)] // distinctness check only, not order-sensitive
    fn distinct_sampling() {
        let mut r = Rng::new(3);
        let s = r.sample_distinct(100, 30);
        assert_eq!(s.len(), 30);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(s.iter().all(|&i| i < 100));
        // full draw
        let all = r.sample_distinct(10, 10);
        let set: std::collections::HashSet<_> = all.iter().collect();
        assert_eq!(set.len(), 10);
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(5);
        let cdf = [0.1, 0.2, 1.0]; // heavy third bucket
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[r.categorical(&cdf)] += 1;
        }
        assert!(counts[2] > counts[0] + counts[1]);
    }
}
