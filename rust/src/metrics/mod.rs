//! Training metrics: the δ^(l) Assumption-1 monitor (Eq. 20), curve
//! recording, and CSV/JSON result writers used by the experiment harnesses.

pub mod delta;
pub mod recorder;

pub use delta::{delta_from_json, delta_metric, delta_metric_with, delta_to_json, DeltaMonitor};
pub use recorder::{CurveRecorder, ResultWriter};
