//! Curve recording + result files (CSV for curves, JSON for summaries).
//!
//! Every experiment harness writes into `results/<experiment>/…` so
//! EXPERIMENTS.md can reference stable paths.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

/// A named set of (step, value) curves written as wide-format CSV.
#[derive(Debug, Default, Clone)]
pub struct CurveRecorder {
    pub columns: Vec<String>,
    /// rows: step -> per-column values (NaN = missing)
    pub rows: Vec<(usize, Vec<f64>)>,
}

impl CurveRecorder {
    pub fn new(columns: &[&str]) -> Self {
        CurveRecorder { columns: columns.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn push(&mut self, step: usize, values: &[f64]) {
        assert_eq!(values.len(), self.columns.len());
        self.rows.push((step, values.to_vec()));
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from("step");
        for c in &self.columns {
            out.push(',');
            out.push_str(c);
        }
        out.push('\n');
        for (step, vals) in &self.rows {
            out.push_str(&step.to_string());
            for v in vals {
                out.push(',');
                if v.is_nan() {
                    out.push_str("");
                } else {
                    out.push_str(&format!("{v}"));
                }
            }
            out.push('\n');
        }
        out
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(())
    }

    /// Last value of a column (for summary tables).
    pub fn last(&self, column: &str) -> Option<f64> {
        let idx = self.columns.iter().position(|c| c == column)?;
        self.rows.iter().rev().find_map(|(_, v)| {
            let x = v[idx];
            if x.is_nan() {
                None
            } else {
                Some(x)
            }
        })
    }
}

/// JSON summary writer for experiment outputs.
pub struct ResultWriter {
    dir: PathBuf,
}

impl ResultWriter {
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        Ok(ResultWriter { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn write_json(&self, name: &str, value: &Json) -> Result<PathBuf> {
        let path = self.dir.join(name);
        crate::util::json::write_atomic(&path, value.to_string_pretty().as_bytes())
            .with_context(|| format!("{path:?}"))?;
        Ok(path)
    }

    pub fn write_csv(&self, name: &str, rec: &CurveRecorder) -> Result<PathBuf> {
        let path = self.dir.join(name);
        rec.write_csv(&path)?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_format() {
        let mut r = CurveRecorder::new(&["loss", "acc"]);
        r.push(0, &[2.5, 0.1]);
        r.push(10, &[1.25, f64::NAN]);
        let csv = r.to_csv();
        assert!(csv.starts_with("step,loss,acc\n"));
        assert!(csv.contains("0,2.5,0.1\n"));
        assert!(csv.contains("10,1.25,\n"));
        assert_eq!(r.last("loss"), Some(1.25));
        assert_eq!(r.last("acc"), Some(0.1)); // NaN skipped
        assert_eq!(r.last("nope"), None);
    }

    #[test]
    fn writes_files() {
        let dir = std::env::temp_dir().join("lags_recorder_test");
        let _ = std::fs::remove_dir_all(&dir);
        let w = ResultWriter::new(&dir).unwrap();
        let mut r = CurveRecorder::new(&["x"]);
        r.push(1, &[3.0]);
        let p = w.write_csv("curve.csv", &r).unwrap();
        assert!(p.exists());
        let j = Json::obj(vec![("final", Json::Num(3.0))]);
        let p2 = w.write_json("summary.json", &j).unwrap();
        let back = Json::parse(&std::fs::read_to_string(p2).unwrap()).unwrap();
        assert_eq!(back.get("final").unwrap().as_f64().unwrap(), 3.0);
    }
}
