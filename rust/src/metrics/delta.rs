//! δ^(l) — the Assumption-1 verification metric (Eq. 20, Fig. 2):
//!
//! ```text
//! δ^(l) = ‖Σ_p x^{p,(l)} − Σ_p TopK(x^{p,(l)}, k^(l))‖²
//!         ───────────────────────────────────────────────
//!         ‖Σ_p x^{p,(l)} − RandK(Σ_p x^{p,(l)}, k^(l))‖²
//! ```
//!
//! with x^{p,(l)} = G^p(v_t)^{(l)} + ε_t^{p,(l)} (the pre-compression
//! accumulators). Assumption 1 holds when δ^(l) ≤ 1. The paper evaluates
//! the denominator with a single RandK draw; we support both a single draw
//! (faithful) and the closed-form expectation (variance-free).

use crate::sparsify::{randk, topk};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// δ^(l) for one layer given the P workers' accumulators (each length n)
/// and the layer's k. `expectation` selects the closed-form denominator.
/// The numerator is the Eq. 20 TopK loss; [`delta_metric_with`] is the
/// generalized form for arbitrary compressors.
pub fn delta_metric(
    accs: &[Vec<f32>],
    k: usize,
    rng: &mut Rng,
    expectation: bool,
) -> f64 {
    let mut kept = vec![0.0f32; accs.first().map(|a| a.len()).unwrap_or(0)];
    delta_metric_with(accs, k, rng, expectation, |_, acc, k, out| {
        topk::topk_mask_into(acc, k, &mut kept);
        out.copy_from_slice(&kept);
    })
}

/// Generalized δ^(l): the numerator is the aggregate loss of an ARBITRARY
/// per-worker compressor, supplied as `keep(p, acc, k, out)` — write into
/// `out` the densified part worker `p` would transmit for accumulator
/// `acc` under budget `k`. With a TopK keep this is exactly
/// [`delta_metric`]; `lags validate` probes each zoo member's real
/// `Compressor::probe` here, so Assumption 1 is checked against what
/// actually crosses the wire (DESIGN.md §Compressor zoo and validation).
pub fn delta_metric_with<F>(
    accs: &[Vec<f32>],
    k: usize,
    rng: &mut Rng,
    expectation: bool,
    mut keep: F,
) -> f64
where
    F: FnMut(usize, &[f32], usize, &mut [f32]),
{
    let p = accs.len();
    assert!(p > 0);
    let n = accs[0].len();

    // Σ_p x^p and Σ_p keep(x^p, k)
    let mut agg = vec![0.0f32; n];
    let mut agg_kept = vec![0.0f32; n];
    let mut kept = vec![0.0f32; n];
    for (pi, acc) in accs.iter().enumerate() {
        debug_assert_eq!(acc.len(), n);
        for i in 0..n {
            agg[i] += acc[i];
        }
        keep(pi, acc, k, &mut kept);
        for i in 0..n {
            agg_kept[i] += kept[i];
        }
    }

    let num: f64 =
        agg.iter().zip(agg_kept.iter()).map(|(&a, &s)| ((a - s) as f64).powi(2)).sum();
    let den: f64 = if expectation {
        randk::randk_expected_error_sq(&agg, k)
    } else {
        randk::randk_error_sq(&agg, k, rng)
    };
    if den == 0.0 {
        // degenerate: aggregate fully captured by k coordinates
        if num == 0.0 {
            return 0.0;
        }
        return f64::INFINITY;
    }
    num / den
}

/// Serialize a δ value for JSON. Finite values pass through as numbers;
/// the degenerate cases — `+∞` (RandK denominator exactly zero while the
/// compressor still lost mass) and NaN — are NOT representable in JSON
/// (`util::json` would emit the invalid literals `inf`/`NaN`), so they
/// become a tagged sentinel object `{"degenerate": "infinite"|"nan"}`.
pub fn delta_to_json(d: f64) -> Json {
    if d.is_finite() {
        Json::Num(d)
    } else {
        let tag = if d.is_nan() { "nan" } else { "infinite" };
        Json::obj(vec![("degenerate", Json::Str(tag.to_string()))])
    }
}

/// Inverse of [`delta_to_json`]: numbers parse as themselves, sentinel
/// objects map back to `f64::INFINITY`/`NAN`. Returns `None` for any
/// other shape.
pub fn delta_from_json(j: &Json) -> Option<f64> {
    if let Json::Num(n) = j {
        return Some(*n);
    }
    match j.opt("degenerate").and_then(|t| t.as_str().ok()) {
        Some("infinite") => Some(f64::INFINITY),
        Some("nan") => Some(f64::NAN),
        _ => None,
    }
}

/// Streaming per-layer δ monitor used by the LAGS trainer (Fig. 2 series).
pub struct DeltaMonitor {
    /// per-layer series: (step, delta)
    pub series: Vec<Vec<(usize, f64)>>,
    rng: Rng,
    expectation: bool,
    every: usize,
}

impl DeltaMonitor {
    pub fn new(num_layers: usize, every: usize, expectation: bool, seed: u64) -> Self {
        DeltaMonitor {
            series: vec![Vec::new(); num_layers],
            rng: Rng::new(seed),
            expectation,
            every: every.max(1),
        }
    }

    pub fn should_sample(&self, step: usize) -> bool {
        step % self.every == 0
    }

    /// Record δ for layer `layer` at `step` from the workers' accumulators.
    pub fn record(&mut self, layer: usize, step: usize, accs: &[Vec<f32>], k: usize) {
        let d = delta_metric(accs, k, &mut self.rng, self.expectation);
        self.series[layer].push((step, d));
    }

    /// Record δ with a caller-supplied numerator (the actual compressor's
    /// kept part per worker — see [`delta_metric_with`]). The denominator
    /// draw consumes this monitor's RNG stream exactly like
    /// [`Self::record`], so checkpoint snapshot/restore is unaffected by
    /// which variant recorded a sample.
    pub fn record_with<F>(
        &mut self,
        layer: usize,
        step: usize,
        accs: &[Vec<f32>],
        k: usize,
        keep: F,
    ) where
        F: FnMut(usize, &[f32], usize, &mut [f32]),
    {
        let d = delta_metric_with(accs, k, &mut self.rng, self.expectation, keep);
        self.series[layer].push((step, d));
    }

    /// Fraction of samples (across all layers) with δ ≤ 1 — the headline
    /// Assumption-1 verification number.
    pub fn fraction_holding(&self) -> f64 {
        let mut total = 0usize;
        let mut hold = 0usize;
        for s in &self.series {
            for &(_, d) in s {
                total += 1;
                if d <= 1.0 {
                    hold += 1;
                }
            }
        }
        if total == 0 {
            return 1.0;
        }
        hold as f64 / total as f64
    }

    pub fn max_delta(&self) -> f64 {
        self.series
            .iter()
            .flat_map(|s| s.iter().map(|&(_, d)| d))
            .fold(0.0, f64::max)
    }

    /// RNG stream position for checkpointing. The monitor's single-draw
    /// RandK denominator advances this stream once per recorded sample,
    /// so resuming without it would shift every later δ draw.
    pub fn rng_snapshot(&self) -> (u64, Option<f64>) {
        self.rng.snapshot()
    }

    /// Install a checkpointed series + RNG position (from
    /// [`Self::rng_snapshot`]) onto a freshly-built monitor.
    pub fn restore(&mut self, series: Vec<Vec<(usize, f64)>>, rng_state: u64, spare: Option<f64>) {
        assert_eq!(series.len(), self.series.len(), "layer count changed under restore");
        self.series = series;
        self.rng = Rng::restore(rng_state, spare);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gaussian_accs(p: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..p).map(|_| (0..n).map(|_| rng.normal_f32()).collect()).collect()
    }

    #[test]
    fn delta_below_one_on_gaussians() {
        let accs = gaussian_accs(16, 512, 1);
        let mut rng = Rng::new(2);
        let d = delta_metric(&accs, 16, &mut rng, true);
        assert!(d < 1.0, "delta={d}");
    }

    #[test]
    fn single_draw_close_to_expectation() {
        let accs = gaussian_accs(8, 4096, 3);
        let mut rng = Rng::new(4);
        let de = delta_metric(&accs, 64, &mut rng, true);
        let mut draws = Vec::new();
        for _ in 0..30 {
            draws.push(delta_metric(&accs, 64, &mut rng, false));
        }
        let mean = draws.iter().sum::<f64>() / draws.len() as f64;
        assert!((mean - de).abs() / de < 0.15, "mean={mean} expect={de}");
    }

    #[test]
    fn k_equals_n_gives_zero() {
        let accs = gaussian_accs(4, 64, 5);
        let mut rng = Rng::new(6);
        let d = delta_metric(&accs, 64, &mut rng, true);
        assert_eq!(d, 0.0);
    }

    #[test]
    fn monitor_aggregates() {
        let mut m = DeltaMonitor::new(2, 1, true, 7);
        let accs = gaussian_accs(8, 256, 8);
        m.record(0, 0, &accs, 8);
        m.record(1, 0, &accs, 16);
        m.record(0, 1, &accs, 8);
        assert_eq!(m.series[0].len(), 2);
        assert_eq!(m.series[1].len(), 1);
        assert!(m.fraction_holding() > 0.99);
        assert!(m.max_delta() < 1.0);
    }

    #[test]
    fn monitor_restore_resumes_rng_stream() {
        // single-draw mode (expectation = false) advances the rng per
        // record; a restored monitor must continue the SAME stream
        let accs = gaussian_accs(4, 128, 11);
        let mut full = DeltaMonitor::new(1, 1, false, 13);
        full.record(0, 0, &accs, 8);
        let (state, spare) = full.rng_snapshot();
        let series = full.series.clone();
        let mut resumed = DeltaMonitor::new(1, 1, false, 13);
        resumed.restore(series, state, spare);
        full.record(0, 1, &accs, 8);
        resumed.record(0, 1, &accs, 8);
        assert_eq!(full.series, resumed.series, "post-restore draws must be bit-identical");
    }

    #[test]
    fn sampling_interval() {
        let m = DeltaMonitor::new(1, 10, true, 9);
        assert!(m.should_sample(0));
        assert!(!m.should_sample(5));
        assert!(m.should_sample(20));
    }

    #[test]
    fn generalized_numerator_with_topk_keep_matches_legacy() {
        let accs = gaussian_accs(8, 512, 21);
        let mut r1 = Rng::new(22);
        let mut r2 = Rng::new(22);
        for expectation in [true, false] {
            let legacy = delta_metric(&accs, 32, &mut r1, expectation);
            let mut kept = vec![0.0f32; 512];
            let general =
                delta_metric_with(&accs, 32, &mut r2, expectation, |_, acc, k, out| {
                    topk::topk_mask_into(acc, k, &mut kept);
                    out.copy_from_slice(&kept);
                });
            assert_eq!(legacy.to_bits(), general.to_bits(), "expectation={expectation}");
        }
    }

    #[test]
    fn keep_nothing_compressor_blows_past_one() {
        // a compressor that transmits nothing loses ALL mass — δ must
        // exceed 1 (the RandK baseline keeps k/n of the energy)
        let accs = gaussian_accs(4, 256, 23);
        let mut rng = Rng::new(24);
        let d = delta_metric_with(&accs, 32, &mut rng, true, |_, _, _, out| {
            out.iter_mut().for_each(|v| *v = 0.0);
        });
        assert!(d > 1.0, "delta={d}");
    }

    #[test]
    fn degenerate_delta_round_trips_as_sentinel_json() {
        // +∞ δ: k = n makes the RandK denominator exactly zero while the
        // keep-nothing numerator stays positive
        let accs = gaussian_accs(2, 16, 25);
        let mut rng = Rng::new(26);
        let d = delta_metric_with(&accs, 16, &mut rng, true, |_, _, _, out| {
            out.iter_mut().for_each(|v| *v = 0.0);
        });
        assert!(d.is_infinite());

        for (v, repr) in [
            (d, r#"{"degenerate":"infinite"}"#),
            (f64::NAN, r#"{"degenerate":"nan"}"#),
            (0.75, "0.75"),
        ] {
            let j = delta_to_json(v);
            let text = j.to_string_compact();
            assert_eq!(text, repr);
            // the serialized form must PARSE as valid JSON (the raw
            // `Json::Num(inf)` path emitted the invalid literal `inf`)
            let parsed = crate::util::json::Json::parse(&text).expect("valid JSON");
            let back = delta_from_json(&parsed).expect("sentinel decodes");
            if v.is_nan() {
                assert!(back.is_nan());
            } else {
                assert_eq!(back, v);
            }
        }
        assert_eq!(delta_from_json(&Json::Str("x".into())), None);
    }

    #[test]
    fn monitor_record_with_consumes_same_rng_stream() {
        // a record_with draw must advance the monitor RNG exactly like
        // record, so mixing variants cannot shift later samples
        let accs = gaussian_accs(4, 128, 27);
        let mut a = DeltaMonitor::new(1, 1, false, 28);
        let mut b = DeltaMonitor::new(1, 1, false, 28);
        a.record(0, 0, &accs, 8);
        let mut kept = vec![0.0f32; 128];
        b.record_with(0, 0, &accs, 8, |_, acc, k, out| {
            topk::topk_mask_into(acc, k, &mut kept);
            out.copy_from_slice(&kept);
        });
        assert_eq!(a.rng_snapshot(), b.rng_snapshot());
        assert_eq!(a.series, b.series);
    }
}
