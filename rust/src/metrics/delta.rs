//! δ^(l) — the Assumption-1 verification metric (Eq. 20, Fig. 2):
//!
//! ```text
//! δ^(l) = ‖Σ_p x^{p,(l)} − Σ_p TopK(x^{p,(l)}, k^(l))‖²
//!         ───────────────────────────────────────────────
//!         ‖Σ_p x^{p,(l)} − RandK(Σ_p x^{p,(l)}, k^(l))‖²
//! ```
//!
//! with x^{p,(l)} = G^p(v_t)^{(l)} + ε_t^{p,(l)} (the pre-compression
//! accumulators). Assumption 1 holds when δ^(l) ≤ 1. The paper evaluates
//! the denominator with a single RandK draw; we support both a single draw
//! (faithful) and the closed-form expectation (variance-free).

use crate::sparsify::{randk, topk};
use crate::util::rng::Rng;

/// δ^(l) for one layer given the P workers' accumulators (each length n)
/// and the layer's k. `expectation` selects the closed-form denominator.
pub fn delta_metric(
    accs: &[Vec<f32>],
    k: usize,
    rng: &mut Rng,
    expectation: bool,
) -> f64 {
    let p = accs.len();
    assert!(p > 0);
    let n = accs[0].len();

    // Σ_p x^p and Σ_p TopK(x^p, k)
    let mut agg = vec![0.0f32; n];
    let mut agg_topk = vec![0.0f32; n];
    let mut kept = vec![0.0f32; n];
    for acc in accs {
        debug_assert_eq!(acc.len(), n);
        for i in 0..n {
            agg[i] += acc[i];
        }
        topk::topk_mask_into(acc, k, &mut kept);
        for i in 0..n {
            agg_topk[i] += kept[i];
        }
    }

    let num: f64 =
        agg.iter().zip(agg_topk.iter()).map(|(&a, &s)| ((a - s) as f64).powi(2)).sum();
    let den: f64 = if expectation {
        randk::randk_expected_error_sq(&agg, k)
    } else {
        randk::randk_error_sq(&agg, k, rng)
    };
    if den == 0.0 {
        // degenerate: aggregate fully captured by k coordinates
        if num == 0.0 {
            return 0.0;
        }
        return f64::INFINITY;
    }
    num / den
}

/// Streaming per-layer δ monitor used by the LAGS trainer (Fig. 2 series).
pub struct DeltaMonitor {
    /// per-layer series: (step, delta)
    pub series: Vec<Vec<(usize, f64)>>,
    rng: Rng,
    expectation: bool,
    every: usize,
}

impl DeltaMonitor {
    pub fn new(num_layers: usize, every: usize, expectation: bool, seed: u64) -> Self {
        DeltaMonitor {
            series: vec![Vec::new(); num_layers],
            rng: Rng::new(seed),
            expectation,
            every: every.max(1),
        }
    }

    pub fn should_sample(&self, step: usize) -> bool {
        step % self.every == 0
    }

    /// Record δ for layer `layer` at `step` from the workers' accumulators.
    pub fn record(&mut self, layer: usize, step: usize, accs: &[Vec<f32>], k: usize) {
        let d = delta_metric(accs, k, &mut self.rng, self.expectation);
        self.series[layer].push((step, d));
    }

    /// Fraction of samples (across all layers) with δ ≤ 1 — the headline
    /// Assumption-1 verification number.
    pub fn fraction_holding(&self) -> f64 {
        let mut total = 0usize;
        let mut hold = 0usize;
        for s in &self.series {
            for &(_, d) in s {
                total += 1;
                if d <= 1.0 {
                    hold += 1;
                }
            }
        }
        if total == 0 {
            return 1.0;
        }
        hold as f64 / total as f64
    }

    pub fn max_delta(&self) -> f64 {
        self.series
            .iter()
            .flat_map(|s| s.iter().map(|&(_, d)| d))
            .fold(0.0, f64::max)
    }

    /// RNG stream position for checkpointing. The monitor's single-draw
    /// RandK denominator advances this stream once per recorded sample,
    /// so resuming without it would shift every later δ draw.
    pub fn rng_snapshot(&self) -> (u64, Option<f64>) {
        self.rng.snapshot()
    }

    /// Install a checkpointed series + RNG position (from
    /// [`Self::rng_snapshot`]) onto a freshly-built monitor.
    pub fn restore(&mut self, series: Vec<Vec<(usize, f64)>>, rng_state: u64, spare: Option<f64>) {
        assert_eq!(series.len(), self.series.len(), "layer count changed under restore");
        self.series = series;
        self.rng = Rng::restore(rng_state, spare);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gaussian_accs(p: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..p).map(|_| (0..n).map(|_| rng.normal_f32()).collect()).collect()
    }

    #[test]
    fn delta_below_one_on_gaussians() {
        let accs = gaussian_accs(16, 512, 1);
        let mut rng = Rng::new(2);
        let d = delta_metric(&accs, 16, &mut rng, true);
        assert!(d < 1.0, "delta={d}");
    }

    #[test]
    fn single_draw_close_to_expectation() {
        let accs = gaussian_accs(8, 4096, 3);
        let mut rng = Rng::new(4);
        let de = delta_metric(&accs, 64, &mut rng, true);
        let mut draws = Vec::new();
        for _ in 0..30 {
            draws.push(delta_metric(&accs, 64, &mut rng, false));
        }
        let mean = draws.iter().sum::<f64>() / draws.len() as f64;
        assert!((mean - de).abs() / de < 0.15, "mean={mean} expect={de}");
    }

    #[test]
    fn k_equals_n_gives_zero() {
        let accs = gaussian_accs(4, 64, 5);
        let mut rng = Rng::new(6);
        let d = delta_metric(&accs, 64, &mut rng, true);
        assert_eq!(d, 0.0);
    }

    #[test]
    fn monitor_aggregates() {
        let mut m = DeltaMonitor::new(2, 1, true, 7);
        let accs = gaussian_accs(8, 256, 8);
        m.record(0, 0, &accs, 8);
        m.record(1, 0, &accs, 16);
        m.record(0, 1, &accs, 8);
        assert_eq!(m.series[0].len(), 2);
        assert_eq!(m.series[1].len(), 1);
        assert!(m.fraction_holding() > 0.99);
        assert!(m.max_delta() < 1.0);
    }

    #[test]
    fn monitor_restore_resumes_rng_stream() {
        // single-draw mode (expectation = false) advances the rng per
        // record; a restored monitor must continue the SAME stream
        let accs = gaussian_accs(4, 128, 11);
        let mut full = DeltaMonitor::new(1, 1, false, 13);
        full.record(0, 0, &accs, 8);
        let (state, spare) = full.rng_snapshot();
        let series = full.series.clone();
        let mut resumed = DeltaMonitor::new(1, 1, false, 13);
        resumed.restore(series, state, spare);
        full.record(0, 1, &accs, 8);
        resumed.record(0, 1, &accs, 8);
        assert_eq!(full.series, resumed.series, "post-restore draws must be bit-identical");
    }

    #[test]
    fn sampling_interval() {
        let m = DeltaMonitor::new(1, 10, true, 9);
        assert!(m.should_sample(0));
        assert!(!m.should_sample(5));
        assert!(m.should_sample(20));
    }
}
