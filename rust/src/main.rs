//! `lags` — the LAGS-SGD coordinator CLI.
//!
//! Subcommands:
//!   info      — inspect artifacts (models, layer tables, buckets)
//!   train     — run a distributed training job (dense|slgs|lags)
//!   resume    — continue a checkpointed run (bit-identical to uninterrupted)
//!   compare   — run all three algorithms with identical seeds (Fig 3/Table 1)
//!   delta     — Assumption-1 delta^(l) monitoring run (Fig 2)
//!   table2    — DES wall-clock reproduction of Table 2
//!   timeline  — DES per-layer comm timeline (Fig 1)
//!   ratios    — Eq. 18 adaptive ratio selection report
//!   calibrate — measure sustained device flops at the zoo's GEMM shapes
//!   smax      — Eq. 19 S_max sweep over r = t_c/t_b
//!   audit     — static determinism-contract lint over rust/src (R1–R5)
//!   validate  — Assumption-1 δ-gate over the (model × compressor) matrix
//!   perf-diff — compare two bench JSON snapshots, fail on regression
//!
//! The global `--isa {scalar,avx2,avx512,neon}` flag (or the `LAGS_ISA`
//! env var) forces the SIMD kernel tier's dispatch before any kernel runs;
//! every ISA is bit-identical, so it selects wall clock, never results.

#![forbid(unsafe_code)]

use anyhow::Result;
use lags::adaptive::{self, perf_model, RatioConfig};
use lags::collectives::NetworkModel;
use lags::config::{NetConfig, TrainConfig};
use lags::metrics::{CurveRecorder, ResultWriter};
use lags::models::zoo;
use lags::pipeline::desim::{simulate, Schedule, SimParams};
use lags::runtime::{calibrate::DEFAULT_BUDGET, Calibration, Runtime};
use lags::trainer::{Algorithm, Trainer};
use lags::util::cli::Args;
use lags::util::json::Json;
use lags::util::{fmt_bytes, fmt_secs};

const USAGE: &str = "\
lags — Layer-wise Adaptive Gradient Sparsification (AAAI'20 reproduction)

USAGE: lags <subcommand> [flags]

Global: --isa scalar|avx2|avx512|neon
        force the SIMD kernel tier's dispatched ISA (default: the
        strongest the CPU supports; LAGS_ISA is the env equivalent).
        Every ISA is bit-identical to the scalar reference kernels, so
        the flag changes wall clock, never results. `lags info` prints
        what was detected and what is dispatched.

  info     [--artifacts DIR] [--layers]
  train    [--artifacts DIR] [--model M] [--algorithm dense|slgs|lags]
           [--workers P] [--threads T] [--pipeline barrier|overlap]
           [--steps N] [--lr F] [--momentum F] [--local-momentum F]
           [--warmup-steps N] [--compression C]
           [--adaptive] [--c-max C] [--reselect-every N]
           [--net gige16|tengige|infiniband] [--net-alpha F]
           [--net-bandwidth F] [--merge-bytes B]
           [--compressor host|host-sampled|xla|xla-sampled|
                         adaptive-stoch|global-topk|qsgd-topk|bottom-k]
           [--delta-every N] [--delta-expectation] [--eval-every N]
           [--seed S] [--verbose]
           [--faults FILE.json] [--faults-trace FILE.json]
           [--quorum Q] [--staleness-bound S]
           [--checkpoint-every N] [--checkpoint-dir DIR] [--resume]
           [--record-trace FILE.json]
           [--calibrate] [--config FILE.json] [--out DIR]

           --artifacts native  selects the built-in pure-rust model zoo
                               (no `make artifacts` needed; also the
                               fallback when ./artifacts is absent).
                               Native models: mlp | mlp_deep | convnet |
                               convnet_deep | rnn — the conv nets run on a
                               synthetic image task, rnn is an Elman/BPTT
                               LM on the markov sequence task (metric:
                               ppl loss); their heterogeneous layer tables
                               are what make --adaptive non-trivial
           --threads T         fans the per-worker hot loop over T OS
                               threads (0 = one per core); results are
                               bit-identical to --threads 1
           --pipeline MODE     overlap (default) streams each layer's
                               rank-ordered reduction + apply concurrently
                               with workers still compressing earlier
                               layers; barrier is the fork-join baseline.
                               Bit-identical either way — a pure perf knob
                               (report.json carries the measured
                               overlap_efficiency)
           --adaptive          Eq. 18 per-layer ratios over the configured
                               --net* interconnect at the real --workers P.
                               P=1 explicitly selects all-dense (c=1):
                               one worker has nothing to hide comm behind,
                               so no phantom cluster is substituted
           --reselect-every N  with --adaptive: every N steps re-run the
                               Eq. 18 selection from MEASURED (EWMA)
                               backward/compress/reduce timings, at a step
                               boundary, after warm-up; report.json
                               carries the selection history
           --merge-bytes B     §5 merge buffer: group consecutive layer
                               messages up to B wire bytes per rank before
                               reduction. Default 0 = flush every layer
                               (a large buffer can defer all reduction
                               past the last publish, trading overlap for
                               fewer messages — the §5 ablation)
           --faults FILE.json  deterministic fault plan: per-worker
                               compute skew, per-(worker,step) link
                               jitter, and a drop/join membership schedule
                               keyed by step. The same plan drives the
                               real trainer (straggler sleeps, elastic
                               re-sharding) AND the DES prediction, so
                               predicted vs measured degradation are
                               directly comparable. Same seed + same plan
                               = bit-identical runs (both --pipeline
                               modes); report.json carries the robustness
                               telemetry under stable field names
           --quorum Q          bounded-staleness mode (LAGS only): each
                               step fires with the Q virtually-fastest
                               alive workers; an excluded worker's
                               compressed messages fold back into its own
                               error-feedback residual instead of being
                               discarded. Participation is a pure function
                               of (plan, step), never wall-clock, so the
                               determinism contract survives
           --staleness-bound S with --quorum: a worker excluded for S
                               consecutive steps is force-included on the
                               next one, bounding gradient staleness
           --checkpoint-every N  write a durable checkpoint to
                               --checkpoint-dir every N steps (plus one at
                               step 0): a versioned, checksummed file
                               capturing the COMPLETE deterministic state
                               (params, per-worker EF residuals, momentum,
                               RNG stream positions, EWMA profile,
                               selection history, membership log), written
                               atomically (temp + fsync + rename). A run
                               resumed from it is bit-identical to the
                               uninterrupted run. Required whenever the
                               fault plan schedules crash@step events
                               (`"crashes": [k, ...]` — the process exits
                               137 at the top of step k; tombstones in the
                               checkpoint dir disarm fired crashes on
                               resume)
           --resume            continue `train` from the checkpoint in
                               --checkpoint-dir instead of starting fresh
                               (the stored config wins; flags other than
                               --checkpoint-dir are ignored)
           --record-trace F    write a per-step per-worker execution trace
                               (measured compute seconds + link-jitter
                               multipliers) to F at the end of the run
           --faults-trace F    replay a trace recorded by --record-trace
                               as a deterministic skew/jitter schedule:
                               rows are median-normalized into per-step
                               compute multipliers driving both the real
                               trainer's straggler pacing and the DES
           --calibrate         measure sustained device flops at startup
                               (the `lags calibrate` microbenchmark) and
                               persist it next to the artifacts; without
                               the flag an existing calibration file is
                               loaded, else the DEVICE_FLOPS fallback
                               prices Eq. 18
  resume   <DIR> [--out DIR]

           continue the run checkpointed in DIR: the artifacts dir, model
           and full config are read back from the checkpoint header, the
           remaining steps run, and the same summary as `train` prints.
           A truncated or corrupted checkpoint fails with a checksum
           error before any state is touched
  compare  same flags as train (runs dense, slgs, lags) [--out DIR]
  delta    [--model M] [--workers P] [--steps N] [--every N] [--out DIR]
  table2   [--net PRESET] [--net-alpha F] [--net-bandwidth F] [--workers P]
           [--out DIR]
  timeline [--profile resnet50|inception_v4|vgg16|lstm_ptb] [--compression C]
  ratios   [--profile NAME | --model M [--artifacts DIR]] [--workers P]
           [--c-max C] [--net PRESET] [--net-alpha F] [--net-bandwidth F]

           without --profile, selects over the LIVE model exactly as
           `train --adaptive` does (same manifest profile, same device
           speed — measured when a calibration exists, DEVICE_FLOPS
           fallback otherwise — same worker count); the printed table IS
           the trainer's initial selection for the same flags
  calibrate [--artifacts DIR] [--budget-ms N] [--out FILE]

           runs the blocked-GEMM microbenchmark at the model zoo's actual
           Dense/Conv/Elman shapes, reports per-shape and sustained
           GFLOP/s, and persists the result (JSON next to the artifacts;
           ./lags_calibration.json for the built-in zoo) so train/ratios
           price Eq. 18 with the measured number
  smax     [--tf F] [--tb F]
  sweep    [--profile NAME] [--compression C] [--workers P] [--net-alpha F]
  audit    [--root rust/src] [--json audit.json]

           static determinism-contract lint (rules R1-R5, DESIGN.md
           §Determinism contract and enforcement): masks comments/strings/
           test modules, flags order-unstable collections in the
           deterministic core, wall-clock/env reads outside util::clock,
           unordered float accumulation, unsafe, and foreign randomness.
           Inline waivers suppress findings but are always emitted into
           the machine-readable audit.json; exits non-zero on any
           unwaived finding (gates the fast CI tier)
  validate [--quick] [--steps N] [--workers P] [--seed S] [--out DIR]
           [--artifacts DIR] [--inject-violation]

           Assumption-1 convergence gate: runs the (zoo model x
           compressor) matrix for a short step budget with the delta^(l)
           monitor in expectation mode, checks delta <= 1 + tol at every
           sampled (layer, step) with the ACTUAL compressor's error in
           the numerator, and writes validation.json (per model x
           compressor x layer: max/mean delta, violation steps, final
           loss vs the dense same-seed baseline). Exits non-zero on any
           violation. The fast CI tier gates on --quick (mlp + convnet
           x the full zoo: host, host-sampled, adaptive-stoch,
           global-topk, qsgd-topk); the scheduled tier runs the full
           5-model matrix. --inject-violation appends the bottom-k
           negative control (keeps the SMALLEST coordinates at c = 2),
           which must FAIL the gate — CI's proof the gate has teeth
  perf-diff <old.json> <new.json> [--tolerance F]

           compare two bench snapshots (the {\"benches\": [...]} documents
           the bench targets write, e.g. BENCH_gemm.json) row by row on
           ns_per_iter. Exits non-zero when any shared row is more than
           --tolerance slower (default 0.10 = +10%); added and removed
           rows are reported but never fail the diff. The CI perf-trend
           step diffs fresh gemm/kernels/sparse_agg rows against the
           committed BENCH_gemm.json snapshot
";

fn main() {
    let args = Args::parse_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            // an injected crash@step is a deliberate kill, not a usage
            // error: exit like a SIGKILLed process so chaos harnesses can
            // tell it apart (and `lags resume` can pick the run back up)
            if e.downcast_ref::<lags::cluster::faults::CrashPoint>().is_some() {
                137
            } else {
                1
            }
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    // resolve the SIMD dispatch FIRST so every kernel call — including the
    // calibrate microbenchmark — runs under the requested ISA
    if let Some(name) = args.get("isa") {
        let isa = lags::runtime::simd::Isa::from_name(name)
            .ok_or_else(|| anyhow::anyhow!("--isa {name:?} is not one of scalar/avx2/avx512/neon"))?;
        lags::runtime::simd::set_active(isa)?;
    }
    match args.subcommand.as_deref() {
        Some("info") => cmd_info(args),
        Some("train") => cmd_train(args),
        Some("resume") => cmd_resume(args),
        Some("compare") => cmd_compare(args),
        Some("delta") => cmd_delta(args),
        Some("table2") => cmd_table2(args),
        Some("timeline") => cmd_timeline(args),
        Some("ratios") => cmd_ratios(args),
        Some("calibrate") => cmd_calibrate(args),
        Some("smax") => cmd_smax(args),
        Some("sweep") => cmd_sweep(args),
        Some("audit") => cmd_audit(args),
        Some("validate") => cmd_validate(args),
        Some("perf-diff") => cmd_perf_diff(args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn artifacts_dir(args: &Args) -> String {
    if let Some(dir) = args.get("artifacts") {
        return dir.to_string();
    }
    // shared probe: ./artifacts when compiled, else the built-in zoo so
    // train/compare/ratios work out of the box
    let dir = lags::runtime::default_artifacts_dir();
    if dir == "native" {
        eprintln!("note: no ./artifacts/manifest.json; using the built-in native zoo");
    }
    dir.to_string()
}

fn cmd_info(args: &Args) -> Result<()> {
    // info only inspects the manifest, so real artifact dirs work even in
    // a non-pjrt build; Runtime::open handles the "native" magic dir
    let dir = artifacts_dir(args);
    let man = if dir == "native" {
        lags::runtime::Runtime::open(&dir, args.usize_or("seed", 42)? as u64)?.manifest
    } else {
        lags::runtime::Manifest::load(&dir)?
    };
    println!("artifacts: {:?} (seed {})", man.dir, man.seed);
    {
        use lags::runtime::simd::Isa;
        let names: Vec<&str> = Isa::available().iter().map(|i| i.name()).collect();
        println!(
            "simd: dispatch {} (detected {}, available: {})",
            lags::runtime::simd::active().isa.name(),
            Isa::detect().name(),
            names.join(", ")
        );
    }
    println!("compress buckets: {:?}", man.compress_buckets);
    for (name, m) in &man.models {
        println!(
            "\nmodel {name}: d={} ({} layers, padded {}) metric={:?} classes={}",
            m.d,
            m.layers.len(),
            m.d_padded,
            m.metric,
            m.classes
        );
        println!("  x {:?} {:?}  y {:?} {:?}", m.x.shape, m.x.dtype, m.y.shape, m.y.dtype);
        if args.bool("layers") {
            for l in &m.layers {
                println!(
                    "  {:<14} size {:>8} off {:>8} bucket {:>7} flops {:.2e}",
                    l.name, l.size, l.offset, l.bucket, l.fwd_flops
                );
            }
        }
    }
    Ok(())
}

fn train_config(args: &Args) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default_for(&args.str_or("model", "mlp"));
    cfg.apply_args(args)?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = train_config(args)?;
    let mut t = if args.bool("resume") {
        anyhow::ensure!(
            !cfg.checkpoint_dir.is_empty(),
            "--resume needs --checkpoint-dir (where the checkpoint lives)"
        );
        Trainer::resume_from_dir(&cfg.checkpoint_dir)?
    } else {
        Trainer::from_artifacts(&artifacts_dir(args), cfg)?
    };
    run_and_report(&mut t, args)
}

/// `lags resume <dir>` — continue the run checkpointed in `<dir>`.
fn cmd_resume(args: &Args) -> Result<()> {
    let dir = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: lags resume <checkpoint-dir>"))?;
    let mut t = Trainer::resume_from_dir(dir)?;
    println!(
        "resuming {} {} at step {} / {} (checkpoint in {dir})",
        t.cfg.algorithm.name(),
        t.cfg.model,
        t.step_index(),
        t.cfg.steps,
    );
    run_and_report(&mut t, args)
}

/// Shared `train`/`resume` tail: run the remaining steps and print the
/// summary + adaptive + robustness lines (CI greps these).
fn run_and_report(t: &mut Trainer, args: &Args) -> Result<()> {
    let report = t.run()?;
    println!("{}", report.summary_line());
    if !report.selections.is_empty() {
        let traj: Vec<String> = report
            .selections
            .iter()
            .map(|s| format!("{:.0}@step{}", s.effective_cmax, s.step))
            .collect();
        println!(
            "adaptive: {} Eq. 18 selection(s) ({} online); effective c_max: {}",
            report.selections.len(),
            report.selections.len() - 1,
            traj.join(" -> ")
        );
    }
    let rb = &report.robustness;
    if !rb.worker_skew.is_empty() || rb.quorum > 0 || !rb.membership_log.is_empty() {
        println!(
            "robustness: quorum={} staleness_bound={} quorum_misses={} staleness_max={} \
             membership_changes={}",
            rb.quorum,
            rb.staleness_bound,
            rb.total_quorum_misses(),
            rb.max_staleness(),
            rb.membership_log.len(),
        );
    }
    if let Some(out) = args.get("out") {
        let w = ResultWriter::new(out)?;
        w.write_json("report.json", &report.to_json())?;
        w.write_csv("curve.csv", &report.curve)?;
        println!("wrote {}/report.json", out);
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let base = train_config(args)?;
    let mut rt = Runtime::open(artifacts_dir(args), base.seed)?;
    // same calibration policy as `train`: --calibrate measures + persists,
    // otherwise an existing calibration file is loaded; all three legs
    // share the runtime, so they price Eq. 18 identically
    rt.calibrate(base.calibrate)?;
    let rt = std::sync::Arc::new(rt);
    let mut rows = Vec::new();
    for alg in [Algorithm::Dense, Algorithm::Slgs, Algorithm::Lags] {
        let mut cfg = base.clone();
        cfg.algorithm = alg;
        if alg != Algorithm::Lags {
            // online re-selection only exists on the LAGS path; the other
            // legs of the comparison run their fixed schedules
            cfg.reselect_every = 0;
        }
        let mut t = Trainer::with_runtime(&rt, cfg)?;
        let r = t.run()?;
        println!("{}", r.summary_line());
        rows.push(r);
    }
    if let Some(out) = args.get("out") {
        let w = ResultWriter::new(out)?;
        let j = Json::Arr(rows.iter().map(|r| r.to_json()).collect());
        w.write_json("compare.json", &j)?;
        for r in &rows {
            w.write_csv(&format!("curve_{}.csv", r.algorithm.name()), &r.curve)?;
        }
        println!("wrote {}/compare.json", out);
    }
    Ok(())
}

fn cmd_delta(args: &Args) -> Result<()> {
    let mut cfg = train_config(args)?;
    cfg.algorithm = Algorithm::Lags;
    cfg.delta_every = args.usize_or("every", 5)?;
    let mut t = Trainer::from_artifacts(&artifacts_dir(args), cfg)?;
    let report = t.run()?;
    println!("{}", report.summary_line());
    println!(
        "delta holds (<=1) for {:.1}% of samples; max delta = {:.4}",
        100.0 * report.delta_fraction_holding.unwrap_or(f64::NAN),
        report.delta_max.unwrap_or(f64::NAN)
    );
    if let Some(out) = args.get("out") {
        let w = ResultWriter::new(out)?;
        let series = t.delta_series().expect("delta monitor active");
        let names: Vec<String> =
            t.model_manifest().layers.iter().map(|l| l.name.clone()).collect();
        let cols: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let mut rec = CurveRecorder::new(&cols);
        // series share the same sampled step grid
        if let Some(first) = series.first() {
            for (row_i, &(step, _)) in first.iter().enumerate() {
                let vals: Vec<f64> = series
                    .iter()
                    .map(|s| s.get(row_i).map(|&(_, d)| d).unwrap_or(f64::NAN))
                    .collect();
                rec.push(step, &vals);
            }
        }
        w.write_csv("delta.csv", &rec)?;
        w.write_csv("loss.csv", &report.curve)?;
        println!("wrote {}/delta.csv", out);
    }
    Ok(())
}

/// α–β parameters from the shared `--net*` surface: `--net PRESET` first,
/// then `--net-alpha`/`--net-bandwidth` overrides (the legacy
/// `--alpha`/`--bandwidth` spellings are still accepted).
fn net_config_from_args(args: &Args) -> Result<NetConfig> {
    let mut net = match args.get("net") {
        Some(p) => NetConfig::preset(p)?,
        None => NetConfig::gige16(),
    };
    net.alpha = args.f64_or("alpha", net.alpha)?;
    net.alpha = args.f64_or("net-alpha", net.alpha)?;
    net.bandwidth = args.f64_or("bandwidth", net.bandwidth)?;
    net.bandwidth = args.f64_or("net-bandwidth", net.bandwidth)?;
    Ok(net)
}

fn network_from_args(args: &Args) -> Result<NetworkModel> {
    Ok(net_config_from_args(args)?.model(args.usize_or("workers", 16)?))
}

fn cmd_table2(args: &Args) -> Result<()> {
    let net = network_from_args(args)?;
    println!(
        "Table 2 reproduction — P={} alpha={} B={}/s  (paper: 16x P102-100, 1GbE)",
        net.workers,
        fmt_secs(net.alpha),
        fmt_bytes(net.bandwidth)
    );
    println!(
        "| {:<13} | {:>7} | {:>7} | {:>7} | {:>5} | {:>5} | {:>5} |",
        "Model", "Dense", "SLGS", "LAGS", "S1", "S2", "Smax"
    );
    let mut rows = Vec::new();
    for m in zoo::table2_models() {
        let c = if m.name == "lstm_ptb" { 250.0 } else { 1000.0 };
        let sp = SimParams::uniform(&m, c);
        let dense = simulate(&m, &net, Schedule::DensePipelined, &SimParams::dense(&m));
        let slgs = simulate(&m, &net, Schedule::Slgs, &sp);
        let lgs = simulate(&m, &net, Schedule::Lags, &sp);
        let s1 = dense.iter_time / lgs.iter_time;
        let s2 = slgs.iter_time / lgs.iter_time;
        let smax = perf_model::smax(m.t_f, m.t_b(), slgs.t_comm);
        println!(
            "| {:<13} | {:>6.3}s | {:>6.3}s | {:>6.3}s | {:>5.2} | {:>5.2} | {:>5.2} |",
            m.name, dense.iter_time, slgs.iter_time, lgs.iter_time, s1, s2, smax
        );
        rows.push(Json::obj(vec![
            ("model", Json::Str(m.name.clone())),
            ("dense", Json::Num(dense.iter_time)),
            ("slgs", Json::Num(slgs.iter_time)),
            ("lags", Json::Num(lgs.iter_time)),
            ("s1", Json::Num(s1)),
            ("s2", Json::Num(s2)),
            ("smax", Json::Num(smax)),
            ("pipelining_benefit_fraction", Json::Num((s2 - 1.0) / (smax - 1.0))),
        ]));
    }
    if let Some(out) = args.get("out") {
        ResultWriter::new(out)?.write_json("table2.json", &Json::Arr(rows))?;
        println!("wrote {}/table2.json", out);
    }
    Ok(())
}

fn cmd_timeline(args: &Args) -> Result<()> {
    let name = args.str_or("profile", "resnet50");
    let m = zoo::by_name(&name).ok_or_else(|| anyhow::anyhow!("unknown profile {name}"))?;
    let net = network_from_args(args)?;
    let c = args.f64_or("compression", 1000.0)?;
    for (sched, label, p) in [
        (Schedule::DensePipelined, "Dense-SGD (Fig 1a)", SimParams::dense(&m)),
        (Schedule::Slgs, "SLGS-SGD  (Fig 1b)", SimParams::uniform(&m, c)),
        (Schedule::Lags, "LAGS-SGD  (Fig 1c)", SimParams::uniform(&m, c)),
    ] {
        let b = simulate(&m, &net, sched, &p);
        println!(
            "\n{label}: iter={} comp={} comm={} hidden={}",
            fmt_secs(b.iter_time),
            fmt_secs(b.t_f + b.t_b),
            fmt_secs(b.t_comm),
            fmt_secs(b.hidden)
        );
        let show = args.usize_or("events", 8)?;
        for e in b.events.iter().take(show) {
            println!(
                "  {:<22} ready {:>9} start {:>9} end {:>9} ({})",
                e.name,
                fmt_secs(e.ready),
                fmt_secs(e.start),
                fmt_secs(e.end),
                fmt_bytes(e.wire_bytes)
            );
        }
        if b.events.len() > show {
            println!("  ... {} more events", b.events.len() - show);
        }
    }
    Ok(())
}

fn cmd_ratios(args: &Args) -> Result<()> {
    if let Some(name) = args.get("profile") {
        // DES zoo profile mode (the paper's published evaluation models)
        let m = zoo::by_name(name).ok_or_else(|| anyhow::anyhow!("unknown profile {name}"))?;
        let net = network_from_args(args)?;
        let c_max = args.f64_or("c-max", 1000.0)?;
        anyhow::ensure!(c_max >= 1.0 && c_max.is_finite(), "--c-max must be >= 1");
        let cfg = RatioConfig { c_max, ..RatioConfig::default() };
        let ratios = adaptive::select_ratios(&m, &net, &cfg);
        println!(
            "Eq. 18 adaptive ratios for {name} (P={}, alpha={}, B={}/s, c_u = {}):",
            net.workers,
            fmt_secs(net.alpha),
            fmt_bytes(net.bandwidth),
            cfg.c_max
        );
        print_ratio_table(m.layers.iter().map(|l| (l.name.as_str(), l.params)), &ratios, &net);
        println!("effective c_max = {:.1}", adaptive::ratio::effective_cmax(&ratios));
        return Ok(());
    }
    // Live-model mode: EXACTLY the initial selection `train --adaptive`
    // makes for the same flags — same manifest profile, same synthetic
    // device speed, same network, same worker count (train_config applies
    // the identical --workers/--c-max/--net* defaults and overrides).
    let mut tc = train_config(args)?;
    // honour the legacy --alpha/--bandwidth spellings here too (the
    // --profile mode accepts them via net_config_from_args); the --net-*
    // spellings, already applied by train_config, take precedence
    if args.get("net-alpha").is_none() {
        tc.net.alpha = args.f64_or("alpha", tc.net.alpha)?;
    }
    if args.get("net-bandwidth").is_none() {
        tc.net.bandwidth = args.f64_or("bandwidth", tc.net.bandwidth)?;
    }
    let mut rt = Runtime::open(artifacts_dir(args), tc.seed)?;
    rt.calibrate(tc.calibrate)?;
    let mm = rt.manifest.model(&tc.model)?;
    let net = tc.net.model(tc.workers);
    let rc = RatioConfig { c_max: tc.c_max, ..RatioConfig::default() };
    let ratios = adaptive::select_ratios_manifest(mm, rt.device_flops(), &net, &rc);
    println!(
        "Eq. 18 initial selection for model {} (P={}, alpha={}, B={}/s, c_u = {}):",
        tc.model,
        tc.workers,
        fmt_secs(net.alpha),
        fmt_bytes(net.bandwidth),
        rc.c_max
    );
    println!(
        "device flops: {:.3e}/s (source: {}; isa: {})",
        rt.device_flops(),
        rt.flops_source(),
        lags::runtime::simd::active().isa.name()
    );
    if tc.workers <= 1 {
        println!("(P = 1: no communication to hide — all layers dense, c = 1)");
    }
    print_ratio_table(mm.layers.iter().map(|l| (l.name.as_str(), l.size)), &ratios, &net);
    println!("effective c_max = {:.1}", adaptive::ratio::effective_cmax(&ratios));
    println!("(this is the selection `lags train --adaptive` starts from with the same flags;");
    println!(" add --reselect-every N to re-run it online from measured timings)");
    Ok(())
}

/// Measure sustained device flops at the zoo's actual GEMM shapes and
/// persist the calibration next to the artifacts, so `train --adaptive`
/// and `ratios` price Eq. 18 with the measured number from now on.
fn cmd_calibrate(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let rt = Runtime::open(&dir, args.usize_or("seed", 42)? as u64)?;
    anyhow::ensure!(
        rt.supports_calibration(),
        "the {} backend's device speed cannot be measured by the host GEMM microbenchmark",
        rt.platform()
    );
    let budget_ms = args.usize_or("budget-ms", DEFAULT_BUDGET.as_millis() as usize)?;
    anyhow::ensure!(budget_ms > 0, "--budget-ms must be >= 1");
    let budget = std::time::Duration::from_millis(budget_ms as u64);
    let mut cal = Calibration::measure(&rt.manifest, budget)?;
    println!(
        "GEMM microbenchmark over the {} zoo ({} shapes, ~{budget_ms}ms budget):",
        dir,
        cal.shapes.len()
    );
    println!("| {:<22} | {:>5} | {:>5} | {:>5} | {:>10} |", "shape", "m", "k", "n", "GFLOP/s");
    for s in &cal.shapes {
        println!(
            "| {:<22} | {:>5} | {:>5} | {:>5} | {:>10.2} |",
            s.label,
            s.m,
            s.k,
            s.n,
            s.flops_per_sec / 1e9
        );
    }
    println!(
        "sustained: {:.3e} flops/s ({:.2} GFLOP/s) — vs the DEVICE_FLOPS fallback {:.1e}",
        cal.flops_per_sec,
        cal.flops_per_sec / 1e9,
        lags::models::DEVICE_FLOPS
    );
    println!("kernel isa: {} (recorded in the calibration as provenance)", cal.isa);
    let default_path = Calibration::default_path(std::path::Path::new(&dir));
    let path = match args.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => default_path.clone(),
    };
    cal.save(&path)?;
    if path == default_path {
        println!(
            "wrote {} (picked up by `lags train`/`lags ratios` for these artifacts)",
            path.display()
        );
    } else {
        println!(
            "wrote {} — note: train/ratios only load {}; --out is for inspection/archival",
            path.display(),
            default_path.display()
        );
    }
    Ok(())
}

/// Shared `lags ratios` table body (layers in the iterator's order). The
/// k column comes from `adaptive::ks_from_ratios` — the exact convention
/// the trainer uses — so the printed k^(l) IS the trainer's k^(l).
fn print_ratio_table<'a, I: Iterator<Item = (&'a str, usize)>>(
    layers: I,
    ratios: &[f64],
    net: &NetworkModel,
) {
    let rows: Vec<(&str, usize)> = layers.collect();
    let sizes: Vec<usize> = rows.iter().map(|&(_, d)| d).collect();
    let ks = adaptive::ks_from_ratios(&sizes, ratios);
    println!(
        "| {:<22} | {:>9} | {:>8} | {:>9} | {:>9} |",
        "layer", "d^(l)", "c^(l)", "k^(l)", "t_comm"
    );
    for ((&(name, d), &c), &k) in rows.iter().zip(ratios.iter()).zip(ks.iter()) {
        println!(
            "| {:<22} | {:>9} | {:>8.1} | {:>9} | {:>9} |",
            name,
            d,
            c,
            k,
            fmt_secs(net.allgather_sparse(k as f64))
        );
    }
}

/// Bandwidth-sensitivity sweep: at which interconnect speed does each
/// technique stop paying? (The paper's motivation section: sparsification
/// targets slow commodity networks like 1GbE.)
fn cmd_sweep(args: &Args) -> Result<()> {
    let name = args.str_or("profile", "resnet50");
    let m = zoo::by_name(&name).ok_or_else(|| anyhow::anyhow!("unknown profile {name}"))?;
    let c = args.f64_or("compression", 1000.0)?;
    let workers = args.usize_or("workers", 16)?;
    let alpha = net_config_from_args(args)?.alpha;
    println!("bandwidth sweep for {name} (P={workers}, c={c}, alpha={}):", fmt_secs(alpha));
    println!(
        "| {:>10} | {:>8} | {:>8} | {:>8} | {:>6} | {:>6} |",
        "bandwidth", "dense", "slgs", "lags", "S1", "S2"
    );
    for exp in 0..=8 {
        // 12.5 MB/s (100 Mb) .. 3.2 GB/s (25 Gb), x2 steps
        let bw = 12.5e6 * (2f64).powi(exp);
        let net = NetworkModel { alpha, bandwidth: bw, workers };
        let sp = SimParams::uniform(&m, c);
        let dense = simulate(&m, &net, Schedule::DensePipelined, &SimParams::dense(&m));
        let slgs = simulate(&m, &net, Schedule::Slgs, &sp);
        let lags = simulate(&m, &net, Schedule::Lags, &sp);
        println!(
            "| {:>10} | {:>7.3}s | {:>7.3}s | {:>7.3}s | {:>6.2} | {:>6.2} |",
            fmt_bytes(bw),
            dense.iter_time,
            slgs.iter_time,
            lags.iter_time,
            dense.iter_time / lags.iter_time,
            slgs.iter_time / lags.iter_time
        );
    }
    println!("(sparsification's S1 shrinks toward 1 as bandwidth grows — the paper's");
    println!(" premise that gradient compression targets slow commodity interconnects)");
    Ok(())
}

/// `lags audit` — run the determinism-contract lint over the source tree
/// and write the machine-readable report. Same driver as the standalone
/// `lags-audit` bin.
fn cmd_audit(args: &Args) -> Result<()> {
    let root = args.str_or("root", "rust/src");
    let json = args.str_or("json", "audit.json");
    lags::analysis::audit::run_cli(std::path::Path::new(&root), Some(std::path::Path::new(&json)))
}

/// `lags validate` — the Assumption-1 δ-gate over the compressor zoo.
/// Writes validation.json and exits non-zero on any δ > 1 + tol sample
/// (see `analysis::validate` for the matrix and tolerance rationale).
fn cmd_validate(args: &Args) -> Result<()> {
    let seed = args.usize_or("seed", 42)? as u64;
    let mut spec = if args.bool("quick") {
        lags::analysis::ValidateSpec::quick(seed)
    } else {
        lags::analysis::ValidateSpec::full(seed)
    };
    spec.steps = args.usize_or("steps", spec.steps)?;
    spec.workers = args.usize_or("workers", spec.workers)?;
    spec.inject_violation = args.bool("inject-violation");
    anyhow::ensure!(spec.steps > spec.delta_every, "--steps must exceed the delta cadence");
    let dir = artifacts_dir(args);
    println!(
        "validate ({} matrix): {} model(s) x {} compressor(s), {} steps, tol {}",
        spec.mode,
        spec.models.len(),
        spec.compressors.len() + usize::from(spec.inject_violation),
        spec.steps,
        spec.tolerance
    );
    let report = lags::analysis::validate::run(&dir, &spec)?;
    for leg in &report.results {
        println!("{}", leg.summary_line());
    }
    let out = args.str_or("out", "validation");
    let w = ResultWriter::new(&out)?;
    w.write_json("validation.json", &report.to_json())?;
    println!("wrote {}/validation.json", out);
    anyhow::ensure!(
        report.pass,
        "Assumption-1 gate FAILED: {} of {} legs have delta > 1 + {} samples \
         (see {}/validation.json)",
        report.results.iter().filter(|r| !r.pass).count(),
        report.results.len(),
        report.tolerance,
        out
    );
    println!("Assumption-1 gate PASSED ({} legs)", report.results.len());
    Ok(())
}

/// `lags perf-diff <old.json> <new.json>` — diff two bench snapshots (the
/// `{"benches": [...]}` documents `util::bench::write_json` emits) on
/// `ns_per_iter`. Shared rows slower by more than `--tolerance` (default
/// 10%) fail the diff; added/removed rows only inform (bench sets grow
/// across PRs). This is the CI perf-trend gate over BENCH_gemm.json.
fn cmd_perf_diff(args: &Args) -> Result<()> {
    let (Some(old_path), Some(new_path)) = (args.positional.first(), args.positional.get(1)) else {
        anyhow::bail!("usage: lags perf-diff <old.json> <new.json> [--tolerance 0.10]");
    };
    let tol = args.f64_or("tolerance", 0.10)?;
    anyhow::ensure!(tol.is_finite() && tol >= 0.0, "--tolerance must be a finite ratio >= 0");
    let load = |p: &str| -> Result<Vec<(String, f64)>> {
        let text = std::fs::read_to_string(p)
            .map_err(|e| anyhow::anyhow!("reading bench snapshot {p}: {e}"))?;
        let doc = Json::parse(&text)?;
        let mut rows = Vec::new();
        for r in doc.get("benches")?.as_arr()? {
            rows.push((r.get("name")?.as_str()?.to_string(), r.get("ns_per_iter")?.as_f64()?));
        }
        Ok(rows)
    };
    let old = load(old_path)?;
    let new = load(new_path)?;
    println!("perf-diff {old_path} -> {new_path} (tolerance +{:.0}%):", tol * 100.0);
    println!("| {:<40} | {:>12} | {:>12} | {:>8} |", "bench", "old ns", "new ns", "delta");
    let mut regressions = Vec::new();
    let mut compared = 0usize;
    for (name, new_ns) in &new {
        match old.iter().find(|(n, _)| n == name) {
            Some((_, old_ns)) if *old_ns > 0.0 => {
                compared += 1;
                let delta = (new_ns - old_ns) / old_ns;
                println!(
                    "| {:<40} | {:>12.1} | {:>12.1} | {:>+7.1}% |",
                    name,
                    old_ns,
                    new_ns,
                    delta * 100.0
                );
                if delta > tol {
                    regressions
                        .push(format!("{name}: {old_ns:.1}ns -> {new_ns:.1}ns ({:+.1}%)", delta * 100.0));
                }
            }
            _ => println!("| {:<40} | {:>12} | {:>12.1} | {:>8} |", name, "-", new_ns, "added"),
        }
    }
    for (name, old_ns) in &old {
        if !new.iter().any(|(n, _)| n == name) {
            println!("| {:<40} | {:>12.1} | {:>12} | {:>8} |", name, old_ns, "-", "removed");
        }
    }
    anyhow::ensure!(
        regressions.is_empty(),
        "perf regression beyond the +{:.0}% tolerance:\n  {}",
        tol * 100.0,
        regressions.join("\n  ")
    );
    println!("perf-diff OK: {compared} shared row(s), none more than {:.0}% slower", tol * 100.0);
    Ok(())
}

fn cmd_smax(args: &Args) -> Result<()> {
    let t_f = args.f64_or("tf", 0.21)?;
    let t_b = args.f64_or("tb", 0.41)?;
    println!("Eq. 19 S_max sweep (t_f={t_f}s, t_b={t_b}s):");
    println!("| {:>6} | {:>6} |", "r", "S_max");
    for i in 0..=20 {
        let r = 0.1 * (10f64).powf(i as f64 / 10.0); // 0.1 .. 10, log grid
        let s = perf_model::smax(t_f, t_b, r * t_b);
        println!("| {:>6.2} | {:>6.3} |", r, s);
    }
    Ok(())
}
