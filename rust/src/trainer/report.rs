//! Training run reports: everything the experiment harnesses print/save.

use super::Algorithm;
use crate::metrics::CurveRecorder;
use crate::util::json::Json;

/// Communication volume accounting (what crossed the simulated wire).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MessageStats {
    pub total_bytes: usize,
    pub total_messages: usize,
    pub iterations: usize,
}

impl MessageStats {
    pub fn record(&mut self, bytes: usize, messages: usize) {
        self.total_bytes += bytes;
        self.total_messages += messages;
        self.iterations += 1;
    }

    pub fn bytes_per_iter(&self) -> f64 {
        if self.iterations == 0 {
            return 0.0;
        }
        self.total_bytes as f64 / self.iterations as f64
    }

    pub fn messages_per_iter(&self) -> f64 {
        if self.iterations == 0 {
            return 0.0;
        }
        self.total_messages as f64 / self.iterations as f64
    }
}

/// Result of one full training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub algorithm: Algorithm,
    pub model: String,
    pub steps: usize,
    pub final_loss: f64,
    pub final_eval_loss: f64,
    /// accuracy in [0,1] or LM loss (ppl = exp)
    pub final_metric: f64,
    pub metric_name: String,
    pub curve: CurveRecorder,
    /// fraction of delta^(l) samples <= 1 (None if not monitored)
    pub delta_fraction_holding: Option<f64>,
    pub delta_max: Option<f64>,
    pub msg_stats: MessageStats,
    /// actual wall-clock of this CPU run
    pub wall_seconds: f64,
    /// hot-loop schedule this run used ("barrier" | "overlap")
    pub pipeline: String,
    /// measured aggregator busy time across the run (zero + reduce +
    /// apply), the real-trainer analogue of the DES's t_comm
    pub measured_comm_seconds: f64,
    /// measured busy time hidden under still-running compute
    pub measured_hidden_seconds: f64,
    /// measured hidden / busy in [0,1] (0 for barrier runs)
    pub overlap_efficiency: f64,
    /// DES-simulated per-iteration time on the paper's 16-node 1GbE testbed
    pub sim_iter_seconds: f64,
    pub sim_hidden_seconds: f64,
    /// DES-predicted hidden / t_comm — compare against `overlap_efficiency`
    pub sim_overlap_efficiency: f64,
}

impl TrainReport {
    /// Human metric: accuracy as-is, perplexity = exp(loss) for LMs.
    pub fn headline_metric(&self) -> f64 {
        if self.metric_name == "ppl_loss" {
            self.final_metric.exp()
        } else {
            self.final_metric
        }
    }

    pub fn headline_name(&self) -> &'static str {
        if self.metric_name == "ppl_loss" {
            "perplexity"
        } else {
            "accuracy"
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("algorithm", Json::Str(self.algorithm.name().into())),
            ("model", Json::Str(self.model.clone())),
            ("steps", Json::Num(self.steps as f64)),
            ("final_loss", Json::Num(self.final_loss)),
            ("final_eval_loss", Json::Num(self.final_eval_loss)),
            ("final_metric", Json::Num(self.final_metric)),
            ("headline_metric", Json::Num(self.headline_metric())),
            ("metric_name", Json::Str(self.metric_name.clone())),
            (
                "delta_fraction_holding",
                self.delta_fraction_holding.map(Json::Num).unwrap_or(Json::Null),
            ),
            ("delta_max", self.delta_max.map(Json::Num).unwrap_or(Json::Null)),
            ("bytes_per_iter", Json::Num(self.msg_stats.bytes_per_iter())),
            ("messages_per_iter", Json::Num(self.msg_stats.messages_per_iter())),
            ("wall_seconds", Json::Num(self.wall_seconds)),
            ("pipeline", Json::Str(self.pipeline.clone())),
            ("measured_comm_seconds", Json::Num(self.measured_comm_seconds)),
            ("measured_hidden_seconds", Json::Num(self.measured_hidden_seconds)),
            ("overlap_efficiency", Json::Num(self.overlap_efficiency)),
            ("sim_iter_seconds", Json::Num(self.sim_iter_seconds)),
            ("sim_hidden_seconds", Json::Num(self.sim_hidden_seconds)),
            ("sim_overlap_efficiency", Json::Num(self.sim_overlap_efficiency)),
        ])
    }

    pub fn summary_line(&self) -> String {
        format!(
            "{:<6} {:<12} steps={:<5} loss={:.4} {}={:.4} bytes/iter={:.0} sim_iter={:.4}s",
            self.algorithm.name(),
            self.model,
            self.steps,
            self.final_loss,
            self.headline_name(),
            self.headline_metric(),
            self.msg_stats.bytes_per_iter(),
            self.sim_iter_seconds
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn message_stats_averages() {
        let mut m = MessageStats::default();
        m.record(100, 2);
        m.record(300, 4);
        assert_eq!(m.bytes_per_iter(), 200.0);
        assert_eq!(m.messages_per_iter(), 3.0);
        let empty = MessageStats::default();
        assert_eq!(empty.bytes_per_iter(), 0.0);
    }

    #[test]
    fn headline_metric_ppl() {
        let r = TrainReport {
            algorithm: Algorithm::Lags,
            model: "m".into(),
            steps: 1,
            final_loss: 1.0,
            final_eval_loss: 1.0,
            final_metric: 2.0,
            metric_name: "ppl_loss".into(),
            curve: CurveRecorder::new(&["train_loss"]),
            delta_fraction_holding: None,
            delta_max: None,
            msg_stats: MessageStats::default(),
            wall_seconds: 0.0,
            pipeline: "overlap".into(),
            measured_comm_seconds: 0.0,
            measured_hidden_seconds: 0.0,
            overlap_efficiency: 0.0,
            sim_iter_seconds: 0.0,
            sim_hidden_seconds: 0.0,
            sim_overlap_efficiency: 0.0,
        };
        assert!((r.headline_metric() - 2.0f64.exp()).abs() < 1e-12);
        assert_eq!(r.headline_name(), "perplexity");
        // json serializes
        let j = r.to_json();
        assert_eq!(j.get("algorithm").unwrap().as_str().unwrap(), "lags");
    }
}
