//! Training run reports: everything the experiment harnesses print/save.

use super::Algorithm;
use crate::metrics::{delta_to_json, CurveRecorder};
use crate::util::json::Json;

/// Communication volume accounting (what crossed the simulated wire).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MessageStats {
    pub total_bytes: usize,
    pub total_messages: usize,
    pub iterations: usize,
}

impl MessageStats {
    pub fn record(&mut self, bytes: usize, messages: usize) {
        self.total_bytes += bytes;
        self.total_messages += messages;
        self.iterations += 1;
    }

    pub fn bytes_per_iter(&self) -> f64 {
        if self.iterations == 0 {
            return 0.0;
        }
        self.total_bytes as f64 / self.iterations as f64
    }

    pub fn messages_per_iter(&self) -> f64 {
        if self.iterations == 0 {
            return 0.0;
        }
        self.total_messages as f64 / self.iterations as f64
    }
}

/// One Eq. 18 ratio-selection event: the initial startup selection
/// (step 0) plus every online re-selection from the measured profile
/// (`--adaptive --reselect-every N`).
#[derive(Debug, Clone, PartialEq)]
pub struct RatioSelection {
    /// steps completed when the selection took effect (0 = startup)
    pub step: usize,
    /// max over the per-layer ratios — Corollary 2's effective global
    /// compression
    pub effective_cmax: f64,
    /// per-layer ratios, manifest order
    pub ratios: Vec<f64>,
}

impl RatioSelection {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("step", Json::Num(self.step as f64)),
            ("effective_cmax", Json::Num(self.effective_cmax)),
            ("ratios", Json::Arr(self.ratios.iter().map(|&r| Json::Num(r)).collect())),
        ])
    }
}

/// One worker's compute skew as configured by the fault plan, plus how
/// many steps it was actually a member for (drops/joins shorten/extend).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerSkew {
    /// stable worker uid
    pub worker: usize,
    /// multiplicative compute-time skew (1.0 = nominal)
    pub skew: f64,
    /// steps this worker was a cluster member
    pub steps_active: usize,
}

/// One elastic-membership event as it was applied by the trainer.
#[derive(Debug, Clone, PartialEq)]
pub struct MembershipChange {
    /// step index the event took effect BEFORE (events apply between steps)
    pub step: usize,
    /// "drop" | "join"
    pub action: String,
    /// stable worker uid
    pub worker: usize,
    /// cluster size after the event applied
    pub workers_after: usize,
}

/// Robustness telemetry for a run under a fault plan / quorum mode
/// (satellite: stable field names — CI and downstream tooling key on
/// them). All-default for a clean full-sync run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RobustnessStats {
    /// per-worker configured skew + membership duration
    pub worker_skew: Vec<WorkerSkew>,
    /// per-layer count of (step × excluded worker) quorum misses,
    /// manifest order
    pub quorum_miss_per_layer: Vec<u64>,
    /// staleness histogram: index s counts re-inclusions after s
    /// consecutive missed steps (index 0 = included with no backlog)
    pub staleness_hist: Vec<u64>,
    /// applied drop/join events in order
    pub membership_log: Vec<MembershipChange>,
    /// configured quorum size (0 = full sync)
    pub quorum: usize,
    /// configured staleness bound (0 = unbounded)
    pub staleness_bound: usize,
}

impl RobustnessStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "worker_skew",
                Json::Arr(
                    self.worker_skew
                        .iter()
                        .map(|w| {
                            Json::obj(vec![
                                ("worker", Json::Num(w.worker as f64)),
                                ("skew", Json::Num(w.skew)),
                                ("steps_active", Json::Num(w.steps_active as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "quorum_miss_per_layer",
                Json::Arr(self.quorum_miss_per_layer.iter().map(|&c| Json::Num(c as f64)).collect()),
            ),
            (
                "staleness_hist",
                Json::Arr(self.staleness_hist.iter().map(|&c| Json::Num(c as f64)).collect()),
            ),
            (
                "membership_log",
                Json::Arr(
                    self.membership_log
                        .iter()
                        .map(|m| {
                            Json::obj(vec![
                                ("step", Json::Num(m.step as f64)),
                                ("action", Json::Str(m.action.clone())),
                                ("worker", Json::Num(m.worker as f64)),
                                ("workers_after", Json::Num(m.workers_after as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("quorum", Json::Num(self.quorum as f64)),
            ("staleness_bound", Json::Num(self.staleness_bound as f64)),
        ])
    }

    /// Total quorum misses across layers (summary-line diagnostic).
    pub fn total_quorum_misses(&self) -> u64 {
        self.quorum_miss_per_layer.iter().sum()
    }

    /// Largest staleness observed at a re-inclusion (0 if none).
    pub fn max_staleness(&self) -> usize {
        self.staleness_hist.iter().rposition(|&c| c > 0).unwrap_or(0)
    }
}

/// Result of one full training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub algorithm: Algorithm,
    pub model: String,
    pub steps: usize,
    pub final_loss: f64,
    pub final_eval_loss: f64,
    /// accuracy in [0,1] or LM loss (ppl = exp)
    pub final_metric: f64,
    pub metric_name: String,
    pub curve: CurveRecorder,
    /// fraction of delta^(l) samples <= 1 (None if not monitored)
    pub delta_fraction_holding: Option<f64>,
    pub delta_max: Option<f64>,
    pub msg_stats: MessageStats,
    /// actual wall-clock of this CPU run
    pub wall_seconds: f64,
    /// hot-loop schedule this run used ("barrier" | "overlap")
    pub pipeline: String,
    /// measured aggregator busy time across the run (zero + reduce +
    /// apply), the real-trainer analogue of the DES's t_comm
    pub measured_comm_seconds: f64,
    /// measured busy time hidden under still-running compute
    pub measured_hidden_seconds: f64,
    /// measured hidden / busy in [0,1] (0 for barrier runs)
    pub overlap_efficiency: f64,
    /// DES-simulated per-iteration time on the configured network at the
    /// configured worker count
    pub sim_iter_seconds: f64,
    pub sim_hidden_seconds: f64,
    /// DES-predicted hidden / t_comm — compare against `overlap_efficiency`
    pub sim_overlap_efficiency: f64,
    /// α of the configured interconnect this run priced comm with
    pub net_alpha: f64,
    /// bandwidth (bytes/s) of the configured interconnect
    pub net_bandwidth: f64,
    /// device speed (flops/s) Eq. 18 and the DES priced compute with
    pub device_flops: f64,
    /// provenance of `device_flops`: "calibrated (...)" when a measured
    /// calibration was attached, else the documented fallback constant
    pub flops_source: String,
    /// Eq. 18 selection history: startup selection + every online
    /// re-selection (empty for non-adaptive runs)
    pub selections: Vec<RatioSelection>,
    /// fault/quorum telemetry (all-default for a clean full-sync run)
    pub robustness: RobustnessStats,
}

impl TrainReport {
    /// Human metric: accuracy as-is, perplexity = exp(loss) for LMs.
    pub fn headline_metric(&self) -> f64 {
        if self.metric_name == "ppl_loss" {
            self.final_metric.exp()
        } else {
            self.final_metric
        }
    }

    pub fn headline_name(&self) -> &'static str {
        if self.metric_name == "ppl_loss" {
            "perplexity"
        } else {
            "accuracy"
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("algorithm", Json::Str(self.algorithm.name().into())),
            ("model", Json::Str(self.model.clone())),
            ("steps", Json::Num(self.steps as f64)),
            ("final_loss", Json::Num(self.final_loss)),
            ("final_eval_loss", Json::Num(self.final_eval_loss)),
            ("final_metric", Json::Num(self.final_metric)),
            ("headline_metric", Json::Num(self.headline_metric())),
            ("metric_name", Json::Str(self.metric_name.clone())),
            (
                "delta_fraction_holding",
                self.delta_fraction_holding.map(delta_to_json).unwrap_or(Json::Null),
            ),
            ("delta_max", self.delta_max.map(delta_to_json).unwrap_or(Json::Null)),
            ("bytes_per_iter", Json::Num(self.msg_stats.bytes_per_iter())),
            ("messages_per_iter", Json::Num(self.msg_stats.messages_per_iter())),
            ("wall_seconds", Json::Num(self.wall_seconds)),
            ("pipeline", Json::Str(self.pipeline.clone())),
            ("measured_comm_seconds", Json::Num(self.measured_comm_seconds)),
            ("measured_hidden_seconds", Json::Num(self.measured_hidden_seconds)),
            ("overlap_efficiency", Json::Num(self.overlap_efficiency)),
            ("sim_iter_seconds", Json::Num(self.sim_iter_seconds)),
            ("sim_hidden_seconds", Json::Num(self.sim_hidden_seconds)),
            ("sim_overlap_efficiency", Json::Num(self.sim_overlap_efficiency)),
            (
                "net",
                Json::obj(vec![
                    ("alpha", Json::Num(self.net_alpha)),
                    ("bandwidth", Json::Num(self.net_bandwidth)),
                ]),
            ),
            ("device_flops", Json::Num(self.device_flops)),
            ("flops_source", Json::Str(self.flops_source.clone())),
            (
                "ratio_selections",
                Json::Arr(self.selections.iter().map(RatioSelection::to_json).collect()),
            ),
            ("robustness", self.robustness.to_json()),
        ])
    }

    pub fn summary_line(&self) -> String {
        format!(
            "{:<6} {:<12} steps={:<5} loss={:.4} {}={:.4} bytes/iter={:.0} sim_iter={:.4}s",
            self.algorithm.name(),
            self.model,
            self.steps,
            self.final_loss,
            self.headline_name(),
            self.headline_metric(),
            self.msg_stats.bytes_per_iter(),
            self.sim_iter_seconds
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn message_stats_averages() {
        let mut m = MessageStats::default();
        m.record(100, 2);
        m.record(300, 4);
        assert_eq!(m.bytes_per_iter(), 200.0);
        assert_eq!(m.messages_per_iter(), 3.0);
        let empty = MessageStats::default();
        assert_eq!(empty.bytes_per_iter(), 0.0);
    }

    #[test]
    fn headline_metric_ppl() {
        let r = TrainReport {
            algorithm: Algorithm::Lags,
            model: "m".into(),
            steps: 1,
            final_loss: 1.0,
            final_eval_loss: 1.0,
            final_metric: 2.0,
            metric_name: "ppl_loss".into(),
            curve: CurveRecorder::new(&["train_loss"]),
            delta_fraction_holding: None,
            delta_max: None,
            msg_stats: MessageStats::default(),
            wall_seconds: 0.0,
            pipeline: "overlap".into(),
            measured_comm_seconds: 0.0,
            measured_hidden_seconds: 0.0,
            overlap_efficiency: 0.0,
            sim_iter_seconds: 0.0,
            sim_hidden_seconds: 0.0,
            sim_overlap_efficiency: 0.0,
            net_alpha: 5e-4,
            net_bandwidth: 111e6,
            device_flops: 1e9,
            flops_source: "DEVICE_FLOPS fallback".into(),
            selections: vec![RatioSelection {
                step: 0,
                effective_cmax: 250.0,
                ratios: vec![1.0, 250.0],
            }],
            robustness: RobustnessStats::default(),
        };
        assert!((r.headline_metric() - 2.0f64.exp()).abs() < 1e-12);
        assert_eq!(r.headline_name(), "perplexity");
        // json serializes, with the net config + selection history aboard
        let j = r.to_json();
        assert_eq!(j.get("algorithm").unwrap().as_str().unwrap(), "lags");
        assert_eq!(j.get("net").unwrap().get("alpha").unwrap().as_f64().unwrap(), 5e-4);
        let sels = j.get("ratio_selections").unwrap().as_arr().unwrap();
        assert_eq!(sels.len(), 1);
        assert_eq!(sels[0].get("effective_cmax").unwrap().as_f64().unwrap(), 250.0);
        // robustness block is always present (all-default for clean runs)
        let rb = j.get("robustness").unwrap();
        assert_eq!(rb.get("quorum").unwrap().as_f64().unwrap(), 0.0);
        assert!(rb.get("membership_log").unwrap().as_arr().unwrap().is_empty());
        // a degenerate (den==0) delta must serialize as the tagged sentinel,
        // never as a bare IEEE infinity (invalid JSON)
        let mut r2 = r.clone();
        r2.delta_max = Some(f64::INFINITY);
        let j2 = r2.to_json();
        assert_eq!(
            j2.get("delta_max").unwrap().to_string_compact(),
            "{\"degenerate\":\"infinite\"}"
        );
    }

    #[test]
    fn robustness_stats_json_field_names_are_stable() {
        let r = RobustnessStats {
            worker_skew: vec![WorkerSkew { worker: 1, skew: 4.0, steps_active: 10 }],
            quorum_miss_per_layer: vec![0, 3],
            staleness_hist: vec![5, 0, 2],
            membership_log: vec![MembershipChange {
                step: 7,
                action: "drop".into(),
                worker: 1,
                workers_after: 3,
            }],
            quorum: 3,
            staleness_bound: 2,
        };
        assert_eq!(r.total_quorum_misses(), 3);
        assert_eq!(r.max_staleness(), 2);
        let j = r.to_json();
        // field names are a stable contract: CI and BENCH tooling grep them
        for key in [
            "worker_skew",
            "quorum_miss_per_layer",
            "staleness_hist",
            "membership_log",
            "quorum",
            "staleness_bound",
        ] {
            assert!(j.get(key).is_ok(), "missing robustness field {key}");
        }
        let ws = &j.get("worker_skew").unwrap().as_arr().unwrap()[0];
        assert_eq!(ws.get("skew").unwrap().as_f64().unwrap(), 4.0);
        let ev = &j.get("membership_log").unwrap().as_arr().unwrap()[0];
        assert_eq!(ev.get("action").unwrap().as_str().unwrap(), "drop");
        assert_eq!(ev.get("workers_after").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(j.get("staleness_hist").unwrap().as_arr().unwrap().len(), 3);
    }
}
