//! The three distributed trainers the paper compares (Fig. 1):
//!
//! * **Dense-SGD** — full gradients, ring allreduce (numerically exact
//!   data-parallel SGD).
//! * **SLGS-SGD** — single-layer gradient sparsification: one global TopK
//!   over the whole flat gradient with error feedback (Lin et al. 2018
//!   style), aggregated once per iteration.
//! * **LAGS-SGD** — Algorithm 1: per-layer TopK with error feedback,
//!   aggregated layer by layer (backprop order), optionally with Eq. 18
//!   adaptive per-layer ratios and the §5 merge buffer.
//!
//! All three share the same AOT `train_step` artifact, the same worker
//! data shards and the same update rule `v ← v − (1/P)·agg` (momentum
//! optional), so convergence differences isolate the sparsification
//! scheme — the paper's Fig. 3 / Table 1 experiment design.

mod report;

pub use report::{MessageStats, TrainReport};

use crate::adaptive::{self, RatioConfig};
use crate::cluster::Cluster;
use crate::collectives::{dense::ring_allreduce_mean, NetworkModel};
use crate::config::TrainConfig;
use crate::data::Synthetic;
use crate::metrics::{CurveRecorder, DeltaMonitor};
use crate::models::ModelProfile;
use crate::pipeline::desim::{simulate, Schedule, SimParams};
use crate::runtime::{Metric, ModelRuntime, Runtime};
use crate::sparsify::CompressorKind;
use anyhow::Result;
use std::sync::Arc;

/// Which distributed optimizer to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    Dense,
    Slgs,
    Lags,
}

impl Algorithm {
    pub fn parse(s: &str) -> Result<Algorithm> {
        Ok(match s {
            "dense" => Algorithm::Dense,
            "slgs" => Algorithm::Slgs,
            "lags" => Algorithm::Lags,
            _ => anyhow::bail!("unknown algorithm {s:?} (dense|slgs|lags)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Dense => "dense",
            Algorithm::Slgs => "slgs",
            Algorithm::Lags => "lags",
        }
    }

    pub fn schedule(&self) -> Schedule {
        match self {
            Algorithm::Dense => Schedule::DensePipelined,
            Algorithm::Slgs => Schedule::Slgs,
            Algorithm::Lags => Schedule::Lags,
        }
    }
}

/// Distributed trainer over the logical worker pool.
pub struct Trainer {
    pub cfg: TrainConfig,
    model: ModelRuntime,
    data: Synthetic,
    cluster: Cluster,
    /// replicated model parameters v_t
    params: Vec<f32>,
    /// momentum buffer over the aggregated update
    momentum_buf: Vec<f32>,
    /// per-layer k^(l) (manifest order)
    ks: Vec<usize>,
    /// per-layer c^(l) actually in use (manifest order)
    ratios: Vec<f64>,
    delta: Option<DeltaMonitor>,
    /// scratch: aggregated update
    agg: Vec<f32>,
    /// scratch: per-worker dense grad buffers for the dense ring
    ring_bufs: Vec<Vec<f32>>,
    msg_stats: MessageStats,
    step_idx: usize,
}

impl Trainer {
    /// Load artifacts and build a trainer.
    pub fn from_artifacts(dir: &str, cfg: TrainConfig) -> Result<Trainer> {
        let rt = Arc::new(Runtime::load(dir)?);
        Self::with_runtime(&rt, cfg)
    }

    pub fn with_runtime(rt: &Arc<Runtime>, cfg: TrainConfig) -> Result<Trainer> {
        cfg.validate()?;
        let model = rt.model_runtime(&cfg.model)?;
        let mm = &model.mm;
        let d = mm.d;
        let max_layer = mm.layers.iter().map(|l| l.size).max().unwrap_or(0);
        let data = Synthetic::for_model(mm, cfg.seed)?;
        let cluster = Cluster::new(cfg.workers, d, max_layer, cfg.sample_stride);

        // per-layer ratios: uniform c, or Eq. 18 adaptive selection over the
        // live model's profile on the paper's 16-node 1GbE network model
        let ratios: Vec<f64> = if cfg.adaptive && cfg.algorithm == Algorithm::Lags {
            let profile = ModelProfile::from_manifest(mm, 1e12);
            let net = NetworkModel::gige_16().with_workers(cfg.workers.max(2));
            let rc = RatioConfig { c_max: cfg.c_max, ..RatioConfig::default() };
            // select_ratios is backprop-ordered; map back to manifest order
            let mut r = adaptive::select_ratios(&profile, &net, &rc);
            r.reverse();
            r
        } else {
            vec![cfg.compression; mm.layers.len()]
        };
        let ks: Vec<usize> = mm
            .layers
            .iter()
            .zip(ratios.iter())
            .map(|(l, &c)| ((l.size as f64 / c).ceil() as usize).clamp(1, l.size))
            .collect();

        let delta = if cfg.delta_every > 0 && cfg.algorithm == Algorithm::Lags {
            Some(DeltaMonitor::new(mm.layers.len(), cfg.delta_every, false, cfg.seed ^ 0xde17a))
        } else {
            None
        };

        let params = model.init_params.clone();
        let ring_bufs = vec![vec![0.0f32; d]; cfg.workers];
        Ok(Trainer {
            momentum_buf: vec![0.0; d],
            agg: vec![0.0; d],
            params,
            ks,
            ratios,
            delta,
            data,
            cluster,
            model,
            ring_bufs,
            msg_stats: MessageStats::default(),
            step_idx: 0,
            cfg,
        })
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    pub fn layer_ks(&self) -> &[usize] {
        &self.ks
    }

    /// Effective k for layer `li` at step `t`, honouring the warm-up
    /// schedule (Lin et al. 2018): the compression ratio ramps
    /// exponentially c_t = c^((t+1)/warmup) until `warmup_steps`.
    fn k_at(&self, li: usize, t: usize) -> usize {
        let size = self.model.mm.layers[li].size;
        if self.cfg.warmup_steps == 0 || t + 1 >= self.cfg.warmup_steps {
            return self.ks[li];
        }
        let frac = (t + 1) as f64 / self.cfg.warmup_steps as f64;
        let c_eff = self.ratios[li].powf(frac).max(1.0);
        ((size as f64 / c_eff).ceil() as usize).clamp(1, size)
    }

    pub fn ratios(&self) -> &[f64] {
        &self.ratios
    }

    /// Run one synchronous iteration; returns the mean training loss.
    pub fn step(&mut self) -> Result<f64> {
        let t = self.step_idx;
        let p = self.cluster.size();

        // --- local gradient computation (the AOT train artifact), per
        // worker. Params are replica-identical, so they are uploaded to the
        // device ONCE and shared across the P executions (§Perf L3-2).
        let params_dev = self.model.params_to_device(&self.params)?;
        for w in 0..p {
            let batch = self.data.batch(w, t);
            let (loss, grad) = self.model.train_step_b(&params_dev, &batch.x, &batch.y)?;
            self.cluster.workers[w].last_loss = loss;
            self.cluster.workers[w].grad = grad;
        }

        // --- momentum correction (local, pre-sparsification) if enabled
        if self.cfg.local_momentum > 0.0 && self.cfg.algorithm != Algorithm::Dense {
            let mu = self.cfg.local_momentum as f32;
            for w in 0..p {
                self.cluster.workers[w].fold_local_momentum(mu);
            }
        }

        // --- aggregate per algorithm
        self.agg.iter_mut().for_each(|v| *v = 0.0);
        match self.cfg.algorithm {
            Algorithm::Dense => self.aggregate_dense()?,
            Algorithm::Slgs => self.aggregate_slgs()?,
            Algorithm::Lags => self.aggregate_lags()?,
        }

        // --- apply: v ← v − (mu·m + agg/P)
        let inv_p = 1.0 / p as f32;
        let mu = self.cfg.momentum as f32;
        for i in 0..self.params.len() {
            let upd = mu * self.momentum_buf[i] + self.agg[i] * inv_p;
            self.momentum_buf[i] = upd;
            self.params[i] -= upd;
        }

        self.step_idx += 1;
        Ok(self.cluster.mean_loss())
    }

    /// Dense-SGD: real ring allreduce over the worker gradients.
    fn aggregate_dense(&mut self) -> Result<()> {
        let p = self.cluster.size();
        let lr = self.cfg.lr as f32;
        for w in 0..p {
            self.ring_bufs[w].copy_from_slice(&self.cluster.workers[w].grad);
        }
        ring_allreduce_mean(&mut self.ring_bufs);
        // agg = P * lr * mean  (apply divides by P again)
        let scale = lr * p as f32;
        for (a, &g) in self.agg.iter_mut().zip(self.ring_bufs[0].iter()) {
            *a = scale * g;
        }
        self.msg_stats.record(self.model.mm.d * 4 * 2, 1); // dense allreduce traffic
        Ok(())
    }

    /// SLGS-SGD: one global TopK over the whole flat accumulator per worker.
    fn aggregate_slgs(&mut self) -> Result<()> {
        let d = self.model.mm.d;
        let t = self.step_idx;
        let lr = self.cfg.lr as f32;
        let k_total: usize =
            (0..self.ks.len()).map(|li| self.k_at(li, t)).sum::<usize>().clamp(1, d);
        let exact = !matches!(
            self.cfg.compressor,
            CompressorKind::HostSampled | CompressorKind::XlaSampled
        );
        let mut kept = vec![0.0f32; d];
        for w in 0..self.cluster.size() {
            let worker = &mut self.cluster.workers[w];
            let grad = std::mem::take(&mut worker.grad);
            let stats = worker.ef.compress_layer(0, &grad, lr, k_total, exact, &mut kept);
            worker.grad = grad;
            self.msg_stats.record(stats.kept * 8, 1);
            for i in 0..d {
                self.agg[i] += kept[i];
            }
        }
        Ok(())
    }

    /// LAGS-SGD (Algorithm 1): per-layer TopK with error feedback, layer
    /// loop in backprop order (L → 1 in the paper's indexing).
    fn aggregate_lags(&mut self) -> Result<()> {
        let lr = self.cfg.lr as f32;
        let t = self.step_idx;
        let layers = self.model.mm.layers.clone();
        let sampled = matches!(
            self.cfg.compressor,
            CompressorKind::HostSampled | CompressorKind::XlaSampled
        );
        let sample_delta = self.delta.as_ref().map(|m| m.should_sample(t)).unwrap_or(false);

        let mut messages_this_iter = 0usize;
        let mut bytes_this_iter = 0usize;
        for (li, layer) in layers.iter().enumerate().rev() {
            let (off, n, k) = (layer.offset, layer.size, self.k_at(li, t));

            // Fig. 2 instrumentation: collect all workers' accumulators
            if sample_delta {
                let accs: Vec<Vec<f32>> = (0..self.cluster.size())
                    .map(|w| {
                        let worker = &self.cluster.workers[w];
                        worker.ef.peek_acc(off, &worker.grad[off..off + n], lr)
                    })
                    .collect();
                if let Some(m) = self.delta.as_mut() {
                    m.record(li, t, &accs, k);
                }
            }

            for w in 0..self.cluster.size() {
                let worker = &mut self.cluster.workers[w];
                let grad = std::mem::take(&mut worker.grad);
                let kept_n: usize;
                match self.cfg.compressor {
                    CompressorKind::HostExact | CompressorKind::HostSampled => {
                        let kept = &mut worker.kept[..n];
                        let stats = worker.ef.compress_layer(
                            off,
                            &grad[off..off + n],
                            lr,
                            k,
                            !sampled,
                            kept,
                        );
                        kept_n = stats.kept;
                        for i in 0..n {
                            self.agg[off + i] += kept[i];
                        }
                    }
                    CompressorKind::XlaExact | CompressorKind::XlaSampled => {
                        let resid = worker.ef.residual_slice(off, n).to_vec();
                        let (sparse, new_resid, _thr) = self.model.compress_layer_xla(
                            layer,
                            &grad[off..off + n],
                            &resid,
                            lr,
                            k,
                            sampled,
                        )?;
                        worker.ef.write_residual(off, &new_resid);
                        kept_n = sparse.iter().filter(|&&v| v != 0.0).count();
                        for i in 0..n {
                            self.agg[off + i] += sparse[i];
                        }
                    }
                }
                worker.grad = grad;
                bytes_this_iter += kept_n * 8;
                messages_this_iter += 1;
            }
        }
        self.msg_stats.record(bytes_this_iter, messages_this_iter);
        Ok(())
    }

    /// Held-out evaluation: mean (loss, metric) over `batches` eval batches.
    pub fn evaluate(&self, batches: usize) -> Result<(f64, f64)> {
        let mut tl = 0.0;
        let mut tm = 0.0;
        for i in 0..batches {
            let b = self.data.eval_batch(i);
            let (loss, metric) = self.model.eval_step(&self.params, &b.x, &b.y)?;
            tl += loss as f64;
            tm += metric as f64;
        }
        Ok((tl / batches as f64, tm / batches as f64))
    }

    /// Simulated per-iteration wall-clock on the paper's testbed (the DES
    /// with this model's profile at the configured P and ratios).
    pub fn simulated_iteration(&self) -> crate::pipeline::desim::IterationBreakdown {
        let profile = ModelProfile::from_manifest(&self.model.mm, 1e12);
        let net = NetworkModel::gige_16().with_workers(self.cfg.workers.max(2));
        let params = match self.cfg.algorithm {
            Algorithm::Dense => SimParams::dense(&profile),
            _ => {
                let mut p = SimParams::uniform(&profile, self.cfg.compression);
                // backprop order = reversed manifest order
                p.ratios = self.ratios.iter().rev().cloned().collect();
                p.merge_bytes = self.cfg.merge_bytes as f64;
                p
            }
        };
        simulate(&profile, &net, self.cfg.algorithm.schedule(), &params)
    }

    /// Run the full configured training loop.
    pub fn run(&mut self) -> Result<TrainReport> {
        let mut curve = CurveRecorder::new(&["train_loss", "eval_loss", "metric"]);
        let wall_start = std::time::Instant::now();
        let mut final_eval = (f64::NAN, f64::NAN);
        for s in 0..self.cfg.steps {
            let loss = self.step()?;
            let do_eval = self.cfg.eval_every > 0
                && ((s + 1) % self.cfg.eval_every == 0 || s + 1 == self.cfg.steps);
            if do_eval {
                final_eval = self.evaluate(self.cfg.eval_batches)?;
                curve.push(s + 1, &[loss, final_eval.0, final_eval.1]);
            } else {
                curve.push(s + 1, &[loss, f64::NAN, f64::NAN]);
            }
            if self.cfg.verbose && (s % 10 == 0 || s + 1 == self.cfg.steps) {
                eprintln!(
                    "[{}] step {:>5} loss {:.4} eval {:.4}/{:.4}",
                    self.cfg.algorithm.name(),
                    s + 1,
                    loss,
                    final_eval.0,
                    final_eval.1
                );
            }
        }
        let wall = wall_start.elapsed().as_secs_f64();
        let sim = self.simulated_iteration();
        let metric_name = match self.model.mm.metric {
            Metric::Accuracy => "accuracy",
            Metric::PplLoss => "ppl_loss",
        };
        Ok(TrainReport {
            algorithm: self.cfg.algorithm,
            model: self.cfg.model.clone(),
            steps: self.cfg.steps,
            final_loss: curve.last("train_loss").unwrap_or(f64::NAN),
            final_eval_loss: final_eval.0,
            final_metric: final_eval.1,
            metric_name: metric_name.to_string(),
            curve,
            delta_fraction_holding: self.delta.as_ref().map(|m| m.fraction_holding()),
            delta_max: self.delta.as_ref().map(|m| m.max_delta()),
            msg_stats: self.msg_stats.clone(),
            wall_seconds: wall,
            sim_iter_seconds: sim.iter_time,
            sim_hidden_seconds: sim.hidden,
        })
    }

    /// Access the delta monitor's per-layer series (Fig. 2 harness).
    pub fn delta_series(&self) -> Option<&[Vec<(usize, f64)>]> {
        self.delta.as_ref().map(|m| m.series.as_slice())
    }

    pub fn model_manifest(&self) -> &crate::runtime::ModelManifest {
        &self.model.mm
    }
}
