//! The three distributed trainers the paper compares (Fig. 1):
//!
//! * **Dense-SGD** — full gradients, ring allreduce (numerically exact
//!   data-parallel SGD).
//! * **SLGS-SGD** — single-layer gradient sparsification: one global TopK
//!   over the whole flat gradient with error feedback (Lin et al. 2018
//!   style), aggregated once per iteration.
//! * **LAGS-SGD** — Algorithm 1: per-layer TopK with error feedback,
//!   aggregated layer by layer (backprop order), optionally with Eq. 18
//!   adaptive per-layer ratios and the §5 merge buffer.
//!
//! All three share the same `train_step` backend, the same worker data
//! shards and the same update rule `v ← v − (1/P)·agg` (momentum
//! optional), so convergence differences isolate the sparsification
//! scheme — the paper's Fig. 3 / Table 1 experiment design.
//!
//! The trainer is layer-KIND agnostic: it walks the manifest's flat
//! `(offset, size)` layer table, so the heterogeneous native zoo (im2col
//! convs, pooling, BPTT recurrence — one fused tensor per block) streams
//! through the same compression, reduction, merge-buffer and adaptive
//! paths as the MLPs, with no trainer-side special cases.
//!
//! ## Hot-loop structure (DESIGN.md §Threading-model, §Streaming-overlap)
//!
//! Each iteration runs three logical phases:
//!
//! 1. **Parallel per-worker phase** — gradient compute, momentum
//!    correction and error-feedback compression fan out over the
//!    [`ParallelExecutor`] (`--threads`). Every worker owns its residuals,
//!    momentum and `SparseVec` message scratch, so the region has no
//!    shared mutable state and its results are independent of scheduling.
//! 2. **Rank-ordered reduction** — the workers' sparse messages are
//!    reduced into the dense `agg` via
//!    [`crate::collectives::sparse_agg::sparse_add_rank_ordered`] in rank
//!    order 0..P-1, layer-major in backprop order: O(P·k) sparse adds,
//!    bit-identical to the sequential dense baseline.
//! 3. **Apply** — `v ← v − (mu·m + agg/P)`.
//!
//! Under `--pipeline barrier` the phases run back-to-back (fork-join).
//! Under `--pipeline overlap` (the default) phases 2–3 **stream**: each
//! worker publishes layer `l`'s message the moment its compression
//! finishes, and the calling thread reduces + applies every layer whose P
//! messages have landed — in backprop order, rank-ordered within the
//! layer — while workers are still compressing earlier layers. Because
//! phase 1 is per-worker pure, layers occupy disjoint `agg`/param slices,
//! and each layer's reduction stays rank-ordered, `--pipeline` and
//! `--threads` are pure performance knobs: bit-identical params, losses
//! and message stats for every setting (asserted by
//! `rust/tests/integration_parallel.rs`).

mod checkpoint;
mod report;

pub use checkpoint::{Checkpoint, DeltaState, WorkerState, CHECKPOINT_FILE};
pub use report::{
    MembershipChange, MessageStats, RatioSelection, RobustnessStats, TrainReport, WorkerSkew,
};

use crate::adaptive::{self, MeasuredProfile, RatioConfig};
use crate::cluster::faults::{self, MembershipAction};
use crate::cluster::Cluster;
use crate::collectives::pipeline::{
    LayerMsg, OverlapMeasure, OverlapTimer, PipelineMode, StreamAggregator,
};
use crate::collectives::{dense::ring_allreduce_mean, sparse_agg, NetworkModel};
use crate::config::TrainConfig;
use crate::data::Synthetic;
use crate::metrics::{CurveRecorder, DeltaMonitor};
use crate::models::ModelProfile;
use crate::pipeline::desim::{simulate, Schedule, SimParams};
use crate::pipeline::merge::{MergeBuffer, MergedGroup};
use crate::runtime::{GradJob, Metric, ModelRuntime, Runtime};
use crate::sparsify::{CompressorKind, LayerCtx, WireFormat};
use crate::util::{clock, ParallelExecutor};
use anyhow::{Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

/// Which distributed optimizer to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    Dense,
    Slgs,
    Lags,
}

impl Algorithm {
    pub fn parse(s: &str) -> Result<Algorithm> {
        Ok(match s {
            "dense" => Algorithm::Dense,
            "slgs" => Algorithm::Slgs,
            "lags" => Algorithm::Lags,
            _ => anyhow::bail!("unknown algorithm {s:?} (dense|slgs|lags)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Dense => "dense",
            Algorithm::Slgs => "slgs",
            Algorithm::Lags => "lags",
        }
    }

    pub fn schedule(&self) -> Schedule {
        match self {
            Algorithm::Dense => Schedule::DensePipelined,
            Algorithm::Slgs => Schedule::Slgs,
            Algorithm::Lags => Schedule::Lags,
        }
    }
}

/// Phase 3 over one slice: v ← v − (μ·m + agg/P) for i in [off, off+n).
/// The update is elementwise, so the barrier paths call it once over the
/// whole vector and the streaming path calls it per completed layer —
/// bit-identical either way.
fn apply_update_range(
    params: &mut [f32],
    momentum: &mut [f32],
    agg: &[f32],
    mu: f32,
    inv_p: f32,
    off: usize,
    n: usize,
) {
    for i in off..off + n {
        let upd = mu * momentum[i] + agg[i] * inv_p;
        momentum[i] = upd;
        params[i] -= upd;
    }
}

/// The aggregator thread's per-phase context: the disjoint state one
/// streamed reduction phase mutates (aggregate, params, momentum) plus
/// the constants that parameterise it. Bundling these keeps
/// [`fire_group`]/[`drain_stream`] at a reviewable arity (the former
/// `#[allow(clippy::too_many_arguments)]` sites) and makes the borrow
/// story explicit: one `StepCtx` = exclusive access to everything the
/// apply touches, handed to the drain closure as a unit.
struct StepCtx<'a> {
    /// per-layer (offset, size) spans the stream covers — the manifest's
    /// layer table for LAGS, a single flat span for SLGS
    spans: &'a [(usize, usize)],
    /// scratch: the aggregated update (zeroed per layer slice)
    agg: &'a mut [f32],
    params: &'a mut [f32],
    momentum: &'a mut [f32],
    /// momentum coefficient μ
    mu: f32,
    /// 1 / (participating rank count)
    inv_p: f32,
    /// per-layer measured reduction seconds (EWMA profile input)
    reduce_secs: &'a mut [f64],
    /// clock the per-layer reductions into `reduce_secs`?
    measure: bool,
}

/// Reduce + apply one flushed §5 merge group on the aggregator thread:
/// for each layer of the group — in backprop order, every REQUIRED rank
/// slot present in `stream` — zero its `agg` slice, reduce the
/// rank-ordered messages into it, and apply that slice's update. With a
/// bounded-staleness quorum armed, excluded ranks' slots are skipped
/// (their messages fold back into their own residuals after the step);
/// with full participation the filter passes every slot, bit-identical
/// to the pre-quorum path. Each layer's rank-ordered reduction is
/// individually clocked into `reduce_secs` when `ctx.measure` is on (the
/// online adaptive profile). Returns the group's total wire bytes.
fn fire_group(
    group: &MergedGroup<usize>,
    stream: &StreamAggregator,
    ctx: &mut StepCtx<'_>,
    timer: &mut OverlapTimer,
) -> usize {
    for &li in &group.layer_indices {
        let begin = clock::now();
        let (off, n) = ctx.spans[li];
        {
            let dst = &mut ctx.agg[off..off + n];
            dst.iter_mut().for_each(|v| *v = 0.0);
            let r0 = ctx.measure.then(clock::now);
            sparse_agg::sparse_add_rank_ordered(
                stream
                    .layer_slots(li)
                    .iter()
                    .zip(stream.required())
                    .filter(|(_, &req)| req)
                    .map(|(s, _)| s.as_ref().expect("required slot")),
                dst,
            );
            if let Some(r0) = r0 {
                ctx.reduce_secs[li] = r0.elapsed().as_secs_f64();
            }
        }
        apply_update_range(
            &mut *ctx.params,
            &mut *ctx.momentum,
            &*ctx.agg,
            ctx.mu,
            ctx.inv_p,
            off,
            n,
        );
        timer.note_busy(begin, clock::now());
    }
    group.payloads.iter().sum()
}

/// Drain one streamed phase on the aggregator (calling) thread: land
/// each published [`LayerMsg`]; every layer that completes — in backprop
/// order, all P ranks present — is staged in the §5 `merge` buffer by
/// wire size, and each flushed group is reduced + applied (per layer,
/// rank-ordered) while workers are still compressing earlier layers. One
/// merged message per rank is accounted per group, so `merge_bytes`
/// shapes the real trainer's message granularity exactly like the DES's.
/// Wire bytes are priced by the active compressor's [`WireFormat`] (a
/// quantized scheme's elements are narrower than (u32, f32) pairs).
/// Returns (wire bytes, message count, measured overlap).
fn drain_stream(
    rx: mpsc::Receiver<LayerMsg>,
    stream: &mut StreamAggregator,
    merge: &mut MergeBuffer<usize>,
    wf: WireFormat,
    mut ctx: StepCtx<'_>,
) -> (usize, usize, OverlapMeasure) {
    let mut timer = OverlapTimer::new();
    let mut bytes = 0usize;
    let mut messages = 0usize;
    // one merged message per PARTICIPATING rank — quorum-excluded ranks
    // put nothing on the (virtual) wire this step
    let p = stream.required_count();
    let mut completed: Vec<usize> = Vec::new();
    let mut done = false;
    while !done {
        match rx.recv() {
            Ok(m) => {
                timer.note_sent(m.sent);
                stream.push(m, |li, _slots| completed.push(li));
                for li in completed.drain(..) {
                    let layer_bytes: usize = stream
                        .layer_slots(li)
                        .iter()
                        .zip(stream.required())
                        .filter(|(_, &req)| req)
                        .map(|(s, _)| wf.message_bytes(s.as_ref().expect("required slot").nnz()))
                        .sum();
                    merge.push_with(li, layer_bytes, layer_bytes);
                }
            }
            Err(_) => {
                // channel closed: end of backprop, flush the partial group
                merge.flush();
                done = true;
            }
        }
        for g in merge.take_groups() {
            bytes += fire_group(&g, stream, &mut ctx, &mut timer);
            messages += p;
        }
    }
    (bytes, messages, timer.finish())
}

/// Distributed trainer over the logical worker pool.
pub struct Trainer {
    pub cfg: TrainConfig,
    model: ModelRuntime,
    data: Synthetic,
    cluster: Cluster,
    /// fork/join + streaming pool for the per-worker phases (`cfg.threads`)
    exec: ParallelExecutor,
    /// replicated model parameters v_t
    params: Vec<f32>,
    /// momentum buffer over the aggregated update
    momentum_buf: Vec<f32>,
    /// per-layer k^(l) (manifest order)
    ks: Vec<usize>,
    /// per-layer c^(l) actually in use (manifest order)
    ratios: Vec<f64>,
    /// per-layer (offset, size) in manifest order — the hot loop walks
    /// this instead of cloning the manifest's layer table every step
    layer_meta: Vec<(usize, usize)>,
    /// scratch: per-layer effective k at the current step (warm-up aware)
    ks_t: Vec<usize>,
    delta: Option<DeltaMonitor>,
    /// scratch: aggregated update
    agg: Vec<f32>,
    /// scratch: per-worker dense grad buffers for the dense ring
    ring_bufs: Vec<Vec<f32>>,
    /// readiness table for the streamed per-layer reduction (`overlap`);
    /// SLGS streams its flat message as a single-span table
    stream: StreamAggregator,
    /// §5 merge buffer shaping the reduction/accounting granularity of
    /// the sparse paths in BOTH pipeline modes; capacity is
    /// `merge_bytes × P` because layers are staged by their TOTAL wire
    /// bytes across ranks (≡ per-rank mean vs `merge_bytes`, in exact
    /// integer arithmetic)
    merge: MergeBuffer<usize>,
    /// the configured α–β interconnect at `cfg.workers` — prices Eq. 18
    /// selection and the DES, replacing the old hard-coded `gige_16()`
    net: NetworkModel,
    /// the runtime backend's device speed (flops/s) — prices the startup
    /// Eq. 18 selection and the DES compute profile. Measured sustained
    /// GEMM flops when a calibration is attached to the runtime; else
    /// the documented fallback constants (native `DEVICE_FLOPS`, PJRT
    /// `PJRT_DEVICE_FLOPS`)
    device_flops: f64,
    /// provenance of `device_flops` (calibrated vs fallback), carried
    /// into the report
    flops_source: String,
    /// online measured-timing accumulator; `Some` only on the adaptive
    /// LAGS path with `--reselect-every N > 0`
    online: Option<MeasuredProfile>,
    /// Eq. 18 selection history (startup + online re-selections)
    selections: Vec<RatioSelection>,
    /// scratch: this step's per-layer reduction seconds (manifest order),
    /// written only while `online` measurement is active
    reduce_secs: Vec<f64>,
    /// scratch: per-layer compression seconds, mean across ranks
    compress_mean: Vec<f64>,
    /// wall-clock of this step's forward+backward fan-out
    last_comp_secs: f64,
    /// measured overlap accumulated across steps (stays zero in barrier
    /// mode) — the real-trainer counterpart of the DES `hidden` time
    overlap: OverlapMeasure,
    msg_stats: MessageStats,
    step_idx: usize,
    /// this step's rank-aligned quorum participation mask (all-true when
    /// `--quorum` is off); re-armed at the top of every step
    participants: Vec<bool>,
    /// per-uid count of steps each worker was a cluster member (only
    /// tracked when robustness telemetry is active)
    steps_active: BTreeMap<usize, usize>,
    /// per-layer count of (step × excluded worker) quorum misses,
    /// manifest order
    robust_quorum_miss: Vec<u64>,
    /// staleness histogram: index s counts re-inclusions after s
    /// consecutive missed steps
    robust_staleness_hist: Vec<u64>,
    /// membership events as they were applied, in order
    robust_membership_log: Vec<MembershipChange>,
    /// artifacts dir this trainer's [`Runtime`] was opened from
    /// (`"native"` for the built-in zoo) — recorded in checkpoints so
    /// `lags resume <dir>` can rebuild the runtime with no extra flags
    artifacts: String,
    /// injected crashes that already fired (loaded from tombstones on
    /// resume; always empty for a fresh run, so every scheduled crash
    /// is armed)
    fired_crashes: BTreeSet<usize>,
    /// `--record-trace` accumulator: one per-step row of measured
    /// per-worker compute seconds + link-jitter multipliers
    trace_rows: Vec<faults::TraceStepRecord>,
}

impl Trainer {
    /// Load artifacts and build a trainer. The magic dir `"native"`
    /// selects the built-in native model zoo seeded with `cfg.seed`.
    ///
    /// Device-flops calibration: `--calibrate` measures + persists a
    /// fresh calibration at startup; otherwise a previously persisted
    /// calibration (if any) is loaded — either way Eq. 18 startup
    /// selection and the DES then price compute with the measured
    /// number instead of the `DEVICE_FLOPS` fallback. Callers that
    /// build their own [`Runtime`] (tests, `compare`) attach calibration
    /// explicitly via [`Runtime::calibrate`].
    pub fn from_artifacts(dir: &str, cfg: TrainConfig) -> Result<Trainer> {
        let mut rt = Runtime::open(dir, cfg.seed)?;
        rt.calibrate(cfg.calibrate)?;
        if cfg.verbose {
            eprintln!(
                "[{}] device flops: {:.3e}/s (source: {})",
                cfg.algorithm.name(),
                rt.device_flops(),
                rt.flops_source()
            );
        }
        Self::with_runtime(&Arc::new(rt), cfg)
    }

    pub fn with_runtime(rt: &Arc<Runtime>, cfg: TrainConfig) -> Result<Trainer> {
        cfg.validate()?;
        let model = rt.model_runtime(&cfg.model)?;
        let mm = &model.mm;
        let d = mm.d;
        let data = Synthetic::for_model(mm, cfg.seed)?;
        let mut cluster = Cluster::new(cfg.workers, d, cfg.sample_stride, cfg.compressor);
        let layer_sizes: Vec<usize> = mm.layers.iter().map(|l| l.size).collect();
        for w in &mut cluster.workers {
            w.ensure_message_scratch(&layer_sizes);
        }

        // per-layer ratios: uniform c, or Eq. 18 adaptive selection over
        // the live model's profile on the CONFIGURED network at the REAL
        // worker count (P = 1 explicitly selects all-dense — see
        // select_ratios_manifest). lags ratios runs the same call, so the
        // CLI report and this selection always agree.
        let net = cfg.net.model(cfg.workers);
        let device_flops = rt.device_flops();
        let flops_source = rt.flops_source();
        let ratios: Vec<f64> = if cfg.adaptive && cfg.algorithm == Algorithm::Lags {
            let rc = RatioConfig { c_max: cfg.c_max, ..RatioConfig::default() };
            adaptive::select_ratios_manifest(mm, device_flops, &net, &rc)
        } else {
            vec![cfg.compression; mm.layers.len()]
        };
        let selections = if cfg.adaptive && cfg.algorithm == Algorithm::Lags {
            vec![RatioSelection {
                step: 0,
                effective_cmax: adaptive::ratio::effective_cmax(&ratios),
                ratios: ratios.clone(),
            }]
        } else {
            Vec::new()
        };
        // online measurement only on the adaptive LAGS path with a
        // re-selection period; everything else keeps its fixed schedule
        let online = if cfg.adaptive && cfg.algorithm == Algorithm::Lags && cfg.reselect_every > 0
        {
            Some(MeasuredProfile::new(
                mm.layers.iter().map(|l| l.name.clone()).collect(),
                mm.layers.iter().map(|l| l.size).collect(),
                mm.layers.iter().map(|l| l.fwd_flops).collect(),
            ))
        } else {
            None
        };
        let ks = adaptive::ks_from_ratios(&layer_sizes, &ratios);
        let layer_meta: Vec<(usize, usize)> = mm.layers.iter().map(|l| (l.offset, l.size)).collect();

        let delta = if cfg.delta_every > 0 && cfg.algorithm == Algorithm::Lags {
            Some(DeltaMonitor::new(
                mm.layers.len(),
                cfg.delta_every,
                cfg.delta_expectation,
                cfg.seed ^ 0xde17a,
            ))
        } else {
            None
        };

        // SLGS streams its single whole-vector message; LAGS/Dense size
        // the table per layer (Dense never uses it)
        let stream_layers = match cfg.algorithm {
            Algorithm::Slgs => 1,
            _ => mm.layers.len().max(1),
        };
        let stream = StreamAggregator::new(stream_layers, cfg.workers);

        let params = model.init_params.clone();
        let ring_bufs = vec![vec![0.0f32; d]; cfg.workers];
        let nl = ks.len();
        Ok(Trainer {
            momentum_buf: vec![0.0; d],
            agg: vec![0.0; d],
            exec: ParallelExecutor::new(cfg.threads),
            ks_t: vec![0; nl],
            params,
            ks,
            ratios,
            layer_meta,
            delta,
            data,
            cluster,
            model,
            ring_bufs,
            stream,
            merge: MergeBuffer::new(cfg.merge_bytes.saturating_mul(cfg.workers)),
            net,
            device_flops,
            flops_source,
            online,
            selections,
            reduce_secs: vec![0.0; nl],
            compress_mean: vec![0.0; nl],
            last_comp_secs: 0.0,
            overlap: OverlapMeasure::default(),
            msg_stats: MessageStats::default(),
            step_idx: 0,
            participants: vec![true; cfg.workers],
            steps_active: BTreeMap::new(),
            robust_quorum_miss: vec![0; nl],
            robust_staleness_hist: Vec::new(),
            robust_membership_log: Vec::new(),
            artifacts: rt.manifest.dir.to_string_lossy().into_owned(),
            fired_crashes: BTreeSet::new(),
            trace_rows: Vec::new(),
            cfg,
        })
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    pub fn layer_ks(&self) -> &[usize] {
        &self.ks
    }

    /// The executor's resolved thread count (0 in the config = per-core).
    pub fn threads(&self) -> usize {
        self.exec.threads()
    }

    /// Measured streaming-overlap statistics accumulated across the steps
    /// run so far (all-zero under `--pipeline barrier` and for Dense).
    pub fn overlap_stats(&self) -> &OverlapMeasure {
        &self.overlap
    }

    /// Effective k for layer `li` at step `t`, honouring the warm-up
    /// schedule (Lin et al. 2018): the compression ratio ramps
    /// exponentially c_t = c^((t+1)/warmup) until `warmup_steps`, landing
    /// exactly on `ks[li]` at `t + 1 == warmup_steps`. Monotone
    /// non-increasing over the ramp for any ratio vector ≥ 1 (asserted by
    /// `prop_warmup_k_monotone_lands_on_ks`).
    pub fn k_at(&self, li: usize, t: usize) -> usize {
        let size = self.model.mm.layers[li].size;
        if self.cfg.warmup_steps == 0 || t + 1 >= self.cfg.warmup_steps {
            return self.ks[li];
        }
        let frac = (t + 1) as f64 / self.cfg.warmup_steps as f64;
        let c_eff = self.ratios[li].powf(frac).max(1.0);
        ((size as f64 / c_eff).ceil() as usize).clamp(1, size)
    }

    /// Per-layer compression ratios currently in effect (manifest order).
    pub fn ratios(&self) -> &[f64] {
        &self.ratios
    }

    /// Eq. 18 selection history: the startup selection plus every online
    /// re-selection so far (empty for non-adaptive runs).
    pub fn selections(&self) -> &[RatioSelection] {
        &self.selections
    }

    /// The configured α–β interconnect at this run's worker count.
    pub fn network(&self) -> &NetworkModel {
        &self.net
    }

    /// Run one synchronous iteration; returns the mean training loss.
    pub fn step(&mut self) -> Result<f64> {
        let t = self.step_idx;

        // --- crash-fault tier: a scheduled crash fires at the TOP of the
        // step, before any state mutates, so the last durable checkpoint
        // is exactly the pre-step state and the resumed process replays
        // this step bit-identically. The fsync'd tombstone disarms the
        // crash for the resumed run only (config validation guarantees a
        // checkpoint dir whenever crashes are scheduled).
        if self.cfg.faults.crash_at(t) && !self.fired_crashes.contains(&t) {
            self.fired_crashes.insert(t);
            checkpoint::write_tombstone(&self.cfg.checkpoint_dir, t)?;
            return Err(anyhow::Error::new(faults::CrashPoint(t)));
        }

        // --- robustness layer: membership events fire strictly BETWEEN
        // steps (here, before step t's gradients), and the step's quorum
        // participation mask is a pure function of (plan, membership,
        // staleness, t) — never of wall-clock
        self.apply_membership_events(t)?;
        self.arm_participation(t);
        if self.robustness_active() {
            for w in &self.cluster.workers {
                *self.steps_active.entry(w.id).or_insert(0) += 1;
            }
        }

        // --- local gradient computation, fanned over the worker pool.
        // Each job fills only worker-owned slots; the native backend runs
        // jobs on the executor's threads, PJRT runs them in rank order
        // with one shared params upload (§Perf L3-2). Either way the
        // per-worker results are identical.
        let mut jobs = Vec::with_capacity(self.cluster.size());
        for w in &mut self.cluster.workers {
            let batch = self.data.batch(w.id, t);
            jobs.push(GradJob {
                x: batch.x,
                y: batch.y,
                loss: &mut w.last_loss,
                grad: &mut w.grad,
                scratch: &mut w.grad_scratch,
            });
        }
        // a perturbing plan needs the compute wall-clock every step: it is
        // the base the straggler sleeps scale, and --record-trace wants it
        // as the recorded rows' per-worker base share (measuring it does
        // not alter any numerics, so the determinism contract is untouched)
        let comp_start = (self.measuring_at(t)
            || self.cfg.faults.perturbs_time()
            || !self.cfg.record_trace.is_empty())
        .then(clock::now);
        self.model.grad_many(&self.exec, &self.params, &mut jobs)?;
        drop(jobs);
        if let Some(s) = comp_start {
            self.last_comp_secs = s.elapsed().as_secs_f64();
        }

        // --- momentum correction (local, pre-sparsification) if enabled
        if self.cfg.local_momentum > 0.0 && self.cfg.algorithm != Algorithm::Dense {
            let mu = self.cfg.local_momentum as f32;
            self.exec.run(&mut self.cluster.workers, |_, w| {
                w.fold_local_momentum(mu);
                Ok(())
            })?;
        }

        // --- aggregate + apply per algorithm (the streaming paths fold
        // phase 3 into the per-layer completion callback)
        match self.cfg.algorithm {
            Algorithm::Dense => self.aggregate_dense()?,
            Algorithm::Slgs => self.aggregate_slgs()?,
            Algorithm::Lags => self.aggregate_lags()?,
        }

        // bounded staleness: excluded workers' already-compressed messages
        // re-enter their own residuals (validate() guarantees quorum > 0
        // only on the LAGS path)
        if self.cfg.quorum > 0 {
            self.fold_late_messages();
            self.note_quorum_outcome();
        }

        if !self.cfg.record_trace.is_empty() {
            self.record_trace_row(t);
        }
        self.step_idx += 1;
        self.observe_and_reselect();
        // durable checkpoint at --checkpoint-every boundaries, AFTER all
        // of this step's state (including any re-selection) has settled
        if self.cfg.checkpoint_every > 0 && self.step_idx % self.cfg.checkpoint_every == 0 {
            self.save_checkpoint()?;
        }
        Ok(self.cluster.mean_loss())
    }

    /// Measurement is active only on the online adaptive path and only
    /// once warm-up has completed — ramp-phase steps run at artificially
    /// low compression, so their compress/reduce timings would poison the
    /// EWMA profile the first re-selection consumes. `t` is the step
    /// about to run (`step_idx`).
    fn measuring_at(&self, t: usize) -> bool {
        self.online.is_some() && t + 1 >= self.cfg.warmup_steps
    }

    /// Whether this run collects robustness telemetry (any fault injected
    /// or quorum mode on). Clean full-sync runs skip the bookkeeping and
    /// report an all-default [`RobustnessStats`].
    fn robustness_active(&self) -> bool {
        !self.cfg.faults.is_none() || self.cfg.quorum > 0
    }

    /// Apply the fault plan's membership events scheduled for step `t`
    /// (strictly between steps), then re-size every P-shaped structure to
    /// the new membership: the departing worker's residual re-shards into
    /// survivors ([`Cluster::drop_worker`]), the streaming aggregator and
    /// dense ring scratch rebuild at the new rank count, and the §5 merge
    /// capacity recomputes as `merge_bytes × CURRENT P` (it used to be
    /// frozen at the startup P — the silent-cap regression the elastic
    /// tests pin down).
    fn apply_membership_events(&mut self, t: usize) -> Result<()> {
        if self.cfg.faults.events.is_empty() {
            return Ok(());
        }
        let events: Vec<_> = self.cfg.faults.events_at(t).cloned().collect();
        if events.is_empty() {
            return Ok(());
        }
        let d = self.model.mm.d;
        let layer_sizes: Vec<usize> = self.model.mm.layers.iter().map(|l| l.size).collect();
        for ev in events {
            match ev.action {
                MembershipAction::Drop => self.cluster.drop_worker(ev.worker)?,
                MembershipAction::Join => self.cluster.join_worker(
                    ev.worker,
                    d,
                    self.cfg.sample_stride,
                    self.cfg.compressor,
                    &layer_sizes,
                )?,
            }
            self.robust_membership_log.push(MembershipChange {
                step: t,
                action: ev.action.name().to_string(),
                worker: ev.worker,
                workers_after: self.cluster.size(),
            });
            if self.cfg.verbose {
                eprintln!(
                    "[{}] step {t}: membership {} worker {} -> P = {}",
                    self.cfg.algorithm.name(),
                    ev.action.name(),
                    ev.worker,
                    self.cluster.size(),
                );
            }
        }
        self.resize_to_membership();
        Ok(())
    }

    /// Re-size every P-shaped structure to the CURRENT cluster
    /// membership: the streaming aggregator's rank slots, the §5 merge
    /// capacity (`merge_bytes × live P`), the dense ring scratch and the
    /// quorum participation mask. Shared by elastic membership events
    /// and checkpoint restore (which rebuilds the worker pool wholesale).
    fn resize_to_membership(&mut self) {
        let d = self.model.mm.d;
        let alive = self.cluster.size();
        let stream_layers = match self.cfg.algorithm {
            Algorithm::Slgs => 1,
            _ => self.layer_meta.len().max(1),
        };
        self.stream.resize(stream_layers, alive);
        self.merge.set_capacity(self.cfg.merge_bytes.saturating_mul(alive));
        self.ring_bufs.resize_with(alive, || vec![0.0f32; d]);
        if self.participants.len() != alive {
            self.participants = vec![true; alive];
        }
    }

    /// Append one `--record-trace` row for completed step `t`: per-uid
    /// measured compute seconds (the shared fan-out wall-clock split
    /// evenly, plus each worker's own measured compression phase — the
    /// per-worker differential a trace replay turns back into skew) and
    /// the plan's link-jitter multipliers. Absent uids record 0.0
    /// compute, which `FaultPlan::from_trace` maps back to nominal.
    fn record_trace_row(&mut self, t: usize) {
        let max_uid = self.cluster.workers.iter().map(|w| w.id).max().unwrap_or(0);
        let mut comp_secs = vec![0.0f64; max_uid + 1];
        let mut alpha_mult = vec![1.0f64; max_uid + 1];
        let mut bw_mult = vec![1.0f64; max_uid + 1];
        let base = self.last_comp_secs / self.cluster.size() as f64;
        for w in &self.cluster.workers {
            comp_secs[w.id] = base + w.step_secs;
            let (a, b) = self.cfg.faults.link_jitter(w.id, t);
            alpha_mult[w.id] = a;
            bw_mult[w.id] = b;
        }
        self.trace_rows.push(faults::TraceStepRecord { step: t, comp_secs, alpha_mult, bw_mult });
    }

    /// Write the rows accumulated under `--record-trace` to the
    /// configured path (atomically), in the `lags-trace` schema that
    /// `--faults-trace` and `FaultPlan::from_trace` replay. A resumed
    /// run records only its post-resume steps.
    pub fn write_trace(&self) -> Result<()> {
        let workers = self.trace_rows.iter().map(|r| r.comp_secs.len()).max().unwrap_or(0);
        let doc = faults::trace_to_json(&self.cfg.model, workers, &self.trace_rows);
        crate::util::json::write_atomic(
            Path::new(&self.cfg.record_trace),
            doc.to_string_pretty().as_bytes(),
        )
        .with_context(|| format!("writing trace {:?}", self.cfg.record_trace))
    }

    /// Recompute this step's quorum participation mask
    /// ([`faults::quorum_participants`]). All-true when `--quorum` is off.
    fn arm_participation(&mut self, t: usize) {
        if self.cfg.quorum == 0 {
            debug_assert_eq!(self.participants.len(), self.cluster.size());
            return; // mask stays all-true (membership resize keeps it so)
        }
        let uids: Vec<usize> = self.cluster.workers.iter().map(|w| w.id).collect();
        let stale: Vec<usize> = self.cluster.workers.iter().map(|w| w.quorum_stale).collect();
        self.participants = faults::quorum_participants(
            &self.cfg.faults,
            &uids,
            &stale,
            t,
            self.cfg.quorum,
            self.cfg.staleness_bound,
        );
    }

    /// Wall-clock straggler injection: per-rank sleeps realising the
    /// plan's virtual pacing, run at the START of each worker's
    /// compression closure — outside every timed compress region, so the
    /// Eq. 18 measured profile sees real compression costs, not sleep
    /// time. The delay scales the measured compute base by the worker's
    /// `virtual_step_time − 1` (its slowdown relative to nominal), capped
    /// so CI-scale runs stay fast. `None` when the plan does not perturb
    /// time or no compute baseline has been measured yet (first step).
    fn straggler_delays(&self, t: usize) -> Option<Vec<Duration>> {
        if !self.cfg.faults.perturbs_time() || self.last_comp_secs <= 0.0 {
            return None;
        }
        const MAX_DELAY_SECS: f64 = 0.25;
        Some(
            self.cluster
                .workers
                .iter()
                .map(|w| {
                    let extra = (self.cfg.faults.virtual_step_time(w.id, t) - 1.0).max(0.0);
                    Duration::from_secs_f64((extra * self.last_comp_secs).min(MAX_DELAY_SECS))
                })
                .collect(),
        )
    }

    /// Bounded staleness (the quorum contract's second half): an excluded
    /// worker's already-compressed messages are NOT discarded — each
    /// coordinate folds back into that worker's own error-feedback
    /// residual, so the mass competes again in the next step's TopK and
    /// the EF convergence argument stays intact. Coordinates within one
    /// worker's messages are disjoint across layers, so the fold order is
    /// irrelevant; the message buffers are cleared for reuse.
    fn fold_late_messages(&mut self) {
        for (rank, w) in self.cluster.workers.iter_mut().enumerate() {
            if self.participants[rank] {
                continue;
            }
            for (li, &(off, _)) in self.layer_meta.iter().enumerate() {
                let msg = &mut w.msgs[li];
                for (&i, &v) in msg.idx.iter().zip(msg.val.iter()) {
                    w.ef.add_residual_at(off + i as usize, v);
                }
                msg.idx.clear();
                msg.val.clear();
            }
        }
    }

    /// Per-step quorum bookkeeping: participants record a staleness-
    /// histogram entry at their backlog (0 for the common case) and reset
    /// it; excluded workers age their backlog and charge one quorum miss
    /// per layer.
    fn note_quorum_outcome(&mut self) {
        let nl = self.layer_meta.len();
        for (rank, w) in self.cluster.workers.iter_mut().enumerate() {
            if self.participants[rank] {
                let s = w.quorum_stale;
                if self.robust_staleness_hist.len() <= s {
                    self.robust_staleness_hist.resize(s + 1, 0);
                }
                self.robust_staleness_hist[s] += 1;
                w.quorum_stale = 0;
            } else {
                w.quorum_stale += 1;
                for miss in self.robust_quorum_miss.iter_mut().take(nl) {
                    *miss += 1;
                }
            }
        }
    }

    /// Online adaptive path: fold this step's measured timings into the
    /// EWMA profile and, at `--reselect-every` boundaries, re-run Eq. 18
    /// over the MEASURED profile and swap in the new `ks`/`ratios`. Runs
    /// strictly BETWEEN steps, so any fixed schedule
    /// (`reselect_every = 0`) is bit-for-bit untouched and the
    /// barrier ≡ overlap determinism contract holds per schedule.
    fn observe_and_reselect(&mut self) {
        let done = self.step_idx; // steps completed; the last ran at t = done - 1
        if !self.measuring_at(done - 1) {
            return; // fixed schedule, or still ramping through warm-up
        }
        let nl = self.layer_meta.len();
        let p = self.cluster.size() as f64;
        for li in 0..nl {
            let s: f64 = self.cluster.workers.iter().map(|w| w.compress_secs[li]).sum();
            self.compress_mean[li] = s / p;
        }
        // skew-aware: the calling thread clocked ITS OWN fan-out, but a
        // synchronous step is paced by the quorum-gating worker's skew —
        // re-inflate so Eq. 18 re-selects against the straggler-inflated
        // profile. gate = 1.0 (healthy plan) folds bit-identically.
        let uids: Vec<usize> = self.cluster.workers.iter().map(|w| w.id).collect();
        let gate = faults::compute_gate(&self.cfg.faults, &uids, self.cfg.quorum);
        {
            let mp = self.online.as_mut().expect("measuring implies online");
            mp.observe_step_skewed(
                self.last_comp_secs,
                gate,
                &self.compress_mean,
                &self.reduce_secs,
            );
        }
        if done % self.cfg.reselect_every != 0 {
            return;
        }
        let (profile, overhead) = {
            let mp = self.online.as_ref().expect("measuring implies online");
            (mp.profile(&self.cfg.model), mp.overhead_backprop())
        };
        let rc = RatioConfig { c_max: self.cfg.c_max, ..RatioConfig::default() };
        self.ratios = adaptive::select_ratios_measured_manifest(&profile, &self.net, &rc, &overhead);
        let sizes: Vec<usize> = self.layer_meta.iter().map(|&(_, n)| n).collect();
        self.ks = adaptive::ks_from_ratios(&sizes, &self.ratios);
        let cmax = adaptive::ratio::effective_cmax(&self.ratios);
        self.selections.push(RatioSelection {
            step: done,
            effective_cmax: cmax,
            ratios: self.ratios.clone(),
        });
        if self.cfg.verbose {
            eprintln!(
                "[{}] step {done}: re-selected ratios from measured profile \
                 (compute {:.3}ms/step), effective c_max = {cmax:.1}",
                self.cfg.algorithm.name(),
                1e3 * self.online.as_ref().expect("measuring implies online").compute_seconds(),
            );
        }
    }

    /// Barrier phase 3: one whole-vector apply pass.
    fn apply_full(&mut self) {
        let inv_p = 1.0 / self.cluster.size() as f32;
        let mu = self.cfg.momentum as f32;
        let d = self.params.len();
        apply_update_range(&mut self.params, &mut self.momentum_buf, &self.agg, mu, inv_p, 0, d);
    }

    /// Dense-SGD: real ring allreduce over the worker gradients (always a
    /// barrier — the ring needs every rank's full gradient).
    fn aggregate_dense(&mut self) -> Result<()> {
        let p = self.cluster.size();
        let lr = self.cfg.lr as f32;
        for w in 0..p {
            self.ring_bufs[w].copy_from_slice(&self.cluster.workers[w].grad);
        }
        ring_allreduce_mean(&mut self.ring_bufs);
        // agg = P * lr * mean (apply divides by P again); every element is
        // overwritten, so no zeroing pass is needed
        let scale = lr * p as f32;
        for (a, &g) in self.agg.iter_mut().zip(self.ring_bufs[0].iter()) {
            *a = scale * g;
        }
        // wire accounting follows cost::allreduce_dense and the sparse
        // paths' per-worker counting: each rank's ring transfer is
        // 2·(4d)·(P−1)/P bytes, so the P ranks together move 8·d·(P−1)
        // bytes, one logical collective message per rank
        self.msg_stats.record(8 * self.model.mm.d * (p - 1), p);
        self.apply_full();
        Ok(())
    }

    /// SLGS-SGD: one global TopK over the whole flat accumulator per
    /// worker. Compression fans out over the executor into worker-owned
    /// sparse messages (no per-step allocation); the reduction is the
    /// rank-ordered sparse sum. Under `overlap` the flat messages stream
    /// through a single-span table — the reduction still cannot start
    /// before the slowest worker publishes (the paper's Fig. 1(b) point:
    /// single-shot sparsification has nothing to hide behind), so the
    /// measured overlap stays ≈ 0 while LAGS's is substantial.
    fn aggregate_slgs(&mut self) -> Result<()> {
        let d = self.model.mm.d;
        let t = self.step_idx;
        let lr = self.cfg.lr as f32;
        let k_total: usize =
            (0..self.ks.len()).map(|li| self.k_at(li, t)).sum::<usize>().clamp(1, d);
        let seed = self.cfg.seed;
        let wf = self.cfg.compressor.wire();
        let delays = self.straggler_delays(t);
        // --record-trace times each worker's whole per-worker phase
        // (straggler sleep included — the recorded profile should carry
        // the fault the run actually experienced)
        let record = !self.cfg.record_trace.is_empty();
        match self.cfg.pipeline {
            PipelineMode::Barrier => {
                self.exec.run(&mut self.cluster.workers, |rank, worker| {
                    let w0 = record.then(clock::now);
                    if let Some(ds) = &delays {
                        if !ds[rank].is_zero() {
                            std::thread::sleep(ds[rank]);
                        }
                    }
                    worker.comp.begin_step(worker.ef.residual(), &worker.grad, lr, k_total);
                    let ctx =
                        LayerCtx { seed, uid: worker.id as u64, step: t as u64, layer: 0 };
                    let (acc, resid) = worker.ef.accumulate(0, &worker.grad, lr);
                    worker.comp.split(&ctx, acc, k_total, &mut worker.msg_flat, resid);
                    if let Some(w0) = w0 {
                        worker.step_secs = w0.elapsed().as_secs_f64();
                    }
                    Ok(())
                })?;
                self.agg.iter_mut().for_each(|v| *v = 0.0);
                sparse_agg::sparse_add_rank_ordered(
                    self.cluster.workers.iter().map(|w| &w.msg_flat),
                    &mut self.agg,
                );
                let bytes: usize =
                    self.cluster.workers.iter().map(|w| wf.message_bytes(w.msg_flat.nnz())).sum();
                self.msg_stats.record(bytes, self.cluster.size());
                self.apply_full();
            }
            PipelineMode::Overlap => {
                self.stream.reset();
                let p = self.cluster.size();
                let inv_p = 1.0 / p as f32;
                let mu = self.cfg.momentum as f32;
                let flat_span = [(0usize, d)];
                let stream = &mut self.stream;
                let merge = &mut self.merge;
                let ctx = StepCtx {
                    spans: &flat_span[..],
                    agg: &mut self.agg[..],
                    params: &mut self.params[..],
                    momentum: &mut self.momentum_buf[..],
                    mu,
                    inv_p,
                    reduce_secs: &mut self.reduce_secs[..1],
                    measure: false,
                };
                let (tx, rx) = mpsc::channel::<LayerMsg>();
                let (bytes, messages, overlap) = self.exec.run_with_sink(
                    &mut self.cluster.workers,
                    tx,
                    |rank, worker, tx| {
                        let w0 = record.then(clock::now);
                        if let Some(ds) = &delays {
                            if !ds[rank].is_zero() {
                                std::thread::sleep(ds[rank]);
                            }
                        }
                        worker.comp.begin_step(worker.ef.residual(), &worker.grad, lr, k_total);
                        let ctx =
                            LayerCtx { seed, uid: worker.id as u64, step: t as u64, layer: 0 };
                        let (acc, resid) = worker.ef.accumulate(0, &worker.grad, lr);
                        worker.comp.split(&ctx, acc, k_total, &mut worker.msg_flat, resid);
                        if let Some(w0) = w0 {
                            worker.step_secs = w0.elapsed().as_secs_f64();
                        }
                        worker.publish_flat(rank, tx);
                        Ok(())
                    },
                    move || drain_stream(rx, stream, merge, wf, ctx),
                )?;
                anyhow::ensure!(self.stream.finished(), "streamed SLGS reduction incomplete");
                self.msg_stats.record(bytes, messages);
                self.overlap.accumulate(&overlap);
                for rank in 0..p {
                    if let Some(m) = self.stream.take(0, rank) {
                        self.cluster.workers[rank].msg_flat = m;
                    }
                }
            }
        }
        Ok(())
    }

    /// Barrier phases 2+3 for LAGS: zero, rank-ordered layer-major
    /// reduction (Alg. 1 line 9) in backprop order, §5 merged-group
    /// message accounting, whole-vector apply. The same values hit the
    /// same coordinates in the same rank order as the dense per-worker
    /// adds did, so the aggregate is bit-identical — at O(Σ_l P·k^(l))
    /// cost. The merge grouping keys on the layers' total wire bytes —
    /// identical across pipeline modes and thread counts because the
    /// messages themselves are — so `MessageStats` stays a pure function
    /// of the schedule.
    fn reduce_apply_barrier_lags(&mut self) {
        let nl = self.layer_meta.len();
        let measure = self.measuring_at(self.step_idx);
        let wf = self.cfg.compressor.wire();
        // participant-filtered: with a quorum armed only participating
        // ranks reduce (and account wire bytes); full participation passes
        // every rank through, bit-identical to the unfiltered path
        let p = self.participants.iter().filter(|&&b| b).count();
        self.agg.iter_mut().for_each(|v| *v = 0.0);
        let mut bytes = 0usize;
        let mut messages = 0usize;
        for li in (0..nl).rev() {
            let (off, n) = self.layer_meta[li];
            let r0 = measure.then(clock::now);
            sparse_agg::sparse_add_rank_ordered(
                self.cluster
                    .workers
                    .iter()
                    .zip(&self.participants)
                    .filter(|(_, &part)| part)
                    .map(|(w, _)| &w.msgs[li]),
                &mut self.agg[off..off + n],
            );
            if let Some(r0) = r0 {
                self.reduce_secs[li] = r0.elapsed().as_secs_f64();
            }
            let layer_bytes: usize = self
                .cluster
                .workers
                .iter()
                .zip(&self.participants)
                .filter(|(_, &part)| part)
                .map(|(w, _)| wf.message_bytes(w.msgs[li].nnz()))
                .sum();
            self.merge.push_with(li, layer_bytes, layer_bytes);
        }
        // nothing observes intermediate flushes in the barrier path, so
        // one end-of-backprop flush + drain accounts every group
        self.merge.flush();
        for g in self.merge.take_groups() {
            bytes += g.payloads.iter().sum::<usize>();
            messages += p;
        }
        self.msg_stats.record(bytes, messages);
        self.apply_full();
    }

    /// LAGS-SGD (Algorithm 1): per-layer TopK with error feedback. The
    /// compression loop is worker-major — each worker (thread) walks its
    /// own layers in backprop order (L → 1 in the paper's indexing).
    /// Under `barrier` the aggregation is the layer-major rank-ordered
    /// sparse reduction after all workers finish; under `overlap` each
    /// layer is published, reduced and applied as soon as its P messages
    /// land, concurrent with the remaining compression — Algorithm 2's
    /// wait-free pipelining realised in the actual hot loop.
    fn aggregate_lags(&mut self) -> Result<()> {
        let lr = self.cfg.lr as f32;
        let t = self.step_idx;
        let nl = self.layer_meta.len();
        for li in 0..nl {
            self.ks_t[li] = self.k_at(li, t);
        }
        let sampled = matches!(
            self.cfg.compressor,
            CompressorKind::HostSampled | CompressorKind::XlaSampled
        );
        let seed = self.cfg.seed;
        let wf = self.cfg.compressor.wire();
        let k_total: usize = self.ks_t.iter().sum();

        // Fig. 2 instrumentation pre-pass: peek_acc only reads this
        // layer's residual slice and compression of other layers never
        // touches it, so collecting all layers before any compression
        // sees the same accumulators the interleaved loop saw — and the
        // monitor's RNG stays on the sequential path (in both pipeline
        // modes). The numerator probes the ACTUAL compressor: begin_step
        // is armed first (idempotent — the compression phase re-arms it
        // with the same inputs), and each probe re-derives the same
        // `(seed, uid, step, layer)` stream the real split will draw, so
        // δ measures exactly what goes on the wire.
        if self.delta.as_ref().map(|m| m.should_sample(t)).unwrap_or(false) {
            for w in &mut self.cluster.workers {
                w.comp.begin_step(w.ef.residual(), &w.grad, lr, k_total);
            }
            let workers = &mut self.cluster.workers;
            let monitor = self.delta.as_mut().expect("sampling implies monitor");
            for li in (0..nl).rev() {
                let (off, n) = self.layer_meta[li];
                let accs: Vec<Vec<f32>> = workers
                    .iter()
                    .map(|w| w.ef.peek_acc(off, &w.grad[off..off + n], lr))
                    .collect();
                monitor.record_with(li, t, &accs, self.ks_t[li], |p, acc, k, out| {
                    let w = &mut workers[p];
                    let ctx =
                        LayerCtx { seed, uid: w.id as u64, step: t as u64, layer: li as u64 };
                    w.comp.probe(&ctx, acc, k, out);
                });
            }
        }

        let measure = self.measuring_at(t);
        let record = !self.cfg.record_trace.is_empty();
        if self.cfg.compressor.is_xla() {
            // the XLA compress executables are not Sync — compression runs
            // sequentially in rank order, and aggregation stays a barrier
            // even under `--pipeline overlap` (bit-identical regardless)
            for worker in self.cluster.workers.iter_mut() {
                let w0 = record.then(clock::now);
                for li in (0..nl).rev() {
                    let (off, n) = self.layer_meta[li];
                    let layer = &self.model.mm.layers[li];
                    let c0 = measure.then(clock::now);
                    let resid = worker.ef.residual_slice(off, n).to_vec();
                    let (sparse, new_resid, _thr) = self.model.compress_layer_xla(
                        layer,
                        &worker.grad[off..off + n],
                        &resid,
                        lr,
                        self.ks_t[li],
                        sampled,
                        &mut worker.compress_scratch,
                    )?;
                    if let Some(c0) = c0 {
                        worker.compress_secs[li] = c0.elapsed().as_secs_f64();
                    }
                    worker.ef.write_residual(off, &new_resid);
                    let msg = &mut worker.msgs[li];
                    msg.len = n;
                    msg.idx.clear();
                    msg.val.clear();
                    for (i, &v) in sparse.iter().enumerate() {
                        if v != 0.0 {
                            msg.idx.push(i as u32);
                            msg.val.push(v);
                        }
                    }
                }
                if let Some(w0) = w0 {
                    worker.step_secs = w0.elapsed().as_secs_f64();
                }
            }
            self.reduce_apply_barrier_lags();
            return Ok(());
        }

        let delays = self.straggler_delays(t);
        match self.cfg.pipeline {
            PipelineMode::Barrier => {
                // worker-major compression into worker-owned per-layer
                // messages, then the fork-join reduction
                let meta = &self.layer_meta;
                let ks_t = &self.ks_t;
                self.exec.run(&mut self.cluster.workers, |rank, worker| {
                    let w0 = record.then(clock::now);
                    if let Some(ds) = &delays {
                        if !ds[rank].is_zero() {
                            std::thread::sleep(ds[rank]);
                        }
                    }
                    worker.comp.begin_step(worker.ef.residual(), &worker.grad, lr, k_total);
                    for li in (0..meta.len()).rev() {
                        let (off, n) = meta[li];
                        let c0 = measure.then(clock::now);
                        let ctx = LayerCtx {
                            seed,
                            uid: worker.id as u64,
                            step: t as u64,
                            layer: li as u64,
                        };
                        let (acc, resid) =
                            worker.ef.accumulate(off, &worker.grad[off..off + n], lr);
                        worker.comp.split(&ctx, acc, ks_t[li], &mut worker.msgs[li], resid);
                        if let Some(c0) = c0 {
                            worker.compress_secs[li] = c0.elapsed().as_secs_f64();
                        }
                    }
                    if let Some(w0) = w0 {
                        worker.step_secs = w0.elapsed().as_secs_f64();
                    }
                    Ok(())
                })?;
                self.reduce_apply_barrier_lags();
            }
            PipelineMode::Overlap => {
                self.stream.reset();
                // reset restores all-required; re-arm this step's quorum
                // mask before any worker publishes
                self.stream.arm_participants(&self.participants);
                let p = self.cluster.size();
                let inv_p = 1.0 / p as f32;
                let mu = self.cfg.momentum as f32;
                let meta = &self.layer_meta;
                let ks_t = &self.ks_t;
                let stream = &mut self.stream;
                let merge = &mut self.merge;
                let ctx = StepCtx {
                    spans: &meta[..],
                    agg: &mut self.agg[..],
                    params: &mut self.params[..],
                    momentum: &mut self.momentum_buf[..],
                    mu,
                    inv_p,
                    reduce_secs: &mut self.reduce_secs[..],
                    measure,
                };
                let (tx, rx) = mpsc::channel::<LayerMsg>();
                let (bytes, messages, overlap) = self.exec.run_with_sink(
                    &mut self.cluster.workers,
                    tx,
                    |rank, worker, tx| {
                        let w0 = record.then(clock::now);
                        if let Some(ds) = &delays {
                            if !ds[rank].is_zero() {
                                std::thread::sleep(ds[rank]);
                            }
                        }
                        worker.comp.begin_step(worker.ef.residual(), &worker.grad, lr, k_total);
                        for li in (0..meta.len()).rev() {
                            let (off, n) = meta[li];
                            let c0 = measure.then(clock::now);
                            let ctx = LayerCtx {
                                seed,
                                uid: worker.id as u64,
                                step: t as u64,
                                layer: li as u64,
                            };
                            let (acc, resid) =
                                worker.ef.accumulate(off, &worker.grad[off..off + n], lr);
                            worker.comp.split(&ctx, acc, ks_t[li], &mut worker.msgs[li], resid);
                            if let Some(c0) = c0 {
                                worker.compress_secs[li] = c0.elapsed().as_secs_f64();
                            }
                            worker.publish_layer(rank, li, tx);
                        }
                        if let Some(w0) = w0 {
                            worker.step_secs = w0.elapsed().as_secs_f64();
                        }
                        Ok(())
                    },
                    move || drain_stream(rx, stream, merge, wf, ctx),
                )?;
                anyhow::ensure!(self.stream.finished(), "streamed LAGS reduction incomplete");
                self.msg_stats.record(bytes, messages);
                self.overlap.accumulate(&overlap);
                for li in 0..nl {
                    for rank in 0..p {
                        if let Some(m) = self.stream.take(li, rank) {
                            self.cluster.workers[rank].msgs[li] = m;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Held-out evaluation: mean (loss, metric) over `batches` eval batches.
    pub fn evaluate(&self, batches: usize) -> Result<(f64, f64)> {
        let mut tl = 0.0;
        let mut tm = 0.0;
        for i in 0..batches {
            let b = self.data.eval_batch(i);
            let (loss, metric) = self.model.eval_step(&self.params, &b.x, &b.y)?;
            tl += loss as f64;
            tm += metric as f64;
        }
        Ok((tl / batches as f64, tm / batches as f64))
    }

    /// Simulated per-iteration wall-clock (the DES with this model's
    /// profile, the CONFIGURED network and the real worker count —
    /// P = 1 honestly simulates with zero communication).
    pub fn simulated_iteration(&self) -> crate::pipeline::desim::IterationBreakdown {
        let profile = ModelProfile::from_manifest(&self.model.mm, self.device_flops);
        let mut net = self.net;
        if self.cfg.faults.perturbs_time() {
            // conservative link pricing under jitter: every message pays
            // the worst-case draw (α inflated, bandwidth deflated) — the
            // DES stays a deterministic single-number prediction
            net.alpha *= 1.0 + self.cfg.faults.alpha_jitter;
            net.bandwidth *= (1.0 - self.cfg.faults.bandwidth_jitter).max(0.05);
        }
        let params = match self.cfg.algorithm {
            Algorithm::Dense => SimParams::dense(&profile),
            _ => {
                let mut p = SimParams::uniform(&profile, self.cfg.compression);
                // backprop order = reversed manifest order
                p.ratios = self.ratios.iter().rev().cloned().collect();
                p.merge_bytes = self.cfg.merge_bytes as f64;
                // a quantized wire format narrows every sparse message
                // (per-message overhead is negligible at DES granularity)
                p.wire_bytes_per_elem = self.cfg.compressor.wire().elem_bytes as f64;
                if self.robustness_active() {
                    // the LIVE membership's skews: the DES predicts the
                    // straggler-degraded (and quorum-recovered) step on
                    // the same fault plan the real trainer runs
                    p.skews = self
                        .cluster
                        .workers
                        .iter()
                        .map(|w| self.cfg.faults.skew_of(w.id))
                        .collect();
                    p.quorum = self.cfg.quorum;
                }
                p
            }
        };
        simulate(&profile, &net, self.cfg.algorithm.schedule(), &params)
    }

    /// Run the full configured training loop. A resumed trainer picks up
    /// at its checkpointed step, so the loop covers only the remaining
    /// steps (the report's curve then spans the post-resume segment; its
    /// final numbers match the uninterrupted run's bit-for-bit).
    pub fn run(&mut self) -> Result<TrainReport> {
        let mut curve = CurveRecorder::new(&["train_loss", "eval_loss", "metric"]);
        let wall_start = clock::now();
        let mut final_eval = (f64::NAN, f64::NAN);
        // a step-0 checkpoint anchors crashes scheduled before the first
        // --checkpoint-every boundary: resume is always possible
        if self.cfg.checkpoint_every > 0 && self.step_idx == 0 {
            self.save_checkpoint()?;
        }
        for s in self.step_idx..self.cfg.steps {
            let loss = self.step()?;
            let do_eval = self.cfg.eval_every > 0
                && ((s + 1) % self.cfg.eval_every == 0 || s + 1 == self.cfg.steps);
            if do_eval {
                final_eval = self.evaluate(self.cfg.eval_batches)?;
                curve.push(s + 1, &[loss, final_eval.0, final_eval.1]);
            } else {
                curve.push(s + 1, &[loss, f64::NAN, f64::NAN]);
            }
            if self.cfg.verbose && (s % 10 == 0 || s + 1 == self.cfg.steps) {
                eprintln!(
                    "[{}] step {:>5} loss {:.4} eval {:.4}/{:.4}",
                    self.cfg.algorithm.name(),
                    s + 1,
                    loss,
                    final_eval.0,
                    final_eval.1
                );
            }
            // adaptive runs report the effective c_max (Corollary 2's
            // convergence knob) once per eval epoch
            if self.cfg.verbose && do_eval && !self.selections.is_empty() {
                eprintln!(
                    "[{}] step {:>5} effective c_max = {:.1} ({} selection(s) so far)",
                    self.cfg.algorithm.name(),
                    s + 1,
                    adaptive::ratio::effective_cmax(&self.ratios),
                    self.selections.len(),
                );
            }
        }
        if !self.cfg.record_trace.is_empty() {
            self.write_trace()?;
            if self.cfg.verbose {
                eprintln!(
                    "[{}] recorded {}-step trace to {:?}",
                    self.cfg.algorithm.name(),
                    self.trace_rows.len(),
                    self.cfg.record_trace,
                );
            }
        }
        let wall = wall_start.elapsed().as_secs_f64();
        let sim = self.simulated_iteration();
        let metric_name = match self.model.mm.metric {
            Metric::Accuracy => "accuracy",
            Metric::PplLoss => "ppl_loss",
        };
        Ok(TrainReport {
            algorithm: self.cfg.algorithm,
            model: self.cfg.model.clone(),
            steps: self.cfg.steps,
            final_loss: curve.last("train_loss").unwrap_or(f64::NAN),
            final_eval_loss: final_eval.0,
            final_metric: final_eval.1,
            metric_name: metric_name.to_string(),
            curve,
            delta_fraction_holding: self.delta.as_ref().map(|m| m.fraction_holding()),
            delta_max: self.delta.as_ref().map(|m| m.max_delta()),
            msg_stats: self.msg_stats.clone(),
            wall_seconds: wall,
            pipeline: self.cfg.pipeline.name().to_string(),
            measured_comm_seconds: self.overlap.busy_seconds,
            measured_hidden_seconds: self.overlap.hidden_seconds,
            overlap_efficiency: self.overlap.efficiency(),
            sim_iter_seconds: sim.iter_time,
            sim_hidden_seconds: sim.hidden,
            sim_overlap_efficiency: sim.overlap_efficiency(),
            net_alpha: self.cfg.net.alpha,
            net_bandwidth: self.cfg.net.bandwidth,
            device_flops: self.device_flops,
            flops_source: self.flops_source.clone(),
            selections: self.selections.clone(),
            robustness: self.robustness_stats(),
        })
    }

    /// Robustness telemetry accumulated so far (all-default for a clean
    /// full-sync run — stable field names, see [`RobustnessStats`]).
    pub fn robustness_stats(&self) -> RobustnessStats {
        if !self.robustness_active() {
            return RobustnessStats::default();
        }
        RobustnessStats {
            worker_skew: self
                .steps_active
                .iter()
                .map(|(&uid, &steps)| WorkerSkew {
                    worker: uid,
                    skew: self.cfg.faults.skew_of(uid),
                    steps_active: steps,
                })
                .collect(),
            quorum_miss_per_layer: self.robust_quorum_miss.clone(),
            staleness_hist: self.robust_staleness_hist.clone(),
            membership_log: self.robust_membership_log.clone(),
            quorum: self.cfg.quorum,
            staleness_bound: self.cfg.staleness_bound,
        }
    }

    /// Current live worker count (elastic membership moves it).
    pub fn cluster_size(&self) -> usize {
        self.cluster.size()
    }

    /// Live §5 merge-buffer capacity, `merge_bytes × CURRENT P` — the
    /// regression hook for the elastic re-capacity fix (the capacity used
    /// to be frozen at the startup worker count).
    pub fn merge_capacity_bytes(&self) -> usize {
        self.merge.capacity_bytes()
    }

    /// Per-coordinate f64 sums of the workers' error-feedback residuals
    /// (conservation assertions in the fault-injection tests).
    pub fn residual_coordinate_sums(&self) -> Vec<f64> {
        self.cluster.residual_coordinate_sums()
    }

    /// Access the delta monitor's per-layer series (Fig. 2 harness).
    pub fn delta_series(&self) -> Option<&[Vec<(usize, f64)>]> {
        self.delta.as_ref().map(|m| m.series.as_slice())
    }

    pub fn model_manifest(&self) -> &crate::runtime::ModelManifest {
        &self.model.mm
    }

    /// The per-run message statistics (test/bench introspection).
    pub fn msg_stats(&self) -> &MessageStats {
        &self.msg_stats
    }
}
