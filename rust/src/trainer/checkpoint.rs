//! Durable checkpoint/restore for the distributed trainer.
//!
//! One file, `checkpoint.bin`, captures the COMPLETE deterministic state
//! of a run, so `resume ≡ uninterrupted` holds bit-for-bit:
//!
//! * the replicated params and the aggregated-momentum buffer;
//! * every worker's error-feedback residual (the deferred gradient mass
//!   the EF convergence argument requires to eventually reach the
//!   parameters — dropping it would silently change the trajectory),
//!   local-momentum buffer, last loss and quorum-staleness backlog,
//!   keyed by stable uid so elastic membership survives the round trip;
//! * the per-layer ratios/ks in effect plus the Eq. 18 selection
//!   history, the online [`MeasuredProfile`] EWMAs, and the δ monitor's
//!   series AND RandK RNG stream position (single-draw mode advances
//!   that stream once per sample — resuming without it would shift
//!   every later δ draw);
//! * message stats, overlap accounting, robustness telemetry, the
//!   membership log and per-uid activity counters, and the global step.
//!
//! The synthetic data stream needs no state: batches are pure functions
//! of `(seed, worker uid, step)`.
//!
//! ## On-disk format (version 1)
//!
//! ```text
//! b"LAGSCKPT" | u32 version LE | u64 header_len LE | header JSON
//!            | binary payload (little-endian) | u64 FNV-1a checksum LE
//! ```
//!
//! The JSON header carries `{kind, step, artifacts, config}` — enough
//! for `lags resume <dir>` to rebuild the [`Runtime`] and the
//! [`TrainConfig`] with no extra flags. All floats live in the binary
//! payload (JSON cannot represent every f32/f64 bit pattern); the
//! trailing checksum covers every preceding byte, and the file is
//! written atomically (temp + fsync + rename), so a crash mid-write
//! can never leave a half-valid checkpoint behind.
//!
//! Crash tombstones ride in the same directory: `crash-{step}.tombstone`
//! marks an injected [`faults::CrashPoint`] that already fired, so the
//! resumed process replays through that step instead of dying again.
//! Tombstones are read ONLY on resume — a fresh run re-arms every crash.

use super::{MembershipChange, MessageStats, RatioSelection, Trainer};
use crate::cluster::Worker;
use crate::collectives::pipeline::OverlapMeasure;
use crate::config::TrainConfig;
use crate::runtime::Runtime;
use crate::util::json::{self, Json};
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::Arc;

const MAGIC: [u8; 8] = *b"LAGSCKPT";
const VERSION: u32 = 1;
const HEADER_KIND: &str = "lags-checkpoint";

/// File name of the checkpoint inside `--checkpoint-dir`.
pub const CHECKPOINT_FILE: &str = "checkpoint.bin";

/// FNV-1a over the whole file body — cheap, dependency-free, and plenty
/// to catch truncation and bit rot (this is integrity, not crypto).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Little-endian byte sink for the binary payload.
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn len(&mut self, v: usize) {
        self.u64(v as u64);
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32s(&mut self, xs: &[f32]) {
        self.len(xs.len());
        for &x in xs {
            self.f32(x);
        }
    }
    fn f64s(&mut self, xs: &[f64]) {
        self.len(xs.len());
        for &x in xs {
            self.f64(x);
        }
    }
    fn u64s(&mut self, xs: &[u64]) {
        self.len(xs.len());
        for &x in xs {
            self.u64(x);
        }
    }
    fn usizes(&mut self, xs: &[usize]) {
        self.len(xs.len());
        for &x in xs {
            self.len(x);
        }
    }
    fn str(&mut self, s: &str) {
        self.len(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }
}

/// Checked little-endian reader over the payload; every read bails with
/// a "truncated" error instead of panicking (the checksum catches real
/// corruption first, but a version-skewed payload must still fail
/// cleanly).
struct Dec<'a> {
    b: &'a [u8],
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.b.len() >= n, "truncated checkpoint payload (wanted {n} more bytes)");
        let (head, rest) = self.b.split_at(n);
        self.b = rest;
        Ok(head)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }
    fn len(&mut self) -> Result<usize> {
        usize::try_from(self.u64()?).context("length overflows usize")
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.len()?;
        (0..n).map(|_| self.f32()).collect()
    }
    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.len()?;
        (0..n).map(|_| self.f64()).collect()
    }
    fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.len()?;
        (0..n).map(|_| self.u64()).collect()
    }
    fn usizes(&mut self) -> Result<Vec<usize>> {
        let n = self.len()?;
        (0..n).map(|_| self.len()).collect()
    }
    fn str(&mut self) -> Result<String> {
        let n = self.len()?;
        Ok(std::str::from_utf8(self.take(n)?).context("non-UTF-8 string field")?.to_string())
    }
    fn finish(&self) -> Result<()> {
        ensure!(self.b.is_empty(), "{} trailing bytes after checkpoint payload", self.b.len());
        Ok(())
    }
}

/// One worker's durable state, keyed by stable uid (NOT rank — elastic
/// membership permutes ranks, uids never change).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerState {
    pub uid: usize,
    pub residual: Vec<f32>,
    pub local_mom: Vec<f32>,
    pub last_loss: f32,
    pub quorum_stale: usize,
}

/// The δ monitor's durable state: per-layer series plus the RandK
/// denominator's RNG stream position.
#[derive(Debug, Clone, PartialEq)]
pub struct DeltaState {
    pub series: Vec<Vec<(usize, f64)>>,
    pub rng_state: u64,
    pub spare: Option<f64>,
}

/// A decoded checkpoint — the complete deterministic trainer state at a
/// step boundary. [`Checkpoint::capture`] and [`Checkpoint::apply_to`]
/// are exact inverses (pinned by the round-trip proptest).
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// steps completed when the checkpoint was taken (`Trainer::step_idx`)
    pub step: usize,
    /// artifacts dir the run's [`Runtime`] was opened from ("native" for
    /// the built-in zoo) — lets `lags resume <dir>` rebuild it
    pub artifacts: String,
    /// the full [`TrainConfig`] as JSON (`TrainConfig::to_json`)
    pub config: Json,
    pub params: Vec<f32>,
    pub momentum: Vec<f32>,
    pub workers: Vec<WorkerState>,
    pub ratios: Vec<f64>,
    pub ks: Vec<usize>,
    pub selections: Vec<RatioSelection>,
    /// online EWMA profile `(t_comp, t_compress, t_reduce, steps)` — see
    /// `MeasuredProfile::ewma_snapshot`
    pub online: Option<(f64, Vec<f64>, Vec<f64>, usize)>,
    pub delta: Option<DeltaState>,
    pub msg_stats: MessageStats,
    pub last_comp_secs: f64,
    pub overlap_busy: f64,
    pub overlap_hidden: f64,
    pub quorum_miss: Vec<u64>,
    pub staleness_hist: Vec<u64>,
    pub membership_log: Vec<MembershipChange>,
    /// per-uid membership-duration counters, sorted by uid
    pub steps_active: Vec<(usize, usize)>,
}

impl Checkpoint {
    /// Snapshot the trainer's complete deterministic state.
    pub fn capture(t: &Trainer) -> Checkpoint {
        Checkpoint {
            step: t.step_idx,
            artifacts: t.artifacts.clone(),
            config: t.cfg.to_json(),
            params: t.params.clone(),
            momentum: t.momentum_buf.clone(),
            workers: t
                .cluster
                .workers
                .iter()
                .map(|w| WorkerState {
                    uid: w.id,
                    residual: w.ef.residual().to_vec(),
                    local_mom: w.local_mom.clone(),
                    last_loss: w.last_loss,
                    quorum_stale: w.quorum_stale,
                })
                .collect(),
            ratios: t.ratios.clone(),
            ks: t.ks.clone(),
            selections: t.selections.clone(),
            online: t.online.as_ref().map(|mp| mp.ewma_snapshot()),
            delta: t.delta.as_ref().map(|m| {
                let (rng_state, spare) = m.rng_snapshot();
                DeltaState { series: m.series.clone(), rng_state, spare }
            }),
            msg_stats: t.msg_stats.clone(),
            last_comp_secs: t.last_comp_secs,
            overlap_busy: t.overlap.busy_seconds,
            overlap_hidden: t.overlap.hidden_seconds,
            quorum_miss: t.robust_quorum_miss.clone(),
            staleness_hist: t.robust_staleness_hist.clone(),
            membership_log: t.robust_membership_log.clone(),
            steps_active: t.steps_active.iter().map(|(&uid, &n)| (uid, n)).collect(),
        }
    }

    /// Install this checkpoint's state onto a freshly-built trainer with
    /// the same config. The cluster is rebuilt worker-by-worker from the
    /// stored uids (membership may differ from the startup P), then every
    /// P-shaped structure re-sizes to the restored membership.
    pub fn apply_to(&self, t: &mut Trainer) -> Result<()> {
        let d = t.model.mm.d;
        let nl = t.layer_meta.len();
        ensure!(
            self.params.len() == d && self.momentum.len() == d,
            "checkpoint/model mismatch: {} params on disk, model has {d}",
            self.params.len()
        );
        ensure!(
            self.ks.len() == nl && self.ratios.len() == nl && self.quorum_miss.len() == nl,
            "checkpoint/model mismatch: {} layers on disk, model has {nl}",
            self.ks.len()
        );
        ensure!(!self.workers.is_empty(), "checkpoint has no workers");
        t.step_idx = self.step;
        t.params.copy_from_slice(&self.params);
        t.momentum_buf.copy_from_slice(&self.momentum);
        let layer_sizes: Vec<usize> = t.model.mm.layers.iter().map(|l| l.size).collect();
        t.cluster.workers = self
            .workers
            .iter()
            .map(|ws| {
                ensure!(
                    ws.residual.len() == d,
                    "worker {}: residual length {} != model dim {d}",
                    ws.uid,
                    ws.residual.len()
                );
                let mut w = Worker::new(ws.uid, d, t.cfg.sample_stride, t.cfg.compressor);
                w.ensure_message_scratch(&layer_sizes);
                w.ef.write_residual(0, &ws.residual);
                w.local_mom = ws.local_mom.clone();
                w.last_loss = ws.last_loss;
                w.quorum_stale = ws.quorum_stale;
                Ok(w)
            })
            .collect::<Result<Vec<_>>>()?;
        t.resize_to_membership();
        t.ratios = self.ratios.clone();
        t.ks = self.ks.clone();
        t.selections = self.selections.clone();
        match (&mut t.online, &self.online) {
            (Some(mp), Some((t_comp, t_compress, t_reduce, steps))) => {
                mp.restore_ewma(*t_comp, t_compress, t_reduce, *steps)
            }
            (None, None) => {}
            _ => bail!("checkpoint and config disagree on online adaptive measurement"),
        }
        match (&mut t.delta, &self.delta) {
            (Some(m), Some(ds)) => m.restore(ds.series.clone(), ds.rng_state, ds.spare),
            (None, None) => {}
            _ => bail!("checkpoint and config disagree on the δ monitor"),
        }
        t.msg_stats = self.msg_stats.clone();
        t.last_comp_secs = self.last_comp_secs;
        t.overlap = OverlapMeasure {
            busy_seconds: self.overlap_busy,
            hidden_seconds: self.overlap_hidden,
        };
        t.robust_quorum_miss = self.quorum_miss.clone();
        t.robust_staleness_hist = self.staleness_hist.clone();
        t.robust_membership_log = self.membership_log.clone();
        t.steps_active = self.steps_active.iter().copied().collect();
        Ok(())
    }

    fn encode_payload(&self, e: &mut Enc) {
        e.f32s(&self.params);
        e.f32s(&self.momentum);
        e.len(self.workers.len());
        for w in &self.workers {
            e.len(w.uid);
            e.f32s(&w.residual);
            e.f32s(&w.local_mom);
            e.f32(w.last_loss);
            e.len(w.quorum_stale);
        }
        e.f64s(&self.ratios);
        e.usizes(&self.ks);
        e.len(self.selections.len());
        for s in &self.selections {
            e.len(s.step);
            e.f64(s.effective_cmax);
            e.f64s(&s.ratios);
        }
        match &self.online {
            None => e.u8(0),
            Some((t_comp, t_compress, t_reduce, steps)) => {
                e.u8(1);
                e.f64(*t_comp);
                e.f64s(t_compress);
                e.f64s(t_reduce);
                e.len(*steps);
            }
        }
        match &self.delta {
            None => e.u8(0),
            Some(ds) => {
                e.u8(1);
                e.u64(ds.rng_state);
                match ds.spare {
                    None => e.u8(0),
                    Some(v) => {
                        e.u8(1);
                        e.f64(v);
                    }
                }
                e.len(ds.series.len());
                for layer in &ds.series {
                    e.len(layer.len());
                    for &(step, delta) in layer {
                        e.len(step);
                        e.f64(delta);
                    }
                }
            }
        }
        e.len(self.msg_stats.total_bytes);
        e.len(self.msg_stats.total_messages);
        e.len(self.msg_stats.iterations);
        e.f64(self.last_comp_secs);
        e.f64(self.overlap_busy);
        e.f64(self.overlap_hidden);
        e.u64s(&self.quorum_miss);
        e.u64s(&self.staleness_hist);
        e.len(self.membership_log.len());
        for m in &self.membership_log {
            e.len(m.step);
            e.str(&m.action);
            e.len(m.worker);
            e.len(m.workers_after);
        }
        e.len(self.steps_active.len());
        for &(uid, n) in &self.steps_active {
            e.len(uid);
            e.len(n);
        }
    }

    fn decode_payload(
        d: &mut Dec<'_>,
        step: usize,
        artifacts: String,
        config: Json,
    ) -> Result<Checkpoint> {
        let params = d.f32s()?;
        let momentum = d.f32s()?;
        let nworkers = d.len()?;
        let mut workers = Vec::with_capacity(nworkers);
        for _ in 0..nworkers {
            workers.push(WorkerState {
                uid: d.len()?,
                residual: d.f32s()?,
                local_mom: d.f32s()?,
                last_loss: d.f32()?,
                quorum_stale: d.len()?,
            });
        }
        let ratios = d.f64s()?;
        let ks = d.usizes()?;
        let nsel = d.len()?;
        let mut selections = Vec::with_capacity(nsel);
        for _ in 0..nsel {
            selections.push(RatioSelection {
                step: d.len()?,
                effective_cmax: d.f64()?,
                ratios: d.f64s()?,
            });
        }
        let online = match d.u8()? {
            0 => None,
            1 => Some((d.f64()?, d.f64s()?, d.f64s()?, d.len()?)),
            v => bail!("bad online flag {v}"),
        };
        let delta = match d.u8()? {
            0 => None,
            1 => {
                let rng_state = d.u64()?;
                let spare = match d.u8()? {
                    0 => None,
                    1 => Some(d.f64()?),
                    v => bail!("bad spare flag {v}"),
                };
                let nl = d.len()?;
                let mut series = Vec::with_capacity(nl);
                for _ in 0..nl {
                    let n = d.len()?;
                    let mut layer = Vec::with_capacity(n);
                    for _ in 0..n {
                        layer.push((d.len()?, d.f64()?));
                    }
                    series.push(layer);
                }
                Some(DeltaState { series, rng_state, spare })
            }
            v => bail!("bad delta flag {v}"),
        };
        let msg_stats = MessageStats {
            total_bytes: d.len()?,
            total_messages: d.len()?,
            iterations: d.len()?,
        };
        let last_comp_secs = d.f64()?;
        let overlap_busy = d.f64()?;
        let overlap_hidden = d.f64()?;
        let quorum_miss = d.u64s()?;
        let staleness_hist = d.u64s()?;
        let nlog = d.len()?;
        let mut membership_log = Vec::with_capacity(nlog);
        for _ in 0..nlog {
            membership_log.push(MembershipChange {
                step: d.len()?,
                action: d.str()?,
                worker: d.len()?,
                workers_after: d.len()?,
            });
        }
        let nactive = d.len()?;
        let mut steps_active = Vec::with_capacity(nactive);
        for _ in 0..nactive {
            steps_active.push((d.len()?, d.len()?));
        }
        Ok(Checkpoint {
            step,
            artifacts,
            config,
            params,
            momentum,
            workers,
            ratios,
            ks,
            selections,
            online,
            delta,
            msg_stats,
            last_comp_secs,
            overlap_busy,
            overlap_hidden,
            quorum_miss,
            staleness_hist,
            membership_log,
            steps_active,
        })
    }

    /// Serialize and write atomically (temp + fsync + rename): readers
    /// only ever see the previous complete checkpoint or this one.
    pub fn write(&self, path: &Path) -> Result<()> {
        let header = Json::obj(vec![
            ("kind", Json::Str(HEADER_KIND.into())),
            ("step", Json::Num(self.step as f64)),
            ("artifacts", Json::Str(self.artifacts.clone())),
            ("config", self.config.clone()),
        ])
        .to_string_compact();
        let mut e = Enc { buf: Vec::with_capacity(header.len() + 64 + 8 * self.params.len()) };
        e.buf.extend_from_slice(&MAGIC);
        e.buf.extend_from_slice(&VERSION.to_le_bytes());
        e.u64(header.len() as u64);
        e.buf.extend_from_slice(header.as_bytes());
        self.encode_payload(&mut e);
        let sum = fnv1a(&e.buf);
        e.u64(sum);
        json::write_atomic(path, &e.buf).with_context(|| format!("writing checkpoint {path:?}"))
    }

    /// Read + verify a checkpoint file. The trailing FNV-1a checksum is
    /// checked before anything is parsed, so truncation and corruption
    /// both fail with an explicit checksum error.
    pub fn read(path: &Path) -> Result<Checkpoint> {
        let data =
            std::fs::read(path).with_context(|| format!("reading checkpoint {path:?}"))?;
        if data.len() < MAGIC.len() + 4 + 8 + 8 {
            bail!(
                "checkpoint {path:?} is only {} bytes — too short to carry its checksum \
                 (truncated write?)",
                data.len()
            );
        }
        let (body, tail) = data.split_at(data.len() - 8);
        let stored = u64::from_le_bytes(tail.try_into().expect("8-byte tail"));
        let computed = fnv1a(body);
        if stored != computed {
            bail!(
                "checkpoint {path:?} failed its checksum (stored {stored:#018x}, computed \
                 {computed:#018x}) — the file is truncated or corrupt"
            );
        }
        let mut d = Dec { b: body };
        let magic = d.take(MAGIC.len())?;
        ensure!(magic == MAGIC, "checkpoint {path:?}: bad magic (not a LAGS checkpoint)");
        let version = u32::from_le_bytes(d.take(4)?.try_into().expect("4 bytes"));
        ensure!(
            version == VERSION,
            "checkpoint {path:?}: unsupported format version {version} (this build reads \
             {VERSION})"
        );
        let hlen = d.len()?;
        let header_bytes = d.take(hlen)?;
        let header = Json::parse(
            std::str::from_utf8(header_bytes).context("checkpoint header is not UTF-8")?,
        )
        .with_context(|| format!("parsing checkpoint header of {path:?}"))?;
        ensure!(
            header.get("kind")?.as_str()? == HEADER_KIND,
            "checkpoint {path:?}: unexpected header kind"
        );
        let step = header.get("step")?.as_usize().context("header step")?;
        let artifacts = header.get("artifacts")?.as_str()?.to_string();
        let config = header.get("config")?.clone();
        let ck = Self::decode_payload(&mut d, step, artifacts, config)
            .with_context(|| format!("decoding checkpoint payload of {path:?}"))?;
        d.finish()?;
        Ok(ck)
    }
}

/// Record that the injected crash at `step` has fired, durably, so the
/// resumed process replays straight through it. Written (fsync'd) BEFORE
/// the crash error propagates.
pub(crate) fn write_tombstone(dir: &str, step: usize) -> Result<()> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating checkpoint dir {dir:?}"))?;
    let path = Path::new(dir).join(format!("crash-{step}.tombstone"));
    json::write_atomic(&path, b"fired\n").with_context(|| format!("writing tombstone {path:?}"))
}

/// Scan `dir` for fired-crash tombstones. Called only on resume — a
/// fresh run starts with every scheduled crash armed.
fn load_tombstones(dir: &str) -> Result<BTreeSet<usize>> {
    let mut fired = BTreeSet::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(fired), // no dir yet ⇒ nothing fired
    };
    for entry in entries {
        let name = entry?.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some(step) = name.strip_prefix("crash-").and_then(|s| s.strip_suffix(".tombstone"))
        {
            if let Ok(s) = step.parse::<usize>() {
                fired.insert(s);
            }
        }
    }
    Ok(fired)
}

impl Trainer {
    /// Path of the checkpoint file inside `dir`.
    pub fn checkpoint_path(dir: &str) -> PathBuf {
        Path::new(dir).join(CHECKPOINT_FILE)
    }

    /// Write the current state to `--checkpoint-dir`, atomically.
    pub fn save_checkpoint(&self) -> Result<()> {
        let dir = &self.cfg.checkpoint_dir;
        ensure!(!dir.is_empty(), "save_checkpoint requires --checkpoint-dir");
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {dir:?}"))?;
        Checkpoint::capture(self).write(&Self::checkpoint_path(dir))
    }

    /// Resume from `dir`'s checkpoint on an already-open runtime (tests
    /// and harnesses that share one [`Runtime`] across runs).
    pub fn resume_with_runtime(rt: &Arc<Runtime>, dir: &str) -> Result<Trainer> {
        let ck = Checkpoint::read(&Self::checkpoint_path(dir))?;
        Self::resume_from_checkpoint(rt, &ck, dir)
    }

    /// `lags resume <dir>`: read the checkpoint, re-open the runtime it
    /// recorded (artifacts dir + seed from the embedded config), and
    /// rebuild the trainer at the saved step. Calibration is never
    /// re-measured on resume (a persisted calibration file still loads),
    /// so resumed pricing matches the original run's.
    pub fn resume_from_dir(dir: &str) -> Result<Trainer> {
        let ck = Checkpoint::read(&Self::checkpoint_path(dir))?;
        let seed = ck.config.get("seed")?.as_usize().context("config seed")? as u64;
        let mut rt = Runtime::open(&ck.artifacts, seed)?;
        rt.calibrate(false)?;
        Self::resume_from_checkpoint(&Arc::new(rt), &ck, dir)
    }

    fn resume_from_checkpoint(rt: &Arc<Runtime>, ck: &Checkpoint, dir: &str) -> Result<Trainer> {
        let model = ck.config.get("model")?.as_str().context("config model")?;
        let mut cfg = TrainConfig::default_for(model);
        cfg.apply_json(&ck.config)?;
        // resume never re-measures calibration, and always checkpoints
        // back into the SAME dir (where the crash tombstones live)
        cfg.calibrate = false;
        cfg.checkpoint_dir = dir.to_string();
        let mut t = Trainer::with_runtime(rt, cfg)?;
        ck.apply_to(&mut t)?;
        t.fired_crashes = load_tombstones(dir)?;
        Ok(t)
    }

    /// Steps completed so far (== the step index the next `step()` runs).
    pub fn step_index(&self) -> usize {
        self.step_idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_known_vectors() {
        // reference values for the 64-bit FNV-1a parameters
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn enc_dec_round_trip_primitives() {
        let mut e = Enc { buf: Vec::new() };
        e.u8(7);
        e.u64(u64::MAX - 3);
        e.f32(-0.5);
        e.f64(std::f64::consts::PI);
        e.f32s(&[1.0, f32::NAN, -0.0]);
        e.f64s(&[2.5]);
        e.u64s(&[9, 8]);
        e.usizes(&[3, 1, 4]);
        e.str("drop");
        let mut d = Dec { b: &e.buf };
        assert_eq!(d.u8().unwrap(), 7);
        assert_eq!(d.u64().unwrap(), u64::MAX - 3);
        assert_eq!(d.f32().unwrap(), -0.5);
        assert_eq!(d.f64().unwrap(), std::f64::consts::PI);
        let fs = d.f32s().unwrap();
        assert_eq!(fs.len(), 3);
        assert_eq!(fs[0], 1.0);
        assert!(fs[1].is_nan(), "NaN survives the binary round trip");
        assert_eq!(fs[2].to_bits(), (-0.0f32).to_bits(), "-0.0 bit pattern preserved");
        assert_eq!(d.f64s().unwrap(), vec![2.5]);
        assert_eq!(d.u64s().unwrap(), vec![9, 8]);
        assert_eq!(d.usizes().unwrap(), vec![3, 1, 4]);
        assert_eq!(d.str().unwrap(), "drop");
        d.finish().unwrap();
    }

    #[test]
    fn truncated_reads_error_not_panic() {
        let mut e = Enc { buf: Vec::new() };
        e.u64(1000); // length prefix promising far more than is present
        let mut d = Dec { b: &e.buf };
        assert!(d.f32s().is_err());
    }
}
