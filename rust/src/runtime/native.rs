//! Native backend: a heterogeneous reference model zoo executed directly
//! on the host.
//!
//! The PJRT backend needs the vendored `xla` crate plus `make artifacts`;
//! neither is required to exercise the *distributed* layer this crate
//! reproduces (workers, error feedback, sparse aggregation, pipelining).
//! This backend supplies the same `train/eval/apply/compress` contract
//! with plain-rust f32 math over a built-in model zoo, so the trainer,
//! the determinism tests and the hot-path benches run in any environment
//! — and, unlike PJRT executables, it is `Sync`, so the P workers'
//! gradient steps genuinely fan out across threads.
//!
//! ## Layer zoo (DESIGN.md §Native-layer-zoo)
//!
//! The zoo is no longer MLP-only. [`NativeNet`] executes a layer graph
//! assembled from [`LayerSpec`]s:
//!
//! * `Dense`    — fused `[fan_in + 1, fan_out]` tensor (last row = bias),
//!   ReLU on hidden layers, identity on the output layer;
//! * `Conv`     — channels-last Conv2d via im2col: the fused tensor is
//!   `[k·k·cin + 1, cout]` (last row = bias), stride + zero padding,
//!   always ReLU;
//! * `MaxPool`  — k×k window, stride k, no parameters;
//! * `Flatten`  — shape bookkeeping only (channels-last is already
//!   row-major contiguous, so it resolves to nothing at runtime);
//! * `Embed`    — token table `[vocab, dim]` over i32 inputs;
//! * `Elman`    — simple recurrent cell unrolled over the sequence with
//!   full BPTT: the fused tensor is `[in + hidden + 1, hidden]` (rows
//!   0..in = Wx, rows in..in+hidden = Wh, last row = bias), tanh states.
//!
//! Fusing each block's weights + bias into ONE manifest tensor matters
//! for the paper's Eq. 18: interleaved 10-float bias tensors would give
//! every weight tensor a near-zero overlap budget (the next "layer" in
//! backprop order would be a bias whose backward takes microseconds) and
//! force the adaptive selection to the cap everywhere. One tensor per
//! block makes the layer table's comm-to-compute ratios mean something.
//!
//! Determinism: every mat-mul hot loop runs through the blocked GEMM
//! kernels in [`super::kernels`], whose per-element f32 accumulation
//! chain is fixed (reduction index ascending, seeded from the incoming
//! value) regardless of blocking or tiling — so results are bit-identical
//! across runs and across `--threads` settings (each worker's math
//! touches only that worker's inputs). See DESIGN.md
//! §Kernels-and-calibration.

use super::kernels;
use super::manifest::{BatchSpec, DType, LayerInfo, Manifest, Metric, ModelManifest};
use super::BatchData;
use crate::sparsify::{threshold, topk};
use crate::util::rng::Rng;
use crate::util::{next_pow2, pad_to};
use anyhow::{bail, ensure, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Strided double-sampling stride baked into the AOT compress artifacts;
/// the native emulation of `CompressorKind::XlaSampled` mirrors it.
pub const XLA_SAMPLE_STRIDE: usize = 64;

// ---------------------------------------------------------------------------
// layer primitives
// ---------------------------------------------------------------------------

/// Geometry of one channels-last Conv2d layer (per-sample input
/// `[h, w, cin]`, output `[out_h, out_w, cout]`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvDims {
    pub h: usize,
    pub w: usize,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvDims {
    pub fn out_h(&self) -> usize {
        (self.h + 2 * self.pad - self.k) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.w + 2 * self.pad - self.k) / self.stride + 1
    }

    /// im2col patch length `k·k·cin` (one GEMM reduction axis).
    pub fn patch(&self) -> usize {
        self.k * self.k * self.cin
    }

    pub fn weight_len(&self) -> usize {
        self.patch() * self.cout
    }

    pub fn in_len(&self) -> usize {
        self.h * self.w * self.cin
    }

    pub fn out_len(&self) -> usize {
        self.out_h() * self.out_w() * self.cout
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.k >= 1 && self.stride >= 1, "conv k/stride must be >= 1");
        ensure!(self.cin >= 1 && self.cout >= 1, "conv channels must be >= 1");
        ensure!(self.pad < self.k, "conv pad must be < k");
        ensure!(
            self.h + 2 * self.pad >= self.k && self.w + 2 * self.pad >= self.k,
            "conv kernel larger than padded input"
        );
        Ok(())
    }
}

/// Gather one sample's im2col matrix: `col[p, q]` with `p` the output
/// pixel `(oy·out_w + ox)` and `q = (ky·k + kx)·cin + ci` — the same
/// (ky, kx, ci) lexicographic reduction order a direct convolution walks,
/// so the GEMM sums coordinates in the identical f32 order. Out-of-image
/// taps are zero (zero padding).
pub fn im2col(d: &ConvDims, x: &[f32], col: &mut [f32]) {
    let (ho, wo, patch) = (d.out_h(), d.out_w(), d.patch());
    debug_assert_eq!(x.len(), d.in_len());
    debug_assert_eq!(col.len(), ho * wo * patch);
    for oy in 0..ho {
        for ox in 0..wo {
            let prow = &mut col[(oy * wo + ox) * patch..(oy * wo + ox + 1) * patch];
            for ky in 0..d.k {
                let iy = (oy * d.stride + ky) as isize - d.pad as isize;
                for kx in 0..d.k {
                    let ix = (ox * d.stride + kx) as isize - d.pad as isize;
                    let dst = &mut prow[(ky * d.k + kx) * d.cin..(ky * d.k + kx + 1) * d.cin];
                    let inside =
                        iy >= 0 && (iy as usize) < d.h && ix >= 0 && (ix as usize) < d.w;
                    if inside {
                        let s = ((iy as usize) * d.w + ix as usize) * d.cin;
                        dst.copy_from_slice(&x[s..s + d.cin]);
                    } else {
                        dst.iter_mut().for_each(|v| *v = 0.0);
                    }
                }
            }
        }
    }
}

/// Scatter-add one sample's `dcol` (the im2col layout of the gradient)
/// back onto the input image — the transpose of [`im2col`].
fn col2im_add(d: &ConvDims, dcol: &[f32], dx: &mut [f32]) {
    let (ho, wo, patch) = (d.out_h(), d.out_w(), d.patch());
    debug_assert_eq!(dx.len(), d.in_len());
    for oy in 0..ho {
        for ox in 0..wo {
            let prow = &dcol[(oy * wo + ox) * patch..(oy * wo + ox + 1) * patch];
            for ky in 0..d.k {
                let iy = (oy * d.stride + ky) as isize - d.pad as isize;
                if iy < 0 || (iy as usize) >= d.h {
                    continue;
                }
                for kx in 0..d.k {
                    let ix = (ox * d.stride + kx) as isize - d.pad as isize;
                    if ix < 0 || (ix as usize) >= d.w {
                        continue;
                    }
                    let src = &prow[(ky * d.k + kx) * d.cin..(ky * d.k + kx + 1) * d.cin];
                    let s = ((iy as usize) * d.w + ix as usize) * d.cin;
                    let dst = &mut dx[s..s + d.cin];
                    for (o, &v) in dst.iter_mut().zip(src.iter()) {
                        *o += v;
                    }
                }
            }
        }
    }
}

/// Reusable im2col scratch for the conv backward pass: the per-sample
/// im2col matrix (`col`), its gradient-layout twin (`dcol`), and the
/// packed-`Wᵀ` buffer the dX GEMM reads (`wt`, only touched when dX is
/// requested). Bundling the three keeps [`conv2d_backward`] at a
/// reviewable arity (formerly an `#[allow(clippy::too_many_arguments)]`
/// site) and documents that they are one borrow unit: worker-owned,
/// resized in place, never aliased with the gradient outputs.
pub struct ConvScratch<'a> {
    pub col: &'a mut Vec<f32>,
    pub dcol: &'a mut Vec<f32>,
    pub wt: &'a mut Vec<f32>,
}

/// The conv backward pass's gradient outputs: `dw`/`db` are accumulated
/// into (`+=`), `dx` (if present) is overwritten per sample.
pub struct ConvGrads<'a> {
    pub dw: &'a mut [f32],
    pub db: &'a mut [f32],
    pub dx: Option<&'a mut [f32]>,
}

/// Conv2d forward over a whole batch. `w` is the fused weight block
/// `[patch, cout]` row-major, `bias` is `[cout]`; `col` is reusable
/// scratch (resized to one sample's im2col matrix). The output is the
/// raw pre-activation — callers apply their own activation mask (the
/// trainer ReLUs the whole batch after this returns, elementwise, which
/// is bit-identical to masking per sample).
pub fn conv2d_forward(
    d: &ConvDims,
    w: &[f32],
    bias: &[f32],
    x: &[f32],
    batch: usize,
    col: &mut Vec<f32>,
    out: &mut [f32],
) {
    let (np, patch, cout) = (d.out_h() * d.out_w(), d.patch(), d.cout);
    debug_assert_eq!(w.len(), d.weight_len());
    debug_assert_eq!(bias.len(), cout);
    debug_assert_eq!(out.len(), batch * np * cout);
    col.clear();
    col.resize(np * patch, 0.0);
    for n in 0..batch {
        im2col(d, &x[n * d.in_len()..(n + 1) * d.in_len()], col);
        // out[p, co] = bias[co] + Σ_q col[p, q]·w[q, co] — one GEMM per
        // sample over the im2col matrix
        let on = &mut out[n * np * cout..(n + 1) * np * cout];
        for p in 0..np {
            on[p * cout..(p + 1) * cout].copy_from_slice(bias);
        }
        kernels::gemm_nn(on, col, w, np, patch, cout);
    }
}

/// Conv2d backward over a whole batch. `delta` is dL/d(out) AFTER the
/// caller applied the activation mask; gradients land in `g`
/// ([`ConvGrads`]), scratch comes from `s` ([`ConvScratch`]).
pub fn conv2d_backward(
    d: &ConvDims,
    w: &[f32],
    x: &[f32],
    batch: usize,
    delta: &[f32],
    s: &mut ConvScratch<'_>,
    g: &mut ConvGrads<'_>,
) {
    let (np, patch, cout) = (d.out_h() * d.out_w(), d.patch(), d.cout);
    debug_assert_eq!(g.dw.len(), d.weight_len());
    debug_assert_eq!(g.db.len(), cout);
    debug_assert_eq!(delta.len(), batch * np * cout);
    s.col.clear();
    s.col.resize(np * patch, 0.0);
    s.dcol.clear();
    s.dcol.resize(np * patch, 0.0);
    if g.dx.is_some() {
        // Wᵀ [cout, patch], packed once for the whole batch
        kernels::pack_transpose(w, patch, cout, s.wt);
    }
    for n in 0..batch {
        let xn = &x[n * d.in_len()..(n + 1) * d.in_len()];
        im2col(d, xn, s.col);
        let dn = &delta[n * np * cout..(n + 1) * np * cout];
        // dW[q, co] += Σ_p col[p, q]·δ[p, co]  (colᵀ·δ — samples in n
        // order, rows in p order, the direct convolution's accumulation)
        kernels::gemm_tn(g.dw, s.col, dn, patch, np, cout);
        // db[co] += Σ_p δ[p, co]
        kernels::col_sum_add(g.db, dn, np, cout);
        if let Some(dx) = g.dx.as_deref_mut() {
            // dcol[p, q] = Σ_co δ[p, co]·wᵀ[co, q], then col2im
            s.dcol.iter_mut().for_each(|v| *v = 0.0);
            kernels::gemm_nn(s.dcol, dn, s.wt, np, cout, patch);
            let dxn = &mut dx[n * d.in_len()..(n + 1) * d.in_len()];
            dxn.iter_mut().for_each(|v| *v = 0.0);
            col2im_add(d, s.dcol, dxn);
        }
    }
}

/// MaxPool window geometry: an `[h, w, c]` input pooled by k×k windows
/// at stride k. A plain value bundle so the pool entry points stay at a
/// reviewable arity (formerly `#[allow(clippy::too_many_arguments)]`
/// sites).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolDims {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub k: usize,
}

/// MaxPool k×k (stride k) forward over a batch of `[h, w, c]` samples,
/// caching each output cell's FIRST-argmax routing index (absolute into
/// the batch's input slab) in `idx` — the backward pass then routes δ by
/// table lookup instead of re-scanning every k×k window
/// ([`maxpool_backward_idx`]). Ties resolve to the first strict max in
/// (ky, kx) scan order, exactly as the re-scanning reference does.
pub fn maxpool_forward_idx(
    p: &PoolDims,
    x: &[f32],
    batch: usize,
    out: &mut [f32],
    idx: &mut Vec<u32>,
) {
    let PoolDims { h, w, c, k } = *p;
    let (ho, wo) = (h / k, w / k);
    debug_assert_eq!(out.len(), batch * ho * wo * c);
    idx.clear();
    idx.resize(batch * ho * wo * c, 0);
    for n in 0..batch {
        let base = n * h * w * c;
        let xn = &x[base..base + h * w * c];
        for oy in 0..ho {
            for ox in 0..wo {
                for ch in 0..c {
                    let mut m = f32::NEG_INFINITY;
                    let mut at = 0usize;
                    for ky in 0..k {
                        for kx in 0..k {
                            let p = ((oy * k + ky) * w + ox * k + kx) * c + ch;
                            if xn[p] > m {
                                m = xn[p];
                                at = p;
                            }
                        }
                    }
                    let o = ((n * ho + oy) * wo + ox) * c + ch;
                    out[o] = m;
                    idx[o] = (base + at) as u32;
                }
            }
        }
    }
}

/// MaxPool forward without index caching (test/reference convenience —
/// the trainer always runs [`maxpool_forward_idx`]).
pub fn maxpool_forward(p: &PoolDims, x: &[f32], batch: usize, out: &mut [f32]) {
    let mut idx = Vec::new();
    maxpool_forward_idx(p, x, batch, out, &mut idx);
}

/// MaxPool backward via the forward pass's cached argmax table: `dx` is
/// overwritten, then each output cell's δ is added at its recorded input
/// position. Output cells are walked in ascending order — the same
/// accumulation order as the re-scanning reference
/// ([`maxpool_backward`]), asserted bit-identical in the unit tests.
pub fn maxpool_backward_idx(idx: &[u32], delta: &[f32], dx: &mut [f32]) {
    debug_assert_eq!(idx.len(), delta.len());
    dx.iter_mut().for_each(|v| *v = 0.0);
    for (&at, &d) in idx.iter().zip(delta.iter()) {
        dx[at as usize] += d;
    }
}

/// MaxPool backward reference: route each output cell's delta to the
/// FIRST argmax position (scan order ky, kx — ties resolve
/// deterministically) by re-scanning the stored input activation. `dx`
/// is overwritten. The trainer uses the cached-index fast path
/// ([`maxpool_backward_idx`]); this re-scan is kept as its conformance
/// reference.
pub fn maxpool_backward(p: &PoolDims, x: &[f32], batch: usize, delta: &[f32], dx: &mut [f32]) {
    let PoolDims { h, w, c, k } = *p;
    let (ho, wo) = (h / k, w / k);
    debug_assert_eq!(delta.len(), batch * ho * wo * c);
    debug_assert_eq!(dx.len(), batch * h * w * c);
    dx.iter_mut().for_each(|v| *v = 0.0);
    for n in 0..batch {
        let xn = &x[n * h * w * c..(n + 1) * h * w * c];
        let dxn = &mut dx[n * h * w * c..(n + 1) * h * w * c];
        for oy in 0..ho {
            for ox in 0..wo {
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut best_at = 0usize;
                    for ky in 0..k {
                        for kx in 0..k {
                            let at = ((oy * k + ky) * w + ox * k + kx) * c + ch;
                            if xn[at] > best {
                                best = xn[at];
                                best_at = at;
                            }
                        }
                    }
                    dxn[best_at] += delta[((n * ho + oy) * wo + ox) * c + ch];
                }
            }
        }
    }
}

/// Elman cell geometry: `batch` sequences of `t` steps, `in_dim` inputs
/// per step, `hidden` state width. A plain value bundle so the recurrent
/// entry points stay at a reviewable arity (formerly
/// `#[allow(clippy::too_many_arguments)]` sites).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ElmanDims {
    pub batch: usize,
    pub t: usize,
    pub in_dim: usize,
    pub hidden: usize,
}

/// The Elman cell's weight matrices: `wx` is `[in_dim, hidden]`, `wh` is
/// `[hidden, hidden]`, both row-major.
pub struct ElmanWeights<'a> {
    pub wx: &'a [f32],
    pub wh: &'a [f32],
}

/// Reusable BPTT scratch: the per-step δ row (`dh`), the recurrent carry
/// row (`carry`), and the packed `Wxᵀ|Whᵀ` block the dx/carry GEMMs read
/// (`wt`, packed once per [`elman_backward`] call).
pub struct ElmanScratch<'a> {
    pub dh: &'a mut Vec<f32>,
    pub carry: &'a mut Vec<f32>,
    pub wt: &'a mut Vec<f32>,
}

/// The BPTT gradient outputs: `dwx`/`dwh`/`db` are accumulated into
/// (`+=`), `dx` (if present) is overwritten.
pub struct ElmanGrads<'a> {
    pub dwx: &'a mut [f32],
    pub dwh: &'a mut [f32],
    pub db: &'a mut [f32],
    pub dx: Option<&'a mut [f32]>,
}

/// Elman forward: `h_s = tanh(Wx·x_s + Wh·h_{s-1} + b)` unrolled over the
/// sequence, `h_0 = 0` per sequence. `x` is `[batch, t, in_dim]`, `out`
/// receives all hidden states `[batch, t, hidden]`.
pub fn elman_forward(e: &ElmanDims, w: &ElmanWeights<'_>, bias: &[f32], x: &[f32], out: &mut [f32]) {
    let ElmanDims { batch, t, in_dim, hidden } = *e;
    let (wx, wh) = (w.wx, w.wh);
    debug_assert_eq!(wx.len(), in_dim * hidden);
    debug_assert_eq!(wh.len(), hidden * hidden);
    debug_assert_eq!(out.len(), batch * t * hidden);
    for n in 0..batch {
        for s in 0..t {
            let base = (n * t + s) * hidden;
            // split so the previous state stays readable while the
            // current row is written
            let (done, cur) = out.split_at_mut(base);
            let orow = &mut cur[..hidden];
            orow.copy_from_slice(bias);
            // h_s = tanh(bias + x_s·Wx + h_{s-1}·Wh): two 1-row GEMMs
            let xrow = &x[(n * t + s) * in_dim..(n * t + s + 1) * in_dim];
            kernels::gemm_nn(orow, xrow, wx, 1, in_dim, hidden);
            if s > 0 {
                let hprev = &done[base - hidden..];
                kernels::gemm_nn(orow, hprev, wh, 1, hidden, hidden);
            }
            for o in orow.iter_mut() {
                *o = o.tanh();
            }
        }
    }
}

/// Elman BPTT: walk each sequence backward carrying `dL/dh` through the
/// recurrence. `delta` is dL/d(h states) as produced by the layers above
/// (tanh' is applied HERE — callers must not pre-mask); `hs` is the
/// forward pass's state tensor; gradients land in `g` ([`ElmanGrads`]),
/// scratch comes from `s` ([`ElmanScratch`]).
pub fn elman_backward(
    e: &ElmanDims,
    w: &ElmanWeights<'_>,
    x: &[f32],
    hs: &[f32],
    delta: &[f32],
    s: &mut ElmanScratch<'_>,
    g: &mut ElmanGrads<'_>,
) {
    let ElmanDims { batch, t, in_dim, hidden } = *e;
    debug_assert_eq!(delta.len(), batch * t * hidden);
    let (dh, carry, wt) = (&mut *s.dh, &mut *s.carry, &mut *s.wt);
    dh.clear();
    dh.resize(hidden, 0.0);
    carry.clear();
    carry.resize(hidden, 0.0);
    // wt = [Whᵀ [hidden, hidden] | Wxᵀ [hidden, in_dim]]: the transposed
    // weights the carry/dx rows multiply against every timestep
    wt.clear();
    wt.resize(hidden * hidden + hidden * in_dim, 0.0);
    let (wht, wxt) = wt.split_at_mut(hidden * hidden);
    kernels::pack_transpose_into(w.wh, hidden, hidden, wht);
    kernels::pack_transpose_into(w.wx, in_dim, hidden, wxt);
    for n in 0..batch {
        carry.iter_mut().for_each(|v| *v = 0.0);
        for step in (0..t).rev() {
            let base = (n * t + step) * hidden;
            let hrow = &hs[base..base + hidden];
            // δ_s = (incoming + recurrent carry) ⊙ tanh'(h_s)
            for j in 0..hidden {
                dh[j] = (delta[base + j] + carry[j]) * (1.0 - hrow[j] * hrow[j]);
            }
            // dWx[i, j] += x_i·δ_j (rank-1), dWh[j0, j] += h_{s-1,j0}·δ_j
            let xrow = &x[(n * t + step) * in_dim..(n * t + step + 1) * in_dim];
            kernels::gemm_tn(g.dwx, xrow, dh, in_dim, 1, hidden);
            if step > 0 {
                let hprev = &hs[base - hidden..base];
                kernels::gemm_tn(g.dwh, hprev, dh, hidden, 1, hidden);
            }
            for (gb, &dj) in g.db.iter_mut().zip(dh.iter()) {
                *gb += dj;
            }
            if let Some(dx) = g.dx.as_deref_mut() {
                // dx_s[i] = Σ_j wx[i, j]·δ_j = δ·Wxᵀ (1-row GEMM)
                let dxrow = &mut dx[(n * t + step) * in_dim..(n * t + step + 1) * in_dim];
                dxrow.iter_mut().for_each(|v| *v = 0.0);
                kernels::gemm_nn(dxrow, dh, wxt, 1, hidden, in_dim);
            }
            if step > 0 {
                // carry_{s-1}[j] = Σ_o wh[j, o]·δ_o = δ·Whᵀ
                carry.iter_mut().for_each(|v| *v = 0.0);
                kernels::gemm_nn(carry, dh, wht, 1, hidden, hidden);
            }
        }
    }
}

/// Mean softmax cross-entropy over `rows` logit rows + per-logit gradient
/// (∂loss/∂logits, mean-reduced over rows).
pub fn softmax_xent(rows: usize, classes: usize, logits: &[f32], labels: &[i32], dlogits: &mut [f32]) -> f32 {
    debug_assert_eq!(logits.len(), rows * classes);
    debug_assert_eq!(labels.len(), rows);
    debug_assert_eq!(dlogits.len(), rows * classes);
    let mut loss = 0.0f32;
    for n in 0..rows {
        let row = &logits[n * classes..(n + 1) * classes];
        let drow = &mut dlogits[n * classes..(n + 1) * classes];
        // lags-audit: allow(R3) reason="max-fold for softmax stabilization: f32::max is order-insensitive, no rounding accumulates"
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for (d, &v) in drow.iter_mut().zip(row.iter()) {
            *d = (v - max).exp();
            z += *d;
        }
        let y = labels[n] as usize;
        loss += z.ln() - (row[y] - max);
        let inv = 1.0 / (z * rows as f32);
        for (j, d) in drow.iter_mut().enumerate() {
            *d = *d * inv - if j == y { 1.0 / rows as f32 } else { 0.0 };
        }
    }
    loss / rows as f32
}

// ---------------------------------------------------------------------------
// model specs + the built-in zoo
// ---------------------------------------------------------------------------

/// Input of a spec-built model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InputKind {
    /// channels-last image `[batch, h, w, c]` f32
    Image { h: usize, w: usize, c: usize },
    /// flat features `[batch, n]` f32
    Flat { n: usize },
    /// token ids `[batch, t]` i32 (labels are `[batch, t]` too)
    Tokens { t: usize },
}

/// One layer of a model spec (shapes are resolved by [`NativeNet::from_spec`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerSpec {
    /// fully-connected `[fan_in + 1, out]`; ReLU unless it is the last layer
    Dense { out: usize },
    /// Conv2d `[k·k·cin + 1, out_ch]`, stride + zero padding, ReLU
    Conv { out_ch: usize, k: usize, stride: usize, pad: usize },
    /// k×k max pooling, stride k (no parameters)
    MaxPool { k: usize },
    /// image → flat features (no parameters, no runtime work:
    /// channels-last row-major is already flat)
    Flatten,
    /// token embedding table `[vocab, dim]` (vocab = the spec's `classes`)
    Embed { dim: usize },
    /// Elman recurrent cell `[in + hidden + 1, hidden]`, tanh, full BPTT
    Elman { hidden: usize },
}

/// A complete native model description: input, layer stack, label space.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub batch: usize,
    pub input: InputKind,
    /// label cardinality: classes for classifiers, vocab for LMs
    pub classes: usize,
    pub metric: Metric,
    pub layers: Vec<LayerSpec>,
}

/// Built-in specs for the heterogeneous zoo models (the MLP family keeps
/// its legacy alternating-w/b manifests and is reconstructed from the
/// manifest table instead). Layer sizes are chosen so that, priced at
/// the uncalibrated-fallback device speed
/// ([`crate::models::DEVICE_FLOPS`]; a `lags calibrate` run replaces it
/// with this machine's measured sustained flops) on the paper's 1GbE
/// testbed, Eq. 18 yields genuinely NON-uniform per-layer ratios — the
/// property the MLP-only zoo could never exhibit.
pub fn zoo_spec(name: &str) -> Option<ModelSpec> {
    match name {
        "convnet" => Some(ModelSpec {
            name: "convnet".into(),
            batch: 16,
            input: InputKind::Image { h: 12, w: 12, c: 3 },
            classes: 10,
            metric: Metric::Accuracy,
            layers: vec![
                LayerSpec::Conv { out_ch: 16, k: 3, stride: 1, pad: 1 },
                LayerSpec::MaxPool { k: 2 },
                LayerSpec::Conv { out_ch: 32, k: 3, stride: 1, pad: 1 },
                LayerSpec::MaxPool { k: 2 },
                LayerSpec::Flatten,
                LayerSpec::Dense { out: 10 },
            ],
        }),
        "convnet_deep" => Some(ModelSpec {
            name: "convnet_deep".into(),
            batch: 8,
            input: InputKind::Image { h: 16, w: 16, c: 3 },
            classes: 10,
            metric: Metric::Accuracy,
            layers: vec![
                LayerSpec::Conv { out_ch: 12, k: 3, stride: 1, pad: 1 },
                LayerSpec::MaxPool { k: 2 },
                LayerSpec::Conv { out_ch: 24, k: 3, stride: 1, pad: 1 },
                LayerSpec::Conv { out_ch: 24, k: 3, stride: 1, pad: 1 },
                LayerSpec::MaxPool { k: 2 },
                LayerSpec::Conv { out_ch: 32, k: 3, stride: 1, pad: 1 },
                LayerSpec::Flatten,
                LayerSpec::Dense { out: 48 },
                LayerSpec::Dense { out: 10 },
            ],
        }),
        "rnn" => Some(ModelSpec {
            name: "rnn".into(),
            batch: 8,
            input: InputKind::Tokens { t: 16 },
            classes: 64,
            metric: Metric::PplLoss,
            layers: vec![
                LayerSpec::Embed { dim: 32 },
                LayerSpec::Elman { hidden: 64 },
                LayerSpec::Dense { out: 64 },
            ],
        }),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// resolved layers + the executable net
// ---------------------------------------------------------------------------

/// Shape-resolved layer with its flat-parameter offset.
#[derive(Debug, Clone)]
struct ResolvedLayer {
    kind: ResolvedKind,
    /// offset of this layer's fused parameter block (0 for paramless)
    off: usize,
    /// f32 activation elements flowing IN for the whole batch (token
    /// count for `Embed`)
    in_len: usize,
    /// f32 activation elements flowing OUT for the whole batch
    out_len: usize,
}

#[derive(Debug, Clone)]
enum ResolvedKind {
    Dense { rows: usize, fan_in: usize, fan_out: usize, relu: bool },
    Conv { dims: ConvDims },
    Pool { h: usize, w: usize, c: usize, k: usize },
    Embed { vocab: usize, dim: usize },
    Elman { t: usize, in_dim: usize, hidden: usize },
}

impl ResolvedLayer {
    fn param_len(&self) -> usize {
        match &self.kind {
            ResolvedKind::Dense { fan_in, fan_out, .. } => (fan_in + 1) * fan_out,
            ResolvedKind::Conv { dims } => (dims.patch() + 1) * dims.cout,
            ResolvedKind::Pool { .. } => 0,
            ResolvedKind::Embed { vocab, dim } => vocab * dim,
            ResolvedKind::Elman { in_dim, hidden, .. } => (in_dim + hidden + 1) * hidden,
        }
    }
}

/// Worker-owned scratch for the native forward/backward pass, reused
/// across steps: per-layer activations, the two δ buffers, the packed Wᵀ
/// the dense/conv/BPTT dX GEMMs read, the im2col `col`/`dcol` matrices,
/// the BPTT `dh`/`carry` rows, and the per-pool-layer argmax routing
/// tables the forward pass caches so the pool backward is a table walk
/// instead of a k×k window re-scan. Every buffer reaches steady-state
/// capacity after the first step, so the hot loop stops allocating.
#[derive(Debug, Clone, Default)]
pub struct GradScratch {
    acts: Vec<Vec<f32>>,
    delta: Vec<f32>,
    prev: Vec<f32>,
    wt: Vec<f32>,
    col: Vec<f32>,
    dcol: Vec<f32>,
    dh: Vec<f32>,
    carry: Vec<f32>,
    /// per-layer MaxPool argmax tables (empty vecs for non-pool layers)
    pool_idx: Vec<Vec<u32>>,
    /// the B-transpose pack buffer `gemm_nt` owns (the dense dX GEMM);
    /// conv/BPTT keep packing into `wt` via their own scratch structs
    gemm: kernels::GemmScratch,
}

/// One hot-loop GEMM shape with its per-step forward execution count —
/// the calibration workload unit ([`NativeNet::gemm_shapes`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GemmShape {
    pub label: String,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// forward-pass executions of this GEMM per training step (the
    /// backward runs proportional work at the same shapes)
    pub calls_per_step: usize,
}

impl GemmShape {
    /// flops of ONE execution: 2·m·k·n.
    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.k as f64 * self.n as f64
    }

    /// forward flops this shape contributes per training step — the
    /// calibration aggregate's weight.
    pub fn step_flops(&self) -> f64 {
        self.calls_per_step as f64 * self.flops()
    }
}

/// Reusable scratch for [`compress_layer_bucket_into`]: the bucket-padded
/// accumulator plus the selection buffers, so the per-layer-per-worker
/// XLA-emulation compress path performs no allocation for the threshold
/// search (the returned sparse/residual vectors stay owned — they are the
/// artifact contract's outputs).
#[derive(Debug, Clone, Default)]
pub struct CompressScratch {
    acc: Vec<f32>,
    sample: Vec<f32>,
    mags: Vec<f32>,
}

/// Executable native model: a resolved layer stack over a flat parameter
/// vector, plus the loss head (softmax cross-entropy over `loss_rows`
/// logit rows — `batch` for classifiers, `batch·t` for LMs).
pub struct NativeNet {
    batch: usize,
    d: usize,
    classes: usize,
    loss_rows: usize,
    /// expected x elements (f32, or token count for token inputs)
    x_elems: usize,
    tokens_in: bool,
    layers: Vec<ResolvedLayer>,
}

/// Intermediate feature shape during spec resolution.
#[derive(Debug, Clone, Copy)]
enum Feat {
    Img { h: usize, w: usize, c: usize },
    Flat { n: usize },
    Seq { t: usize, n: usize },
    Tok { t: usize },
}

impl NativeNet {
    /// Resolve a [`ModelSpec`] into an executable net, validating shapes.
    pub fn from_spec(spec: &ModelSpec) -> Result<NativeNet> {
        let (layers, _) = resolve(spec)?;
        NativeNet::from_resolved(spec, layers)
    }

    /// Assemble the net from an already-resolved layer stack (shared by
    /// [`NativeNet::from_spec`] and the zoo path of
    /// [`NativeNet::from_manifest`], so a spec is resolved exactly once).
    fn from_resolved(spec: &ModelSpec, layers: Vec<ResolvedLayer>) -> Result<NativeNet> {
        let last = layers.last().expect("resolve ensures non-empty");
        let (loss_rows, classes) = match &last.kind {
            ResolvedKind::Dense { rows, fan_out, .. } => (*rows, *fan_out),
            _ => bail!("model {} must end in a Dense layer", spec.name),
        };
        let d: usize = layers.iter().map(|l| l.param_len()).sum();
        let (x_elems, tokens_in) = match spec.input {
            InputKind::Image { h, w, c } => (spec.batch * h * w * c, false),
            InputKind::Flat { n } => (spec.batch * n, false),
            InputKind::Tokens { t } => (spec.batch * t, true),
        };
        Ok(NativeNet { batch: spec.batch, d, classes, loss_rows, x_elems, tokens_in, layers })
    }

    /// Reconstruct a net from a manifest: known zoo specs are matched by
    /// name (the manifest's layer table must agree structurally); any
    /// other manifest is reconstructed as the legacy alternating-w/b MLP
    /// this backend originally served.
    pub fn from_manifest(mm: &ModelManifest) -> Result<NativeNet> {
        if let Some(spec) = zoo_spec(&mm.name) {
            // resolve ONCE: the same walk yields the expectation table
            // the manifest must match and the executable layer stack
            let (layers, infos) = resolve(&spec)?;
            let d: usize = infos.iter().map(|l| l.size).sum();
            ensure!(d == mm.d, "model {}: manifest d {} != spec d {d}", mm.name, mm.d);
            let (x, y) = spec_batch_specs(&spec);
            ensure!(x == mm.x && y == mm.y, "model {}: batch specs diverge from the zoo spec", mm.name);
            ensure!(spec.classes == mm.classes, "model {}: classes diverge from the zoo spec", mm.name);
            ensure!(infos.len() == mm.layers.len(), "model {}: layer count diverges from the zoo spec", mm.name);
            for (e, g) in infos.iter().zip(mm.layers.iter()) {
                ensure!(
                    e.name == g.name && e.shape == g.shape && e.offset == g.offset,
                    "model {}: layer {} diverges from the zoo spec",
                    mm.name,
                    g.name
                );
            }
            return NativeNet::from_resolved(&spec, layers);
        }
        // legacy MLP reconstruction (mlp, mlp_deep and custom test
        // manifests): alternating row-major w [fan_in, fan_out] / b
        // [fan_out] pairs over [batch, in] f32 inputs
        ensure!(mm.x.shape.len() == 2 && mm.x.dtype == DType::F32, "native backend wants [batch, in] f32 inputs");
        ensure!(mm.y.shape.len() == 1 && mm.y.dtype == DType::I32, "native backend wants [batch] i32 labels");
        ensure!(!mm.layers.is_empty() && mm.layers.len() % 2 == 0, "native backend wants alternating w/b layers");
        let batch = mm.x.shape[0];
        let mut dims = vec![mm.x.shape[1]];
        for pair in mm.layers.chunks(2) {
            let (w, b) = (&pair[0], &pair[1]);
            ensure!(w.shape.len() == 2 && b.shape.len() == 1, "layer pair {}/{} not (matrix, bias)", w.name, b.name);
            ensure!(w.shape[0] == *dims.last().unwrap(), "layer {} fan-in mismatch", w.name);
            ensure!(w.shape[1] == b.shape[0], "layer {} bias mismatch", w.name);
            dims.push(w.shape[1]);
        }
        ensure!(*dims.last().unwrap() == mm.classes, "output width != classes");
        let npairs = dims.len() - 1;
        let mut layers = Vec::with_capacity(npairs);
        let mut off = 0;
        for l in 0..npairs {
            let (fan_in, fan_out) = (dims[l], dims[l + 1]);
            layers.push(ResolvedLayer {
                kind: ResolvedKind::Dense { rows: batch, fan_in, fan_out, relu: l + 1 < npairs },
                off,
                in_len: batch * fan_in,
                out_len: batch * fan_out,
            });
            off += (fan_in + 1) * fan_out;
        }
        ensure!(off == mm.d, "layer sizes sum to {off} but d = {}", mm.d);
        Ok(NativeNet {
            batch,
            d: mm.d,
            classes: mm.classes,
            loss_rows: batch,
            x_elems: batch * dims[0],
            tokens_in: false,
            layers,
        })
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// The labelled GEMM shapes this net's hot loop actually executes —
    /// Dense whole-batch mat-muls, per-sample im2col Conv mat-muls, and
    /// the per-timestep Elman GEMV rows — each with its forward-pass
    /// execution count per training step. The calibration microbenchmark
    /// (`runtime::calibrate`) times the blocked kernels at exactly these
    /// shapes and weights the aggregate by `step_flops`, so measured
    /// device flops reflect the real workload mix (big conv/dense
    /// mat-muls dominating, as they dominate trainer time) rather than a
    /// synthetic square GEMM or an unweighted mean over tiny GEMVs.
    pub fn gemm_shapes(&self) -> Vec<GemmShape> {
        let b = self.batch;
        let mut out = Vec::new();
        for layer in &self.layers {
            match &layer.kind {
                ResolvedKind::Dense { rows, fan_in, fan_out, .. } => {
                    out.push(GemmShape {
                        label: format!("dense_{rows}x{fan_in}x{fan_out}"),
                        m: *rows,
                        k: *fan_in,
                        n: *fan_out,
                        calls_per_step: 1,
                    });
                }
                ResolvedKind::Conv { dims } => {
                    let np = dims.out_h() * dims.out_w();
                    out.push(GemmShape {
                        label: format!("conv_{np}x{}x{}", dims.patch(), dims.cout),
                        m: np,
                        k: dims.patch(),
                        n: dims.cout,
                        calls_per_step: b,
                    });
                }
                ResolvedKind::Elman { t, in_dim, hidden } => {
                    out.push(GemmShape {
                        label: format!("elman_x_1x{in_dim}x{hidden}"),
                        m: 1,
                        k: *in_dim,
                        n: *hidden,
                        calls_per_step: b * t,
                    });
                    out.push(GemmShape {
                        label: format!("elman_h_1x{hidden}x{hidden}"),
                        m: 1,
                        k: *hidden,
                        n: *hidden,
                        calls_per_step: b * t,
                    });
                }
                ResolvedKind::Pool { .. } | ResolvedKind::Embed { .. } => {}
            }
        }
        out
    }

    /// Seeded initial parameters, deterministic in (seed, layer index):
    /// He-normal dense/conv weights, Xavier-ish recurrent blocks, zero
    /// biases — the native stand-in for `init.bin`.
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut params = vec![0.0f32; self.d];
        let mut pi = 0u64; // parametric layer index (matches legacy w_l numbering)
        for layer in &self.layers {
            if layer.param_len() == 0 {
                continue;
            }
            let mut rng = Rng::new(seed ^ 0x9a7e_11e5 ^ (pi << 40));
            pi += 1;
            let off = layer.off;
            match &layer.kind {
                ResolvedKind::Dense { fan_in, fan_out, .. } => {
                    let sigma = (2.0 / *fan_in as f32).sqrt();
                    rng.fill_normal(&mut params[off..off + fan_in * fan_out], sigma);
                }
                ResolvedKind::Conv { dims } => {
                    let sigma = (2.0 / dims.patch() as f32).sqrt();
                    rng.fill_normal(&mut params[off..off + dims.weight_len()], sigma);
                }
                ResolvedKind::Embed { vocab, dim } => {
                    rng.fill_normal(&mut params[off..off + vocab * dim], 0.5);
                }
                ResolvedKind::Elman { in_dim, hidden, .. } => {
                    let sx = (1.0 / *in_dim as f32).sqrt();
                    rng.fill_normal(&mut params[off..off + in_dim * hidden], sx);
                    let sh = 0.5 * (1.0 / *hidden as f32).sqrt();
                    rng.fill_normal(
                        &mut params[off + in_dim * hidden..off + (in_dim + hidden) * hidden],
                        sh,
                    );
                }
                ResolvedKind::Pool { .. } => unreachable!("paramless"),
            }
            // bias rows stay zero
        }
        params
    }

    fn check_batch(&self, x: &BatchData, y: &BatchData) -> Result<()> {
        ensure!(x.len() == self.x_elems, "x batch shape mismatch");
        ensure!(y.len() == self.loss_rows, "y batch shape mismatch");
        match (x, self.tokens_in) {
            (BatchData::F32(_), false) | (BatchData::I32(_), true) => {}
            _ => bail!("x dtype mismatch for this model"),
        }
        let BatchData::I32(yv) = y else { bail!("y must be i32") };
        for &label in yv {
            ensure!((label as usize) < self.classes, "label out of range");
        }
        if self.tokens_in {
            let BatchData::I32(xv) = x else { unreachable!() };
            for &tok in xv {
                ensure!((tok as usize) < self.classes, "token out of range");
            }
        }
        Ok(())
    }

    /// Forward pass into reusable per-layer activation buffers (`acts[l]`
    /// holds layer `l`'s full-batch output; the last entry holds raw
    /// logits). Every element is overwritten, so stale contents don't
    /// matter. `pool_idx[l]` receives each pool layer's argmax routing
    /// table for the backward pass.
    fn forward_into(
        &self,
        params: &[f32],
        x: &BatchData,
        acts: &mut Vec<Vec<f32>>,
        col: &mut Vec<f32>,
        pool_idx: &mut Vec<Vec<u32>>,
    ) {
        let nl = self.layers.len();
        let b = self.batch;
        acts.resize_with(nl, Vec::new);
        pool_idx.resize_with(nl, Vec::new);
        for l in 0..nl {
            let layer = &self.layers[l];
            let (done, rest) = acts.split_at_mut(l);
            let out = &mut rest[0];
            out.resize(layer.out_len, 0.0);
            let off = layer.off;
            // f32 activations feeding layer l: the previous layer's
            // output, or the raw batch for layer 0 (token inputs are
            // consumed by Embed directly and stay None here)
            let input_f32: Option<&[f32]> = if l == 0 {
                match x {
                    BatchData::F32(xv) => Some(xv.as_slice()),
                    BatchData::I32(_) => None,
                }
            } else {
                Some(done[l - 1].as_slice())
            };
            match &layer.kind {
                ResolvedKind::Dense { rows, fan_in, fan_out, relu } => {
                    // out = bias + input·W: one whole-batch GEMM
                    let input = input_f32.expect("checked: f32 input");
                    let w = &params[off..off + fan_in * fan_out];
                    let bias = &params[off + fan_in * fan_out..off + (fan_in + 1) * fan_out];
                    for r in 0..*rows {
                        out[r * fan_out..(r + 1) * fan_out].copy_from_slice(bias);
                    }
                    kernels::gemm_nn(out, input, w, *rows, *fan_in, *fan_out);
                    if *relu {
                        for o in out.iter_mut() {
                            *o = o.max(0.0);
                        }
                    }
                }
                ResolvedKind::Conv { dims } => {
                    let input = input_f32.expect("checked: f32 input");
                    let w = &params[off..off + dims.weight_len()];
                    let bias = &params[off + dims.weight_len()..off + dims.weight_len() + dims.cout];
                    conv2d_forward(dims, w, bias, input, b, col, out);
                    // conv output is always ReLU'd (whole-batch elementwise
                    // mask, bit-identical to masking inside the batch loop)
                    for o in out.iter_mut() {
                        *o = o.max(0.0);
                    }
                }
                ResolvedKind::Pool { h, w, c, k } => {
                    let input = input_f32.expect("checked: f32 input");
                    let p = PoolDims { h: *h, w: *w, c: *c, k: *k };
                    maxpool_forward_idx(&p, input, b, out, &mut pool_idx[l]);
                }
                ResolvedKind::Embed { vocab: _, dim } => {
                    let BatchData::I32(toks) = x else { unreachable!("checked") };
                    for (r, &tok) in toks.iter().enumerate() {
                        let src = &params[off + tok as usize * dim..off + (tok as usize + 1) * dim];
                        out[r * dim..(r + 1) * dim].copy_from_slice(src);
                    }
                }
                ResolvedKind::Elman { t, in_dim, hidden } => {
                    // Embed/Dense always precedes Elman, so l > 0 here
                    let input = input_f32.expect("checked: f32 input");
                    let wx = &params[off..off + in_dim * hidden];
                    let wh = &params[off + in_dim * hidden..off + (in_dim + hidden) * hidden];
                    let bias = &params
                        [off + (in_dim + hidden) * hidden..off + (in_dim + hidden + 1) * hidden];
                    let e = ElmanDims { batch: b, t: *t, in_dim: *in_dim, hidden: *hidden };
                    elman_forward(&e, &ElmanWeights { wx, wh }, bias, input, out);
                }
            }
        }
    }

    /// One train step: loss + flat gradient written into `grad` (resized
    /// to d; the caller owns the buffer so repeated steps don't allocate).
    /// `scratch` is worker-owned and reused across steps — after the first
    /// call the step performs no heap allocation.
    pub fn train_step_into(
        &self,
        params: &[f32],
        x: &BatchData,
        y: &BatchData,
        grad: &mut Vec<f32>,
        scratch: &mut GradScratch,
    ) -> Result<f32> {
        ensure!(params.len() == self.d, "params dim mismatch");
        self.check_batch(x, y)?;
        let BatchData::I32(yv) = y else { bail!("y must be i32") };
        let b = self.batch;
        let nl = self.layers.len();
        let GradScratch { acts, delta, prev, wt, col, dcol, dh, carry, pool_idx, gemm } = scratch;
        self.forward_into(params, x, acts, col, pool_idx);

        delta.clear();
        delta.resize(self.loss_rows * self.classes, 0.0);
        let loss = softmax_xent(self.loss_rows, self.classes, &acts[nl - 1], yv, delta);

        grad.clear();
        grad.resize(self.d, 0.0);

        for l in (0..nl).rev() {
            let layer = &self.layers[l];
            let off = layer.off;
            // f32 activations that fed layer l in the forward pass (None
            // only for layer-0 token inputs, which Embed reads directly)
            let input_f32: Option<&[f32]> = if l == 0 {
                match x {
                    BatchData::F32(xv) => Some(xv.as_slice()),
                    BatchData::I32(_) => None,
                }
            } else {
                Some(acts[l - 1].as_slice())
            };
            match &layer.kind {
                ResolvedKind::Dense { rows, fan_in, fan_out, relu } => {
                    // δ here is dL/d(post-activation); fold the layer's own
                    // ReLU mask first (relu'(0) = 0, matching the forward
                    // clamp), then the linear part
                    if *relu {
                        for (dv, &av) in delta.iter_mut().zip(acts[l].iter()) {
                            if av <= 0.0 {
                                *dv = 0.0;
                            }
                        }
                    }
                    let input = input_f32.expect("checked: f32 input");
                    // dW = inputᵀ·δ;  db[j] = Σ_r δ[r,j]
                    let boff = off + fan_in * fan_out;
                    kernels::gemm_tn(
                        &mut grad[off..boff],
                        input,
                        delta,
                        *fan_in,
                        *rows,
                        *fan_out,
                    );
                    kernels::col_sum_add(&mut grad[boff..boff + fan_out], delta, *rows, *fan_out);
                    // δ_prev = δ·Wᵀ (the nt kernel packs W transposed into
                    // `wt` so its inner walk is contiguous; the j-ascending
                    // accumulation order — and therefore every f32 sum —
                    // is the kernel contract's). The next layer applies
                    // its own activation mask.
                    if l > 0 {
                        let w = &params[off..off + fan_in * fan_out];
                        prev.clear();
                        prev.resize(rows * fan_in, 0.0);
                        kernels::gemm_nt(prev, delta, w, *rows, *fan_out, *fan_in, gemm);
                        std::mem::swap(&mut *delta, &mut *prev);
                    }
                }
                ResolvedKind::Conv { dims } => {
                    // conv output is always ReLU'd: mask by the stored
                    // post-activation output
                    for (dv, &av) in delta.iter_mut().zip(acts[l].iter()) {
                        if av <= 0.0 {
                            *dv = 0.0;
                        }
                    }
                    let input = input_f32.expect("checked: f32 input");
                    let wlen = dims.weight_len();
                    let w = &params[off..off + wlen];
                    let gslice = &mut grad[off..off + wlen + dims.cout];
                    let (dw, db) = gslice.split_at_mut(wlen);
                    let mut scr = ConvScratch { col: &mut *col, dcol: &mut *dcol, wt: &mut *wt };
                    if l > 0 {
                        prev.clear();
                        prev.resize(layer.in_len, 0.0);
                        let mut g = ConvGrads { dw, db, dx: Some(&mut prev[..]) };
                        conv2d_backward(dims, w, input, b, delta, &mut scr, &mut g);
                        std::mem::swap(&mut *delta, &mut *prev);
                    } else {
                        let mut g = ConvGrads { dw, db, dx: None };
                        conv2d_backward(dims, w, input, b, delta, &mut scr, &mut g);
                    }
                }
                ResolvedKind::Pool { .. } => {
                    // routes δ to the argmax tap recorded by the forward
                    // pass (no k×k re-scan); no parameters, no mask
                    if l > 0 {
                        prev.clear();
                        prev.resize(layer.in_len, 0.0);
                        maxpool_backward_idx(&pool_idx[l], delta, prev);
                        std::mem::swap(&mut *delta, &mut *prev);
                    }
                }
                ResolvedKind::Embed { vocab: _, dim } => {
                    // scatter-add δ rows into the table rows (token order
                    // is fixed, so the accumulation is deterministic)
                    let BatchData::I32(toks) = x else { unreachable!("checked") };
                    for (r, &tok) in toks.iter().enumerate() {
                        let grow =
                            &mut grad[off + tok as usize * dim..off + (tok as usize + 1) * dim];
                        let drow = &delta[r * dim..(r + 1) * dim];
                        for (g, &dj) in grow.iter_mut().zip(drow.iter()) {
                            *g += dj;
                        }
                    }
                }
                ResolvedKind::Elman { t, in_dim, hidden } => {
                    let input = input_f32.expect("checked: f32 input");
                    let (wxl, whl) = (in_dim * hidden, hidden * hidden);
                    let w = &params[off..off + wxl + whl];
                    let (wx, wh) = w.split_at(wxl);
                    let gslice = &mut grad[off..off + wxl + whl + hidden];
                    let (dwx, rest) = gslice.split_at_mut(wxl);
                    let (dwh, db) = rest.split_at_mut(whl);
                    prev.clear();
                    prev.resize(layer.in_len, 0.0);
                    let e = ElmanDims { batch: b, t: *t, in_dim: *in_dim, hidden: *hidden };
                    let mut scr = ElmanScratch { dh: &mut *dh, carry: &mut *carry, wt: &mut *wt };
                    let mut g = ElmanGrads { dwx, dwh, db, dx: Some(&mut prev[..]) };
                    elman_backward(&e, &ElmanWeights { wx, wh }, input, &acts[l], delta, &mut scr, &mut g);
                    std::mem::swap(&mut *delta, &mut *prev);
                }
            }
        }
        Ok(loss)
    }

    /// Eval step: (mean loss, metric) — top-1 accuracy for classifiers,
    /// the loss itself for `Metric::PplLoss` models (perplexity =
    /// exp(loss); same contract as the PJRT LM artifacts).
    pub fn eval_step(&self, params: &[f32], x: &BatchData, y: &BatchData) -> Result<(f32, f32)> {
        ensure!(params.len() == self.d, "params dim mismatch");
        self.check_batch(x, y)?;
        let BatchData::I32(yv) = y else { bail!("y must be i32") };
        let mut acts = Vec::new();
        let mut col = Vec::new();
        let mut pool_idx = Vec::new();
        self.forward_into(params, x, &mut acts, &mut col, &mut pool_idx);
        let logits = acts.last().expect("non-empty net");
        let (rows, c) = (self.loss_rows, self.classes);
        let mut dscratch = vec![0.0f32; rows * c];
        let loss = softmax_xent(rows, c, logits, yv, &mut dscratch);
        let mut correct = 0usize;
        for n in 0..rows {
            let row = &logits[n * c..(n + 1) * c];
            let mut best = (0usize, f32::NEG_INFINITY);
            for (j, &v) in row.iter().enumerate() {
                if v > best.1 {
                    best = (j, v);
                }
            }
            if best.0 == yv[n] as usize {
                correct += 1;
            }
        }
        let acc = correct as f32 / rows as f32;
        Ok((loss, acc))
    }

    /// Metric-aware eval used by the runtime facade: classifiers report
    /// accuracy, LMs report the loss (ppl convention).
    pub fn eval_metric(
        &self,
        params: &[f32],
        x: &BatchData,
        y: &BatchData,
        metric: Metric,
    ) -> Result<(f32, f32)> {
        let (loss, acc) = self.eval_step(params, x, y)?;
        Ok(match metric {
            Metric::Accuracy => (loss, acc),
            Metric::PplLoss => (loss, loss),
        })
    }
}

// ---------------------------------------------------------------------------
// spec resolution + manifests
// ---------------------------------------------------------------------------

/// Walk a spec's layer list resolving shapes; returns the executable
/// layers plus the manifest layer table (one fused tensor per parametric
/// layer).
fn resolve(spec: &ModelSpec) -> Result<(Vec<ResolvedLayer>, Vec<LayerInfo>)> {
    ensure!(!spec.layers.is_empty(), "model {} has no layers", spec.name);
    ensure!(spec.batch >= 1 && spec.classes >= 2, "model {} needs batch >= 1, classes >= 2", spec.name);
    let b = spec.batch;
    let mut feat = match spec.input {
        InputKind::Image { h, w, c } => Feat::Img { h, w, c },
        InputKind::Flat { n } => Feat::Flat { n },
        InputKind::Tokens { t } => Feat::Tok { t },
    };
    let mut layers: Vec<ResolvedLayer> = Vec::new();
    let mut infos: Vec<LayerInfo> = Vec::new();
    let mut off = 0usize;
    let (mut n_conv, mut n_fc, mut n_rnn) = (0usize, 0usize, 0usize);
    let n_spec = spec.layers.len();
    for (i, ls) in spec.layers.iter().enumerate() {
        let last = i + 1 == n_spec;
        match *ls {
            LayerSpec::Flatten => {
                feat = match feat {
                    Feat::Img { h, w, c } => Feat::Flat { n: h * w * c },
                    Feat::Flat { n } => Feat::Flat { n },
                    _ => bail!("model {}: Flatten needs image/flat input", spec.name),
                };
                continue; // channels-last is already contiguous: no runtime layer
            }
            LayerSpec::Dense { out } => {
                ensure!(out >= 1, "dense out must be >= 1");
                let (rows, fan_in, seq) = match feat {
                    Feat::Flat { n } => (b, n, None),
                    Feat::Img { h, w, c } => (b, h * w * c, None), // implicit flatten
                    Feat::Seq { t, n } => (b * t, n, Some(t)),
                    Feat::Tok { .. } => bail!("model {}: Dense cannot read raw tokens", spec.name),
                };
                n_fc += 1;
                let name = if last { "head".to_string() } else { format!("fc{n_fc}") };
                let size = (fan_in + 1) * out;
                infos.push(LayerInfo {
                    name,
                    shape: vec![fan_in + 1, out],
                    size,
                    offset: off,
                    bucket: next_pow2(size).max(1024),
                    fwd_flops: 2.0 * rows as f64 * fan_in as f64 * out as f64
                        + rows as f64 * out as f64,
                });
                layers.push(ResolvedLayer {
                    kind: ResolvedKind::Dense { rows, fan_in, fan_out: out, relu: !last },
                    off,
                    in_len: rows * fan_in,
                    out_len: rows * out,
                });
                off += size;
                feat = match seq {
                    Some(t) => Feat::Seq { t, n: out },
                    None => Feat::Flat { n: out },
                };
            }
            LayerSpec::Conv { out_ch, k, stride, pad } => {
                let Feat::Img { h, w, c } = feat else {
                    bail!("model {}: Conv needs an image input", spec.name)
                };
                let dims = ConvDims { h, w, cin: c, cout: out_ch, k, stride, pad };
                dims.validate()?;
                ensure!(!last, "model {} must end in a Dense layer", spec.name);
                n_conv += 1;
                let size = (dims.patch() + 1) * out_ch;
                let npix = dims.out_h() * dims.out_w();
                infos.push(LayerInfo {
                    name: format!("conv{n_conv}"),
                    shape: vec![dims.patch() + 1, out_ch],
                    size,
                    offset: off,
                    bucket: next_pow2(size).max(1024),
                    fwd_flops: 2.0 * b as f64 * npix as f64 * dims.patch() as f64 * out_ch as f64
                        + b as f64 * npix as f64 * out_ch as f64,
                });
                layers.push(ResolvedLayer {
                    kind: ResolvedKind::Conv { dims },
                    off,
                    in_len: b * dims.in_len(),
                    out_len: b * dims.out_len(),
                });
                off += size;
                feat = Feat::Img { h: dims.out_h(), w: dims.out_w(), c: out_ch };
            }
            LayerSpec::MaxPool { k } => {
                let Feat::Img { h, w, c } = feat else {
                    bail!("model {}: MaxPool needs an image input", spec.name)
                };
                ensure!(k >= 1 && h % k == 0 && w % k == 0, "model {}: pool {k} must divide {h}x{w}", spec.name);
                layers.push(ResolvedLayer {
                    kind: ResolvedKind::Pool { h, w, c, k },
                    off,
                    in_len: b * h * w * c,
                    out_len: b * (h / k) * (w / k) * c,
                });
                feat = Feat::Img { h: h / k, w: w / k, c };
            }
            LayerSpec::Embed { dim } => {
                let Feat::Tok { t } = feat else {
                    bail!("model {}: Embed needs token input (and must come first)", spec.name)
                };
                ensure!(dim >= 1, "embed dim must be >= 1");
                let vocab = spec.classes;
                let size = vocab * dim;
                infos.push(LayerInfo {
                    name: "embed".to_string(),
                    shape: vec![vocab, dim],
                    size,
                    offset: off,
                    bucket: next_pow2(size).max(1024),
                    fwd_flops: b as f64 * t as f64 * dim as f64,
                });
                layers.push(ResolvedLayer {
                    kind: ResolvedKind::Embed { vocab, dim },
                    off,
                    in_len: b * t,
                    out_len: b * t * dim,
                });
                off += size;
                feat = Feat::Seq { t, n: dim };
            }
            LayerSpec::Elman { hidden } => {
                let Feat::Seq { t, n } = feat else {
                    bail!("model {}: Elman needs a sequence input (Embed first)", spec.name)
                };
                ensure!(hidden >= 1, "elman hidden must be >= 1");
                n_rnn += 1;
                let size = (n + hidden + 1) * hidden;
                infos.push(LayerInfo {
                    name: format!("rnn{n_rnn}"),
                    shape: vec![n + hidden + 1, hidden],
                    size,
                    offset: off,
                    bucket: next_pow2(size).max(1024),
                    fwd_flops: 2.0 * b as f64 * t as f64 * (n * hidden + hidden * hidden) as f64
                        + b as f64 * t as f64 * hidden as f64,
                });
                layers.push(ResolvedLayer {
                    kind: ResolvedKind::Elman { t, in_dim: n, hidden },
                    off,
                    in_len: b * t * n,
                    out_len: b * t * hidden,
                });
                off += size;
                feat = Feat::Seq { t, n: hidden };
            }
        }
    }
    let Some(last) = layers.last() else { bail!("model {} resolves to no layers", spec.name) };
    match &last.kind {
        ResolvedKind::Dense { fan_out, relu, .. } => {
            ensure!(!relu, "internal: output layer must be linear");
            ensure!(*fan_out == spec.classes, "model {}: head width {} != classes {}", spec.name, fan_out, spec.classes);
        }
        _ => bail!("model {} must end in a Dense layer", spec.name),
    }
    Ok((layers, infos))
}

/// The (x, y) batch specs a spec-defined model exchanges with the data
/// layer (shared by the manifest builder and manifest validation).
fn spec_batch_specs(spec: &ModelSpec) -> (BatchSpec, BatchSpec) {
    match spec.input {
        InputKind::Image { h, w, c } => (
            BatchSpec { shape: vec![spec.batch, h, w, c], dtype: DType::F32 },
            BatchSpec { shape: vec![spec.batch], dtype: DType::I32 },
        ),
        InputKind::Flat { n } => (
            BatchSpec { shape: vec![spec.batch, n], dtype: DType::F32 },
            BatchSpec { shape: vec![spec.batch], dtype: DType::I32 },
        ),
        InputKind::Tokens { t } => (
            BatchSpec { shape: vec![spec.batch, t], dtype: DType::I32 },
            BatchSpec { shape: vec![spec.batch, t], dtype: DType::I32 },
        ),
    }
}

/// Build the manifest entry for a spec-defined model (fused one-tensor-
/// per-block layer table). Errors on invalid specs, like the sibling
/// constructors.
pub fn spec_manifest(spec: &ModelSpec) -> Result<ModelManifest> {
    let (_, infos) = resolve(spec)?;
    let d: usize = infos.iter().map(|l| l.size).sum();
    let (x, y) = spec_batch_specs(spec);
    Ok(ModelManifest {
        name: spec.name.clone(),
        d,
        d_padded: pad_to(d, 4096),
        metric: spec.metric,
        classes: spec.classes,
        x,
        y,
        layers: infos,
        files: BTreeMap::new(),
    })
}

/// Layer table for a legacy MLP spec (shared by the manifest builder and
/// [`NativeNet::from_manifest`] validation).
fn layer_table(dims: &[usize], batch: usize) -> Vec<LayerInfo> {
    let mut layers = Vec::new();
    let mut off = 0;
    for l in 0..dims.len() - 1 {
        let (fan_in, fan_out) = (dims[l], dims[l + 1]);
        let wsize = fan_in * fan_out;
        layers.push(LayerInfo {
            name: format!("w{}", l + 1),
            shape: vec![fan_in, fan_out],
            size: wsize,
            offset: off,
            bucket: next_pow2(wsize).max(1024),
            fwd_flops: 2.0 * batch as f64 * wsize as f64,
        });
        off += wsize;
        layers.push(LayerInfo {
            name: format!("b{}", l + 1),
            shape: vec![fan_out],
            size: fan_out,
            offset: off,
            bucket: next_pow2(fan_out).max(1024),
            fwd_flops: batch as f64 * fan_out as f64,
        });
        off += fan_out;
    }
    layers
}

/// Build the manifest entry for one legacy native MLP (alternating w/b
/// layer table — kept for the `mlp` family so existing tooling and tests
/// see unchanged manifests).
fn mlp_manifest(name: &str, in_dim: usize, hidden: &[usize], classes: usize, batch: usize) -> ModelManifest {
    let mut dims = vec![in_dim];
    dims.extend_from_slice(hidden);
    dims.push(classes);
    let layers = layer_table(&dims, batch);
    let d: usize = layers.iter().map(|l| l.size).sum();
    ModelManifest {
        name: name.to_string(),
        d,
        d_padded: pad_to(d, 4096),
        metric: Metric::Accuracy,
        classes,
        x: BatchSpec { shape: vec![batch, in_dim], dtype: DType::F32 },
        y: BatchSpec { shape: vec![batch], dtype: DType::I32 },
        layers,
        files: BTreeMap::new(),
    }
}

/// The built-in zoo served when no artifacts directory is given:
/// * `mlp` — 32 → 64 → 64 → 10, the quick-test model;
/// * `mlp_deep` — 64 → 128 → 96 → 64 → 48 → 32 → 10, twelve tensors with
///   skewed sizes, the layer-wise-pipelining stress model;
/// * `convnet` — 12×12×3 images → conv16 → pool → conv32 → pool → head,
///   the heterogeneous comm/compute model (conv layers carry ~50× more
///   flops per parameter than the dense head);
/// * `convnet_deep` — 16×16×3 images, four convs + two dense layers, the
///   deep-pipeline stress model where Eq. 18 selects all three regimes
///   (dense, fractional, capped) at once;
/// * `rnn` — order-1 Markov tokens → embed32 → elman64 (BPTT) → head,
///   the LM workload (metric: ppl loss).
pub fn native_manifest(seed: u64) -> Manifest {
    let mut models: Vec<ModelManifest> = vec![
        mlp_manifest("mlp", 32, &[64, 64], 10, 32),
        mlp_manifest("mlp_deep", 64, &[128, 96, 64, 48, 32], 10, 32),
    ];
    for name in ["convnet", "convnet_deep", "rnn"] {
        let spec = zoo_spec(name).expect("builtin");
        models.push(spec_manifest(&spec).expect("builtin zoo specs are valid"));
    }
    let mut buckets: Vec<usize> = models
        .iter()
        .flat_map(|m| m.layers.iter().map(|l| l.bucket))
        .collect();
    buckets.sort_unstable();
    buckets.dedup();
    Manifest {
        dir: PathBuf::from("native"),
        models: models.into_iter().map(|m| (m.name.clone(), m)).collect(),
        compress_buckets: buckets,
        compress_files: BTreeMap::new(),
        seed,
    }
}

// ---------------------------------------------------------------------------
// apply / compress emulation (unchanged contract)
// ---------------------------------------------------------------------------

/// Host emulation of the fused momentum-SGD apply artifact:
/// m' = mu·m + agg, p' = p − m', over padded buffers.
pub fn apply_update_host(
    params_pad: &[f32],
    mom_pad: &[f32],
    agg_pad: &[f32],
    mu: f32,
) -> (Vec<f32>, Vec<f32>) {
    let mut p2 = Vec::with_capacity(params_pad.len());
    let mut m2 = Vec::with_capacity(params_pad.len());
    for i in 0..params_pad.len() {
        let m = mu * mom_pad[i] + agg_pad[i];
        m2.push(m);
        p2.push(params_pad[i] - m);
    }
    (p2, m2)
}

/// Host emulation of the compress artifact contract: pad to the layer
/// bucket, acc = resid + lr·grad, threshold (exact sort or strided
/// double-sampling with the artifact's baked stride) over the padded
/// buffer, split, trim back to the layer size. Matches the PJRT path's
/// numerics so `CompressorKind::Xla*` stays runnable without artifacts.
pub fn compress_layer_bucket(
    layer: &LayerInfo,
    grad: &[f32],
    resid: &[f32],
    lr: f32,
    k: usize,
    sampled: bool,
) -> Result<(Vec<f32>, Vec<f32>, f32)> {
    compress_layer_bucket_into(layer, grad, resid, lr, k, sampled, &mut CompressScratch::default())
}

/// Allocation-free (for the threshold search) form of
/// [`compress_layer_bucket`]: the accumulator and the quickselect/sample
/// buffers come from worker-owned `scratch`, so the trainer's per-layer
/// per-worker cadence stops paying a `kth_largest_abs` allocation per call
/// (§Perf L3-1 applied to the XLA-emulation path).
pub fn compress_layer_bucket_into(
    layer: &LayerInfo,
    grad: &[f32],
    resid: &[f32],
    lr: f32,
    k: usize,
    sampled: bool,
    scratch: &mut CompressScratch,
) -> Result<(Vec<f32>, Vec<f32>, f32)> {
    let n = layer.size;
    ensure!(grad.len() == n && resid.len() == n, "layer slice mismatch");
    let acc = &mut scratch.acc;
    acc.clear();
    acc.resize(layer.bucket, 0.0); // zero-pad the bucket tail every call
    for i in 0..n {
        acc[i] = resid[i] + lr * grad[i];
    }
    let thr = if sampled {
        threshold::sampled_threshold_with_buf(
            acc,
            k,
            XLA_SAMPLE_STRIDE,
            &mut scratch.sample,
            &mut scratch.mags,
        )
    } else {
        topk::kth_largest_abs_with_buf(acc, k, &mut scratch.mags)
    };
    let mut sparse = vec![0.0f32; n];
    let mut new_resid = vec![0.0f32; n];
    topk::split_with_threshold(&acc[..n], thr, &mut sparse, &mut new_resid);
    Ok((sparse, new_resid, thr))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> (NativeNet, ModelManifest) {
        let mm = mlp_manifest("toy", 6, &[8], 3, 4);
        (NativeNet::from_manifest(&mm).unwrap(), mm)
    }

    fn toy_batch(mm: &ModelManifest, seed: u64) -> (BatchData, BatchData) {
        let mut rng = Rng::new(seed);
        match mm.x.dtype {
            DType::F32 => {
                let mut xs = vec![0.0f32; mm.x.elements()];
                rng.fill_normal(&mut xs, 1.0);
                let ys: Vec<i32> =
                    (0..mm.y.elements()).map(|_| rng.below(mm.classes) as i32).collect();
                (BatchData::F32(xs), BatchData::I32(ys))
            }
            DType::I32 => {
                let xs: Vec<i32> =
                    (0..mm.x.elements()).map(|_| rng.below(mm.classes) as i32).collect();
                let ys: Vec<i32> =
                    (0..mm.y.elements()).map(|_| rng.below(mm.classes) as i32).collect();
                (BatchData::I32(xs), BatchData::I32(ys))
            }
        }
    }

    #[test]
    fn manifest_validates_and_round_trips() {
        let man = native_manifest(42);
        for mm in man.models.values() {
            mm.validate().unwrap();
            let m = NativeNet::from_manifest(mm).unwrap();
            assert_eq!(m.init_params(42).len(), mm.d);
        }
        for name in ["mlp", "mlp_deep", "convnet", "convnet_deep", "rnn"] {
            assert!(man.models.contains_key(name), "zoo misses {name}");
        }
    }

    #[test]
    fn zoo_layer_tables_are_heterogeneous() {
        // the point of the conv/rnn zoo: flops-per-param must differ by
        // orders of magnitude across one model's layers (mlp's never did)
        let man = native_manifest(1);
        for name in ["convnet", "convnet_deep"] {
            let mm = &man.models[name];
            let fpp: Vec<f64> =
                mm.layers.iter().map(|l| l.fwd_flops / l.size as f64).collect();
            let (lo, hi) = fpp
                .iter()
                .fold((f64::INFINITY, 0.0f64), |(lo, hi), &v| (lo.min(v), hi.max(v)));
            assert!(hi / lo > 10.0, "{name}: flops/param spread {lo}..{hi} too flat");
        }
    }

    #[test]
    fn spec_mismatch_manifest_rejected() {
        // a manifest that borrows a zoo name but not its layout must error,
        // not silently execute the wrong math
        let mut mm = spec_manifest(&zoo_spec("convnet").unwrap()).unwrap();
        mm.layers[0].name = "not_conv1".into();
        assert!(NativeNet::from_manifest(&mm).is_err());
        // and an invalid spec errors instead of panicking
        let bad = ModelSpec {
            name: "bad".into(),
            batch: 2,
            input: InputKind::Image { h: 8, w: 8, c: 1 },
            classes: 3,
            metric: Metric::Accuracy,
            layers: vec![LayerSpec::MaxPool { k: 3 }, LayerSpec::Dense { out: 3 }],
        };
        assert!(spec_manifest(&bad).is_err());
        assert!(NativeNet::from_spec(&bad).is_err());
    }

    #[test]
    fn grad_matches_finite_differences() {
        let (m, mm) = toy();
        let params = m.init_params(1);
        let (x, y) = toy_batch(&mm, 2);
        let mut grad = Vec::new();
        let mut gs = GradScratch::default();
        let loss0 = m.train_step_into(&params, &x, &y, &mut grad, &mut gs).unwrap();
        assert!(loss0.is_finite());
        // central differences on a few coordinates
        let mut rng = Rng::new(3);
        for _ in 0..12 {
            let i = rng.below(mm.d);
            let eps = 1e-3f32;
            let mut pp = params.clone();
            pp[i] += eps;
            let mut scratch = Vec::new();
            let lp = m.train_step_into(&pp, &x, &y, &mut scratch, &mut gs).unwrap();
            pp[i] -= 2.0 * eps;
            let lm = m.train_step_into(&pp, &x, &y, &mut scratch, &mut gs).unwrap();
            let fd = (lp as f64 - lm as f64) / (2.0 * eps as f64);
            let an = grad[i] as f64;
            assert!(
                (fd - an).abs() < 1e-2 * (1.0 + an.abs().max(fd.abs())),
                "coord {i}: analytic {an} vs fd {fd}"
            );
        }
    }

    #[test]
    fn train_step_deterministic_and_buffer_reusing() {
        let man = native_manifest(4);
        for name in ["mlp", "convnet", "rnn"] {
            let mm = &man.models[name];
            let m = NativeNet::from_manifest(mm).unwrap();
            let params = m.init_params(4);
            let (x, y) = toy_batch(mm, 5);
            let mut g1 = Vec::new();
            let mut g2 = vec![9.0f32; 3]; // wrong-size buffer must be fixed up
            // fresh vs reused (dirty) scratch must not change a single bit
            let mut gs1 = GradScratch::default();
            let mut gs2 = GradScratch::default();
            m.train_step_into(&params, &x, &y, &mut g2, &mut gs2).unwrap();
            let l1 = m.train_step_into(&params, &x, &y, &mut g1, &mut gs1).unwrap();
            let l2 = m.train_step_into(&params, &x, &y, &mut g2, &mut gs2).unwrap();
            assert_eq!(l1, l2, "{name}");
            assert_eq!(g1, g2, "{name}");
            assert!(g1.iter().any(|&g| g != 0.0), "{name}: zero grad");
            assert!(g1.iter().all(|g| g.is_finite()), "{name}: non-finite grad");
        }
    }

    #[test]
    fn sgd_reduces_loss_on_fixed_batch() {
        let (m, mm) = toy();
        let mut params = m.init_params(6);
        let (x, y) = toy_batch(&mm, 7);
        let mut grad = Vec::new();
        let mut gs = GradScratch::default();
        let first = m.train_step_into(&params, &x, &y, &mut grad, &mut gs).unwrap();
        let mut last = first;
        for _ in 0..60 {
            last = m.train_step_into(&params, &x, &y, &mut grad, &mut gs).unwrap();
            for (p, g) in params.iter_mut().zip(grad.iter()) {
                *p -= 0.2 * g;
            }
        }
        assert!(last < 0.5 * first, "loss {first} -> {last}");
    }

    #[test]
    fn sgd_overfits_conv_and_rnn_batches() {
        // the new layer kinds train end-to-end: plain SGD on one fixed
        // batch must cut the loss decisively for every heterogeneous model
        let man = native_manifest(8);
        for (name, lr, iters, factor) in
            [("convnet", 0.2f32, 40, 0.7f32), ("rnn", 0.3, 60, 0.7)]
        {
            let mm = &man.models[name];
            let m = NativeNet::from_manifest(mm).unwrap();
            let mut params = m.init_params(8);
            let (x, y) = if name == "rnn" {
                // identity LM task (predict the current token): learnable
                // through wx alone, so the drop isolates layer correctness
                // from task difficulty
                let (x, _) = toy_batch(mm, 9);
                let BatchData::I32(xs) = &x else { unreachable!() };
                let y = BatchData::I32(xs.clone());
                (x, y)
            } else {
                toy_batch(mm, 9)
            };
            let mut grad = Vec::new();
            let mut gs = GradScratch::default();
            let first = m.train_step_into(&params, &x, &y, &mut grad, &mut gs).unwrap();
            let mut last = first;
            for _ in 0..iters {
                last = m.train_step_into(&params, &x, &y, &mut grad, &mut gs).unwrap();
                for (p, g) in params.iter_mut().zip(grad.iter()) {
                    *p -= lr * g;
                }
            }
            assert!(last.is_finite() && last < factor * first, "{name}: loss {first} -> {last}");
        }
    }

    // NOTE: im2col-vs-direct-convolution equivalence (forward AND
    // backward, random shapes/strides/paddings) lives in
    // rust/tests/proptest_invariants.rs — one naive reference, not two.

    #[test]
    fn maxpool_routes_gradient_to_argmax() {
        let (h, w) = (4usize, 4usize);
        let p = PoolDims { h, w, c: 1, k: 2 };
        let mut x = vec![0.0f32; h * w];
        x[5] = 3.0; // window (0,0): max at (1,1)
        x[2] = 7.0; // window (0,1): max at (0,2)
        let mut out = vec![0.0f32; 4];
        maxpool_forward(&p, &x, 1, &mut out);
        assert_eq!(out[0], 3.0);
        assert_eq!(out[1], 7.0);
        let delta = vec![1.0f32, 2.0, 4.0, 8.0];
        let mut dx = vec![0.0f32; h * w];
        maxpool_backward(&p, &x, 1, &delta, &mut dx);
        assert_eq!(dx[5], 1.0);
        assert_eq!(dx[2], 2.0);
        assert_eq!(dx.iter().filter(|&&v| v != 0.0).count(), 4);
        let s: f32 = dx.iter().sum();
        assert_eq!(s, 15.0, "pooling neither duplicates nor drops gradient mass");
    }

    #[test]
    fn maxpool_cached_idx_matches_rescan_backward() {
        // the cached-argmax fast path must route bit-identically to the
        // re-scanning reference, including ties (equal values in one
        // window resolve to the first strict max in scan order)
        let (h, w, c, k) = (6usize, 4usize, 2usize, 2usize);
        let p = PoolDims { h, w, c, k };
        let batch = 3usize;
        let mut rng = Rng::new(21);
        let mut x = vec![0.0f32; batch * h * w * c];
        rng.fill_normal(&mut x, 1.0);
        // inject ties: duplicate some values inside windows
        x[3] = x[1];
        x[10] = x[2];
        let (ho, wo) = (h / k, w / k);
        let mut out_a = vec![0.0f32; batch * ho * wo * c];
        let mut out_b = vec![0.0f32; batch * ho * wo * c];
        let mut idx = Vec::new();
        maxpool_forward(&p, &x, batch, &mut out_a);
        maxpool_forward_idx(&p, &x, batch, &mut out_b, &mut idx);
        assert_eq!(out_a, out_b);
        let mut delta = vec![0.0f32; out_a.len()];
        rng.fill_normal(&mut delta, 1.0);
        let mut dx_scan = vec![0.0f32; x.len()];
        let mut dx_idx = vec![0.0f32; x.len()];
        maxpool_backward(&p, &x, batch, &delta, &mut dx_scan);
        maxpool_backward_idx(&idx, &delta, &mut dx_idx);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&dx_idx), bits(&dx_scan));
    }

    #[test]
    fn gemm_shapes_cover_parametric_hot_loops() {
        let man = native_manifest(1);
        let conv = NativeNet::from_manifest(&man.models["convnet"]).unwrap();
        let shapes = conv.gemm_shapes();
        // conv1, conv2, head — pools contribute no GEMM
        assert_eq!(shapes.len(), 3);
        assert!(shapes.iter().all(|s| s.flops() > 0.0 && s.step_flops() >= s.flops()));
        // conv GEMMs run once per sample (batch 16), the head once per step
        assert_eq!(shapes[0].calls_per_step, 16);
        assert_eq!(shapes[2].calls_per_step, 1);
        // the calibration weight must be dominated by the conv mat-muls,
        // not the head GEMV-ish tail — that is the aggregation's point
        assert!(shapes[0].step_flops() > 10.0 * shapes[2].step_flops());
        let rnn = NativeNet::from_manifest(&man.models["rnn"]).unwrap();
        // embed has no GEMM; elman contributes two shapes; head one
        let rs = rnn.gemm_shapes();
        assert_eq!(rs.len(), 3);
        // elman GEMVs run batch·t times per step
        assert_eq!(rs[0].calls_per_step, 8 * 16);
    }

    #[test]
    fn elman_zero_weights_give_bias_states() {
        let (t, i, h) = (3usize, 2usize, 2usize);
        let wx = vec![0.0f32; i * h];
        let wh = vec![0.0f32; h * h];
        let bias = vec![0.25f32, -0.5];
        let x = vec![1.0f32; t * i];
        let mut out = vec![0.0f32; t * h];
        let e = ElmanDims { batch: 1, t, in_dim: i, hidden: h };
        elman_forward(&e, &ElmanWeights { wx: &wx, wh: &wh }, &bias, &x, &mut out);
        for s in 0..t {
            assert!((out[s * h] - 0.25f32.tanh()).abs() < 1e-6);
            assert!((out[s * h + 1] - (-0.5f32).tanh()).abs() < 1e-6);
        }
    }

    #[test]
    fn train_step_rejects_bad_tokens_and_labels() {
        let man = native_manifest(3);
        let mm = &man.models["rnn"];
        let m = NativeNet::from_manifest(mm).unwrap();
        let params = m.init_params(3);
        let mut grad = Vec::new();
        let mut gs = GradScratch::default();
        let xs = vec![0i32; mm.x.elements()];
        let mut ys = vec![0i32; mm.y.elements()];
        ys[0] = mm.classes as i32; // out of range
        let r = m.train_step_into(
            &params,
            &BatchData::I32(xs.clone()),
            &BatchData::I32(ys),
            &mut grad,
            &mut gs,
        );
        assert!(r.is_err());
        let mut xs_bad = xs;
        xs_bad[0] = mm.classes as i32;
        let r = m.train_step_into(
            &params,
            &BatchData::I32(xs_bad),
            &BatchData::I32(vec![0i32; mm.y.elements()]),
            &mut grad,
            &mut gs,
        );
        assert!(r.is_err());
    }

    #[test]
    fn eval_metric_is_accuracy_in_range() {
        let (m, mm) = toy();
        let params = m.init_params(8);
        let (x, y) = toy_batch(&mm, 9);
        let (loss, acc) = m.eval_step(&params, &x, &y).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!((0.0..=1.0).contains(&acc));
        // LM metric convention: metric == loss
        let man = native_manifest(8);
        let rm = &man.models["rnn"];
        let rn = NativeNet::from_manifest(rm).unwrap();
        let (x, y) = toy_batch(rm, 10);
        let (loss, metric) = rn.eval_metric(&rn.init_params(8), &x, &y, rm.metric).unwrap();
        assert_eq!(loss, metric);
    }

    #[test]
    fn apply_update_host_math() {
        let p = vec![1.0f32, 2.0, 3.0];
        let m = vec![0.5f32, 0.0, -1.0];
        let a = vec![0.1f32, 0.2, 0.3];
        let (p2, m2) = apply_update_host(&p, &m, &a, 0.9);
        for i in 0..3 {
            let expect_m = 0.9 * m[i] + a[i];
            assert_eq!(m2[i], expect_m);
            assert_eq!(p2[i], p[i] - expect_m);
        }
    }

    #[test]
    fn bucket_compress_scratch_reuse_bit_identical() {
        // one dirty scratch across layers with different bucket sizes must
        // match the fresh-allocation form exactly (tail re-zeroing)
        let (_, mm) = toy();
        let mut scratch = CompressScratch::default();
        let mut rng = Rng::new(11);
        for (li, layer) in mm.layers.iter().enumerate() {
            let n = layer.size;
            let grad: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let resid: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.3).collect();
            let k = (n / 4).max(1);
            for sampled in [false, true] {
                let a = compress_layer_bucket(layer, &grad, &resid, 0.2, k, sampled).unwrap();
                let b = compress_layer_bucket_into(layer, &grad, &resid, 0.2, k, sampled, &mut scratch)
                    .unwrap();
                assert_eq!(a, b, "layer {li} sampled={sampled}");
            }
        }
    }

    #[test]
    fn bucket_compress_matches_unpadded_exact_threshold() {
        let (_, mm) = toy();
        let layer = &mm.layers[0]; // w1, padded into a larger bucket
        let mut rng = Rng::new(10);
        let n = layer.size;
        let grad: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let resid: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.2).collect();
        let k = (n / 5).max(1);
        let (sparse, new_resid, thr) =
            compress_layer_bucket(layer, &grad, &resid, 0.1, k, false).unwrap();
        // zero-padding must not perturb the exact threshold
        let acc: Vec<f32> = resid.iter().zip(grad.iter()).map(|(&r, &g)| r + 0.1 * g).collect();
        assert_eq!(thr, topk::kth_largest_abs(&acc, k));
        for i in 0..n {
            assert_eq!(sparse[i] + new_resid[i], acc[i], "mass conservation i={i}");
        }
    }
}
