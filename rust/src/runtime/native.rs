//! Native backend: a reference MLP family executed directly on the host.
//!
//! The PJRT backend needs the vendored `xla` crate plus `make artifacts`;
//! neither is required to exercise the *distributed* layer this crate
//! reproduces (workers, error feedback, sparse aggregation, pipelining).
//! This backend supplies the same `train/eval/apply/compress` contract
//! with plain-rust f32 math over a small built-in model zoo, so the
//! trainer, the determinism tests and the hot-path benches run in any
//! environment — and, unlike PJRT executables, it is `Sync`, so the P
//! workers' gradient steps genuinely fan out across threads.
//!
//! Determinism: every loop runs in a fixed order with f32 accumulation,
//! so results are bit-identical across runs and across `--threads`
//! settings (each worker's math touches only that worker's inputs).

use super::manifest::{BatchSpec, DType, LayerInfo, Manifest, Metric, ModelManifest};
use super::BatchData;
use crate::sparsify::{threshold, topk};
use crate::util::rng::Rng;
use crate::util::{next_pow2, pad_to};
use anyhow::{bail, ensure, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Strided double-sampling stride baked into the AOT compress artifacts;
/// the native emulation of `CompressorKind::XlaSampled` mirrors it.
pub const XLA_SAMPLE_STRIDE: usize = 64;

/// Fully-connected classifier: dims = [in, h1, ..., hk, classes], ReLU
/// hidden activations, softmax cross-entropy loss, flat param layout
/// `[w1, b1, w2, b2, ...]` with row-major `w_l: [dims[l], dims[l+1]]` —
/// the layer table the manifest publishes.
pub struct NativeMlp {
    dims: Vec<usize>,
    batch: usize,
    d: usize,
}

/// Layer table for an MLP spec (shared by the manifest builder and
/// [`NativeMlp::from_manifest`] validation).
fn layer_table(dims: &[usize], batch: usize) -> Vec<LayerInfo> {
    let mut layers = Vec::new();
    let mut off = 0;
    for l in 0..dims.len() - 1 {
        let (fan_in, fan_out) = (dims[l], dims[l + 1]);
        let wsize = fan_in * fan_out;
        layers.push(LayerInfo {
            name: format!("w{}", l + 1),
            shape: vec![fan_in, fan_out],
            size: wsize,
            offset: off,
            bucket: next_pow2(wsize).max(1024),
            fwd_flops: 2.0 * batch as f64 * wsize as f64,
        });
        off += wsize;
        layers.push(LayerInfo {
            name: format!("b{}", l + 1),
            shape: vec![fan_out],
            size: fan_out,
            offset: off,
            bucket: next_pow2(fan_out).max(1024),
            fwd_flops: batch as f64 * fan_out as f64,
        });
        off += fan_out;
    }
    layers
}

/// Build the manifest entry for one native MLP.
fn mlp_manifest(name: &str, in_dim: usize, hidden: &[usize], classes: usize, batch: usize) -> ModelManifest {
    let mut dims = vec![in_dim];
    dims.extend_from_slice(hidden);
    dims.push(classes);
    let layers = layer_table(&dims, batch);
    let d: usize = layers.iter().map(|l| l.size).sum();
    ModelManifest {
        name: name.to_string(),
        d,
        d_padded: pad_to(d, 4096),
        metric: Metric::Accuracy,
        classes,
        x: BatchSpec { shape: vec![batch, in_dim], dtype: DType::F32 },
        y: BatchSpec { shape: vec![batch], dtype: DType::I32 },
        layers,
        files: BTreeMap::new(),
    }
}

/// The built-in zoo served when no artifacts directory is given:
/// * `mlp` — 32 → 64 → 64 → 10, the quick-test model;
/// * `mlp_deep` — 64 → 128 → 96 → 64 → 48 → 32 → 10, twelve tensors with
///   skewed sizes, the layer-wise-pipelining stress model for the hot-path
///   benches.
pub fn native_manifest(seed: u64) -> Manifest {
    let models: Vec<ModelManifest> = vec![
        mlp_manifest("mlp", 32, &[64, 64], 10, 32),
        mlp_manifest("mlp_deep", 64, &[128, 96, 64, 48, 32], 10, 32),
    ];
    let mut buckets: Vec<usize> = models
        .iter()
        .flat_map(|m| m.layers.iter().map(|l| l.bucket))
        .collect();
    buckets.sort_unstable();
    buckets.dedup();
    Manifest {
        dir: PathBuf::from("native"),
        models: models.into_iter().map(|m| (m.name.clone(), m)).collect(),
        compress_buckets: buckets,
        compress_files: BTreeMap::new(),
        seed,
    }
}

/// Worker-owned scratch for the native forward/backward pass, reused
/// across steps: per-layer activations, the two δ buffers, and the
/// per-layer Wᵀ cache for the dX walk. Every buffer reaches steady-state
/// capacity after the first step, so the hot loop stops allocating; the
/// Wᵀ cache additionally turns the per-sample `Σ_j W[i,j]·δ[j]` column
/// reduction into contiguous row-walk axpys (one strided transpose per
/// layer instead of `batch` strided reads).
#[derive(Debug, Clone, Default)]
pub struct GradScratch {
    acts: Vec<Vec<f32>>,
    delta: Vec<f32>,
    prev: Vec<f32>,
    wt: Vec<f32>,
}

/// Reusable scratch for [`compress_layer_bucket_into`]: the bucket-padded
/// accumulator plus the selection buffers, so the per-layer-per-worker
/// XLA-emulation compress path performs no allocation for the threshold
/// search (the returned sparse/residual vectors stay owned — they are the
/// artifact contract's outputs).
#[derive(Debug, Clone, Default)]
pub struct CompressScratch {
    acc: Vec<f32>,
    sample: Vec<f32>,
    mags: Vec<f32>,
}

impl NativeMlp {
    /// Reconstruct the MLP shape from a manifest layer table (validates
    /// the alternating w/b structure this backend requires).
    pub fn from_manifest(mm: &ModelManifest) -> Result<NativeMlp> {
        ensure!(mm.x.shape.len() == 2 && mm.x.dtype == DType::F32, "native backend wants [batch, in] f32 inputs");
        ensure!(mm.y.shape.len() == 1 && mm.y.dtype == DType::I32, "native backend wants [batch] i32 labels");
        ensure!(!mm.layers.is_empty() && mm.layers.len() % 2 == 0, "native backend wants alternating w/b layers");
        let batch = mm.x.shape[0];
        let mut dims = vec![mm.x.shape[1]];
        for pair in mm.layers.chunks(2) {
            let (w, b) = (&pair[0], &pair[1]);
            ensure!(w.shape.len() == 2 && b.shape.len() == 1, "layer pair {}/{} not (matrix, bias)", w.name, b.name);
            ensure!(w.shape[0] == *dims.last().unwrap(), "layer {} fan-in mismatch", w.name);
            ensure!(w.shape[1] == b.shape[0], "layer {} bias mismatch", w.name);
            dims.push(w.shape[1]);
        }
        ensure!(*dims.last().unwrap() == mm.classes, "output width != classes");
        Ok(NativeMlp { dims, batch, d: mm.d })
    }

    /// Seeded He-normal initial parameters (biases zero), deterministic in
    /// (seed, shape) — the native stand-in for `init.bin`.
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut params = vec![0.0f32; self.d];
        let mut off = 0;
        for l in 0..self.dims.len() - 1 {
            let (fan_in, fan_out) = (self.dims[l], self.dims[l + 1]);
            let mut rng = Rng::new(seed ^ 0x9a7e_11e5 ^ ((l as u64) << 40));
            let sigma = (2.0 / fan_in as f32).sqrt();
            rng.fill_normal(&mut params[off..off + fan_in * fan_out], sigma);
            off += fan_in * fan_out + fan_out; // biases stay zero
        }
        params
    }

    fn check_batch(&self, x: &BatchData, y: &BatchData) -> Result<(usize, usize)> {
        let (b, in_dim) = (self.batch, self.dims[0]);
        ensure!(x.len() == b * in_dim, "x batch shape mismatch");
        ensure!(y.len() == b, "y batch shape mismatch");
        Ok((b, in_dim))
    }

    /// Forward pass into reusable per-layer activation buffers (`acts[l]`
    /// has shape [batch, dims[l+1]]; the last entry holds raw logits).
    /// Every element is overwritten, so stale contents don't matter.
    fn forward_into(&self, params: &[f32], x: &[f32], acts: &mut Vec<Vec<f32>>) {
        let nl = self.dims.len() - 1;
        let b = self.batch;
        acts.resize_with(nl, Vec::new);
        let mut off = 0;
        for l in 0..nl {
            let (fan_in, fan_out) = (self.dims[l], self.dims[l + 1]);
            let w = &params[off..off + fan_in * fan_out];
            let bias = &params[off + fan_in * fan_out..off + fan_in * fan_out + fan_out];
            off += fan_in * fan_out + fan_out;
            let (done, rest) = acts.split_at_mut(l);
            let input: &[f32] = if l == 0 { x } else { &done[l - 1] };
            let out = &mut rest[0];
            out.resize(b * fan_out, 0.0);
            for n in 0..b {
                let row = &input[n * fan_in..(n + 1) * fan_in];
                let orow = &mut out[n * fan_out..(n + 1) * fan_out];
                orow.copy_from_slice(bias);
                for (i, &xi) in row.iter().enumerate() {
                    if xi != 0.0 {
                        let wrow = &w[i * fan_out..(i + 1) * fan_out];
                        for (o, &wij) in orow.iter_mut().zip(wrow.iter()) {
                            *o += xi * wij;
                        }
                    }
                }
                if l + 1 < nl {
                    for o in orow.iter_mut() {
                        *o = o.max(0.0);
                    }
                }
            }
        }
    }

    /// Mean softmax cross-entropy + per-logit gradient (∂loss/∂logits).
    fn softmax_xent(&self, logits: &[f32], labels: &[i32], dlogits: &mut [f32]) -> f32 {
        let (b, c) = (self.batch, *self.dims.last().unwrap());
        let mut loss = 0.0f32;
        for n in 0..b {
            let row = &logits[n * c..(n + 1) * c];
            let drow = &mut dlogits[n * c..(n + 1) * c];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f32;
            for (d, &v) in drow.iter_mut().zip(row.iter()) {
                *d = (v - max).exp();
                z += *d;
            }
            let y = labels[n] as usize;
            loss += z.ln() - (row[y] - max);
            let inv = 1.0 / (z * b as f32);
            for (j, d) in drow.iter_mut().enumerate() {
                *d = *d * inv - if j == y { 1.0 / b as f32 } else { 0.0 };
            }
        }
        loss / b as f32
    }

    /// One train step: loss + flat gradient written into `grad` (resized
    /// to d; the caller owns the buffer so repeated steps don't allocate).
    /// `scratch` is worker-owned and reused across steps — after the first
    /// call the step performs no heap allocation.
    pub fn train_step_into(
        &self,
        params: &[f32],
        x: &BatchData,
        y: &BatchData,
        grad: &mut Vec<f32>,
        scratch: &mut GradScratch,
    ) -> Result<f32> {
        ensure!(params.len() == self.d, "params dim mismatch");
        let (b, _) = self.check_batch(x, y)?;
        let BatchData::F32(xv) = x else { bail!("x must be f32") };
        let BatchData::I32(yv) = y else { bail!("y must be i32") };
        for &label in yv {
            ensure!((label as usize) < *self.dims.last().unwrap(), "label out of range");
        }

        let nl = self.dims.len() - 1;
        let GradScratch { acts, delta, prev, wt } = scratch;
        self.forward_into(params, xv, acts);
        let c = self.dims[nl];
        delta.clear();
        delta.resize(b * c, 0.0);
        let loss = self.softmax_xent(&acts[nl - 1], yv, delta);

        grad.clear();
        grad.resize(self.d, 0.0);
        // layer offsets (w, b) for the backward walk
        let mut offs = Vec::with_capacity(nl);
        let mut off = 0;
        for l in 0..nl {
            offs.push(off);
            off += self.dims[l] * self.dims[l + 1] + self.dims[l + 1];
        }

        for l in (0..nl).rev() {
            let (fan_in, fan_out) = (self.dims[l], self.dims[l + 1]);
            let woff = offs[l];
            let boff = woff + fan_in * fan_out;
            let input: &[f32] = if l == 0 { xv } else { &acts[l - 1] };

            // dW[i,j] = Σ_n a[n,i]·δ[n,j];  db[j] = Σ_n δ[n,j]
            for n in 0..b {
                let arow = &input[n * fan_in..(n + 1) * fan_in];
                let drow = &delta[n * fan_out..(n + 1) * fan_out];
                for (i, &ai) in arow.iter().enumerate() {
                    if ai != 0.0 {
                        let grow = &mut grad[woff + i * fan_out..woff + (i + 1) * fan_out];
                        for (g, &dj) in grow.iter_mut().zip(drow.iter()) {
                            *g += ai * dj;
                        }
                    }
                }
                let gb = &mut grad[boff..boff + fan_out];
                for (g, &dj) in gb.iter_mut().zip(drow.iter()) {
                    *g += dj;
                }
            }

            // δ_prev[n,i] = relu'(a[n,i]) · Σ_j W[i,j]·δ[n,j]. W is cached
            // transposed once per layer so the per-sample inner walk is a
            // contiguous axpy over Wᵀ rows (length fan_in) instead of b
            // strided column reductions; the j-ascending accumulation
            // order — and therefore every f32 sum — is unchanged.
            if l > 0 {
                let w = &params[woff..woff + fan_in * fan_out];
                wt.clear();
                wt.resize(fan_out * fan_in, 0.0);
                for i in 0..fan_in {
                    let wrow = &w[i * fan_out..(i + 1) * fan_out];
                    for (j, &wij) in wrow.iter().enumerate() {
                        wt[j * fan_in + i] = wij;
                    }
                }
                prev.clear();
                prev.resize(b * fan_in, 0.0);
                for n in 0..b {
                    let drow = &delta[n * fan_out..(n + 1) * fan_out];
                    let prow = &mut prev[n * fan_in..(n + 1) * fan_in];
                    for (j, &dj) in drow.iter().enumerate() {
                        let wtrow = &wt[j * fan_in..(j + 1) * fan_in];
                        for (p, &wji) in prow.iter_mut().zip(wtrow.iter()) {
                            *p += wji * dj;
                        }
                    }
                    // relu' mask: zero where the forward activation was
                    // clamped (matches the branchy reference, which never
                    // accumulated those entries)
                    let arow = &input[n * fan_in..(n + 1) * fan_in];
                    for (p, &ai) in prow.iter_mut().zip(arow.iter()) {
                        if ai <= 0.0 {
                            *p = 0.0;
                        }
                    }
                }
                std::mem::swap(&mut *delta, &mut *prev);
            }
        }
        Ok(loss)
    }

    /// Eval step: (mean loss, top-1 accuracy).
    pub fn eval_step(&self, params: &[f32], x: &BatchData, y: &BatchData) -> Result<(f32, f32)> {
        ensure!(params.len() == self.d, "params dim mismatch");
        let (b, _) = self.check_batch(x, y)?;
        let BatchData::F32(xv) = x else { bail!("x must be f32") };
        let BatchData::I32(yv) = y else { bail!("y must be i32") };
        for &label in yv {
            ensure!((label as usize) < *self.dims.last().unwrap(), "label out of range");
        }
        let nl = self.dims.len() - 1;
        let mut acts = Vec::new();
        self.forward_into(params, xv, &mut acts);
        let logits = &acts[nl - 1];
        let c = self.dims[nl];
        let mut scratch = vec![0.0f32; b * c];
        let loss = self.softmax_xent(logits, yv, &mut scratch);
        let mut correct = 0usize;
        for n in 0..b {
            let row = &logits[n * c..(n + 1) * c];
            let mut best = (0usize, f32::NEG_INFINITY);
            for (j, &v) in row.iter().enumerate() {
                if v > best.1 {
                    best = (j, v);
                }
            }
            if best.0 == yv[n] as usize {
                correct += 1;
            }
        }
        Ok((loss, correct as f32 / b as f32))
    }
}

/// Host emulation of the fused momentum-SGD apply artifact:
/// m' = mu·m + agg, p' = p − m', over padded buffers.
pub fn apply_update_host(
    params_pad: &[f32],
    mom_pad: &[f32],
    agg_pad: &[f32],
    mu: f32,
) -> (Vec<f32>, Vec<f32>) {
    let mut p2 = Vec::with_capacity(params_pad.len());
    let mut m2 = Vec::with_capacity(params_pad.len());
    for i in 0..params_pad.len() {
        let m = mu * mom_pad[i] + agg_pad[i];
        m2.push(m);
        p2.push(params_pad[i] - m);
    }
    (p2, m2)
}

/// Host emulation of the compress artifact contract: pad to the layer
/// bucket, acc = resid + lr·grad, threshold (exact sort or strided
/// double-sampling with the artifact's baked stride) over the padded
/// buffer, split, trim back to the layer size. Matches the PJRT path's
/// numerics so `CompressorKind::Xla*` stays runnable without artifacts.
pub fn compress_layer_bucket(
    layer: &LayerInfo,
    grad: &[f32],
    resid: &[f32],
    lr: f32,
    k: usize,
    sampled: bool,
) -> Result<(Vec<f32>, Vec<f32>, f32)> {
    compress_layer_bucket_into(layer, grad, resid, lr, k, sampled, &mut CompressScratch::default())
}

/// Allocation-free (for the threshold search) form of
/// [`compress_layer_bucket`]: the accumulator and the quickselect/sample
/// buffers come from worker-owned `scratch`, so the trainer's per-layer
/// per-worker cadence stops paying a `kth_largest_abs` allocation per call
/// (§Perf L3-1 applied to the XLA-emulation path).
pub fn compress_layer_bucket_into(
    layer: &LayerInfo,
    grad: &[f32],
    resid: &[f32],
    lr: f32,
    k: usize,
    sampled: bool,
    scratch: &mut CompressScratch,
) -> Result<(Vec<f32>, Vec<f32>, f32)> {
    let n = layer.size;
    ensure!(grad.len() == n && resid.len() == n, "layer slice mismatch");
    let acc = &mut scratch.acc;
    acc.clear();
    acc.resize(layer.bucket, 0.0); // zero-pad the bucket tail every call
    for i in 0..n {
        acc[i] = resid[i] + lr * grad[i];
    }
    let thr = if sampled {
        threshold::sampled_threshold_with_buf(
            acc,
            k,
            XLA_SAMPLE_STRIDE,
            &mut scratch.sample,
            &mut scratch.mags,
        )
    } else {
        topk::kth_largest_abs_with_buf(acc, k, &mut scratch.mags)
    };
    let mut sparse = vec![0.0f32; n];
    let mut new_resid = vec![0.0f32; n];
    topk::split_with_threshold(&acc[..n], thr, &mut sparse, &mut new_resid);
    Ok((sparse, new_resid, thr))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> (NativeMlp, ModelManifest) {
        let mm = mlp_manifest("toy", 6, &[8], 3, 4);
        (NativeMlp::from_manifest(&mm).unwrap(), mm)
    }

    fn toy_batch(mm: &ModelManifest, seed: u64) -> (BatchData, BatchData) {
        let mut rng = Rng::new(seed);
        let mut xs = vec![0.0f32; mm.x.elements()];
        rng.fill_normal(&mut xs, 1.0);
        let ys: Vec<i32> = (0..mm.y.elements()).map(|_| rng.below(mm.classes) as i32).collect();
        (BatchData::F32(xs), BatchData::I32(ys))
    }

    #[test]
    fn manifest_validates_and_round_trips() {
        let man = native_manifest(42);
        for mm in man.models.values() {
            mm.validate().unwrap();
            let m = NativeMlp::from_manifest(mm).unwrap();
            assert_eq!(m.init_params(42).len(), mm.d);
        }
        assert!(man.models.contains_key("mlp") && man.models.contains_key("mlp_deep"));
    }

    #[test]
    fn grad_matches_finite_differences() {
        let (m, mm) = toy();
        let params = m.init_params(1);
        let (x, y) = toy_batch(&mm, 2);
        let mut grad = Vec::new();
        let mut gs = GradScratch::default();
        let loss0 = m.train_step_into(&params, &x, &y, &mut grad, &mut gs).unwrap();
        assert!(loss0.is_finite());
        // central differences on a few coordinates, f64-refined via eps
        let mut rng = Rng::new(3);
        for _ in 0..12 {
            let i = rng.below(mm.d);
            let eps = 1e-3f32;
            let mut pp = params.clone();
            pp[i] += eps;
            let mut scratch = Vec::new();
            let lp = m.train_step_into(&pp, &x, &y, &mut scratch, &mut gs).unwrap();
            pp[i] -= 2.0 * eps;
            let lm = m.train_step_into(&pp, &x, &y, &mut scratch, &mut gs).unwrap();
            let fd = (lp as f64 - lm as f64) / (2.0 * eps as f64);
            let an = grad[i] as f64;
            assert!(
                (fd - an).abs() < 1e-2 * (1.0 + an.abs().max(fd.abs())),
                "coord {i}: analytic {an} vs fd {fd}"
            );
        }
    }

    #[test]
    fn train_step_deterministic_and_buffer_reusing() {
        let (m, mm) = toy();
        let params = m.init_params(4);
        let (x, y) = toy_batch(&mm, 5);
        let mut g1 = Vec::new();
        let mut g2 = vec![9.0f32; 3]; // wrong-size buffer must be fixed up
        // fresh vs reused (dirty) scratch must not change a single bit
        let mut gs1 = GradScratch::default();
        let mut gs2 = GradScratch::default();
        m.train_step_into(&params, &x, &y, &mut g2, &mut gs2).unwrap();
        let l1 = m.train_step_into(&params, &x, &y, &mut g1, &mut gs1).unwrap();
        let l2 = m.train_step_into(&params, &x, &y, &mut g2, &mut gs2).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(g1, g2);
        assert!(g1.iter().any(|&g| g != 0.0));
        assert!(g1.iter().all(|g| g.is_finite()));
    }

    #[test]
    fn sgd_reduces_loss_on_fixed_batch() {
        let (m, mm) = toy();
        let mut params = m.init_params(6);
        let (x, y) = toy_batch(&mm, 7);
        let mut grad = Vec::new();
        let mut gs = GradScratch::default();
        let first = m.train_step_into(&params, &x, &y, &mut grad, &mut gs).unwrap();
        let mut last = first;
        for _ in 0..60 {
            last = m.train_step_into(&params, &x, &y, &mut grad, &mut gs).unwrap();
            for (p, g) in params.iter_mut().zip(grad.iter()) {
                *p -= 0.2 * g;
            }
        }
        assert!(last < 0.5 * first, "loss {first} -> {last}");
    }

    #[test]
    fn eval_metric_is_accuracy_in_range() {
        let (m, mm) = toy();
        let params = m.init_params(8);
        let (x, y) = toy_batch(&mm, 9);
        let (loss, acc) = m.eval_step(&params, &x, &y).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn apply_update_host_math() {
        let p = vec![1.0f32, 2.0, 3.0];
        let m = vec![0.5f32, 0.0, -1.0];
        let a = vec![0.1f32, 0.2, 0.3];
        let (p2, m2) = apply_update_host(&p, &m, &a, 0.9);
        for i in 0..3 {
            let expect_m = 0.9 * m[i] + a[i];
            assert_eq!(m2[i], expect_m);
            assert_eq!(p2[i], p[i] - expect_m);
        }
    }

    #[test]
    fn bucket_compress_scratch_reuse_bit_identical() {
        // one dirty scratch across layers with different bucket sizes must
        // match the fresh-allocation form exactly (tail re-zeroing)
        let (_, mm) = toy();
        let mut scratch = CompressScratch::default();
        let mut rng = Rng::new(11);
        for (li, layer) in mm.layers.iter().enumerate() {
            let n = layer.size;
            let grad: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let resid: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.3).collect();
            let k = (n / 4).max(1);
            for sampled in [false, true] {
                let a = compress_layer_bucket(layer, &grad, &resid, 0.2, k, sampled).unwrap();
                let b = compress_layer_bucket_into(layer, &grad, &resid, 0.2, k, sampled, &mut scratch)
                    .unwrap();
                assert_eq!(a, b, "layer {li} sampled={sampled}");
            }
        }
    }

    #[test]
    fn bucket_compress_matches_unpadded_exact_threshold() {
        let (_, mm) = toy();
        let layer = &mm.layers[0]; // w1, padded into a larger bucket
        let mut rng = Rng::new(10);
        let n = layer.size;
        let grad: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let resid: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.2).collect();
        let k = (n / 5).max(1);
        let (sparse, new_resid, thr) =
            compress_layer_bucket(layer, &grad, &resid, 0.1, k, false).unwrap();
        // zero-padding must not perturb the exact threshold
        let acc: Vec<f32> = resid.iter().zip(grad.iter()).map(|(&r, &g)| r + 0.1 * g).collect();
        assert_eq!(thr, topk::kth_largest_abs(&acc, k));
        for i in 0..n {
            assert_eq!(sparse[i] + new_resid[i], acc[i], "mass conservation i={i}");
        }
    }
}
