//! Shared f32 GEMM kernel core for the native backend's hot loops.
//!
//! Every inner-product loop in `runtime::native` — Dense forward/backward,
//! im2col Conv2d forward + dW/dX, the Elman recurrence and its BPTT — is a
//! `C += A·B` over small row-major matrices. This module centralises them
//! behind one cache-blocked, register-tiled kernel family instead of the
//! original naive triple loops (DESIGN.md §Kernels-and-calibration).
//!
//! ## The fixed-reduction-order contract
//!
//! Each output element `C[i,j]` is updated as ONE running f32 accumulation
//! chain, seeded from the incoming `C[i,j]`, adding the products
//! `A[i,kk]·B[kk,j]` in strictly ascending `kk` order:
//!
//! ```text
//! C[i,j] = (((C0 + t_0) + t_1) + ... + t_{K-1})        t_kk = a·b, f32
//! ```
//!
//! That is exactly what the reference triple loop [`gemm_ref`] produces —
//! and it is what every blocked/tiled path here produces too, because:
//!
//! * **K blocking** round-trips the partial chain through `C` between
//!   blocks; f32 store/load is exact, so the chain is unchanged;
//! * **register tiling** (`MR`×`NR` accumulator tiles) loads the tile FROM
//!   `C` (never from zero), accumulates ascending `kk`, and stores back —
//!   again the same chain;
//! * the 8-wide unrolled inner loops vectorize ACROSS output elements
//!   (independent chains), never across the reduction dimension, so no
//!   f32 sum is ever reassociated.
//!
//! The kernels are therefore bit-identical to [`gemm_ref`] for every
//! shape including remainder tiles (asserted by the unit tests here and
//! `prop_blocked_gemm_bit_identical_to_reference`), and — being pure
//! functions of their arguments — thread-count independent, which is what
//! keeps the trainer's parallel≡sequential / overlap≡barrier bit-identity
//! contracts intact.

use super::simd;

/// Register-tile rows: each micro-kernel step amortises one `B` row load
/// across this many `A` rows (shared with the SIMD tier's tile bodies).
const MR: usize = simd::MR;
/// Register-tile columns of the SCALAR micro-kernel: the unrolled vector
/// width of its inner loops. The dispatched [`simd::KernelSet`] may tile
/// wider (AVX-512 runs 16-column tiles) — legal under the contract
/// because tile width only selects which independent per-element chains
/// run together, never the order within one chain.
pub(crate) const NR: usize = 8;
/// Reduction-dimension cache block: keeps the active `B` panel (`KC`×`NR`
/// f32) resident in L1/L2 across a row sweep.
const KC: usize = 256;

/// Reusable scratch a [`gemm_nt`] caller owns so the steady-state hot loop
/// stays allocation-free (the Bᵀ pack buffer grows once to the largest
/// shape, then is reused).
#[derive(Debug, Default, Clone)]
pub struct GemmScratch {
    bt: Vec<f32>,
}

/// `C[m,n] += A·B` with `A` row-major `[m,k]`, `B` row-major `[k,n]`.
pub fn gemm_nn(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    gemm_blocked::<false>(c, a, b, m, k, n);
}

/// `C[m,n] += Aᵀ·B` with `A` STORED `[k,m]` row-major (i.e. the reduction
/// dimension is A's row index), `B` row-major `[k,n]` — the dW shape.
pub fn gemm_tn(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    gemm_blocked::<true>(c, a, b, m, k, n);
}

/// `C[m,n] += A·Bᵀ` with `B` STORED `[n,k]` row-major — the dX shape.
/// Implemented by packing `Bᵀ` into the caller-owned [`GemmScratch`] (so
/// the steady-state hot loop stays allocation-free) and running the `nn`
/// kernel; the pack is an exact element copy, so the reduction chain is
/// the `kk`-ascending one of the contract.
pub fn gemm_nt(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    scratch: &mut GemmScratch,
) {
    debug_assert_eq!(b.len(), n * k);
    pack_transpose(b, n, k, &mut scratch.bt);
    gemm_blocked::<false>(c, a, &scratch.bt, m, k, n);
}

/// Transpose row-major `src[rows, cols]` into `dst[cols, rows]`,
/// resizing `dst`. Reads are contiguous (row walk), writes strided —
/// the same pack the dense-backward Wᵀ cache always used.
pub fn pack_transpose(src: &[f32], rows: usize, cols: usize, dst: &mut Vec<f32>) {
    dst.clear();
    dst.resize(rows * cols, 0.0);
    pack_transpose_into(src, rows, cols, dst);
}

/// [`pack_transpose`] into a caller-sized slice — for packing several
/// transposed blocks into one scratch buffer (the Elman backward's
/// `Whᵀ | Wxᵀ` pack). Every element of `dst` is overwritten.
pub fn pack_transpose_into(src: &[f32], rows: usize, cols: usize, dst: &mut [f32]) {
    debug_assert_eq!(src.len(), rows * cols);
    debug_assert_eq!(dst.len(), rows * cols);
    for r in 0..rows {
        let srow = &src[r * cols..(r + 1) * cols];
        for (cc, &v) in srow.iter().enumerate() {
            dst[cc * rows + r] = v;
        }
    }
}

/// `dst[j] += Σ_r src[r, j]` over row-major `src[rows, cols]`, rows
/// ascending — the bias-gradient column sum.
pub fn col_sum_add(dst: &mut [f32], src: &[f32], rows: usize, cols: usize) {
    debug_assert_eq!(dst.len(), cols);
    debug_assert_eq!(src.len(), rows * cols);
    for r in 0..rows {
        let srow = &src[r * cols..(r + 1) * cols];
        for (d, &s) in dst.iter_mut().zip(srow.iter()) {
            *d += s;
        }
    }
}

/// Fixed-order reference implementation: the naive triple loop whose
/// per-element accumulation chain DEFINES the kernel contract (and which
/// matches the order the pre-kernel native backend accumulated in).
/// `ta`/`tb` select the transposed-storage variants of [`gemm_tn`] /
/// [`gemm_nt`]. Used by the conformance proptest and as the honest
/// "before" baseline of the `gemm_{naive,blocked}` bench family.
pub fn gemm_ref(
    c: &mut [f32],
    a: &[f32],
    ta: bool,
    b: &[f32],
    tb: bool,
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        for kk in 0..k {
            let av = if ta { a[kk * m + i] } else { a[i * k + kk] };
            let crow = &mut c[i * n..(i + 1) * n];
            if tb {
                for (j, o) in crow.iter_mut().enumerate() {
                    *o += av * b[j * k + kk];
                }
            } else {
                let brow = &b[kk * n..(kk + 1) * n];
                for (o, &bv) in crow.iter_mut().zip(brow.iter()) {
                    *o += av * bv;
                }
            }
        }
    }
}

/// The blocked core. `TA` selects A's storage: `false` = row-major
/// `[m,k]`, `true` = transposed storage `[k,m]`. `B` is always row-major
/// `[k,n]` and `C` row-major `[m,n]`.
///
/// The full-tile inner loop dispatches through the process-wide
/// [`simd::KernelSet`] (resolved once at startup, forceable via
/// `--isa`/`LAGS_ISA`); remainder rows/columns always run the scalar
/// sweeps below. Every dispatched tile body is bit-identical to
/// [`gemm_tile_scalar`], so the kernel's output is ISA-invariant.
fn gemm_blocked<const TA: bool>(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(c.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    #[inline(always)]
    fn a_at<const TA: bool>(a: &[f32], m: usize, k: usize, i: usize, kk: usize) -> f32 {
        if TA {
            a[kk * m + i]
        } else {
            a[i * k + kk]
        }
    }
    let ks = simd::active();
    let nr = ks.nr;
    let mut k0 = 0;
    while k0 < k {
        let kb = KC.min(k - k0);
        // full MR-row blocks through the register-tiled micro-kernel
        let m_main = m - m % MR;
        let mut i0 = 0;
        while i0 < m_main {
            // nr-column tiles: MR×nr accumulators seeded FROM C
            let mut j0 = 0;
            while j0 + nr <= n {
                ks.gemm_tile(
                    c,
                    &simd::GemmTile { a, b, m, k, n, i0, j0, k0, kb, ta: TA },
                );
                j0 += nr;
            }
            // column remainder: per-row axpy sweeps, kk ascending
            if j0 < n {
                for r in 0..MR {
                    let i = i0 + r;
                    for kk in k0..k0 + kb {
                        let av = a_at::<TA>(a, m, k, i, kk);
                        let crow = &mut c[i * n + j0..(i + 1) * n];
                        let brow = &b[kk * n + j0..(kk + 1) * n];
                        for (o, &bv) in crow.iter_mut().zip(brow.iter()) {
                            *o += av * bv;
                        }
                    }
                }
            }
            i0 += MR;
        }
        // row remainder: full-width axpy sweeps, kk ascending
        for i in m_main..m {
            for kk in k0..k0 + kb {
                let av = a_at::<TA>(a, m, k, i, kk);
                let crow = &mut c[i * n..(i + 1) * n];
                let brow = &b[kk * n..(kk + 1) * n];
                for (o, &bv) in crow.iter_mut().zip(brow.iter()) {
                    *o += av * bv;
                }
            }
        }
        k0 += kb;
    }
}

/// The PR-5 scalar register tile, verbatim — the bit-exactness reference
/// every [`simd`] tile body must match: an MR×[`NR`] accumulator tile
/// seeded FROM `C`, products added in strictly ascending `kk`, stored
/// back. The 8-wide unrolled inner loop vectorizes ACROSS output elements
/// (independent chains), never across the reduction dimension.
pub(crate) fn gemm_tile_scalar(c: &mut [f32], t: &simd::GemmTile<'_>) {
    let simd::GemmTile { a, b, m, k, n, i0, j0, k0, kb, ta } = *t;
    let mut acc = [[0.0f32; NR]; MR];
    for (r, arow) in acc.iter_mut().enumerate() {
        let crow = &c[(i0 + r) * n + j0..(i0 + r) * n + j0 + NR];
        arow.copy_from_slice(crow);
    }
    for kk in k0..k0 + kb {
        let brow = &b[kk * n + j0..kk * n + j0 + NR];
        for (r, arow) in acc.iter_mut().enumerate() {
            let av = if ta { a[kk * m + i0 + r] } else { a[(i0 + r) * k + kk] };
            for (o, &bv) in arow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
    for (r, arow) in acc.iter().enumerate() {
        let crow = &mut c[(i0 + r) * n + j0..(i0 + r) * n + j0 + NR];
        crow.copy_from_slice(arow);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// Every variant, at shapes that exercise full tiles, row/column
    /// remainders, M=1 GEMV rows and K crossing the KC block boundary,
    /// must be BIT-identical to the fixed-order reference — seeded from a
    /// non-zero C so the chain-seeding behaviour is covered too.
    #[test]
    fn blocked_matches_reference_bitwise() {
        let shapes = [
            (1usize, 1usize, 1usize),
            (4, 8, 8),      // exactly one MR×NR tile
            (5, 9, 11),     // remainders everywhere
            (1, 64, 64),    // the Elman GEMV shape
            (3, 7, 1),      // single output column
            (16, 300, 10),  // K crosses the KC=256 block boundary
            (7, 257, 17),
        ];
        for (si, &(m, k, n)) in shapes.iter().enumerate() {
            let mut rng = Rng::new(0xee_u64 ^ (si as u64) << 8);
            let a = randvec(&mut rng, m * k);
            let at = {
                let mut t = Vec::new();
                pack_transpose(&a, m, k, &mut t);
                t
            };
            let b = randvec(&mut rng, k * n);
            let bt = {
                let mut t = Vec::new();
                pack_transpose(&b, k, n, &mut t);
                t
            };
            let c0 = randvec(&mut rng, m * n);

            let mut want = c0.clone();
            gemm_ref(&mut want, &a, false, &b, false, m, k, n);

            let mut got = c0.clone();
            gemm_nn(&mut got, &a, &b, m, k, n);
            assert_eq!(bits(&got), bits(&want), "nn {m}x{k}x{n}");

            let mut got = c0.clone();
            gemm_tn(&mut got, &at, &b, m, k, n);
            assert_eq!(bits(&got), bits(&want), "tn {m}x{k}x{n}");

            let mut got = c0.clone();
            let mut scratch = GemmScratch::default();
            gemm_nt(&mut got, &a, &bt, m, k, n, &mut scratch);
            assert_eq!(bits(&got), bits(&want), "nt {m}x{k}x{n}");

            // the ref's own transpose flags agree with the packed forms
            let mut want_t = c0.clone();
            gemm_ref(&mut want_t, &at, true, &bt, true, m, k, n);
            assert_eq!(bits(&want_t), bits(&want), "ref flags {m}x{k}x{n}");
        }
    }

    #[test]
    fn degenerate_shapes_are_noops_or_empty() {
        // k = 0: nothing to accumulate, C untouched
        let mut c = vec![1.5f32, -2.5];
        gemm_nn(&mut c, &[], &[], 1, 0, 2);
        assert_eq!(c, vec![1.5, -2.5]);
        // m = 0 / n = 0: empty C
        let mut c: Vec<f32> = Vec::new();
        gemm_nn(&mut c, &[], &[1.0, 2.0], 0, 2, 0);
        assert!(c.is_empty());
    }

    #[test]
    fn gemm_small_known_values() {
        // [1 2; 3 4] · [5 6; 7 8] = [19 22; 43 50], on top of C = I
        let a = vec![1.0f32, 2.0, 3.0, 4.0];
        let b = vec![5.0f32, 6.0, 7.0, 8.0];
        let mut c = vec![1.0f32, 0.0, 0.0, 1.0];
        gemm_nn(&mut c, &a, &b, 2, 2, 2);
        assert_eq!(c, vec![20.0, 22.0, 43.0, 51.0]);
    }

    #[test]
    fn pack_transpose_round_trips() {
        let mut rng = Rng::new(9);
        let a = randvec(&mut rng, 5 * 7);
        let mut t = Vec::new();
        pack_transpose(&a, 5, 7, &mut t);
        assert_eq!(t.len(), 35);
        for r in 0..5 {
            for cc in 0..7 {
                assert_eq!(t[cc * 5 + r], a[r * 7 + cc]);
            }
        }
        let mut back = Vec::new();
        pack_transpose(&t, 7, 5, &mut back);
        assert_eq!(back, a);
    }

    #[test]
    fn col_sum_add_accumulates_rows_in_order() {
        let src = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // [3, 2]
        let mut dst = vec![10.0f32, 20.0];
        col_sum_add(&mut dst, &src, 3, 2);
        assert_eq!(dst, vec![19.0, 32.0]);
    }
}
