//! Measured device-flops calibration for the native backend.
//!
//! Eq. 18's comm-to-compute trade-off is only as honest as its compute
//! price. Before calibration existed, every native-backend budget was
//! priced at the hard-coded [`crate::models::DEVICE_FLOPS`] guess; this
//! module replaces the guess with a MEASURED number: a short
//! microbenchmark runs the blocked GEMM kernels (`runtime::kernels`) at
//! the model zoo's actual hot-loop shapes ([`super::native::NativeNet::
//! gemm_shapes`]), derives the machine's sustained f32 flops/s over that
//! shape mix, and persists it as JSON next to the artifacts so later runs
//! (and `lags ratios`) price Eq. 18 with it (DESIGN.md
//! §Kernels-and-calibration).
//!
//! Calibration is deliberately EXPLICIT: `lags calibrate` (or `lags train
//! --calibrate`) measures and persists; plain runs only LOAD a persisted
//! file. Measuring implicitly on every startup would make two separately
//! constructed trainers disagree on their Eq. 18 inputs whenever the
//! machine's load shifted between them — breaking the bit-identity
//! contracts the test suite holds the trainer to.

use super::kernels;
use super::native::NativeNet;
use super::Manifest;
use crate::util::clock;
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{ensure, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Duration;

/// Total measurement budget of a default calibration run. Split across
/// the deduped shape set; each shape also gets a minimum floor so tiny
/// GEMV shapes still collect a stable sample.
pub const DEFAULT_BUDGET: Duration = Duration::from_millis(240);

/// Minimum per-shape measurement window.
const MIN_SHAPE_WINDOW: Duration = Duration::from_millis(4);

/// One measured GEMM shape.
#[derive(Debug, Clone, PartialEq)]
pub struct ShapeSample {
    pub label: String,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// measured sustained throughput at this shape (flops/s)
    pub flops_per_sec: f64,
    /// aggregation weight: forward flops per training step this shape
    /// contributes, summed across the zoo models that execute it
    pub step_flops: f64,
}

/// A measured (or loaded) device-speed calibration.
#[derive(Debug, Clone, PartialEq)]
pub struct Calibration {
    /// sustained flops/s over the whole shape mix — the number that
    /// replaces `DEVICE_FLOPS` in `Runtime::device_flops`
    pub flops_per_sec: f64,
    /// the SIMD kernel ISA that was dispatched while measuring
    /// (`runtime::simd`); `"unrecorded"` for files persisted before the
    /// field existed. A calibration is only an honest compute price for
    /// runs dispatching the same ISA.
    pub isa: String,
    pub shapes: Vec<ShapeSample>,
    /// the file this calibration was loaded from (None = freshly
    /// measured, not yet persisted)
    pub source: Option<PathBuf>,
}

impl Calibration {
    /// Where a calibration for `artifacts_dir` lives: `calibration.json`
    /// inside a real artifacts directory, `lags_calibration.json` in the
    /// working directory for the built-in `"native"` zoo (which has no
    /// directory on disk).
    pub fn default_path(artifacts_dir: &Path) -> PathBuf {
        if artifacts_dir == Path::new("native") {
            PathBuf::from("lags_calibration.json")
        } else {
            artifacts_dir.join("calibration.json")
        }
    }

    /// Measure sustained flops at every hot-loop GEMM shape of the
    /// manifest's NativeNet-servable models (shapes deduped across
    /// models, per-step-flops weights summed), spreading `budget` across
    /// the shapes. The aggregate is the flops-WEIGHTED harmonic mean of
    /// the per-shape rates — the time to execute the zoo's actual
    /// per-step shape mix once, divided into its flops — so the big
    /// conv/dense mat-muls dominate the figure the way they dominate
    /// trainer time, and the tiny Elman GEMV rows don't drag it down.
    /// Errors if the manifest serves no native model at all.
    pub fn measure(man: &Manifest, budget: Duration) -> Result<Calibration> {
        // dedupe by (m, k, n); keep the first label, sum the weights
        let mut shapes: BTreeMap<(usize, usize, usize), (String, f64)> = BTreeMap::new();
        for mm in man.models.values() {
            let Ok(net) = NativeNet::from_manifest(mm) else { continue };
            for s in net.gemm_shapes() {
                let e = shapes
                    .entry((s.m, s.k, s.n))
                    .or_insert_with(|| (s.label.clone(), 0.0));
                e.1 += s.step_flops();
            }
        }
        ensure!(
            !shapes.is_empty(),
            "no native-servable model in {:?}: nothing to calibrate against",
            man.dir
        );
        let window = budget
            .div_f64(shapes.len() as f64)
            .max(MIN_SHAPE_WINDOW);
        let mut samples = Vec::with_capacity(shapes.len());
        // weighted harmonic mean: Σw / Σ(w / rate)
        let (mut wsum, mut wtime) = (0.0f64, 0.0f64);
        let mut rng = Rng::new(0xca11_b8a7e);
        for ((m, k, n), (label, weight)) in shapes {
            let (flops, secs) = time_shape(&mut rng, m, k, n, window);
            let rate = flops / secs;
            wsum += weight;
            wtime += weight / rate;
            samples.push(ShapeSample { label, m, k, n, flops_per_sec: rate, step_flops: weight });
        }
        Ok(Calibration {
            flops_per_sec: wsum / wtime,
            isa: super::simd::active().isa.name().to_string(),
            shapes: samples,
            source: None,
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("flops_per_sec", Json::Num(self.flops_per_sec)),
            ("isa", Json::Str(self.isa.clone())),
            (
                "shapes",
                Json::Arr(
                    self.shapes
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("label", Json::Str(s.label.clone())),
                                ("m", Json::Num(s.m as f64)),
                                ("k", Json::Num(s.k as f64)),
                                ("n", Json::Num(s.n as f64)),
                                ("flops_per_sec", Json::Num(s.flops_per_sec)),
                                ("step_flops", Json::Num(s.step_flops)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Calibration> {
        if let Some(ver) = v.opt("version") {
            let ver = ver.as_f64()?;
            ensure!(ver == 1.0, "unsupported calibration version {ver} (this build reads v1)");
        }
        let flops = v.get("flops_per_sec")?.as_f64()?;
        ensure!(
            flops.is_finite() && flops > 0.0,
            "calibration flops_per_sec must be positive, got {flops}"
        );
        // optional so pre-SIMD-tier v1 files keep loading
        let isa = match v.opt("isa") {
            Some(s) => s.as_str()?.to_string(),
            None => "unrecorded".to_string(),
        };
        let mut shapes = Vec::new();
        if let Some(arr) = v.opt("shapes") {
            for s in arr.as_arr()? {
                shapes.push(ShapeSample {
                    label: s.get("label")?.as_str()?.to_string(),
                    m: s.get("m")?.as_usize()?,
                    k: s.get("k")?.as_usize()?,
                    n: s.get("n")?.as_usize()?,
                    flops_per_sec: s.get("flops_per_sec")?.as_f64()?,
                    step_flops: s.get("step_flops")?.as_f64()?,
                });
            }
        }
        Ok(Calibration { flops_per_sec: flops, isa, shapes, source: None })
    }

    /// Load a persisted calibration; `Ok(None)` when the file doesn't
    /// exist, `Err` when it exists but doesn't parse (a corrupt file is
    /// an actionable problem, not a silent fallback).
    pub fn load(path: &Path) -> Result<Option<Calibration>> {
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading calibration {path:?}"))?;
        let mut cal = Calibration::from_json(&Json::parse(&text)?)
            .with_context(|| format!("parsing calibration {path:?}"))?;
        cal.source = Some(path.to_path_buf());
        Ok(Some(cal))
    }

    /// Persist to `path` (atomically — a crash mid-write must not leave a
    /// truncated calibration that poisons every later run) and record it
    /// as this calibration's source.
    pub fn save(&mut self, path: &Path) -> Result<()> {
        crate::util::json::write_atomic(path, self.to_json().to_string_pretty().as_bytes())
            .with_context(|| format!("writing calibration {path:?}"))?;
        self.source = Some(path.to_path_buf());
        Ok(())
    }
}

/// Time `gemm_nn` at one shape for at least `window`, returning (total
/// flops executed, elapsed seconds). Iteration counts double until the
/// window is filled, so tiny GEMV shapes get enough repetitions for the
/// timer's resolution while big shapes don't overshoot the budget.
fn time_shape(rng: &mut Rng, m: usize, k: usize, n: usize, window: Duration) -> (f64, f64) {
    let mut a = vec![0.0f32; m * k];
    let mut b = vec![0.0f32; k * n];
    let mut c = vec![0.0f32; m * n];
    rng.fill_normal(&mut a, 1.0);
    rng.fill_normal(&mut b, 1.0);
    let gemm_flops = 2.0 * m as f64 * k as f64 * n as f64;
    // warm-up (page in the buffers, settle the clock)
    kernels::gemm_nn(&mut c, &a, &b, m, k, n);
    let target = window.as_secs_f64();
    let mut iters = 1usize;
    loop {
        // C would drift toward huge magnitudes over many accumulating
        // iterations; re-zeroing outside the timed region keeps the
        // arithmetic in the normal f32 range without charging the memset
        c.iter_mut().for_each(|v| *v = 0.0);
        let t0 = clock::now();
        for _ in 0..iters {
            kernels::gemm_nn(&mut c, &a, &b, m, k, n);
        }
        let dt = t0.elapsed().as_secs_f64();
        std::hint::black_box(&c);
        if dt >= target || iters >= (1 << 24) {
            return (gemm_flops * iters as f64, dt.max(1e-9));
        }
        // scale straight to the target with headroom, at least doubling
        let scale = (target / dt.max(1e-9) * 1.25).max(2.0);
        iters = ((iters as f64 * scale) as usize).min(1 << 24);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::native_manifest;

    #[test]
    fn measure_native_zoo_yields_positive_flops() {
        let man = native_manifest(1);
        let cal = Calibration::measure(&man, Duration::from_millis(30)).unwrap();
        assert!(cal.flops_per_sec.is_finite() && cal.flops_per_sec > 0.0);
        assert!(!cal.shapes.is_empty());
        for s in &cal.shapes {
            assert!(s.flops_per_sec > 0.0, "{}: non-positive throughput", s.label);
            assert!(s.step_flops > 0.0, "{}: zero aggregation weight", s.label);
        }
        // the weighted harmonic mean lies within the per-shape rates
        let (lo, hi) = cal.shapes.iter().fold((f64::INFINITY, 0.0f64), |(lo, hi), s| {
            (lo.min(s.flops_per_sec), hi.max(s.flops_per_sec))
        });
        assert!(
            cal.flops_per_sec >= lo && cal.flops_per_sec <= hi,
            "aggregate {} outside per-shape range [{lo}, {hi}]",
            cal.flops_per_sec
        );
        assert!(cal.source.is_none(), "freshly measured, not loaded");
        // a fresh measurement records the ISA it actually dispatched
        assert_eq!(cal.isa, crate::runtime::simd::active().isa.name());
    }

    #[test]
    fn json_round_trip() {
        let cal = Calibration {
            flops_per_sec: 2.5e9,
            isa: "avx2".into(),
            shapes: vec![ShapeSample {
                label: "dense_32x64x10".into(),
                m: 32,
                k: 64,
                n: 10,
                flops_per_sec: 3.1e9,
                step_flops: 40960.0,
            }],
            source: None,
        };
        let back = Calibration::from_json(&Json::parse(&cal.to_json().to_string_compact()).unwrap())
            .unwrap();
        assert_eq!(back, cal);
        // a calibration claiming zero/negative speed is rejected
        assert!(Calibration::from_json(&Json::parse(r#"{"flops_per_sec": 0}"#).unwrap()).is_err());
        assert!(
            Calibration::from_json(&Json::parse(r#"{"flops_per_sec": -1e9}"#).unwrap()).is_err()
        );
        // a future-version file must refuse to load, not misprice Eq. 18
        assert!(Calibration::from_json(
            &Json::parse(r#"{"version": 2, "flops_per_sec": 1e9}"#).unwrap()
        )
        .is_err());
        // pre-SIMD-tier files (no "isa" key) still load, marked unrecorded
        let legacy =
            Calibration::from_json(&Json::parse(r#"{"flops_per_sec": 1e9}"#).unwrap()).unwrap();
        assert_eq!(legacy.isa, "unrecorded");
    }

    #[test]
    fn load_missing_file_is_none() {
        assert!(Calibration::load(Path::new("definitely/not/a/calibration.json"))
            .unwrap()
            .is_none());
    }

    #[test]
    fn default_paths() {
        assert_eq!(
            Calibration::default_path(Path::new("native")),
            PathBuf::from("lags_calibration.json")
        );
        assert_eq!(
            Calibration::default_path(Path::new("artifacts")),
            PathBuf::from("artifacts/calibration.json")
        );
    }
}
