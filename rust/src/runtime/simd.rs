//! Explicit SIMD kernel tier with runtime ISA dispatch (ROADMAP item,
//! DESIGN.md §SIMD dispatch).
//!
//! Three hot-path kernel families get hand-vectorized paths — the GEMM
//! register tile ([`super::kernels`]), the branchless TopK select
//! ([`crate::sparsify::topk`]) and the rank-ordered sparse reduction
//! ([`crate::collectives::sparse_agg`] via `SparseVec::add_into`) — behind
//! ONE dispatch decision resolved at startup into a [`KernelSet`]:
//!
//! * x86_64: AVX2 and AVX-512F via `std::arch` intrinsics, gated on
//!   `is_x86_feature_detected!`;
//! * aarch64: NEON (architecturally mandatory, so always available);
//! * everywhere: the PR-5 scalar kernels, kept verbatim as the
//!   bit-exactness reference.
//!
//! The dispatched ISA is overridable for testing and provenance with
//! `--isa {scalar,avx2,avx512,neon}` / `LAGS_ISA` — the forced-ISA CI
//! matrix re-runs the bit-identity suites under `LAGS_ISA=scalar` vs the
//! detected ISA to prove training is ISA-invariant end to end.
//!
//! ## Determinism contract (bit-identical to scalar, not per-ISA goldens)
//!
//! Every SIMD path must preserve the per-output-element `kk`-ascending f32
//! accumulation chain of [`super::kernels::gemm_ref`]. The vector paths
//! achieve this *lane-blocked*: lanes are always OUTPUT elements
//! (independent chains), never the reduction dimension, so no f32 sum is
//! ever reassociated; multiplies and adds are separate roundings
//! (`mul`+`add`, never FMA — scalar Rust f32 does not contract); the
//! column-tile width per ISA (NR = 8 scalar/AVX2/NEON, 16 AVX-512) is
//! free to differ because it only changes which independent chains run
//! together, never the order within one chain. TopK select and the sparse
//! reduction are pure per-element bit operations / single adds, so their
//! SIMD forms are trivially chain-preserving; NaN/±0 semantics are kept by
//! using sign-bit masking for `abs` and ordered-quiet (`GE_OQ` /
//! `vcgeq_f32`) compares — exactly the scalar `v.abs() >= thr`.
//!
//! ## Unsafe containment
//!
//! This module is the ONLY place in the crate allowed to use `unsafe`
//! (`#![deny(unsafe_code)]` at the crate root, `#![allow(unsafe_code)]`
//! here): each ISA×family pair is a private `#[target_feature]` `unsafe
//! fn` body plus a safe entry that asserts slice bounds before the single
//! `unsafe { .. }` call, and every `unsafe` token line carries a reasoned
//! `lags-audit` R4 waiver pinned by the audit self-test.

#![allow(unsafe_code)]

use crate::sparsify::{sparse, topk};
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU8, Ordering};

/// Register-tile rows of the GEMM micro-kernel — shared by every ISA (the
/// blocked driver in [`super::kernels`] walks row blocks of this height).
pub const MR: usize = 4;

/// An instruction-set tier the dispatcher can select.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// The PR-5 scalar kernels — the bit-exactness reference, always
    /// available.
    Scalar = 0,
    /// 8-lane f32 via `std::arch::x86_64` AVX2.
    Avx2 = 1,
    /// 16-lane f32 via `std::arch::x86_64` AVX-512F.
    Avx512 = 2,
    /// 4-lane f32 via `std::arch::aarch64` NEON (8-wide tiles as register
    /// pairs).
    Neon = 3,
}

impl Isa {
    /// The CLI / `LAGS_ISA` / calibration-provenance name.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Avx512 => "avx512",
            Isa::Neon => "neon",
        }
    }

    /// Parse a `--isa` / `LAGS_ISA` name.
    pub fn from_name(s: &str) -> Option<Isa> {
        match s {
            "scalar" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "avx512" => Some(Isa::Avx512),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> Option<Isa> {
        match v {
            0 => Some(Isa::Scalar),
            1 => Some(Isa::Avx2),
            2 => Some(Isa::Avx512),
            3 => Some(Isa::Neon),
            _ => None,
        }
    }

    /// Every ISA this hardware can run, weakest first (Scalar is always
    /// present; the strongest entry is what [`Isa::detect`] picks).
    pub fn available() -> Vec<Isa> {
        #[allow(unused_mut)]
        let mut isas = vec![Isa::Scalar];
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") {
                isas.push(Isa::Avx2);
            }
            if is_x86_feature_detected!("avx512f") {
                isas.push(Isa::Avx512);
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            // NEON is architecturally mandatory on aarch64
            isas.push(Isa::Neon);
        }
        isas
    }

    /// The strongest ISA this hardware supports.
    pub fn detect() -> Isa {
        *Isa::available().last().expect("Scalar is always available")
    }
}

/// The arguments of one GEMM register-tile update: accumulate
/// `C[i0..i0+MR, j0..j0+NR] += A[.., k0..k0+kb] · B[k0..k0+kb, ..]` with
/// the tile seeded FROM `C`. `ta` selects A's storage (`false` = row-major
/// `[m,k]`, `true` = transposed `[k,m]`); `B` is row-major `[k,n]`.
#[derive(Clone, Copy)]
pub struct GemmTile<'a> {
    pub a: &'a [f32],
    pub b: &'a [f32],
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub i0: usize,
    pub j0: usize,
    pub k0: usize,
    pub kb: usize,
    pub ta: bool,
}

/// The dispatch product: one function pointer per kernel family, resolved
/// once per process (or forced per test). Every member is bit-identical to
/// its scalar twin by the module contract, so swapping sets can never
/// change a result — only wall clock.
#[derive(Clone, Copy)]
pub struct KernelSet {
    pub isa: Isa,
    /// Column-tile width of this ISA's GEMM micro-kernel.
    pub nr: usize,
    tile: fn(&mut [f32], &GemmTile),
    mask: fn(&[f32], f32, &mut [f32]),
    split: fn(&[f32], f32, &mut [f32], &mut [f32]),
    spadd: fn(&[u32], &[f32], &mut [f32]),
}

impl KernelSet {
    /// The kernel set for one ISA. Panics if the ISA is not in
    /// [`Isa::available`] — constructing a set whose intrinsics the CPU
    /// lacks would be instant UB, so availability is the safety gate every
    /// `unsafe` entry below leans on.
    pub fn for_isa(isa: Isa) -> KernelSet {
        assert!(
            Isa::available().contains(&isa),
            "ISA {} is not available on this hardware",
            isa.name()
        );
        match isa {
            Isa::Scalar => KernelSet {
                isa,
                nr: super::kernels::NR,
                tile: super::kernels::gemm_tile_scalar,
                mask: topk::mask_with_threshold_scalar,
                split: topk::split_with_threshold_scalar,
                spadd: sparse::sparse_add_scalar,
            },
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => KernelSet {
                isa,
                nr: 8,
                tile: x86::gemm_tile_avx2,
                mask: x86::mask_avx2,
                split: x86::split_avx2,
                spadd: x86::sparse_add_avx2,
            },
            #[cfg(target_arch = "x86_64")]
            Isa::Avx512 => KernelSet {
                isa,
                nr: 16,
                tile: x86::gemm_tile_avx512,
                mask: x86::mask_avx512,
                split: x86::split_avx512,
                // scatter stores are scalar either way, so the 512-bit set
                // reuses the 256-bit gather path for the sparse reduction
                spadd: x86::sparse_add_avx2,
            },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => KernelSet {
                isa,
                nr: 8,
                tile: neon::gemm_tile_neon,
                mask: neon::mask_neon,
                split: neon::split_neon,
                // aarch64 has no gather: the scalar loop IS the fast path
                spadd: sparse::sparse_add_scalar,
            },
            #[cfg(not(target_arch = "x86_64"))]
            Isa::Avx2 | Isa::Avx512 => unreachable!("guarded by the availability assert"),
            #[cfg(not(target_arch = "aarch64"))]
            Isa::Neon => unreachable!("guarded by the availability assert"),
        }
    }

    /// One GEMM register-tile update (full MR rows, `nr` columns).
    #[inline]
    pub fn gemm_tile(&self, c: &mut [f32], t: &GemmTile<'_>) {
        (self.tile)(c, t)
    }

    /// `out_i = x_i if |x_i| >= thr else 0` (bit-preserving select).
    #[inline]
    pub fn mask_with_threshold(&self, x: &[f32], thr: f32, out: &mut [f32]) {
        (self.mask)(x, thr, out)
    }

    /// Split `x` at the threshold into kept + residual (kept ⊕ resid = x).
    #[inline]
    pub fn split_with_threshold(&self, x: &[f32], thr: f32, kept: &mut [f32], resid: &mut [f32]) {
        (self.split)(x, thr, kept, resid)
    }

    /// `out[idx[i]] += val[i]` for one sparse message (indices strictly
    /// increasing).
    #[inline]
    pub fn sparse_add(&self, idx: &[u32], val: &[f32], out: &mut [f32]) {
        (self.spadd)(idx, val, out)
    }
}

/// Sentinel: dispatch not yet resolved.
const ISA_UNRESOLVED: u8 = u8::MAX;

/// The process-wide dispatch decision. An `AtomicU8` (not a `OnceLock`) so
/// the forced-ISA tests and `--isa` can re-point it; every ISA is
/// bit-identical, so a benign resolve race cannot change results.
static ACTIVE: AtomicU8 = AtomicU8::new(ISA_UNRESOLVED);

/// The ISA the process dispatches to (resolving `LAGS_ISA` / hardware
/// detection on first use).
pub fn active_isa() -> Isa {
    match Isa::from_u8(ACTIVE.load(Ordering::Relaxed)) {
        Some(isa) => isa,
        None => {
            let isa = resolve_startup_isa();
            ACTIVE.store(isa as u8, Ordering::Relaxed);
            isa
        }
    }
}

/// The active [`KernelSet`] — what the kernel call sites dispatch through.
#[inline]
pub fn active() -> KernelSet {
    KernelSet::for_isa(active_isa())
}

/// Force the dispatched ISA (the `--isa` flag and the forced-ISA test
/// matrix). Fails if the hardware cannot run it.
pub fn set_active(isa: Isa) -> Result<()> {
    if !Isa::available().contains(&isa) {
        bail!(
            "ISA {} is not available on this hardware (available: {})",
            isa.name(),
            Isa::available().iter().map(|i| i.name()).collect::<Vec<_>>().join(", ")
        );
    }
    ACTIVE.store(isa as u8, Ordering::Relaxed);
    Ok(())
}

/// First-use resolution: the `LAGS_ISA` override if set (unknown or
/// unavailable names abort — a forced-ISA CI run must never silently fall
/// back), else hardware detection.
#[allow(clippy::disallowed_methods)] // LAGS_ISA is the documented forced-ISA override; read once, before any kernel dispatch
fn resolve_startup_isa() -> Isa {
    // lags-audit: allow(R2) reason="forced-ISA test override read once at first dispatch; every ISA is bit-identical by the module contract, so the env can only select which proof path runs, never change a result"
    match std::env::var("LAGS_ISA") {
        Err(_) => Isa::detect(),
        Ok(name) => {
            let isa = Isa::from_name(name.trim()).unwrap_or_else(|| {
                panic!("LAGS_ISA={name:?} is not one of scalar/avx2/avx512/neon")
            });
            assert!(
                Isa::available().contains(&isa),
                "LAGS_ISA={} is not available on this hardware",
                isa.name()
            );
            isa
        }
    }
}

/// Shared bounds gate for the SIMD gemm-tile entries: with this true,
/// every raw load/store of a tile body stays inside `c` / `t.b` (loads
/// from `t.a` use safe indexing and need no gate).
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
fn tile_in_bounds(c: &[f32], t: &GemmTile<'_>, nr: usize) -> bool {
    t.j0 + nr <= t.n && c.len() >= (t.i0 + MR) * t.n && t.b.len() >= (t.k0 + t.kb) * t.n
}

// ---------------------------------------------------------------------------
// x86_64: AVX2 + AVX-512F
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{tile_in_bounds, GemmTile, MR};
    use crate::sparsify::{sparse, topk};
    use std::arch::x86_64::*;

    /// |v| as a bit pattern: clear the sign bit, preserve NaN payloads —
    /// the vector twin of `f32::abs`.
    const ABS_BITS: u32 = 0x7fff_ffff;

    pub(super) fn gemm_tile_avx2(c: &mut [f32], t: &GemmTile<'_>) {
        assert!(tile_in_bounds(c, t, 8), "gemm tile out of bounds");
        // SAFETY: this entry is only reachable through a KernelSet built
        // after `is_x86_feature_detected!("avx2")`; the assert above
        // bounds every raw load/store inside `c` / `t.b`.
        // lags-audit: allow(R4) reason="single dispatch into the avx2 tile body; CPU feature gated at KernelSet construction and slice bounds asserted on entry"
        unsafe { gemm_tile_avx2_impl(c, t) }
    }

    #[target_feature(enable = "avx2")]
    // lags-audit: allow(R4) reason="std::arch avx2 intrinsics are unsafe fns; pointers stay inside the entry-asserted c/b ranges and the reduction keeps the scalar kk-ascending chain (mul+add, lanes = output columns)"
    unsafe fn gemm_tile_avx2_impl(c: &mut [f32], t: &GemmTile<'_>) {
        let GemmTile { a, b, m, k, n, i0, j0, k0, kb, ta } = *t;
        // one 8-lane accumulator per tile row, seeded FROM C — lanes are
        // output columns (independent chains), never the reduction dim
        let mut acc = [_mm256_setzero_ps(); MR];
        for (r, accr) in acc.iter_mut().enumerate() {
            *accr = _mm256_loadu_ps(c.as_ptr().add((i0 + r) * n + j0));
        }
        for kk in k0..k0 + kb {
            let bv = _mm256_loadu_ps(b.as_ptr().add(kk * n + j0));
            for (r, accr) in acc.iter_mut().enumerate() {
                let av = if ta { a[kk * m + i0 + r] } else { a[(i0 + r) * k + kk] };
                // mul then add, never FMA: scalar f32 does not contract,
                // so the SIMD chain must round after the multiply too
                *accr = _mm256_add_ps(*accr, _mm256_mul_ps(_mm256_set1_ps(av), bv));
            }
        }
        for (r, accr) in acc.iter().enumerate() {
            _mm256_storeu_ps(c.as_mut_ptr().add((i0 + r) * n + j0), *accr);
        }
    }

    pub(super) fn gemm_tile_avx512(c: &mut [f32], t: &GemmTile<'_>) {
        assert!(tile_in_bounds(c, t, 16), "gemm tile out of bounds");
        // SAFETY: as for avx2 — avx512f gated at KernelSet construction,
        // bounds asserted above.
        // lags-audit: allow(R4) reason="single dispatch into the avx512 tile body; CPU feature gated at KernelSet construction and slice bounds asserted on entry"
        unsafe { gemm_tile_avx512_impl(c, t) }
    }

    #[target_feature(enable = "avx512f")]
    // lags-audit: allow(R4) reason="std::arch avx512f intrinsics are unsafe fns; pointers stay inside the entry-asserted c/b ranges and the reduction keeps the scalar kk-ascending chain (mul+add, lanes = output columns)"
    unsafe fn gemm_tile_avx512_impl(c: &mut [f32], t: &GemmTile<'_>) {
        let GemmTile { a, b, m, k, n, i0, j0, k0, kb, ta } = *t;
        let mut acc = [_mm512_setzero_ps(); MR];
        for (r, accr) in acc.iter_mut().enumerate() {
            *accr = _mm512_loadu_ps(c.as_ptr().add((i0 + r) * n + j0));
        }
        for kk in k0..k0 + kb {
            let bv = _mm512_loadu_ps(b.as_ptr().add(kk * n + j0));
            for (r, accr) in acc.iter_mut().enumerate() {
                let av = if ta { a[kk * m + i0 + r] } else { a[(i0 + r) * k + kk] };
                *accr = _mm512_add_ps(*accr, _mm512_mul_ps(_mm512_set1_ps(av), bv));
            }
        }
        for (r, accr) in acc.iter().enumerate() {
            _mm512_storeu_ps(c.as_mut_ptr().add((i0 + r) * n + j0), *accr);
        }
    }

    pub(super) fn mask_avx2(x: &[f32], thr: f32, out: &mut [f32]) {
        assert_eq!(x.len(), out.len());
        // SAFETY: avx2 gated at KernelSet construction; the vector loop
        // stays below `x.len()`, which equals `out.len()` by the assert.
        // lags-audit: allow(R4) reason="single dispatch into the avx2 mask body; CPU feature gated at KernelSet construction and equal slice lengths asserted on entry"
        unsafe { mask_avx2_impl(x, thr, out) }
    }

    #[target_feature(enable = "avx2")]
    // lags-audit: allow(R4) reason="std::arch avx2 intrinsics are unsafe fns; loads/stores bounded by the 8-lane loop guard, select is the scalar bitmask semantics (sign-bit abs + GE_OQ compare)"
    unsafe fn mask_avx2_impl(x: &[f32], thr: f32, out: &mut [f32]) {
        let n = x.len();
        let absmask = _mm256_set1_ps(f32::from_bits(ABS_BITS));
        let thrv = _mm256_set1_ps(thr);
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(x.as_ptr().add(i));
            // ordered-quiet >=: NaN lanes (in v or thr) select 0, exactly
            // the scalar `v.abs() >= thr`
            let keep = _mm256_cmp_ps::<_CMP_GE_OQ>(_mm256_and_ps(v, absmask), thrv);
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_and_ps(v, keep));
            i += 8;
        }
        // lane tail: the scalar reference on the remainder
        topk::mask_with_threshold_scalar(&x[i..], thr, &mut out[i..]);
    }

    pub(super) fn mask_avx512(x: &[f32], thr: f32, out: &mut [f32]) {
        assert_eq!(x.len(), out.len());
        // SAFETY: as for avx2 (avx512f gate + equal lengths).
        // lags-audit: allow(R4) reason="single dispatch into the avx512 mask body; CPU feature gated at KernelSet construction and equal slice lengths asserted on entry"
        unsafe { mask_avx512_impl(x, thr, out) }
    }

    #[target_feature(enable = "avx512f")]
    // lags-audit: allow(R4) reason="std::arch avx512f intrinsics are unsafe fns; loads/stores bounded by the 16-lane loop guard, maskz_mov preserves the scalar bitmask-select semantics"
    unsafe fn mask_avx512_impl(x: &[f32], thr: f32, out: &mut [f32]) {
        let n = x.len();
        let absmask = _mm512_set1_epi32(ABS_BITS as i32);
        let thrv = _mm512_set1_ps(thr);
        let mut i = 0;
        while i + 16 <= n {
            let v = _mm512_loadu_ps(x.as_ptr().add(i));
            let abs = _mm512_castsi512_ps(_mm512_and_si512(_mm512_castps_si512(v), absmask));
            let keep = _mm512_cmp_ps_mask::<_CMP_GE_OQ>(abs, thrv);
            // zero-masked move: kept lanes keep their exact bits (NaN
            // payloads, -0.0), dropped lanes become the literal +0.0
            _mm512_storeu_ps(out.as_mut_ptr().add(i), _mm512_maskz_mov_ps(keep, v));
            i += 16;
        }
        topk::mask_with_threshold_scalar(&x[i..], thr, &mut out[i..]);
    }

    pub(super) fn split_avx2(x: &[f32], thr: f32, kept: &mut [f32], resid: &mut [f32]) {
        assert!(x.len() == kept.len() && x.len() == resid.len());
        // SAFETY: avx2 gated at KernelSet construction; all three slices
        // have the asserted equal length bounding the vector loop.
        // lags-audit: allow(R4) reason="single dispatch into the avx2 split body; CPU feature gated at KernelSet construction and equal slice lengths asserted on entry"
        unsafe { split_avx2_impl(x, thr, kept, resid) }
    }

    #[target_feature(enable = "avx2")]
    // lags-audit: allow(R4) reason="std::arch avx2 intrinsics are unsafe fns; loads/stores bounded by the 8-lane loop guard, kept/resid are the complementary scalar bitmask selects"
    unsafe fn split_avx2_impl(x: &[f32], thr: f32, kept: &mut [f32], resid: &mut [f32]) {
        let n = x.len();
        let absmask = _mm256_set1_ps(f32::from_bits(ABS_BITS));
        let thrv = _mm256_set1_ps(thr);
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(x.as_ptr().add(i));
            let keep = _mm256_cmp_ps::<_CMP_GE_OQ>(_mm256_and_ps(v, absmask), thrv);
            _mm256_storeu_ps(kept.as_mut_ptr().add(i), _mm256_and_ps(v, keep));
            // andnot(keep, v) = !keep & v — the scalar `bits & !m`
            _mm256_storeu_ps(resid.as_mut_ptr().add(i), _mm256_andnot_ps(keep, v));
            i += 8;
        }
        topk::split_with_threshold_scalar(&x[i..], thr, &mut kept[i..], &mut resid[i..]);
    }

    pub(super) fn split_avx512(x: &[f32], thr: f32, kept: &mut [f32], resid: &mut [f32]) {
        assert!(x.len() == kept.len() && x.len() == resid.len());
        // SAFETY: as for avx2 (avx512f gate + equal lengths).
        // lags-audit: allow(R4) reason="single dispatch into the avx512 split body; CPU feature gated at KernelSet construction and equal slice lengths asserted on entry"
        unsafe { split_avx512_impl(x, thr, kept, resid) }
    }

    #[target_feature(enable = "avx512f")]
    // lags-audit: allow(R4) reason="std::arch avx512f intrinsics are unsafe fns; loads/stores bounded by the 16-lane loop guard, complementary maskz_mov pair preserves kept+resid == x bitwise"
    unsafe fn split_avx512_impl(x: &[f32], thr: f32, kept: &mut [f32], resid: &mut [f32]) {
        let n = x.len();
        let absmask = _mm512_set1_epi32(ABS_BITS as i32);
        let thrv = _mm512_set1_ps(thr);
        let mut i = 0;
        while i + 16 <= n {
            let v = _mm512_loadu_ps(x.as_ptr().add(i));
            let abs = _mm512_castsi512_ps(_mm512_and_si512(_mm512_castps_si512(v), absmask));
            let keep = _mm512_cmp_ps_mask::<_CMP_GE_OQ>(abs, thrv);
            _mm512_storeu_ps(kept.as_mut_ptr().add(i), _mm512_maskz_mov_ps(keep, v));
            _mm512_storeu_ps(resid.as_mut_ptr().add(i), _mm512_maskz_mov_ps(!keep, v));
            i += 16;
        }
        topk::split_with_threshold_scalar(&x[i..], thr, &mut kept[i..], &mut resid[i..]);
    }

    pub(super) fn sparse_add_avx2(idx: &[u32], val: &[f32], out: &mut [f32]) {
        assert_eq!(idx.len(), val.len());
        // the gather reads out[idx[l]] BEFORE any per-lane bounds panic
        // could fire, so every index must be proven in-bounds up front
        // (the scan autovectorizes; O(nnz) over u32 is noise next to the
        // gather+add it guards)
        if let Some(maxi) = idx.iter().copied().max() {
            assert!(
                (maxi as usize) < out.len(),
                "sparse index {maxi} out of bounds for dense len {}",
                out.len()
            );
        }
        debug_assert!(
            idx.windows(2).all(|w| w[0] < w[1]),
            "SparseVec indices must be strictly increasing"
        );
        if out.len() > i32::MAX as usize {
            // i32 gather offsets can't span it; the scalar loop can
            sparse::sparse_add_scalar(idx, val, out);
            return;
        }
        // SAFETY: avx2 gated at KernelSet construction; every gather lane
        // was bounds-proven above and fits an i32 offset.
        // lags-audit: allow(R4) reason="single dispatch into the avx2 gather body; CPU feature gated at KernelSet construction, every index bounds-proven on entry and within i32 offset range"
        unsafe { sparse_add_avx2_impl(idx, val, out) }
    }

    #[target_feature(enable = "avx2")]
    // lags-audit: allow(R4) reason="std::arch avx2 gather is an unsafe fn; lanes read bounds-proven indices, and strictly-increasing indices mean lanes never alias, so each output gets exactly the scalar single add"
    unsafe fn sparse_add_avx2_impl(idx: &[u32], val: &[f32], out: &mut [f32]) {
        let n = idx.len();
        let mut i = 0;
        while i + 8 <= n {
            let iv = _mm256_loadu_si256(idx.as_ptr().add(i) as *const __m256i);
            let gathered = _mm256_i32gather_ps::<4>(out.as_ptr(), iv);
            let sum = _mm256_add_ps(gathered, _mm256_loadu_ps(val.as_ptr().add(i)));
            let mut lanes = [0.0f32; 8];
            _mm256_storeu_ps(lanes.as_mut_ptr(), sum);
            // AVX2 has no scatter: ascending per-lane stores (indices are
            // strictly increasing, so lanes never alias within a chunk)
            for (l, &s) in lanes.iter().enumerate() {
                out[idx[i + l] as usize] = s;
            }
            i += 8;
        }
        sparse::sparse_add_scalar(&idx[i..], &val[i..], out);
    }
}

// ---------------------------------------------------------------------------
// aarch64: NEON
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{tile_in_bounds, GemmTile, MR};
    use crate::sparsify::topk;
    use std::arch::aarch64::*;

    pub(super) fn gemm_tile_neon(c: &mut [f32], t: &GemmTile<'_>) {
        assert!(tile_in_bounds(c, t, 8), "gemm tile out of bounds");
        // SAFETY: NEON is architecturally mandatory on aarch64; the
        // assert above bounds every raw load/store inside `c` / `t.b`.
        // lags-audit: allow(R4) reason="single dispatch into the neon tile body; NEON is mandatory on aarch64 and slice bounds are asserted on entry"
        unsafe { gemm_tile_neon_impl(c, t) }
    }

    #[target_feature(enable = "neon")]
    // lags-audit: allow(R4) reason="std::arch neon intrinsics are unsafe fns; pointers stay inside the entry-asserted c/b ranges and the reduction keeps the scalar kk-ascending chain (vmul+vadd, lanes = output columns)"
    unsafe fn gemm_tile_neon_impl(c: &mut [f32], t: &GemmTile<'_>) {
        let GemmTile { a, b, m, k, n, i0, j0, k0, kb, ta } = *t;
        // 8-wide tile rows as register pairs of 4 lanes each
        let mut acc = [[vdupq_n_f32(0.0); 2]; MR];
        for (r, arow) in acc.iter_mut().enumerate() {
            let base = (i0 + r) * n + j0;
            arow[0] = vld1q_f32(c.as_ptr().add(base));
            arow[1] = vld1q_f32(c.as_ptr().add(base + 4));
        }
        for kk in k0..k0 + kb {
            let b0 = vld1q_f32(b.as_ptr().add(kk * n + j0));
            let b1 = vld1q_f32(b.as_ptr().add(kk * n + j0 + 4));
            for (r, arow) in acc.iter_mut().enumerate() {
                let av = if ta { a[kk * m + i0 + r] } else { a[(i0 + r) * k + kk] };
                let avv = vdupq_n_f32(av);
                // vmul + vadd, never vfma: match the scalar rounding chain
                arow[0] = vaddq_f32(arow[0], vmulq_f32(avv, b0));
                arow[1] = vaddq_f32(arow[1], vmulq_f32(avv, b1));
            }
        }
        for (r, arow) in acc.iter().enumerate() {
            let base = (i0 + r) * n + j0;
            vst1q_f32(c.as_mut_ptr().add(base), arow[0]);
            vst1q_f32(c.as_mut_ptr().add(base + 4), arow[1]);
        }
    }

    pub(super) fn mask_neon(x: &[f32], thr: f32, out: &mut [f32]) {
        assert_eq!(x.len(), out.len());
        // SAFETY: NEON mandatory on aarch64; equal lengths asserted bound
        // the 4-lane loop.
        // lags-audit: allow(R4) reason="single dispatch into the neon mask body; NEON is mandatory on aarch64 and equal slice lengths are asserted on entry"
        unsafe { mask_neon_impl(x, thr, out) }
    }

    #[target_feature(enable = "neon")]
    // lags-audit: allow(R4) reason="std::arch neon intrinsics are unsafe fns; loads/stores bounded by the 4-lane loop guard, vcge+vand is the scalar bitmask select (NaN compares false)"
    unsafe fn mask_neon_impl(x: &[f32], thr: f32, out: &mut [f32]) {
        let n = x.len();
        let thrv = vdupq_n_f32(thr);
        let mut i = 0;
        while i + 4 <= n {
            let v = vld1q_f32(x.as_ptr().add(i));
            let keep = vcgeq_f32(vabsq_f32(v), thrv);
            let bits = vandq_u32(vreinterpretq_u32_f32(v), keep);
            vst1q_f32(out.as_mut_ptr().add(i), vreinterpretq_f32_u32(bits));
            i += 4;
        }
        topk::mask_with_threshold_scalar(&x[i..], thr, &mut out[i..]);
    }

    pub(super) fn split_neon(x: &[f32], thr: f32, kept: &mut [f32], resid: &mut [f32]) {
        assert!(x.len() == kept.len() && x.len() == resid.len());
        // SAFETY: NEON mandatory on aarch64; equal lengths asserted bound
        // the 4-lane loop.
        // lags-audit: allow(R4) reason="single dispatch into the neon split body; NEON is mandatory on aarch64 and equal slice lengths are asserted on entry"
        unsafe { split_neon_impl(x, thr, kept, resid) }
    }

    #[target_feature(enable = "neon")]
    // lags-audit: allow(R4) reason="std::arch neon intrinsics are unsafe fns; loads/stores bounded by the 4-lane loop guard, vand/vbic are the complementary scalar bitmask selects"
    unsafe fn split_neon_impl(x: &[f32], thr: f32, kept: &mut [f32], resid: &mut [f32]) {
        let n = x.len();
        let thrv = vdupq_n_f32(thr);
        let mut i = 0;
        while i + 4 <= n {
            let v = vld1q_f32(x.as_ptr().add(i));
            let keep = vcgeq_f32(vabsq_f32(v), thrv);
            let vbits = vreinterpretq_u32_f32(v);
            vst1q_f32(kept.as_mut_ptr().add(i), vreinterpretq_f32_u32(vandq_u32(vbits, keep)));
            // vbic(a, b) = a & !b — the scalar `bits & !m`
            vst1q_f32(resid.as_mut_ptr().add(i), vreinterpretq_f32_u32(vbicq_u32(vbits, keep)));
            i += 4;
        }
        topk::split_with_threshold_scalar(&x[i..], thr, &mut kept[i..], &mut resid[i..]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn isa_names_round_trip() {
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Avx512, Isa::Neon] {
            assert_eq!(Isa::from_name(isa.name()), Some(isa));
            assert_eq!(Isa::from_u8(isa as u8), Some(isa));
        }
        assert_eq!(Isa::from_name("sse9"), None);
        assert_eq!(Isa::from_u8(200), None);
    }

    #[test]
    fn scalar_is_always_available_and_detect_is_strongest() {
        let av = Isa::available();
        assert_eq!(av[0], Isa::Scalar);
        assert!(av.contains(&Isa::detect()));
        assert_eq!(*av.last().unwrap(), Isa::detect());
    }

    #[test]
    fn for_isa_builds_every_available_set() {
        for isa in Isa::available() {
            let ks = KernelSet::for_isa(isa);
            assert_eq!(ks.isa, isa);
            assert!(ks.nr == 8 || ks.nr == 16);
        }
        assert_eq!(KernelSet::for_isa(Isa::Scalar).nr, 8);
    }

    #[test]
    fn set_active_rejects_nothing_available_and_accepts_detected() {
        // what detect() picked must be settable; scalar always is
        set_active(Isa::detect()).unwrap();
        set_active(Isa::Scalar).unwrap();
        assert_eq!(active_isa(), Isa::Scalar);
        assert_eq!(active().isa, Isa::Scalar);
        set_active(Isa::detect()).unwrap();
    }

    /// Every available ISA's mask/split/sparse_add must match the scalar
    /// set bitwise, across lane tails and IEEE specials. (The GEMM tile is
    /// covered end-to-end by the kernels unit tests, the conformance
    /// proptest and the forced-ISA integration suite.)
    #[test]
    fn select_and_sparse_add_bit_identical_across_isas() {
        let scalar = KernelSet::for_isa(Isa::Scalar);
        for isa in Isa::available() {
            let ks = KernelSet::for_isa(isa);
            for n in [0usize, 1, 3, 4, 7, 8, 9, 15, 16, 17, 31, 33, 250] {
                let mut rng = Rng::new(7 + n as u64);
                let mut x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
                if n >= 4 {
                    x[0] = f32::NAN;
                    x[1] = f32::INFINITY;
                    x[2] = -0.0;
                    x[3] = 0.0;
                }
                for thr in [0.0f32, 0.5, f32::INFINITY, f32::NAN] {
                    let (mut m0, mut m1) = (vec![9.0f32; n], vec![9.0f32; n]);
                    scalar.mask_with_threshold(&x, thr, &mut m0);
                    ks.mask_with_threshold(&x, thr, &mut m1);
                    let (mut k0, mut r0) = (vec![9.0f32; n], vec![9.0f32; n]);
                    let (mut k1, mut r1) = (vec![9.0f32; n], vec![9.0f32; n]);
                    scalar.split_with_threshold(&x, thr, &mut k0, &mut r0);
                    ks.split_with_threshold(&x, thr, &mut k1, &mut r1);
                    for i in 0..n {
                        assert_eq!(m0[i].to_bits(), m1[i].to_bits(), "{} mask n={n} i={i}", isa.name());
                        assert_eq!(k0[i].to_bits(), k1[i].to_bits(), "{} kept n={n} i={i}", isa.name());
                        assert_eq!(r0[i].to_bits(), r1[i].to_bits(), "{} resid n={n} i={i}", isa.name());
                    }
                }
                // sparse add: strictly-increasing indices over a dense out
                let dense = 4 * n + 16;
                let idx: Vec<u32> = (0..n).map(|j| (j * 4 + 1) as u32).collect();
                let val: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
                let mut o0: Vec<f32> = (0..dense).map(|_| rng.normal_f32()).collect();
                let mut o1 = o0.clone();
                scalar.sparse_add(&idx, &val, &mut o0);
                ks.sparse_add(&idx, &val, &mut o1);
                for i in 0..dense {
                    assert_eq!(o0[i].to_bits(), o1[i].to_bits(), "{} spadd n={n} i={i}", isa.name());
                }
            }
        }
    }
}
