//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1 CPU):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. One compiled executable per artifact;
//! compress-bucket executables are compiled lazily and cached.
//!
//! All artifacts were lowered with `return_tuple=True`, so every execution
//! returns a single tuple literal that is decomposed here.

pub mod manifest;

pub use manifest::{BatchSpec, DType, LayerInfo, Manifest, Metric, ModelManifest};

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::sync::Mutex;

/// A batch tensor crossing into PJRT.
#[derive(Debug, Clone, PartialEq)]
pub enum BatchData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl BatchData {
    pub fn len(&self) -> usize {
        match self {
            BatchData::F32(v) => v.len(),
            BatchData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn to_literal(&self, shape: &[usize]) -> Result<xla::Literal> {
        let dims: Vec<i64> = shape.iter().map(|&s| s as i64).collect();
        let lit = match self {
            BatchData::F32(v) => xla::Literal::vec1(v),
            BatchData::I32(v) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }

    fn to_device(&self, client: &xla::PjRtClient, shape: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(match self {
            BatchData::F32(v) => client.buffer_from_host_buffer(v, shape, None)?,
            BatchData::I32(v) => client.buffer_from_host_buffer(v, shape, None)?,
        })
    }
}

/// Shared PJRT client + manifest; the factory for [`ModelRuntime`]s.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    /// (bucket, sampled) -> compiled compress executable
    compress_cache: Mutex<BTreeMap<(usize, bool), std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    pub fn load(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, compress_cache: Mutex::new(BTreeMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile_file(&self, file: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.manifest.artifact_path(file);
        let path_str = path.to_str().context("non-utf8 path")?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).with_context(|| format!("compiling {file}"))
    }

    /// Build the full runtime for one model (train + eval + apply compiled
    /// eagerly; compress buckets lazily via [`Runtime::compress_exe`]).
    pub fn model_runtime(self: &std::sync::Arc<Self>, name: &str) -> Result<ModelRuntime> {
        let mm = self.manifest.model(name)?.clone();
        let train = self.compile_file(mm.file("train")?)?;
        let eval = self.compile_file(mm.file("eval")?)?;
        let apply = self.compile_file(mm.file("apply")?)?;
        let init_params = self.manifest.load_init_params(&mm)?;
        Ok(ModelRuntime { rt: self.clone(), mm, train, eval, apply, init_params })
    }

    /// Lazily compile + cache the compress executable for a bucket.
    pub fn compress_exe(
        &self,
        bucket: usize,
        sampled: bool,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        {
            let cache = self.compress_cache.lock().unwrap();
            if let Some(e) = cache.get(&(bucket, sampled)) {
                return Ok(e.clone());
            }
        }
        let (exact_f, sampled_f) = self
            .manifest
            .compress_files
            .get(&bucket)
            .with_context(|| format!("no compress artifact for bucket {bucket}"))?;
        let file = if sampled { sampled_f } else { exact_f };
        let exe = std::sync::Arc::new(self.compile_file(file)?);
        self.compress_cache.lock().unwrap().insert((bucket, sampled), exe.clone());
        Ok(exe)
    }

    /// Run a compress artifact: (grad[n], resid[n], lr, k) -> (sparse,
    /// resid', thr). Inputs must already be padded to the bucket length.
    pub fn run_compress(
        &self,
        bucket: usize,
        sampled: bool,
        grad: &[f32],
        resid: &[f32],
        lr: f32,
        k: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        anyhow::ensure!(grad.len() == bucket && resid.len() == bucket, "pad to bucket first");
        let exe = self.compress_exe(bucket, sampled)?;
        let g = xla::Literal::vec1(grad);
        let r = xla::Literal::vec1(resid);
        let lr_l = xla::Literal::scalar(lr);
        let k_l = xla::Literal::scalar(k as i32);
        let result = exe.execute::<xla::Literal>(&[g, r, lr_l, k_l])?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        anyhow::ensure!(parts.len() == 3, "compress artifact returned {} outputs", parts.len());
        let sparse = parts[0].to_vec::<f32>()?;
        let new_resid = parts[1].to_vec::<f32>()?;
        let thr = parts[2].to_vec::<f32>()?[0];
        Ok((sparse, new_resid, thr))
    }
}

/// Compiled executables + metadata for one model.
pub struct ModelRuntime {
    rt: std::sync::Arc<Runtime>,
    pub mm: ModelManifest,
    train: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
    apply: xla::PjRtLoadedExecutable,
    pub init_params: Vec<f32>,
}

impl ModelRuntime {
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    fn exec_step(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        params: &[f32],
        x: &BatchData,
        y: &BatchData,
    ) -> Result<(f32, xla::Literal)> {
        anyhow::ensure!(params.len() == self.mm.d, "params dim mismatch");
        anyhow::ensure!(x.len() == self.mm.x.elements(), "x batch shape mismatch");
        anyhow::ensure!(y.len() == self.mm.y.elements(), "y batch shape mismatch");
        let p = xla::Literal::vec1(params);
        let xl = x.to_literal(&self.mm.x.shape)?;
        let yl = y.to_literal(&self.mm.y.shape)?;
        let result = exe.execute::<xla::Literal>(&[p, xl, yl])?[0][0].to_literal_sync()?;
        let (loss_l, second) = result.to_tuple2()?;
        let loss = loss_l.to_vec::<f32>()?[0];
        Ok((loss, second))
    }

    /// Run the train artifact: returns (loss, flat gradient[d]).
    pub fn train_step(
        &self,
        params: &[f32],
        x: &BatchData,
        y: &BatchData,
    ) -> Result<(f32, Vec<f32>)> {
        let (loss, grad_l) = self.exec_step(&self.train, params, x, y)?;
        let grad = grad_l.to_vec::<f32>()?;
        anyhow::ensure!(grad.len() == self.mm.d, "grad dim mismatch");
        Ok((loss, grad))
    }

    /// Upload the (replica-shared) parameter vector to the device once;
    /// reuse the returned buffer across all P workers' [`Self::train_step_b`]
    /// calls in an iteration (§Perf L3-2: saves P-1 host→device copies of
    /// d floats per step).
    pub fn params_to_device(&self, params: &[f32]) -> Result<xla::PjRtBuffer> {
        anyhow::ensure!(params.len() == self.mm.d, "params dim mismatch");
        Ok(self.rt.client.buffer_from_host_buffer(params, &[self.mm.d], None)?)
    }

    /// Buffered train step: params already on device.
    pub fn train_step_b(
        &self,
        params_dev: &xla::PjRtBuffer,
        x: &BatchData,
        y: &BatchData,
    ) -> Result<(f32, Vec<f32>)> {
        anyhow::ensure!(x.len() == self.mm.x.elements(), "x batch shape mismatch");
        anyhow::ensure!(y.len() == self.mm.y.elements(), "y batch shape mismatch");
        let xb = x.to_device(&self.rt.client, &self.mm.x.shape)?;
        let yb = y.to_device(&self.rt.client, &self.mm.y.shape)?;
        let result = self.train.execute_b::<&xla::PjRtBuffer>(&[params_dev, &xb, &yb])?[0][0]
            .to_literal_sync()?;
        let (loss_l, grad_l) = result.to_tuple2()?;
        let loss = loss_l.to_vec::<f32>()?[0];
        let grad = grad_l.to_vec::<f32>()?;
        anyhow::ensure!(grad.len() == self.mm.d, "grad dim mismatch");
        Ok((loss, grad))
    }

    /// Run the eval artifact: returns (loss, metric).
    pub fn eval_step(&self, params: &[f32], x: &BatchData, y: &BatchData) -> Result<(f32, f32)> {
        let (loss, metric_l) = self.exec_step(&self.eval, params, x, y)?;
        Ok((loss, metric_l.to_vec::<f32>()?[0]))
    }

    /// Run the fused momentum-SGD apply artifact over padded buffers:
    /// (params[dp], mom[dp], agg[dp], mu) -> (params', mom').
    pub fn apply_update(
        &self,
        params_pad: &[f32],
        mom_pad: &[f32],
        agg_pad: &[f32],
        mu: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let dp = self.mm.d_padded;
        anyhow::ensure!(
            params_pad.len() == dp && mom_pad.len() == dp && agg_pad.len() == dp,
            "apply buffers must be padded to d_padded"
        );
        let p = xla::Literal::vec1(params_pad);
        let m = xla::Literal::vec1(mom_pad);
        let a = xla::Literal::vec1(agg_pad);
        let mu_l = xla::Literal::scalar(mu);
        let result =
            self.apply.execute::<xla::Literal>(&[p, m, a, mu_l])?[0][0].to_literal_sync()?;
        let (p2, m2) = result.to_tuple2()?;
        Ok((p2.to_vec::<f32>()?, m2.to_vec::<f32>()?))
    }

    /// Compress one layer through the AOT Pallas artifact. Handles padding
    /// to the layer's bucket; returns (sparse[n], resid'[n], thr) trimmed
    /// back to the layer size.
    pub fn compress_layer_xla(
        &self,
        layer: &LayerInfo,
        grad: &[f32],
        resid: &[f32],
        lr: f32,
        k: usize,
        sampled: bool,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let n = layer.size;
        anyhow::ensure!(grad.len() == n && resid.len() == n, "layer slice mismatch");
        let b = layer.bucket;
        let mut gp = vec![0.0f32; b];
        let mut rp = vec![0.0f32; b];
        gp[..n].copy_from_slice(grad);
        rp[..n].copy_from_slice(resid);
        let (mut s, mut r, thr) = self.rt.run_compress(b, sampled, &gp, &rp, lr, k)?;
        s.truncate(n);
        r.truncate(n);
        Ok((s, r, thr))
    }
}
