//! Model-execution runtimes behind one facade.
//!
//! Two backends implement the `train/eval/apply/compress` contract:
//!
//! * [`native`] — a pure-rust heterogeneous model zoo: MLPs, im2col
//!   Conv2d nets and an Elman/BPTT recurrent LM (always available;
//!   `Sync`, so the trainer's [`crate::util::ParallelExecutor`] fans the
//!   P workers' gradient steps across threads). Selected by
//!   [`Runtime::native`] or by loading the magic artifacts dir
//!   `"native"`.
//! * [`pjrt`] (feature `pjrt`) — AOT HLO-text artifacts executed through
//!   the vendored `xla` crate's PJRT CPU client. PJRT objects are not
//!   `Sync`, so this backend runs worker gradient steps sequentially in
//!   rank order; results are bit-identical either way because each
//!   worker's step is independent.
//!
//! The facade keeps the seed API: `Runtime::load(dir)` →
//! `model_runtime(name)` → `train_step / eval_step / apply_update /
//! compress_layer_xla`, plus the new [`ModelRuntime::grad_many`] batch
//! entry point the parallel trainer hot loop uses.

pub mod calibrate;
pub mod kernels;
pub mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod simd;

pub use calibrate::Calibration;
pub use manifest::{BatchSpec, DType, LayerInfo, Manifest, Metric, ModelManifest};

use crate::util::executor::ParallelExecutor;
use anyhow::Result;
use std::path::Path;

/// A batch tensor crossing into a backend.
#[derive(Debug, Clone, PartialEq)]
pub enum BatchData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl BatchData {
    pub fn len(&self) -> usize {
        match self {
            BatchData::F32(v) => v.len(),
            BatchData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One worker's gradient-compute job for [`ModelRuntime::grad_many`]: the
/// batch to run and the worker-owned output slots to fill. Holding `&mut`
/// slots (rather than returning fresh vectors) keeps the hot loop free of
/// per-step gradient allocations and lets jobs fan out across threads
/// with no shared mutable state. `scratch` is the worker-owned backward
/// scratch (activations, δ buffers, Wᵀ cache) the native backend reuses
/// across steps; the PJRT backend ignores it.
pub struct GradJob<'a> {
    pub x: BatchData,
    pub y: BatchData,
    pub loss: &'a mut f32,
    pub grad: &'a mut Vec<f32>,
    pub scratch: &'a mut native::GradScratch,
}

/// Default seed for the native zoo when loaded via the `"native"` magic
/// artifacts path (mirrors the artifacts' baked manifest seed).
const NATIVE_DEFAULT_SEED: u64 = 42;

/// The artifacts directory a zero-config run should use: `"artifacts"`
/// when `./artifacts/manifest.json` exists, else the built-in native zoo
/// (`"native"`). The CLI and the examples share this probe so the
/// fallback policy has exactly one source of truth.
pub fn default_artifacts_dir() -> &'static str {
    if Path::new("artifacts/manifest.json").exists() {
        "artifacts"
    } else {
        "native"
    }
}

enum RuntimeBackend {
    Native { seed: u64 },
    #[cfg(feature = "pjrt")]
    Pjrt(std::sync::Arc<pjrt::PjrtRuntime>),
}

/// Shared backend + manifest; the factory for [`ModelRuntime`]s.
pub struct Runtime {
    pub manifest: Manifest,
    backend: RuntimeBackend,
    /// measured device-speed calibration ([`calibrate`]); attached
    /// explicitly via [`Runtime::calibrate`] / [`Runtime::set_calibration`]
    /// — never loaded implicitly, so tests constructing runtimes directly
    /// stay independent of files in the working directory
    calibration: Option<Calibration>,
}

impl Runtime {
    /// Open an artifacts directory (PJRT backend), or the built-in native
    /// zoo (with its default seed) when `artifacts_dir` is the literal
    /// `"native"`.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        Runtime::open(artifacts_dir, NATIVE_DEFAULT_SEED)
    }

    /// Like [`Runtime::load`], but seeds the native zoo with `seed` when
    /// `artifacts_dir` is the magic `"native"`. The single entry point
    /// every caller shares, so the special case lives here only; the seed
    /// mirrors the role of the artifacts' baked manifest seed.
    pub fn open(artifacts_dir: impl AsRef<Path>, seed: u64) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref();
        if dir == Path::new("native") {
            return Ok(Runtime::native(seed));
        }
        let manifest = Manifest::load(dir)?;
        #[cfg(feature = "pjrt")]
        {
            let rt = pjrt::PjrtRuntime::new()?;
            Ok(Runtime {
                manifest,
                backend: RuntimeBackend::Pjrt(std::sync::Arc::new(rt)),
                calibration: None,
            })
        }
        #[cfg(not(feature = "pjrt"))]
        {
            anyhow::bail!(
                "artifacts at {:?} need the PJRT backend; rebuild with `--features pjrt` \
                 (and the vendored xla crate) or use the built-in native runtime \
                 (artifacts dir \"native\")",
                manifest.dir
            )
        }
    }

    /// The built-in native model zoo, seeded for deterministic init params.
    pub fn native(seed: u64) -> Runtime {
        Runtime {
            manifest: native::native_manifest(seed),
            backend: RuntimeBackend::Native { seed },
            calibration: None,
        }
    }

    pub fn platform(&self) -> String {
        match &self.backend {
            RuntimeBackend::Native { .. } => "native-host".to_string(),
            #[cfg(feature = "pjrt")]
            RuntimeBackend::Pjrt(rt) => rt.platform(),
        }
    }

    /// Device speed (flops/s) this backend's models execute at — what
    /// Eq. 18 startup selection and the DES price compute with. The
    /// native backend prefers an attached MEASURED calibration
    /// ([`Runtime::calibrate`]) and falls back to the documented
    /// [`crate::models::DEVICE_FLOPS`] constant when uncalibrated; PJRT
    /// artifacts use the accelerator-class constant (a host-GEMM
    /// calibration says nothing about an accelerator).
    pub fn device_flops(&self) -> f64 {
        match &self.backend {
            RuntimeBackend::Native { .. } => self
                .calibration
                .as_ref()
                .map(|c| c.flops_per_sec)
                .unwrap_or(crate::models::DEVICE_FLOPS),
            #[cfg(feature = "pjrt")]
            RuntimeBackend::Pjrt(_) => crate::models::PJRT_DEVICE_FLOPS,
        }
    }

    /// Human-readable provenance of [`Runtime::device_flops`] — surfaced
    /// by `lags ratios` and `report.json` so every Eq. 18 number states
    /// whether it was priced with measured or guessed compute speed.
    pub fn flops_source(&self) -> String {
        match &self.backend {
            RuntimeBackend::Native { .. } => match &self.calibration {
                Some(c) => match &c.source {
                    Some(p) => format!("calibrated ({})", p.display()),
                    None => "calibrated (in-memory measurement)".to_string(),
                },
                None => "DEVICE_FLOPS fallback (run `lags calibrate` to measure)".to_string(),
            },
            #[cfg(feature = "pjrt")]
            RuntimeBackend::Pjrt(_) => "PJRT_DEVICE_FLOPS constant".to_string(),
        }
    }

    /// Whether this backend's device speed can be measured by the host
    /// GEMM microbenchmark (native only).
    pub fn supports_calibration(&self) -> bool {
        matches!(self.backend, RuntimeBackend::Native { .. })
    }

    /// The attached calibration, if any.
    pub fn calibration(&self) -> Option<&Calibration> {
        self.calibration.as_ref()
    }

    /// Attach an already-measured/loaded calibration (native backend
    /// only; ignored elsewhere).
    pub fn set_calibration(&mut self, cal: Calibration) {
        if self.supports_calibration() {
            self.calibration = Some(cal);
        }
    }

    /// Calibration entry point shared by the CLI paths: when `measure` is
    /// true, run the GEMM microbenchmark at this manifest's shapes and
    /// PERSIST the result to the default path for this artifacts dir;
    /// otherwise just LOAD a previously persisted calibration if one
    /// exists. Either way the result is attached, so subsequent
    /// [`Runtime::device_flops`] calls report the measured number.
    /// No-op on backends that don't support host calibration.
    pub fn calibrate(&mut self, measure: bool) -> Result<()> {
        if !self.supports_calibration() {
            return Ok(());
        }
        let path = Calibration::default_path(&self.manifest.dir);
        if measure {
            let mut cal = Calibration::measure(&self.manifest, calibrate::DEFAULT_BUDGET)?;
            cal.save(&path)?;
            self.calibration = Some(cal);
        } else if let Some(cal) = Calibration::load(&path)? {
            self.calibration = Some(cal);
        }
        Ok(())
    }

    /// Build the full runtime for one model.
    pub fn model_runtime(&self, name: &str) -> Result<ModelRuntime> {
        let mm = self.manifest.model(name)?.clone();
        match &self.backend {
            RuntimeBackend::Native { seed } => {
                let m = native::NativeNet::from_manifest(&mm)?;
                let init_params = m.init_params(*seed);
                Ok(ModelRuntime { mm, init_params, backend: ModelBackend::Native(m) })
            }
            #[cfg(feature = "pjrt")]
            RuntimeBackend::Pjrt(rt) => {
                let model = pjrt::PjrtModel::compile(rt.clone(), &self.manifest, &mm)?;
                let init_params = self.manifest.load_init_params(&mm)?;
                Ok(ModelRuntime { mm, init_params, backend: ModelBackend::Pjrt(model) })
            }
        }
    }
}

enum ModelBackend {
    Native(native::NativeNet),
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::PjrtModel),
}

/// Compiled/ready executables + metadata for one model.
pub struct ModelRuntime {
    pub mm: ModelManifest,
    pub init_params: Vec<f32>,
    backend: ModelBackend,
}

impl ModelRuntime {
    /// Run one train step: returns (loss, flat gradient[d]).
    pub fn train_step(
        &self,
        params: &[f32],
        x: &BatchData,
        y: &BatchData,
    ) -> Result<(f32, Vec<f32>)> {
        match &self.backend {
            ModelBackend::Native(m) => {
                let mut grad = Vec::new();
                let mut scratch = native::GradScratch::default();
                let loss = m.train_step_into(params, x, y, &mut grad, &mut scratch)?;
                Ok((loss, grad))
            }
            #[cfg(feature = "pjrt")]
            ModelBackend::Pjrt(m) => m.train_step(&self.mm, params, x, y),
        }
    }

    /// Compute every worker's (loss, gradient) for one iteration, writing
    /// into the worker-owned slots of `jobs`.
    ///
    /// The native backend fans the jobs over `exec` (the trainer's
    /// `--threads` pool); each job only touches its own slots, so the
    /// results are bit-identical to the sequential rank-order run. The
    /// PJRT backend executes sequentially (PJRT objects are not `Sync`)
    /// with a single host→device params upload shared by all P workers.
    pub fn grad_many(
        &self,
        exec: &ParallelExecutor,
        params: &[f32],
        jobs: &mut [GradJob<'_>],
    ) -> Result<()> {
        match &self.backend {
            ModelBackend::Native(m) => exec.run(jobs, |_, job| {
                *job.loss = m.train_step_into(params, &job.x, &job.y, job.grad, job.scratch)?;
                Ok(())
            }),
            #[cfg(feature = "pjrt")]
            ModelBackend::Pjrt(m) => {
                let params_dev = m.params_to_device(&self.mm, params)?;
                for job in jobs.iter_mut() {
                    let (loss, grad) = m.train_step_b(&self.mm, &params_dev, &job.x, &job.y)?;
                    *job.loss = loss;
                    *job.grad = grad;
                }
                Ok(())
            }
        }
    }

    /// Run the eval step: returns (loss, metric) — accuracy for
    /// classifiers, the loss itself for `Metric::PplLoss` models.
    pub fn eval_step(&self, params: &[f32], x: &BatchData, y: &BatchData) -> Result<(f32, f32)> {
        match &self.backend {
            ModelBackend::Native(m) => m.eval_metric(params, x, y, self.mm.metric),
            #[cfg(feature = "pjrt")]
            ModelBackend::Pjrt(m) => m.eval_step(&self.mm, params, x, y),
        }
    }

    /// Fused momentum-SGD apply over padded buffers:
    /// (params[dp], mom[dp], agg[dp], mu) -> (params', mom').
    pub fn apply_update(
        &self,
        params_pad: &[f32],
        mom_pad: &[f32],
        agg_pad: &[f32],
        mu: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let dp = self.mm.d_padded;
        anyhow::ensure!(
            params_pad.len() == dp && mom_pad.len() == dp && agg_pad.len() == dp,
            "apply buffers must be padded to d_padded"
        );
        match &self.backend {
            ModelBackend::Native(_) => {
                Ok(native::apply_update_host(params_pad, mom_pad, agg_pad, mu))
            }
            #[cfg(feature = "pjrt")]
            ModelBackend::Pjrt(m) => m.apply_update(&self.mm, params_pad, mom_pad, agg_pad, mu),
        }
    }

    /// Compress one layer through the compress artifact (PJRT) or its
    /// bit-faithful host emulation (native). Returns (sparse[n],
    /// resid'[n], thr) trimmed back to the layer size. `scratch` is
    /// worker-owned selection scratch for the native emulation; PJRT runs
    /// the selection on-device and ignores it.
    pub fn compress_layer_xla(
        &self,
        layer: &LayerInfo,
        grad: &[f32],
        resid: &[f32],
        lr: f32,
        k: usize,
        sampled: bool,
        scratch: &mut native::CompressScratch,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        match &self.backend {
            ModelBackend::Native(_) => {
                native::compress_layer_bucket_into(layer, grad, resid, lr, k, sampled, scratch)
            }
            #[cfg(feature = "pjrt")]
            ModelBackend::Pjrt(m) => {
                let _ = scratch;
                // the facade has no manifest handle here; compress artifacts
                // are keyed by bucket, which LayerInfo carries
                m.compress_layer_xla_by_bucket(layer, grad, resid, lr, k, sampled)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_runtime_serves_zoo() {
        let rt = Runtime::native(7);
        assert_eq!(rt.platform(), "native-host");
        let mr = rt.model_runtime("mlp").unwrap();
        assert_eq!(mr.init_params.len(), mr.mm.d);
        assert!(rt.model_runtime("nope").is_err());
    }

    #[test]
    fn native_init_params_seeded() {
        let a = Runtime::native(1).model_runtime("mlp").unwrap().init_params;
        let b = Runtime::native(1).model_runtime("mlp").unwrap().init_params;
        let c = Runtime::native(2).model_runtime("mlp").unwrap().init_params;
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn device_flops_prefers_calibration_and_labels_source() {
        let mut rt = Runtime::native(7);
        assert!(rt.supports_calibration());
        assert_eq!(rt.device_flops(), crate::models::DEVICE_FLOPS);
        assert!(rt.flops_source().contains("fallback"), "{}", rt.flops_source());
        rt.set_calibration(Calibration {
            flops_per_sec: 3.5e9,
            isa: "scalar".into(),
            shapes: Vec::new(),
            source: None,
        });
        assert_eq!(rt.device_flops(), 3.5e9);
        assert!(rt.flops_source().starts_with("calibrated"), "{}", rt.flops_source());
        assert!(rt.calibration().is_some());
    }

    #[test]
    fn load_native_magic_dir() {
        let rt = Runtime::load("native").unwrap();
        assert!(rt.manifest.models.contains_key("mlp_deep"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn load_missing_artifacts_errors() {
        assert!(Runtime::load("definitely/not/a/dir").is_err());
    }
}
