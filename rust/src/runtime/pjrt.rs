//! PJRT backend: load AOT HLO-text artifacts and execute them.
//!
//! Wraps the vendored `xla` crate (xla_extension 0.5.1 CPU):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. One compiled executable per artifact;
//! compress-bucket executables are compiled lazily and cached.
//!
//! All artifacts were lowered with `return_tuple=True`, so every execution
//! returns a single tuple literal that is decomposed here.
//!
//! PJRT objects wrap raw C++ pointers and are not `Sync`, so this backend
//! executes the P workers' gradient steps **sequentially in rank order**
//! (with a single host→device params upload per iteration, §Perf L3-2);
//! the host-side compression/aggregation around it still parallelises.

use super::manifest::{LayerInfo, Manifest, ModelManifest};
use super::BatchData;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

impl BatchData {
    fn to_literal(&self, shape: &[usize]) -> Result<xla::Literal> {
        let dims: Vec<i64> = shape.iter().map(|&s| s as i64).collect();
        let lit = match self {
            BatchData::F32(v) => xla::Literal::vec1(v),
            BatchData::I32(v) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }

    fn to_device(&self, client: &xla::PjRtClient, shape: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(match self {
            BatchData::F32(v) => client.buffer_from_host_buffer(v, shape, None)?,
            BatchData::I32(v) => client.buffer_from_host_buffer(v, shape, None)?,
        })
    }
}

/// Shared PJRT client + compress-executable cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    /// (bucket, sampled) -> compiled compress executable
    compress_cache: Mutex<BTreeMap<(usize, bool), Arc<xla::PjRtLoadedExecutable>>>,
}

impl PjrtRuntime {
    pub fn new() -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client, compress_cache: Mutex::new(BTreeMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile_file(&self, manifest: &Manifest, file: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = manifest.artifact_path(file);
        let path_str = path.to_str().context("non-utf8 path")?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).with_context(|| format!("compiling {file}"))
    }

    /// Lazily compile + cache the compress executable for a bucket.
    pub fn compress_exe(
        &self,
        manifest: &Manifest,
        bucket: usize,
        sampled: bool,
    ) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        {
            let cache = self.compress_cache.lock().unwrap();
            if let Some(e) = cache.get(&(bucket, sampled)) {
                return Ok(e.clone());
            }
        }
        let (exact_f, sampled_f) = manifest
            .compress_files
            .get(&bucket)
            .with_context(|| format!("no compress artifact for bucket {bucket}"))?;
        let file = if sampled { sampled_f } else { exact_f };
        let exe = Arc::new(self.compile_file(manifest, file)?);
        self.compress_cache.lock().unwrap().insert((bucket, sampled), exe.clone());
        Ok(exe)
    }

    /// Run a compress artifact: (grad[n], resid[n], lr, k) -> (sparse,
    /// resid', thr). Inputs must already be padded to the bucket length.
    pub fn run_compress(
        &self,
        manifest: &Manifest,
        bucket: usize,
        sampled: bool,
        grad: &[f32],
        resid: &[f32],
        lr: f32,
        k: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        anyhow::ensure!(grad.len() == bucket && resid.len() == bucket, "pad to bucket first");
        let exe = self.compress_exe(manifest, bucket, sampled)?;
        let g = xla::Literal::vec1(grad);
        let r = xla::Literal::vec1(resid);
        let lr_l = xla::Literal::scalar(lr);
        let k_l = xla::Literal::scalar(k as i32);
        let result = exe.execute::<xla::Literal>(&[g, r, lr_l, k_l])?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        anyhow::ensure!(parts.len() == 3, "compress artifact returned {} outputs", parts.len());
        let sparse = parts[0].to_vec::<f32>()?;
        let new_resid = parts[1].to_vec::<f32>()?;
        let thr = parts[2].to_vec::<f32>()?[0];
        Ok((sparse, new_resid, thr))
    }
}

/// Compiled executables for one model (plus a manifest copy for the lazy
/// compress-bucket lookups).
pub struct PjrtModel {
    rt: Arc<PjrtRuntime>,
    manifest: Manifest,
    train: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
    apply: xla::PjRtLoadedExecutable,
}

impl PjrtModel {
    /// Compile train + eval + apply eagerly (compress buckets stay lazy).
    pub fn compile(
        rt: Arc<PjrtRuntime>,
        manifest: &Manifest,
        mm: &ModelManifest,
    ) -> Result<PjrtModel> {
        let train = rt.compile_file(manifest, mm.file("train")?)?;
        let eval = rt.compile_file(manifest, mm.file("eval")?)?;
        let apply = rt.compile_file(manifest, mm.file("apply")?)?;
        Ok(PjrtModel { rt, manifest: manifest.clone(), train, eval, apply })
    }

    fn exec_step(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        mm: &ModelManifest,
        params: &[f32],
        x: &BatchData,
        y: &BatchData,
    ) -> Result<(f32, xla::Literal)> {
        anyhow::ensure!(params.len() == mm.d, "params dim mismatch");
        anyhow::ensure!(x.len() == mm.x.elements(), "x batch shape mismatch");
        anyhow::ensure!(y.len() == mm.y.elements(), "y batch shape mismatch");
        let p = xla::Literal::vec1(params);
        let xl = x.to_literal(&mm.x.shape)?;
        let yl = y.to_literal(&mm.y.shape)?;
        let result = exe.execute::<xla::Literal>(&[p, xl, yl])?[0][0].to_literal_sync()?;
        let (loss_l, second) = result.to_tuple2()?;
        let loss = loss_l.to_vec::<f32>()?[0];
        Ok((loss, second))
    }

    /// Run the train artifact: returns (loss, flat gradient[d]).
    pub fn train_step(
        &self,
        mm: &ModelManifest,
        params: &[f32],
        x: &BatchData,
        y: &BatchData,
    ) -> Result<(f32, Vec<f32>)> {
        let (loss, grad_l) = self.exec_step(&self.train, mm, params, x, y)?;
        let grad = grad_l.to_vec::<f32>()?;
        anyhow::ensure!(grad.len() == mm.d, "grad dim mismatch");
        Ok((loss, grad))
    }

    /// Upload the (replica-shared) parameter vector to the device once;
    /// reuse the returned buffer across all P workers' [`Self::train_step_b`]
    /// calls in an iteration (§Perf L3-2: saves P-1 host→device copies of
    /// d floats per step).
    pub fn params_to_device(&self, mm: &ModelManifest, params: &[f32]) -> Result<xla::PjRtBuffer> {
        anyhow::ensure!(params.len() == mm.d, "params dim mismatch");
        Ok(self.rt.client.buffer_from_host_buffer(params, &[mm.d], None)?)
    }

    /// Buffered train step: params already on device.
    pub fn train_step_b(
        &self,
        mm: &ModelManifest,
        params_dev: &xla::PjRtBuffer,
        x: &BatchData,
        y: &BatchData,
    ) -> Result<(f32, Vec<f32>)> {
        anyhow::ensure!(x.len() == mm.x.elements(), "x batch shape mismatch");
        anyhow::ensure!(y.len() == mm.y.elements(), "y batch shape mismatch");
        let xb = x.to_device(&self.rt.client, &mm.x.shape)?;
        let yb = y.to_device(&self.rt.client, &mm.y.shape)?;
        let result = self.train.execute_b::<&xla::PjRtBuffer>(&[params_dev, &xb, &yb])?[0][0]
            .to_literal_sync()?;
        let (loss_l, grad_l) = result.to_tuple2()?;
        let loss = loss_l.to_vec::<f32>()?[0];
        let grad = grad_l.to_vec::<f32>()?;
        anyhow::ensure!(grad.len() == mm.d, "grad dim mismatch");
        Ok((loss, grad))
    }

    /// Run the eval artifact: returns (loss, metric).
    pub fn eval_step(
        &self,
        mm: &ModelManifest,
        params: &[f32],
        x: &BatchData,
        y: &BatchData,
    ) -> Result<(f32, f32)> {
        let (loss, metric_l) = self.exec_step(&self.eval, mm, params, x, y)?;
        Ok((loss, metric_l.to_vec::<f32>()?[0]))
    }

    /// Run the fused momentum-SGD apply artifact over padded buffers:
    /// (params[dp], mom[dp], agg[dp], mu) -> (params', mom').
    pub fn apply_update(
        &self,
        mm: &ModelManifest,
        params_pad: &[f32],
        mom_pad: &[f32],
        agg_pad: &[f32],
        mu: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let dp = mm.d_padded;
        anyhow::ensure!(
            params_pad.len() == dp && mom_pad.len() == dp && agg_pad.len() == dp,
            "apply buffers must be padded to d_padded"
        );
        let p = xla::Literal::vec1(params_pad);
        let m = xla::Literal::vec1(mom_pad);
        let a = xla::Literal::vec1(agg_pad);
        let mu_l = xla::Literal::scalar(mu);
        let result =
            self.apply.execute::<xla::Literal>(&[p, m, a, mu_l])?[0][0].to_literal_sync()?;
        let (p2, m2) = result.to_tuple2()?;
        Ok((p2.to_vec::<f32>()?, m2.to_vec::<f32>()?))
    }

    /// Compress one layer through the AOT Pallas artifact. Handles padding
    /// to the layer's bucket; returns (sparse[n], resid'[n], thr) trimmed
    /// back to the layer size.
    pub fn compress_layer_xla_by_bucket(
        &self,
        layer: &LayerInfo,
        grad: &[f32],
        resid: &[f32],
        lr: f32,
        k: usize,
        sampled: bool,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let n = layer.size;
        anyhow::ensure!(grad.len() == n && resid.len() == n, "layer slice mismatch");
        let b = layer.bucket;
        let mut gp = vec![0.0f32; b];
        let mut rp = vec![0.0f32; b];
        gp[..n].copy_from_slice(grad);
        rp[..n].copy_from_slice(resid);
        let (mut s, mut r, thr) =
            self.rt.run_compress(&self.manifest, b, sampled, &gp, &rp, lr, k)?;
        s.truncate(n);
        r.truncate(n);
        Ok((s, r, thr))
    }
}
