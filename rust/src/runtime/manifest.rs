//! Typed view of `artifacts/manifest.json` (emitted by python/compile/aot.py).
//!
//! The manifest is the contract between the build-time python layer and the
//! runtime rust layer: layer tables (name/shape/offset/size/bucket/flops),
//! batch specs, metric kind, artifact file names, compress bucket list.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone, PartialEq)]
pub struct LayerInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub size: usize,
    pub offset: usize,
    /// compress artifact bucket (next pow2, >= MIN_BUCKET)
    pub bucket: usize,
    /// forward FLOPs attributed to this tensor (per batch)
    pub fwd_flops: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Debug, Clone, PartialEq)]
pub struct BatchSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl BatchSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<Self> {
        let shape = v
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|s| s.as_usize())
            .collect::<Result<Vec<_>>>()?;
        let dtype = match v.get("dtype")?.as_str()? {
            "float32" => DType::F32,
            "int32" => DType::I32,
            other => bail!("unsupported dtype {other:?}"),
        };
        Ok(BatchSpec { shape, dtype })
    }
}

/// Which evaluation metric the model's eval artifact returns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    /// top-1 accuracy in [0,1]
    Accuracy,
    /// cross-entropy loss; perplexity = exp(loss)
    PplLoss,
}

#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    /// flat parameter dimension
    pub d: usize,
    /// d padded to the apply-artifact alignment
    pub d_padded: usize,
    pub metric: Metric,
    /// label cardinality (classes for classifiers, vocab for LMs)
    pub classes: usize,
    pub x: BatchSpec,
    pub y: BatchSpec,
    pub layers: Vec<LayerInfo>,
    pub files: BTreeMap<String, String>,
}

impl ModelManifest {
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn file(&self, kind: &str) -> Result<&str> {
        self.files
            .get(kind)
            .map(|s| s.as_str())
            .with_context(|| format!("model {} has no {kind:?} artifact", self.name))
    }

    /// Total forward FLOPs per batch.
    pub fn total_fwd_flops(&self) -> f64 {
        self.layers.iter().map(|l| l.fwd_flops).sum()
    }

    /// Look up a layer by name (the heterogeneous-zoo tests and reports
    /// key per-layer expectations on names like `"conv1"` / `"head"`).
    pub fn layer(&self, name: &str) -> Option<&LayerInfo> {
        self.layers.iter().find(|l| l.name == name)
    }

    fn from_json(v: &Json) -> Result<Self> {
        let name = v.get("name")?.as_str()?.to_string();
        let metric = match v.get("metric")?.as_str()? {
            "accuracy" => Metric::Accuracy,
            "ppl_loss" => Metric::PplLoss,
            other => bail!("unknown metric {other:?}"),
        };
        let mut layers = Vec::new();
        for l in v.get("layers")?.as_arr()? {
            layers.push(LayerInfo {
                name: l.get("name")?.as_str()?.to_string(),
                shape: l
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|s| s.as_usize())
                    .collect::<Result<Vec<_>>>()?,
                size: l.get("size")?.as_usize()?,
                offset: l.get("offset")?.as_usize()?,
                bucket: l.get("bucket")?.as_usize()?,
                fwd_flops: l.get("fwd_flops")?.as_f64()?,
            });
        }
        let files = v
            .get("files")?
            .as_obj()?
            .iter()
            .map(|(k, f)| Ok((k.clone(), f.as_str()?.to_string())))
            .collect::<Result<BTreeMap<_, _>>>()?;
        let mm = ModelManifest {
            name,
            d: v.get("d")?.as_usize()?,
            d_padded: v.get("d_padded")?.as_usize()?,
            metric,
            classes: v.get("classes")?.as_usize()?,
            x: BatchSpec::from_json(v.get("x")?)?,
            y: BatchSpec::from_json(v.get("y")?)?,
            layers,
            files,
        };
        mm.validate()?;
        Ok(mm)
    }

    pub fn validate(&self) -> Result<()> {
        let mut off = 0;
        for l in &self.layers {
            if l.offset != off {
                bail!("layer {} offset {} != expected {}", l.name, l.offset, off);
            }
            let prod: usize = l.shape.iter().product();
            if prod != l.size {
                bail!("layer {} shape/size mismatch", l.name);
            }
            if l.bucket < l.size {
                bail!("layer {} bucket {} < size {}", l.name, l.bucket, l.size);
            }
            off += l.size;
        }
        if off != self.d {
            bail!("layer sizes sum to {off} but d = {}", self.d);
        }
        if self.d_padded < self.d {
            bail!("d_padded < d");
        }
        Ok(())
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelManifest>,
    pub compress_buckets: Vec<usize>,
    /// bucket -> (exact file, sampled file)
    pub compress_files: BTreeMap<usize, (String, String)>,
    pub seed: u64,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = Json::parse(&text).context("parsing manifest.json")?;

        let mut models = BTreeMap::new();
        for (name, mv) in v.get("models")?.as_obj()? {
            models.insert(name.clone(), ModelManifest::from_json(mv)?);
        }
        let compress_buckets = v
            .get("compress_buckets")?
            .as_arr()?
            .iter()
            .map(|b| b.as_usize())
            .collect::<Result<Vec<_>>>()?;
        let mut compress_files = BTreeMap::new();
        for (k, f) in v.get("compress_files")?.as_obj()? {
            let bucket: usize = k.parse().context("bucket key")?;
            compress_files.insert(
                bucket,
                (
                    f.get("exact")?.as_str()?.to_string(),
                    f.get("sampled")?.as_str()?.to_string(),
                ),
            );
        }
        let seed = v.get("seed")?.as_usize()? as u64;
        Ok(Manifest { dir, models, compress_buckets, compress_files, seed })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models.get(name).with_context(|| {
            format!(
                "model {name:?} not in manifest (have: {})",
                self.models.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }

    pub fn artifact_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Load the seeded initial flat parameters for a model.
    pub fn load_init_params(&self, m: &ModelManifest) -> Result<Vec<f32>> {
        let path = self.artifact_path(m.file("init")?);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        anyhow::ensure!(bytes.len() == 4 * m.d, "init.bin size mismatch");
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_manifest_json() -> String {
        r#"{
          "models": {
            "toy": {
              "name": "toy", "d": 6, "d_padded": 4096, "metric": "accuracy",
              "classes": 2,
              "x": {"shape": [2, 2], "dtype": "float32"},
              "y": {"shape": [2], "dtype": "int32"},
              "files": {"train": "toy_train.hlo.txt", "init": "toy_init.bin"},
              "layers": [
                {"name": "w", "shape": [2,2], "size": 4, "offset": 0, "bucket": 1024, "fwd_flops": 16.0},
                {"name": "b", "shape": [2], "size": 2, "offset": 4, "bucket": 1024, "fwd_flops": 2.0}
              ]
            }
          },
          "compress_buckets": [1024],
          "compress_files": {"1024": {"exact": "compress_1024.hlo.txt", "sampled": "compresss_1024.hlo.txt"}},
          "seed": 42
        }"#
        .to_string()
    }

    #[test]
    fn parse_tiny() {
        let dir = std::env::temp_dir().join("lags_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), tiny_manifest_json()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let toy = m.model("toy").unwrap();
        assert_eq!(toy.d, 6);
        assert_eq!(toy.layers.len(), 2);
        assert_eq!(toy.layers[1].offset, 4);
        assert_eq!(toy.x.dtype, DType::F32);
        assert_eq!(toy.y.dtype, DType::I32);
        assert_eq!(toy.metric, Metric::Accuracy);
        assert_eq!(toy.classes, 2);
        assert_eq!(m.compress_files[&1024].0, "compress_1024.hlo.txt");
        assert!(m.model("missing").is_err());
        assert_eq!(toy.total_fwd_flops(), 18.0);
        assert_eq!(toy.layer("w").unwrap().size, 4);
        assert!(toy.layer("nope").is_none());
    }

    #[test]
    fn validation_catches_bad_offsets() {
        let bad = tiny_manifest_json().replace("\"offset\": 4", "\"offset\": 5");
        let v = Json::parse(&bad).unwrap();
        assert!(ModelManifest::from_json(v.get("models").unwrap().get("toy").unwrap()).is_err());
    }
}
