//! Discrete-event simulation of one S-SGD iteration (Fig. 1).
//!
//! The model: computation is a serial device stream (forward pass, then
//! per-layer backward in output-to-input order); communication is a serial
//! NIC stream. A layer's message becomes *ready* when its backward step
//! finishes; messages are transmitted FIFO in ready order. The iteration
//! ends when both streams drain (synchronous SGD barrier).
//!
//! This is exactly the two-resource pipeline the paper's Fig. 1 draws, and
//! the same model MG-WFBP (Shi et al. 2019) uses for wait-free backprop
//! analysis. Calibration: per-layer backward times from
//! [`crate::models::zoo`], α–β collective costs from
//! [`crate::collectives::cost`].

use crate::collectives::NetworkModel;
use crate::models::ModelProfile;

/// What each algorithm puts on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// Fig 1(a): layer-wise DENSE allreduce, pipelined with backprop.
    DensePipelined,
    /// Non-pipelined dense baseline: one allreduce of the whole model after
    /// backprop (what a naive framework without WFBP does).
    DenseSingle,
    /// Fig 1(b): single-shot sparse allgather after the full backprop
    /// (current sparsification methods — SLGS).
    Slgs,
    /// Fig 1(c): layer-wise sparse allgather, pipelined (LAGS), with the
    /// §5 merge buffer.
    Lags,
}

/// One communication event in the simulated timeline.
#[derive(Debug, Clone)]
pub struct CommEvent {
    /// label: layer name or merged group "l5..l2"
    pub name: String,
    /// time the payload became ready (last contributing backward done)
    pub ready: f64,
    pub start: f64,
    pub end: f64,
    pub wire_bytes: f64,
}

/// Timing breakdown of one iteration.
#[derive(Debug, Clone)]
pub struct IterationBreakdown {
    pub schedule: Schedule,
    pub t_f: f64,
    pub t_b: f64,
    /// sum of pure communication time (busy NIC time)
    pub t_comm: f64,
    /// sparsification overhead total (serialized on the compute stream)
    pub t_spar: f64,
    /// wall-clock of the whole iteration
    pub iter_time: f64,
    /// communication time hidden under computation
    pub hidden: f64,
    pub events: Vec<CommEvent>,
}

impl IterationBreakdown {
    /// Fraction of pure communication time hidden under compute, in
    /// [0, 1]. The prediction the real trainer's measured
    /// `overlap_efficiency` (streamed-reduction hidden / busy time) is
    /// compared against — the DES's answer to "how much should
    /// `--pipeline overlap` be able to hide for this schedule?".
    pub fn overlap_efficiency(&self) -> f64 {
        if self.t_comm > 0.0 {
            self.hidden / self.t_comm
        } else {
            0.0
        }
    }
}

/// Simulation parameters beyond the model/network.
///
/// Sparsification overhead runs on the COMPRESSION+COMM pipeline (the
/// paper's implementation compresses and communicates on a thread separate
/// from the backprop stream), so in LAGS it overlaps the remaining
/// backprop, while in SLGS the single whole-model selection has nothing
/// left to overlap — one of the two sources of LAGS's Table-2 advantage
/// (the other being comm overlap itself).
#[derive(Debug, Clone)]
pub struct SimParams {
    /// per-layer compression ratio c^(l) (indexed in backprop order);
    /// ignored by the dense schedules. len == model.layers.len()
    pub ratios: Vec<f64>,
    /// merge-buffer capacity in wire bytes (0 = no merging)
    pub merge_bytes: f64,
    /// sparsification overhead: t_spar(l) = spar_fixed + spar_per_elem * d_l
    pub spar_fixed: f64,
    pub spar_per_elem: f64,
    /// wire bytes per transmitted sparse element (index + value encoding;
    /// 8 = u32 index + f32 value, 5 = u32 index + u8 quantization level).
    /// Ignored by the dense schedules. Per-message header overhead is
    /// negligible at DES granularity and not modeled.
    pub wire_bytes_per_elem: f64,
    /// per-worker multiplicative compute skews (`cluster::faults`); empty
    /// = homogeneous cluster. A synchronous step's compute stream is paced
    /// by the slowest participant, so the gating skew scales t_f and every
    /// t_b — message ready-times shift with it while comm cost does not.
    pub skews: Vec<f64>,
    /// bounded-staleness quorum size (0 = full sync): with q < P, the
    /// q-th fastest worker gates the step instead of the slowest — the
    /// DES-predicted throughput recovery of `--quorum`.
    pub quorum: usize,
}

impl SimParams {
    pub fn uniform(model: &ModelProfile, c: f64) -> SimParams {
        SimParams {
            ratios: vec![c; model.layers.len()],
            // small sparse messages: flush every ~32 KiB so latency
            // amortizes without deferring transmission to backprop end
            merge_bytes: 32.0 * 1024.0,
            // double-sampling top-k (compress + decompress pair): fixed
            // launch + linear scan; ~4 ms per 1M elements on the paper's
            // P102-100 class GPU
            spar_fixed: 5e-5,
            spar_per_elem: 4e-9,
            wire_bytes_per_elem: 8.0,
            skews: Vec::new(),
            quorum: 0,
        }
    }

    pub fn dense(model: &ModelProfile) -> SimParams {
        SimParams {
            ratios: vec![1.0; model.layers.len()],
            // Horovod-style tensor fusion buffer (64 MiB) — the dense
            // baseline also batches small layers, as real frameworks do
            merge_bytes: 64.0 * 1024.0 * 1024.0,
            spar_fixed: 0.0,
            spar_per_elem: 0.0,
            wire_bytes_per_elem: 8.0,
            skews: Vec::new(),
            quorum: 0,
        }
    }

    /// The compute-pacing multiplier: q-th smallest skew (q = quorum, or
    /// everyone when 0). 1.0 for the homogeneous cluster.
    pub fn skew_gate(&self) -> f64 {
        if self.skews.is_empty() {
            return 1.0;
        }
        let mut s = self.skews.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let q = if self.quorum == 0 { s.len() } else { self.quorum.min(s.len()) };
        s[q - 1].max(1e-9)
    }
}

/// Simulate one iteration; see module docs for the two-stream model.
pub fn simulate(
    model: &ModelProfile,
    net: &NetworkModel,
    schedule: Schedule,
    params: &SimParams,
) -> IterationBreakdown {
    assert_eq!(params.ratios.len(), model.layers.len(), "one ratio per layer");
    let l = model.layers.len();
    let sparsifies = matches!(schedule, Schedule::Slgs | Schedule::Lags);

    // --- compute stream: forward, then backward per layer. Sparsification
    // runs on the compression+comm pipeline (see SimParams docs), so it
    // does NOT extend the compute stream. Under a straggler plan the whole
    // stream is paced by the gating worker's skew (everyone waits at the
    // synchronous reduction; with a quorum, only for the q-th fastest).
    let gate = params.skew_gate();
    let mut ready = vec![0.0f64; l];
    let mut t = model.t_f * gate;
    for i in 0..l {
        t += model.layers[i].t_b * gate;
        ready[i] = t;
    }
    let comp_done = t;
    let spar_of = |i: usize| {
        if sparsifies {
            params.spar_fixed + params.spar_per_elem * model.layers[i].params as f64
        } else {
            0.0
        }
    };
    let t_spar_total: f64 = (0..l).map(spar_of).sum();

    // --- build messages per schedule
    struct Msg {
        name: String,
        ready: f64,
        bytes: f64,
        time: f64,
    }
    let k_of = |i: usize| (model.layers[i].params as f64 / params.ratios[i]).max(1.0);
    // grouped (merge-buffer) pipelined message builder, shared by the
    // dense-fusion and LAGS schedules: `load(i)` is the byte load layer i
    // adds to the buffer; `cost(total_load)` prices a flushed group.
    let grouped = |load: &dyn Fn(usize) -> f64, cost: &dyn Fn(f64) -> (f64, f64)| -> Vec<Msg> {
        let mut msgs = Vec::new();
        let mut group: Vec<usize> = Vec::new();
        let mut group_load = 0.0f64;
        let mut group_spar = 0.0f64;
        let flush =
            |group: &mut Vec<usize>, group_load: &mut f64, group_spar: &mut f64, msgs: &mut Vec<Msg>| {
                if group.is_empty() {
                    return;
                }
                let first = *group.first().unwrap();
                let last = *group.last().unwrap();
                let name = if group.len() == 1 {
                    model.layers[first].name.clone()
                } else {
                    format!("{}..{}", model.layers[first].name, model.layers[last].name)
                };
                let (bytes, time) = cost(*group_load);
                msgs.push(Msg { name, ready: ready[last], bytes, time: time + *group_spar });
                group.clear();
                *group_load = 0.0;
                *group_spar = 0.0;
            };
        for i in 0..l {
            group.push(i);
            group_load += load(i);
            group_spar += spar_of(i);
            let full = params.merge_bytes > 0.0 && group_load >= params.merge_bytes;
            if full || params.merge_bytes == 0.0 {
                flush(&mut group, &mut group_load, &mut group_spar, &mut msgs);
            }
        }
        flush(&mut group, &mut group_load, &mut group_spar, &mut msgs);
        msgs
    };
    let mut msgs: Vec<Msg>;
    match schedule {
        Schedule::DensePipelined => {
            msgs = grouped(
                &|i| model.layers[i].params as f64 * 4.0,
                &|bytes| (bytes, net.allreduce_dense(bytes)),
            );
        }
        Schedule::DenseSingle => {
            msgs = Vec::new();
            let bytes = model.d() as f64 * 4.0;
            msgs.push(Msg {
                name: "all".into(),
                ready: comp_done,
                bytes,
                time: net.allreduce_dense(bytes),
            });
        }
        Schedule::Slgs => {
            // single TopK over the whole model: k_total = d / c_max-equiv;
            // use the same per-layer budget summed, matching equal traffic
            // whole-model selection cost is paid serially before the send
            let k_total: f64 = (0..l).map(k_of).sum();
            let spar = params.spar_fixed + params.spar_per_elem * model.d() as f64;
            let wb = params.wire_bytes_per_elem;
            msgs = vec![Msg {
                name: "all".into(),
                ready: comp_done,
                bytes: wb * k_total,
                time: spar + net.allgather_sparse_encoded(k_total, wb),
            }];
        }
        Schedule::Lags => {
            // merge consecutive ready layers until the buffer fills or
            // backprop ends (§5 heuristic 1); wire load = wire_bytes_per_elem
            // bytes per kept coordinate (8 for index+value, 5 for index+level)
            let wb = params.wire_bytes_per_elem;
            msgs = grouped(&|i| wb * k_of(i), &|bytes| {
                (bytes, net.allgather_sparse_encoded(bytes / wb, wb))
            });
        }
    }

    // --- NIC stream: FIFO in ready order
    msgs.sort_by(|a, b| a.ready.partial_cmp(&b.ready).unwrap());
    let mut nic_free = 0.0f64;
    let mut events = Vec::with_capacity(msgs.len());
    let mut t_comm = 0.0;
    for m in msgs {
        let start = m.ready.max(nic_free);
        let end = start + m.time;
        nic_free = end;
        t_comm += m.time;
        events.push(CommEvent { name: m.name, ready: m.ready, start, end, wire_bytes: m.bytes });
    }
    let iter_time = comp_done.max(nic_free);
    // hidden = comm that overlapped computation
    let tail = (nic_free - comp_done).max(0.0);
    let hidden = (t_comm - tail).max(0.0);

    IterationBreakdown {
        schedule,
        t_f: model.t_f * gate,
        t_b: model.t_b() * gate,
        t_comm,
        t_spar: t_spar_total,
        iter_time,
        hidden,
        events,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    fn net() -> NetworkModel {
        NetworkModel::gige_16()
    }

    #[test]
    fn lags_never_slower_than_slgs() {
        for m in zoo::table2_models() {
            let p = SimParams::uniform(&m, 1000.0);
            let lags = simulate(&m, &net(), Schedule::Lags, &p);
            let slgs = simulate(&m, &net(), Schedule::Slgs, &p);
            assert!(
                lags.iter_time <= slgs.iter_time + 1e-9,
                "{}: lags {} > slgs {}",
                m.name,
                lags.iter_time,
                slgs.iter_time
            );
        }
    }

    #[test]
    fn sparse_never_slower_than_dense() {
        for m in zoo::table2_models() {
            let sp = SimParams::uniform(&m, 1000.0);
            let dp = SimParams::dense(&m);
            let lags = simulate(&m, &net(), Schedule::Lags, &sp);
            let dense = simulate(&m, &net(), Schedule::DensePipelined, &dp);
            assert!(lags.iter_time < dense.iter_time, "{}", m.name);
        }
    }

    #[test]
    fn pipelined_dense_beats_single_dense() {
        // With per-message latency the comparison depends on fusion tuning,
        // so check the clean invariant at alpha = 0: starting transfers
        // earlier can only help when messages are free to issue.
        let free = NetworkModel { alpha: 0.0, ..net() };
        for m in zoo::table2_models() {
            let p = SimParams::dense(&m);
            let a = simulate(&m, &free, Schedule::DensePipelined, &p);
            let b = simulate(&m, &free, Schedule::DenseSingle, &p);
            assert!(a.iter_time <= b.iter_time + 1e-9, "{}", m.name);
        }
        // and with the default fused buffer + real alpha, pipelined dense
        // must still hide a nonzero amount of communication
        let a = simulate(&zoo::resnet50(), &net(), Schedule::DensePipelined, &SimParams::dense(&zoo::resnet50()));
        assert!(a.hidden > 0.0);
    }

    #[test]
    fn iter_time_lower_bound() {
        // can never beat pure compute or pure comm
        let m = zoo::resnet50();
        let p = SimParams::uniform(&m, 1000.0);
        for s in [Schedule::DensePipelined, Schedule::Slgs, Schedule::Lags] {
            let b = simulate(&m, &net(), s, &p);
            assert!(b.iter_time >= b.t_f + b.t_b - 1e-9);
            assert!(b.iter_time >= b.t_comm - 1e-9);
        }
    }

    #[test]
    fn events_are_fifo_non_overlapping() {
        let m = zoo::inception_v4();
        let p = SimParams::uniform(&m, 1000.0);
        let b = simulate(&m, &net(), Schedule::Lags, &p);
        for w in b.events.windows(2) {
            assert!(w[1].start >= w[0].end - 1e-12);
            assert!(w[0].start >= w[0].ready - 1e-12);
        }
        assert!(!b.events.is_empty());
    }

    #[test]
    fn merge_buffer_reduces_messages() {
        let m = zoo::resnet50();
        let mut p = SimParams::uniform(&m, 1000.0);
        p.merge_bytes = 0.0;
        let unmerged = simulate(&m, &net(), Schedule::Lags, &p);
        p.merge_bytes = 32.0 * 1024.0;
        let merged = simulate(&m, &net(), Schedule::Lags, &p);
        assert!(merged.events.len() < unmerged.events.len());
        // at 1GbE latency (7.5ms/message at P=16), fewer messages must win
        assert!(
            merged.iter_time <= unmerged.iter_time + 1e-9,
            "merged {} > unmerged {}",
            merged.iter_time,
            unmerged.iter_time
        );
        // over-merging (buffer bigger than all traffic) degenerates to a
        // single end-of-backprop message = no overlap left
        p.merge_bytes = 1e12;
        let single = simulate(&m, &net(), Schedule::Lags, &p);
        assert_eq!(single.events.len(), 1);
        assert!(single.hidden < 1e-9);
    }

    #[test]
    fn hidden_time_bounded() {
        let m = zoo::resnet50();
        let p = SimParams::uniform(&m, 1000.0);
        let b = simulate(&m, &net(), Schedule::Lags, &p);
        assert!(b.hidden >= 0.0);
        assert!(b.hidden <= b.t_comm + 1e-12);
        assert!((0.0..=1.0).contains(&b.overlap_efficiency()));
        assert!(b.overlap_efficiency() > 0.0, "LAGS must hide something");
        // SLGS hides nothing: its single message starts at comp_done
        let s = simulate(&m, &net(), Schedule::Slgs, &p);
        assert!(s.hidden < 1e-12);
        assert!(s.overlap_efficiency() < 1e-9);
    }

    #[test]
    fn skew_gate_scales_compute_and_quorum_drops_it() {
        let m = zoo::resnet50();
        let mut p = SimParams::uniform(&m, 1000.0);
        let base = simulate(&m, &net(), Schedule::Lags, &p);

        // full participation: the 4x straggler paces the step
        p.skews = vec![1.0, 4.0, 1.0, 1.0];
        assert!((p.skew_gate() - 4.0).abs() < 1e-12);
        let skewed = simulate(&m, &net(), Schedule::Lags, &p);
        assert!((skewed.t_f - 4.0 * base.t_f).abs() < 1e-9);
        assert!((skewed.t_b - 4.0 * base.t_b).abs() < 1e-9);
        assert!(skewed.iter_time > base.iter_time);

        // quorum 3-of-4 excludes the straggler: gate back to 1.0, and the
        // predicted iteration time returns to the homogeneous one exactly
        p.quorum = 3;
        assert!((p.skew_gate() - 1.0).abs() < 1e-12);
        let quorum = simulate(&m, &net(), Schedule::Lags, &p);
        assert!((quorum.iter_time - base.iter_time).abs() < 1e-12);
    }

    #[test]
    fn narrower_wire_encoding_cheapens_sparse_comm() {
        let m = zoo::resnet50();
        let mut p = SimParams::uniform(&m, 1000.0);
        let wide_l = simulate(&m, &net(), Schedule::Lags, &p);
        let wide_s = simulate(&m, &net(), Schedule::Slgs, &p);
        // index+level encoding (qsgd-topk): 5 bytes/elem instead of 8
        p.wire_bytes_per_elem = 5.0;
        let narrow_l = simulate(&m, &net(), Schedule::Lags, &p);
        let narrow_s = simulate(&m, &net(), Schedule::Slgs, &p);
        assert!(narrow_l.t_comm < wide_l.t_comm);
        assert!(narrow_s.t_comm < wide_s.t_comm);
        let sum = |b: &IterationBreakdown| b.events.iter().map(|e| e.wire_bytes).sum::<f64>();
        assert!(sum(&narrow_l) < sum(&wide_l));
        // SLGS bytes scale exactly with the encoding (single message)
        assert!((sum(&narrow_s) / sum(&wide_s) - 5.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn single_worker_no_comm() {
        let m = zoo::resnet50();
        let p = SimParams::uniform(&m, 1000.0);
        let n1 = NetworkModel::gige_16().with_workers(1);
        let b = simulate(&m, &n1, Schedule::Lags, &p);
        // pipeline busy time reduces to pure sparsification cost
        assert!((b.t_comm - b.t_spar).abs() < 1e-12);
        assert!(b.iter_time >= b.t_f + b.t_b - 1e-9);
        // only the last group's spar can stick out past backprop
        assert!(b.iter_time <= b.t_f + b.t_b + b.t_spar + 1e-9);
    }
}
