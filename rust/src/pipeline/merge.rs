//! Small-message merge buffer (§5 heuristic 1) — the NUMERIC counterpart
//! of the grouping the DES models: sparsified layer messages are staged in
//! a buffer and flushed as one combined message when the buffer fills or
//! the last layer (backprop order) arrives.
//!
//! Two consumers:
//!
//! * the LAGS trainer's per-layer reduction (both `--pipeline` modes):
//!   completed layers are staged by WIRE SIZE (`MergeBuffer<usize>`; the
//!   payloads themselves stay in the `StreamAggregator`'s rank slots) and
//!   each flushed group is reduced + applied as one unit, with one merged
//!   message per rank accounted in `MessageStats` — so the merge-vs-no-
//!   merge ablation runs in the real trainer, not just the DES;
//! * the DES/ablation harnesses, which stage whole [`SparseVec`]
//!   payloads (`MergeBuffer<SparseVec>`, the default).

use crate::sparsify::sparse::SparseVec;

/// A group of per-layer payloads flushed together.
#[derive(Debug, Clone)]
pub struct MergedGroup<T = SparseVec> {
    /// backprop-order layer indices contained in this flush
    pub layer_indices: Vec<usize>,
    /// per-layer staged payloads, same order as layer_indices
    pub payloads: Vec<T>,
}

impl MergedGroup<SparseVec> {
    pub fn wire_bytes(&self) -> usize {
        self.payloads.iter().map(|p| p.wire_bytes()).sum()
    }
}

/// Staging buffer: push per-layer payloads, get groups out.
pub struct MergeBuffer<T = SparseVec> {
    capacity_bytes: usize,
    staged: Vec<(usize, T)>,
    staged_bytes: usize,
    flushed: Vec<MergedGroup<T>>,
}

impl<T> MergeBuffer<T> {
    /// capacity 0 disables merging (every layer flushes immediately).
    pub fn new(capacity_bytes: usize) -> Self {
        MergeBuffer { capacity_bytes, staged: Vec::new(), staged_bytes: 0, flushed: Vec::new() }
    }

    /// Stage `payload` for `layer_idx`, accounting `bytes` against the
    /// capacity; flushes when the buffer fills.
    pub fn push_with(&mut self, layer_idx: usize, bytes: usize, payload: T) {
        self.staged_bytes += bytes;
        self.staged.push((layer_idx, payload));
        if self.capacity_bytes == 0 || self.staged_bytes >= self.capacity_bytes {
            self.flush();
        }
    }

    /// Force a flush (end of backprop — "gradients of the first layer have
    /// been calculated").
    pub fn flush(&mut self) {
        if self.staged.is_empty() {
            return;
        }
        let mut idxs = Vec::with_capacity(self.staged.len());
        let mut payloads = Vec::with_capacity(self.staged.len());
        for (i, p) in self.staged.drain(..) {
            idxs.push(i);
            payloads.push(p);
        }
        self.staged_bytes = 0;
        self.flushed.push(MergedGroup { layer_indices: idxs, payloads });
    }

    /// Drain all completed groups.
    pub fn take_groups(&mut self) -> Vec<MergedGroup<T>> {
        std::mem::take(&mut self.flushed)
    }

    pub fn pending_bytes(&self) -> usize {
        self.staged_bytes
    }

    pub fn capacity_bytes(&self) -> usize {
        self.capacity_bytes
    }

    /// Re-size the capacity live. The trainer's capacity is
    /// `merge_bytes × P`, and under elastic membership P is the CURRENT
    /// worker count — freezing the startup P would silently mis-scale the
    /// per-rank grouping threshold after every drop/join. Already-staged
    /// layers are kept; if they now exceed the new capacity they flush on
    /// the next push (same rule as filling up normally).
    pub fn set_capacity(&mut self, capacity_bytes: usize) {
        self.capacity_bytes = capacity_bytes;
    }
}

impl MergeBuffer<SparseVec> {
    /// Stage a sparse message, accounting its wire bytes.
    pub fn push(&mut self, layer_idx: usize, msg: SparseVec) {
        let bytes = msg.wire_bytes();
        self.push_with(layer_idx, bytes, msg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(nnz: usize) -> SparseVec {
        SparseVec {
            len: 1000,
            idx: (0..nnz as u32).collect(),
            val: vec![1.0; nnz],
        }
    }

    #[test]
    fn zero_capacity_flushes_each() {
        let mut b = MergeBuffer::new(0);
        b.push(0, msg(5));
        b.push(1, msg(5));
        let g = b.take_groups();
        assert_eq!(g.len(), 2);
        assert_eq!(g[0].layer_indices, vec![0]);
    }

    #[test]
    fn merges_until_capacity() {
        let mut b = MergeBuffer::new(100); // 12 nnz * 8B = 96 < 100; 13*8=104 >= 100
        b.push(0, msg(6)); // 48B staged
        assert!(b.take_groups().is_empty());
        b.push(1, msg(7)); // 104B -> flush
        let g = b.take_groups();
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].layer_indices, vec![0, 1]);
        assert_eq!(g[0].wire_bytes(), 13 * 8);
    }

    #[test]
    fn final_flush_drains_partial() {
        let mut b = MergeBuffer::new(1 << 20);
        b.push(0, msg(3));
        b.push(1, msg(3));
        assert_eq!(b.pending_bytes(), 48);
        b.flush();
        let g = b.take_groups();
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].layer_indices, vec![0, 1]);
        assert_eq!(b.pending_bytes(), 0);
    }

    #[test]
    fn flush_on_empty_is_noop() {
        let mut b = MergeBuffer::new(10);
        b.flush();
        assert!(b.take_groups().is_empty());
    }

    #[test]
    fn set_capacity_rescales_grouping_live() {
        // regression: capacity used to be frozen at construction, so a
        // membership change could not rescale the merge_bytes × P threshold
        let mut b = MergeBuffer::new(200);
        b.push(0, msg(6)); // 48B < 200: stays staged
        assert!(b.take_groups().is_empty());
        b.set_capacity(80); // cluster shrank: threshold drops
        assert_eq!(b.capacity_bytes(), 80);
        b.push(1, msg(6)); // 96B >= 80 -> flush both staged layers
        let g = b.take_groups();
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].layer_indices, vec![0, 1]);
        // shrinking to 0 restores per-layer flushing
        b.set_capacity(0);
        b.push(2, msg(1));
        assert_eq!(b.take_groups().len(), 1);
    }
}
