//! Wait-free backprop pipeline: scheduling + timing of layer-wise
//! communication (the system half of the paper, §5 and Fig. 1).
//!
//! * [`desim`] — discrete-event simulator that replays one training
//!   iteration's timeline for Dense-SGD (pipelined, Fig 1a), SLGS-SGD
//!   (single-shot sparse, Fig 1b) and LAGS-SGD (pipelined sparse, Fig 1c)
//!   over a calibrated [`crate::models::ModelProfile`] and
//!   [`crate::collectives::NetworkModel`]. Regenerates Table 2 / Fig 1.
//! * [`merge`] — the §5 small-message merge buffer heuristic: sparsified
//!   layer messages are batched until the buffer fills (or backprop ends)
//!   so the (P-1)·α latency term is paid once per group, not per layer.

pub mod desim;
pub mod merge;

pub use desim::{simulate, CommEvent, IterationBreakdown, Schedule};
pub use merge::MergeBuffer;
