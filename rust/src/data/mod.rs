//! Synthetic workload generators — the stand-ins for Cifar-10 / ImageNet /
//! PTB (see DESIGN.md §Scale-substitutions).
//!
//! Requirements for the convergence experiments (Fig 2/3, Table 1):
//! the task must be *learnable* (so Dense/SLGS/LAGS produce meaningful
//! accuracy/perplexity trends), *stationary*, and *shardable* so each of
//! the P workers draws an i.i.d. stream from its own PRNG fork — the
//! data-parallel sampling model of Eq. 1.
//!
//! * [`teacher`] — classification: labels from a fixed random 2-layer
//!   teacher MLP over gaussian inputs (mlp model), or class-template images
//!   with additive noise (cnn model).
//! * [`markov`] — language modelling: an order-1 Markov chain with sparse
//!   transition structure; next-token prediction is learnable down to the
//!   chain's entropy floor.

pub mod markov;
pub mod teacher;

use crate::runtime::{BatchData, DType, ModelManifest};
use crate::util::rng::Rng;
use anyhow::Result;

/// One training/eval batch, shaped per the manifest's batch specs.
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: BatchData,
    pub y: BatchData,
}

/// A per-model synthetic data source. Worker `p` gets an independent
/// stream; `eval` streams are disjoint from all workers'.
pub enum Synthetic {
    TeacherMlp(teacher::TeacherMlp),
    TeacherImage(teacher::TeacherImage),
    Markov(markov::MarkovText),
}

impl Synthetic {
    /// Choose a generator matching the model's batch specs.
    pub fn for_model(mm: &ModelManifest, seed: u64) -> Result<Synthetic> {
        match (mm.x.dtype, mm.x.shape.len()) {
            (DType::F32, 2) => {
                let (b, din) = (mm.x.shape[0], mm.x.shape[1]);
                Ok(Synthetic::TeacherMlp(teacher::TeacherMlp::new(din, mm.classes, b, seed)))
            }
            (DType::F32, 4) => {
                let s = &mm.x.shape;
                Ok(Synthetic::TeacherImage(teacher::TeacherImage::new(
                    s[0], s[1], s[2], s[3], mm.classes, seed,
                )))
            }
            (DType::I32, 2) => {
                let (b, t) = (mm.x.shape[0], mm.x.shape[1]);
                Ok(Synthetic::Markov(markov::MarkovText::new(mm.classes, b, t, seed)))
            }
            (dt, rank) => anyhow::bail!("no generator for dtype {dt:?} rank {rank}"),
        }
    }

    /// Draw the next batch for worker `p` at step `step` (pure function of
    /// (seed, p, step) — workers can replay deterministically).
    pub fn batch(&self, worker: usize, step: usize) -> Batch {
        let stream = (worker as u64) << 32 | step as u64;
        match self {
            Synthetic::TeacherMlp(t) => t.batch(stream),
            Synthetic::TeacherImage(t) => t.batch(stream),
            Synthetic::Markov(m) => m.batch(stream),
        }
    }

    /// Held-out batch stream (disjoint stream id space from workers).
    pub fn eval_batch(&self, idx: usize) -> Batch {
        self.batch(usize::MAX >> 8, idx)
    }
}

/// Helper shared by generators: derive the batch RNG.
pub(crate) fn batch_rng(base: &Rng, stream: u64) -> Rng {
    base.fork(stream)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{BatchSpec, Metric};
    use std::collections::BTreeMap;

    fn mm(xshape: Vec<usize>, xdt: DType, yshape: Vec<usize>, classes: usize) -> ModelManifest {
        ModelManifest {
            name: "t".into(),
            d: 1,
            d_padded: 4096,
            metric: Metric::Accuracy,
            classes,
            x: BatchSpec { shape: xshape, dtype: xdt },
            y: BatchSpec { shape: yshape, dtype: DType::I32 },
            layers: vec![],
            files: BTreeMap::new(),
        }
    }

    #[test]
    fn picks_generator_by_spec() {
        let m1 = mm(vec![8, 32], DType::F32, vec![8], 10);
        assert!(matches!(Synthetic::for_model(&m1, 1).unwrap(), Synthetic::TeacherMlp(_)));
        let m2 = mm(vec![4, 16, 16, 3], DType::F32, vec![4], 10);
        assert!(matches!(Synthetic::for_model(&m2, 1).unwrap(), Synthetic::TeacherImage(_)));
        let m3 = mm(vec![2, 16], DType::I32, vec![2, 16], 64);
        assert!(matches!(Synthetic::for_model(&m3, 1).unwrap(), Synthetic::Markov(_)));
    }

    #[test]
    fn deterministic_replay() {
        let m = mm(vec![8, 32], DType::F32, vec![8], 10);
        let g = Synthetic::for_model(&m, 7).unwrap();
        let a = g.batch(3, 5);
        let b = g.batch(3, 5);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = g.batch(3, 6);
        assert_ne!(a.x, c.x);
        let d = g.batch(4, 5);
        assert_ne!(a.x, d.x);
    }

    #[test]
    fn eval_stream_disjoint_from_workers() {
        let m = mm(vec![8, 32], DType::F32, vec![8], 10);
        let g = Synthetic::for_model(&m, 7).unwrap();
        let e = g.eval_batch(0);
        for w in 0..8 {
            let b = g.batch(w, 0);
            assert_ne!(e.x, b.x);
        }
    }
}
