//! Markov-chain token streams (the PTB stand-in for LM workloads).
//!
//! An order-1 chain over `vocab` tokens where each token has a small set of
//! likely successors (sparse, skewed transition rows). A recurrent or
//! attention LM can reduce next-token cross-entropy down to the chain's
//! conditional entropy, so perplexity *trends* across Dense/SLGS/LAGS are
//! meaningful while the entropy floor keeps runs short.

use super::{batch_rng, Batch};
use crate::runtime::BatchData;
use crate::util::rng::Rng;

pub struct MarkovText {
    vocab: usize,
    batch: usize,
    seq: usize,
    /// per-token successor CDFs: (successor ids, cumulative weights)
    rows: Vec<(Vec<usize>, Vec<f64>)>,
    base: Rng,
}

impl MarkovText {
    pub fn new(vocab: usize, batch: usize, seq: usize, seed: u64) -> Self {
        let mut init = Rng::new(seed ^ 0x3A2C0F);
        let succ = 4.min(vocab);
        let rows = (0..vocab)
            .map(|_| {
                let ids = init.sample_distinct(vocab, succ);
                // skewed weights: geometric-ish 1, 1/2, 1/4 ... plus a
                // small uniform escape mass handled via an extra bucket
                let mut cdf = Vec::with_capacity(succ + 1);
                let mut acc = 0.0;
                for i in 0..succ {
                    acc += 1.0 / (1 << i) as f64;
                    cdf.push(acc);
                }
                acc += 0.15; // escape-to-uniform mass
                cdf.push(acc);
                (ids, cdf)
            })
            .collect();
        MarkovText { vocab, batch, seq, rows, base: Rng::new(seed) }
    }

    fn next_token(&self, cur: usize, rng: &mut Rng) -> usize {
        let (ids, cdf) = &self.rows[cur];
        let bucket = rng.categorical(cdf);
        if bucket < ids.len() {
            ids[bucket]
        } else {
            rng.below(self.vocab) // escape: uniform random token
        }
    }

    /// Generate (x, y) = (tokens[0..T], tokens[1..=T]) per sequence.
    pub fn batch(&self, stream: u64) -> Batch {
        let mut rng = batch_rng(&self.base, stream);
        let mut xs = vec![0i32; self.batch * self.seq];
        let mut ys = vec![0i32; self.batch * self.seq];
        for b in 0..self.batch {
            let mut cur = rng.below(self.vocab);
            for t in 0..self.seq {
                xs[b * self.seq + t] = cur as i32;
                cur = self.next_token(cur, &mut rng);
                ys[b * self.seq + t] = cur as i32;
            }
        }
        Batch { x: BatchData::I32(xs), y: BatchData::I32(ys) }
    }

    /// Empirical conditional entropy (nats) of the chain — the loss floor
    /// a perfect model converges to. Estimated by sampling.
    pub fn entropy_floor(&self, samples: usize) -> f64 {
        let mut rng = self.base.fork(0xFEED);
        let mut total = 0.0;
        for _ in 0..samples {
            let cur = rng.below(self.vocab);
            let (ids, cdf) = &self.rows[cur];
            let z = *cdf.last().unwrap();
            // entropy of the successor distribution incl. uniform escape
            let mut h = 0.0;
            let mut prev = 0.0;
            for (i, &c) in cdf.iter().enumerate() {
                let p = (c - prev) / z;
                prev = c;
                if i < ids.len() {
                    h -= p * p.ln();
                } else {
                    // escape mass spread over vocab
                    let pu = p / self.vocab as f64;
                    if pu > 0.0 {
                        h -= self.vocab as f64 * pu * pu.ln();
                    }
                }
            }
            total += h;
        }
        total / samples as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_shift() {
        let m = MarkovText::new(64, 4, 16, 1);
        let b = m.batch(0);
        let BatchData::I32(xs) = &b.x else { panic!() };
        let BatchData::I32(ys) = &b.y else { panic!() };
        assert_eq!(xs.len(), 64);
        assert_eq!(ys.len(), 64);
        // y is x shifted by one within each sequence
        for s in 0..4 {
            for t in 0..15 {
                assert_eq!(ys[s * 16 + t], xs[s * 16 + t + 1]);
            }
        }
        assert!(xs.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn chain_is_predictable() {
        // successor distribution is skewed: the most likely successor should
        // appear much more often than 1/vocab
        let m = MarkovText::new(64, 1, 4096, 2);
        let b = m.batch(0);
        let BatchData::I32(xs) = &b.x else { panic!() };
        let BatchData::I32(ys) = &b.y else { panic!() };
        let mut hit = 0usize;
        for (x, y) in xs.iter().zip(ys.iter()) {
            let top = m.rows[*x as usize].0[0] as i32;
            if *y == top {
                hit += 1;
            }
        }
        let rate = hit as f64 / xs.len() as f64;
        assert!(rate > 0.25, "top-successor rate {rate} too low");
    }

    #[test]
    fn entropy_floor_sane() {
        let m = MarkovText::new(64, 1, 4, 3);
        let h = m.entropy_floor(500);
        // between 0 (deterministic) and ln(64) (uniform)
        assert!(h > 0.3 && h < (64f64).ln(), "h={h}");
    }
}
