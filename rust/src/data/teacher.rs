//! Teacher-generated classification tasks (the Cifar-10 stand-ins).

use super::{batch_rng, Batch};
use crate::runtime::BatchData;
use crate::util::rng::Rng;

/// Labels from a frozen random 2-layer MLP teacher over gaussian inputs:
/// y = argmax(relu(x W1) W2). A student MLP of comparable width can reach
/// high accuracy, so Dense/SLGS/LAGS accuracy *differences* are visible.
pub struct TeacherMlp {
    in_dim: usize,
    classes: usize,
    batch: usize,
    hidden: usize,
    w1: Vec<f32>, // [in_dim, hidden]
    w2: Vec<f32>, // [hidden, classes]
    base: Rng,
}

impl TeacherMlp {
    pub fn new(in_dim: usize, classes: usize, batch: usize, seed: u64) -> Self {
        let hidden = 32.max(classes * 2);
        let mut init = Rng::new(seed ^ 0x7EAC4E12);
        let mut w1 = vec![0.0f32; in_dim * hidden];
        let mut w2 = vec![0.0f32; hidden * classes];
        init.fill_normal(&mut w1, (2.0 / in_dim as f32).sqrt());
        init.fill_normal(&mut w2, (2.0 / hidden as f32).sqrt());
        TeacherMlp { in_dim, classes, batch, hidden, w1, w2, base: Rng::new(seed) }
    }

    pub fn label(&self, x: &[f32]) -> i32 {
        // h = relu(x W1); logits = h W2; argmax
        let mut h = vec![0.0f32; self.hidden];
        for j in 0..self.hidden {
            let mut acc = 0.0f32;
            for i in 0..self.in_dim {
                acc += x[i] * self.w1[i * self.hidden + j];
            }
            h[j] = acc.max(0.0);
        }
        let mut best = (0usize, f32::NEG_INFINITY);
        for c in 0..self.classes {
            let mut acc = 0.0f32;
            for j in 0..self.hidden {
                acc += h[j] * self.w2[j * self.classes + c];
            }
            if acc > best.1 {
                best = (c, acc);
            }
        }
        best.0 as i32
    }

    pub fn batch(&self, stream: u64) -> Batch {
        let mut rng = batch_rng(&self.base, stream);
        let mut xs = vec![0.0f32; self.batch * self.in_dim];
        rng.fill_normal(&mut xs, 1.0);
        let ys: Vec<i32> =
            (0..self.batch).map(|b| self.label(&xs[b * self.in_dim..(b + 1) * self.in_dim])).collect();
        Batch { x: BatchData::F32(xs), y: BatchData::I32(ys) }
    }
}

/// Class-template images with additive gaussian noise (the conv-net task):
/// x = template[y] + sigma * noise. Templates are smooth random fields so
/// convolutions can exploit locality.
pub struct TeacherImage {
    batch: usize,
    h: usize,
    w: usize,
    c: usize,
    classes: usize,
    noise: f32,
    templates: Vec<Vec<f32>>, // classes x (h*w*c)
    base: Rng,
}

impl TeacherImage {
    pub fn new(batch: usize, h: usize, w: usize, c: usize, classes: usize, seed: u64) -> Self {
        let mut init = Rng::new(seed ^ 0x1A6E5);
        let n = h * w * c;
        let templates = (0..classes)
            .map(|_| {
                // smooth field: random low-frequency sinusoid mixture
                let (fx, fy) = (init.range_f64(0.5, 3.0), init.range_f64(0.5, 3.0));
                let (px, py) = (init.range_f64(0.0, 6.28), init.range_f64(0.0, 6.28));
                let amp = init.range_f64(0.8, 1.2);
                let mut t = vec![0.0f32; n];
                for yy in 0..h {
                    for xx in 0..w {
                        for ch in 0..c {
                            let v = amp
                                * ((fx * xx as f64 / w as f64 * 6.28 + px).sin()
                                    + (fy * yy as f64 / h as f64 * 6.28 + py).cos()
                                    + 0.3 * ch as f64);
                            t[(yy * w + xx) * c + ch] = v as f32;
                        }
                    }
                }
                t
            })
            .collect();
        TeacherImage { batch, h, w, c, classes, noise: 0.7, templates, base: Rng::new(seed) }
    }

    pub fn batch(&self, stream: u64) -> Batch {
        let mut rng = batch_rng(&self.base, stream);
        let n = self.h * self.w * self.c;
        let mut xs = vec![0.0f32; self.batch * n];
        let mut ys = vec![0i32; self.batch];
        for b in 0..self.batch {
            let y = rng.below(self.classes);
            ys[b] = y as i32;
            let t = &self.templates[y];
            for i in 0..n {
                xs[b * n + i] = t[i] + self.noise * rng.normal_f32();
            }
        }
        Batch { x: BatchData::F32(xs), y: BatchData::I32(ys) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[allow(clippy::disallowed_types)] // distinctness check only, not order-sensitive
    fn mlp_labels_in_range_and_varied() {
        let t = TeacherMlp::new(32, 10, 64, 1);
        let b = t.batch(0);
        let BatchData::I32(ys) = &b.y else { panic!() };
        assert!(ys.iter().all(|&y| (0..10).contains(&y)));
        let distinct: std::collections::HashSet<_> = ys.iter().collect();
        assert!(distinct.len() >= 3, "labels collapsed: {distinct:?}");
    }

    #[test]
    fn mlp_labels_depend_on_x_not_rng() {
        let t = TeacherMlp::new(16, 5, 4, 2);
        let b = t.batch(9);
        let BatchData::F32(xs) = &b.x else { panic!() };
        let BatchData::I32(ys) = &b.y else { panic!() };
        for i in 0..4 {
            assert_eq!(t.label(&xs[i * 16..(i + 1) * 16]), ys[i]);
        }
    }

    #[test]
    fn image_batch_shapes() {
        let t = TeacherImage::new(8, 16, 16, 3, 10, 3);
        let b = t.batch(0);
        assert_eq!(b.x.len(), 8 * 16 * 16 * 3);
        assert_eq!(b.y.len(), 8);
    }

    #[test]
    fn image_classes_distinguishable() {
        // mean distance between class templates must exceed noise floor
        let t = TeacherImage::new(4, 8, 8, 3, 4, 4);
        let mut min_dist = f32::INFINITY;
        for a in 0..4 {
            for b in (a + 1)..4 {
                let d: f32 = t.templates[a]
                    .iter()
                    .zip(&t.templates[b])
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum::<f32>()
                    .sqrt();
                min_dist = min_dist.min(d);
            }
        }
        assert!(min_dist > 1.0, "templates too close: {min_dist}");
    }
}
