//! Eq. 18: adaptive per-layer compression-ratio selection.
//!
//! ```text
//! c^(l) = max{ c_u,  min{ c | t_comm^(l)(c) + t_spar^(l) <= t_comp^(l-1) } }
//! ```
//!
//! (as printed; the intent — and what the surrounding text says — is that
//! c^(l) is the SMALLEST ratio whose communication hides under the
//! pipelined computation, CAPPED at the upper bound c_u. We implement the
//! intent: `min(c_u, smallest c that fits)`, and fall back to c_u when even
//! c_u cannot hide the layer.)
//!
//! `t_comp^(l-1)` is the backward time of the NEXT layer in backprop order
//! (the computation the transfer can overlap with, Fig. 1c); for the last
//! transmitted layer there is nothing left to overlap, so the cap applies.

use crate::collectives::NetworkModel;
use crate::models::ModelProfile;
use crate::runtime::ModelManifest;

#[derive(Debug, Clone)]
pub struct RatioConfig {
    /// upper bound c_u on any layer's compression ratio (paper uses 1000)
    pub c_max: f64,
    /// lower bound (1 = allow dense layers when bandwidth permits)
    pub c_min: f64,
    /// sparsification overhead model (same as the DES)
    pub spar_fixed: f64,
    pub spar_per_elem: f64,
}

impl Default for RatioConfig {
    fn default() -> Self {
        RatioConfig { c_max: 1000.0, c_min: 1.0, spar_fixed: 5e-5, spar_per_elem: 4e-9 }
    }
}

/// Smallest c such that allgather_sparse(d/c) + t_spar <= budget.
/// Closed form: t = (P-1)(α + 8 (d/c) / B) + t_spar <= budget
///   ⇒ c >= 8 d (P-1) / (B (budget - t_spar - (P-1)α))
fn smallest_fitting_c(net: &NetworkModel, d: usize, t_spar: f64, budget: f64) -> Option<f64> {
    let p = net.workers as f64;
    if net.workers <= 1 {
        return Some(1.0); // no communication at all
    }
    let fixed = t_spar + (p - 1.0) * net.alpha;
    if budget <= fixed {
        return None; // even k=0 wouldn't fit: latency alone exceeds budget
    }
    let c = 8.0 * d as f64 * (p - 1.0) / (net.bandwidth * (budget - fixed));
    Some(c.max(1.0))
}

/// Core of Eq. 18: select c^(l) for every layer of `model` (backprop
/// order), pricing layer i's sparsification overhead with `t_spar(i)`.
fn select_with<F: Fn(usize) -> f64>(
    model: &ModelProfile,
    net: &NetworkModel,
    cfg: &RatioConfig,
    t_spar: F,
) -> Vec<f64> {
    let l = model.layers.len();
    let mut out = Vec::with_capacity(l);
    for i in 0..l {
        let d = model.layers[i].params;
        let budget = if i + 1 < l { model.layers[i + 1].t_b } else { 0.0 };
        let c = match smallest_fitting_c(net, d, t_spar(i), budget) {
            Some(c) => c.clamp(cfg.c_min, cfg.c_max),
            None => cfg.c_max,
        };
        out.push(c);
    }
    out
}

/// Select c^(l) for every layer of `model` (backprop order). Layer l's
/// budget is the backward time of layer l+1 (the next to compute); the last
/// layer gets no overlap budget and is capped at c_max. Sparsification
/// overhead comes from the analytic `spar_fixed + spar_per_elem·d` model.
pub fn select_ratios(model: &ModelProfile, net: &NetworkModel, cfg: &RatioConfig) -> Vec<f64> {
    select_with(model, net, cfg, |i| {
        cfg.spar_fixed + cfg.spar_per_elem * model.layers[i].params as f64
    })
}

/// Eq. 18 with MEASURED per-layer sparsification/aggregation overheads
/// (seconds, backprop order) in place of the analytic spar model — the
/// online adaptive path's entry point (`adaptive::online`).
pub fn select_ratios_measured(
    model: &ModelProfile,
    net: &NetworkModel,
    cfg: &RatioConfig,
    t_spar: &[f64],
) -> Vec<f64> {
    assert_eq!(t_spar.len(), model.layers.len(), "one overhead per layer");
    select_with(model, net, cfg, |i| t_spar[i])
}

/// Per-layer kept-coordinate counts for manifest-order `ratios`:
/// k^(l) = ceil(d_l / c^(l)), clamped to [1, d_l]. The single source of
/// the ks-from-ratios convention (startup selection AND online
/// re-selection go through here).
pub fn ks_from_ratios(sizes: &[usize], ratios: &[f64]) -> Vec<usize> {
    assert_eq!(sizes.len(), ratios.len());
    sizes
        .iter()
        .zip(ratios.iter())
        .map(|(&d, &c)| ((d as f64 / c).ceil() as usize).clamp(1, d))
        .collect()
}

/// Manifest-order wrapper over [`select_ratios_measured`] applying the
/// same P ≤ 1 all-dense rule as [`select_ratios_manifest`] — the online
/// re-selection entry point (`model` in backprop order).
pub fn select_ratios_measured_manifest(
    model: &ModelProfile,
    net: &NetworkModel,
    cfg: &RatioConfig,
    t_spar: &[f64],
) -> Vec<f64> {
    if net.workers <= 1 {
        return vec![1.0; model.layers.len()];
    }
    let mut r = select_ratios_measured(model, net, cfg, t_spar);
    r.reverse();
    r
}

/// The selection the trainer makes at startup, shared with `lags ratios`
/// so the CLI report and `Trainer::ratios()` agree on the same inputs:
/// Eq. 18 over the live manifest's profile at `device_flops`, returned in
/// MANIFEST order. P ≤ 1 explicitly selects all-dense (c = 1 everywhere):
/// a single worker has no communication to hide, so sparsifying would
/// only add compression error — no phantom 2-worker cluster.
pub fn select_ratios_manifest(
    mm: &ModelManifest,
    device_flops: f64,
    net: &NetworkModel,
    cfg: &RatioConfig,
) -> Vec<f64> {
    if net.workers <= 1 {
        return vec![1.0; mm.layers.len()];
    }
    let profile = ModelProfile::from_manifest(mm, device_flops);
    let mut r = select_ratios(&profile, net, cfg);
    r.reverse();
    r
}

/// Effective global compression c_max over the selection (drives the
/// convergence bound of Corollary 2).
pub fn effective_cmax(ratios: &[f64]) -> f64 {
    // lags-audit: allow(R3) reason="max-fold, not a float sum: f64::max is order-insensitive (associative+commutative over non-NaN ratios)"
    ratios.iter().cloned().fold(1.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn ratios_within_bounds() {
        let m = zoo::resnet50();
        let net = NetworkModel::gige_16();
        let cfg = RatioConfig::default();
        let rs = select_ratios(&m, &net, &cfg);
        assert_eq!(rs.len(), m.layers.len());
        assert!(rs.iter().all(|&c| (cfg.c_min..=cfg.c_max).contains(&c)));
    }

    #[test]
    fn faster_network_needs_less_compression() {
        let m = zoo::lstm_ptb();
        let cfg = RatioConfig::default();
        let slow = NetworkModel { alpha: 5e-4, bandwidth: 111e6, workers: 16 };
        let fast = NetworkModel { alpha: 5e-6, bandwidth: 111e8, workers: 16 };
        let rs_slow = select_ratios(&m, &slow, &cfg);
        let rs_fast = select_ratios(&m, &fast, &cfg);
        for (s, f) in rs_slow.iter().zip(rs_fast.iter()) {
            assert!(f <= s, "fast {f} > slow {s}");
        }
        // 100x network should drop at least one layer's requirement
        assert!(rs_fast.iter().sum::<f64>() < rs_slow.iter().sum::<f64>());
    }

    #[test]
    fn selected_comm_fits_budget_when_not_capped() {
        let m = zoo::resnet50();
        let net = NetworkModel::gige_16();
        let cfg = RatioConfig::default();
        let rs = select_ratios(&m, &net, &cfg);
        for i in 0..m.layers.len() - 1 {
            let c = rs[i];
            if c < cfg.c_max - 1e-9 && c > cfg.c_min + 1e-9 {
                let d = m.layers[i].params;
                let t_spar = cfg.spar_fixed + cfg.spar_per_elem * d as f64;
                let t = net.layer_comm_time(d, c) + t_spar;
                assert!(t <= m.layers[i + 1].t_b + 1e-9, "layer {i}: {t}");
            }
        }
    }

    #[test]
    fn single_worker_all_dense() {
        let m = zoo::resnet50();
        let net = NetworkModel::gige_16().with_workers(1);
        let rs = select_ratios(&m, &net, &RatioConfig::default());
        assert!(rs.iter().all(|&c| c == 1.0));
    }

    #[test]
    fn last_layer_capped() {
        let m = zoo::resnet50();
        let net = NetworkModel::gige_16();
        let cfg = RatioConfig::default();
        let rs = select_ratios(&m, &net, &cfg);
        assert_eq!(*rs.last().unwrap(), cfg.c_max);
    }

    #[test]
    fn effective_cmax_is_max() {
        assert_eq!(effective_cmax(&[1.0, 250.0, 10.0]), 250.0);
    }

    #[test]
    fn measured_spar_matches_analytic_when_equal() {
        let m = zoo::resnet50();
        let net = NetworkModel::gige_16();
        let cfg = RatioConfig::default();
        let spar: Vec<f64> = m
            .layers
            .iter()
            .map(|l| cfg.spar_fixed + cfg.spar_per_elem * l.params as f64)
            .collect();
        assert_eq!(select_ratios_measured(&m, &net, &cfg, &spar), select_ratios(&m, &net, &cfg));
        // larger measured overheads can only demand more compression
        let spar2: Vec<f64> = spar.iter().map(|s| s * 10.0).collect();
        let r1 = select_ratios_measured(&m, &net, &cfg, &spar);
        let r2 = select_ratios_measured(&m, &net, &cfg, &spar2);
        for (a, b) in r1.iter().zip(r2.iter()) {
            assert!(b >= a, "{b} < {a}");
        }
    }

    #[test]
    fn convnet_conv_vs_dense_head_ratios_differ_under_gige16() {
        // The heterogeneous-zoo acceptance criterion: `lags ratios --model
        // convnet --net gige16 --adaptive` (defaults: P = 4, device =
        // DEVICE_FLOPS) must report a NON-uniform vector where a conv
        // layer's ratio differs from the dense head's by more than 2×.
        // Structure: the head's small transfer hides entirely under the
        // conv stack's long backward (c = 1), while the first-computed
        // conv has nothing left to overlap with (c = c_max).
        let man = crate::runtime::native::native_manifest(42);
        let mm = &man.models["convnet"];
        let net = NetworkModel::gige_16().with_workers(4);
        let cfg = RatioConfig::default();
        let rs = select_ratios_manifest(mm, crate::models::DEVICE_FLOPS, &net, &cfg);
        let head = mm.layers.iter().position(|l| l.name == "head").expect("head layer");
        let conv_max = mm
            .layers
            .iter()
            .zip(rs.iter())
            .filter(|(l, _)| l.name.starts_with("conv"))
            .map(|(_, &c)| c)
            .fold(0.0f64, f64::max);
        assert!(
            conv_max > 2.0 * rs[head],
            "conv max {conv_max} vs head {} not >2x apart: {rs:?}",
            rs[head]
        );
        let (lo, hi) = rs.iter().fold((f64::INFINITY, 0.0f64), |(lo, hi), &c| {
            (lo.min(c), hi.max(c))
        });
        assert!(hi > lo, "selection degenerated to uniform: {rs:?}");
        // ... while the MLP family, with its near-identical layer shapes,
        // still selects a uniform vector on the same network — the very
        // degeneracy that motivated the conv/rnn zoo
        let mlp = &man.models["mlp"];
        let rs_mlp = select_ratios_manifest(mlp, crate::models::DEVICE_FLOPS, &net, &cfg);
        assert!(rs_mlp.iter().all(|&c| c == rs_mlp[0]), "{rs_mlp:?}");
    }

    #[test]
    fn convnet_deep_selects_all_three_regimes() {
        // dense (c_min), fractional (in between) and capped (c_max) must
        // all appear at once on the deep conv model — the selection is a
        // real function of the layer table, not a binary switch
        let man = crate::runtime::native::native_manifest(42);
        let mm = &man.models["convnet_deep"];
        let net = NetworkModel::gige_16().with_workers(4);
        let cfg = RatioConfig::default();
        let rs = select_ratios_manifest(mm, crate::models::DEVICE_FLOPS, &net, &cfg);
        assert!(rs.iter().any(|&c| c <= cfg.c_min + 1e-9), "no dense layer: {rs:?}");
        assert!(rs.iter().any(|&c| c >= cfg.c_max - 1e-9), "no capped layer: {rs:?}");
        assert!(
            rs.iter().any(|&c| c > cfg.c_min + 1e-9 && c < cfg.c_max - 1e-9),
            "no fractional layer: {rs:?}"
        );
    }

    #[test]
    fn rnn_head_dense_recurrent_capped_under_gige16() {
        // LM shape: the head's allgather hides under the BPTT backward
        // (c = 1); the embedding is the last gradient produced, with
        // nothing to overlap (c = c_max) — the paper's LSTM story
        let man = crate::runtime::native::native_manifest(42);
        let mm = &man.models["rnn"];
        let net = NetworkModel::gige_16().with_workers(4);
        let cfg = RatioConfig::default();
        let rs = select_ratios_manifest(mm, crate::models::DEVICE_FLOPS, &net, &cfg);
        let by_name = |n: &str| {
            mm.layers.iter().position(|l| l.name == n).map(|i| rs[i]).expect("layer")
        };
        assert!(by_name("embed") > 2.0 * by_name("head"), "{rs:?}");
        assert!(by_name("head") < 2.0, "head should ride the BPTT budget: {rs:?}");
    }

    #[test]
    fn manifest_selection_is_manifest_ordered_and_dense_at_p1() {
        let man = crate::runtime::native::native_manifest(1);
        let mm = man.models.values().next().unwrap();
        let cfg = RatioConfig::default();
        let net = NetworkModel::gige_16().with_workers(4);
        let rs = select_ratios_manifest(mm, 1e12, &net, &cfg);
        assert_eq!(rs.len(), mm.layers.len());
        // manifest order = reversed backprop order of the profile selection
        let profile = crate::models::ModelProfile::from_manifest(mm, 1e12);
        let mut expect = select_ratios(&profile, &net, &cfg);
        expect.reverse();
        assert_eq!(rs, expect);
        // P = 1: explicitly all-dense, no phantom cluster
        let rs1 = select_ratios_manifest(mm, 1e12, &net.with_workers(1), &cfg);
        assert!(rs1.iter().all(|&c| c == 1.0));
    }
}
