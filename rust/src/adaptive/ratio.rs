//! Eq. 18: adaptive per-layer compression-ratio selection.
//!
//! ```text
//! c^(l) = max{ c_u,  min{ c | t_comm^(l)(c) + t_spar^(l) <= t_comp^(l-1) } }
//! ```
//!
//! (as printed; the intent — and what the surrounding text says — is that
//! c^(l) is the SMALLEST ratio whose communication hides under the
//! pipelined computation, CAPPED at the upper bound c_u. We implement the
//! intent: `min(c_u, smallest c that fits)`, and fall back to c_u when even
//! c_u cannot hide the layer.)
//!
//! `t_comp^(l-1)` is the backward time of the NEXT layer in backprop order
//! (the computation the transfer can overlap with, Fig. 1c); for the last
//! transmitted layer there is nothing left to overlap, so the cap applies.

use crate::collectives::NetworkModel;
use crate::models::ModelProfile;

#[derive(Debug, Clone)]
pub struct RatioConfig {
    /// upper bound c_u on any layer's compression ratio (paper uses 1000)
    pub c_max: f64,
    /// lower bound (1 = allow dense layers when bandwidth permits)
    pub c_min: f64,
    /// sparsification overhead model (same as the DES)
    pub spar_fixed: f64,
    pub spar_per_elem: f64,
}

impl Default for RatioConfig {
    fn default() -> Self {
        RatioConfig { c_max: 1000.0, c_min: 1.0, spar_fixed: 5e-5, spar_per_elem: 4e-9 }
    }
}

/// Smallest c such that allgather_sparse(d/c) + t_spar <= budget.
/// Closed form: t = (P-1)(α + 8 (d/c) / B) + t_spar <= budget
///   ⇒ c >= 8 d (P-1) / (B (budget - t_spar - (P-1)α))
fn smallest_fitting_c(net: &NetworkModel, d: usize, t_spar: f64, budget: f64) -> Option<f64> {
    let p = net.workers as f64;
    if net.workers <= 1 {
        return Some(1.0); // no communication at all
    }
    let fixed = t_spar + (p - 1.0) * net.alpha;
    if budget <= fixed {
        return None; // even k=0 wouldn't fit: latency alone exceeds budget
    }
    let c = 8.0 * d as f64 * (p - 1.0) / (net.bandwidth * (budget - fixed));
    Some(c.max(1.0))
}

/// Select c^(l) for every layer of `model` (backprop order). Layer l's
/// budget is the backward time of layer l+1 (the next to compute); the last
/// layer gets no overlap budget and is capped at c_max.
pub fn select_ratios(model: &ModelProfile, net: &NetworkModel, cfg: &RatioConfig) -> Vec<f64> {
    let l = model.layers.len();
    let mut out = Vec::with_capacity(l);
    for i in 0..l {
        let d = model.layers[i].params;
        let t_spar = cfg.spar_fixed + cfg.spar_per_elem * d as f64;
        let budget = if i + 1 < l { model.layers[i + 1].t_b } else { 0.0 };
        let c = match smallest_fitting_c(net, d, t_spar, budget) {
            Some(c) => c.clamp(cfg.c_min, cfg.c_max),
            None => cfg.c_max,
        };
        out.push(c);
    }
    out
}

/// Effective global compression c_max over the selection (drives the
/// convergence bound of Corollary 2).
pub fn effective_cmax(ratios: &[f64]) -> f64 {
    ratios.iter().cloned().fold(1.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn ratios_within_bounds() {
        let m = zoo::resnet50();
        let net = NetworkModel::gige_16();
        let cfg = RatioConfig::default();
        let rs = select_ratios(&m, &net, &cfg);
        assert_eq!(rs.len(), m.layers.len());
        assert!(rs.iter().all(|&c| (cfg.c_min..=cfg.c_max).contains(&c)));
    }

    #[test]
    fn faster_network_needs_less_compression() {
        let m = zoo::lstm_ptb();
        let cfg = RatioConfig::default();
        let slow = NetworkModel { alpha: 5e-4, bandwidth: 111e6, workers: 16 };
        let fast = NetworkModel { alpha: 5e-6, bandwidth: 111e8, workers: 16 };
        let rs_slow = select_ratios(&m, &slow, &cfg);
        let rs_fast = select_ratios(&m, &fast, &cfg);
        for (s, f) in rs_slow.iter().zip(rs_fast.iter()) {
            assert!(f <= s, "fast {f} > slow {s}");
        }
        // 100x network should drop at least one layer's requirement
        assert!(rs_fast.iter().sum::<f64>() < rs_slow.iter().sum::<f64>());
    }

    #[test]
    fn selected_comm_fits_budget_when_not_capped() {
        let m = zoo::resnet50();
        let net = NetworkModel::gige_16();
        let cfg = RatioConfig::default();
        let rs = select_ratios(&m, &net, &cfg);
        for i in 0..m.layers.len() - 1 {
            let c = rs[i];
            if c < cfg.c_max - 1e-9 && c > cfg.c_min + 1e-9 {
                let d = m.layers[i].params;
                let t_spar = cfg.spar_fixed + cfg.spar_per_elem * d as f64;
                let t = net.layer_comm_time(d, c) + t_spar;
                assert!(t <= m.layers[i + 1].t_b + 1e-9, "layer {i}: {t}");
            }
        }
    }

    #[test]
    fn single_worker_all_dense() {
        let m = zoo::resnet50();
        let net = NetworkModel::gige_16().with_workers(1);
        let rs = select_ratios(&m, &net, &RatioConfig::default());
        assert!(rs.iter().all(|&c| c == 1.0));
    }

    #[test]
    fn last_layer_capped() {
        let m = zoo::resnet50();
        let net = NetworkModel::gige_16();
        let cfg = RatioConfig::default();
        let rs = select_ratios(&m, &net, &cfg);
        assert_eq!(*rs.last().unwrap(), cfg.c_max);
    }

    #[test]
    fn effective_cmax_is_max() {
        assert_eq!(effective_cmax(&[1.0, 250.0, 10.0]), 250.0);
    }
}
