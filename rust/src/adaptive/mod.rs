//! The ADAPTIVE half of LAGS: per-layer compression-ratio selection
//! (Eq. 18) and the pipelining speedup bound (Eq. 19).
//!
//! * [`ratio`] — choose c^(l) so each layer's communication (plus its
//!   sparsification overhead) hides under the next layer's backward
//!   computation, capped at c_u.
//! * [`perf_model`] — Eq. 19's S_max and the r = t_c/t_b analysis.

pub mod perf_model;
pub mod ratio;

pub use perf_model::{smax, smax_components};
pub use ratio::{select_ratios, RatioConfig};
