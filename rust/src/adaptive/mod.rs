//! The ADAPTIVE half of LAGS: per-layer compression-ratio selection
//! (Eq. 18) and the pipelining speedup bound (Eq. 19).
//!
//! * [`ratio`] — choose c^(l) so each layer's communication (plus its
//!   sparsification overhead) hides under the next layer's backward
//!   computation, capped at c_u.
//! * [`online`] — the measurement-driven half: EWMA accumulation of
//!   per-layer hot-loop timings so the trainer can re-run Eq. 18 from
//!   MEASURED inputs every `--reselect-every` steps.
//! * [`perf_model`] — Eq. 19's S_max and the r = t_c/t_b analysis.

pub mod online;
pub mod perf_model;
pub mod ratio;

pub use online::MeasuredProfile;
pub use perf_model::{smax, smax_components};
pub use ratio::{
    ks_from_ratios, select_ratios, select_ratios_manifest, select_ratios_measured,
    select_ratios_measured_manifest, RatioConfig,
};
