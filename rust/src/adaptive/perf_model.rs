//! Eq. 19: the maximum speedup of LAGS-SGD over SLGS-SGD from pipelining.
//!
//! ```text
//! S_max = 1 + 1 / ( t_f / min(t_c, t_b)  +  max(r, 1/r) ),   r = t_c / t_b
//! ```
//!
//! The bound: pipelining can hide at most min(t_b, t_c) of the iteration,
//! so S = (t_f + t_b + t_c) / (t_f + t_b + t_c - min(t_b, t_c)).

/// Eq. 19 with explicit (t_f, t_b, t_c).
pub fn smax(t_f: f64, t_b: f64, t_c: f64) -> f64 {
    assert!(t_f >= 0.0 && t_b > 0.0 && t_c >= 0.0);
    if t_c == 0.0 {
        return 1.0; // nothing to hide
    }
    let r = t_c / t_b;
    1.0 + 1.0 / (t_f / t_c.min(t_b) + r.max(1.0 / r))
}

/// Direct form S = total / (total - hidden); must equal [`smax`].
pub fn smax_direct(t_f: f64, t_b: f64, t_c: f64) -> f64 {
    let total = t_f + t_b + t_c;
    let hidden = t_b.min(t_c);
    total / (total - hidden)
}

/// Decomposition used by the Table-2 harness: (S_max, r, upper bound
/// 1 + t_b/(t_f+t_b) reached at r == 1).
pub fn smax_components(t_f: f64, t_b: f64, t_c: f64) -> (f64, f64, f64) {
    (smax(t_f, t_b, t_c), t_c / t_b, 1.0 + t_b / (t_f + t_b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_direct_form() {
        for &(f, b, c) in
            &[(0.2, 0.4, 0.3), (0.1, 1.0, 1.0), (0.5, 0.3, 2.0), (0.0, 1.0, 0.5), (0.3, 0.7, 0.01)]
        {
            let a = smax(f, b, c);
            let d = smax_direct(f, b, c);
            assert!((a - d).abs() < 1e-12, "({f},{b},{c}): {a} vs {d}");
        }
    }

    #[test]
    fn peak_at_r_equal_one() {
        let (t_f, t_b) = (0.2, 0.5);
        let peak = smax(t_f, t_b, t_b);
        for &c in &[0.1, 0.25, 0.45, 0.55, 1.0, 3.0] {
            assert!(smax(t_f, t_b, c) <= peak + 1e-12, "c={c}");
        }
        // and the peak equals the paper's upper bound 1 + t_b/(t_f+t_b)
        assert!((peak - (1.0 + t_b / (t_f + t_b))).abs() < 1e-12);
    }

    #[test]
    fn bounded_below_by_one() {
        for &(f, b, c) in &[(0.1, 0.2, 0.001), (1.0, 0.1, 10.0), (0.0, 0.5, 0.0)] {
            assert!(smax(f, b, c) >= 1.0);
        }
    }

    #[test]
    fn no_comm_no_speedup() {
        assert_eq!(smax(0.2, 0.4, 0.0), 1.0);
    }

    #[test]
    fn paper_table2_magnitudes() {
        // ResNet-50 calibration (t_f=0.21, t_b=0.41, sparse t_c≈0.33)
        // should land near the paper's S_max = 1.52
        let s = smax(0.21, 0.41, 0.33);
        assert!((1.35..1.7).contains(&s), "resnet50 S_max={s}");
        // LSTM-PTB: t_f=0.23, t_b=0.46, t_c≈0.33 → paper 1.28
        let s2 = smax(0.23, 0.46, 0.33);
        assert!((1.2..1.6).contains(&s2), "lstm S_max={s2}");
    }
}
