//! Online measurement-driven ratio re-selection — the "A" in LAGS made
//! real.
//!
//! The startup selection prices Eq. 18 with a static device profile —
//! manifest flops at the runtime's `device_flops()`, i.e. the persisted
//! `lags calibrate` measurement when one exists, else the documented
//! [`crate::models::DEVICE_FLOPS`] fallback. Either way that profile is
//! fixed at startup; this module replaces it with MEASURED hot-loop
//! timings: every step the trainer feeds
//!
//! * the wall-clock of the forward+backward fan-out (the compute
//!   stream; the backward share is 2/3 by the bwd ≈ 2×fwd flops ratio),
//! * each layer's error-feedback compression time (mean across ranks),
//! * each layer's rank-ordered reduction time (from the same per-layer
//!   busy intervals the [`crate::collectives::pipeline::OverlapTimer`]
//!   accounting observes),
//!
//! into an EWMA [`MeasuredProfile`]; every `--reselect-every N` steps the
//! trainer re-runs Eq. 18 over the measured profile and swaps in the new
//! `ks`/`ratios` — strictly BETWEEN steps, so a fixed schedule
//! (`reselect_every = 0`) is bit-for-bit untouched and the
//! barrier ≡ overlap determinism contract holds per schedule.
//!
//! The network stays a CONFIGURED α–β model (`--net*` / `NetConfig`): the
//! logical cluster has no real NIC to clock, so communication is priced
//! while computation and sparsification are measured.

use crate::models::{LayerProfile, ModelProfile};

/// EWMA weight of the newest sample: s ← β·x + (1−β)·s. Small enough to
/// ride out scheduler noise, large enough to track a phase change within
/// a few tens of steps.
const EWMA_BETA: f64 = 0.2;

/// EWMA-accumulated measured per-layer timings (stored in MANIFEST
/// order; Eq. 18 consumers read them out in backprop order).
#[derive(Debug, Clone)]
pub struct MeasuredProfile {
    /// layer names, manifest order
    names: Vec<String>,
    /// parameter counts, manifest order
    params: Vec<usize>,
    /// each layer's share of total backward flops, manifest order — the
    /// backward runs as one fused pass per worker, so the measured total
    /// is attributed per layer by flops weight rather than clocked per
    /// layer
    flops_frac: Vec<f64>,
    /// EWMA of the COMPUTE (forward + backward) fan-out wall-clock per
    /// step (s) — the trainer's grad call runs both passes, so the
    /// backward share is derived via the bwd ≈ 2×fwd flops ratio
    t_comp: f64,
    /// EWMA per-layer compression seconds, manifest order
    t_compress: Vec<f64>,
    /// EWMA per-layer reduction seconds, manifest order
    t_reduce: Vec<f64>,
    /// steps observed so far
    steps: usize,
}

impl MeasuredProfile {
    /// `names`/`params`/`fwd_flops` come straight from the model manifest
    /// (manifest order).
    pub fn new(names: Vec<String>, params: Vec<usize>, fwd_flops: Vec<f64>) -> MeasuredProfile {
        let n = names.len();
        assert!(n > 0 && params.len() == n && fwd_flops.len() == n);
        let total: f64 = fwd_flops.iter().sum();
        let flops_frac = if total > 0.0 {
            fwd_flops.iter().map(|f| f / total).collect()
        } else {
            vec![1.0 / n as f64; n]
        };
        MeasuredProfile {
            names,
            params,
            flops_frac,
            t_comp: 0.0,
            t_compress: vec![0.0; n],
            t_reduce: vec![0.0; n],
            steps: 0,
        }
    }

    fn fold(prev: f64, x: f64, first: bool) -> f64 {
        if first {
            x
        } else {
            EWMA_BETA * x + (1.0 - EWMA_BETA) * prev
        }
    }

    /// Feed one step's measurements (slices in manifest order;
    /// `comp_secs` is the forward+backward fan-out wall-clock). The
    /// first observation seeds the EWMA directly.
    pub fn observe_step(&mut self, comp_secs: f64, compress_secs: &[f64], reduce_secs: &[f64]) {
        debug_assert_eq!(compress_secs.len(), self.t_compress.len());
        debug_assert_eq!(reduce_secs.len(), self.t_reduce.len());
        let first = self.steps == 0;
        self.t_comp = Self::fold(self.t_comp, comp_secs.max(0.0), first);
        for (t, &x) in self.t_compress.iter_mut().zip(compress_secs) {
            *t = Self::fold(*t, x.max(0.0), first);
        }
        for (t, &x) in self.t_reduce.iter_mut().zip(reduce_secs) {
            *t = Self::fold(*t, x.max(0.0), first);
        }
        self.steps += 1;
    }

    /// [`Self::observe_step`] with the compute sample re-inflated by a
    /// straggler `gate` (`cluster::faults::compute_gate`). The calling
    /// thread clocks ITS OWN fan-out wall-clock, but under a fault plan
    /// the synchronous step is paced by the gating worker's skew — so
    /// Eq. 18 must re-select against `gate × comp_secs`, the measured
    /// straggler-inflated profile, not the local one. `gate = 1.0`
    /// reduces to `observe_step` exactly (bit-identical fold), keeping
    /// the no-fault determinism contract intact.
    pub fn observe_step_skewed(
        &mut self,
        comp_secs: f64,
        gate: f64,
        compress_secs: &[f64],
        reduce_secs: &[f64],
    ) {
        self.observe_step(comp_secs * gate.max(0.0), compress_secs, reduce_secs);
    }

    /// Number of steps folded in so far (0 = nothing measured yet).
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Checkpoint snapshot of the EWMA state: `(t_comp, t_compress,
    /// t_reduce, steps)`. The structural fields (names / params /
    /// flops_frac) are NOT captured — they are a pure function of the
    /// model manifest and are rebuilt by [`Self::new`] on restore.
    pub fn ewma_snapshot(&self) -> (f64, Vec<f64>, Vec<f64>, usize) {
        (self.t_comp, self.t_compress.clone(), self.t_reduce.clone(), self.steps)
    }

    /// Install an EWMA state captured by [`Self::ewma_snapshot`] onto a
    /// freshly-built profile (same manifest ⇒ same layer count).
    pub fn restore_ewma(
        &mut self,
        t_comp: f64,
        t_compress: &[f64],
        t_reduce: &[f64],
        steps: usize,
    ) {
        assert_eq!(t_compress.len(), self.t_compress.len(), "layer count changed under restore");
        assert_eq!(t_reduce.len(), self.t_reduce.len(), "layer count changed under restore");
        self.t_comp = t_comp;
        self.t_compress.copy_from_slice(t_compress);
        self.t_reduce.copy_from_slice(t_reduce);
        self.steps = steps;
    }

    /// Smoothed forward+backward compute wall-clock (s).
    pub fn compute_seconds(&self) -> f64 {
        self.t_comp
    }

    /// Smoothed per-layer reduction seconds, manifest order (diagnostics).
    pub fn reduce_seconds(&self) -> &[f64] {
        &self.t_reduce
    }

    /// Measured model profile in BACKPROP order for Eq. 18. The clocked
    /// compute covers forward + backward; with bwd ≈ 2×fwd flops, the
    /// backward share is 2/3 of the measurement, apportioned per layer
    /// by flops fraction (t_f — the remaining 1/3 — is not consumed by
    /// the selection, only carried for reporting).
    pub fn profile(&self, model_name: &str) -> ModelProfile {
        let t_b_total = self.t_comp * 2.0 / 3.0;
        let layers: Vec<LayerProfile> = self
            .names
            .iter()
            .zip(self.params.iter())
            .zip(self.flops_frac.iter())
            .rev()
            .map(|((name, &params), &frac)| LayerProfile {
                name: name.clone(),
                params,
                t_b: t_b_total * frac,
            })
            .collect();
        ModelProfile { name: model_name.to_string(), t_f: self.t_comp / 3.0, layers }
    }

    /// Measured per-layer pipeline overhead (compression + reduction
    /// seconds) in BACKPROP order — the `t_spar` Eq. 18 charges against
    /// each layer's overlap budget
    /// ([`crate::adaptive::select_ratios_measured`]).
    pub fn overhead_backprop(&self) -> Vec<f64> {
        self.t_compress
            .iter()
            .zip(self.t_reduce.iter())
            .rev()
            .map(|(&c, &r)| c + r)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mp() -> MeasuredProfile {
        MeasuredProfile::new(
            vec!["a".into(), "b".into(), "c".into()],
            vec![100, 200, 300],
            vec![1e6, 2e6, 1e6],
        )
    }

    #[test]
    fn first_observation_seeds_ewma() {
        let mut m = mp();
        assert_eq!(m.steps(), 0);
        m.observe_step(0.4, &[0.01, 0.02, 0.03], &[0.001, 0.002, 0.003]);
        assert_eq!(m.steps(), 1);
        assert_eq!(m.compute_seconds(), 0.4);
        assert_eq!(m.reduce_seconds(), &[0.001, 0.002, 0.003]);
    }

    #[test]
    fn ewma_moves_toward_new_samples() {
        let mut m = mp();
        m.observe_step(0.4, &[0.01; 3], &[0.0; 3]);
        m.observe_step(0.8, &[0.03; 3], &[0.0; 3]);
        // β = 0.2: 0.2·0.8 + 0.8·0.4 = 0.48
        assert!((m.compute_seconds() - 0.48).abs() < 1e-12);
        for _ in 0..200 {
            m.observe_step(0.8, &[0.03; 3], &[0.0; 3]);
        }
        assert!((m.compute_seconds() - 0.8).abs() < 1e-6, "converges to the plateau");
    }

    #[test]
    fn profile_is_backprop_ordered_and_flops_weighted() {
        let mut m = mp();
        m.observe_step(0.6, &[0.0; 3], &[0.0; 3]);
        let p = m.profile("t");
        assert_eq!(p.layers.len(), 3);
        // backprop order: manifest layer "c" (output side) first
        assert_eq!(p.layers[0].name, "c");
        assert_eq!(p.layers[2].name, "a");
        assert_eq!(p.layers[0].params, 300);
        // backward share = 2/3 of the 0.6s compute = 0.4s, split by the
        // flops fractions 0.25 / 0.5 / 0.25; forward gets the last 1/3
        assert!((p.layers[0].t_b - 0.1).abs() < 1e-12);
        assert!((p.layers[1].t_b - 0.2).abs() < 1e-12);
        assert!((p.t_b() - 0.4).abs() < 1e-12);
        assert!((p.t_f - 0.2).abs() < 1e-12);
    }

    #[test]
    fn overhead_sums_compress_and_reduce_in_backprop_order() {
        let mut m = mp();
        m.observe_step(0.4, &[0.01, 0.02, 0.03], &[0.001, 0.002, 0.003]);
        let o = m.overhead_backprop();
        assert_eq!(o.len(), 3);
        assert!((o[0] - 0.033).abs() < 1e-12); // layer "c"
        assert!((o[2] - 0.011).abs() < 1e-12); // layer "a"
    }

    #[test]
    fn skewed_observation_inflates_only_compute() {
        let mut plain = mp();
        let mut skewed = mp();
        plain.observe_step(1.6, &[0.01; 3], &[0.002; 3]);
        skewed.observe_step_skewed(0.4, 4.0, &[0.01; 3], &[0.002; 3]);
        assert_eq!(skewed.compute_seconds(), plain.compute_seconds());
        assert_eq!(skewed.reduce_seconds(), plain.reduce_seconds());
        // gate 1.0 is bit-identical to the un-gated call
        let mut a = mp();
        let mut b = mp();
        a.observe_step(0.37, &[0.01; 3], &[0.002; 3]);
        b.observe_step_skewed(0.37, 1.0, &[0.01; 3], &[0.002; 3]);
        assert_eq!(a.compute_seconds(), b.compute_seconds());
    }

    #[test]
    fn ewma_snapshot_restore_is_bit_identical() {
        let mut m = mp();
        m.observe_step(0.4, &[0.01, 0.02, 0.03], &[0.001, 0.002, 0.003]);
        m.observe_step(0.7, &[0.02, 0.01, 0.04], &[0.002, 0.001, 0.004]);
        let (tc, comp, red, steps) = m.ewma_snapshot();
        let mut fresh = mp();
        fresh.restore_ewma(tc, &comp, &red, steps);
        // the restored profile folds the NEXT observation identically
        m.observe_step(0.9, &[0.03; 3], &[0.005; 3]);
        fresh.observe_step(0.9, &[0.03; 3], &[0.005; 3]);
        assert_eq!(m.compute_seconds(), fresh.compute_seconds());
        assert_eq!(m.reduce_seconds(), fresh.reduce_seconds());
        assert_eq!(m.overhead_backprop(), fresh.overhead_backprop());
        assert_eq!(m.steps(), fresh.steps());
    }

    #[test]
    fn negative_samples_clamped() {
        let mut m = mp();
        m.observe_step(-1.0, &[-0.5; 3], &[-0.5; 3]);
        assert_eq!(m.compute_seconds(), 0.0);
    }
}
