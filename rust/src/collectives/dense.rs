//! Dense ring allreduce — the numeric reduction a real NCCL/Horovod run
//! performs, executed in-process over the logical workers' gradient
//! buffers.
//!
//! The reduction follows the actual ring schedule (reduce-scatter then
//! allgather over P-1 steps each, chunked by rank) rather than a naive
//! `sum/P`, so floating-point association matches what a real ring
//! allreduce produces and the result is identical across our workers —
//! exactly the property S-SGD relies on for replica consistency.

/// In-place ring allreduce over P worker buffers, then divide by P
/// (gradient averaging). All buffers must be the same length; on return
/// every buffer holds the same averaged vector.
pub fn ring_allreduce_mean(buffers: &mut [Vec<f32>]) {
    let p = buffers.len();
    assert!(p > 0);
    let n = buffers[0].len();
    assert!(buffers.iter().all(|b| b.len() == n));
    if p == 1 {
        return;
    }

    // chunk boundaries: chunk r covers [starts[r], starts[r+1])
    let starts: Vec<usize> = (0..=p).map(|r| r * n / p).collect();

    // reduce-scatter: at step s rank r sends chunk (r - s) mod p to rank
    // r+1, which accumulates it. After p-1 steps rank r fully owns chunk
    // (r + 1) mod p. Sequential in-place processing is hazard-free: the
    // chunk a rank sends at step s is never the chunk it receives at step s.
    for s in 0..p - 1 {
        for r in 0..p {
            let src = (r + p - s) % p; // chunk r sends at step s
            let dst = (r + 1) % p;
            let (a, b) = (starts[src], starts[src + 1]);
            // dst.chunk += r.chunk  (split_at_mut to borrow two buffers)
            let (lo, hi) = if r < dst {
                let (l, h) = buffers.split_at_mut(dst);
                (&l[r], &mut h[0])
            } else {
                let (l, h) = buffers.split_at_mut(r);
                let dst_ref = &mut l[dst];
                (&h[0] as &Vec<f32>, dst_ref)
            };
            for i in a..b {
                hi[i] += lo[i];
            }
        }
    }

    // each rank r now fully owns chunk (r+1 mod p); average it
    for r in 0..p {
        let own = (r + 1) % p;
        let (a, b) = (starts[own], starts[own + 1]);
        let inv = 1.0 / p as f32;
        for i in a..b {
            buffers[r][i] *= inv;
        }
    }

    // allgather: propagate owned chunks around the ring
    for s in 0..p - 1 {
        for r in 0..p {
            let src_chunk = (r + 1 + p - s) % p; // chunk r sends at step s
            let dst = (r + 1) % p;
            let (a, b) = (starts[src_chunk], starts[src_chunk + 1]);
            let (src_buf, dst_buf) = if r < dst {
                let (l, h) = buffers.split_at_mut(dst);
                (&l[r], &mut h[0])
            } else {
                let (l, h) = buffers.split_at_mut(r);
                (&h[0] as &Vec<f32>, &mut l[dst])
            };
            dst_buf[a..b].copy_from_slice(&src_buf[a..b]);
        }
    }
}

/// Reference implementation: sum / P with a fixed left-to-right order.
/// Used by tests to bound the ring result (association differs, so allow
/// f32 tolerance).
pub fn naive_mean(buffers: &[Vec<f32>]) -> Vec<f32> {
    let p = buffers.len();
    let n = buffers[0].len();
    let mut out = vec![0.0f32; n];
    for b in buffers {
        for i in 0..n {
            out[i] += b[i];
        }
    }
    for v in out.iter_mut() {
        *v /= p as f32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn make(p: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..p).map(|_| (0..n).map(|_| rng.normal_f32()).collect()).collect()
    }

    #[test]
    fn matches_naive_mean() {
        for &(p, n) in &[(2usize, 10usize), (3, 17), (4, 64), (8, 100), (16, 31)] {
            let mut bufs = make(p, n, p as u64 * 1000 + n as u64);
            let expect = naive_mean(&bufs);
            ring_allreduce_mean(&mut bufs);
            for r in 0..p {
                for i in 0..n {
                    assert!(
                        (bufs[r][i] - expect[i]).abs() < 1e-4,
                        "p={p} n={n} rank={r} i={i}: {} vs {}",
                        bufs[r][i],
                        expect[i]
                    );
                }
            }
        }
    }

    #[test]
    fn replicas_bitwise_identical() {
        let mut bufs = make(8, 1000, 42);
        ring_allreduce_mean(&mut bufs);
        for r in 1..8 {
            assert_eq!(bufs[0], bufs[r], "rank {r} diverged");
        }
    }

    #[test]
    fn single_worker_noop() {
        let mut bufs = make(1, 16, 7);
        let orig = bufs[0].clone();
        ring_allreduce_mean(&mut bufs);
        assert_eq!(bufs[0], orig);
    }

    #[test]
    fn n_smaller_than_p() {
        let mut bufs = make(8, 3, 9);
        let expect = naive_mean(&bufs);
        ring_allreduce_mean(&mut bufs);
        for r in 0..8 {
            for i in 0..3 {
                assert!((bufs[r][i] - expect[i]).abs() < 1e-5);
            }
        }
    }
}
