//! Streaming per-layer reduction pipeline (the paper's Fig. 1(c) dataflow
//! realised in the real trainer, not just the DES).
//!
//! In barrier mode the trainer runs `compute-all → reduce-all`, so wall
//! clock is `T_compute + T_reduce`. Here each worker publishes layer `l`'s
//! [`SparseVec`] message the moment that layer's compression finishes
//! ([`LayerMsg`] through an `mpsc` sink), and the aggregator — the calling
//! thread of [`crate::util::ParallelExecutor::run_with_sink`] — consumes
//! layers in backprop order as soon as all `P` messages for a layer have
//! landed, reducing (and applying) them **concurrently** with workers that
//! are still compressing earlier layers: `max(T_compute, T_reduce)`.
//!
//! Determinism survives the overlap (DESIGN.md §Streaming-overlap):
//!
//! * within a layer the reduction stays rank-ordered 0..P-1 — messages
//!   land in rank-indexed slots, and a layer is reduced only once all P
//!   slots are full, in slot order;
//! * across layers the aggregate slices are disjoint, so the (arbitrary)
//!   completion order cannot change any f32 sum;
//! * the apply `v ← v − (μ·m + agg/P)` is elementwise, so applying it
//!   per-layer as each slice completes is bit-identical to the dense
//!   end-of-step pass.
//!
//! `--pipeline {barrier,overlap}` is therefore a pure performance knob,
//! enforced bit-for-bit by `rust/tests/integration_parallel.rs`.

use crate::sparsify::sparse::SparseVec;
use anyhow::Result;
use std::time::{Duration, Instant};

/// Which hot-loop schedule the trainer runs (`--pipeline`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipelineMode {
    /// Fork-join: all workers finish compressing, then one rank-ordered
    /// reduction pass, then one dense apply pass.
    Barrier,
    /// Streaming: per-layer publish, reduce + apply each layer as soon as
    /// its P messages land, overlapped with the remaining compute.
    Overlap,
}

impl PipelineMode {
    pub fn parse(s: &str) -> Result<PipelineMode> {
        Ok(match s {
            "barrier" => PipelineMode::Barrier,
            "overlap" => PipelineMode::Overlap,
            _ => anyhow::bail!("unknown pipeline mode {s:?} (barrier|overlap)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PipelineMode::Barrier => "barrier",
            PipelineMode::Overlap => "overlap",
        }
    }
}

/// One layer's sparse message from one worker rank, published the moment
/// that layer's compression finished. `sent` is stamped on the producing
/// thread, so the aggregator can tell overlapped work from tail work.
pub struct LayerMsg {
    pub rank: usize,
    pub layer: usize,
    pub msg: SparseVec,
    pub sent: Instant,
}

/// Measured overlap of the streamed reduction phase: how much of the
/// aggregator's busy time was hidden under still-running compute. The
/// real-trainer counterpart of the DES's `hidden` / `t_comm` split.
#[derive(Debug, Clone, Copy, Default)]
pub struct OverlapMeasure {
    /// total aggregator busy time (zero + reduce + apply), seconds
    pub busy_seconds: f64,
    /// busy time hidden under compute (spent before the last publish)
    pub hidden_seconds: f64,
}

impl OverlapMeasure {
    /// The un-hidden tail — busy time after the last worker published.
    pub fn tail_seconds(&self) -> f64 {
        (self.busy_seconds - self.hidden_seconds).max(0.0)
    }

    /// hidden / busy in [0, 1]; 0 when nothing was streamed (barrier runs).
    pub fn efficiency(&self) -> f64 {
        if self.busy_seconds > 0.0 {
            self.hidden_seconds / self.busy_seconds
        } else {
            0.0
        }
    }

    pub fn accumulate(&mut self, other: &OverlapMeasure) {
        self.busy_seconds += other.busy_seconds;
        self.hidden_seconds += other.hidden_seconds;
    }
}

/// Wall-clock bookkeeping for one streamed phase. Busy intervals are
/// recorded per reduced layer; the portion of each interval that lies
/// before the **last send timestamp** counts as hidden (compute was still
/// producing messages), mirroring `desim`'s `hidden = t_comm − tail`.
/// Timestamps are production-side (`LayerMsg::sent`), so a degenerate
/// sequential run — where every message is produced before draining
/// starts — correctly measures zero hidden time.
#[derive(Debug)]
pub struct OverlapTimer {
    last_sent: Option<Instant>,
    intervals: Vec<(Instant, Instant)>,
}

impl OverlapTimer {
    pub fn new() -> OverlapTimer {
        OverlapTimer { last_sent: None, intervals: Vec::new() }
    }

    pub fn note_sent(&mut self, sent: Instant) {
        self.last_sent = Some(match self.last_sent {
            Some(t) => t.max(sent),
            None => sent,
        });
    }

    pub fn note_busy(&mut self, start: Instant, end: Instant) {
        self.intervals.push((start, end));
    }

    pub fn finish(&self) -> OverlapMeasure {
        let mut busy = Duration::ZERO;
        let mut hidden = Duration::ZERO;
        for &(s, e) in &self.intervals {
            busy += e.saturating_duration_since(s);
            if let Some(ls) = self.last_sent {
                hidden += e.min(ls).saturating_duration_since(s);
            }
        }
        OverlapMeasure {
            busy_seconds: busy.as_secs_f64(),
            hidden_seconds: hidden.as_secs_f64(),
        }
    }
}

impl Default for OverlapTimer {
    fn default() -> Self {
        Self::new()
    }
}

/// Rank-indexed readiness table for the streamed reduction.
///
/// Messages arrive in any interleaving (each worker publishes its own
/// layers in backprop order, but workers race each other); [`Self::push`]
/// buffers them in `[layer][rank]` slots and fires the completion callback
/// for each layer **in strict backprop order** (layer L-1 first,
/// descending) once all `P` ranks have landed — the order Algorithm 2
/// consumes layers, and the order the NIC stream of the DES transmits
/// them. The callback receives the rank-ordered slot slice, so the
/// per-layer f32 reduction is independent of arrival order (asserted by
/// `prop_stream_aggregator_arrival_order_invariant`).
pub struct StreamAggregator {
    /// arrived messages, `slots[layer][rank]`; `None` until published
    slots: Vec<Vec<Option<SparseVec>>>,
    /// per-layer count of arrivals from REQUIRED ranks (non-required
    /// arrivals land in their slots but never gate firing)
    arrived: Vec<usize>,
    /// next layer to fire, walking L-1 → 0; `None` once all fired
    cursor: Option<usize>,
    workers: usize,
    /// this step's participation mask (bounded-staleness quorum): a layer
    /// fires once every `true` rank has landed. All-true by default and
    /// after every `reset`.
    required: Vec<bool>,
    required_count: usize,
}

impl StreamAggregator {
    pub fn new(layers: usize, workers: usize) -> StreamAggregator {
        assert!(layers > 0 && workers > 0);
        StreamAggregator {
            slots: (0..layers).map(|_| (0..workers).map(|_| None).collect()).collect(),
            arrived: vec![0; layers],
            cursor: Some(layers - 1),
            workers,
            required: vec![true; workers],
            required_count: workers,
        }
    }

    /// Rebuild the table for a new (layers, workers) shape — elastic
    /// membership resizes the live aggregator between steps. Equivalent to
    /// constructing fresh, but keeps the allocation when the shape is
    /// unchanged.
    pub fn resize(&mut self, layers: usize, workers: usize) {
        assert!(layers > 0 && workers > 0);
        if layers == self.layers() && workers == self.workers {
            self.reset();
            return;
        }
        *self = StreamAggregator::new(layers, workers);
    }

    pub fn layers(&self) -> usize {
        self.slots.len()
    }

    /// Rank (worker) count of the table.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// This step's rank participation mask (all-true when quorum is off).
    pub fn required(&self) -> &[bool] {
        &self.required
    }

    /// Number of required ranks — the per-layer message count the merged
    /// reduction consumes.
    pub fn required_count(&self) -> usize {
        self.required_count
    }

    /// Arm a per-step participation mask: layers fire once every `true`
    /// rank has landed; excluded ranks' messages still land in their slots
    /// (the trainer reclaims them and folds them back into that worker's
    /// error-feedback residual) but never gate firing. Must be armed
    /// before the step's first push; `reset` restores all-required.
    pub fn arm_participants(&mut self, mask: &[bool]) {
        assert_eq!(mask.len(), self.workers, "mask must be rank-aligned");
        debug_assert!(self.arrived.iter().all(|&a| a == 0), "arm before pushing");
        self.required.copy_from_slice(mask);
        self.required_count = mask.iter().filter(|&&b| b).count();
        assert!(self.required_count > 0, "at least one rank must participate");
    }

    /// Rank-indexed slots of `layer` — all `Some` once the layer has
    /// fired. The trainer's merged-group reduction reads payloads from
    /// here after the completion callback recorded the layer, so buffers
    /// stay in the table for the post-step reclaim.
    pub fn layer_slots(&self, layer: usize) -> &[Option<SparseVec>] {
        &self.slots[layer]
    }

    /// Arm for a new step: counts reset, cursor back to the last layer,
    /// participation back to all-required. Slots are normally already
    /// empty (the trainer reclaims buffers after each step); leftovers
    /// from an aborted step are dropped.
    pub fn reset(&mut self) {
        for layer in &mut self.slots {
            for slot in layer.iter_mut() {
                *slot = None;
            }
        }
        self.arrived.iter_mut().for_each(|a| *a = 0);
        self.cursor = Some(self.slots.len() - 1);
        self.required.iter_mut().for_each(|r| *r = true);
        self.required_count = self.workers;
    }

    /// All layers fired?
    pub fn finished(&self) -> bool {
        self.cursor.is_none()
    }

    /// Land one message; fire `on_layer(layer, rank_ordered_slots)` for
    /// every layer that becomes consumable, in backprop order. With a
    /// quorum mask armed, only required ranks' arrivals count toward
    /// firing — excluded slots may still be `None` when the layer fires,
    /// and the consumer must filter by [`Self::required`].
    pub fn push<F>(&mut self, m: LayerMsg, mut on_layer: F)
    where
        F: FnMut(usize, &[Option<SparseVec>]),
    {
        debug_assert!(m.layer < self.slots.len() && m.rank < self.workers);
        debug_assert!(self.slots[m.layer][m.rank].is_none(), "duplicate publish");
        let counts = self.required[m.rank];
        self.slots[m.layer][m.rank] = Some(m.msg);
        if counts {
            self.arrived[m.layer] += 1;
        }
        while let Some(next) = self.cursor {
            if self.arrived[next] < self.required_count {
                break;
            }
            on_layer(next, &self.slots[next]);
            self.cursor = next.checked_sub(1);
        }
    }

    /// Take back the message buffer for `(layer, rank)` so the trainer can
    /// return it to its owning worker — the steady-state loop keeps zero
    /// allocation because buffers cycle worker → channel → table → worker.
    pub fn take(&mut self, layer: usize, rank: usize) -> Option<SparseVec> {
        self.slots[layer][rank].take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::sparse_agg;
    use crate::util::rng::Rng;

    fn msg(rank: usize, layer: usize, n: usize, seed: u64) -> LayerMsg {
        let mut rng = Rng::new(seed);
        let mut dense = vec![0.0f32; n];
        for i in rng.sample_distinct(n, (n / 3).max(1)) {
            dense[i] = rng.normal_f32();
        }
        LayerMsg { rank, layer, msg: SparseVec::from_dense(&dense), sent: crate::util::clock::now() }
    }

    #[test]
    fn fires_layers_in_backprop_order() {
        let (layers, workers, n) = (3usize, 2usize, 16usize);
        let mut agg = StreamAggregator::new(layers, workers);
        let mut fired = Vec::new();
        // arrival order deliberately front-to-back: layer 0 completes first
        for layer in 0..layers {
            for rank in 0..workers {
                agg.push(msg(rank, layer, n, (layer * 7 + rank) as u64), |l, slots| {
                    assert!(slots.iter().all(|s| s.is_some()));
                    fired.push(l);
                });
            }
        }
        assert_eq!(fired, vec![2, 1, 0], "strict backprop order");
        assert!(agg.finished());
    }

    #[test]
    fn reduction_matches_rank_order_regardless_of_arrival() {
        let (layers, workers, n) = (4usize, 3usize, 32usize);
        // reference: rank-ordered reduction per layer
        let mut expect = vec![vec![0.0f32; n]; layers];
        let mut msgs = Vec::new();
        for layer in 0..layers {
            for rank in 0..workers {
                let m = msg(rank, layer, n, (layer * 100 + rank) as u64);
                m.msg.add_into(&mut expect[layer]);
                msgs.push(m);
            }
        }
        // adversarial arrival: reverse rank order, layers interleaved
        msgs.reverse();
        let mut agg = StreamAggregator::new(layers, workers);
        let mut out = vec![vec![0.0f32; n]; layers];
        for m in msgs {
            agg.push(m, |l, slots| {
                sparse_agg::sparse_add_rank_ordered(
                    slots.iter().map(|s| s.as_ref().unwrap()),
                    &mut out[l],
                );
            });
        }
        assert!(agg.finished());
        assert_eq!(out, expect);
        // buffers are reclaimable and reset re-arms
        for layer in 0..layers {
            for rank in 0..workers {
                assert!(agg.take(layer, rank).is_some());
            }
        }
        agg.reset();
        assert!(!agg.finished());
    }

    #[test]
    fn quorum_mask_fires_without_excluded_ranks() {
        let (layers, workers, n) = (3usize, 3usize, 16usize);
        let mut agg = StreamAggregator::new(layers, workers);
        agg.arm_participants(&[true, false, true]);
        assert_eq!(agg.required_count(), 2);
        let mut fired = Vec::new();
        // only ranks 0 and 2 publish; every layer must still fire
        for layer in (0..layers).rev() {
            for rank in [0usize, 2] {
                agg.push(msg(rank, layer, n, (layer * 7 + rank) as u64), |l, slots| {
                    // required slots full, excluded slot still empty
                    assert!(slots[0].is_some() && slots[2].is_some());
                    assert!(slots[1].is_none());
                    fired.push(l);
                });
            }
        }
        assert_eq!(fired, vec![2, 1, 0]);
        assert!(agg.finished());
        // the straggler's late message lands without re-firing anything
        agg.push(msg(1, 2, n, 99), |_, _| panic!("late message must not fire"));
        assert!(agg.take(2, 1).is_some(), "late buffer is reclaimable");
        // reset restores full participation
        agg.reset();
        assert_eq!(agg.required_count(), 3);
        assert!(agg.required().iter().all(|&b| b));
    }

    #[test]
    fn resize_rebuilds_for_new_membership() {
        let mut agg = StreamAggregator::new(3, 4);
        agg.push(msg(0, 2, 8, 1), |_, _| {});
        agg.resize(3, 2); // a drop shrank the cluster
        assert_eq!((agg.layers(), agg.workers()), (3, 2));
        assert!(!agg.finished());
        let mut fired = Vec::new();
        for layer in (0..3).rev() {
            for rank in 0..2 {
                agg.push(msg(rank, layer, 8, (layer * 3 + rank) as u64), |l, _| fired.push(l));
            }
        }
        assert_eq!(fired, vec![2, 1, 0]);
        // same-shape resize is just a reset
        agg.resize(3, 2);
        assert!(!agg.finished());
        assert!(agg.layer_slots(2).iter().all(|s| s.is_none()));
    }

    #[test]
    fn overlap_timer_counts_hidden_before_last_send() {
        let t0 = crate::util::clock::now();
        let mut timer = OverlapTimer::new();
        let ms = Duration::from_millis(1);
        // busy interval entirely before the last send → fully hidden
        timer.note_busy(t0, t0 + ms);
        // busy interval entirely after the last send → pure tail
        timer.note_busy(t0 + 3 * ms, t0 + 5 * ms);
        timer.note_sent(t0 + 2 * ms);
        let m = timer.finish();
        assert!((m.busy_seconds - 0.003).abs() < 1e-9);
        assert!((m.hidden_seconds - 0.001).abs() < 1e-9);
        assert!((m.tail_seconds() - 0.002).abs() < 1e-9);
        assert!(m.efficiency() > 0.3 && m.efficiency() < 0.34);
    }

    #[test]
    fn pipeline_mode_parses() {
        assert_eq!(PipelineMode::parse("barrier").unwrap(), PipelineMode::Barrier);
        assert_eq!(PipelineMode::parse("overlap").unwrap(), PipelineMode::Overlap);
        assert!(PipelineMode::parse("nope").is_err());
        assert_eq!(PipelineMode::Overlap.name(), "overlap");
    }

    #[test]
    fn empty_measure_efficiency_zero() {
        assert_eq!(OverlapTimer::new().finish().efficiency(), 0.0);
    }
}
