//! Collective communication: numeric implementations + α–β cost models.
//!
//! Two concerns, deliberately separated:
//!
//! * **Numerics** ([`dense`], [`sparse_agg`]): the actual arithmetic a real
//!   cluster would compute — ring allreduce over dense gradients, sparse
//!   allgather + coalesce over TopK messages. These run in-process over the
//!   logical workers and are bit-deterministic given the reduction order.
//! * **Timing** ([`cost`]): the analytic α–β communication model the paper
//!   itself uses for Eq. 18's `t_comm(c)` prediction (cf. Renggli et al.,
//!   SparCML; Li et al., Pipe-SGD). The DES (`pipeline::desim`) consumes
//!   these costs to regenerate Table 2 / Fig 1 wall-clock numbers.

//! * **Streaming** ([`pipeline`]): the per-layer readiness table +
//!   overlap accounting that lets the trainer reduce layer `l` while
//!   layers `< l` are still computing (`--pipeline overlap`), without
//!   giving up the rank-ordered determinism contract.

pub mod cost;
pub mod dense;
pub mod pipeline;
pub mod sparse_agg;

pub use cost::{CollectiveCost, NetworkModel};
pub use dense::ring_allreduce_mean;
pub use pipeline::{LayerMsg, OverlapMeasure, PipelineMode, StreamAggregator};
pub use sparse_agg::{sparse_allgather_sum, tree_merge_sum};
