//! α–β (latency–bandwidth) collective cost models.
//!
//! The paper's adaptive ratio selection (Eq. 18) predicts `t_comm^(l)(c)`
//! "using the communication model of the AllGather or AllReduce collectives
//! (e.g., Li et al. 2018; Renggli et al. 2018)". These are those models:
//!
//! * dense ring allreduce of m bytes over P nodes:
//!     `2 (P-1) α + 2 m (P-1) / (P B)`
//! * sparse allgather (each node contributes its own k-nonzero message,
//!   ring-propagated):  `(P-1) (α + m_s / B)` with `m_s = 8k` bytes
//!   (u32 idx + f32 val per kept coordinate).
//!
//! α additionally includes a fixed per-message software overhead (NCCL/MPI
//! launch, kernel dispatch) — dominant for the paper's many small layer
//! messages, which is exactly why the §5 merge-buffer heuristic exists.

/// Cluster interconnect parameters.
#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    /// per-message latency (s) — wire latency + software launch overhead
    pub alpha: f64,
    /// bandwidth (bytes/s)
    pub bandwidth: f64,
    /// number of workers
    pub workers: usize,
}

impl NetworkModel {
    /// The paper's testbed: 16 nodes, 1 Gbps Ethernet. Effective TCP
    /// bandwidth ~ 111 MB/s; α ~ 0.5 ms measured for small AllReduce on
    /// OpenMPI+1GbE clusters (Shi et al., MG-WFBP).
    pub fn gige_16() -> Self {
        NetworkModel { alpha: 5e-4, bandwidth: 111e6, workers: 16 }
    }

    /// 10 GbE: ~1.11 GB/s effective TCP bandwidth; α dominated by the
    /// same software launch overhead, mildly reduced (~0.1 ms).
    pub fn tengige_16() -> Self {
        NetworkModel { alpha: 1e-4, bandwidth: 1.11e9, workers: 16 }
    }

    /// 100 Gbps-class InfiniBand with RDMA: ~12 GB/s, ~5 µs per message.
    pub fn infiniband_16() -> Self {
        NetworkModel { alpha: 5e-6, bandwidth: 1.2e10, workers: 16 }
    }

    pub fn with_workers(mut self, p: usize) -> Self {
        self.workers = p;
        self
    }

    /// Dense ring allreduce time for a payload of `bytes`.
    pub fn allreduce_dense(&self, bytes: f64) -> f64 {
        let p = self.workers as f64;
        if self.workers <= 1 {
            return 0.0;
        }
        2.0 * (p - 1.0) * self.alpha + 2.0 * bytes * (p - 1.0) / (p * self.bandwidth)
    }

    /// Sparse allgather time where each worker contributes `k` nonzeros
    /// (8 bytes each on the wire — the index+value encoding).
    pub fn allgather_sparse(&self, k: f64) -> f64 {
        self.allgather_sparse_encoded(k, 8.0)
    }

    /// Sparse allgather time at an explicit wire encoding of
    /// `bytes_per_elem` bytes per transmitted nonzero (8 = u32 idx +
    /// f32 val, 5 = u32 idx + u8 quantization level).
    pub fn allgather_sparse_encoded(&self, k: f64, bytes_per_elem: f64) -> f64 {
        let p = self.workers as f64;
        if self.workers <= 1 {
            return 0.0;
        }
        let msg = bytes_per_elem * k;
        (p - 1.0) * (self.alpha + msg / self.bandwidth)
    }

    /// Communication time for one LAGS layer of `d` elements at compression
    /// ratio `c` (k = d/c kept).
    pub fn layer_comm_time(&self, d: usize, c: f64) -> f64 {
        let k = (d as f64 / c).max(1.0);
        self.allgather_sparse(k)
    }
}

/// Cost of one collective invocation, split for reporting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CollectiveCost {
    pub latency: f64,
    pub transfer: f64,
}

impl CollectiveCost {
    pub fn total(&self) -> f64 {
        self.latency + self.transfer
    }
}

/// Split-form dense allreduce cost (for merge-buffer ablations).
pub fn allreduce_dense_cost(net: &NetworkModel, bytes: f64) -> CollectiveCost {
    let p = net.workers as f64;
    if net.workers <= 1 {
        return CollectiveCost { latency: 0.0, transfer: 0.0 };
    }
    CollectiveCost {
        latency: 2.0 * (p - 1.0) * net.alpha,
        transfer: 2.0 * bytes * (p - 1.0) / (p * net.bandwidth),
    }
}

/// Split-form sparse allgather cost.
pub fn allgather_sparse_cost(net: &NetworkModel, k: f64) -> CollectiveCost {
    let p = net.workers as f64;
    if net.workers <= 1 {
        return CollectiveCost { latency: 0.0, transfer: 0.0 };
    }
    CollectiveCost { latency: (p - 1.0) * net.alpha, transfer: (p - 1.0) * 8.0 * k / net.bandwidth }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_worker_is_free() {
        let net = NetworkModel { alpha: 1e-3, bandwidth: 1e8, workers: 1 };
        assert_eq!(net.allreduce_dense(1e6), 0.0);
        assert_eq!(net.allgather_sparse(1e4), 0.0);
    }

    #[test]
    fn dense_cost_scales_with_bytes() {
        let net = NetworkModel::gige_16();
        let t1 = net.allreduce_dense(1e6);
        let t2 = net.allreduce_dense(2e6);
        assert!(t2 > t1);
        // bandwidth term doubles, latency term constant
        let lat = 2.0 * 15.0 * net.alpha;
        assert!(((t2 - lat) / (t1 - lat) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn sparse_beats_dense_at_high_compression() {
        let net = NetworkModel::gige_16();
        let d = 25_000_000usize; // ResNet-50-ish
        let dense = net.allreduce_dense(d as f64 * 4.0);
        let sparse = net.layer_comm_time(d, 1000.0);
        assert!(sparse < dense / 10.0, "dense={dense} sparse={sparse}");
    }

    #[test]
    fn layer_comm_monotone_in_c() {
        let net = NetworkModel::gige_16();
        let mut last = f64::INFINITY;
        for c in [1.0, 10.0, 100.0, 1000.0] {
            let t = net.layer_comm_time(1_000_000, c);
            assert!(t <= last);
            last = t;
        }
    }

    #[test]
    fn encoded_allgather_generalizes_legacy() {
        let net = NetworkModel::gige_16();
        // the legacy 8-byte call is exactly the encoded one at 8.0
        assert_eq!(net.allgather_sparse(5e4), net.allgather_sparse_encoded(5e4, 8.0));
        // a narrower encoding is strictly cheaper at equal nnz (same α)
        let wide = net.allgather_sparse_encoded(5e4, 8.0);
        let narrow = net.allgather_sparse_encoded(5e4, 5.0);
        assert!(narrow < wide);
        let p = net.workers as f64;
        let expect = (p - 1.0) * (net.alpha + 5.0 * 5e4 / net.bandwidth);
        assert!((narrow - expect).abs() < 1e-15);
    }

    #[test]
    fn split_costs_sum_to_total() {
        let net = NetworkModel::gige_16();
        let c = allreduce_dense_cost(&net, 3e6);
        assert!((c.total() - net.allreduce_dense(3e6)).abs() < 1e-12);
        let g = allgather_sparse_cost(&net, 5e4);
        assert!((g.total() - net.allgather_sparse(5e4)).abs() < 1e-12);
    }
}
