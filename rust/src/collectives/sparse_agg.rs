//! Sparse gradient aggregation (Algorithm 1, line 9).
//!
//! Each worker contributes `TopK(acc^p, k)` as a [`SparseVec`]; the
//! aggregate is the elementwise SUM over workers (the 1/P averaging is
//! folded into the apply step). Two equivalent schedules:
//!
//! * [`sparse_allgather_sum`] — what AllGather-based sparse S-SGD does:
//!   every worker receives all P messages and reduces locally, in rank
//!   order, so all replicas stay bit-identical.
//! * [`tree_merge_sum`] — pairwise coalescing tree (SparCML-style);
//!   used to check associativity and by the merge-buffer ablation.

use crate::sparsify::sparse::SparseVec;

/// Rank-ordered reduction of sparse messages into a dense accumulator.
/// Deterministic: the sum order is rank 0, 1, ..., P-1 for every replica.
pub fn sparse_allgather_sum(messages: &[SparseVec], out: &mut [f32]) {
    out.iter_mut().for_each(|v| *v = 0.0);
    sparse_add_rank_ordered(messages, out);
}

/// The trainer hot-path variant: reduce rank-ordered messages into an
/// accumulator that the caller already zeroed (the trainer zeroes its
/// dense `agg` once per iteration, so re-clearing every layer slice would
/// reintroduce an O(d) dense pass per layer). Accepts any iterator over
/// message refs so per-worker-owned messages can be reduced without
/// collecting them into a contiguous slice. Cost is O(Σ nnz) — the O(P·k)
/// aggregation Algorithm 1 line 9 calls for. The sum order is exactly the
/// iteration order; pass ranks 0..P-1 to stay bit-identical to
/// [`sparse_allgather_sum`], which every replica of an AllGather-based
/// sparse S-SGD performs locally.
pub fn sparse_add_rank_ordered<'a, I>(messages: I, out: &mut [f32])
where
    I: IntoIterator<Item = &'a SparseVec>,
{
    for m in messages {
        m.add_into(out);
    }
}

/// Pairwise tree merge of the sparse messages (stays sparse until the end).
/// Equivalent to the allgather sum up to f32 association.
pub fn tree_merge_sum(messages: &[SparseVec]) -> SparseVec {
    assert!(!messages.is_empty());
    let mut level: Vec<SparseVec> = messages.to_vec();
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        let mut it = level.chunks(2);
        for pair in &mut it {
            match pair {
                [a, b] => next.push(a.merge(b)),
                [a] => next.push(a.clone()),
                _ => unreachable!(),
            }
        }
        level = next;
    }
    level.pop().unwrap()
}

/// Total wire bytes for an allgather round of these messages (what the
/// timing model charges).
pub fn allgather_wire_bytes(messages: &[SparseVec]) -> usize {
    messages.iter().map(|m| m.wire_bytes()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_sparse(n: usize, nnz: usize, seed: u64) -> SparseVec {
        let mut rng = Rng::new(seed);
        let mut dense = vec![0.0f32; n];
        for i in rng.sample_distinct(n, nnz) {
            dense[i] = rng.normal_f32();
        }
        SparseVec::from_dense(&dense)
    }

    #[test]
    fn allgather_matches_dense_sum() {
        let n = 500;
        let msgs: Vec<SparseVec> = (0..8).map(|p| random_sparse(n, 30, p)).collect();
        let mut out = vec![0.0f32; n];
        sparse_allgather_sum(&msgs, &mut out);
        let mut expect = vec![0.0f32; n];
        for m in &msgs {
            for (e, v) in expect.iter_mut().zip(m.to_dense()) {
                *e += v;
            }
        }
        assert_eq!(out, expect);
    }

    #[test]
    fn tree_matches_allgather_within_f32() {
        let n = 300;
        let msgs: Vec<SparseVec> = (0..7).map(|p| random_sparse(n, 40, 100 + p)).collect();
        let mut flat = vec![0.0f32; n];
        sparse_allgather_sum(&msgs, &mut flat);
        let tree = tree_merge_sum(&msgs).to_dense();
        for i in 0..n {
            assert!((flat[i] - tree[i]).abs() < 1e-5, "i={i}");
        }
    }

    #[test]
    fn overlapping_indices_sum() {
        let a = SparseVec { len: 4, idx: vec![0, 2], val: vec![1.0, 2.0] };
        let b = SparseVec { len: 4, idx: vec![2, 3], val: vec![3.0, 4.0] };
        let mut out = vec![0.0f32; 4];
        sparse_allgather_sum(&[a.clone(), b.clone()], &mut out);
        assert_eq!(out, vec![1.0, 0.0, 5.0, 4.0]);
        assert_eq!(tree_merge_sum(&[a, b]).to_dense(), vec![1.0, 0.0, 5.0, 4.0]);
    }

    #[test]
    fn wire_bytes() {
        let msgs = vec![random_sparse(100, 10, 1), random_sparse(100, 5, 2)];
        assert_eq!(allgather_wire_bytes(&msgs), 15 * 8);
    }
}
