//! First-class compressor abstraction — the seam every sparsification
//! scheme plugs into (DESIGN.md §Compressor zoo and validation).
//!
//! A [`Compressor`] turns one layer's error-feedback accumulator
//! `acc = eps + lr·grad` into a sparse wire message plus a new residual,
//! under the hard contract
//!
//! ```text
//! densify(msg) + resid == acc      (bit-exact, per coordinate)
//! ```
//!
//! so no gradient mass is ever created or destroyed — the invariant the
//! EF convergence argument (arxiv 1809.10505) and the repo's
//! conservation tests rest on. Implementations own their scratch (no
//! allocation in the steady-state hot loop) and draw any randomness from
//! a per-call stream forked from `(seed, uid, step, layer)` via
//! [`LayerCtx::rng`] — never from ambient state — so results are
//! bit-identical across thread counts, pipeline modes and reruns, and
//! checkpoints need no compressor RNG state at all.
//!
//! The zoo:
//!
//! * [`TopK`] — exact or double-sampling-threshold Top-k (the paper's
//!   Algorithm 1 operator; `host`/`host-sampled`, and the host half of
//!   the `xla*` kinds).
//! * [`AdaptiveStoch`] — adaptive-sparsity stochastic compression (arxiv
//!   2112.04088): the kept-set size floats with the gradient's
//!   participation ratio `‖a‖₁²/‖a‖₂²` under the layer budget `k`;
//!   coordinates are kept with magnitude-proportional probability.
//! * [`GlobalTopk`] — one global threshold across ALL layers (arxiv
//!   2009.09271) with per-layer error feedback; [`Compressor::begin_step`]
//!   caches the model-wide k_total-th magnitude, per-layer splits reuse it.
//! * [`QsgdTopk`] — a QSGD-style stochastic quantizer composed on exact
//!   TopK values; quantization error folds into the EF residual
//!   **exactly** (a Sterbenz-lemma construction, see the impl).
//! * [`BottomK`] — keeps the k SMALLEST magnitudes: a deliberately
//!   δ-violating negative control for the `lags validate` gate.

use super::error_feedback::CompressStats;
use super::sparse::SparseVec;
use super::threshold::SampledThreshold;
use super::topk;
use crate::util::rng::Rng;

/// Deterministic identity of one compression call. The RNG stream is a
/// pure function of these four coordinates, so a compressor invoked for
/// the same (seed, worker uid, step, layer) draws the same randomness on
/// any thread, in any pipeline mode, on any rerun — and a resumed run
/// replays the stream with no checkpointed RNG state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerCtx {
    pub seed: u64,
    /// stable worker uid (not rank: ranks shift under elastic membership)
    pub uid: u64,
    pub step: u64,
    pub layer: u64,
}

impl LayerCtx {
    /// The per-call PRNG stream: seed → uid → step → layer forks.
    pub fn rng(&self) -> Rng {
        Rng::new(self.seed).fork(self.uid).fork(self.step).fork(self.layer)
    }
}

/// Bytes-on-wire accounting for one compressor's message encoding. The
/// in-memory [`SparseVec`] always carries f32 values; the wire format is
/// what the DES and `MessageStats` price — index+value pairs for the
/// plain schemes, index+sign+level (plus a per-message shared norm) for
/// the quantized one.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireFormat {
    /// bytes per transmitted element
    pub elem_bytes: usize,
    /// fixed per-message overhead (e.g. the QSGD norm scalar)
    pub msg_overhead: usize,
}

impl WireFormat {
    /// The legacy (u32 index, f32 value) pair — 8 bytes per element.
    pub const INDEX_VALUE: WireFormat = WireFormat { elem_bytes: 8, msg_overhead: 0 };
    /// QSGD-on-TopK: u32 index + 1 byte (sign + 7-bit level) per element,
    /// plus one f32 norm scalar per message.
    pub const INDEX_LEVEL: WireFormat = WireFormat { elem_bytes: 5, msg_overhead: 4 };

    /// Wire bytes for a message with `nnz` transmitted elements.
    pub fn message_bytes(&self, nnz: usize) -> usize {
        self.msg_overhead + self.elem_bytes * nnz
    }
}

/// One sparsification scheme. Object-safe; boxed per worker.
///
/// Contract (enforced by `rust/tests/compressor_contract.rs`):
/// 1. `densify(msg) + resid == acc` bit-exact after [`Self::split`];
/// 2. the kept count respects the scheme's budget;
/// 3. identical `(ctx, acc, k)` ⇒ identical output, regardless of
///    thread, pipeline mode, or process;
/// 4. all randomness comes from `ctx.rng()` (audit rule R5).
pub trait Compressor: Send {
    /// Once per worker per step, BEFORE any per-layer split: global
    /// schemes cache model-wide state here (e.g. the global threshold).
    /// `resid`/`grad` are the worker's full flat vectors; the default is
    /// a no-op. Must be idempotent — the trainer's δ-instrumentation
    /// pre-pass re-arms it before the compression phase does.
    fn begin_step(&mut self, _resid: &[f32], _grad: &[f32], _lr: f32, _k_total: usize) {}

    /// Split one layer's accumulator into a sparse message (indices local
    /// to the layer) and the new residual. `msg` and `resid` are fully
    /// overwritten; `acc.len() == resid.len()`.
    fn split(
        &mut self,
        ctx: &LayerCtx,
        acc: &[f32],
        k: usize,
        msg: &mut SparseVec,
        resid: &mut [f32],
    ) -> CompressStats;

    /// Densified kept part for `acc` WITHOUT touching any error-feedback
    /// state — the generalized δ^(l) numerator (Eq. 20). Because the RNG
    /// is re-derived from `ctx`, the probe reproduces exactly what
    /// [`Self::split`] will transmit for the same call coordinates. The
    /// default routes through `split` on local scratch; probing runs on
    /// the δ sampling cadence, so the allocation is off the hot path.
    fn probe(&mut self, ctx: &LayerCtx, acc: &[f32], k: usize, out: &mut [f32]) {
        let n = acc.len();
        debug_assert_eq!(out.len(), n);
        let mut msg = SparseVec::new(n);
        let mut resid = vec![0.0f32; n];
        self.split(ctx, acc, k, &mut msg, &mut resid);
        out.iter_mut().for_each(|v| *v = 0.0);
        for (&i, &v) in msg.idx.iter().zip(msg.val.iter()) {
            out[i as usize] = v;
        }
    }

    /// This scheme's wire encoding (bytes accounting).
    fn wire(&self) -> WireFormat {
        WireFormat::INDEX_VALUE
    }
}

/// Shared one-pass threshold split: coordinates with `|v| >= thr` go on
/// the wire, the rest become residual. Exactly the split
/// `ErrorFeedback::compress_layer_sparse` performs, including tie
/// behaviour (every `|v| == thr` is kept) and NaN handling (NaN is never
/// kept — comparisons with NaN are false).
fn threshold_split(acc: &[f32], thr: f32, msg: &mut SparseVec, resid: &mut [f32]) -> usize {
    msg.len = acc.len();
    msg.idx.clear();
    msg.val.clear();
    for (i, (&v, r)) in acc.iter().zip(resid.iter_mut()).enumerate() {
        if v.abs() >= thr {
            msg.idx.push(i as u32);
            msg.val.push(v);
            *r = 0.0;
        } else {
            *r = v;
        }
    }
    msg.nnz()
}

/// Exact or sampled-threshold Top-k — Algorithm 1's operator, the
/// baseline every other zoo member is validated against. Deterministic;
/// never touches the ctx RNG.
pub struct TopK {
    exact: bool,
    sampler: SampledThreshold,
    mags: Vec<f32>,
}

impl TopK {
    pub fn new(exact: bool, sample_stride: usize) -> Self {
        TopK { exact, sampler: SampledThreshold::new(sample_stride), mags: Vec::new() }
    }
}

impl Compressor for TopK {
    fn split(
        &mut self,
        _ctx: &LayerCtx,
        acc: &[f32],
        k: usize,
        msg: &mut SparseVec,
        resid: &mut [f32],
    ) -> CompressStats {
        let thr = if self.exact {
            topk::kth_largest_abs_with_buf(acc, k, &mut self.mags)
        } else {
            self.sampler.estimate(acc, k)
        };
        let kept = threshold_split(acc, thr, msg, resid);
        CompressStats { threshold: thr, kept }
    }
}

/// Adaptive-sparsity stochastic compression (arxiv 2112.04088): the
/// effective kept-set size floats with the gradient's participation
/// ratio `s = ‖a‖₁² / ‖a‖₂² ∈ [1, n]` (≈ the count of "active"
/// coordinates), clamped to the layer budget `k`. Each coordinate is
/// kept with probability `min(1, k_eff·|a_i|/‖a‖₁)` — magnitude-
/// proportional importance sampling — with a hard stop at `k` keeps, so
/// the budget is never exceeded. Kept coordinates transmit their RAW
/// accumulator value (no 1/p reweighting): the selection bias lands in
/// the residual and is corrected by error feedback, which keeps the
/// mass-conservation contract bit-exact.
pub struct AdaptiveStoch;

impl Compressor for AdaptiveStoch {
    fn split(
        &mut self,
        ctx: &LayerCtx,
        acc: &[f32],
        k: usize,
        msg: &mut SparseVec,
        resid: &mut [f32],
    ) -> CompressStats {
        msg.len = acc.len();
        msg.idx.clear();
        msg.val.clear();
        let mut l1 = 0.0f64;
        let mut l2 = 0.0f64;
        for &v in acc {
            let a = v.abs() as f64;
            l1 += a;
            l2 += a * a;
        }
        if l2 == 0.0 || !l2.is_finite() || k == 0 {
            resid.copy_from_slice(acc);
            return CompressStats { threshold: 0.0, kept: 0 };
        }
        let participation = (l1 * l1 / l2).round() as usize;
        let k_eff = participation.clamp(1, k);
        // one uniform draw per coordinate, in index order, whether or not
        // the budget is already exhausted — the stream position is a pure
        // function of the coordinate index, so the kept set is too
        let mut rng = ctx.rng();
        let mut kept = 0usize;
        for (i, (&v, r)) in acc.iter().zip(resid.iter_mut()).enumerate() {
            let p = (k_eff as f64 * v.abs() as f64 / l1).min(1.0);
            let u = rng.uniform();
            if kept < k && u < p {
                msg.idx.push(i as u32);
                msg.val.push(v);
                *r = 0.0;
                kept += 1;
            } else {
                *r = v;
            }
        }
        CompressStats { threshold: 0.0, kept }
    }
}

/// Global-threshold selection (arxiv 2009.09271): one magnitude
/// threshold — the model-wide k_total-th largest |eps + lr·g| — shared
/// by every layer's split, with per-layer error feedback. Contrasts with
/// LAGS's layer-wise selection: a layer whose magnitudes are globally
/// small may send (almost) nothing this step, its mass deferring through
/// the residual until it competes globally.
pub struct GlobalTopk {
    thr: f32,
    acc: Vec<f32>,
    mags: Vec<f32>,
}

impl GlobalTopk {
    pub fn new() -> Self {
        GlobalTopk { thr: f32::INFINITY, acc: Vec::new(), mags: Vec::new() }
    }
}

impl Default for GlobalTopk {
    fn default() -> Self {
        Self::new()
    }
}

impl Compressor for GlobalTopk {
    fn begin_step(&mut self, resid: &[f32], grad: &[f32], lr: f32, k_total: usize) {
        self.acc.clear();
        self.acc.extend(resid.iter().zip(grad.iter()).map(|(&r, &g)| r + lr * g));
        self.thr = topk::kth_largest_abs_with_buf(&self.acc, k_total, &mut self.mags);
    }

    fn split(
        &mut self,
        _ctx: &LayerCtx,
        acc: &[f32],
        _k: usize,
        msg: &mut SparseVec,
        resid: &mut [f32],
    ) -> CompressStats {
        let kept = threshold_split(acc, self.thr, msg, resid);
        CompressStats { threshold: self.thr, kept }
    }
}

/// QSGD levels per power-of-two norm bracket. A power of two, so the
/// level spacing Δ is itself an exact power of two — the keystone of the
/// exact-residual construction below.
const QSGD_LEVELS: u32 = 128;

/// Smallest power of two >= x, exactly, via the exponent bits. `None`
/// when x is zero/subnormal/non-finite or the next power would overflow
/// (callers fall back to unquantized TopK — correct, just not quantized).
fn pow2_at_least(x: f32) -> Option<f32> {
    if !x.is_finite() || x < f32::MIN_POSITIVE {
        return None;
    }
    let bits = x.to_bits();
    let exp = ((bits >> 23) & 0xff) as i32 - 127;
    let e = if bits & 0x7f_ffff == 0 { exp } else { exp + 1 };
    if e > 127 {
        return None;
    }
    Some(f32::from_bits(((e + 127) as u32) << 23))
}

/// QSGD-style stochastic quantization (arxiv 1610.02132) composed on
/// exact TopK: selection picks the k largest magnitudes, then each kept
/// value is stochastically rounded onto the grid `±ℓ·Δ`,
/// `Δ = norm'/128`, where `norm'` is `max|a_i|` rounded UP to a power of
/// two. Quantization error folds into the EF residual **bit-exactly**:
///
/// * Δ is a power of two, so every grid point `ℓ·Δ` (ℓ ≤ 128 = 2⁷) is
///   exactly representable in f32;
/// * for ℓ̂ ≥ 1 the rounded grid point g satisfies `g/2 ≤ |a| ≤ 2g`
///   (round-down: `g ≤ |a| < 2g`; round-up from ℓ ≥ 1:
///   `g/2 ≤ ℓΔ ≤ |a| < g`; round-up from ℓ = 0 is only taken when
///   `|a| ≥ Δ/2`), so by the Sterbenz lemma `fl(a − g) = a − g` exactly;
/// * ℓ̂ = 0 means the coordinate is omitted from the wire and its
///   residual is `a` itself — also exact.
///
/// So `densify(msg) + resid == acc` holds bit-for-bit even though values
/// are quantized, and the wire only needs index + sign + 7-bit level per
/// element plus one norm scalar per message ([`WireFormat::INDEX_LEVEL`]).
/// The round-trip error per kept coordinate is bounded by the level
/// spacing: `|a − q| ≤ Δ ≤ 2·max|a| / 128`.
pub struct QsgdTopk {
    mags: Vec<f32>,
}

impl QsgdTopk {
    pub fn new() -> Self {
        QsgdTopk { mags: Vec::new() }
    }
}

impl Default for QsgdTopk {
    fn default() -> Self {
        Self::new()
    }
}

impl Compressor for QsgdTopk {
    fn split(
        &mut self,
        ctx: &LayerCtx,
        acc: &[f32],
        k: usize,
        msg: &mut SparseVec,
        resid: &mut [f32],
    ) -> CompressStats {
        let thr = topk::kth_largest_abs_with_buf(acc, k, &mut self.mags);
        // plain max loop (order-insensitive), not a float fold — audit R3
        let mut norm = 0.0f32;
        for &v in acc {
            norm = norm.max(v.abs());
        }
        let delta = match pow2_at_least(norm) {
            Some(p) => p / QSGD_LEVELS as f32, // exact: both are powers of two
            None => {
                // zero/degenerate layer: plain TopK split, nothing to quantize
                let kept = threshold_split(acc, thr, msg, resid);
                return CompressStats { threshold: thr, kept };
            }
        };
        msg.len = acc.len();
        msg.idx.clear();
        msg.val.clear();
        let mut rng = ctx.rng();
        for (i, (&v, r)) in acc.iter().zip(resid.iter_mut()).enumerate() {
            if v.abs() >= thr {
                let t = v.abs() / delta; // exact power-of-two scaling, t <= 128
                let level = t.floor();
                let frac = (t - level) as f64;
                // one draw per SELECTED coordinate (stream position is a
                // pure function of the kept set, which is deterministic)
                let up = rng.uniform() < frac;
                let mut lv = level + if up { 1.0 } else { 0.0 };
                if level == 0.0 && v.abs() < 0.5 * delta {
                    // below Δ/2 the Sterbenz window doesn't cover a
                    // round-up; drop deterministically (resid = a, exact)
                    lv = 0.0;
                }
                if lv == 0.0 {
                    *r = v;
                } else {
                    let q = (lv * delta).copysign(v); // grid point, exact
                    msg.idx.push(i as u32);
                    msg.val.push(q);
                    *r = v - q; // exact by Sterbenz
                }
            } else {
                *r = v;
            }
        }
        CompressStats { threshold: thr, kept: msg.nnz() }
    }

    fn wire(&self) -> WireFormat {
        WireFormat::INDEX_LEVEL
    }
}

/// Negative control: keeps the k SMALLEST magnitudes, maximally
/// violating Assumption 1 (δ ≫ 1 — almost all mass is lost relative to
/// RandK). Exists so `lags validate --inject-violation` can prove the
/// δ-gate actually fails a bad compressor; never a sane training choice.
pub struct BottomK {
    mags: Vec<f32>,
}

impl BottomK {
    pub fn new() -> Self {
        BottomK { mags: Vec::new() }
    }
}

impl Default for BottomK {
    fn default() -> Self {
        Self::new()
    }
}

impl Compressor for BottomK {
    fn split(
        &mut self,
        _ctx: &LayerCtx,
        acc: &[f32],
        k: usize,
        msg: &mut SparseVec,
        resid: &mut [f32],
    ) -> CompressStats {
        msg.len = acc.len();
        msg.idx.clear();
        msg.val.clear();
        let n = acc.len();
        if n == 0 || k == 0 {
            resid.copy_from_slice(acc);
            return CompressStats { threshold: 0.0, kept: 0 };
        }
        let k = k.min(n);
        self.mags.clear();
        self.mags.extend(acc.iter().map(|v| v.abs()));
        let (_, kth, _) = self.mags.select_nth_unstable_by(k - 1, f32::total_cmp);
        let thr = *kth; // k-th SMALLEST |acc|
        for (i, (&v, r)) in acc.iter().zip(resid.iter_mut()).enumerate() {
            if v.abs() <= thr {
                msg.idx.push(i as u32);
                msg.val.push(v);
                *r = 0.0;
            } else {
                *r = v;
            }
        }
        CompressStats { threshold: thr, kept: msg.nnz() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(layer: u64) -> LayerCtx {
        LayerCtx { seed: 42, uid: 1, step: 3, layer }
    }

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal_f32()).collect()
    }

    fn densify(msg: &SparseVec) -> Vec<f32> {
        let mut out = vec![0.0f32; msg.len];
        for (&i, &v) in msg.idx.iter().zip(msg.val.iter()) {
            out[i as usize] = v;
        }
        out
    }

    #[test]
    fn ctx_rng_streams_are_distinct_per_coordinate() {
        let base = ctx(0);
        let mut seen = std::vec::Vec::new();
        for (seed, uid, step, layer) in
            [(42, 1, 3, 0), (43, 1, 3, 0), (42, 2, 3, 0), (42, 1, 4, 0), (42, 1, 3, 1)]
        {
            let mut r = LayerCtx { seed, uid, step, layer }.rng();
            seen.push(r.next_u64());
        }
        let mut again = base.rng();
        assert_eq!(seen[0], again.next_u64(), "same ctx must replay the stream");
        for i in 0..seen.len() {
            for j in (i + 1)..seen.len() {
                assert_ne!(seen[i], seen[j], "streams {i} and {j} collide");
            }
        }
    }

    #[test]
    fn topk_matches_error_feedback_split() {
        // the trait-based TopK must be bit-identical to the historical
        // compress_layer_sparse split (same threshold, same kept set)
        use crate::sparsify::ErrorFeedback;
        let n = 512;
        let grad = randvec(n, 9);
        for exact in [true, false] {
            let mut ef = ErrorFeedback::new(n, 8);
            let mut msg_ref = SparseVec::new(n);
            let s_ref = ef.compress_layer_sparse(0, &grad, 0.1, 24, exact, &mut msg_ref);

            let mut comp = TopK::new(exact, 8);
            let acc: Vec<f32> = grad.iter().map(|&g| 0.1 * g).collect();
            let mut msg = SparseVec::new(n);
            let mut resid = vec![0.0f32; n];
            let s = comp.split(&ctx(0), &acc, 24, &mut msg, &mut resid);
            assert_eq!(s.threshold, s_ref.threshold, "exact={exact}");
            assert_eq!(s.kept, s_ref.kept, "exact={exact}");
            assert_eq!(msg.idx, msg_ref.idx, "exact={exact}");
            assert_eq!(msg.val, msg_ref.val, "exact={exact}");
        }
    }

    #[test]
    fn every_compressor_conserves_mass_bit_exactly() {
        let n = 300;
        let acc = randvec(n, 11);
        let k = 30;
        let k_total = 60;
        let mut zoo: Vec<Box<dyn Compressor>> = vec![
            Box::new(TopK::new(true, 4)),
            Box::new(TopK::new(false, 4)),
            Box::new(AdaptiveStoch),
            Box::new(GlobalTopk::new()),
            Box::new(QsgdTopk::new()),
            Box::new(BottomK::new()),
        ];
        for (ci, comp) in zoo.iter_mut().enumerate() {
            let grad: Vec<f32> = acc.clone();
            comp.begin_step(&vec![0.0; n], &grad, 1.0, k_total);
            let mut msg = SparseVec::new(n);
            let mut resid = vec![0.0f32; n];
            comp.split(&ctx(0), &acc, k, &mut msg, &mut resid);
            let dense = densify(&msg);
            for i in 0..n {
                assert_eq!(
                    (dense[i] + resid[i]).to_bits(),
                    acc[i].to_bits(),
                    "compressor {ci} coordinate {i}: {} + {} != {}",
                    dense[i],
                    resid[i],
                    acc[i]
                );
                assert!(dense[i] == 0.0 || resid[i] == 0.0 || ci == 4, "disjoint split");
            }
        }
    }

    #[test]
    fn adaptive_stoch_respects_budget_and_replays() {
        let n = 2048;
        let acc = randvec(n, 13);
        let k = 64;
        let mut a = AdaptiveStoch;
        let mut m1 = SparseVec::new(n);
        let mut r1 = vec![0.0f32; n];
        let s1 = a.split(&ctx(5), &acc, k, &mut m1, &mut r1);
        assert!(s1.kept <= k, "kept {} > budget {k}", s1.kept);
        assert!(s1.kept > 0, "nothing kept on a dense gaussian layer");
        // same ctx ⇒ bit-identical; different layer ⇒ different draw
        let mut m2 = SparseVec::new(n);
        let mut r2 = vec![0.0f32; n];
        a.split(&ctx(5), &acc, k, &mut m2, &mut r2);
        assert_eq!(m1.idx, m2.idx);
        assert_eq!(m1.val, m2.val);
        let mut m3 = SparseVec::new(n);
        let mut r3 = vec![0.0f32; n];
        a.split(&ctx(6), &acc, k, &mut m3, &mut r3);
        assert_ne!(m1.idx, m3.idx, "layer fork must change the kept set");
    }

    #[test]
    fn adaptive_stoch_floats_below_budget_on_peaked_input() {
        // one dominant coordinate ⇒ participation ratio ≈ 1 ⇒ k_eff ≈ 1:
        // the kept count must float far below the budget
        let n = 1024;
        let mut acc = vec![1e-4f32; n];
        acc[17] = 100.0;
        let mut a = AdaptiveStoch;
        let mut msg = SparseVec::new(n);
        let mut resid = vec![0.0f32; n];
        let s = a.split(&ctx(1), &acc, 256, &mut msg, &mut resid);
        assert!(s.kept <= 4, "peaked input kept {} of budget 256", s.kept);
        assert!(msg.idx.contains(&17), "the dominant coordinate must be kept");
    }

    #[test]
    fn global_topk_threshold_is_model_wide() {
        // two "layers": all large magnitudes live in layer 0. With
        // k_total = 4 the global threshold must select only layer-0 mass.
        let l0 = vec![5.0f32, -6.0, 7.0, -8.0];
        let l1 = vec![0.1f32, -0.2, 0.3, -0.4];
        let flat: Vec<f32> = l0.iter().chain(l1.iter()).copied().collect();
        let mut g = GlobalTopk::new();
        g.begin_step(&vec![0.0; 8], &flat, 1.0, 4);
        let mut msg = SparseVec::new(4);
        let mut resid = vec![0.0f32; 4];
        let s0 = g.split(&ctx(0), &l0, 2, &mut msg, &mut resid);
        assert_eq!(s0.kept, 4, "every layer-0 coordinate beats the global threshold");
        let s1 = g.split(&ctx(1), &l1, 2, &mut msg, &mut resid);
        assert_eq!(s1.kept, 0, "layer 1 sends nothing; its mass defers via EF");
        assert_eq!(resid, l1, "starved layer keeps its whole accumulator as residual");
    }

    #[test]
    fn qsgd_error_bounded_by_level_spacing() {
        let n = 4096;
        let acc = randvec(n, 17);
        let norm = acc.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let delta = pow2_at_least(norm).unwrap() / QSGD_LEVELS as f32;
        let mut q = QsgdTopk::new();
        let mut msg = SparseVec::new(n);
        let mut resid = vec![0.0f32; n];
        let s = q.split(&ctx(2), &acc, 256, &mut msg, &mut resid);
        assert!(s.kept > 0 && s.kept <= 257, "kept={}", s.kept);
        for (&i, &v) in msg.idx.iter().zip(msg.val.iter()) {
            let a = acc[i as usize];
            assert!((a - v).abs() <= delta, "i={i} |{a} - {v}| > Δ={delta}");
            // transmitted values sit exactly on the ±ℓΔ grid
            let l = (v.abs() / delta).round();
            assert_eq!(v.abs(), l * delta, "off-grid value {v}");
            assert!(l >= 1.0 && l <= QSGD_LEVELS as f32);
        }
        // selected-but-dropped coordinates (ℓ̂ = 0) are bounded too
        let dense = densify(&msg);
        let thr = s.threshold;
        for i in 0..n {
            if acc[i].abs() >= thr && dense[i] == 0.0 {
                assert!(acc[i].abs() < delta, "dropped large value {}", acc[i]);
            }
        }
    }

    #[test]
    fn qsgd_wire_format_is_narrower() {
        let q = QsgdTopk::new();
        assert_eq!(q.wire(), WireFormat::INDEX_LEVEL);
        assert_eq!(WireFormat::INDEX_VALUE.message_bytes(10), 80);
        assert_eq!(WireFormat::INDEX_LEVEL.message_bytes(10), 54);
        assert_eq!(TopK::new(true, 1).wire(), WireFormat::INDEX_VALUE);
    }

    #[test]
    fn pow2_at_least_exact_brackets() {
        assert_eq!(pow2_at_least(1.0), Some(1.0));
        assert_eq!(pow2_at_least(1.5), Some(2.0));
        assert_eq!(pow2_at_least(0.25), Some(0.25));
        assert_eq!(pow2_at_least(0.26), Some(0.5));
        assert_eq!(pow2_at_least(3.0e38), None, "next power overflows");
        assert_eq!(pow2_at_least(0.0), None);
        assert_eq!(pow2_at_least(f32::NAN), None);
        for x in [1e-30f32, 7.3, 1234.5, 3.0e30] {
            let p = pow2_at_least(x).unwrap();
            assert!(p >= x && p / 2.0 < x, "x={x} p={p}");
        }
    }

    #[test]
    fn probe_matches_split_transmission() {
        let n = 512;
        let acc = randvec(n, 23);
        for comp in [
            Box::new(TopK::new(true, 4)) as Box<dyn Compressor>,
            Box::new(AdaptiveStoch),
            Box::new(QsgdTopk::new()),
        ]
        .iter_mut()
        {
            let c = ctx(7);
            let mut probed = vec![9.0f32; n];
            comp.probe(&c, &acc, 32, &mut probed);
            let mut msg = SparseVec::new(n);
            let mut resid = vec![0.0f32; n];
            comp.split(&c, &acc, 32, &mut msg, &mut resid);
            assert_eq!(probed, densify(&msg), "probe must equal the real transmission");
        }
    }

    #[test]
    fn bottomk_inverts_selection() {
        let acc = vec![10.0f32, -0.1, 5.0, 0.2, -8.0, 0.05];
        let mut b = BottomK::new();
        let mut msg = SparseVec::new(6);
        let mut resid = vec![0.0f32; 6];
        let s = b.split(&ctx(0), &acc, 3, &mut msg, &mut resid);
        assert_eq!(s.kept, 3);
        assert_eq!(msg.idx, vec![1, 3, 5], "the three smallest magnitudes");
        assert_eq!(resid, vec![10.0, 0.0, 5.0, 0.0, -8.0, 0.0]);
    }
}
