//! Sparse gradient wire format: (u32 index, f32 value) pairs.
//!
//! This is what actually crosses the (simulated) network in SLGS/LAGS —
//! the paper's message size `k * 8` bytes per layer per worker. The codec
//! is exercised by the sparse allgather in `collectives::sparse_agg` and
//! the merge buffer in `pipeline::merge`.

/// A sparse view of a dense f32 vector.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SparseVec {
    /// logical dense length
    pub len: usize,
    /// strictly increasing coordinate indices
    pub idx: Vec<u32>,
    /// values at those coordinates
    pub val: Vec<f32>,
}

impl SparseVec {
    pub fn new(len: usize) -> Self {
        SparseVec { len, idx: Vec::new(), val: Vec::new() }
    }

    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Wire size in bytes (index + value per nonzero).
    pub fn wire_bytes(&self) -> usize {
        self.nnz() * 8
    }

    /// Encode the nonzeros of a dense vector.
    pub fn from_dense(x: &[f32]) -> Self {
        let mut s = SparseVec::new(x.len());
        for (i, &v) in x.iter().enumerate() {
            if v != 0.0 {
                s.idx.push(i as u32);
                s.val.push(v);
            }
        }
        s
    }

    /// Encode values of `x` at |x_i| >= thr (fused mask + encode; avoids
    /// materializing the dense masked vector on the hot path).
    pub fn from_dense_threshold(x: &[f32], thr: f32) -> Self {
        let mut s = SparseVec::new(x.len());
        for (i, &v) in x.iter().enumerate() {
            if v.abs() >= thr {
                s.idx.push(i as u32);
                s.val.push(v);
            }
        }
        s
    }

    /// Decode to a fresh dense vector.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len];
        self.add_into(&mut out);
        out
    }

    /// Accumulate into an existing dense buffer: out[idx] += val.
    /// This is the aggregation step of Algorithm 1 line 9; it dispatches
    /// through the process-wide [`crate::runtime::simd::KernelSet`] —
    /// every ISA path performs the same single add per coordinate, so the
    /// result is bit-identical to [`sparse_add_scalar`] on every ISA.
    pub fn add_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.len);
        crate::runtime::simd::active().sparse_add(&self.idx, &self.val, out);
    }

    /// Accumulate a scaled copy: out[idx] += scale * val.
    pub fn add_scaled_into(&self, scale: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.len);
        for (&i, &v) in self.idx.iter().zip(self.val.iter()) {
            out[i as usize] += scale * v;
        }
    }

    /// Serialize to bytes (little-endian [nnz u32][len u32][idx...][val...]).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + self.nnz() * 8);
        out.extend_from_slice(&(self.nnz() as u32).to_le_bytes());
        out.extend_from_slice(&(self.len as u32).to_le_bytes());
        for &i in &self.idx {
            out.extend_from_slice(&i.to_le_bytes());
        }
        for &v in &self.val {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    pub fn from_bytes(b: &[u8]) -> anyhow::Result<Self> {
        anyhow::ensure!(b.len() >= 8, "truncated sparse header");
        let nnz = u32::from_le_bytes(b[0..4].try_into()?) as usize;
        let len = u32::from_le_bytes(b[4..8].try_into()?) as usize;
        anyhow::ensure!(b.len() == 8 + nnz * 8, "bad sparse payload size");
        let mut idx = Vec::with_capacity(nnz);
        let mut val = Vec::with_capacity(nnz);
        for i in 0..nnz {
            let o = 8 + i * 4;
            idx.push(u32::from_le_bytes(b[o..o + 4].try_into()?));
        }
        for i in 0..nnz {
            let o = 8 + nnz * 4 + i * 4;
            val.push(f32::from_le_bytes(b[o..o + 4].try_into()?));
        }
        Ok(SparseVec { len, idx, val })
    }

    /// Merge-coalesce two index-sorted sparse vectors (values summed at
    /// shared indices). Used by tree-reduction aggregation.
    pub fn merge(&self, other: &SparseVec) -> SparseVec {
        debug_assert_eq!(self.len, other.len);
        let mut out = SparseVec::new(self.len);
        out.idx.reserve(self.nnz() + other.nnz());
        out.val.reserve(self.nnz() + other.nnz());
        let (mut a, mut b) = (0, 0);
        while a < self.nnz() && b < other.nnz() {
            match self.idx[a].cmp(&other.idx[b]) {
                std::cmp::Ordering::Less => {
                    out.idx.push(self.idx[a]);
                    out.val.push(self.val[a]);
                    a += 1;
                }
                std::cmp::Ordering::Greater => {
                    out.idx.push(other.idx[b]);
                    out.val.push(other.val[b]);
                    b += 1;
                }
                std::cmp::Ordering::Equal => {
                    out.idx.push(self.idx[a]);
                    out.val.push(self.val[a] + other.val[b]);
                    a += 1;
                    b += 1;
                }
            }
        }
        for i in a..self.nnz() {
            out.idx.push(self.idx[i]);
            out.val.push(self.val[i]);
        }
        for i in b..other.nnz() {
            out.idx.push(other.idx[i]);
            out.val.push(other.val[i]);
        }
        out
    }
}

/// The PR-1 scalar sparse reduction, verbatim — the bit-exactness
/// reference for the SIMD gather path (and the scalar/NEON `KernelSet`
/// member): one f32 add per (index, value) pair, indices ascending.
pub(crate) fn sparse_add_scalar(idx: &[u32], val: &[f32], out: &mut [f32]) {
    for (&i, &v) in idx.iter().zip(val.iter()) {
        out[i as usize] += v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sparse_random(n: usize, nnz: usize, seed: u64) -> SparseVec {
        let mut rng = Rng::new(seed);
        let mut dense = vec![0.0f32; n];
        for i in rng.sample_distinct(n, nnz) {
            dense[i] = rng.normal_f32();
        }
        SparseVec::from_dense(&dense)
    }

    #[test]
    fn dense_round_trip() {
        let x = vec![0.0f32, 1.5, 0.0, -2.0, 0.0, 3.0];
        let s = SparseVec::from_dense(&x);
        assert_eq!(s.nnz(), 3);
        assert_eq!(s.to_dense(), x);
        assert_eq!(s.wire_bytes(), 24);
    }

    #[test]
    fn threshold_encode_matches_mask() {
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..512).map(|_| rng.normal_f32()).collect();
        let thr = crate::sparsify::topk::kth_largest_abs(&x, 50);
        let s = SparseVec::from_dense_threshold(&x, thr);
        let (masked, _) = crate::sparsify::topk::topk_mask(&x, 50);
        assert_eq!(s.to_dense(), masked);
    }

    #[test]
    fn bytes_round_trip() {
        let s = sparse_random(1000, 64, 2);
        let b = s.to_bytes();
        assert_eq!(b.len(), 8 + 64 * 8);
        let s2 = SparseVec::from_bytes(&b).unwrap();
        assert_eq!(s, s2);
    }

    #[test]
    fn bytes_reject_truncated() {
        let s = sparse_random(100, 10, 3);
        let b = s.to_bytes();
        assert!(SparseVec::from_bytes(&b[..b.len() - 1]).is_err());
        assert!(SparseVec::from_bytes(&b[..4]).is_err());
    }

    #[test]
    fn merge_equals_dense_sum() {
        let a = sparse_random(300, 40, 4);
        let b = sparse_random(300, 40, 5);
        let m = a.merge(&b);
        let mut expect = a.to_dense();
        for (e, v) in expect.iter_mut().zip(b.to_dense()) {
            *e += v;
        }
        assert_eq!(m.to_dense(), expect);
        // indices stay sorted
        assert!(m.idx.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn add_scaled() {
        let a = sparse_random(50, 5, 6);
        let mut out = vec![0.0f32; 50];
        a.add_scaled_into(0.5, &mut out);
        let expect: Vec<f32> = a.to_dense().iter().map(|v| v * 0.5).collect();
        assert_eq!(out, expect);
    }
}
