//! Per-worker, per-layer error-feedback state (Algorithm 1, lines 7-8).
//!
//! Each worker keeps the residual `eps_t^{p,(l)}` — the mass its TopK
//! dropped — and folds it into the next iteration's accumulator:
//!
//! ```text
//! acc  = eps + lr * grad          (line 7)
//! eps' = acc - TopK(acc, k)       (line 8)
//! ```
//!
//! The invariant `TopK(acc,k) + eps' == acc` holds exactly in f32 because
//! the split only moves elements, never rounds.

use super::topk;
use crate::sparsify::sparse::SparseVec;
use crate::sparsify::threshold::SampledThreshold;

/// Residual state for one worker across the whole flat parameter vector,
/// sliced per layer by the caller.
#[derive(Debug, Clone)]
pub struct ErrorFeedback {
    resid: Vec<f32>,
    /// scratch accumulator reused across layers (no alloc in the hot loop)
    acc: Vec<f32>,
    /// scratch |acc| buffer for the quickselect (§Perf L3-1)
    mags: Vec<f32>,
    sampler: SampledThreshold,
}

/// Result of one layer compression (borrowed views into internal buffers
/// would complicate lifetimes; the kept vector is written by the caller).
pub struct CompressStats {
    pub threshold: f32,
    pub kept: usize,
}

impl ErrorFeedback {
    pub fn new(d: usize, sample_stride: usize) -> Self {
        ErrorFeedback {
            resid: vec![0.0; d],
            acc: Vec::new(),
            mags: Vec::new(),
            sampler: SampledThreshold::new(sample_stride),
        }
    }

    pub fn dim(&self) -> usize {
        self.resid.len()
    }

    pub fn residual(&self) -> &[f32] {
        &self.resid
    }

    /// Residual slice for one layer (XLA compress path reads this).
    pub fn residual_slice(&self, off: usize, n: usize) -> &[f32] {
        &self.resid[off..off + n]
    }

    /// Overwrite one layer's residual (XLA compress path writes back).
    pub fn write_residual(&mut self, off: usize, data: &[f32]) {
        self.resid[off..off + data.len()].copy_from_slice(data);
    }

    /// Fold mass back into the residual at flat coordinate `i`. Used by
    /// the robustness layer: a quorum-excluded worker's already-compressed
    /// message re-enters its own accumulator here (bounded staleness), and
    /// a departing worker's residual is re-sharded into survivors
    /// coordinate-by-coordinate (elastic membership).
    pub fn add_residual_at(&mut self, i: usize, v: f32) {
        self.resid[i] += v;
    }

    /// Residual L2^2 — diagnostic for how much mass is deferred.
    pub fn residual_norm_sq(&self) -> f64 {
        self.resid.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Compress one layer slice [off, off+n): fold lr*grad into the stored
    /// residual, write the TopK part into `kept[0..n]`, keep the rest as the
    /// new residual. `exact` selects exact vs double-sampling threshold.
    pub fn compress_layer(
        &mut self,
        off: usize,
        grad: &[f32],
        lr: f32,
        k: usize,
        exact: bool,
        kept: &mut [f32],
    ) -> CompressStats {
        let n = grad.len();
        debug_assert_eq!(kept.len(), n);
        let resid = &mut self.resid[off..off + n];

        // acc = resid + lr * grad (scratch)
        self.acc.clear();
        self.acc.extend(resid.iter().zip(grad.iter()).map(|(&r, &g)| r + lr * g));

        let thr = if exact {
            topk::kth_largest_abs_with_buf(&self.acc, k, &mut self.mags)
        } else {
            self.sampler.estimate(&self.acc, k)
        };
        topk::split_with_threshold(&self.acc, thr, kept, resid);
        CompressStats { threshold: thr, kept: topk::count_kept(&self.acc, thr) }
    }

    /// Like [`Self::compress_layer`], but emits the TopK part directly as a
    /// sparse `(index, value)` message instead of a dense masked buffer —
    /// the Algorithm 1 line 9 wire format. One pass over the accumulator
    /// splits it: coordinates at or above the threshold go into `msg`
    /// (indices local to the layer slice), the rest become the new
    /// residual. `msg`'s buffers are reused, so the steady-state hot loop
    /// performs no allocation. The kept set (and therefore `msg.nnz()`)
    /// matches the dense variant exactly, including ties at the threshold.
    pub fn compress_layer_sparse(
        &mut self,
        off: usize,
        grad: &[f32],
        lr: f32,
        k: usize,
        exact: bool,
        msg: &mut SparseVec,
    ) -> CompressStats {
        let n = grad.len();
        let resid = &mut self.resid[off..off + n];

        // acc = resid + lr * grad (scratch)
        self.acc.clear();
        self.acc.extend(resid.iter().zip(grad.iter()).map(|(&r, &g)| r + lr * g));

        let thr = if exact {
            topk::kth_largest_abs_with_buf(&self.acc, k, &mut self.mags)
        } else {
            self.sampler.estimate(&self.acc, k)
        };

        msg.len = n;
        msg.idx.clear();
        msg.val.clear();
        for (i, (&v, r)) in self.acc.iter().zip(resid.iter_mut()).enumerate() {
            if v.abs() >= thr {
                msg.idx.push(i as u32);
                msg.val.push(v);
                *r = 0.0;
            } else {
                *r = v;
            }
        }
        CompressStats { threshold: thr, kept: msg.nnz() }
    }

    /// Form one layer's accumulator `acc = resid + lr*grad` in the scratch
    /// buffer and hand back `(acc, resid)` as simultaneously-borrowed
    /// slices (disjoint fields, so the borrows coexist). This is the
    /// entry point for trait-based compressors: the caller follows up
    /// with `Compressor::split(ctx, acc, k, msg, resid)`, which overwrites
    /// the residual — exactly the state transition
    /// [`Self::compress_layer_sparse`] performs for TopK.
    pub fn accumulate(&mut self, off: usize, grad: &[f32], lr: f32) -> (&[f32], &mut [f32]) {
        let n = grad.len();
        let resid = &mut self.resid[off..off + n];
        self.acc.clear();
        self.acc.extend(resid.iter().zip(grad.iter()).map(|(&r, &g)| r + lr * g));
        (&self.acc, resid)
    }

    /// The accumulator (resid + lr*grad) for a layer WITHOUT updating state.
    /// Used by the delta^(l) measurement (Eq. 20), which needs x^{p,(l)} =
    /// G^p + eps^p before compression.
    pub fn peek_acc(&self, off: usize, grad: &[f32], lr: f32) -> Vec<f32> {
        self.resid[off..off + grad.len()]
            .iter()
            .zip(grad.iter())
            .map(|(&r, &g)| r + lr * g)
            .collect()
    }

    pub fn reset(&mut self) {
        self.resid.iter_mut().for_each(|v| *v = 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn mass_conservation_per_step() {
        let mut rng = Rng::new(1);
        let n = 256;
        let mut ef = ErrorFeedback::new(n, 4);
        let mut kept = vec![0.0f32; n];
        for step in 0..20 {
            let grad: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let before = ef.peek_acc(0, &grad, 0.1);
            ef.compress_layer(0, &grad, 0.1, 16, true, &mut kept);
            for i in 0..n {
                let total = kept[i] + ef.residual()[i];
                assert!((total - before[i]).abs() < 1e-6, "step {step} i {i}");
            }
        }
    }

    #[test]
    fn layered_slices_are_independent() {
        let mut rng = Rng::new(2);
        let mut ef = ErrorFeedback::new(100, 4);
        let g1: Vec<f32> = (0..40).map(|_| rng.normal_f32()).collect();
        let g2: Vec<f32> = (0..60).map(|_| rng.normal_f32()).collect();
        let mut k1 = vec![0.0f32; 40];
        let mut k2 = vec![0.0f32; 60];
        ef.compress_layer(0, &g1, 1.0, 4, true, &mut k1);
        let resid_l1: Vec<f32> = ef.residual()[..40].to_vec();
        ef.compress_layer(40, &g2, 1.0, 6, true, &mut k2);
        // compressing layer 2 must not touch layer 1 residual
        assert_eq!(&ef.residual()[..40], resid_l1.as_slice());
    }

    #[test]
    fn residual_accumulates_dropped_mass() {
        let mut ef = ErrorFeedback::new(4, 1);
        let grad = vec![10.0f32, 1.0, 0.1, 0.01];
        let mut kept = vec![0.0f32; 4];
        let stats = ef.compress_layer(0, &grad, 1.0, 1, true, &mut kept);
        assert_eq!(stats.kept, 1);
        assert_eq!(kept, vec![10.0, 0.0, 0.0, 0.0]);
        assert_eq!(ef.residual(), &[0.0, 1.0, 0.1, 0.01]);
        // second step: residual + new grad competes for top-1
        let stats2 = ef.compress_layer(0, &[0.0, 1.0, 0.0, 0.0], 1.0, 1, true, &mut kept);
        assert_eq!(stats2.kept, 1);
        assert_eq!(kept, vec![0.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn sampled_threshold_path_conserves_mass() {
        let mut rng = Rng::new(3);
        let n = 4096;
        let mut ef = ErrorFeedback::new(n, 16);
        let grad: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let before = ef.peek_acc(0, &grad, 0.5);
        let mut kept = vec![0.0f32; n];
        ef.compress_layer(0, &grad, 0.5, 40, false, &mut kept);
        for i in 0..n {
            assert!((kept[i] + ef.residual()[i] - before[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn sparse_variant_matches_dense_variant() {
        // compress_layer_sparse must produce the same residuals, kept set
        // and values as compress_layer — bit-identical, for both the exact
        // and sampled threshold paths, across repeated (stateful) steps.
        let mut rng = Rng::new(7);
        let n = 512;
        for exact in [true, false] {
            let mut dense_ef = ErrorFeedback::new(n, 8);
            let mut sparse_ef = ErrorFeedback::new(n, 8);
            let mut kept = vec![0.0f32; n];
            let mut msg = SparseVec::new(n);
            for step in 0..10 {
                let grad: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
                let sd = dense_ef.compress_layer(0, &grad, 0.1, 24, exact, &mut kept);
                let ss = sparse_ef.compress_layer_sparse(0, &grad, 0.1, 24, exact, &mut msg);
                assert_eq!(sd.threshold, ss.threshold, "exact={exact} step={step}");
                assert_eq!(sd.kept, ss.kept, "exact={exact} step={step}");
                assert_eq!(msg.to_dense(), kept, "exact={exact} step={step}");
                assert_eq!(dense_ef.residual(), sparse_ef.residual());
            }
        }
    }

    #[test]
    fn sparse_variant_reuses_buffers_per_layer() {
        let mut rng = Rng::new(8);
        let mut ef = ErrorFeedback::new(100, 4);
        let g1: Vec<f32> = (0..40).map(|_| rng.normal_f32()).collect();
        let g2: Vec<f32> = (0..60).map(|_| rng.normal_f32()).collect();
        let mut msg = SparseVec::new(0);
        ef.compress_layer_sparse(0, &g1, 1.0, 4, true, &mut msg);
        assert_eq!(msg.len, 40);
        assert!(msg.nnz() >= 4);
        assert!(msg.idx.iter().all(|&i| (i as usize) < 40));
        // reuse the same message buffer for a second layer slice
        ef.compress_layer_sparse(40, &g2, 1.0, 6, true, &mut msg);
        assert_eq!(msg.len, 60);
        assert!(msg.nnz() >= 6);
        // kept + residual reconstruct the accumulator on the second layer
        let dense = msg.to_dense();
        for i in 0..60 {
            let total = dense[i] + ef.residual()[40 + i];
            assert!((total - g2[i]).abs() < 1e-6, "i={i}");
        }
    }

    #[test]
    fn reset_clears() {
        let mut ef = ErrorFeedback::new(8, 1);
        let mut kept = vec![0.0f32; 8];
        // distinct magnitudes so top-2 actually drops mass into the residual
        let grad: Vec<f32> = (1..=8).map(|i| i as f32).collect();
        ef.compress_layer(0, &grad, 1.0, 2, true, &mut kept);
        assert!(ef.residual_norm_sq() > 0.0);
        ef.reset();
        assert_eq!(ef.residual_norm_sq(), 0.0);
    }
}
