//! Double-sampling threshold estimation (Lin et al. 2018, §System of the
//! LAGS paper heuristic 2): instead of an exact O(n log n) selection over
//! the full accumulator, estimate the k-th largest |x| from a subsample.
//!
//! The paper uses this to cut the GPU top-k time; here it cuts the host
//! selection cost from O(n) over the full layer to O(s) over the sample,
//! which matters for the biggest layers of the DES profiles.

use super::topk::{kth_largest_abs, kth_largest_abs_with_buf};
use crate::util::rng::Rng;

/// Strided deterministic sampling — mirrors the Pallas artifact
/// (`compress_sampled` with `sample_idx = arange(0, n, stride)`), so the
/// host and XLA paths produce identical thresholds.
pub fn sampled_threshold(x: &[f32], k: usize, stride: usize) -> f32 {
    sampled_threshold_with_buf(x, k, stride, &mut Vec::new(), &mut Vec::new())
}

/// Allocation-free form of [`sampled_threshold`] for hot loops: `sample`
/// and `mags` are reusable scratch vectors (cleared and refilled).
pub fn sampled_threshold_with_buf(
    x: &[f32],
    k: usize,
    stride: usize,
    sample: &mut Vec<f32>,
    mags: &mut Vec<f32>,
) -> f32 {
    let n = x.len();
    if n == 0 || k == 0 {
        return f32::INFINITY;
    }
    let stride = stride.max(1);
    sample.clear();
    sample.extend(x.iter().step_by(stride).copied());
    let s = sample.len();
    // ceil(k * s / n), clamped to [1, s] — matches ref.sampled_threshold_ref
    let ks = ((k * s + n - 1) / n).clamp(1, s);
    kth_largest_abs_with_buf(sample, ks, mags)
}

/// PRNG-sampled variant (what a GPU implementation would do); statistically
/// equivalent to the strided variant on exchangeable inputs.
pub fn sampled_threshold_random(x: &[f32], k: usize, s: usize, rng: &mut Rng) -> f32 {
    let n = x.len();
    if n == 0 || k == 0 {
        return f32::INFINITY;
    }
    let s = s.clamp(1, n);
    let sample: Vec<f32> = (0..s).map(|_| x[rng.below(n)]).collect();
    let ks = ((k * s + n - 1) / n).clamp(1, s);
    kth_largest_abs(&sample, ks)
}

/// Reusable sampled-threshold state (avoids re-allocating the sample and
/// quickselect buffers in the trainer hot loop — the non-buf
/// `kth_largest_abs` allocates per call, §Perf L3-1).
#[derive(Debug, Clone)]
pub struct SampledThreshold {
    stride: usize,
    sample: Vec<f32>,
    mags: Vec<f32>,
}

impl SampledThreshold {
    pub fn new(stride: usize) -> Self {
        SampledThreshold { stride: stride.max(1), sample: Vec::new(), mags: Vec::new() }
    }

    pub fn estimate(&mut self, x: &[f32], k: usize) -> f32 {
        sampled_threshold_with_buf(x, k, self.stride, &mut self.sample, &mut self.mags)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsify::topk;
    use crate::util::rng::Rng;

    #[test]
    fn stride_one_is_exact() {
        let mut r = Rng::new(1);
        let x: Vec<f32> = (0..500).map(|_| r.normal_f32()).collect();
        assert_eq!(sampled_threshold(&x, 50, 1), kth_largest_abs(&x, 50));
    }

    #[test]
    fn estimate_close_on_gaussian() {
        let mut r = Rng::new(2);
        let n = 65536;
        let x: Vec<f32> = (0..n).map(|_| r.normal_f32()).collect();
        let k = n / 100;
        let exact = kth_largest_abs(&x, k);
        let est = sampled_threshold(&x, k, 64);
        // kept-count within 4x of target
        let kept = topk::count_kept(&x, est);
        assert!(kept >= k / 4 && kept <= k * 4, "kept={kept} k={k} est={est} exact={exact}");
    }

    #[test]
    fn random_variant_reasonable() {
        let mut r = Rng::new(3);
        let n = 32768;
        let x: Vec<f32> = (0..n).map(|_| r.normal_f32()).collect();
        let k = n / 50;
        let est = sampled_threshold_random(&x, k, n / 32, &mut r);
        let kept = topk::count_kept(&x, est);
        assert!(kept >= k / 4 && kept <= k * 4, "kept={kept} k={k}");
    }

    #[test]
    fn reusable_state_matches_free_fn() {
        let mut r = Rng::new(4);
        let x: Vec<f32> = (0..4096).map(|_| r.normal_f32()).collect();
        let mut st = SampledThreshold::new(16);
        assert_eq!(st.estimate(&x, 40), sampled_threshold(&x, 40, 16));
        // reuse on a second vector
        let y: Vec<f32> = (0..2048).map(|_| r.normal_f32()).collect();
        assert_eq!(st.estimate(&y, 20), sampled_threshold(&y, 20, 16));
    }

    #[test]
    fn degenerate_inputs() {
        assert!(sampled_threshold(&[], 5, 4).is_infinite());
        assert!(sampled_threshold(&[1.0], 0, 4).is_infinite());
    }
}
